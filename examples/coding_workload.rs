//! AI-coding workload: ARL-Tangram vs Kubernetes pods, side by side
//! (the paper's §6.2 coding row and §6.3 CPU-scaling story at one setting).
//!
//! Shows the two over-provisioning effects the paper targets: trajectory-
//! lifetime reservation (pods idle between actions) and the lack of elastic
//! DoP for the long-tailed reward computation.
//!
//! Run: `cargo run --release --example coding_workload -- --batch 256`

use arl_tangram::action::{ActionKind, TaskId};
use arl_tangram::baselines::{BaselineBackend, K8sCfg};
use arl_tangram::coordinator::{run, Backend, RunCfg, TangramBackend, TangramCfg};
use arl_tangram::metrics::Metrics;
use arl_tangram::rollout::workloads::{Catalog, CatalogCfg, Workload, WorkloadKind};
use arl_tangram::util::cli::Args;

fn report(name: &str, m: &Metrics) {
    let (exec, queue, ovh) = m.act_breakdown();
    println!("--- {name}");
    println!("  mean ACT        : {:8.2}s (p99 {:.2}s)", m.mean_act(), m.p99_act());
    println!(
        "  env-exec ACT    : {:8.2}s   reward ACT: {:.2}s",
        m.mean_act_of(ActionKind::EnvExec),
        m.mean_act_of(ActionKind::RewardCpu)
    );
    println!("  exec/queue/ovh  : {exec:.2}s / {queue:.2}s / {ovh:.3}s");
    println!("  step duration   : {:8.2}s", m.mean_step_dur());
    println!("  cpu utilization : {:8.3}", m.mean_util("cpu"));
}

fn main() {
    let args = Args::new("AI-coding workload: ARL-Tangram vs K8s")
        .opt("batch", "256", "trajectories per RL step")
        .opt("steps", "2", "RL steps")
        .opt("cores-per-node", "256", "cores per CPU node")
        .opt("nodes", "5", "CPU nodes")
        .opt("seed", "1", "rng seed")
        .parse()
        .unwrap_or_else(|u| {
            eprintln!("{u}");
            std::process::exit(2)
        });
    let nodes = args.u64("nodes") as u32;
    let cores = args.u64("cores-per-node") as u32;

    let cat = Catalog::build(&CatalogCfg {
        cpu_nodes: nodes,
        cores_per_node: cores,
        ..CatalogCfg::default()
    });
    let wl = Workload::new(TaskId(0), WorkloadKind::Coding);
    let cfg = RunCfg {
        batch: args.u64("batch") as usize,
        steps: args.u64("steps") as u32,
        seed: args.u64("seed"),
        ..RunCfg::default()
    };

    let mut tangram = TangramBackend::new(
        &cat,
        TangramCfg {
            cpu_nodes: nodes,
            cores_per_numa: cores / 2,
            ..TangramCfg::default()
        },
    );
    let m_tangram = run(&mut tangram, &cat, &[wl.clone()], &cfg);

    let mut k8s = BaselineBackend::coding(
        &cat,
        K8sCfg { nodes, cores_per_node: cores, ..K8sCfg::default() },
    );
    let m_k8s = run(&mut k8s, &cat, &[wl], &cfg);

    println!(
        "AI coding, batch={} steps={} cores={}\n",
        cfg.batch,
        cfg.steps,
        nodes * cores
    );
    report("arl-tangram", &m_tangram);
    report("k8s baseline", &m_k8s);
    println!(
        "\nspeedup: mean ACT {:.2}x | step duration {:.2}x | sched decisions {} (avg {:?})",
        m_k8s.mean_act() / m_tangram.mean_act().max(1e-9),
        m_k8s.mean_step_dur() / m_tangram.mean_step_dur().max(1e-9),
        tangram.sched_invocations,
        tangram.mean_sched_latency(),
    );
}
