//! End-to-end validation: real GRPO training of a small transformer policy
//! with reward scoring routed through the ARL-Tangram machinery.
//!
//! All three layers compose here, with Python nowhere on the path:
//!   L1/L2 — the Pallas-attention transformer and GRPO train step, AOT-lowered
//!           to HLO and executed via PJRT (`runtime::{Trainer, RewardModel}`);
//!   L3   — reward-scoring requests become *actions* scheduled by the elastic
//!          algorithm onto the EOE GPU manager (warm/cold accounting, chunked
//!          allocation), exactly like the paper's reward services.
//!
//! Per step: sample a group of completions from the policy (autoregressive,
//! on-device forward), score them through the coordinator, GRPO-normalize
//! advantages within the group, and apply one Adam step. Logs the loss curve
//! and per-step reward to stdout + `e2e_training_curve.csv`.
//!
//! Run: `cargo run --release --example e2e_grpo_training -- --steps 150`

use arl_tangram::action::{
    Action, ActionId, ActionKind, ActionSpec, CostSpec, DimCost, ElasticityModel,
    ResourceClass, ResourceKindId, ResourceRegistry, ServiceId, TaskId, TrajId,
};
use arl_tangram::cluster::gpu::RestoreModel;
use arl_tangram::managers::{GpuManager, ServiceSpec};
use arl_tangram::runtime::{PjrtEngine, RewardModel, Trainer};
use arl_tangram::scheduler::{ElasticScheduler, ResourceState, SchedulerConfig};
use arl_tangram::sim::{SimDur, SimTime};
use arl_tangram::util::cli::Args;
use arl_tangram::util::rng::Rng;
use std::collections::HashMap;
use std::io::Write;
use std::time::Instant;

fn softmax_sample(logits: &[f32], temp: f32, rng: &mut Rng) -> usize {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&l| ((l - m) / temp).exp()).collect();
    let total: f32 = exps.iter().sum();
    let mut u = rng.f64() as f32 * total;
    for (i, e) in exps.iter().enumerate() {
        u -= e;
        if u <= 0.0 {
            return i;
        }
    }
    exps.len() - 1
}

fn main() -> arl_tangram::util::error::Result<()> {
    let args = Args::new("e2e GRPO training through ARL-Tangram")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("steps", "150", "training steps")
        .opt("lr", "0.0003", "Adam learning rate")
        .opt("gen-tokens", "24", "completion length sampled per sequence")
        .opt("temp", "1.0", "sampling temperature")
        .opt("seed", "7", "rng seed")
        .opt("csv", "e2e_training_curve.csv", "loss-curve output")
        .parse()
        .unwrap_or_else(|u| {
            eprintln!("{u}");
            std::process::exit(2)
        });

    let t_load = Instant::now();
    let eng = PjrtEngine::load(args.str("artifacts"))?;
    println!(
        "loaded {} artifacts on {} in {:.1}s (policy {:.1}M params)",
        eng.meta.artifacts.len(),
        eng.platform(),
        t_load.elapsed().as_secs_f64(),
        eng.meta.policy.param_count as f64 / 1e6,
    );
    let mut trainer = Trainer::init(&eng, args.u64("seed") as u32)?;
    let judge = RewardModel::init(&eng, 1 + args.u64("seed") as u32)?;
    let (b, s) = (trainer.batch, trainer.seq);
    let gen_tokens = (args.u64("gen-tokens") as usize).min(s - 2);
    let prompt_len = s - gen_tokens;

    // ---- L3: the judge as a managed GPU service -------------------------
    let mut registry = ResourceRegistry::new();
    let gpu_kind = registry.register("gpu_units", ResourceClass::GpuUnits, 8);
    let svc = ServiceSpec {
        id: ServiceId(0),
        name: "judge".into(),
        weights_gb: eng.meta.reward.param_count as f64 * 4.0 / 1e9,
        dop_choices: vec![1, 2, 4, 8],
        efficiency: vec![1.0, 0.92, 0.85, 0.82, 0.72, 0.68, 0.65, 0.62],
    };
    let mut gpu = GpuManager::new(1, RestoreModel::default(), vec![svc]);
    gpu.prewarm(SimTime::ZERO);
    let sched = ElasticScheduler::new(SchedulerConfig::default());

    let mut rng = Rng::new(args.u64("seed"));
    let steps = args.u64("steps") as u32;
    let lr = args.f64("lr") as f32;
    let temp = args.f64("temp") as f32;
    let mut csv = std::fs::File::create(args.str("csv"))?;
    writeln!(csv, "step,loss,mean_reward,act_ms,warm_ratio,step_secs")?;

    let mut next_action = 0u64;
    let run_start = Instant::now();
    println!("training {steps} steps: batch={b} seq={s} prompt={prompt_len} gen={gen_tokens}");

    for step in 0..steps {
        let t_step = Instant::now();

        // ---- rollout: autoregressive sampling on-device -----------------
        let mut tokens = vec![0i32; b * s];
        for (row, chunk) in tokens.chunks_mut(s).enumerate() {
            let _ = row;
            for (p, t) in chunk.iter_mut().take(prompt_len).enumerate() {
                *t = (p % 17) as i32 + 1; // shared prompt → one GRPO group
            }
        }
        for t in prompt_len..s {
            let logits = trainer.logits(&tokens)?;
            let vocab = trainer.vocab;
            for row in 0..b {
                let off = (row * s + (t - 1)) * vocab;
                let tok = softmax_sample(&logits[off..off + vocab], temp, &mut rng);
                tokens[row * s + t] = tok as i32;
            }
        }

        // ---- reward scoring as scheduled actions -------------------------
        // one action per judge micro-batch, flowing through the elastic
        // scheduler + EOE GPU manager with real compute as the payload
        let rb = judge.batch;
        let n_chunks = b.div_ceil(rb);
        let mut rewards = vec![0f32; b];
        let mut acts_ms: Vec<f64> = Vec::new();
        let virt_now = SimTime(step as u64 * 1_000_000_000);
        for chunk in 0..n_chunks {
            let id = ActionId(next_action);
            next_action += 1;
            let spec = ActionSpec {
                task: TaskId(0),
                trajectory: TrajId(chunk as u64),
                kind: ActionKind::RewardModel,
                cost: CostSpec::single(&registry, gpu_kind, DimCost::Discrete(vec![1, 2, 4, 8])),
                key_resource: Some(gpu_kind),
                elasticity: ElasticityModel::Table(vec![1.0, 0.92, 0.85, 0.82]),
                profiled_dur: Some(SimDur::from_millis(50)),
                service: Some(ServiceId(0)),
                true_dur: SimDur::from_millis(50),
            };
            let action = Action::new(id, spec, virt_now);
            let queue = [&action];
            let mut pools: HashMap<ResourceKindId, &dyn ResourceState> = HashMap::new();
            pools.insert(gpu_kind, &gpu);
            let decisions = sched.schedule(virt_now, &queue, &pools);
            let units = decisions.first().map(|d| d.units).unwrap_or(1);
            let t_act = Instant::now();
            let _lease = gpu
                .allocate(id, ServiceId(0), units as u8, virt_now)
                .map_err(arl_tangram::util::error::Error::from)?;
            // real compute: build the judge micro-batch and score it.
            // The judge window is the *tail* of each sequence so the
            // generated region is always visible to the reward model.
            let rs = judge.seq.min(s);
            let tail = s - rs;
            let mut jt = vec![0i32; rb * judge.seq];
            let mut jm = vec![0f32; rb * judge.seq];
            for r in 0..rb {
                let src = (chunk * rb + r).min(b - 1);
                for p in 0..rs {
                    jt[r * judge.seq + p] = tokens[src * s + tail + p];
                    jm[r * judge.seq + p] = 1.0;
                }
            }
            let scores = judge.score(&jt, &jm)?;
            for r in 0..rb {
                let dst = chunk * rb + r;
                if dst < b {
                    rewards[dst] = scores[r];
                }
            }
            gpu.complete(id, virt_now).map_err(arl_tangram::util::error::Error::from)?;
            acts_ms.push(t_act.elapsed().as_secs_f64() * 1e3);
        }

        // ---- GRPO: group-relative advantages -----------------------------
        let mean_r: f32 = rewards.iter().sum::<f32>() / b as f32;
        let var: f32 =
            rewards.iter().map(|r| (r - mean_r) * (r - mean_r)).sum::<f32>() / b as f32;
        let std = var.sqrt().max(1e-4);
        let adv: Vec<f32> = rewards.iter().map(|r| (r - mean_r) / std).collect();

        // mask: train only on the generated region
        let mut mask = vec![0f32; b * (s - 1)];
        for row in 0..b {
            for t in (prompt_len - 1)..(s - 1) {
                mask[row * (s - 1) + t] = 1.0;
            }
        }
        let old_logp = trainer.logprobs(&tokens)?;
        let loss = trainer.train_step(&tokens, &mask, &adv, &old_logp, lr)?;

        let act_ms = acts_ms.iter().sum::<f64>() / acts_ms.len() as f64;
        let step_secs = t_step.elapsed().as_secs_f64();
        writeln!(
            csv,
            "{step},{loss},{mean_r},{act_ms:.2},{:.3},{step_secs:.2}",
            gpu.warm_ratio()
        )?;
        if step % 10 == 0 || step + 1 == steps {
            println!(
                "step {step:4}  loss {loss:+.4}  mean_reward {mean_r:+.4}  \
                 act {act_ms:6.1}ms  warm {:.0}%  ({step_secs:.1}s)",
                gpu.warm_ratio() * 100.0
            );
        }
    }
    println!(
        "done in {:.1}s — loss curve in {}; trainer at step {}",
        run_start.elapsed().as_secs_f64(),
        args.str("csv"),
        trainer.step_count()?
    );
    Ok(())
}
