//! MOPD + DeepSearch sharing one GPU pool ("MOPD+Search", paper §6.2):
//! ten reward services multiplexed by the EOE GPU manager vs a static
//! per-service deployment. Demonstrates task-level pooling — the paper's
//! second over-provisioning category.
//!
//! Run: `cargo run --release --example multitask_gpu_sharing -- --batch 128`

use arl_tangram::action::{ActionKind, TaskId};
use arl_tangram::baselines::BaselineBackend;
use arl_tangram::coordinator::{run, RunCfg, TangramBackend, TangramCfg};
use arl_tangram::metrics::Metrics;
use arl_tangram::rollout::workloads::{Catalog, CatalogCfg, Workload, WorkloadKind};
use arl_tangram::util::cli::Args;

fn rm_act(m: &Metrics) -> f64 {
    m.mean_act_of(ActionKind::RewardModel)
}

fn main() {
    let args = Args::new("MOPD+DeepSearch GPU sharing: Tangram vs static services")
        .opt("batch", "128", "trajectories per step per task")
        .opt("gpu-nodes", "5", "8-GPU nodes")
        .opt("seed", "3", "rng seed")
        .parse()
        .unwrap_or_else(|u| {
            eprintln!("{u}");
            std::process::exit(2)
        });

    let cat = Catalog::build(&CatalogCfg {
        gpu_nodes: args.u64("gpu-nodes") as u32,
        ..CatalogCfg::default()
    });
    let wls = [
        Workload::new(TaskId(1), WorkloadKind::DeepSearch),
        Workload::new(TaskId(2), WorkloadKind::Mopd),
    ];
    let cfg = RunCfg {
        batch: args.u64("batch") as usize,
        steps: 1,
        seed: args.u64("seed"),
        ..RunCfg::default()
    };

    let mut tangram = TangramBackend::new(
        &cat,
        TangramCfg { gpu_nodes: args.u64("gpu-nodes") as u32, ..TangramCfg::default() },
    );
    let m_t = run(&mut tangram, &cat, &wls, &cfg);

    let mut stat = BaselineBackend::mopd_search(&cat);
    let m_s = run(&mut stat, &cat, &wls, &cfg);

    println!("MOPD+Search, batch={} per task, {} GPUs\n", cfg.batch, args.u64("gpu-nodes") * 8);
    println!("                        tangram      static");
    println!("reward-model ACT   : {:8.2}s  {:10.2}s", rm_act(&m_t), rm_act(&m_s));
    println!("overall mean ACT   : {:8.2}s  {:10.2}s", m_t.mean_act(), m_s.mean_act());
    println!(
        "mean step duration : {:8.2}s  {:10.2}s",
        m_t.mean_step_dur(),
        m_s.mean_step_dur()
    );
    println!(
        "gpu utilization    : {:8.3}   {:9.3}",
        m_t.mean_util("gpu"),
        m_s.mean_util("gpu")
    );
    println!(
        "\nEOE cache: {} warm / {} cold ({:.0}% warm), restore total {:?}",
        tangram.gpu.n_warm,
        tangram.gpu.n_cold,
        tangram.gpu.warm_ratio() * 100.0,
        tangram.gpu.restore_time_total,
    );
    println!(
        "speedup: reward ACT {:.2}x, step {:.2}x",
        rm_act(&m_s) / rm_act(&m_t).max(1e-9),
        m_s.mean_step_dur() / m_t.mean_step_dur().max(1e-9)
    );
}
