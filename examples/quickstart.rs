//! Quickstart: the ARL-Tangram public API in ~60 lines.
//!
//! Builds the default external-resource catalog, deploys the coordinator,
//! runs one small AI-coding RL step in the discrete-event simulator, and
//! prints ACT statistics — compare against the Kubernetes baseline by
//! flipping `--backend k8s`.
//!
//! Run: `cargo run --release --example quickstart -- --batch 64`

use arl_tangram::action::TaskId;
use arl_tangram::baselines::{BaselineBackend, K8sCfg};
use arl_tangram::coordinator::{run, Backend, RunCfg, TangramBackend, TangramCfg};
use arl_tangram::rollout::workloads::{Catalog, CatalogCfg, Workload, WorkloadKind};
use arl_tangram::util::cli::Args;

fn main() {
    let args = Args::new("ARL-Tangram quickstart")
        .opt("backend", "tangram", "tangram | k8s")
        .opt("batch", "64", "trajectories per RL step")
        .opt("steps", "1", "RL steps")
        .opt("seed", "42", "rng seed")
        .parse()
        .unwrap_or_else(|u| {
            eprintln!("{u}");
            std::process::exit(2)
        });

    // 1. describe the external world: CPU cluster, GPU cluster, APIs
    let cat = Catalog::build(&CatalogCfg::default());

    // 2. pick a workload (AI coding: multi-turn env actions + scalable reward)
    let wl = Workload::new(TaskId(0), WorkloadKind::Coding);

    // 3. deploy a backend and run the simulated RL training loop
    let cfg = RunCfg {
        batch: args.u64("batch") as usize,
        steps: args.u64("steps") as u32,
        seed: args.u64("seed"),
        ..RunCfg::default()
    };
    let mut tangram;
    let mut k8s;
    let backend: &mut dyn Backend = match args.str("backend").as_str() {
        "k8s" => {
            k8s = BaselineBackend::coding(&cat, K8sCfg::default());
            &mut k8s
        }
        _ => {
            tangram = TangramBackend::new(&cat, TangramCfg::default());
            &mut tangram
        }
    };
    let name = backend.name();
    let m = run(backend, &cat, &[wl], &cfg);

    // 4. inspect the metrics
    println!("backend            : {name}");
    println!("trajectories       : {}", m.trajectories.len());
    println!("actions            : {}", m.actions.len());
    println!("mean ACT           : {:8.2}s", m.mean_act());
    println!("p99 ACT            : {:8.2}s", m.p99_act());
    println!("mean step duration : {:8.2}s", m.mean_step_dur());
    let (exec, queue, ovh) = m.act_breakdown();
    println!("ACT breakdown      : exec {exec:.2}s | queue {queue:.2}s | overhead {ovh:.3}s");
    println!("env-active ratio   : {:.2}", m.mean_active_ratio());
}
