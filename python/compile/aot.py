"""AOT pipeline: lower the Layer-2 JAX graphs to HLO-text artifacts.

Interchange format is HLO *text*, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (written to ``artifacts/``):

  policy_init.hlo.txt      (seed:u32) -> params…
  policy_fwd.hlo.txt       (params…, tokens:i32[B,S]) -> logits
  policy_logprobs.hlo.txt  (params…, tokens) -> logp[B,S-1]
  train_step.hlo.txt       (params…, m…, v…, step, tokens, mask, adv,
                            old_logp, lr) -> (params'…, m'…, v'…, step', loss)
  reward_init.hlo.txt      (seed:u32) -> rparams…
  reward_fwd.hlo.txt       (rparams…, tokens:i32[RB,S], mask:f32[RB,S]) -> scores
  meta.json                calling convention: flattening order, shapes,
                           dtypes, model configs, batch sizes.

"params…" means the pytree flattened in ``jax.tree_util`` order; the order is
recorded in meta.json and is the contract with ``rust/src/runtime``.
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _params_spec(cfg: M.ModelConfig, reward: bool):
    """ShapeDtypeStruct pytree matching init_params/init_reward_params."""
    init = M.init_reward_params if reward else M.init_params
    return jax.eval_shape(lambda k: init(k, cfg), _spec((2,), jnp.uint32))


def lower_all(
    policy_cfg: M.ModelConfig,
    reward_cfg: M.ModelConfig,
    batch: int,
    reward_batch: int,
    out_dir: str,
) -> dict:
    """Lower every artifact; returns the meta dict (also written to disk)."""
    os.makedirs(out_dir, exist_ok=True)
    seq = policy_cfg.max_seq
    rseq = reward_cfg.max_seq

    p_spec = _params_spec(policy_cfg, reward=False)
    r_spec = _params_spec(reward_cfg, reward=True)
    tokens = _spec((batch, seq), jnp.int32)
    mask = _spec((batch, seq - 1), jnp.float32)
    adv = _spec((batch,), jnp.float32)
    old_logp = _spec((batch, seq - 1), jnp.float32)
    scalar_f = _spec((), jnp.float32)
    scalar_i = _spec((), jnp.int32)
    seed = _spec((), jnp.uint32)
    r_tokens = _spec((reward_batch, rseq), jnp.int32)
    r_mask = _spec((reward_batch, rseq), jnp.float32)

    def policy_init(s):
        return M.init_params(jax.random.PRNGKey(s), policy_cfg)

    def reward_init(s):
        return M.init_reward_params(jax.random.PRNGKey(s), reward_cfg)

    def policy_fwd(params, toks):
        return M.forward(params, toks, policy_cfg)

    def policy_logprobs(params, toks):
        return M.token_logprobs(params, toks, policy_cfg)

    def train_step(params, m, v, step, toks, msk, a, olp, lr):
        return M.train_step(
            params, m, v, step, toks, msk, a, olp, lr, policy_cfg
        )

    def reward_fwd(rparams, toks, msk):
        return M.reward_forward(rparams, toks, msk, reward_cfg)

    jobs = {
        "policy_init": (policy_init, (seed,), {}),
        "policy_fwd": (policy_fwd, (p_spec, tokens), {}),
        "policy_logprobs": (policy_logprobs, (p_spec, tokens), {}),
        "train_step": (
            train_step,
            (p_spec, p_spec, p_spec, scalar_i, tokens, mask, adv, old_logp, scalar_f),
            # Donate params + optimizer state: 1:1 input→output aliasing keeps
            # the training loop allocation-free on the PJRT side.
            {"donate_argnums": (0, 1, 2, 3)},
        ),
        "reward_init": (reward_init, (seed,), {}),
        "reward_fwd": (reward_fwd, (r_spec, r_tokens, r_mask), {}),
    }

    files = {}
    for name, (fn, args, jit_kw) in jobs.items():
        lowered = jax.jit(fn, **jit_kw).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        files[name] = os.path.basename(path)
        print(f"  lowered {name:16s} -> {path} ({len(text)} chars)")

    # Calling convention: concrete leaf specs (from eval_shape) in
    # tree_flatten order.
    p_leaves = [
        {"name": jax.tree_util.keystr(kp), "shape": list(l.shape), "dtype": str(l.dtype)}
        for kp, l in jax.tree_util.tree_flatten_with_path(p_spec)[0]
    ]
    r_leaves = [
        {"name": jax.tree_util.keystr(kp), "shape": list(l.shape), "dtype": str(l.dtype)}
        for kp, l in jax.tree_util.tree_flatten_with_path(r_spec)[0]
    ]

    meta = {
        "format": 1,
        "policy": {
            "config": dataclasses.asdict(policy_cfg),
            "param_count": policy_cfg.param_count(),
            "params": p_leaves,
            "batch": batch,
            "seq": seq,
        },
        "reward": {
            "config": dataclasses.asdict(reward_cfg),
            "param_count": reward_cfg.param_count(),
            "params": r_leaves,
            "batch": reward_batch,
            "seq": rseq,
        },
        "train": {
            "adam": {"b1": M.ADAM_B1, "b2": M.ADAM_B2, "eps": M.ADAM_EPS},
            "clip_eps": M.CLIP_EPS,
            "entropy_coef": M.ENTROPY_COEF,
            # input order: params…, m…, v…, step, tokens, mask, adv, old_logp, lr
            # output order: params'…, m'…, v'…, step', loss
            "n_param_arrays": len(p_leaves),
        },
        "artifacts": files,
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"  wrote {out_dir}/meta.json")
    return meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--policy", default="small", choices=sorted(M.PRESETS))
    ap.add_argument("--reward", default="tiny", choices=sorted(M.PRESETS))
    ap.add_argument("--batch", type=int, default=8, help="train/rollout batch")
    ap.add_argument("--reward-batch", type=int, default=8)
    args = ap.parse_args()

    policy_cfg = M.PRESETS[args.policy]
    reward_cfg = M.PRESETS[args.reward]
    print(
        f"AOT: policy={args.policy} ({policy_cfg.param_count()/1e6:.1f}M params) "
        f"reward={args.reward} ({reward_cfg.param_count()/1e6:.1f}M params)"
    )
    lower_all(policy_cfg, reward_cfg, args.batch, args.reward_batch, args.out)


if __name__ == "__main__":
    main()
