"""Layer-1 Pallas flash-attention kernel.

This is the compute hot-spot of the reward-model / policy services that the
Rust coordinator multiplexes (paper §5.3: "reward model service must compile
kernels ... load model parameters"). The paper's services run on H-series
GPUs; per the hardware-adaptation rule we re-express the same insight —
bounded fast-memory footprint independent of sequence length — TPU-style:

* the HBM↔VMEM schedule is carried by ``BlockSpec``: each grid program sees
  one ``(block_q, head_dim)`` query tile and streams K/V tiles through an
  online-softmax accumulator, so the S×S score matrix never materializes;
* matmul tiles are MXU-shaped (multiples of the 128-lane systolic array for
  production configs; tests exercise smaller tiles as well);
* no warp/WMMA decomposition: parallelism is expressed through the grid and
  the MXU, not threadblocks.

Kernels are lowered with ``interpret=True`` — the CPU PJRT plugin cannot run
Mosaic custom-calls; real-TPU efficiency is estimated analytically (see
DESIGN.md §Hardware-Adaptation and EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 128 matches the MXU systolic array on real TPUs; the
# kernel accepts any divisor of the sequence length so tiny test shapes work.
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128

# Large-negative used for masked logits. Not -inf: -inf - -inf = nan in the
# running-max rescale.
_MASK_VALUE = -1e30


def _mha_kernel(q_ref, k_ref, v_ref, o_ref, *, sm_scale, causal, block_k):
    """One (batch·head, q-tile) grid program of online-softmax attention.

    ``q_ref``: (block_q, d) query tile in VMEM.
    ``k_ref``/``v_ref``: (seq_k, d) — the full K/V for this batch·head; the
    kernel streams ``block_k``-row tiles out of them, which is the VMEM
    working set on real hardware (the BlockSpec keeps HBM→VMEM transfers
    tile-granular under Mosaic).
    """
    q = q_ref[...].astype(jnp.float32) * sm_scale
    block_q, _ = q.shape
    seq_k = k_ref.shape[0]
    head_dim_v = v_ref.shape[1]
    q_tile = pl.program_id(1)

    m0 = jnp.full((block_q,), _MASK_VALUE, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, head_dim_v), jnp.float32)

    def body(kt, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(kt * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(kt * block_k, block_k), slice(None)))
        s = q @ k.astype(jnp.float32).T  # (block_q, block_k) on the MXU
        if causal:
            q_pos = q_tile * block_q + jax.lax.iota(jnp.int32, block_q)
            k_pos = kt * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask, s, _MASK_VALUE)
        m_new = jnp.maximum(m, s.max(axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    num_k_tiles = seq_k // block_k
    if causal:
        # Tiles strictly above the diagonal contribute nothing; skip them.
        # (q_tile+1)*block_q is the first masked row bound; ceil-divide.
        hi = jax.lax.div((q_tile + 1) * block_q + block_k - 1, block_k)
        hi = jnp.minimum(hi, num_k_tiles)
    else:
        hi = num_k_tiles
    m, l, acc = jax.lax.fori_loop(0, hi, body, (m0, l0, acc0))
    # Rows with no unmasked key keep l == 0 only if the mask killed the whole
    # row; causal attention always sees the diagonal, so l > 0 here.
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def _flash_impl(q, k, v, causal, bq, bk, interpret):
    """The raw pallas_call (no autodiff rule of its own)."""
    batch, heads, seq_q, head_dim = q.shape
    seq_k = k.shape[2]
    sm_scale = 1.0 / (head_dim**0.5)
    bh = batch * heads
    head_dim_v = v.shape[3]
    qr = q.reshape(bh, seq_q, head_dim)
    kr = k.reshape(bh, seq_k, head_dim)
    vr = v.reshape(bh, seq_k, head_dim_v)

    grid = (bh, seq_q // bq)
    out = pl.pallas_call(
        functools.partial(
            _mha_kernel, sm_scale=sm_scale, causal=causal, block_k=bk
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, head_dim), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, seq_k, head_dim), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, seq_k, head_dim_v), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, head_dim_v), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq_q, head_dim_v), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(batch, heads, seq_q, head_dim_v)


# ``pallas_call`` carries no autodiff rule, and the GRPO train step needs
# gradients through attention. Forward runs the Pallas kernel; backward is
# the VJP of the jnp reference (mathematically identical attention). A
# dedicated Pallas backward kernel is the listed future extension.
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, bq, bk, interpret):
    return _flash_impl(q, k, v, causal, bq, bk, interpret)


def _flash_fwd(q, k, v, causal, bq, bk, interpret):
    return _flash_impl(q, k, v, causal, bq, bk, interpret), (q, k, v)


def _flash_bwd(causal, bq, bk, interpret, res, g):
    from .ref import mha_ref  # local import to avoid a cycle at module load

    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: mha_ref(q_, k_, v_, causal=causal), q, k, v)
    return vjp(g)


_flash.defvjp(_flash_fwd, _flash_bwd)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int | None = None,
    block_k: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Blocked online-softmax attention.

    Args:
      q, k, v: ``(batch, heads, seq, head_dim)`` arrays (f32 or bf16).
      causal: apply a causal mask.
      block_q/block_k: tile sizes; must divide the sequence lengths. Default
        clamps ``DEFAULT_BLOCK_*`` to the sequence length.
      interpret: must stay True on CPU PJRT (see module docstring).

    Returns:
      ``(batch, heads, seq, head_dim)`` attention output, same dtype as q.
    """
    batch, heads, seq_q, head_dim = q.shape
    seq_k = k.shape[2]
    if k.shape != (batch, heads, seq_k, head_dim):
        raise ValueError(f"bad k shape {k.shape}")
    if v.shape[:3] != (batch, heads, seq_k):
        raise ValueError(f"bad v shape {v.shape}")
    if causal and seq_q != seq_k:
        raise ValueError("causal attention requires seq_q == seq_k")
    bq = min(block_q or DEFAULT_BLOCK_Q, seq_q)
    bk = min(block_k or DEFAULT_BLOCK_K, seq_k)
    if seq_q % bq or seq_k % bk:
        raise ValueError(f"block sizes ({bq},{bk}) must divide ({seq_q},{seq_k})")
    if causal and bq % bk:
        raise ValueError("causal tiling requires block_q % block_k == 0")
    return _flash(q, k, v, causal, bq, bk, interpret)


def vmem_bytes(block_q: int, block_k: int, head_dim: int, seq_k: int) -> int:
    """Analytic VMEM working set of one grid program, in bytes (f32 accum).

    Used by the §Perf analysis: q tile + one K/V tile + accumulator + softmax
    state. The full-K/V in_spec above is an interpret-mode convenience; on
    Mosaic the pl.load tiling keeps residency at one (block_k, d) tile per
    operand, which is what we account here.
    """
    f32 = 4
    q_tile = block_q * head_dim * f32
    kv_tiles = 2 * block_k * head_dim * f32
    acc = block_q * head_dim * f32
    softmax_state = 2 * block_q * f32
    scores = block_q * block_k * f32
    return q_tile + kv_tiles + acc + softmax_state + scores


def mxu_flops(batch, heads, seq_q, seq_k, head_dim, causal=True) -> int:
    """Matmul FLOPs of one attention forward (for MXU-utilization estimates)."""
    full = 2 * batch * heads * seq_q * seq_k * head_dim * 2  # QK^T and PV
    return full // 2 if causal else full
