"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every kernel must match its ref
within tolerance across the pytest shape/dtype sweeps. Written with the
most literal formulation possible (materialized score matrix, plain
softmax) — clarity over speed.
"""

from __future__ import annotations

import jax.numpy as jnp

_MASK_VALUE = -1e30


def mha_ref(q, k, v, *, causal: bool = True):
    """Reference multi-head attention. q,k,v: (batch, heads, seq, head_dim)."""
    head_dim = q.shape[-1]
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / (head_dim**0.5)
    if causal:
        seq_q, seq_k = s.shape[-2], s.shape[-1]
        mask = jnp.tril(jnp.ones((seq_q, seq_k), bool), k=seq_k - seq_q)
        s = jnp.where(mask, s, _MASK_VALUE)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def rmsnorm_ref(x, w, *, eps: float = 1e-6):
    """Reference RMSNorm over the last dim."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf / jnp.sqrt(ms + eps) * w.astype(jnp.float32)).astype(x.dtype)
