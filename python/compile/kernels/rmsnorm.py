"""Layer-1 Pallas RMSNorm kernel.

Row-blocked RMS normalization with learned gain. Small relative to the
attention kernel, but it is the second-most frequent op in the reward-model
forward and demonstrates the row-tile BlockSpec pattern (grid over row
tiles, full feature dim resident in VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 128
EPS = 1e-6


def _rmsnorm_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + EPS) * w[None, :]).astype(o_ref.dtype)


def _rmsnorm_impl(x, w, block_rows, interpret):
    lead = x.shape[:-1]
    d = x.shape[-1]
    rows = 1
    for s in lead:
        rows *= s
    xr = x.reshape(rows, d)
    br = min(block_rows, rows)
    padded = (rows + br - 1) // br * br
    if padded != rows:
        xr = jnp.concatenate([xr, jnp.zeros((padded - rows, d), x.dtype)], axis=0)
    out = pl.pallas_call(
        _rmsnorm_kernel,
        grid=(padded // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, d), x.dtype),
        interpret=interpret,
    )(xr, w)
    return out[:rows].reshape(*lead, d)


# Forward = Pallas kernel, backward = VJP of the jnp reference (see
# attention.py for rationale — pallas_call has no autodiff rule).
@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _rmsnorm(x, w, block_rows, interpret):
    return _rmsnorm_impl(x, w, block_rows, interpret)


def _rmsnorm_fwd(x, w, block_rows, interpret):
    return _rmsnorm_impl(x, w, block_rows, interpret), (x, w)


def _rmsnorm_bwd(block_rows, interpret, res, g):
    from .ref import rmsnorm_ref

    x, w = res
    _, vjp = jax.vjp(rmsnorm_ref, x, w)
    return vjp(g)


_rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def rmsnorm(
    x: jax.Array,
    w: jax.Array,
    *,
    block_rows: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """RMS-normalize the last dim of ``x`` (any leading shape) scaled by ``w``.

    ``x``: (..., d); ``w``: (d,). Rows are processed in ``block_rows`` tiles.
    """
    if x.shape[-1] != w.shape[0]:
        raise ValueError(f"feature dims differ: {x.shape[-1]} vs {w.shape[0]}")
    # Rows are padded up to a tile multiple inside _rmsnorm_impl; padding rows
    # normalize to 0 (finite thanks to EPS) and get sliced away.
    return _rmsnorm(x, w, block_rows or DEFAULT_BLOCK_ROWS, interpret)
