"""Layer-2 JAX model: transformer policy + reward model + GRPO train step.

These are the compute graphs behind the two kinds of model services the
Rust coordinator manages:

* the **policy** being RL-trained (forward for rollout logits, per-token
  log-probs for GRPO, and a full Adam train step), and
* the **reward model / LLM-judge** service multiplexed by the GPU manager
  (paper §5.3), a smaller transformer with a pooled scalar head.

Everything routes its attention through the Layer-1 Pallas flash-attention
kernel and its norms through the Pallas RMSNorm kernel, so the AOT-lowered
HLO artifacts contain the kernels' computation. ``aot.py`` lowers the public
functions here to HLO text for the Rust runtime; nothing in this file runs
at serving/training time.

Parameter pytrees are plain nested dicts. Flattening order (which defines
the artifact calling convention for Rust) is recorded by
``param_specs`` and serialized to ``artifacts/meta.json``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .kernels.attention import flash_attention
from .kernels.rmsnorm import rmsnorm

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer configuration."""

    vocab: int = 512
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 512
    max_seq: int = 64
    # pallas tile sizes (clamped to seq inside the kernel)
    block_q: int = 64
    block_k: int = 64

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        """Total learnable parameters (for reporting/model sizing)."""
        per_layer = (
            4 * self.d_model * self.d_model  # wq wk wv wo
            + 2 * self.d_model * self.d_ff  # mlp in/out
            + 2 * self.d_model  # two norms
        )
        return (
            self.vocab * self.d_model  # tied embedding/unembedding
            + self.max_seq * self.d_model  # positional
            + self.n_layers * per_layer
            + self.d_model  # final norm
        )


# Preset model sizes. `small` is the e2e-training default (fast enough for a
# few hundred CPU-PJRT steps); `base` approximates the ~100M-param scale of
# the system-prompt target and is used for compile-only checks + perf math.
PRESETS: dict[str, ModelConfig] = {
    "tiny": ModelConfig(),
    "small": ModelConfig(
        vocab=1024, d_model=256, n_layers=4, n_heads=8, d_ff=1024, max_seq=128
    ),
    "base": ModelConfig(
        vocab=32768,
        d_model=768,
        n_layers=12,
        n_heads=12,
        d_ff=3072,
        max_seq=256,
        block_q=128,
        block_k=128,
    ),
}


def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    """Initialize a parameter pytree (scaled-normal init, tied unembedding)."""
    n = cfg.n_layers
    keys = jax.random.split(key, 2 + 6 * n)
    d, f = cfg.d_model, cfg.d_ff

    def dense(k, fan_in, shape):
        return (jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(
            jnp.float32
        )

    params: Params = {
        "embed": dense(keys[0], 1.0, (cfg.vocab, d)) * 0.02 * jnp.sqrt(1.0),
        "pos": dense(keys[1], 1.0, (cfg.max_seq, d)) * 0.02,
        "layers": [],
        "ln_f": jnp.ones((d,), jnp.float32),
    }
    for i in range(n):
        k = keys[2 + 6 * i : 8 + 6 * i]
        params["layers"].append(
            {
                "wq": dense(k[0], d, (d, d)),
                "wk": dense(k[1], d, (d, d)),
                "wv": dense(k[2], d, (d, d)),
                "wo": dense(k[3], d, (d, d)) / jnp.sqrt(2.0 * n),
                "w1": dense(k[4], d, (d, f)),
                "w2": dense(k[5], f, (f, d)) / jnp.sqrt(2.0 * n),
                "ln1": jnp.ones((d,), jnp.float32),
                "ln2": jnp.ones((d,), jnp.float32),
            }
        )
    return params


def _block(x: jax.Array, lp: Params, cfg: ModelConfig) -> jax.Array:
    """One pre-norm transformer block. x: (batch, seq, d_model)."""
    b, s, d = x.shape
    h = rmsnorm(x, lp["ln1"])
    q = (h @ lp["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    k = (h @ lp["wk"]).reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    v = (h @ lp["wv"]).reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    attn = flash_attention(
        q, k, v, causal=True, block_q=cfg.block_q, block_k=cfg.block_k
    )
    attn = attn.transpose(0, 2, 1, 3).reshape(b, s, d)
    x = x + attn @ lp["wo"]
    h = rmsnorm(x, lp["ln2"])
    h = jax.nn.gelu(h @ lp["w1"]) @ lp["w2"]
    return x + h


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Policy forward. tokens: (batch, seq) int32 → logits (batch, seq, vocab)."""
    b, s = tokens.shape
    x = params["embed"][tokens] + params["pos"][:s][None, :, :]
    for lp in params["layers"]:
        x = _block(x, lp, cfg)
    x = rmsnorm(x, params["ln_f"])
    return x @ params["embed"].T  # tied unembedding


def token_logprobs(
    params: Params, tokens: jax.Array, cfg: ModelConfig
) -> jax.Array:
    """Log p(tokens[t] | tokens[<t]) for t ≥ 1; shape (batch, seq-1)."""
    logits = forward(params, tokens, cfg)[:, :-1, :]
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt = tokens[:, 1:]
    return jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]


# ---------------------------------------------------------------------------
# Reward model (LLM-as-a-judge service)
# ---------------------------------------------------------------------------


def init_reward_params(key: jax.Array, cfg: ModelConfig) -> Params:
    """Reward model = transformer trunk + scalar head."""
    k1, k2 = jax.random.split(key)
    params = init_params(k1, cfg)
    params["head"] = (
        jax.random.normal(k2, (cfg.d_model, 1), jnp.float32)
        / jnp.sqrt(cfg.d_model)
    )
    return params


def reward_forward(
    params: Params, tokens: jax.Array, mask: jax.Array, cfg: ModelConfig
) -> jax.Array:
    """Score trajectories. tokens: (batch, seq) int32, mask: (batch, seq) f32.

    Returns (batch,) scores in (-1, 1): masked mean-pool of the final hidden
    states through a linear head and tanh — the standard RM head shape.
    """
    b, s = tokens.shape
    x = params["embed"][tokens] + params["pos"][:s][None, :, :]
    for lp in params["layers"]:
        x = _block(x, lp, cfg)
    x = rmsnorm(x, params["ln_f"])
    denom = jnp.maximum(mask.sum(axis=1, keepdims=True), 1.0)
    pooled = (x * mask[..., None]).sum(axis=1) / denom
    return jnp.tanh(pooled @ params["head"])[:, 0]


# ---------------------------------------------------------------------------
# GRPO loss + Adam train step
# ---------------------------------------------------------------------------

CLIP_EPS = 0.2
ENTROPY_COEF = 0.002


def grpo_loss(
    params: Params,
    tokens: jax.Array,
    mask: jax.Array,
    advantages: jax.Array,
    old_logp: jax.Array,
    cfg: ModelConfig,
) -> jax.Array:
    """Clipped-ratio policy-gradient loss with group-relative advantages.

    GRPO (Shao et al., 2024) computes advantages per *group* of rollouts for
    the same prompt: A_i = (r_i - mean_g) / std_g. That normalization happens
    in the Rust trainer (it owns the groups); here we consume per-sequence
    ``advantages`` broadcast over tokens, exactly like the paper's VeRL setup.

    tokens: (B, S) int32; mask: (B, S-1) f32 over *target* positions;
    advantages: (B,) f32; old_logp: (B, S-1) f32 behaviour log-probs.
    """
    logits = forward(params, tokens, cfg)[:, :-1, :]
    logp_all = jax.nn.log_softmax(logits, axis=-1)
    tgt = tokens[:, 1:]
    logp = jnp.take_along_axis(logp_all, tgt[..., None], axis=-1)[..., 0]

    ratio = jnp.exp(logp - old_logp)
    adv = advantages[:, None]
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - CLIP_EPS, 1.0 + CLIP_EPS) * adv
    pg = -jnp.minimum(unclipped, clipped)

    entropy = -(jnp.exp(logp_all) * logp_all).sum(axis=-1)

    denom = jnp.maximum(mask.sum(), 1.0)
    pg_loss = (pg * mask).sum() / denom
    ent_bonus = (entropy * mask).sum() / denom
    return pg_loss - ENTROPY_COEF * ent_bonus


ADAM_B1 = 0.9
ADAM_B2 = 0.95
ADAM_EPS = 1e-8


def train_step(
    params: Params,
    m: Params,
    v: Params,
    step: jax.Array,
    tokens: jax.Array,
    mask: jax.Array,
    advantages: jax.Array,
    old_logp: jax.Array,
    lr: jax.Array,
    cfg: ModelConfig,
):
    """One GRPO Adam step. Returns (params', m', v', step+1, loss).

    The whole update is a single HLO module so the Rust trainer keeps
    parameters and optimizer state resident as PJRT buffers between steps
    (donation-friendly: each input param/opt tensor maps 1:1 to an output).
    """
    loss, grads = jax.value_and_grad(grpo_loss)(
        params, tokens, mask, advantages, old_logp, cfg
    )
    step = step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - ADAM_B1**t
    bc2 = 1.0 - ADAM_B2**t

    def upd(p, g, m_, v_):
        m_n = ADAM_B1 * m_ + (1.0 - ADAM_B1) * g
        v_n = ADAM_B2 * v_ + (1.0 - ADAM_B2) * g * g
        p_n = p - lr * (m_n / bc1) / (jnp.sqrt(v_n / bc2) + ADAM_EPS)
        return p_n, m_n, v_n

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(m)
    flat_v = treedef.flatten_up_to(v)
    new_p, new_m, new_v = [], [], []
    for p, g, m_, v_ in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m_, v_)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    return (
        jax.tree_util.tree_unflatten(treedef, new_p),
        jax.tree_util.tree_unflatten(treedef, new_m),
        jax.tree_util.tree_unflatten(treedef, new_v),
        step,
        loss,
    )


def zeros_like_params(params: Params) -> Params:
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def param_specs(params: Params) -> list[dict[str, Any]]:
    """Flattening-order spec of a param pytree (the Rust calling convention)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        specs.append(
            {
                "name": jax.tree_util.keystr(path),
                "shape": list(leaf.shape),
                "dtype": str(leaf.dtype),
                "elems": int(leaf.size),
            }
        )
    return specs
