"""Seeded randomized shape/dtype sweep helper.

Offline substitute for `hypothesis` (unavailable in this build image): a
deterministic generator enumerates randomized parameter combinations so the
kernel tests cover a broad, reproducible slice of the input space. Failures
print the exact case tuple for replay.
"""

from __future__ import annotations

import itertools
import random
from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class AttnCase:
    batch: int
    heads: int
    seq: int
    head_dim: int
    block_q: int
    block_k: int
    causal: bool
    dtype: str

    def label(self) -> str:
        return (
            f"b{self.batch}h{self.heads}s{self.seq}d{self.head_dim}"
            f"_q{self.block_q}k{self.block_k}_{'c' if self.causal else 'f'}_{self.dtype}"
        )


_DTYPES = ["float32", "bfloat16"]


def _divisors(n: int) -> list[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def attention_cases(n_random: int = 24, seed: int = 20260710) -> list[AttnCase]:
    """A fixed corner set plus ``n_random`` seeded random cases."""
    corners = [
        AttnCase(1, 1, 8, 4, 8, 8, True, "float32"),   # single tile
        AttnCase(1, 1, 8, 4, 4, 2, True, "float32"),   # multi k-tile per q
        AttnCase(2, 4, 64, 32, 32, 16, True, "float32"),
        AttnCase(2, 2, 64, 32, 64, 64, False, "float32"),
        AttnCase(1, 2, 128, 16, 128, 128, True, "float32"),  # MXU-shaped
        AttnCase(1, 1, 16, 8, 16, 16, True, "bfloat16"),
        AttnCase(3, 1, 32, 64, 8, 8, False, "bfloat16"),
    ]
    rng = random.Random(seed)
    out = list(corners)
    for _ in range(n_random):
        seq = rng.choice([8, 16, 32, 64, 128])
        bq = rng.choice(_divisors(seq))
        # causal tiling requires block_q % block_k == 0
        bk = rng.choice(_divisors(bq))
        causal = rng.random() < 0.7
        if not causal:
            bk = rng.choice(_divisors(seq))
        out.append(
            AttnCase(
                batch=rng.choice([1, 2, 3]),
                heads=rng.choice([1, 2, 4]),
                seq=seq,
                head_dim=rng.choice([4, 8, 16, 32]),
                block_q=bq,
                block_k=bk,
                causal=causal,
                dtype=rng.choice(_DTYPES),
            )
        )
    return out


@dataclass(frozen=True)
class NormCase:
    rows: tuple
    d: int
    block_rows: int
    dtype: str

    def label(self) -> str:
        return f"r{'x'.join(map(str, self.rows))}_d{self.d}_br{self.block_rows}_{self.dtype}"


def rmsnorm_cases(n_random: int = 20, seed: int = 777) -> list[NormCase]:
    corners = [
        NormCase((1,), 1, 1, "float32"),
        NormCase((4, 4), 8, 4, "float32"),
        NormCase((3, 7), 48, 4, "float32"),      # rows not a tile multiple
        NormCase((2, 5, 3), 16, 128, "float32"), # block > rows (clamped)
        NormCase((8,), 32, 3, "bfloat16"),
    ]
    rng = random.Random(seed)
    out = list(corners)
    for _ in range(n_random):
        ndim = rng.choice([1, 2, 3])
        rows = tuple(rng.randint(1, 9) for _ in range(ndim))
        out.append(
            NormCase(
                rows=rows,
                d=rng.choice([1, 2, 8, 16, 33, 64, 128]),
                block_rows=rng.choice([1, 2, 4, 8, 64]),
                dtype=rng.choice(_DTYPES),
            )
        )
    return out


def tolerance(dtype: str) -> tuple[float, float]:
    """(rtol, atol) per dtype: bf16 has ~3 decimal digits."""
    if dtype == "bfloat16":
        return 2e-2, 2e-2
    return 2e-5, 2e-5


def as_dtype(name: str):
    return jnp.bfloat16 if name == "bfloat16" else jnp.float32
