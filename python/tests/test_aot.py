"""AOT pipeline tests: artifacts lower, parse, and the meta contract holds.

Full lowering of all six artifacts is exercised by `make artifacts`; here we
lower the cheap ones and validate structure so the suite stays fast.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def tiny_meta(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    meta = aot.lower_all(
        M.PRESETS["tiny"], M.PRESETS["tiny"], batch=2, reward_batch=2, out_dir=out
    )
    meta["_dir"] = out
    return meta


def test_all_artifacts_written(tiny_meta):
    d = tiny_meta["_dir"]
    for name, fname in tiny_meta["artifacts"].items():
        path = os.path.join(d, fname)
        assert os.path.exists(path), name
        text = open(path).read()
        assert "ENTRY" in text, f"{name} missing ENTRY computation"
        assert "HloModule" in text


def test_meta_param_specs_match_eval_shape(tiny_meta):
    cfg = M.ModelConfig(**tiny_meta["policy"]["config"])
    spec = jax.eval_shape(
        lambda k: M.init_params(k, cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
    )
    leaves = jax.tree_util.tree_leaves(spec)
    assert len(leaves) == len(tiny_meta["policy"]["params"])
    for rec, leaf in zip(tiny_meta["policy"]["params"], leaves):
        assert tuple(rec["shape"]) == leaf.shape
        assert rec["dtype"] == str(leaf.dtype)


def test_meta_json_round_trips(tiny_meta):
    with open(os.path.join(tiny_meta["_dir"], "meta.json")) as f:
        loaded = json.load(f)
    assert loaded["format"] == 1
    assert loaded["train"]["n_param_arrays"] == len(loaded["policy"]["params"])
    assert loaded["policy"]["batch"] == 2


def test_entry_parameter_count_matches_convention(tiny_meta):
    """train_step HLO entry must have 3·P + 5 parameters (params,m,v + step,
    tokens, mask, adv, old_logp, lr → wait, that's 6 extras)."""
    d = tiny_meta["_dir"]
    text = open(os.path.join(d, tiny_meta["artifacts"]["train_step"])).read()
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    entry_block = []
    for l in lines[start:]:
        entry_block.append(l)
        if l.strip() == "}":
            break
    n_params = sum(" parameter(" in l for l in entry_block)
    p = len(tiny_meta["policy"]["params"])
    # params, m, v pytrees + step, tokens, mask, advantages, old_logp, lr
    assert n_params == 3 * p + 6, (n_params, p)


def test_hlo_contains_no_custom_calls(tiny_meta):
    """interpret=True must lower Pallas into plain HLO (CPU-runnable)."""
    d = tiny_meta["_dir"]
    for name, fname in tiny_meta["artifacts"].items():
        text = open(os.path.join(d, fname)).read()
        assert "custom-call" not in text.lower() or "mosaic" not in text.lower(), name
