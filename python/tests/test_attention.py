"""Pallas flash-attention vs pure-jnp oracle — the core L1 correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.attention import (
    flash_attention,
    mxu_flops,
    vmem_bytes,
)
from compile.kernels.ref import mha_ref

from .sweep import attention_cases, as_dtype, tolerance


def _qkv(case, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    shape = (case.batch, case.heads, case.seq, case.head_dim)
    dt = as_dtype(case.dtype)
    q = jax.random.normal(keys[0], shape, dt)
    k = jax.random.normal(keys[1], shape, dt)
    v = jax.random.normal(keys[2], shape, dt)
    return q, k, v


@pytest.mark.parametrize("case", attention_cases(), ids=lambda c: c.label())
def test_matches_reference(case):
    q, k, v = _qkv(case)
    out = flash_attention(
        q, k, v, causal=case.causal, block_q=case.block_q, block_k=case.block_k
    )
    ref = mha_ref(q, k, v, causal=case.causal)
    rtol, atol = tolerance(case.dtype)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=rtol, atol=atol
    )


def test_block_size_invariance():
    """Output must not depend on the tiling — only on the math."""
    case = attention_cases()[2]
    q, k, v = _qkv(case)
    outs = [
        flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
        for bq, bk in [(64, 64), (32, 32), (16, 8), (64, 16)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-5, atol=1e-5)


def test_causal_masks_future():
    """Perturbing future keys/values must not change earlier outputs."""
    key = jax.random.PRNGKey(3)
    q, k, v = _qkv(attention_cases()[2], seed=3)
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    k2 = k.at[:, :, -8:, :].add(100.0)
    v2 = v.at[:, :, -8:, :].add(-50.0)
    out2 = flash_attention(q, k2, v2, causal=True, block_q=16, block_k=16)
    np.testing.assert_allclose(out[:, :, :-8], out2[:, :, :-8], rtol=1e-5, atol=1e-5)
    assert not np.allclose(out[:, :, -1], out2[:, :, -1])


def test_non_causal_attends_everywhere():
    q, k, v = _qkv(attention_cases()[3], seed=4)
    out = flash_attention(q, k, v, causal=False)
    k2 = k.at[:, :, -1:, :].add(100.0)
    out2 = flash_attention(q, k2, v, causal=False)
    assert not np.allclose(out[:, :, 0], out2[:, :, 0])


def test_gradients_match_reference():
    """custom_vjp backward must equal the reference gradient."""
    q, k, v = _qkv(attention_cases()[1], seed=5)

    def loss_kernel(q, k, v):
        return (flash_attention(q, k, v, causal=True, block_q=4, block_k=2) ** 2).sum()

    def loss_ref(q, k, v):
        return (mha_ref(q, k, v, causal=True) ** 2).sum()

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_softmax_numerics_large_logits():
    """Online softmax must not overflow with huge score magnitudes."""
    case = attention_cases()[2]
    q, k, v = _qkv(case, seed=6)
    q = q * 100.0
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=16)
    assert np.isfinite(np.asarray(out, np.float32)).all()
    ref = mha_ref(q, k, v, causal=True)
    # tolerance is looser here: with 100× logits the blocked and reference
    # accumulation orders legitimately differ in the last ~2 bits
    np.testing.assert_allclose(out, ref, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize(
    "bad",
    [
        dict(block_q=7),            # does not divide seq
        dict(block_q=32, block_k=24),  # bk does not divide seq... and bq%bk
        dict(causal=True, block_q=16, block_k=32),  # bq % bk != 0
    ],
)
def test_rejects_bad_tilings(bad):
    q, k, v = _qkv(attention_cases()[2])
    kwargs = dict(causal=True, block_q=32, block_k=16)
    kwargs.update(bad)
    with pytest.raises(ValueError):
        flash_attention(q, k, v, **kwargs)


def test_rejects_mismatched_shapes():
    q, k, v = _qkv(attention_cases()[2])
    with pytest.raises(ValueError):
        flash_attention(q, k[:, :, :32], v, causal=True)
    with pytest.raises(ValueError):
        flash_attention(q, k[:1], v, causal=False)


def test_vmem_estimate_within_tpu_budget():
    """Production BlockSpec (128×128, d=128) must fit comfortably in 16 MiB VMEM."""
    bytes_needed = vmem_bytes(block_q=128, block_k=128, head_dim=128, seq_k=4096)
    assert bytes_needed < 16 * 2**20 / 4, bytes_needed  # ≤ quarter of VMEM


def test_flops_accounting():
    full = mxu_flops(1, 1, 128, 128, 64, causal=False)
    assert full == 2 * 128 * 128 * 64 * 2
    assert mxu_flops(1, 1, 128, 128, 64, causal=True) == full // 2
