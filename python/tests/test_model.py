"""Layer-2 model tests: shapes, invariants, and learning behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.PRESETS["tiny"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def rparams():
    return M.init_reward_params(jax.random.PRNGKey(1), CFG)


def _tokens(b, s, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, CFG.vocab)


def test_param_count_matches_formula(params):
    actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
    assert actual == CFG.param_count()


def test_forward_shape(params):
    toks = _tokens(3, CFG.max_seq)
    logits = M.forward(params, toks, CFG)
    assert logits.shape == (3, CFG.max_seq, CFG.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_forward_is_causal(params):
    """Changing a later token must not change earlier logits."""
    toks = _tokens(1, CFG.max_seq, seed=2)
    l1 = M.forward(params, toks, CFG)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % CFG.vocab)
    l2 = M.forward(params, toks2, CFG)
    np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], rtol=1e-4, atol=1e-4)


def test_token_logprobs_are_valid(params):
    toks = _tokens(2, CFG.max_seq, seed=3)
    lp = M.token_logprobs(params, toks, CFG)
    assert lp.shape == (2, CFG.max_seq - 1)
    assert (np.asarray(lp) <= 1e-5).all()  # log-probs ≤ 0


def test_reward_scores_bounded(rparams):
    toks = _tokens(4, CFG.max_seq, seed=4)
    mask = jnp.ones((4, CFG.max_seq), jnp.float32)
    scores = M.reward_forward(rparams, toks, mask, CFG)
    assert scores.shape == (4,)
    a = np.asarray(scores)
    assert (np.abs(a) < 1.0).all()  # tanh range, strictly inside


def test_reward_respects_mask(rparams):
    """Scores must depend only on unmasked positions."""
    toks = _tokens(1, CFG.max_seq, seed=5)
    half = CFG.max_seq // 2
    mask = jnp.concatenate(
        [jnp.ones((1, half)), jnp.zeros((1, CFG.max_seq - half))], axis=1
    )
    s1 = M.reward_forward(rparams, toks, mask, CFG)
    # NOTE: masked-out tokens still enter the attention trunk (as in real RMs
    # scoring padded batches with causal attention) — but *pooling* ignores
    # them, so perturbing a masked position changes nothing only when the
    # perturbation is beyond every unmasked position under causality.
    toks2 = toks.at[0, -1].set((toks[0, -1] + 7) % CFG.vocab)
    s2 = M.reward_forward(rparams, toks2, mask, CFG)
    np.testing.assert_allclose(s1, s2, rtol=1e-5, atol=1e-5)


def test_grpo_loss_finite_and_clip_active(params):
    toks = _tokens(4, CFG.max_seq, seed=6)
    mask = jnp.ones((4, CFG.max_seq - 1), jnp.float32)
    olp = M.token_logprobs(params, toks, CFG)
    adv = jnp.array([1.0, -1.0, 0.5, -0.5])
    loss = M.grpo_loss(params, toks, mask, adv, olp, CFG)
    assert np.isfinite(float(loss))
    # With old_logp == current logp, ratio == 1: pg term reduces to -mean(adv·mask)
    # (= 0 here) minus the entropy bonus, so loss should be ≤ 0.
    assert float(loss) <= 0.0


def test_train_step_learns_preferred_sequences():
    """Adam+GRPO must push logprobs of positively-advantaged sequences up."""
    cfg = CFG
    p = M.init_params(jax.random.PRNGKey(7), cfg)
    m = M.zeros_like_params(p)
    v = M.zeros_like_params(p)
    step = jnp.int32(0)
    toks = _tokens(4, cfg.max_seq, seed=8)
    mask = jnp.ones((4, cfg.max_seq - 1), jnp.float32)
    adv = jnp.array([2.0, 2.0, -2.0, -2.0])
    lr = jnp.float32(3e-4)
    lp0 = M.token_logprobs(p, toks, cfg).sum(axis=1)
    ts = jax.jit(M.train_step, static_argnums=(9,))
    for _ in range(8):
        olp = M.token_logprobs(p, toks, cfg)
        p, m, v, step, loss = ts(p, m, v, step, toks, mask, adv, olp, lr, cfg)
    lp1 = M.token_logprobs(p, toks, cfg).sum(axis=1)
    delta = np.asarray(lp1 - lp0)
    assert delta[0] > 0 and delta[1] > 0, delta
    assert delta[2] < 0 and delta[3] < 0, delta
    assert int(step) == 8


def test_train_step_masked_positions_do_not_train():
    """Zero mask ⇒ zero gradient ⇒ params unchanged."""
    cfg = CFG
    p = M.init_params(jax.random.PRNGKey(9), cfg)
    m = M.zeros_like_params(p)
    v = M.zeros_like_params(p)
    toks = _tokens(2, cfg.max_seq, seed=10)
    mask = jnp.zeros((2, cfg.max_seq - 1), jnp.float32)
    olp = M.token_logprobs(p, toks, cfg)
    p2, *_ = M.train_step(
        p, m, v, jnp.int32(0), toks, mask, jnp.zeros((2,)), olp, jnp.float32(1e-3), cfg
    )
    for a, b in zip(jax.tree_util.tree_leaves(p), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(a, b, atol=1e-7)


def test_param_specs_order_is_stable(params):
    specs = M.param_specs(params)
    flat, _ = jax.tree_util.tree_flatten(params)
    assert len(specs) == len(flat)
    for spec, leaf in zip(specs, flat):
        assert tuple(spec["shape"]) == leaf.shape
        assert spec["dtype"] == str(leaf.dtype)


def test_presets_well_formed():
    for name, cfg in M.PRESETS.items():
        assert cfg.d_model % cfg.n_heads == 0, name
        assert cfg.param_count() > 0
    assert M.PRESETS["base"].param_count() > 50_000_000  # ~100M-scale preset
