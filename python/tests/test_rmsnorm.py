"""Pallas RMSNorm vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import rmsnorm_ref
from compile.kernels.rmsnorm import rmsnorm

from .sweep import as_dtype, rmsnorm_cases, tolerance


@pytest.mark.parametrize("case", rmsnorm_cases(), ids=lambda c: c.label())
def test_matches_reference(case):
    kx, kw = jax.random.split(jax.random.PRNGKey(1))
    dt = as_dtype(case.dtype)
    x = jax.random.normal(kx, (*case.rows, case.d), dt)
    w = jax.random.normal(kw, (case.d,), dt)
    out = rmsnorm(x, w, block_rows=case.block_rows)
    ref = rmsnorm_ref(x, w)
    rtol, atol = tolerance(case.dtype)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=rtol, atol=atol
    )


def test_block_rows_invariance():
    x = jax.random.normal(jax.random.PRNGKey(2), (13, 32), jnp.float32)
    w = jnp.ones((32,))
    outs = [rmsnorm(x, w, block_rows=br) for br in (1, 2, 5, 13, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-6, atol=1e-6)


def test_zero_rows_are_finite():
    """EPS keeps all-zero rows finite (exercises the padding path too)."""
    x = jnp.zeros((3, 16), jnp.float32)
    out = rmsnorm(x, jnp.ones((16,)), block_rows=2)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(out, np.zeros((3, 16)), atol=1e-6)


def test_gradients_match_reference():
    x = jax.random.normal(jax.random.PRNGKey(4), (5, 24), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(5), (24,), jnp.float32)
    gk = jax.grad(lambda x, w: (rmsnorm(x, w, block_rows=2) ** 2).sum(), (0, 1))(x, w)
    gr = jax.grad(lambda x, w: (rmsnorm_ref(x, w) ** 2).sum(), (0, 1))(x, w)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_rejects_mismatched_feature_dim():
    with pytest.raises(ValueError):
        rmsnorm(jnp.zeros((2, 8)), jnp.ones((4,)))


def test_scale_equivariance():
    """rmsnorm(c·x) == rmsnorm(x) for c > 0 — the defining invariant."""
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 32), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(7), (32,), jnp.float32)
    a = rmsnorm(x, w)
    b = rmsnorm(x * 37.5, w)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
