//! Figure 3 — the motivation measurements (paper §2.2–§2.3).
//!
//! (a) mean ACT + step duration under 1× vs 0.5× external resources;
//! (b) per-teacher GPU activity under static MOPD deployment (avg < 3%);
//! (c) env-active time ratio of coding trajectories (≈ 47%);
//! (d) external-invocation counts per window for DeepSearch vs MOPD
//!     (swinging ~3 orders of magnitude).

use arl_tangram::bench::*;
use arl_tangram::sim::SimDur;

fn main() {
    println!("=== Figure 3(a): ACT under 1x vs 0.5x external resources (coding) ===");
    let (batch, _, _) = cpu_scale(1280);
    for (label, nodes, cores) in [("1.0x (1280 cores)", 5u32, 256u32), ("0.5x (640 cores)", 5, 128)] {
        let cat = catalog_with_cores(nodes, cores);
        let mut be = tangram(&cat, cores, nodes, 5);
        let (m, wall) = run_experiment(&mut be, &cat, &[coding_wl()], batch, 2, 42);
        println!(
            "{}",
            row(
                label,
                &[
                    format!("ACT {:.2}s", m.mean_act()),
                    format!("step {:.1}s", m.mean_step_dur()),
                    format!("[{wall:.0}s wall]"),
                ],
            )
        );
    }

    println!("\n=== Figure 3(b): teacher-service GPU activity under static MOPD ===");
    let cat = testbed_catalog();
    let mut be = mopd_baseline(&cat);
    let (m, _) = run_experiment(&mut be, &cat, &[mopd_wl()], 2048, 2, 43);
    let mut names: Vec<String> = m
        .util
        .iter()
        .filter(|u| u.name.starts_with("svc:teacher"))
        .map(|u| u.name.clone())
        .collect();
    names.sort();
    names.dedup();
    let mut total = 0.0;
    for n in &names {
        let act = m.mean_util(n);
        total += act;
        println!("{}", row(n, &[format!("{:.1}% activity", act * 100.0)]));
    }
    println!(
        "{}",
        row(
            "mean over teachers",
            &[format!("{:.1}% occupancy", total / names.len().max(1) as f64 * 100.0)]
        )
    );
    println!("(we report replica *occupancy* — an upper bound on the paper's SM activity,");
    println!(" which is per-kernel compute utilization and sits ~10x lower; the shape —");
    println!(" low mean, large cross-service spread — is the reproduced claim)");

    println!("\n=== Figure 3(c): coding env-active time ratio ===");
    let cat = testbed_catalog();
    let mut be = coding_baseline(&cat, 256, 5);
    let (m, _) = run_experiment(&mut be, &cat, &[coding_wl()], 1280, 1, 44);
    println!(
        "{}",
        row(
            "baseline (pod-per-traj)",
            &[format!("{:.0}% active (paper: 47%)", m.mean_active_ratio() * 100.0)]
        )
    );

    println!("\n=== Figure 3(d): invocations per 60s window ===");
    let cat = testbed_catalog();
    let mut be = tangram(&cat, 256, 5, 5);
    let wls = [deepsearch_wl(), mopd_wl()];
    let (m, _) = run_experiment(&mut be, &cat, &wls, 2048, 2, 45);
    for (task, name) in [(wls[0].task, "deepsearch"), (wls[1].task, "mopd")] {
        let tl = m.invocation_timeline(SimDur::from_secs(60), Some(task));
        let counts: Vec<u64> = tl.iter().map(|(_, c)| *c).collect();
        let max = counts.iter().max().copied().unwrap_or(0);
        let min_nonzero = counts.iter().filter(|&&c| c > 0).min().copied().unwrap_or(1);
        println!(
            "{}",
            row(
                name,
                &[
                    format!("windows {}", counts.len()),
                    format!("min {min_nonzero}"),
                    format!("max {max}"),
                    format!("swing {:.0}x", max as f64 / min_nonzero as f64),
                ],
            )
        );
    }
}
