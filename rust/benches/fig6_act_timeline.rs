//! Figure 6 — ACT over training time + step-duration speedups, per workload,
//! ARL-Tangram vs the workload's baseline (paper §6.2).
//!
//! Paper expectations: consistently lower ACT under Tangram; step-duration
//! speedups ≈1.4× (coding) and ≈1.5× (deepsearch); smaller for MOPD (long-
//! tail-dominated rollout).

use arl_tangram::bench::*;
use arl_tangram::coordinator::Backend;
use arl_tangram::metrics::Metrics;
use arl_tangram::rollout::workloads::Catalog;
use arl_tangram::rollout::Workload;
use arl_tangram::sim::SimDur;

fn timeline(m: &Metrics, label: &str) {
    let tl = m.act_timeline(SimDur::from_secs(120));
    let pts: Vec<String> = tl
        .iter()
        .take(8)
        .map(|(t, act)| format!("{:.0}s:{:.1}s", t, act))
        .collect();
    println!("  {label:<14} ACT(t): {}", pts.join("  "));
}

fn compare(
    name: &str,
    cat: &Catalog,
    wls: &[Workload],
    batch: usize,
    tangram_be: &mut dyn Backend,
    baseline_be: &mut dyn Backend,
    seed: u64,
) {
    let (mt, wt) = run_experiment(tangram_be, cat, wls, batch, 2, seed);
    let (mb, wb) = run_experiment(baseline_be, cat, wls, batch, 2, seed);
    println!("--- {name} (batch {batch}) [{wt:.0}s + {wb:.0}s wall]");
    timeline(&mt, "tangram");
    timeline(&mb, "baseline");
    println!(
        "{}",
        row(
            "  mean ACT",
            &[
                format!("{:.2}s", mt.mean_act()),
                format!("{:.2}s", mb.mean_act()),
                format!("{:.2}x", mb.mean_act() / mt.mean_act().max(1e-9)),
            ],
        )
    );
    println!(
        "{}",
        row(
            "  step duration",
            &[
                format!("{:.1}s", mt.mean_step_dur()),
                format!("{:.1}s", mb.mean_step_dur()),
                format!("{:.2}x", mb.mean_step_dur() / mt.mean_step_dur().max(1e-9)),
            ],
        )
    );
}

fn main() {
    println!("=== Figure 6: ACT timelines + step durations (tangram | baseline | speedup) ===\n");
    let cat = testbed_catalog();

    // CPU side: contention-preserving scale (batch/cores ratio fixed)
    let (cb, cn, cpn) = cpu_scale(1280);
    let ccat = catalog_with_cores(cn, cpn);
    compare(
        "AI Coding vs K8s",
        &ccat,
        &[coding_wl()],
        cb,
        &mut tangram(&ccat, cpn, cn, 5),
        &mut coding_baseline(&ccat, cpn, cn),
        101,
    );

    compare(
        "MOPD vs SGLang-static",
        &cat,
        &[mopd_wl()],
        gpu_batch(2048),
        &mut tangram(&cat, 256, 5, 5),
        &mut mopd_baseline(&cat),
        102,
    );

    compare(
        "DeepSearch vs unmanaged",
        &cat,
        &[deepsearch_wl()],
        gpu_batch(2048),
        &mut tangram(&cat, 256, 5, 5),
        &mut deepsearch_baseline(&cat),
        103,
    );

    compare(
        "MOPD+Search vs static-multi",
        &cat,
        &[deepsearch_wl(), mopd_wl()],
        gpu_batch(1024),
        &mut tangram(&cat, 256, 5, 5),
        &mut mopd_search_baseline(&cat),
        104,
    );

    println!("\npaper expectations: coding step ~1.4x, deepsearch step ~1.5x, MOPD smaller");
}
