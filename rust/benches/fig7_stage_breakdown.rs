//! Figure 7 — normalized per-trajectory stage breakdown (gen / tool / reward),
//! ARL-Tangram vs baseline per workload (paper §6.2).
//!
//! Paper expectations for AI coding: env interactions ↓ ~9.0×, reward ↓
//! ~2.8×, total external ↓ ~4.3×; DeepSearch reward slightly *worse* under
//! Tangram (single service ⇒ restore overhead); MOPD+Search strongly better.

use arl_tangram::bench::*;
use arl_tangram::coordinator::Backend;
use arl_tangram::metrics::Metrics;
use arl_tangram::rollout::workloads::Catalog;
use arl_tangram::rollout::Workload;

fn stages(m: &Metrics) -> (f64, f64, f64) {
    m.stage_totals()
}

fn compare(name: &str, cat: &Catalog, wls: &[Workload], batch: usize, t: &mut dyn Backend, b: &mut dyn Backend, seed: u64) {
    let (mt, _) = run_experiment(t, cat, wls, batch, 2, seed);
    let (mb, _) = run_experiment(b, cat, wls, batch, 2, seed);
    let (tg, tt, tr) = stages(&mt);
    let (bg, bt, br) = stages(&mb);
    let norm = (tg + tt + tr).max(1e-9); // normalize by tangram total (paper convention)
    println!("--- {name} (batch {batch}; columns normalized by tangram total)");
    println!(
        "{}",
        row("  tangram", &[format!("gen {:.2}", tg / norm), format!("tool {:.3}", tt / norm), format!("reward {:.3}", tr / norm), format!("total {:.2}", (tg + tt + tr) / norm)])
    );
    println!(
        "{}",
        row("  baseline", &[format!("gen {:.2}", bg / norm), format!("tool {:.3}", bt / norm), format!("reward {:.3}", br / norm), format!("total {:.2}", (bg + bt + br) / norm)])
    );
    println!(
        "{}",
        row(
            "  external speedup",
            &[
                format!("tool {:.1}x", bt / tt.max(1e-9)),
                format!("reward {:.1}x", br / tr.max(1e-9)),
                format!("total {:.1}x", (bt + br) / (tt + tr).max(1e-9)),
            ],
        )
    );
}

fn main() {
    println!("=== Figure 7: stage breakdown per trajectory ===\n");
    let cat = testbed_catalog();
    let (cb, cn, cpn) = cpu_scale(1280);
    let ccat = catalog_with_cores(cn, cpn);
    compare(
        "AI Coding vs K8s",
        &ccat,
        &[coding_wl()],
        cb,
        &mut tangram(&ccat, cpn, cn, 5),
        &mut coding_baseline(&ccat, cpn, cn),
        201,
    );
    compare(
        "MOPD vs SGLang-static",
        &cat,
        &[mopd_wl()],
        gpu_batch(2048),
        &mut tangram(&cat, 256, 5, 5),
        &mut mopd_baseline(&cat),
        202,
    );
    compare(
        "DeepSearch vs unmanaged",
        &cat,
        &[deepsearch_wl()],
        gpu_batch(2048),
        &mut tangram(&cat, 256, 5, 5),
        &mut deepsearch_baseline(&cat),
        203,
    );
    compare(
        "MOPD+Search vs static-multi",
        &cat,
        &[deepsearch_wl(), mopd_wl()],
        gpu_batch(1024),
        &mut tangram(&cat, 256, 5, 5),
        &mut mopd_search_baseline(&cat),
        204,
    );
    println!("\npaper expectations (coding): tool ~9.0x, reward ~2.8x, total ~4.3x");
}
