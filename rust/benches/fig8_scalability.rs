//! Figure 8 — scalability in RL batch size and resource capacity (paper §6.3).
//!
//! (a) CPU: coding ACT vs batch (vs K8s; paper 3.1–27.7×, K8s collapses at
//!     1536) and vs core capacity (768/1024/1280 at fixed batch);
//! (b) GPU: MOPD reward ACT vs batch (vs SGLang-static and ServerlessLLM;
//!     paper 3.4×/18.1× over SGLang, ~100× over ServerlessLLM) and the
//!     capacity sweep showing Tangram matching the 40-GPU static ACT with a
//!     fraction of the GPUs (paper: 29%).

use arl_tangram::bench::*;

fn main() {
    // ---- (a) CPU: batch sweep -------------------------------------------
    println!("=== Figure 8(a) left: coding mean ACT vs RL batch (1280 cores) ===");
    println!("{}", row("batch", &["tangram".into(), "k8s".into(), "speedup".into()]));
    // contention-preserving: quick mode shrinks cores 4x along with batch
    let (_, cn, cpn) = cpu_scale(1280);
    let batches: Vec<usize> = vec![128, 256, 512, 1024, 1536];
    for &b in &batches {
        let cat = catalog_with_cores(cn, cpn);
        let mut t = tangram(&cat, cpn, cn, 5);
        let (mt, _) = run_experiment(&mut t, &cat, &[coding_wl()], b, 1, 301);
        let mut k = coding_baseline(&cat, cpn, cn);
        let (mk, _) = run_experiment(&mut k, &cat, &[coding_wl()], b, 1, 301);
        println!(
            "{}",
            row(
                &format!("{b}"),
                &[
                    format!("{:.2}s", mt.mean_act()),
                    format!("{:.2}s", mk.mean_act()),
                    format!("{:.1}x", mk.mean_act() / mt.mean_act().max(1e-9)),
                ],
            )
        );
    }

    println!("\n=== Figure 8(a) right: coding mean ACT vs CPU capacity (fixed batch) ===");
    let (fixed, _, base_cpn) = cpu_scale(1280);
    println!("{}", row("cores", &["tangram".into(), "k8s".into(), "speedup".into()]));
    for nodes in [3u32, 4, 5] {
        let cores = nodes * base_cpn;
        let cat = catalog_with_cores(nodes, base_cpn);
        let mut t = tangram(&cat, base_cpn, nodes, 5);
        let (mt, _) = run_experiment(&mut t, &cat, &[coding_wl()], fixed, 1, 302);
        let mut k = coding_baseline(&cat, base_cpn, nodes);
        let (mk, _) = run_experiment(&mut k, &cat, &[coding_wl()], fixed, 1, 302);
        println!(
            "{}",
            row(
                &format!("{cores}"),
                &[
                    format!("{:.2}s", mt.mean_act()),
                    format!("{:.2}s", mk.mean_act()),
                    format!("{:.1}x", mk.mean_act() / mt.mean_act().max(1e-9)),
                ],
            )
        );
    }

    // ---- (b) GPU: batch sweep -------------------------------------------
    println!("\n=== Figure 8(b) left: MOPD mean ACT vs RL batch (40 GPUs) ===");
    println!(
        "{}",
        row("batch", &["tangram".into(), "sglang".into(), "serverless".into(), "vs sglang".into()])
    );
    let gbatches: Vec<usize> = vec![256, 512, 1024, 2048];
    for &b in &gbatches {
        let cat = testbed_catalog();
        let mut t = tangram(&cat, 256, 5, 5);
        let (mt, _) = run_experiment(&mut t, &cat, &[mopd_wl()], b, 1, 303);
        let mut s = mopd_baseline(&cat);
        let (ms, _) = run_experiment(&mut s, &cat, &[mopd_wl()], b, 1, 303);
        let mut sl = serverless_baseline(&cat, 5);
        let (msl, _) = run_experiment(&mut sl, &cat, &[mopd_wl()], b, 1, 303);
        let fail = msl.failed_actions();
        println!(
            "{}",
            row(
                &format!("{b}"),
                &[
                    format!("{:.2}s", mt.mean_act()),
                    format!("{:.2}s", ms.mean_act()),
                    if fail > 0 {
                        format!("{:.1}s ({fail} fail)", msl.mean_act())
                    } else {
                        format!("{:.2}s", msl.mean_act())
                    },
                    format!("{:.1}x", ms.mean_act() / mt.mean_act().max(1e-9)),
                ],
            )
        );
    }

    println!("\n=== Figure 8(b) right: GPUs needed by tangram to match the 40-GPU static ACT ===");
    let b = gpu_batch(1024);
    let cat = testbed_catalog();
    let mut s = mopd_baseline(&cat);
    let (ms, _) = run_experiment(&mut s, &cat, &[mopd_wl()], b, 1, 304);
    let target = ms.mean_act();
    println!("static 40-GPU ACT target: {target:.2}s (batch {b})");
    println!("{}", row("tangram GPUs", &["ACT".into(), "vs target".into(), "saving".into()]));
    for nodes in [1u32, 2, 3, 4, 5] {
        let mut t = tangram(&cat, 256, 5, nodes);
        let (mt, _) = run_experiment(&mut t, &cat, &[mopd_wl()], b, 1, 304);
        let gpus = nodes * 8;
        println!(
            "{}",
            row(
                &format!("{gpus}"),
                &[
                    format!("{:.2}s", mt.mean_act()),
                    format!("{:.2}x", mt.mean_act() / target.max(1e-9)),
                    format!("{:.0}%", (1.0 - gpus as f64 / 40.0) * 100.0),
                ],
            )
        );
    }
    println!("\npaper expectations: tangram matches the static ACT at ~29% of the GPUs (71.2% saving)");
}
