//! Figure 9 — ablation of the elastic scheduling algorithm on the AI-coding
//! trace (paper §6.4): elastic DoP 1..32 vs fixed DoP=4 and DoP=16, across
//! batch sizes and under halved CPU capacity.
//!
//! Paper expectations: elastic ≈2.0× better than DoP=4 at batch 256, ≈3.0×
//! better than DoP=16 at batch 1280, ≈1.8× better than DoP=4 at 1× cores.
//! Same trace per column (identical seed ⇒ identical trajectory plans; only
//! the reward-action cost spec differs).
//!
//! Extra ablation (DESIGN.md §7): greedy-eviction depth 1/2/3.

use arl_tangram::bench::*;
use arl_tangram::coordinator::{run, RunCfg, TangramBackend, TangramCfg};
use arl_tangram::rollout::workloads::Catalog;
use arl_tangram::scheduler::SchedulerConfig;

fn run_variant(cat: &Catalog, cpn: u32, fixed_dop: Option<u64>, batch: usize, seed: u64, depth: u64) -> f64 {
    let mut wl = coding_wl();
    wl.fixed_dop = fixed_dop;
    let mut be = TangramBackend::new(
        cat,
        TangramCfg {
            cpu_nodes: 5,
            numa_per_node: 2,
            cores_per_numa: (cpn / 2).max(1),
            sched: SchedulerConfig { depth, ..SchedulerConfig::default() },
            ..TangramCfg::default()
        },
    );
    let cfg = RunCfg { batch, steps: 1, seed, ..RunCfg::default() };
    let m = run(&mut be, cat, &[wl], &cfg);
    m.mean_act()
}

fn main() {
    println!("=== Figure 9: elastic scheduling vs fixed DoP (coding trace) ===\n");
    println!(
        "{}",
        row("batch", &["elastic".into(), "DoP=4".into(), "DoP=16".into(), "vs4".into(), "vs16".into()])
    );
    let (_, _, cpn) = cpu_scale(1280);
    let batches: Vec<usize> = vec![256, 512, 1280];
    for &b in &batches {
        let cat = catalog_with_cores(5, cpn);
        let e = run_variant(&cat, cpn, None, b, 900 + b as u64, 2);
        let d4 = run_variant(&cat, cpn, Some(4), b, 900 + b as u64, 2);
        let d16 = run_variant(&cat, cpn, Some(16), b, 900 + b as u64, 2);
        println!(
            "{}",
            row(
                &format!("{b}"),
                &[
                    format!("{e:.2}s"),
                    format!("{d4:.2}s"),
                    format!("{d16:.2}s"),
                    format!("{:.1}x", d4 / e.max(1e-9)),
                    format!("{:.1}x", d16 / e.max(1e-9)),
                ],
            )
        );
    }

    println!("\n--- capacity: 0.5x cores, fixed batch ---");
    let (b, _, cpn) = cpu_scale(512);
    let cat_half = catalog_with_cores(5, cpn / 2);
    let e = run_variant(&cat_half, cpn / 2, None, b, 950, 2);
    let d4 = run_variant(&cat_half, cpn / 2, Some(4), b, 950, 2);
    println!(
        "{}",
        row(
            &format!("{b} @640c"),
            &[format!("{e:.2}s"), format!("{d4:.2}s"), format!("{:.1}x vs DoP=4", d4 / e.max(1e-9))],
        )
    );

    println!("\n--- extra ablation: approximation depth (elastic, batch {b}) ---");
    let cat = catalog_with_cores(5, cpn);
    for depth in [1u64, 2, 3] {
        let act = run_variant(&cat, cpn, None, b, 960, depth);
        println!("{}", row(&format!("depth={depth}"), &[format!("{act:.2}s")]));
    }
    println!("\npaper expectations: ~2.0x vs DoP=4 (b256), ~3.0x vs DoP=16 (b1280), ~1.8x at low capacity; depth 2-3 sufficient");
}
