//! Dirty-pool scheduler bench: every built-in scenario pack on the tangram
//! backend, dirty-pool vs legacy full-sweep scheduling, reporting elastic-
//! scheduler invocation counts and mean `drain_started` wall time, plus a
//! timed million-action pass serial and on the `--shards 4 --threads 4`
//! worker pool (actions/sec, threaded speedup, peak RSS). Writes
//! `BENCH_sched.json` (override the path with `ARL_BENCH_OUT`; the worker
//! pool must clear `ARL_BENCH_MIN_SPEEDUP`, default 1.3x).
//!
//! The hot-path claim this regenerates: scheduling only dirty pools cuts
//! invocations super-linearly with pool count on multi-node packs — one
//! completion pumps one pool, not `O(pools)` — at identical metrics.

use arl_tangram::bench::{admission_bench, sched_bench_json, sched_bench_rows, throughput_bench};

fn main() {
    println!("=== dirty-pool scheduling vs full sweep (tangram) ===");
    println!(
        "{:<16} {:>6} {:>12} {:>12} {:>9} {:>12} {:>12}  {}",
        "pack", "pools", "invocations", "sweep", "reduction", "mean sched", "mean drain", "metrics"
    );
    let rows = sched_bench_rows();
    for r in &rows {
        println!(
            "{:<16} {:>6} {:>12} {:>12} {:>8.1}x {:>10}ns {:>10}ns  {}",
            r.pack,
            r.pools,
            r.sched_invocations,
            r.sched_invocations_sweep,
            r.reduction(),
            r.mean_sched_ns,
            r.mean_drain_ns,
            if r.metrics_equal { "equal" } else { "DIVERGED" },
        );
    }
    let admission = admission_bench();
    println!(
        "admission ({}): mean ACT {:.2}s with vs {:.2}s without (ratio {:.4}), savings {:.3} / {:.3}",
        admission.pack,
        admission.mean_act_with,
        admission.mean_act_without,
        admission.act_ratio(),
        admission.savings_with,
        admission.savings_without,
    );
    let throughput = match throughput_bench() {
        Ok(t) => t,
        Err(e) => {
            eprintln!("throughput bench failed: {e}");
            std::process::exit(1);
        }
    };
    println!(
        "throughput ({}): {} actions in {:.2}s = {:.0} actions/sec, peak RSS {} KiB",
        throughput.pack,
        throughput.actions,
        throughput.wall_secs,
        throughput.actions_per_sec,
        throughput.peak_rss_kb,
    );
    println!(
        "threaded   ({} threads): {} actions in {:.2}s = {:.0} actions/sec, speedup {:.2}x",
        throughput.threads,
        throughput.actions,
        throughput.wall_secs_threaded,
        throughput.actions_per_sec_threaded,
        throughput.speedup(),
    );
    let out = std::env::var("ARL_BENCH_OUT").unwrap_or_else(|_| "BENCH_sched.json".to_string());
    let json = sched_bench_json(&rows, &admission, Some(&throughput));
    match std::fs::write(&out, &json) {
        Ok(()) => println!("\nwrote {out}"),
        Err(e) => {
            eprintln!("failed to write {out}: {e}");
            std::process::exit(1);
        }
    }
    // the acceptance bar is fewer invocations *at equal metrics* — a
    // divergent row is a regression, not a report line
    let diverged: Vec<&str> =
        rows.iter().filter(|r| !r.metrics_equal).map(|r| r.pack.as_str()).collect();
    if !diverged.is_empty() {
        eprintln!("dirty-pool scheduling diverged from full sweep on: {}", diverged.join(", "));
        std::process::exit(1);
    }
    if let Some(r) = rows.iter().find(|r| r.sched_invocations > r.sched_invocations_sweep) {
        eprintln!(
            "dirty-pool scheduling did MORE work on '{}': {} > {}",
            r.pack, r.sched_invocations, r.sched_invocations_sweep
        );
        std::process::exit(1);
    }
    let (dirty_total, sweep_total) = rows.iter().fold((0u64, 0u64), |(d, s), r| {
        (d + r.sched_invocations, s + r.sched_invocations_sweep)
    });
    if dirty_total >= sweep_total {
        eprintln!("no aggregate invocation reduction: {dirty_total} !< {sweep_total}");
        std::process::exit(1);
    }
    // the worker pool must pay for itself: actions/sec at 4 threads over
    // the serial drain, floor configurable for noisy runners
    let min_speedup: f64 = std::env::var("ARL_BENCH_MIN_SPEEDUP")
        .unwrap_or_else(|_| "1.3".to_string())
        .parse()
        .unwrap_or(1.3);
    if throughput.speedup() < min_speedup {
        eprintln!(
            "threaded drain speedup {:.2}x below the {min_speedup:.2}x floor \
             (set ARL_BENCH_MIN_SPEEDUP to adjust)",
            throughput.speedup()
        );
        std::process::exit(1);
    }
}
