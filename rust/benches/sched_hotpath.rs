//! Scheduler hot-path micro-benchmarks (§Perf, DESIGN.md §8).
//!
//! The paper's constraint: action durations go down to ~1ms, so scheduling
//! decisions must be far below that. Measures Algorithm 1 end-to-end over
//! synthetic queues (flat-pool and GPU-chunk topologies), `DPArrange` alone,
//! and the DES engine's raw event throughput.

use arl_tangram::action::{
    Action, ActionId, ActionKind, ActionSpec, CostSpec, DimCost, ElasticityModel,
    ResourceClass, ResourceKindId, ResourceRegistry, ServiceId, TaskId, TenantId, TrajId,
};
use arl_tangram::bench::{time_it, timing_header};
use arl_tangram::scheduler::{
    dp_arrange, BasicOperator, ChunkOperator, DpOperator, ElasticScheduler, ResourceMap,
    ResourceState, SchedulerConfig,
};
use arl_tangram::sim::{Engine, SimDur, SimTime};

struct Pool {
    units: u64,
    chunks: Option<([u32; 4], [u32; 4])>,
}

impl ResourceState for Pool {
    fn available_units(&self) -> u64 {
        self.units
    }
    fn accommodate(&self, mins: &[u64]) -> bool {
        mins.iter().sum::<u64>() <= self.units
    }
    fn dp_operator(&self, reserved: &[u64]) -> Box<dyn DpOperator> {
        match self.chunks {
            Some((avail, max)) => {
                let _ = reserved;
                Box::new(ChunkOperator::new(avail, max))
            }
            None => {
                let used: u64 = reserved.iter().sum();
                Box::new(BasicOperator::new(self.units.saturating_sub(used)))
            }
        }
    }
    fn running_completions(&self) -> Vec<(SimTime, u64)> {
        vec![(SimTime(1_000_000_000), 2); 8]
    }
}

fn mk_queue(reg: &ResourceRegistry, kind: ResourceKindId, n: usize, scalable: bool) -> Vec<Action> {
    (0..n)
        .map(|i| {
            let cost = if scalable {
                if i % 3 == 0 {
                    CostSpec::single(reg, kind, DimCost::Range { min: 1, max: 32 })
                } else {
                    CostSpec::single(reg, kind, DimCost::Fixed(1))
                }
            } else {
                CostSpec::single(reg, kind, DimCost::Discrete(vec![1, 2, 4, 8]))
            };
            Action::new(
                ActionId(i as u64),
                ActionSpec {
                    task: TaskId(0),
                    tenant: TenantId(0),
                    trajectory: TrajId(i as u64),
                    kind: ActionKind::RewardCpu,
                    cost,
                    key_resource: Some(kind),
                    elasticity: ElasticityModel::Amdahl { serial_frac: 0.05 },
                    profiled_dur: Some(SimDur::from_secs(20 + (i as u64 * 7) % 50)),
                    service: Some(ServiceId(0)),
                    true_dur: SimDur::from_secs(20),
                },
                SimTime::ZERO,
            )
        })
        .collect()
}

fn main() {
    let mut reg = ResourceRegistry::new();
    let cpu = reg.register("cpu", ResourceClass::CpuCores, 256);
    let sched = ElasticScheduler::new(SchedulerConfig::default());
    println!("=== scheduler hot path ===");
    println!("{}", timing_header());

    for &n in &[16usize, 64, 256, 1024] {
        let queue = mk_queue(&reg, cpu, n, true);
        let refs: Vec<&Action> = queue.iter().collect();
        let pool = Pool { units: 256, chunks: None };
        let mut map = ResourceMap::new();
        map.insert(cpu, &pool);
        let s = time_it(&format!("alg1 cpu-pool queue={n}"), 200, || {
            std::hint::black_box(sched.schedule(SimTime::ZERO, &refs, &map));
        });
        println!("{}", s.row());
    }

    // GPU chunk topology (40 GPUs)
    for &n in &[16usize, 64, 256] {
        let queue = mk_queue(&reg, cpu, n, false);
        let refs: Vec<&Action> = queue.iter().collect();
        let bounds = ChunkOperator::cluster_bounds(40);
        let pool = Pool { units: 40, chunks: Some(([0, 0, 0, 5], bounds)) };
        let mut map = ResourceMap::new();
        map.insert(cpu, &pool);
        let s = time_it(&format!("alg1 gpu-chunks queue={n}"), 100, || {
            std::hint::black_box(sched.schedule(SimTime::ZERO, &refs, &map));
        });
        println!("{}", s.row());
    }

    // DPArrange alone
    for &(m, units) in &[(8usize, 64u64), (16, 128), (32, 256)] {
        let op = BasicOperator::new(units);
        let sets: Vec<Vec<u64>> = (0..m).map(|_| (1..=16).collect()).collect();
        let s = time_it(&format!("dp_arrange tasks={m} units={units}"), 200, || {
            std::hint::black_box(dp_arrange(&op, &sets, |i, k| {
                ElasticityModel::Amdahl { serial_frac: 0.05 }
                    .scaled_dur(SimDur::from_secs(10 + i as u64), k)
            }));
        });
        println!("{}", s.row());
    }

    // DES engine raw throughput
    let s = time_it("DES 100k events", 20, || {
        let mut eng: Engine<u64> = Engine::new();
        for i in 0..1000u64 {
            eng.schedule_at(SimTime(i), i);
        }
        let mut n = 0u64;
        eng.run_while(|eng, _, ev| {
            n += 1;
            if n < 100_000 {
                eng.schedule_in(SimDur(1 + ev % 97), ev + 1);
            }
            true
        });
        std::hint::black_box(n);
    });
    println!("{}", s.row());
    println!(
        "→ DES throughput ≈ {:.1}M events/s",
        100_000.0 / (s.mean_ns / 1e9) / 1e6
    );
}
