//! Table 1 — ACT breakdown: execution / queuing / system overhead, for
//! AI Coding (CPU-intensive) and MOPD (GPU-intensive) at two batch sizes
//! each (paper §6.4).
//!
//! Paper expectations: CPU overhead ≤3% of exec even congested; GPU
//! overhead (restore) ≈25% of exec, stable as concurrency grows.

use arl_tangram::bench::*;

fn main() {
    println!("=== Table 1: ACT breakdown (seconds per action) ===\n");
    println!(
        "{}",
        row(
            "workload (batch)",
            &["exec".into(), "queue".into(), "sys ovh".into(), "ovh/exec".into()]
        )
    );

    let (_, cn, cpn) = cpu_scale(1280);
    let coding_batches = vec![1280usize, 1536];
    for b in coding_batches {
        let cat = catalog_with_cores(cn, cpn);
        let mut t = tangram(&cat, cpn, cn, 5);
        let (m, _) = run_experiment(&mut t, &cat, &[coding_wl()], b, 1, 401);
        let (exec, queue, ovh) = m.act_breakdown();
        println!(
            "{}",
            row(
                &format!("Coding ({b})"),
                &[
                    format!("{exec:.3}"),
                    format!("{queue:.3}"),
                    format!("{ovh:.3}"),
                    format!("{:.1}%", ovh / exec.max(1e-9) * 100.0),
                ],
            )
        );
    }

    let mopd_batches = vec![2048usize, 3072];
    for b in mopd_batches {
        let cat = testbed_catalog();
        let mut t = tangram(&cat, 256, 5, 5);
        let (m, _) = run_experiment(&mut t, &cat, &[mopd_wl()], b, 1, 402);
        let (exec, queue, ovh) = m.act_breakdown();
        println!(
            "{}",
            row(
                &format!("MOPD ({b})"),
                &[
                    format!("{exec:.3}"),
                    format!("{queue:.3}"),
                    format!("{ovh:.3}"),
                    format!("{:.1}%", ovh / exec.max(1e-9) * 100.0),
                ],
            )
        );
    }
    println!("\npaper expectations: coding ovh ≤3% of exec; MOPD ovh ≈25% (restore), stable with batch");
}
