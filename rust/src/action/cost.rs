//! Vectorized resource-cost modeling (paper §4.1).
//!
//! `C_i = (c_{i,0}, …, c_{i,k-1})` where each dimension constrains the
//! feasible unit quantities of one resource kind: nothing, a fixed amount,
//! a contiguous `[min, max]` range, or a discrete set (e.g. GPU DoP
//! `{1, 2, 4, 8}`).

use super::ResourceKindId;
use std::ops::{AddAssign, SubAssign};

/// Per-dimension feasible-units constraint.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DimCost {
    /// The action does not use this resource.
    None,
    /// Exactly this many units.
    Fixed(u64),
    /// Any amount in `[min, max]` (contiguous elasticity).
    Range { min: u64, max: u64 },
    /// One of these unit counts (sorted ascending; e.g. `[1,2,4,8]`).
    Discrete(Vec<u64>),
}

impl DimCost {
    pub fn min_units(&self) -> u64 {
        match self {
            DimCost::None => 0,
            DimCost::Fixed(n) => *n,
            DimCost::Range { min, .. } => *min,
            DimCost::Discrete(v) => v.first().copied().unwrap_or(0),
        }
    }

    pub fn max_units(&self) -> u64 {
        match self {
            DimCost::None => 0,
            DimCost::Fixed(n) => *n,
            DimCost::Range { max, .. } => *max,
            DimCost::Discrete(v) => v.last().copied().unwrap_or(0),
        }
    }

    /// Enumerate all feasible unit choices (ascending).
    pub fn choices(&self) -> Vec<u64> {
        match self {
            DimCost::None => vec![0],
            DimCost::Fixed(n) => vec![*n],
            DimCost::Range { min, max } => (*min..=*max).collect(),
            DimCost::Discrete(v) => v.clone(),
        }
    }

    pub fn allows(&self, m: u64) -> bool {
        match self {
            DimCost::None => m == 0,
            DimCost::Fixed(n) => m == *n,
            DimCost::Range { min, max } => (*min..=*max).contains(&m),
            DimCost::Discrete(v) => v.binary_search(&m).is_ok(),
        }
    }

    /// More than one feasible choice ⇒ the dimension is scalable.
    pub fn has_choice(&self) -> bool {
        match self {
            DimCost::None | DimCost::Fixed(_) => false,
            DimCost::Range { min, max } => max > min,
            DimCost::Discrete(v) => v.len() > 1,
        }
    }

    /// Validate internal consistency (sortedness, non-empty, min≤max).
    pub fn validate(&self) -> Result<(), String> {
        match self {
            DimCost::None => Ok(()),
            DimCost::Fixed(n) if *n > 0 => Ok(()),
            DimCost::Fixed(_) => Err("Fixed(0) — use None".into()),
            DimCost::Range { min, max } => {
                if *min == 0 {
                    Err("Range.min must be ≥ 1".into())
                } else if min > max {
                    Err(format!("Range min {min} > max {max}"))
                } else {
                    Ok(())
                }
            }
            DimCost::Discrete(v) => {
                if v.is_empty() {
                    Err("empty Discrete set".into())
                } else if v[0] == 0 {
                    Err("Discrete contains 0".into())
                } else if v.windows(2).any(|w| w[0] >= w[1]) {
                    Err("Discrete not strictly ascending".into())
                } else {
                    Ok(())
                }
            }
        }
    }
}

/// Full cost vector of an action: one [`DimCost`] per registered kind.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CostSpec {
    dims: Vec<DimCost>,
}

impl CostSpec {
    pub fn new(dims: Vec<DimCost>) -> Self {
        CostSpec { dims }
    }

    /// Cost touching a single dimension (the common case).
    pub fn single(
        reg: &super::ResourceRegistry,
        kind: ResourceKindId,
        cost: DimCost,
    ) -> Self {
        let mut dims = vec![DimCost::None; reg.len()];
        dims[kind.0 as usize] = cost;
        CostSpec { dims }
    }

    /// Builder: set an additional dimension.
    pub fn with(mut self, kind: ResourceKindId, cost: DimCost) -> Self {
        self.dims[kind.0 as usize] = cost;
        self
    }

    pub fn dim(&self, kind: ResourceKindId) -> &DimCost {
        &self.dims[kind.0 as usize]
    }

    pub fn dim_has_choice(&self, kind: ResourceKindId) -> bool {
        self.dims[kind.0 as usize].has_choice()
    }

    pub fn len(&self) -> usize {
        self.dims.len()
    }

    pub fn is_empty(&self) -> bool {
        self.dims.is_empty()
    }

    /// Minimum-requirement vector `c_i^min` (candidate-selection constraint).
    pub fn min_vector(&self) -> ResourceVector {
        ResourceVector::from_vec(self.dims.iter().map(|d| d.min_units()).collect())
    }

    pub fn iter(&self) -> impl Iterator<Item = (ResourceKindId, &DimCost)> {
        self.dims
            .iter()
            .enumerate()
            .map(|(i, d)| (ResourceKindId(i as u32), d))
    }

    pub fn validate(&self) -> Result<(), String> {
        for (i, d) in self.dims.iter().enumerate() {
            d.validate().map_err(|e| format!("dim {i}: {e}"))?;
        }
        if self.dims.iter().all(|d| matches!(d, DimCost::None)) {
            return Err("cost vector touches no resource".into());
        }
        Ok(())
    }
}

/// Concrete unit quantities per resource kind (allocations, availability).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ResourceVector {
    units: Vec<u64>,
}

impl ResourceVector {
    pub fn zeros(k: usize) -> Self {
        ResourceVector { units: vec![0; k] }
    }

    pub fn from_vec(units: Vec<u64>) -> Self {
        ResourceVector { units }
    }

    pub fn get(&self, kind: ResourceKindId) -> u64 {
        self.units[kind.0 as usize]
    }

    pub fn set(&mut self, kind: ResourceKindId, v: u64) {
        self.units[kind.0 as usize] = v;
    }

    pub fn len(&self) -> usize {
        self.units.len()
    }

    pub fn is_empty(&self) -> bool {
        self.units.is_empty()
    }

    /// Component-wise `self ≥ other` (the `R_j ≥ Σ c^min` check, quantity
    /// part; topology feasibility is the managers' `accommodate`).
    pub fn dominates(&self, other: &ResourceVector) -> bool {
        debug_assert_eq!(self.units.len(), other.units.len());
        self.units.iter().zip(&other.units).all(|(a, b)| a >= b)
    }

    pub fn checked_sub(&self, other: &ResourceVector) -> Option<ResourceVector> {
        if !self.dominates(other) {
            return None;
        }
        Some(ResourceVector::from_vec(
            self.units.iter().zip(&other.units).map(|(a, b)| a - b).collect(),
        ))
    }

    pub fn iter(&self) -> impl Iterator<Item = (ResourceKindId, u64)> + '_ {
        self.units
            .iter()
            .enumerate()
            .map(|(i, &v)| (ResourceKindId(i as u32), v))
    }
}

impl AddAssign<&ResourceVector> for ResourceVector {
    fn add_assign(&mut self, o: &ResourceVector) {
        debug_assert_eq!(self.units.len(), o.units.len());
        for (a, b) in self.units.iter_mut().zip(&o.units) {
            *a += b;
        }
    }
}

impl SubAssign<&ResourceVector> for ResourceVector {
    fn sub_assign(&mut self, o: &ResourceVector) {
        debug_assert_eq!(self.units.len(), o.units.len());
        for (a, b) in self.units.iter_mut().zip(&o.units) {
            debug_assert!(*a >= *b, "resource underflow");
            *a -= b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::action::{ResourceClass, ResourceRegistry};

    #[test]
    fn dim_cost_bounds_and_choices() {
        assert_eq!(DimCost::None.choices(), vec![0]);
        assert_eq!(DimCost::Fixed(3).choices(), vec![3]);
        assert_eq!(DimCost::Range { min: 2, max: 4 }.choices(), vec![2, 3, 4]);
        let d = DimCost::Discrete(vec![1, 2, 4, 8]);
        assert_eq!(d.min_units(), 1);
        assert_eq!(d.max_units(), 8);
        assert!(d.allows(4));
        assert!(!d.allows(3));
        assert!(d.has_choice());
        assert!(!DimCost::Fixed(3).has_choice());
    }

    #[test]
    fn validation_catches_malformed() {
        assert!(DimCost::Fixed(0).validate().is_err());
        assert!(DimCost::Range { min: 0, max: 3 }.validate().is_err());
        assert!(DimCost::Range { min: 5, max: 3 }.validate().is_err());
        assert!(DimCost::Discrete(vec![]).validate().is_err());
        assert!(DimCost::Discrete(vec![2, 2]).validate().is_err());
        assert!(DimCost::Discrete(vec![0, 1]).validate().is_err());
        assert!(DimCost::Discrete(vec![1, 2, 4]).validate().is_ok());
    }

    #[test]
    fn cost_spec_multi_dim() {
        let mut reg = ResourceRegistry::new();
        let cpu = reg.register("cpu", ResourceClass::CpuCores, 64);
        let mem = reg.register("mem", ResourceClass::CpuMemoryGb, 512);
        let spec = CostSpec::single(&reg, cpu, DimCost::Range { min: 1, max: 8 })
            .with(mem, DimCost::Fixed(4));
        assert!(spec.validate().is_ok());
        let min = spec.min_vector();
        assert_eq!(min.get(cpu), 1);
        assert_eq!(min.get(mem), 4);
        assert!(spec.dim_has_choice(cpu));
        assert!(!spec.dim_has_choice(mem));
    }

    #[test]
    fn empty_cost_rejected() {
        let mut reg = ResourceRegistry::new();
        let _ = reg.register("cpu", ResourceClass::CpuCores, 1);
        let spec = CostSpec::new(vec![DimCost::None]);
        assert!(spec.validate().is_err());
    }

    #[test]
    fn vector_arithmetic() {
        let mut a = ResourceVector::from_vec(vec![10, 5]);
        let b = ResourceVector::from_vec(vec![3, 5]);
        assert!(a.dominates(&b));
        assert!(!b.dominates(&a));
        a -= &b;
        assert_eq!(a, ResourceVector::from_vec(vec![7, 0]));
        a += &b;
        assert_eq!(a.get(ResourceKindId(0)), 10);
        assert_eq!(a.checked_sub(&ResourceVector::from_vec(vec![11, 0])), None);
    }
}
