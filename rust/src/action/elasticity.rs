//! Elasticity modeling (paper §4.1, Eq. 1).
//!
//! For a scalable action, elasticity maps allocated units `m` of the key
//! resource to an efficiency ratio `0 < E(m) ≤ 1`:
//!
//! ```text
//! getDur(m) = T_ori / (E(m) · m)
//! ```
//!
//! `E(1) = 1` by definition (the profile is normalized to one unit).

use crate::sim::SimDur;

/// `E(m)` families. `None` marks actions whose elasticity is unknown —
/// the scheduler then pins them at their minimum request (§4.2).
#[derive(Debug, Clone, PartialEq)]
pub enum ElasticityModel {
    /// Unknown elasticity (`E_i is None` in Algorithm 1).
    None,
    /// Perfect linear scaling: `E(m) = 1`.
    PerfectScaling,
    /// Amdahl's law with serial fraction `s`:
    /// speedup(m) = 1 / (s + (1-s)/m)  ⇒  E(m) = speedup(m)/m.
    /// Models parallel test-suite execution (pytest -n) with setup cost.
    Amdahl { serial_frac: f64 },
    /// Tabulated efficiency at m = 1, 2, 3, …: `table[m-1] = E(m)`.
    /// Allocations beyond the table clamp to the last entry. Models profiled
    /// GPU services where TP efficiency is measured per DoP.
    Table(Vec<f64>),
}

impl ElasticityModel {
    /// Efficiency `E(m)`; `m == 0` is a caller bug.
    pub fn efficiency(&self, m: u64) -> f64 {
        debug_assert!(m >= 1, "E(m) needs m ≥ 1");
        let m = m.max(1);
        match self {
            // Unknown elasticity never scales: treat extra units as useless.
            ElasticityModel::None => 1.0 / m as f64,
            ElasticityModel::PerfectScaling => 1.0,
            ElasticityModel::Amdahl { serial_frac } => {
                let s = serial_frac.clamp(0.0, 1.0);
                let speedup = 1.0 / (s + (1.0 - s) / m as f64);
                speedup / m as f64
            }
            ElasticityModel::Table(t) => {
                if t.is_empty() {
                    1.0 / m as f64
                } else {
                    let idx = (m as usize - 1).min(t.len() - 1);
                    t[idx].clamp(1e-6, 1.0)
                }
            }
        }
    }

    /// Eq. 1: execution duration with `m` units given single-unit `t_ori`.
    pub fn scaled_dur(&self, t_ori: SimDur, m: u64) -> SimDur {
        let m = m.max(1);
        let e = self.efficiency(m);
        let denom = e * m as f64;
        debug_assert!(denom > 0.0);
        SimDur((t_ori.0 as f64 / denom).round() as u64)
    }

    /// Speedup factor over a single unit.
    pub fn speedup(&self, m: u64) -> f64 {
        self.efficiency(m) * m.max(1) as f64
    }

    /// True if more units can ever help.
    pub fn is_scalable(&self) -> bool {
        !matches!(self, ElasticityModel::None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_unit_is_identity() {
        let t = SimDur::from_secs(10);
        for e in [
            ElasticityModel::None,
            ElasticityModel::PerfectScaling,
            ElasticityModel::Amdahl { serial_frac: 0.2 },
            ElasticityModel::Table(vec![1.0, 0.9, 0.8]),
        ] {
            assert_eq!(e.scaled_dur(t, 1), t, "{e:?}");
            assert!((e.efficiency(1) - 1.0).abs() < 1e-9, "{e:?}");
        }
    }

    #[test]
    fn perfect_scaling_divides() {
        let e = ElasticityModel::PerfectScaling;
        assert_eq!(e.scaled_dur(SimDur::from_secs(8), 4), SimDur::from_secs(2));
        assert_eq!(e.speedup(16), 16.0);
    }

    #[test]
    fn amdahl_caps_speedup() {
        let e = ElasticityModel::Amdahl { serial_frac: 0.25 };
        // asymptotic speedup = 1/0.25 = 4
        assert!(e.speedup(1_000) < 4.0);
        assert!(e.speedup(1_000) > 3.9);
        // speedup(2) = 1/(0.25+0.375) = 1.6
        assert!((e.speedup(2) - 1.6).abs() < 1e-9);
        // monotone non-decreasing speedup
        let mut last = 0.0;
        for m in 1..64 {
            let s = e.speedup(m);
            assert!(s >= last);
            last = s;
        }
    }

    #[test]
    fn unknown_elasticity_never_speeds_up() {
        let e = ElasticityModel::None;
        let t = SimDur::from_secs(10);
        assert_eq!(e.scaled_dur(t, 8), t);
        assert!(!e.is_scalable());
    }

    #[test]
    fn table_lookup_and_clamp() {
        let e = ElasticityModel::Table(vec![1.0, 0.95, 0.85, 0.7]);
        assert!((e.efficiency(2) - 0.95).abs() < 1e-9);
        assert!((e.efficiency(4) - 0.7).abs() < 1e-9);
        assert!((e.efficiency(100) - 0.7).abs() < 1e-9); // clamps
        let t = SimDur::from_secs(19);
        // dur(2) = 19 / (0.95*2) = 10
        assert_eq!(e.scaled_dur(t, 2), SimDur::from_secs(10));
    }

    #[test]
    fn efficiency_always_in_unit_interval() {
        for e in [
            ElasticityModel::PerfectScaling,
            ElasticityModel::Amdahl { serial_frac: 0.5 },
            ElasticityModel::Table(vec![0.9, 2.0]), // 2.0 must clamp to 1.0
        ] {
            for m in 1..20 {
                let eff = e.efficiency(m);
                assert!(eff > 0.0 && eff <= 1.0, "{e:?} m={m} eff={eff}");
            }
        }
    }
}
