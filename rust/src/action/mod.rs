//! Unified action-level formulation (paper §4.1).
//!
//! Every external invocation — a shell command in an AI-coding environment,
//! a reward-model scoring batch, a search-API call — is normalized into an
//! [`ActionSpec`]: a vectorized resource cost `C_i` over the resource kinds
//! registered with the system, an optional *key elasticity resource* with an
//! elasticity model `E(m)`, and a profiled single-unit duration `T_ori`
//! (Eq. 1: `getDur(m) = T_ori / (E(m)·m)`).

pub mod cost;
pub mod elasticity;

pub use cost::{CostSpec, DimCost, ResourceVector};
pub use elasticity::ElasticityModel;

use crate::sim::{SimDur, SimTime};

/// Index into the [`ResourceRegistry`]. One per managed resource type
/// (CPU cores, CPU memory, GPU units, each API endpoint's quota, ...).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ResourceKindId(pub u32);

/// Broad class of a resource kind; managers claim kinds by class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResourceClass {
    /// CPU cores on the environment cluster (AOE manager).
    CpuCores,
    /// CPU memory, GiB granularity (co-managed with cores).
    CpuMemoryGb,
    /// GPUs on the reward-service cluster (EOE manager).
    GpuUnits,
    /// Concurrency-limited external service (Basic manager).
    ApiConcurrency,
    /// Quota-per-window external service (Basic manager).
    ApiQuota,
}

/// A registered resource kind.
#[derive(Debug, Clone)]
pub struct ResourceKindInfo {
    pub name: String,
    pub class: ResourceClass,
    /// Total units in the pool (cores / GPUs / concurrent slots / quota).
    pub capacity: u64,
}

/// Registry of all external resource kinds managed by the system.
/// `ResourceVector`s are indexed by registration order.
#[derive(Debug, Clone, Default)]
pub struct ResourceRegistry {
    kinds: Vec<ResourceKindInfo>,
}

impl ResourceRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register(&mut self, name: &str, class: ResourceClass, capacity: u64) -> ResourceKindId {
        assert!(
            self.kinds.iter().all(|k| k.name != name),
            "duplicate resource kind {name}"
        );
        self.kinds.push(ResourceKindInfo { name: name.to_string(), class, capacity });
        ResourceKindId(self.kinds.len() as u32 - 1)
    }

    pub fn len(&self) -> usize {
        self.kinds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kinds.is_empty()
    }

    pub fn info(&self, id: ResourceKindId) -> &ResourceKindInfo {
        &self.kinds[id.0 as usize]
    }

    pub fn by_name(&self, name: &str) -> Option<ResourceKindId> {
        self.kinds
            .iter()
            .position(|k| k.name == name)
            .map(|i| ResourceKindId(i as u32))
    }

    pub fn iter(&self) -> impl Iterator<Item = (ResourceKindId, &ResourceKindInfo)> {
        self.kinds
            .iter()
            .enumerate()
            .map(|(i, k)| (ResourceKindId(i as u32), k))
    }

    /// Zeroed vector with one slot per registered kind.
    pub fn zero_vector(&self) -> ResourceVector {
        ResourceVector::zeros(self.len())
    }

    /// Vector of full capacities.
    pub fn capacity_vector(&self) -> ResourceVector {
        ResourceVector::from_vec(self.kinds.iter().map(|k| k.capacity).collect())
    }
}

/// What kind of external invocation an action is (reporting + workload gen).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ActionKind {
    /// Tool call inside a coding environment (shell exec, file edit).
    EnvExec,
    /// Reward computation on CPUs (e.g. run the test suite).
    RewardCpu,
    /// Reward-model / teacher-model inference on GPUs.
    RewardModel,
    /// External API call (search, fetch, PDF parse).
    ApiCall,
}

impl ActionKind {
    pub fn name(self) -> &'static str {
        match self {
            ActionKind::EnvExec => "env_exec",
            ActionKind::RewardCpu => "reward_cpu",
            ActionKind::RewardModel => "reward_model",
            ActionKind::ApiCall => "api_call",
        }
    }
}

/// Identifiers threading actions back to their RL context.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub u32);
/// The RL job (tenant) an action belongs to. Single-job scenarios use
/// tenant 0 everywhere; multi-tenant specs share the same elastic pools
/// under weighted-fair queueing (ROADMAP item 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub struct TenantId(pub u32);
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TrajId(pub u64);
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ActionId(pub u64);

/// A GPU-backed model service (reward model / teacher). The GPU manager
/// treats each (service, DoP) pair as a distinct deployable variant (§5.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ServiceId(pub u32);

/// The unified action formulation submitted to the coordinator.
#[derive(Debug, Clone)]
pub struct ActionSpec {
    pub task: TaskId,
    /// The RL job this action belongs to (0 for single-tenant scenarios).
    pub tenant: TenantId,
    pub trajectory: TrajId,
    pub kind: ActionKind,
    /// Vectorized resource cost `C_i`: one [`DimCost`] per registered kind.
    pub cost: CostSpec,
    /// The single resource type that dominates elasticity (§4.1 assumption),
    /// if the action is elastic.
    pub key_resource: Option<ResourceKindId>,
    /// Elasticity model `E(m)` on the key resource.
    pub elasticity: ElasticityModel,
    /// Profiled execution duration with one unit of the key resource
    /// (`T_ori`). `None` for unprofiled actions — the scheduler then treats
    /// them as non-scalable and uses historical averages for heap estimates.
    pub profiled_dur: Option<SimDur>,
    /// For [`ActionKind::RewardModel`]: which service must execute it.
    pub service: Option<ServiceId>,
    /// True duration the simulator charges (hidden from the scheduler unless
    /// profiled; models LLM-output-dependent variability).
    pub true_dur: SimDur,
}

impl ActionSpec {
    /// Execution duration under `m` units of the key resource (Eq. 1),
    /// based on the *true* duration (used by the execution substrate).
    pub fn exec_dur(&self, m: u64) -> SimDur {
        self.elasticity.scaled_dur(self.true_dur, m)
    }

    /// Scheduler-visible duration estimate under `m` units (uses the
    /// profiled duration; `None` if unprofiled).
    pub fn est_dur(&self, m: u64) -> Option<SimDur> {
        self.profiled_dur.map(|d| self.elasticity.scaled_dur(d, m))
    }

    /// Whether the scheduler may scale this action (§4.2: needs both a known
    /// elasticity and a profiled duration).
    pub fn is_scalable(&self) -> bool {
        self.key_resource.is_some()
            && !matches!(self.elasticity, ElasticityModel::None)
            && self.profiled_dur.is_some()
            && self.cost.dim_has_choice(self.key_resource.unwrap())
    }
}

/// Lifecycle states of a submitted action.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActionState {
    Waiting,
    Running,
    Done,
    Failed,
}

/// A submitted action tracked by the coordinator.
#[derive(Debug, Clone)]
pub struct Action {
    pub id: ActionId,
    pub spec: ActionSpec,
    pub state: ActionState,
    pub submitted_at: SimTime,
    pub started_at: Option<SimTime>,
    pub finished_at: Option<SimTime>,
    /// Units of the key resource actually allocated.
    pub allocated_units: u64,
    /// Setup/restore overhead charged before execution (EOE restore, cgroup
    /// update, pod creation for baselines).
    pub overhead: SimDur,
    /// Transparent retries performed so far (API transient failures).
    pub retry_count: u32,
}

impl Action {
    pub fn new(id: ActionId, spec: ActionSpec, now: SimTime) -> Self {
        Action {
            id,
            spec,
            state: ActionState::Waiting,
            submitted_at: now,
            started_at: None,
            finished_at: None,
            allocated_units: 0,
            overhead: SimDur::ZERO,
            retry_count: 0,
        }
    }

    /// Action completion time so far (queuing + execution), defined once the
    /// action finished. The paper's headline per-action metric (Eq. 2).
    pub fn act(&self) -> Option<SimDur> {
        Some(self.finished_at? - self.submitted_at)
    }

    pub fn queue_dur(&self) -> Option<SimDur> {
        Some(self.started_at? - self.submitted_at)
    }

    pub fn exec_dur_actual(&self) -> Option<SimDur> {
        Some(self.finished_at? - self.started_at?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reg() -> ResourceRegistry {
        let mut r = ResourceRegistry::new();
        r.register("cpu", ResourceClass::CpuCores, 256);
        r.register("mem", ResourceClass::CpuMemoryGb, 2048);
        r.register("gpu", ResourceClass::GpuUnits, 40);
        r
    }

    #[test]
    fn registry_roundtrip() {
        let r = reg();
        assert_eq!(r.len(), 3);
        let cpu = r.by_name("cpu").unwrap();
        assert_eq!(r.info(cpu).capacity, 256);
        assert_eq!(r.by_name("nope"), None);
        assert_eq!(r.capacity_vector().get(ResourceKindId(2)), 40);
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn duplicate_kind_panics() {
        let mut r = reg();
        r.register("cpu", ResourceClass::CpuCores, 1);
    }

    #[test]
    fn action_lifecycle_metrics() {
        let r = reg();
        let cpu = r.by_name("cpu").unwrap();
        let spec = ActionSpec {
            task: TaskId(0),
            tenant: TenantId(0),
            trajectory: TrajId(0),
            kind: ActionKind::RewardCpu,
            cost: CostSpec::single(&r, cpu, DimCost::Range { min: 1, max: 8 }),
            key_resource: Some(cpu),
            elasticity: ElasticityModel::PerfectScaling,
            profiled_dur: Some(SimDur::from_secs(8)),
            service: None,
            true_dur: SimDur::from_secs(8),
        };
        assert!(spec.is_scalable());
        assert_eq!(spec.exec_dur(4), SimDur::from_secs(2));
        let mut a = Action::new(ActionId(1), spec, SimTime(0));
        a.started_at = Some(SimTime(5));
        a.finished_at = Some(SimTime(25));
        assert_eq!(a.queue_dur(), Some(SimDur(5)));
        assert_eq!(a.exec_dur_actual(), Some(SimDur(20)));
        assert_eq!(a.act(), Some(SimDur(25)));
    }

    #[test]
    fn fixed_cost_is_not_scalable() {
        let r = reg();
        let cpu = r.by_name("cpu").unwrap();
        let spec = ActionSpec {
            task: TaskId(0),
            tenant: TenantId(0),
            trajectory: TrajId(0),
            kind: ActionKind::EnvExec,
            cost: CostSpec::single(&r, cpu, DimCost::Fixed(1)),
            key_resource: Some(cpu),
            elasticity: ElasticityModel::PerfectScaling,
            profiled_dur: Some(SimDur::from_secs(1)),
            service: None,
            true_dur: SimDur::from_secs(1),
        };
        assert!(!spec.is_scalable(), "fixed unit set leaves nothing to scale");
    }
}
