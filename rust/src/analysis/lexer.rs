//! Minimal Rust lexer for the determinism lint.
//!
//! Hand-rolled in the `util::json` idiom: a byte cursor, no regexes, no
//! `syn`. It produces exactly the structure the lexical rules need —
//! identifiers, single-char punctuation, literals, line numbers — and
//! discards comments and whitespace (`arl-lint: allow` comments are parsed
//! from raw source lines by the engine, not from tokens). Block comments
//! nest, raw strings honor their `#` fences, and lifetimes are told apart
//! from char literals, so token streams stay aligned with real Rust even
//! in tricky files.

/// Token class. `Punct` is always a single character; multi-char operators
/// (`::`, `->`, `..`) appear as consecutive punct tokens and are matched
/// positionally by the rules.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Punct,
    Str,
    Char,
    Num,
    Lifetime,
}

/// One lexed token. `text` carries the lexeme for idents and puncts (the
/// only kinds the rules match by content); literals keep an empty text.
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
}

impl Token {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct && self.text.len() == 1 && self.text.as_bytes()[0] == c as u8
    }
}

/// Tokenize `src`. Never fails: unterminated literals simply run to EOF,
/// which is good enough for a linter that only sees `rustc`-clean input.
pub fn lex(src: &str) -> Vec<Token> {
    Lexer { s: src.as_bytes(), pos: 0, line: 1, out: Vec::new() }.run()
}

struct Lexer<'a> {
    s: &'a [u8],
    pos: usize,
    line: usize,
    out: Vec<Token>,
}

impl Lexer<'_> {
    fn run(mut self) -> Vec<Token> {
        while self.pos < self.s.len() {
            let c = self.s[self.pos];
            if c == b'\n' {
                self.line += 1;
                self.pos += 1;
            } else if c.is_ascii_whitespace() {
                self.pos += 1;
            } else if c == b'/' && self.peek(1) == Some(b'/') {
                self.line_comment();
            } else if c == b'/' && self.peek(1) == Some(b'*') {
                self.block_comment();
            } else if c == b'"' {
                self.string();
                self.push_lit(TokKind::Str);
            } else if c == b'\'' {
                self.char_or_lifetime();
            } else if c == b'_' || c.is_ascii_alphabetic() {
                if !self.try_prefixed_literal() {
                    self.ident();
                }
            } else if c.is_ascii_digit() {
                self.number();
            } else {
                self.out.push(Token {
                    kind: TokKind::Punct,
                    text: (c as char).to_string(),
                    line: self.line,
                });
                self.pos += 1;
            }
        }
        self.out
    }

    fn peek(&self, off: usize) -> Option<u8> {
        self.s.get(self.pos + off).copied()
    }

    fn push_lit(&mut self, kind: TokKind) {
        self.out.push(Token { kind, text: String::new(), line: self.line });
    }

    fn line_comment(&mut self) {
        while self.pos < self.s.len() && self.s[self.pos] != b'\n' {
            self.pos += 1;
        }
    }

    fn block_comment(&mut self) {
        // Rust block comments nest
        self.pos += 2;
        let mut depth = 1usize;
        while self.pos < self.s.len() && depth > 0 {
            match self.s[self.pos] {
                b'\n' => self.line += 1,
                b'/' if self.peek(1) == Some(b'*') => {
                    depth += 1;
                    self.pos += 1;
                }
                b'*' if self.peek(1) == Some(b'/') => {
                    depth -= 1;
                    self.pos += 1;
                }
                _ => {}
            }
            self.pos += 1;
        }
    }

    /// Consume a `"…"` literal starting at the opening quote.
    fn string(&mut self) {
        self.pos += 1;
        while self.pos < self.s.len() {
            match self.s[self.pos] {
                b'\\' => self.pos += 1,
                b'\n' => self.line += 1,
                b'"' => {
                    self.pos += 1;
                    return;
                }
                _ => {}
            }
            self.pos += 1;
        }
    }

    /// Consume a `r"…"` / `r#"…"#` literal starting at the first `#` or `"`.
    fn raw_string(&mut self) {
        let mut hashes = 0usize;
        while self.peek(0) == Some(b'#') {
            hashes += 1;
            self.pos += 1;
        }
        self.pos += 1; // opening quote
        while self.pos < self.s.len() {
            let c = self.s[self.pos];
            if c == b'\n' {
                self.line += 1;
            } else if c == b'"' {
                let mut ok = true;
                for k in 0..hashes {
                    if self.peek(1 + k) != Some(b'#') {
                        ok = false;
                        break;
                    }
                }
                if ok {
                    self.pos += 1 + hashes;
                    return;
                }
            }
            self.pos += 1;
        }
    }

    /// `r"…"`, `r#"…"#`, `b"…"`, `br"…"`, `b'…'` — string/char literals with
    /// an ident-looking prefix. Returns false if the cursor is a plain ident.
    fn try_prefixed_literal(&mut self) -> bool {
        let c = self.s[self.pos];
        let (skip, next) = match (c, self.peek(1)) {
            (b'r', Some(b'"')) => (1, b'"'),
            (b'r', Some(b'#')) => {
                // raw string `r#"…"#` vs raw ident `r#type`
                let mut k = 1;
                while self.peek(k) == Some(b'#') {
                    k += 1;
                }
                if self.peek(k) == Some(b'"') {
                    (1, b'#')
                } else {
                    return false;
                }
            }
            (b'b', Some(b'"')) => (1, b'"'),
            (b'b', Some(b'\'')) => (1, b'\''),
            (b'b', Some(b'r')) => match self.peek(2) {
                Some(b'"') => (2, b'"'),
                Some(b'#') => (2, b'#'),
                _ => return false,
            },
            _ => return false,
        };
        self.pos += skip;
        match next {
            b'"' => {
                self.string();
                self.push_lit(TokKind::Str);
            }
            b'#' => {
                self.raw_string();
                self.push_lit(TokKind::Str);
            }
            _ => {
                self.char_literal();
                self.push_lit(TokKind::Char);
            }
        }
        true
    }

    /// At a `'`: lifetime (`'a`) or char literal (`'x'`, `'\n'`).
    fn char_or_lifetime(&mut self) {
        let ident_next = matches!(self.peek(1), Some(c) if c == b'_' || c.is_ascii_alphabetic());
        if ident_next && self.peek(2) != Some(b'\'') {
            self.pos += 1;
            while self.pos < self.s.len()
                && (self.s[self.pos] == b'_' || self.s[self.pos].is_ascii_alphanumeric())
            {
                self.pos += 1;
            }
            self.push_lit(TokKind::Lifetime);
        } else {
            self.char_literal();
            self.push_lit(TokKind::Char);
        }
    }

    /// Consume a char literal starting at the opening `'`.
    fn char_literal(&mut self) {
        self.pos += 1;
        while self.pos < self.s.len() {
            match self.s[self.pos] {
                b'\\' => self.pos += 1,
                b'\'' => {
                    self.pos += 1;
                    return;
                }
                b'\n' => return, // malformed; don't eat the rest of the file
                _ => {}
            }
            self.pos += 1;
        }
    }

    fn ident(&mut self) {
        let start = self.pos;
        while self.pos < self.s.len()
            && (self.s[self.pos] == b'_' || self.s[self.pos].is_ascii_alphanumeric())
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.s[start..self.pos]).unwrap_or("").to_string();
        self.out.push(Token { kind: TokKind::Ident, text, line: self.line });
    }

    /// Numbers including suffixes (`1u64`, `0xFF`) and decimals; a `.` is
    /// consumed only when a digit follows, so `0..n` and `1.max(x)` keep
    /// their puncts.
    fn number(&mut self) {
        while self.pos < self.s.len() {
            let c = self.s[self.pos];
            if c == b'_' || c.is_ascii_alphanumeric() {
                self.pos += 1;
            } else if c == b'.'
                && matches!(self.peek(1), Some(d) if d.is_ascii_digit())
            {
                self.pos += 1;
            } else {
                break;
            }
        }
        self.push_lit(TokKind::Num);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn comments_and_strings_are_invisible() {
        let src = r##"
            // Instant::now in a comment
            /* nested /* SystemTime */ still comment */
            let s = "Instant::now()";
            let r = r#"SystemTime "quoted" inside"#;
            let b = b"bytes";
            real_ident();
        "##;
        let ids = idents(src);
        assert!(ids.contains(&"real_ident".to_string()));
        assert!(!ids.iter().any(|i| i == "Instant" || i == "SystemTime" || i == "now"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let toks = lex("fn f<'a>(x: &'a str) -> char { 'x' }");
        let lifetimes = toks.iter().filter(|t| t.kind == TokKind::Lifetime).count();
        let chars = toks.iter().filter(|t| t.kind == TokKind::Char).count();
        assert_eq!(lifetimes, 2);
        assert_eq!(chars, 1);
    }

    #[test]
    fn line_numbers_track_newlines() {
        let toks = lex("a\nb\n\nc");
        let lines: Vec<usize> = toks.iter().map(|t| t.line).collect();
        assert_eq!(lines, vec![1, 2, 4]);
    }

    #[test]
    fn escaped_quotes_and_ranges() {
        let toks = lex(r#"let c = '\''; let s = "a\"b"; for i in 0..map.len() {}"#);
        assert!(toks.iter().any(|t| t.is_ident("map")));
        assert!(toks.iter().any(|t| t.is_ident("len")));
        // the range dots survive as puncts
        assert!(toks.windows(2).any(|w| w[0].is_punct('.') && w[1].is_punct('.')));
    }

    #[test]
    fn numbers_keep_method_dots() {
        let toks = lex("let x = 1.0 + 2.max(3) + 0xFFu64;");
        let nums = toks.iter().filter(|t| t.kind == TokKind::Num).count();
        assert_eq!(nums, 3);
        assert!(toks.iter().any(|t| t.is_ident("max")));
    }
}
