//! Determinism lint: static source-level enforcement of the replay
//! contracts (`arl-tangram lint`).
//!
//! Every claim this reproduction makes — byte-identical record→replay, the
//! golden trace suites, the fuzz oracle — rests on source conventions:
//! sorted pool/lane iteration, factors quantized to 1/8, no wall-clock or
//! ambient randomness in decision paths, the `Metrics::ledger` field kept
//! off the serialized surface. The fuzz oracle catches violations at
//! runtime per-seed; this module catches them at review time on every
//! line. Like the rest of `util/`, it is dependency-free and hand-rolled
//! (no `syn`, no clippy plugins): a small Rust lexer ([`lexer`]) feeds
//! seven lexical rules ([`rules`]), and accepted findings live in a
//! committed `lint_baseline.json` that is only allowed to shrink.
//!
//! Rule summary (full semantics in `testdata/README.md`):
//!
//! | rule              | contract                                          |
//! |-------------------|---------------------------------------------------|
//! | `nondet-iteration`| no HashMap/HashSet iteration in decision paths    |
//! | `wall-clock`      | `Instant`/`SystemTime` only in `util::stopwatch`  |
//! | `ambient-rng`     | randomness only via seeded `util::rng::SplitMix64`|
//! | `raw-factor`      | factor arithmetic goes through `quantize`         |
//! | `panic-budget`    | per-file `.unwrap()/.expect()` count ratchet      |
//! | `golden-surface`  | unserialized fields stay out of `to_json` paths   |
//! | `ambient-threads` | threads spawn only in `coordinator::parallel`     |
//!
//! Suppression: `// arl-lint: allow(<rule>): <reason>` on the offending
//! line or the comment block directly above it; the reason is mandatory.

pub mod lexer;
pub mod rules;

pub use rules::{lint_source, LintConfig};

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

/// The seven determinism rules.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum RuleId {
    NondetIteration,
    WallClock,
    AmbientRng,
    RawFactor,
    PanicBudget,
    GoldenSurface,
    AmbientThreads,
}

impl RuleId {
    pub const ALL: [RuleId; 7] = [
        RuleId::NondetIteration,
        RuleId::WallClock,
        RuleId::AmbientRng,
        RuleId::RawFactor,
        RuleId::PanicBudget,
        RuleId::GoldenSurface,
        RuleId::AmbientThreads,
    ];

    pub fn name(self) -> &'static str {
        match self {
            RuleId::NondetIteration => "nondet-iteration",
            RuleId::WallClock => "wall-clock",
            RuleId::AmbientRng => "ambient-rng",
            RuleId::RawFactor => "raw-factor",
            RuleId::PanicBudget => "panic-budget",
            RuleId::GoldenSurface => "golden-surface",
            RuleId::AmbientThreads => "ambient-threads",
        }
    }

    pub fn parse(s: &str) -> Option<RuleId> {
        RuleId::ALL.iter().copied().find(|r| r.name() == s)
    }
}

impl fmt::Display for RuleId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One lint hit: rule, repo-relative file, 1-based line, human message.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: RuleId,
    pub file: String,
    pub line: usize,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Lint every `.rs` file under `root` (recursive, sorted traversal so
/// reports are byte-stable). File paths in findings are `root`-prefixed
/// with forward slashes, matching the committed baseline keys.
pub fn lint_tree(root: &Path, cfg: &LintConfig) -> Result<Vec<Finding>, String> {
    let prefix = root.to_string_lossy().replace('\\', "/");
    let mut files: Vec<(std::path::PathBuf, String)> = Vec::new();
    collect_rs(root, &prefix, &mut files)?;
    files.sort_by(|a, b| a.1.cmp(&b.1));
    let mut out = Vec::new();
    for (path, rel) in files {
        let src = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        out.extend(lint_source(&rel, &src, cfg));
    }
    Ok(out)
}

fn collect_rs(
    dir: &Path,
    prefix: &str,
    out: &mut Vec<(std::path::PathBuf, String)>,
) -> Result<(), String> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
        let path = entry.path();
        let name = entry.file_name().to_string_lossy().into_owned();
        if path.is_dir() {
            collect_rs(&path, &format!("{prefix}/{name}"), out)?;
        } else if name.ends_with(".rs") {
            out.push((path, format!("{prefix}/{name}")));
        }
    }
    Ok(())
}

/// Accepted findings: exact per-(rule, file) counts. The ratchet is
/// two-sided — counts above the baseline are new violations, counts below
/// it are a stale baseline that must be shrunk (`--write-baseline`) so
/// headroom can never silently accumulate.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Baseline {
    /// rule name → file → accepted finding count.
    pub counts: BTreeMap<String, BTreeMap<String, u64>>,
}

/// Outcome of checking findings against a [`Baseline`].
#[derive(Debug, Clone, Default)]
pub struct Comparison {
    /// (rule, file) buckets that grew past the baseline.
    pub violations: Vec<String>,
    /// (rule, file) buckets that shrank below the baseline.
    pub stale: Vec<String>,
}

impl Comparison {
    pub fn ok(&self) -> bool {
        self.violations.is_empty() && self.stale.is_empty()
    }
}

impl Baseline {
    pub fn from_findings(findings: &[Finding]) -> Baseline {
        let mut counts: BTreeMap<String, BTreeMap<String, u64>> = BTreeMap::new();
        for f in findings {
            *counts
                .entry(f.rule.name().to_string())
                .or_default()
                .entry(f.file.clone())
                .or_insert(0) += 1;
        }
        Baseline { counts }
    }

    /// Load a committed baseline. A missing file is an empty baseline (zero
    /// accepted findings), so a fresh tree is held to the strictest bar.
    pub fn load(path: &Path) -> Result<Baseline, String> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(Baseline::default())
            }
            Err(e) => return Err(format!("read {}: {e}", path.display())),
        };
        let json = Json::parse(&text).map_err(|e| format!("parse {}: {e}", path.display()))?;
        let obj = json
            .as_obj()
            .ok_or_else(|| format!("{}: expected an object", path.display()))?;
        let mut counts: BTreeMap<String, BTreeMap<String, u64>> = BTreeMap::new();
        for (rule, files) in obj {
            if RuleId::parse(rule).is_none() {
                return Err(format!("{}: unknown rule {rule:?}", path.display()));
            }
            let files = files
                .as_obj()
                .ok_or_else(|| format!("{}: rule {rule:?} is not an object", path.display()))?;
            let entry = counts.entry(rule.clone()).or_default();
            for (file, n) in files {
                let n = n
                    .as_u64()
                    .ok_or_else(|| format!("{}: {rule}/{file} is not a count", path.display()))?;
                entry.insert(file.clone(), n);
            }
        }
        Ok(Baseline { counts })
    }

    pub fn save(&self, path: &Path) -> Result<(), String> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .map_err(|e| format!("write {}: {e}", path.display()))
    }

    pub fn to_json(&self) -> Json {
        let rules: Vec<(&str, Json)> = self
            .counts
            .iter()
            .map(|(rule, files)| {
                let pairs: Vec<(&str, Json)> = files
                    .iter()
                    .map(|(f, n)| (f.as_str(), Json::num(*n as f64)))
                    .collect();
                (rule.as_str(), Json::obj(pairs))
            })
            .collect();
        Json::obj(rules)
    }

    /// Two-sided ratchet check of `findings` against this baseline.
    pub fn compare(&self, findings: &[Finding]) -> Comparison {
        let actual = Baseline::from_findings(findings);
        let mut cmp = Comparison::default();
        let mut keys: std::collections::BTreeSet<(&String, &String)> =
            std::collections::BTreeSet::new();
        for (rule, files) in self.counts.iter().chain(actual.counts.iter()) {
            for file in files.keys() {
                keys.insert((rule, file));
            }
        }
        for (rule, file) in keys {
            let base = self.counts.get(rule).and_then(|f| f.get(file)).copied().unwrap_or(0);
            let now = actual.counts.get(rule).and_then(|f| f.get(file)).copied().unwrap_or(0);
            if now > base {
                cmp.violations.push(format!(
                    "{file}: [{rule}] {now} findings, baseline accepts {base} — fix the new \
                     ones or add an `arl-lint: allow` with a reason"
                ));
            } else if now < base {
                cmp.stale.push(format!(
                    "{file}: [{rule}] baseline accepts {base} but only {now} remain — shrink \
                     it with `arl-tangram lint --write-baseline` (the ratchet is one-way)"
                ));
            }
        }
        cmp
    }
}

/// Machine-readable report for `arl-tangram lint --json`.
pub fn report_json(findings: &[Finding], cmp: &Comparison) -> Json {
    let counts = Baseline::from_findings(findings).to_json();
    Json::obj(vec![
        ("ok", Json::Bool(cmp.ok())),
        (
            "findings",
            Json::arr(findings.iter().map(|f| {
                Json::obj(vec![
                    ("rule", Json::str(f.rule.name())),
                    ("file", Json::str(f.file.as_str())),
                    ("line", Json::num(f.line as f64)),
                    ("message", Json::str(f.message.as_str())),
                ])
            })),
        ),
        ("counts", counts),
        ("violations", Json::arr(cmp.violations.iter().map(|v| Json::str(v.as_str())))),
        ("stale", Json::arr(cmp.stale.iter().map(|s| Json::str(s.as_str())))),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(rule: RuleId, file: &str) -> Finding {
        Finding { rule, file: file.into(), line: 1, message: String::new() }
    }

    #[test]
    fn baseline_roundtrip_and_compare() {
        let fs = vec![
            finding(RuleId::PanicBudget, "src/a.rs"),
            finding(RuleId::PanicBudget, "src/a.rs"),
            finding(RuleId::WallClock, "src/b.rs"),
        ];
        let b = Baseline::from_findings(&fs);
        let text = format!("{}", b.to_json());
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.path(&["panic-budget", "src/a.rs"]).unwrap().as_u64(), Some(2));
        assert!(b.compare(&fs).ok());
    }

    #[test]
    fn ratchet_flags_growth_and_staleness() {
        let base = Baseline::from_findings(&[finding(RuleId::PanicBudget, "src/a.rs")]);
        // growth: two findings against a baseline of one
        let grown = vec![
            finding(RuleId::PanicBudget, "src/a.rs"),
            finding(RuleId::PanicBudget, "src/a.rs"),
        ];
        let cmp = base.compare(&grown);
        assert_eq!(cmp.violations.len(), 1);
        assert!(cmp.stale.is_empty());
        // staleness: zero findings against a baseline of one
        let cmp = base.compare(&[]);
        assert!(cmp.violations.is_empty());
        assert_eq!(cmp.stale.len(), 1);
    }

    #[test]
    fn missing_baseline_is_empty() {
        let b = Baseline::load(Path::new("testdata/definitely-missing-baseline.json")).unwrap();
        assert!(b.counts.is_empty());
        assert!(b.compare(&[]).ok());
    }
}
