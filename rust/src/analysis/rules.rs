//! The seven determinism rules, evaluated over the lexer's token stream.
//!
//! Every rule is lexical: no type inference, no name resolution. The
//! `nondet-iteration` rule approximates typing by collecting every binding
//! declared `name: HashMap<…>` / `name: HashSet<…>` — `let` bindings and fn
//! params scoped to their function, struct fields to their file — plus a
//! configured list of hash-typed fields shared across files (lane queue
//! maps and manager tables that the coordinator reaches through its lanes).
//! False positives are possible by construction; that is what the
//! structured `// arl-lint: allow(<rule>): <reason>` comment and the
//! committed shrink-only baseline are for. Tokens inside `#[cfg(test)]` /
//! `#[test]` items are exempt from every rule: tests are not decision
//! paths.

use super::lexer::{lex, TokKind, Token};
use super::{Finding, RuleId};
use std::collections::{BTreeMap, BTreeSet};

/// Rule configuration. `Default` encodes this repository's contracts; tests
/// construct variants to probe individual rules.
#[derive(Debug, Clone)]
pub struct LintConfig {
    /// Path prefixes of decision-path modules: code here feeds the
    /// record/replay decision stream, so iteration order and factor
    /// arithmetic are contractual.
    pub decision_paths: Vec<String>,
    /// Exact file paths allowed to read the wall clock (observability
    /// helpers only; wall time must never feed serialized state).
    pub wall_clock_allow: Vec<String>,
    /// Hash-typed struct fields reached across file boundaries (e.g. the
    /// coordinator iterating its lanes' queue maps).
    pub shared_hash_fields: Vec<String>,
    /// Serialization functions whose bodies form the golden surface.
    pub serialize_fns: Vec<String>,
    /// Identifiers that are contractually excluded from serialization.
    pub unserialized_fields: Vec<String>,
    /// Exact file paths allowed to spawn threads or build channels (the
    /// coordinator's drain worker pool only; ambient parallelism anywhere
    /// else could reorder observable decisions).
    pub thread_allow: Vec<String>,
}

impl Default for LintConfig {
    fn default() -> Self {
        LintConfig {
            decision_paths: vec![
                "src/coordinator/".into(),
                "src/lanes/".into(),
                "src/autoscale/".into(),
                "src/scheduler/".into(),
                "src/managers/".into(),
            ],
            wall_clock_allow: vec!["src/util/stopwatch.rs".into()],
            shared_hash_fields: vec![
                "queues".into(),
                "mgrs".into(),
                "endpoints".into(),
                "active".into(),
                "bindings".into(),
                "services".into(),
            ],
            serialize_fns: vec!["to_json".into(), "summary_json".into()],
            unserialized_fields: vec!["ledger".into()],
            thread_allow: vec!["src/coordinator/parallel.rs".into()],
        }
    }
}

/// Hash-iteration method names that observe (or depend on) bucket order.
const ITER_METHODS: [&str; 10] = [
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "into_iter",
    "into_keys",
    "into_values",
    "drain",
    "retain",
];

/// Ambient randomness identifiers (the `rand` ecosystem's entropy taps).
const BANNED_RNG: [&str; 6] =
    ["thread_rng", "from_entropy", "OsRng", "StdRng", "SmallRng", "RandomState"];

/// Threading identifiers that stand alone (no `::` context needed): channel
/// constructors and join-handle types always mean ambient parallelism.
const BANNED_THREADS: [&str; 5] =
    ["mpsc", "sync_channel", "JoinHandle", "ScopedJoinHandle", "Condvar"];

/// Lint one file. `path` is the repo-relative path with forward slashes
/// (e.g. `src/lanes/api.rs`); it selects which rules apply. Findings
/// suppressed by `arl-lint: allow` comments are already filtered out.
pub fn lint_source(path: &str, src: &str, cfg: &LintConfig) -> Vec<Finding> {
    let toks = lex(src);
    let mask = test_mask(&toks);
    let mut out = Vec::new();
    rule_nondet_iteration(path, &toks, &mask, cfg, &mut out);
    rule_wall_clock(path, &toks, &mask, cfg, &mut out);
    rule_ambient_rng(path, &toks, &mask, &mut out);
    rule_raw_factor(path, &toks, &mask, cfg, &mut out);
    rule_panic_budget(path, &toks, &mask, &mut out);
    rule_golden_surface(path, &toks, &mask, cfg, &mut out);
    rule_ambient_threads(path, &toks, &mask, cfg, &mut out);

    let lines: Vec<&str> = src.lines().collect();
    let allows = parse_allows(&lines);
    out.retain(|f| !suppressed(f, &allows, &lines));
    out.sort_by(|a, b| (a.line, a.rule as u8).cmp(&(b.line, b.rule as u8)));
    out.dedup_by(|a, b| a.rule == b.rule && a.line == b.line);
    out
}

fn in_decision_path(path: &str, cfg: &LintConfig) -> bool {
    cfg.decision_paths.iter().any(|p| path.starts_with(p.as_str()))
}

// ---------------------------------------------------------------------------
// token-stream geometry
// ---------------------------------------------------------------------------

/// Mark every token covered by a `#[cfg(test)]` / `#[test]` item (attribute
/// through the close of the following brace block, or through `;` for
/// braceless items). `#[cfg(not(test))]` guards real code and is not
/// masked.
fn test_mask(toks: &[Token]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0;
    while i + 1 < toks.len() {
        if !(toks[i].is_punct('#') && toks[i + 1].is_punct('[')) {
            i += 1;
            continue;
        }
        let attr_end = match matching(toks, i + 1, '[', ']') {
            Some(e) => e,
            None => break,
        };
        let mut has_test = false;
        let mut has_not = false;
        for t in &toks[i + 2..attr_end] {
            has_test |= t.is_ident("test");
            has_not |= t.is_ident("not");
        }
        if !has_test || has_not {
            i = attr_end + 1;
            continue;
        }
        // skip any stacked attributes between the cfg and the item
        let mut j = attr_end + 1;
        while j + 1 < toks.len() && toks[j].is_punct('#') && toks[j + 1].is_punct('[') {
            match matching(toks, j + 1, '[', ']') {
                Some(e) => j = e + 1,
                None => return mask,
            }
        }
        // item body: first top-level `{…}` block, or a braceless `…;`
        let mut depth = 0i32;
        let mut end = None;
        while j < toks.len() {
            if toks[j].is_punct('(') {
                depth += 1;
            } else if toks[j].is_punct(')') {
                depth -= 1;
            } else if depth == 0 && toks[j].is_punct(';') {
                end = Some(j);
                break;
            } else if depth == 0 && toks[j].is_punct('{') {
                end = matching(toks, j, '{', '}');
                break;
            }
            j += 1;
        }
        let end = match end {
            Some(e) => e,
            None => toks.len() - 1,
        };
        for m in mask.iter_mut().take(end + 1).skip(i) {
            *m = true;
        }
        i = end + 1;
    }
    mask
}

/// Index of the delimiter closing the one at `open`.
fn matching(toks: &[Token], open: usize, oc: char, cc: char) -> Option<usize> {
    let mut depth = 0i32;
    for (j, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct(oc) {
            depth += 1;
        } else if t.is_punct(cc) {
            depth -= 1;
            if depth == 0 {
                return Some(j);
            }
        }
    }
    None
}

/// `(start, end)` token spans of every `fn` with a body (signature through
/// closing brace). Trait-method signatures without bodies are skipped.
fn fn_regions(toks: &[Token]) -> Vec<(usize, usize)> {
    let mut regions = Vec::new();
    for i in 0..toks.len() {
        if !toks[i].is_ident("fn") {
            continue;
        }
        let mut depth = 0i32;
        let mut j = i + 1;
        while j < toks.len() {
            if toks[j].is_punct('(') {
                depth += 1;
            } else if toks[j].is_punct(')') {
                depth -= 1;
            } else if depth == 0 && toks[j].is_punct(';') {
                break; // body-less trait signature
            } else if depth == 0 && toks[j].is_punct('{') {
                if let Some(close) = matching(toks, j, '{', '}') {
                    regions.push((i, close));
                }
                break;
            }
            j += 1;
        }
    }
    regions
}

// ---------------------------------------------------------------------------
// rule: nondet-iteration
// ---------------------------------------------------------------------------

fn rule_nondet_iteration(
    path: &str,
    toks: &[Token],
    mask: &[bool],
    cfg: &LintConfig,
    out: &mut Vec<Finding>,
) {
    if !in_decision_path(path, cfg) {
        return;
    }
    let regions = fn_regions(toks);

    // phase A: collect hash-typed declarations (`name: HashMap<…>`)
    let mut file_scope: BTreeSet<String> = BTreeSet::new();
    let mut fn_scope: Vec<(usize, usize, String)> = Vec::new();
    for i in 0..toks.len() {
        if !(toks[i].is_ident("HashMap") || toks[i].is_ident("HashSet")) {
            continue;
        }
        let name = match decl_name_before(toks, i) {
            Some(n) => n,
            None => continue,
        };
        match innermost(&regions, i) {
            Some((s, e)) => fn_scope.push((s, e, name)),
            None => {
                file_scope.insert(name);
            }
        }
    }
    let hash_typed = |name: &str, at: usize| -> bool {
        cfg.shared_hash_fields.iter().any(|f| f == name)
            || file_scope.contains(name)
            || fn_scope.iter().any(|(s, e, n)| *s <= at && at <= *e && n == name)
    };

    // phase B: flag iteration over hash-typed names
    for i in 0..toks.len() {
        if mask[i] {
            continue;
        }
        // receiver: `name.iter()` / `name.values()` / …
        if i >= 2
            && toks[i].kind == TokKind::Ident
            && ITER_METHODS.contains(&toks[i].text.as_str())
            && toks[i - 1].is_punct('.')
            && matches!(toks.get(i + 1), Some(t) if t.is_punct('('))
            && toks[i - 2].kind == TokKind::Ident
            && hash_typed(&toks[i - 2].text, i - 2)
        {
            out.push(Finding {
                rule: RuleId::NondetIteration,
                file: path.to_string(),
                line: toks[i].line,
                message: format!(
                    "`{}.{}()` iterates a HashMap/HashSet in a decision path; \
                     use a sorted structure or justify with an allow comment",
                    toks[i - 2].text, toks[i].text
                ),
            });
        }
        // `for … in <expr-mentioning-hash-binding> {`
        if toks[i].is_ident("for") && !matches!(toks.get(i + 1), Some(t) if t.is_punct('<')) {
            for j in i + 1..toks.len().min(i + 64) {
                if toks[j].is_punct('{') || toks[j].is_punct(';') {
                    break;
                }
                if toks[j].kind == TokKind::Ident && hash_typed(&toks[j].text, j) {
                    out.push(Finding {
                        rule: RuleId::NondetIteration,
                        file: path.to_string(),
                        line: toks[j].line,
                        message: format!(
                            "`for` over HashMap/HashSet-typed `{}` in a decision path; \
                             use a sorted structure or justify with an allow comment",
                            toks[j].text
                        ),
                    });
                    break;
                }
            }
        }
    }
}

/// For a `HashMap`/`HashSet` type token at `i`, walk back through the type
/// path (`&`, lifetimes, `mut`, `std::collections::`) to the `name:`
/// annotation introducing it. Returns `None` for value positions
/// (`HashMap::new()`), return types, and nested generics (`Vec<HashMap<…>>`
/// — the container itself is not a hash table).
fn decl_name_before(toks: &[Token], i: usize) -> Option<String> {
    let mut j = i.checked_sub(1)?;
    loop {
        let t = &toks[j];
        let skip = t.is_punct('&')
            || t.kind == TokKind::Lifetime
            || t.is_ident("std")
            || t.is_ident("collections")
            || t.is_ident("mut")
            || t.is_ident("dyn");
        if skip {
            j = j.checked_sub(1)?;
        } else if t.is_punct(':') && j >= 1 && toks[j - 1].is_punct(':') {
            j = j.checked_sub(2)?; // path separator `::`
        } else {
            break;
        }
    }
    if toks[j].is_punct(':')
        && j >= 1
        && toks[j - 1].kind == TokKind::Ident
        && !(j >= 2 && toks[j - 2].is_punct(':'))
    {
        Some(toks[j - 1].text.clone())
    } else {
        None
    }
}

fn innermost(regions: &[(usize, usize)], at: usize) -> Option<(usize, usize)> {
    regions
        .iter()
        .filter(|(s, e)| *s <= at && at <= *e)
        .min_by_key(|(s, e)| e - s)
        .copied()
}

// ---------------------------------------------------------------------------
// rule: wall-clock
// ---------------------------------------------------------------------------

fn rule_wall_clock(
    path: &str,
    toks: &[Token],
    mask: &[bool],
    cfg: &LintConfig,
    out: &mut Vec<Finding>,
) {
    if cfg.wall_clock_allow.iter().any(|p| p == path) {
        return;
    }
    for (i, t) in toks.iter().enumerate() {
        if mask[i] {
            continue;
        }
        if t.is_ident("Instant") || t.is_ident("SystemTime") {
            out.push(Finding {
                rule: RuleId::WallClock,
                file: path.to_string(),
                line: t.line,
                message: format!(
                    "`{}` outside the observability allowlist; \
                     time spans via `util::stopwatch::Stopwatch`, decisions via virtual SimTime",
                    t.text
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// rule: ambient-rng
// ---------------------------------------------------------------------------

fn rule_ambient_rng(path: &str, toks: &[Token], mask: &[bool], out: &mut Vec<Finding>) {
    for (i, t) in toks.iter().enumerate() {
        if mask[i] {
            continue;
        }
        let banned = BANNED_RNG.contains(&t.text.as_str())
            || (t.is_ident("rand")
                && matches!(toks.get(i + 1), Some(a) if a.is_punct(':'))
                && matches!(toks.get(i + 2), Some(b) if b.is_punct(':')));
        if banned {
            out.push(Finding {
                rule: RuleId::AmbientRng,
                file: path.to_string(),
                line: t.line,
                message: format!(
                    "ambient randomness (`{}`); all randomness must flow from a \
                     seeded `util::rng::SplitMix64`",
                    t.text
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// rule: raw-factor
// ---------------------------------------------------------------------------

/// A statement in a decision path that does arithmetic on a `*factor*`
/// identifier without going through `Autoscaler::quantize` bypasses the
/// 1/8-quantization contract. Statements are token spans between `;`/`{`/`}`
/// boundaries.
fn rule_raw_factor(
    path: &str,
    toks: &[Token],
    mask: &[bool],
    cfg: &LintConfig,
    out: &mut Vec<Finding>,
) {
    if !in_decision_path(path, cfg) {
        return;
    }
    let mut start = 0usize;
    for i in 0..=toks.len() {
        let boundary = i == toks.len()
            || toks[i].is_punct(';')
            || toks[i].is_punct('{')
            || toks[i].is_punct('}');
        if !boundary {
            continue;
        }
        let span = &toks[start..i];
        let span_mask = &mask[start..i];
        start = i + 1;
        let factor_tok = span.iter().zip(span_mask).find(|(t, m)| {
            !**m && t.kind == TokKind::Ident && t.text.to_lowercase().contains("factor")
        });
        let factor_tok = match factor_tok {
            Some((t, _)) => t,
            None => continue,
        };
        let has_arith = span.iter().any(|t| t.is_punct('*') || t.is_punct('/'));
        let has_quantize = span.iter().any(|t| t.is_ident("quantize"));
        if has_arith && !has_quantize {
            out.push(Finding {
                rule: RuleId::RawFactor,
                file: path.to_string(),
                line: factor_tok.line,
                message: format!(
                    "arithmetic on `{}` without `Autoscaler::quantize`; scale factors \
                     must come from the quantized menu",
                    factor_tok.text
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// rule: panic-budget
// ---------------------------------------------------------------------------

fn rule_panic_budget(path: &str, toks: &[Token], mask: &[bool], out: &mut Vec<Finding>) {
    for i in 1..toks.len() {
        if mask[i] {
            continue;
        }
        let t = &toks[i];
        if (t.is_ident("unwrap") || t.is_ident("expect"))
            && toks[i - 1].is_punct('.')
            && matches!(toks.get(i + 1), Some(n) if n.is_punct('('))
        {
            out.push(Finding {
                rule: RuleId::PanicBudget,
                file: path.to_string(),
                line: t.line,
                message: format!(
                    "`.{}()` in non-test code counts against the per-file panic budget",
                    t.text
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// rule: golden-surface
// ---------------------------------------------------------------------------

fn rule_golden_surface(
    path: &str,
    toks: &[Token],
    mask: &[bool],
    cfg: &LintConfig,
    out: &mut Vec<Finding>,
) {
    for i in 0..toks.len() {
        if mask[i] || !toks[i].is_ident("fn") {
            continue;
        }
        let name = match toks.get(i + 1) {
            Some(t) if t.kind == TokKind::Ident => &t.text,
            _ => continue,
        };
        if !cfg.serialize_fns.iter().any(|f| f == name) {
            continue;
        }
        // body = first top-level brace block after the signature
        let mut j = i + 2;
        let mut depth = 0i32;
        while j < toks.len() {
            if toks[j].is_punct('(') {
                depth += 1;
            } else if toks[j].is_punct(')') {
                depth -= 1;
            } else if depth == 0 && (toks[j].is_punct(';') || toks[j].is_punct('{')) {
                break;
            }
            j += 1;
        }
        if j >= toks.len() || toks[j].is_punct(';') {
            continue;
        }
        let close = match matching(toks, j, '{', '}') {
            Some(c) => c,
            None => continue,
        };
        for t in &toks[j..close] {
            if cfg.unserialized_fields.iter().any(|f| t.is_ident(f)) {
                out.push(Finding {
                    rule: RuleId::GoldenSurface,
                    file: path.to_string(),
                    line: t.line,
                    message: format!(
                        "`{}` is contractually unserialized (golden byte-identity) but is \
                         referenced from `{name}`",
                        t.text
                    ),
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// rule: ambient-threads
// ---------------------------------------------------------------------------

/// Threads (and the channels that usually ride along) may exist in exactly
/// one place: the coordinator's drain worker pool, where plans are applied
/// in a deterministic order on the driver thread. Anywhere else, ambient
/// parallelism can reorder observable decisions — the one failure mode no
/// runtime oracle can reliably reproduce, so it is banned at the source
/// level. Lexically: the ident `thread` in path position (`::` directly
/// before or after, catching `std::thread::spawn`, `thread::scope`, and
/// `use std::thread`), plus the standalone channel/handle identifiers in
/// [`BANNED_THREADS`].
fn rule_ambient_threads(
    path: &str,
    toks: &[Token],
    mask: &[bool],
    cfg: &LintConfig,
    out: &mut Vec<Finding>,
) {
    if cfg.thread_allow.iter().any(|p| p == path) {
        return;
    }
    let path_sep_at = |j: usize| -> bool {
        j + 1 < toks.len() && toks[j].is_punct(':') && toks[j + 1].is_punct(':')
    };
    for (i, t) in toks.iter().enumerate() {
        if mask[i] {
            continue;
        }
        let in_path = t.is_ident("thread")
            && ((i >= 2 && path_sep_at(i - 2)) || path_sep_at(i + 1));
        if in_path || BANNED_THREADS.contains(&t.text.as_str()) {
            out.push(Finding {
                rule: RuleId::AmbientThreads,
                file: path.to_string(),
                line: t.line,
                message: format!(
                    "`{}` outside `coordinator::parallel`; threads are allowed only in \
                     the drain worker pool, where apply order stays deterministic",
                    t.text
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// allow comments
// ---------------------------------------------------------------------------

/// Parse every `// arl-lint: allow(<rule>): <reason>` comment. The reason is
/// mandatory — an allow without one grants nothing.
fn parse_allows(lines: &[&str]) -> BTreeMap<usize, BTreeSet<RuleId>> {
    let mut allows: BTreeMap<usize, BTreeSet<RuleId>> = BTreeMap::new();
    for (idx, line) in lines.iter().enumerate() {
        let comment = match line.find("//") {
            Some(c) => &line[c..],
            None => continue,
        };
        let rest = match comment.find("arl-lint:") {
            Some(p) => comment[p + "arl-lint:".len()..].trim_start(),
            None => continue,
        };
        let rest = match rest.strip_prefix("allow(") {
            Some(r) => r,
            None => continue,
        };
        let close = match rest.find(')') {
            Some(c) => c,
            None => continue,
        };
        let rule = match RuleId::parse(rest[..close].trim()) {
            Some(r) => r,
            None => continue,
        };
        let reason = match rest[close + 1..].trim_start().strip_prefix(':') {
            Some(r) => r.trim(),
            None => continue,
        };
        if reason.is_empty() {
            continue;
        }
        allows.entry(idx + 1).or_default().insert(rule);
    }
    allows
}

/// A finding is suppressed by an allow on its own line (trailing comment)
/// or in the run of comment-only lines directly above it.
fn suppressed(f: &Finding, allows: &BTreeMap<usize, BTreeSet<RuleId>>, lines: &[&str]) -> bool {
    let hit = |l: usize| allows.get(&l).is_some_and(|s| s.contains(&f.rule));
    if hit(f.line) {
        return true;
    }
    let mut l = f.line.saturating_sub(1);
    while l >= 1 && lines.get(l - 1).map(|s| s.trim_start().starts_with("//")).unwrap_or(false) {
        if hit(l) {
            return true;
        }
        l -= 1;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_decision(src: &str) -> Vec<Finding> {
        lint_source("src/lanes/fixture.rs", src, &LintConfig::default())
    }

    #[test]
    fn decl_scoping_separates_functions() {
        // `dp` is a HashMap in one fn and a Vec in another — only the
        // HashMap fn's iteration may fire.
        let src = "
            fn sparse() {
                let mut dp: HashMap<usize, f64> = HashMap::new();
                for (k, v) in dp.iter() { let _ = (k, v); }
            }
            fn dense() {
                let mut dp = vec![0.0; 8];
                for v in dp.iter() { let _ = v; }
            }
        ";
        let f = lint_decision(src);
        assert_eq!(f.iter().filter(|f| f.rule == RuleId::NondetIteration).count(), 1);
        assert_eq!(f[0].line, 4);
    }

    #[test]
    fn struct_fields_are_file_scoped() {
        let src = "
            struct Lane { table: HashMap<u32, u64> }
            impl Lane {
                fn sum(&self) -> u64 { self.table.values().sum() }
            }
        ";
        let f = lint_decision(src);
        assert_eq!(f.iter().filter(|f| f.rule == RuleId::NondetIteration).count(), 1);
    }

    #[test]
    fn test_mask_exempts_cfg_test_items() {
        let src = "
            #[cfg(test)]
            mod tests {
                fn helper(m: &HashMap<u32, u32>) -> u32 { m.values().sum() }
            }
        ";
        assert!(lint_decision(src).is_empty());
    }

    #[test]
    fn cfg_not_test_is_not_masked() {
        let src = "
            #[cfg(not(test))]
            fn live(m: &HashMap<u32, u32>) -> u32 { m.values().sum() }
        ";
        assert_eq!(lint_decision(src).len(), 1);
    }

    #[test]
    fn ambient_threads_fires_on_spawns_and_channels() {
        let src = "
            fn racy() {
                let h = std::thread::spawn(|| 1);
                let (tx, rx) = mpsc::channel();
            }
        ";
        let f = lint_decision(src);
        assert_eq!(f.iter().filter(|f| f.rule == RuleId::AmbientThreads).count(), 2);
        // `use std::thread;` is path position too
        let f = lint_decision("use std::thread;");
        assert_eq!(f.iter().filter(|f| f.rule == RuleId::AmbientThreads).count(), 1);
    }

    #[test]
    fn ambient_threads_skips_plain_idents_and_the_allowlist() {
        // `threads` (the knob) and a local named `thread` with no `::`
        // context are not spawns
        let src = "
            fn knob(threads: usize) -> usize { let thread = threads; thread }
        ";
        assert!(lint_decision(src)
            .iter()
            .all(|f| f.rule != RuleId::AmbientThreads));
        // the worker pool itself is allowlisted
        let pool = "fn drain() { std::thread::scope(|s| {}); }";
        let f = lint_source("src/coordinator/parallel.rs", pool, &LintConfig::default());
        assert!(f.iter().all(|f| f.rule != RuleId::AmbientThreads));
        // but the same code anywhere else fires
        let f = lint_source("src/coordinator/tangram.rs", pool, &LintConfig::default());
        assert_eq!(f.iter().filter(|f| f.rule == RuleId::AmbientThreads).count(), 1);
    }
}
