//! Elastic pool autoscaler: size external pools to demand instead of peak.
//!
//! The paper's headline efficiency claim (§1, §6: up to 71.2% external-
//! resource savings) comes from *elasticity* — growing and shrinking CPU
//! nodes, GPU reward/teacher nodes, serverless containers, and API quota
//! lanes around rollout demand rather than provisioning for the burst. This
//! subsystem turns that claim into a measurable quantity:
//!
//! * a [`ScalePolicy`] trait ([`policy`]) with two built-in policies —
//!   queue-pressure (decaying-peak demand tracking with an any-queue burst
//!   response) and EWMA arrival forecasting;
//! * an [`Autoscaler`] wrapper that adds the policy-agnostic safety rails:
//!   scale-**up** applies after a per-class **cold-start penalty** (CPU node
//!   warm-up, GPU node restore, serverless-container/quota-lane cold start)
//!   and is billed from the decision instant (requisitioned capacity costs
//!   money while it boots); scale-**down** is gated by hysteresis
//!   (`down_hold`) so oscillating arrivals cannot flap the pool;
//! * [`PoolClass`]/[`PoolPressure`] — the observation interface backends
//!   expose (`Backend::scale_classes`) and the resize entry point consumes
//!   (`Backend::resize`, which reuses the `cpu_pool_scale` /
//!   `gpu_pool_scale` / `api_limit_scale` fault-injection machinery).
//!
//! # Scale targets
//!
//! A *target* is a [`LaneKey`] (`class` + optional `endpoint`): the CPU and
//! GPU pools are
//! single-target classes (`endpoint == None`), while the API class reports
//! one [`PoolPressure`] row **per provider endpoint** (sorted by endpoint
//! id) so each provider's quota lanes resize independently — a flapping
//! search provider no longer drags the PDF-parse lanes down with it. All
//! targets of a class bill into one provision series (`PoolClass::name`);
//! [`Autoscaler::billed_units`] folds per-target requisitions into the pool
//! total the driver records.
//!
//! # Determinism contract
//!
//! Autoscaler decisions are part of recorded scenario traces, so they must
//! be byte-reproducible across processes: evaluations happen on a fixed
//! virtual-time cadence (`interval`), factors are quantized to multiples of
//! `quantum` (defaults to 1/8 — exactly representable in f64 *and* in the
//! JSON round-trip), and every input is derived from deterministic backend
//! state (observation rows arrive sorted by target). Keep it that way: no
//! wall-clock reads, no unordered iteration.

pub mod policy;

pub use policy::{EwmaForecast, QueuePressure, ScalePolicy};

use crate::sim::{SimDur, SimTime};
use crate::util::error::Result;
use crate::util::json::Json;
use crate::{bail, err};
use std::collections::BTreeMap;

/// An elastically-resizable class of external pool. The derived ordering is
/// the deterministic evaluation order (backends return observations sorted
/// by `(class, endpoint)`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum PoolClass {
    /// CPU environment nodes (resized through the cordon machinery).
    Cpu,
    /// GPU reward/teacher nodes (resized through whole-node cordons that
    /// respect the EOE residency cache — see `GpuCluster::set_pool_scale`).
    Gpu,
    /// API quota lanes (resized through the provider-limit machinery, one
    /// target per endpoint).
    Api,
}

impl PoolClass {
    /// Every class in the deterministic evaluation order (the lane order of
    /// `lanes::ElasticLane` implementations).
    pub const ALL: [PoolClass; 3] = [PoolClass::Cpu, PoolClass::Gpu, PoolClass::Api];

    /// Stable pool name — matches the `Backend::provisioned` gauge names so
    /// provision records form one series per pool (per-endpoint API targets
    /// share the `api_lanes` series; see [`Autoscaler::billed_units`]).
    /// Indexed, not matched: scaling paths stay free of per-class `match`es
    /// (the `ElasticLane` refactor's contract).
    pub fn name(self) -> &'static str {
        const NAMES: [&str; 3] = ["cpu_cores", "gpus", "api_lanes"];
        NAMES[self as usize]
    }
}

/// The deterministic identity of one scale target: a pool class plus the
/// optional sub-pool endpoint inside it. The derived `Ord` matches the old
/// `(PoolClass, Option<u32>)` tuple order exactly (`None < Some`), so every
/// sorted-iteration contract keyed by lane survives the type unchanged.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct LaneKey {
    pub class: PoolClass,
    /// `None` for the single-target CPU and GPU pools, `Some(endpoint kind
    /// id)` for per-endpoint API rows.
    pub endpoint: Option<u32>,
}

impl LaneKey {
    /// The whole-class target (CPU, GPU, or a class-wide API resize).
    pub fn class_wide(class: PoolClass) -> LaneKey {
        LaneKey { class, endpoint: None }
    }

    /// A per-endpoint sub-pool target.
    pub fn endpoint(class: PoolClass, endpoint: u32) -> LaneKey {
        LaneKey { class, endpoint: Some(endpoint) }
    }
}

/// A live demand observation for one scale target (`Backend::scale_classes`).
#[derive(Debug, Clone)]
pub struct PoolPressure {
    /// The scale target this observation belongs to.
    pub key: LaneKey,
    /// Actions waiting in this target's queues.
    pub queued: u64,
    /// Minimum units the queued actions demand (so unit-denominated
    /// policies never mix an action count into a resource-unit sum).
    pub queued_units: u64,
    /// Units currently allocated to running attempts.
    pub in_use_units: u64,
    /// Currently schedulable units (after prior resizes).
    pub provisioned_units: u64,
    /// Full static provision (scale factor 1.0).
    pub baseline_units: u64,
}

impl PoolPressure {
    /// The deterministic target key this observation scales.
    pub fn key(&self) -> LaneKey {
        self.key
    }
}

/// Which built-in [`ScalePolicy`] to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// Decaying-peak queue-pressure tracking with an any-queue burst jump.
    Queue,
    /// EWMA arrival/demand forecast.
    Ewma,
}

impl PolicyKind {
    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::Queue => "queue",
            PolicyKind::Ewma => "ewma",
        }
    }

    pub fn parse(s: &str) -> Result<PolicyKind> {
        Ok(match s {
            "queue" => PolicyKind::Queue,
            "ewma" => PolicyKind::Ewma,
            other => bail!("unknown autoscale policy '{other}' (expected: queue | ewma)"),
        })
    }
}

/// Autoscaler knobs. Defaults are tuned so the cold-start-storm and
/// gpu-thrash packs save well over the acceptance bar at mean-ACT parity:
/// scale-up is eager (any queued action jumps to full provision), scale-down
/// is conservative (decaying-peak demand memory plus `down_hold` hysteresis).
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscaleCfg {
    pub policy: PolicyKind,
    /// Evaluation cadence (virtual time).
    pub interval: SimDur,
    /// Floor on the scale factor (never deprovision below this).
    pub min_factor: f64,
    /// Capacity margin over tracked demand.
    pub headroom: f64,
    /// Queue depth at which the queue policy jumps straight to full
    /// provision (burst response).
    pub up_queue: u64,
    /// Per-evaluation decay of the queue policy's demand peak (1.0 = never
    /// forget; 0.95 at a 2s interval ≈ 27s half-life).
    pub peak_decay: f64,
    /// EWMA smoothing factor of the forecast policy.
    pub ewma_alpha: f64,
    /// Hysteresis: the policy must want less capacity for this long,
    /// continuously, before a scale-down applies.
    pub down_hold: SimDur,
    /// Cold-start penalty of CPU node capacity (warm-up before scaled-up
    /// cores become schedulable; billed from the decision).
    pub cpu_warmup: SimDur,
    /// Cold-start penalty of GPU node capacity (node boot; the *service*
    /// re-warm cost is separate — an uncordoned node comes back with a
    /// flushed residency cache, so restores flow through the existing EOE
    /// cache-miss path).
    pub gpu_warmup: SimDur,
    /// Cold-start penalty of API quota lanes / serverless containers.
    pub api_warmup: SimDur,
    /// Scale-factor quantization step (multiples are exact in f64/JSON).
    pub quantum: f64,
    /// Autoscale-aware admission: when set, the driver schedules a wakeup
    /// at each warming requisition's maturity instant and applies the
    /// resize there, instead of waiting for the next evaluation tick past
    /// the warm-up — queued work is pre-admitted against capacity that is
    /// billed-but-still-warming, so queue wait overlaps the cold start
    /// instead of following it. Billing points never move (scale-ups bill
    /// from the decision instant either way); only the substrate-apply
    /// instant does, so `savings_vs_static` agrees with the admission-off
    /// run up to the decision-timing drift the earlier applies induce.
    pub admission: bool,
}

impl Default for AutoscaleCfg {
    fn default() -> Self {
        AutoscaleCfg {
            policy: PolicyKind::Queue,
            interval: SimDur::from_secs(2),
            min_factor: 0.25,
            headroom: 1.5,
            up_queue: 1,
            peak_decay: 0.95,
            ewma_alpha: 0.3,
            down_hold: SimDur::from_secs(10),
            cpu_warmup: SimDur::from_secs(5),
            gpu_warmup: SimDur::from_secs(5),
            api_warmup: SimDur::from_secs(2),
            quantum: 0.125,
            admission: false,
        }
    }
}

impl AutoscaleCfg {
    pub fn validate(&self) -> Result<()> {
        if self.interval.0 == 0 {
            bail!("autoscale interval must be positive");
        }
        if !(0.05..=1.0).contains(&self.min_factor) {
            bail!("autoscale min_factor {} out of [0.05, 1]", self.min_factor);
        }
        if self.headroom < 1.0 {
            bail!("autoscale headroom {} must be >= 1", self.headroom);
        }
        if !(0.0..=1.0).contains(&self.peak_decay) {
            bail!("autoscale peak_decay {} out of [0, 1]", self.peak_decay);
        }
        if !(0.0..=1.0).contains(&self.ewma_alpha) || self.ewma_alpha == 0.0 {
            bail!("autoscale ewma_alpha {} out of (0, 1]", self.ewma_alpha);
        }
        if !(0.0..=0.5).contains(&self.quantum) || self.quantum == 0.0 {
            bail!("autoscale quantum {} out of (0, 0.5]", self.quantum);
        }
        if self.up_queue == 0 {
            bail!("autoscale up_queue must be >= 1");
        }
        Ok(())
    }

    /// Per-class cold-start penalty, indexed (no per-class `match` on the
    /// scaling path — the `ElasticLane` contract).
    pub fn warmup(&self, class: PoolClass) -> SimDur {
        [self.cpu_warmup, self.gpu_warmup, self.api_warmup][class as usize]
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("policy", Json::str(self.policy.name())),
            ("interval_secs", Json::num(self.interval.secs_f64())),
            ("min_factor", Json::num(self.min_factor)),
            ("headroom", Json::num(self.headroom)),
            ("up_queue", Json::num(self.up_queue as f64)),
            ("peak_decay", Json::num(self.peak_decay)),
            ("ewma_alpha", Json::num(self.ewma_alpha)),
            ("down_hold_secs", Json::num(self.down_hold.secs_f64())),
            ("cpu_warmup_secs", Json::num(self.cpu_warmup.secs_f64())),
            ("gpu_warmup_secs", Json::num(self.gpu_warmup.secs_f64())),
            ("api_warmup_secs", Json::num(self.api_warmup.secs_f64())),
            ("quantum", Json::num(self.quantum)),
        ];
        // emitted only when set, so default-config trace headers keep their
        // pre-admission bytes (the golden-trace compatibility choice)
        if self.admission {
            pairs.push(("admission", Json::Bool(true)));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let obj = j.as_obj().ok_or_else(|| err!("'autoscale' must be an object"))?;
        let mut cfg = AutoscaleCfg::default();
        for (k, v) in obj {
            let f = || v.as_f64().ok_or_else(|| err!("autoscale key '{k}' must be a number"));
            let d = || {
                let secs = f()?;
                if secs < 0.0 {
                    bail!("autoscale key '{k}' must be non-negative");
                }
                Ok::<SimDur, crate::util::error::Error>(SimDur::from_secs_f64(secs))
            };
            match k.as_str() {
                "policy" => {
                    cfg.policy = PolicyKind::parse(
                        v.as_str().ok_or_else(|| err!("'policy' must be a string"))?,
                    )?
                }
                "interval_secs" => cfg.interval = d()?,
                "min_factor" => cfg.min_factor = f()?,
                "headroom" => cfg.headroom = f()?,
                "up_queue" => {
                    cfg.up_queue =
                        v.as_u64().ok_or_else(|| err!("'up_queue' must be an integer"))?
                }
                "peak_decay" => cfg.peak_decay = f()?,
                "ewma_alpha" => cfg.ewma_alpha = f()?,
                "down_hold_secs" => cfg.down_hold = d()?,
                "cpu_warmup_secs" => cfg.cpu_warmup = d()?,
                "gpu_warmup_secs" => cfg.gpu_warmup = d()?,
                "api_warmup_secs" => cfg.api_warmup = d()?,
                "quantum" => cfg.quantum = f()?,
                "admission" => {
                    cfg.admission =
                        v.as_bool().ok_or_else(|| err!("'admission' must be a boolean"))?
                }
                other => bail!("unknown autoscale key '{other}'"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

/// What the autoscaler wants done, in evaluation order. `pool_units` on
/// [`ScaleCmd::Decide`] is the new **pool-total** billed provision for the
/// class (per-target requisitions folded via [`Autoscaler::billed_units`]),
/// so the driver can record one coherent provision series per pool even
/// when API endpoints scale independently.
#[derive(Debug, Clone, PartialEq)]
pub enum ScaleCmd {
    /// Scale-up decided: capacity is billed from now but only becomes
    /// schedulable once the cold-start penalty elapses — the matching
    /// [`ScaleCmd::Apply`] fires at the first evaluation past the warm-up.
    Decide { key: LaneKey, factor: f64, pool_units: u64 },
    /// Resize the substrate now (`Backend::resize`).
    Apply { key: LaneKey, factor: f64 },
}

#[derive(Debug)]
struct TargetState {
    /// Last factor applied in the substrate.
    factor: f64,
    /// Scale-up awaiting its cold start: (schedulable at, factor).
    pending: Option<(SimTime, f64)>,
    /// When the policy first started wanting less than the current factor
    /// (hysteresis clock; any higher wish resets it).
    below_since: Option<SimTime>,
    /// Last observed static baseline of the target (billing denominator).
    baseline: u64,
}

impl TargetState {
    fn new() -> Self {
        TargetState { factor: 1.0, pending: None, below_since: None, baseline: 1 }
    }

    /// The factor scale-up decisions compare against: a pending scale-up
    /// counts as already granted (no double-requisition while warming).
    fn effective(&self) -> f64 {
        self.pending.map_or(self.factor, |(_, f)| f)
    }
}

const EPS: f64 = 1e-9;

/// Policy wrapper owning the hysteresis / cold-start state machine, keyed
/// by scale target ([`LaneKey`]).
pub struct Autoscaler {
    cfg: AutoscaleCfg,
    policy: Box<dyn ScalePolicy>,
    targets: BTreeMap<LaneKey, TargetState>,
    /// Applied resizes (test/reporting aid).
    pub applied: u64,
}

impl Autoscaler {
    pub fn new(cfg: AutoscaleCfg) -> Self {
        let policy: Box<dyn ScalePolicy> = match cfg.policy {
            PolicyKind::Queue => Box::new(QueuePressure::default()),
            PolicyKind::Ewma => Box::new(EwmaForecast::default()),
        };
        Autoscaler { cfg, policy, targets: BTreeMap::new(), applied: 0 }
    }

    pub fn interval(&self) -> SimDur {
        self.cfg.interval
    }

    /// Whether autoscale-aware admission is on (see `AutoscaleCfg::admission`).
    pub fn admission(&self) -> bool {
        self.cfg.admission
    }

    /// Earliest instant a warming requisition becomes schedulable, if any —
    /// the admission wakeup the driver schedules so capacity applies at
    /// maturity instead of at the next evaluation tick past it.
    pub fn next_pending_ready(&self) -> Option<SimTime> {
        self.targets.values().filter_map(|st| st.pending.map(|(ready, _)| ready)).min()
    }

    /// Mature every warming requisition whose cold start has elapsed and
    /// return the substrate resizes to run, in deterministic target order.
    /// This is the admission fast path: it touches only `pending` state —
    /// no policy evaluation, no demand-memory decay, no hysteresis clock —
    /// so maturation itself never perturbs the decision stream or the
    /// billed totals; only the apply instants move earlier.
    pub fn mature(&mut self, now: SimTime) -> Vec<ScaleCmd> {
        let mut cmds = Vec::new();
        for (&key, st) in self.targets.iter_mut() {
            if let Some((ready, f)) = st.pending {
                if now >= ready {
                    st.pending = None;
                    st.factor = f;
                    self.applied += 1;
                    cmds.push(ScaleCmd::Apply { key, factor: f });
                }
            }
        }
        cmds
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Factor currently applied in the substrate for a single-target class
    /// (1.0 before any resize).
    pub fn applied_factor(&self, class: PoolClass) -> f64 {
        self.applied_factor_of(LaneKey::class_wide(class))
    }

    /// Factor currently applied for one target (1.0 before any resize).
    pub fn applied_factor_of(&self, key: LaneKey) -> f64 {
        self.targets.get(&key).map_or(1.0, |s| s.factor)
    }

    /// Pool-total billed units of a class: per-target `baseline × effective
    /// factor` (pending scale-ups count — requisitioned capacity is paid for
    /// while it warms), summed over every target of the class. This is the
    /// single series the driver records under `class.name()`.
    pub fn billed_units(&self, class: PoolClass) -> u64 {
        let sum: u64 = self
            .targets
            .iter()
            .filter(|(k, _)| k.class == class)
            .map(|(_, st)| (st.baseline as f64 * st.effective()).round() as u64)
            .sum();
        sum.max(1)
    }

    fn quantize(x: f64, cfg: &AutoscaleCfg) -> f64 {
        // round demand UP to the next quantum (capacity safety margin) and
        // clamp to [min_factor, 1]; quantum multiples stay exact in f64
        let q = (x / cfg.quantum).ceil() * cfg.quantum;
        q.clamp(cfg.min_factor, 1.0)
    }

    /// One evaluation tick: feed per-target observations (sorted by
    /// `(class, endpoint)`), get back the resize commands to run.
    /// Deterministic in (`now`, `obs`, prior evaluations).
    pub fn eval(&mut self, now: SimTime, obs: &[PoolPressure]) -> Vec<ScaleCmd> {
        // register every target (and refresh its baseline) up front so a
        // Decide on the first target of a class bills the whole class
        for o in obs {
            let st = self.targets.entry(o.key()).or_insert_with(TargetState::new);
            st.baseline = o.baseline_units.max(1);
        }
        let mut cmds = Vec::new();
        for o in obs {
            let desired = Self::quantize(self.policy.desired(now, o, &self.cfg), &self.cfg);
            let warm = self.cfg.warmup(o.key.class);
            let mut matured: Option<f64> = None;
            let mut apply: Option<f64> = None;
            let mut decide: Option<f64> = None;
            {
                let st = self.targets.get_mut(&o.key()).expect("target registered above");
                // 1. a warming scale-up matured → apply it in the substrate
                if let Some((ready, f)) = st.pending {
                    if now >= ready {
                        st.pending = None;
                        st.factor = f;
                        matured = Some(f);
                    }
                }
                let effective = st.effective();
                if desired > effective + EPS {
                    // 2. scale-up: requisition now, schedulable after warm-up
                    st.below_since = None;
                    if warm.0 == 0 {
                        st.pending = None;
                        st.factor = desired;
                        apply = Some(desired);
                    } else {
                        st.pending = Some((now + warm, desired));
                        decide = Some(desired);
                    }
                } else if desired < effective - EPS {
                    // 3. scale-down: only after wanting less for down_hold
                    match st.below_since {
                        None => st.below_since = Some(now),
                        Some(since) if now - since >= self.cfg.down_hold => {
                            st.below_since = None;
                            st.pending = None;
                            st.factor = desired;
                            apply = Some(desired);
                        }
                        Some(_) => {}
                    }
                } else {
                    st.below_since = None;
                }
            }
            if let Some(f) = matured {
                self.applied += 1;
                cmds.push(ScaleCmd::Apply { key: o.key, factor: f });
            }
            if let Some(f) = apply {
                self.applied += 1;
                cmds.push(ScaleCmd::Apply { key: o.key, factor: f });
            }
            if let Some(f) = decide {
                let pool_units = self.billed_units(o.key.class);
                cmds.push(ScaleCmd::Decide { key: o.key, factor: f, pool_units });
            }
        }
        cmds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(class: PoolClass, queued: u64, in_use: u64, base: u64) -> PoolPressure {
        obs_ep(class, None, queued, in_use, base)
    }

    fn obs_ep(
        class: PoolClass,
        endpoint: Option<u32>,
        queued: u64,
        in_use: u64,
        base: u64,
    ) -> PoolPressure {
        PoolPressure {
            key: LaneKey { class, endpoint },
            queued,
            queued_units: queued,
            in_use_units: in_use,
            provisioned_units: base,
            baseline_units: base,
        }
    }

    fn t(secs: u64) -> SimTime {
        SimTime(SimDur::from_secs(secs).0)
    }

    #[test]
    fn cfg_round_trips_through_json() {
        let cfg = AutoscaleCfg {
            policy: PolicyKind::Ewma,
            min_factor: 0.25,
            down_hold: SimDur::from_secs(30),
            gpu_warmup: SimDur::from_secs(8),
            ..AutoscaleCfg::default()
        };
        let j = cfg.to_json();
        let back = AutoscaleCfg::from_json(&j).unwrap();
        assert_eq!(back, cfg);
        assert_eq!(back.to_json().to_string(), j.to_string());
    }

    #[test]
    fn cfg_rejects_garbage() {
        assert!(AutoscaleCfg::from_json(&Json::parse(r#"{"warp":1}"#).unwrap()).is_err());
        assert!(
            AutoscaleCfg::from_json(&Json::parse(r#"{"min_factor":0.001}"#).unwrap()).is_err()
        );
        assert!(AutoscaleCfg::from_json(&Json::parse(r#"{"policy":"nope"}"#).unwrap()).is_err());
        assert!(AutoscaleCfg::from_json(&Json::parse(r#"{"quantum":0.9}"#).unwrap()).is_err());
    }

    #[test]
    fn quantized_factors_are_json_exact() {
        let cfg = AutoscaleCfg::default();
        for i in 1..=8u32 {
            let f = Autoscaler::quantize(i as f64 / 8.0, &cfg);
            let j = Json::num(f).to_string();
            let back = Json::parse(&j).unwrap().as_f64().unwrap();
            assert_eq!(back, f, "factor {f} must round-trip exactly");
        }
    }

    #[test]
    fn idle_scales_down_only_after_hold() {
        let mut a = Autoscaler::new(AutoscaleCfg::default());
        let idle = [obs(PoolClass::Cpu, 0, 0, 128)];
        // hysteresis: wanting less since t=0, hold is 10s
        assert!(a.eval(t(0), &idle).is_empty());
        assert!(a.eval(t(2), &idle).is_empty());
        assert!(a.eval(t(8), &idle).is_empty());
        let cmds = a.eval(t(10), &idle);
        assert_eq!(
            cmds,
            vec![ScaleCmd::Apply { key: LaneKey::class_wide(PoolClass::Cpu), factor: 0.25 }],
            "sustained idle must scale down to the floor"
        );
        assert_eq!(a.applied_factor(PoolClass::Cpu), 0.25);
        // and stays there without further commands
        assert!(a.eval(t(12), &idle).is_empty());
    }

    #[test]
    fn burst_decides_up_then_applies_after_warmup() {
        let mut a = Autoscaler::new(AutoscaleCfg::default());
        let idle = [obs(PoolClass::Cpu, 0, 0, 128)];
        for s in [0u64, 2, 4, 6, 8, 10] {
            let _ = a.eval(t(s), &idle);
        }
        assert_eq!(a.applied_factor(PoolClass::Cpu), 0.25);
        // burst arrives: decision is immediate, capacity bills at once…
        let busy = [obs(PoolClass::Cpu, 5, 10, 128)];
        let cmds = a.eval(t(12), &busy);
        assert_eq!(
            cmds,
            vec![ScaleCmd::Decide {
                key: LaneKey::class_wide(PoolClass::Cpu),
                factor: 1.0,
                pool_units: 128
            }]
        );
        // …but the substrate resize waits out the 5s cold start
        assert_eq!(a.applied_factor(PoolClass::Cpu), 0.25);
        assert!(a.eval(t(14), &busy).is_empty(), "still warming");
        let cmds = a.eval(t(18), &busy);
        assert_eq!(
            cmds,
            vec![ScaleCmd::Apply { key: LaneKey::class_wide(PoolClass::Cpu), factor: 1.0 }]
        );
        assert_eq!(a.applied_factor(PoolClass::Cpu), 1.0);
    }

    #[test]
    fn gpu_class_uses_its_own_warmup() {
        let mut a = Autoscaler::new(AutoscaleCfg {
            gpu_warmup: SimDur::from_secs(8),
            ..AutoscaleCfg::default()
        });
        let idle = [obs(PoolClass::Gpu, 0, 0, 24)];
        for s in [0u64, 2, 4, 6, 8, 10] {
            let _ = a.eval(t(s), &idle);
        }
        assert_eq!(a.applied_factor(PoolClass::Gpu), 0.25);
        let busy = [obs(PoolClass::Gpu, 3, 8, 24)];
        let cmds = a.eval(t(12), &busy);
        assert_eq!(
            cmds,
            vec![ScaleCmd::Decide {
                key: LaneKey::class_wide(PoolClass::Gpu),
                factor: 1.0,
                pool_units: 24
            }]
        );
        // 8s gpu warm-up: not schedulable at +6s, applies at +8s
        assert!(a.eval(t(18), &busy).is_empty(), "gpu cold start still running");
        let cmds = a.eval(t(20), &busy);
        assert_eq!(
            cmds,
            vec![ScaleCmd::Apply { key: LaneKey::class_wide(PoolClass::Gpu), factor: 1.0 }]
        );
    }

    #[test]
    fn oscillating_arrivals_do_not_flap() {
        // queue flips between empty and deep every evaluation (period well
        // under down_hold): the factor must never leave 1.0 and no resize
        // may be issued — this is the hysteresis acceptance test.
        let mut a = Autoscaler::new(AutoscaleCfg::default());
        let mut resizes = 0;
        for i in 0..50u64 {
            let queued = if i % 2 == 0 { 40 } else { 0 };
            let in_use = if i % 2 == 0 { 0 } else { 64 };
            let cmds = a.eval(t(i * 2), &[obs(PoolClass::Cpu, queued, in_use, 128)]);
            resizes += cmds.len();
        }
        assert_eq!(resizes, 0, "oscillation under down_hold must not move the pool");
        assert_eq!(a.applied_factor(PoolClass::Cpu), 1.0);
    }

    #[test]
    fn classes_scale_independently() {
        let mut a = Autoscaler::new(AutoscaleCfg::default());
        let all = [
            obs(PoolClass::Cpu, 3, 50, 128),  // busy → stays up
            obs(PoolClass::Gpu, 2, 12, 24),   // busy → stays up
            obs(PoolClass::Api, 0, 0, 200),   // idle → scales down after hold
        ];
        for s in [0u64, 2, 4, 6, 8] {
            let _ = a.eval(t(s), &all);
        }
        let cmds = a.eval(t(10), &all);
        assert_eq!(
            cmds,
            vec![ScaleCmd::Apply { key: LaneKey::class_wide(PoolClass::Api), factor: 0.25 }]
        );
        assert_eq!(a.applied_factor(PoolClass::Cpu), 1.0);
        assert_eq!(a.applied_factor(PoolClass::Gpu), 1.0);
        assert_eq!(a.applied_factor(PoolClass::Api), 0.25);
    }

    #[test]
    fn api_endpoints_scale_independently() {
        // one busy provider, one idle provider: only the idle endpoint's
        // lanes scale down, and the command carries its endpoint id
        let mut a = Autoscaler::new(AutoscaleCfg::default());
        let rows = [
            obs_ep(PoolClass::Api, Some(2), 4, 40, 64), // busy
            obs_ep(PoolClass::Api, Some(3), 0, 0, 24),  // idle
        ];
        for s in [0u64, 2, 4, 6, 8] {
            let _ = a.eval(t(s), &rows);
        }
        let cmds = a.eval(t(10), &rows);
        assert_eq!(
            cmds,
            vec![ScaleCmd::Apply { key: LaneKey::endpoint(PoolClass::Api, 3), factor: 0.25 }]
        );
        assert_eq!(a.applied_factor_of(LaneKey::endpoint(PoolClass::Api, 2)), 1.0);
        assert_eq!(a.applied_factor_of(LaneKey::endpoint(PoolClass::Api, 3)), 0.25);
    }

    #[test]
    fn decide_bills_the_whole_class_pool() {
        // two endpoints; endpoint 0 scales down to the floor, then bursts:
        // the Decide's pool_units must cover BOTH endpoints — endpoint 0 at
        // its requisitioned full provision, endpoint 1 untouched at 1.0
        let mut a = Autoscaler::new(AutoscaleCfg::default());
        let idle0 = [
            obs_ep(PoolClass::Api, Some(0), 0, 0, 100),
            obs_ep(PoolClass::Api, Some(1), 2, 80, 100),
        ];
        for s in [0u64, 2, 4, 6, 8, 10] {
            let _ = a.eval(t(s), &idle0);
        }
        assert_eq!(a.applied_factor_of(LaneKey::endpoint(PoolClass::Api, 0)), 0.25);
        assert_eq!(a.billed_units(PoolClass::Api), 25 + 100);
        let burst = [
            obs_ep(PoolClass::Api, Some(0), 6, 10, 100),
            obs_ep(PoolClass::Api, Some(1), 2, 80, 100),
        ];
        let cmds = a.eval(t(12), &burst);
        assert_eq!(
            cmds,
            vec![ScaleCmd::Decide {
                key: LaneKey::endpoint(PoolClass::Api, 0),
                factor: 1.0,
                pool_units: 200
            }],
            "requisitioned endpoint 0 plus endpoint 1 at full provision"
        );
    }

    #[test]
    fn admission_flag_round_trips_and_defaults_off() {
        let cfg = AutoscaleCfg::default();
        assert!(!cfg.admission);
        // default config omits the key entirely (golden-header stability)
        assert!(!cfg.to_json().to_string().contains("admission"));
        let on = AutoscaleCfg { admission: true, ..AutoscaleCfg::default() };
        let j = on.to_json();
        assert!(j.to_string().contains("\"admission\":true"));
        let back = AutoscaleCfg::from_json(&j).unwrap();
        assert_eq!(back, on);
        assert!(
            AutoscaleCfg::from_json(&Json::parse(r#"{"admission":"yes"}"#).unwrap()).is_err()
        );
    }

    #[test]
    fn mature_applies_exactly_at_the_ready_instant() {
        let mut a = Autoscaler::new(AutoscaleCfg::default());
        let idle = [obs(PoolClass::Cpu, 0, 0, 128)];
        for s in [0u64, 2, 4, 6, 8, 10] {
            let _ = a.eval(t(s), &idle);
        }
        assert_eq!(a.applied_factor(PoolClass::Cpu), 0.25);
        assert_eq!(a.next_pending_ready(), None);
        let busy = [obs(PoolClass::Cpu, 5, 10, 128)];
        let cmds = a.eval(t(12), &busy);
        assert!(matches!(cmds[0], ScaleCmd::Decide { .. }));
        // requisitioned at t=12 under the 5s cpu cold start
        assert_eq!(a.next_pending_ready(), Some(t(17)));
        // billed from the decision instant while warming
        assert_eq!(a.billed_units(PoolClass::Cpu), 128);
        assert!(a.mature(t(16)).is_empty(), "cold start still running");
        let cmds = a.mature(t(17));
        assert_eq!(
            cmds,
            vec![ScaleCmd::Apply { key: LaneKey::class_wide(PoolClass::Cpu), factor: 1.0 }]
        );
        assert_eq!(a.applied_factor(PoolClass::Cpu), 1.0);
        assert_eq!(a.next_pending_ready(), None);
        // billing is unchanged by the early apply…
        assert_eq!(a.billed_units(PoolClass::Cpu), 128);
        // …and the next evaluation does not re-apply the matured resize
        assert!(a.eval(t(18), &busy).is_empty());
    }

    #[test]
    fn mature_keeps_other_targets_warming() {
        // endpoint 0 bursts at t=12 (2s api warm-up → ready t=14), endpoint
        // 1 bursts at t=13 via a direct second eval (ready t=15): maturing
        // at t=14 must apply only endpoint 0 and keep endpoint 1 pending.
        let mut a = Autoscaler::new(AutoscaleCfg::default());
        let idle = [
            obs_ep(PoolClass::Api, Some(0), 0, 0, 100),
            obs_ep(PoolClass::Api, Some(1), 0, 0, 100),
        ];
        for s in [0u64, 2, 4, 6, 8, 10] {
            let _ = a.eval(t(s), &idle);
        }
        let burst0 = [
            obs_ep(PoolClass::Api, Some(0), 4, 0, 100),
            obs_ep(PoolClass::Api, Some(1), 0, 0, 100),
        ];
        let _ = a.eval(t(12), &burst0);
        let burst_both = [
            obs_ep(PoolClass::Api, Some(0), 4, 0, 100),
            obs_ep(PoolClass::Api, Some(1), 4, 0, 100),
        ];
        let _ = a.eval(t(13), &burst_both);
        assert_eq!(a.next_pending_ready(), Some(t(14)));
        let billed_warming = a.billed_units(PoolClass::Api);
        assert_eq!(billed_warming, 200, "both requisitions on the bill");
        let cmds = a.mature(t(14));
        assert_eq!(
            cmds,
            vec![ScaleCmd::Apply { key: LaneKey::endpoint(PoolClass::Api, 0), factor: 1.0 }]
        );
        // endpoint 0's apply never un-bills endpoint 1's warming requisition
        assert_eq!(a.billed_units(PoolClass::Api), 200);
        assert_eq!(a.next_pending_ready(), Some(t(15)));
    }

    #[test]
    fn renewed_demand_resets_peak_and_hold() {
        let mut a = Autoscaler::new(AutoscaleCfg::default());
        let idle = [obs(PoolClass::Cpu, 0, 0, 128)];
        assert!(a.eval(t(0), &idle).is_empty());
        assert!(a.eval(t(8), &idle).is_empty());
        // a burst at t=9 refills the demand peak and resets the hold clock
        assert!(a.eval(t(9), &[obs(PoolClass::Cpu, 4, 60, 128)]).is_empty());
        // idle again: the peak must first decay below full provision, then a
        // fresh down_hold must elapse — nothing moves until t=25
        for s in [11u64, 13, 15, 17, 19, 21, 23] {
            assert!(a.eval(t(s), &idle).is_empty(), "still decaying/holding at t={s}");
        }
        let cmds = a.eval(t(25), &idle);
        assert_eq!(cmds.len(), 1, "hold elapsed from the post-burst reset");
        match &cmds[0] {
            ScaleCmd::Apply { key, factor } => {
                assert_eq!(key.class, PoolClass::Cpu);
                assert_eq!(key.endpoint, None);
                assert!(*factor < 1.0, "stepped decay must be moving down, got {factor}");
            }
            other => panic!("expected a scale-down Apply, got {other:?}"),
        }
    }
}
