//! Built-in scale policies.
//!
//! A [`ScalePolicy`] maps one scale target's live demand observation to a
//! *desired* capacity factor in `[0, 1]`; the [`super::Autoscaler`] wrapper
//! owns everything temporal (quantization, cold-start warm-ups, scale-down
//! hysteresis), so policies stay pure demand models and remain trivially
//! deterministic. Targets are [`LaneKey`]s — the API class feeds one
//! observation per provider endpoint, and each keeps its own demand memory.

use super::{AutoscaleCfg, LaneKey, PoolPressure};
use crate::sim::SimTime;
use std::collections::BTreeMap;

/// Demand model: observation → desired capacity factor (pre-quantization;
/// the autoscaler clamps into `[min_factor, 1]`).
pub trait ScalePolicy {
    fn name(&self) -> &'static str;

    fn desired(&mut self, now: SimTime, obs: &PoolPressure, cfg: &AutoscaleCfg) -> f64;
}

/// Queue-pressure policy with decaying-peak demand memory.
///
/// Any queued action is treated as the front of a burst and jumps the
/// desire straight to full provision (rollout arrivals are thundering
/// herds, §2.3 — ramping would starve them through the whole climb). With
/// an empty queue the desire tracks a decaying peak of `in_use × headroom`,
/// so short quiet windows inside a step keep capacity hot while sustained
/// idle (inter-step training gaps, run tails) steps the pool down.
#[derive(Debug, Default)]
pub struct QueuePressure {
    peak: BTreeMap<LaneKey, f64>,
}

impl ScalePolicy for QueuePressure {
    fn name(&self) -> &'static str {
        "queue"
    }

    fn desired(&mut self, _now: SimTime, obs: &PoolPressure, cfg: &AutoscaleCfg) -> f64 {
        let base = obs.baseline_units.max(1) as f64;
        let peak = self.peak.entry(obs.key()).or_insert(0.0);
        if obs.queued >= cfg.up_queue {
            // burst response: demand is at least everything we have
            *peak = base;
            return 1.0;
        }
        let inst = obs.in_use_units as f64 * cfg.headroom;
        *peak = (*peak * cfg.peak_decay).max(inst);
        (*peak / base).min(1.0)
    }
}

/// EWMA arrival-forecast policy.
///
/// Smooths instantaneous unit demand (`in_use_units + queued_units`) with
/// an exponential moving average and provisions `forecast × headroom`.
/// Reacts slower than [`QueuePressure`] on bursts but is immune to sampling
/// noise — the right trade for steady high-duty workloads.
#[derive(Debug, Default)]
pub struct EwmaForecast {
    demand: BTreeMap<LaneKey, f64>,
}

impl ScalePolicy for EwmaForecast {
    fn name(&self) -> &'static str {
        "ewma"
    }

    fn desired(&mut self, _now: SimTime, obs: &PoolPressure, cfg: &AutoscaleCfg) -> f64 {
        let base = obs.baseline_units.max(1) as f64;
        let inst = (obs.in_use_units + obs.queued_units) as f64;
        let d = self.demand.entry(obs.key()).or_insert(inst);
        *d += cfg.ewma_alpha * (inst - *d);
        (*d * cfg.headroom / base).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autoscale::PoolClass;

    fn obs(queued: u64, in_use: u64, base: u64) -> PoolPressure {
        PoolPressure {
            key: LaneKey::class_wide(PoolClass::Cpu),
            queued,
            queued_units: queued,
            in_use_units: in_use,
            provisioned_units: base,
            baseline_units: base,
        }
    }

    #[test]
    fn queue_policy_jumps_on_any_queue() {
        let cfg = AutoscaleCfg::default();
        let mut p = QueuePressure::default();
        assert_eq!(p.desired(SimTime::ZERO, &obs(1, 0, 128), &cfg), 1.0);
        // …and stays near full through one quiet observation (peak memory)
        let quiet = p.desired(SimTime::ZERO, &obs(0, 0, 128), &cfg);
        assert!(quiet > 0.9, "peak must decay slowly, got {quiet}");
    }

    #[test]
    fn queue_policy_tracks_usage_with_headroom() {
        let cfg = AutoscaleCfg::default();
        let mut p = QueuePressure::default();
        let d = p.desired(SimTime::ZERO, &obs(0, 32, 128), &cfg);
        assert!((d - 32.0 * cfg.headroom / 128.0).abs() < 1e-12);
    }

    #[test]
    fn queue_policy_decays_to_zero_when_idle() {
        let cfg = AutoscaleCfg::default();
        let mut p = QueuePressure::default();
        let _ = p.desired(SimTime::ZERO, &obs(3, 100, 128), &cfg);
        let mut last = 1.0;
        for _ in 0..200 {
            last = p.desired(SimTime::ZERO, &obs(0, 0, 128), &cfg);
        }
        assert!(last < 0.01, "idle peak must decay away, got {last}");
    }

    #[test]
    fn per_endpoint_demand_memories_are_disjoint() {
        // hammering endpoint 0 must not inflate endpoint 1's desire
        let cfg = AutoscaleCfg::default();
        let mut p = QueuePressure::default();
        let mut hot = obs(0, 100, 128);
        hot.key = LaneKey::endpoint(PoolClass::Api, 0);
        let mut cold = obs(0, 0, 128);
        cold.key = LaneKey::endpoint(PoolClass::Api, 1);
        let d_hot = p.desired(SimTime::ZERO, &hot, &cfg);
        let d_cold = p.desired(SimTime::ZERO, &cold, &cfg);
        assert!(d_hot > 0.9, "hot endpoint near full, got {d_hot}");
        assert_eq!(d_cold, 0.0, "cold endpoint must see no demand");
    }

    #[test]
    fn ewma_converges_to_steady_demand() {
        let cfg = AutoscaleCfg::default();
        let mut p = EwmaForecast::default();
        let mut d = 0.0;
        for _ in 0..100 {
            d = p.desired(SimTime::ZERO, &obs(0, 32, 128), &cfg);
        }
        assert!((d - 32.0 * cfg.headroom / 128.0).abs() < 1e-6, "got {d}");
        // demand vanishes → forecast follows
        for _ in 0..100 {
            d = p.desired(SimTime::ZERO, &obs(0, 0, 128), &cfg);
        }
        assert!(d < 1e-3, "got {d}");
    }

    #[test]
    fn desired_is_capped_at_one() {
        let cfg = AutoscaleCfg::default();
        let mut q = QueuePressure::default();
        let mut e = EwmaForecast::default();
        for _ in 0..10 {
            assert!(q.desired(SimTime::ZERO, &obs(0, 1000, 128), &cfg) <= 1.0);
            assert!(e.desired(SimTime::ZERO, &obs(500, 1000, 128), &cfg) <= 1.0);
        }
    }
}
