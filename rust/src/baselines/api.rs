//! Unmanaged-API baseline for DeepSearch (paper §6.1).
//!
//! Each trajectory fires API calls immediately with no admission control;
//! the provider's rate limits and load-dependent failures hit directly, and
//! the client retries with exponential backoff (≤3 times, 600s timeout) —
//! the retry storms that inflate ACT and invalidate trajectories in §6.2.

use crate::action::{Action, ActionId, ResourceKindId};
use crate::cluster::api::{ApiEndpoint, ApiOutcome};
use crate::coordinator::backend::Started;
use crate::sim::{SimDur, SimTime};
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

/// The unmanaged API client.
#[derive(Debug)]
pub struct UnmanagedApi {
    endpoints: HashMap<ResourceKindId, ApiEndpoint>,
    outcomes: HashMap<ActionId, (ResourceKindId, ApiOutcome)>,
    queue: VecDeque<Arc<Action>>,
}

impl UnmanagedApi {
    pub fn new(endpoints: HashMap<ResourceKindId, ApiEndpoint>) -> Self {
        UnmanagedApi { endpoints, outcomes: HashMap::new(), queue: VecDeque::new() }
    }

    pub fn handles(&self, a: &Action) -> bool {
        a.spec
            .cost
            .iter()
            .any(|(k, d)| d.min_units() > 0 && self.endpoints.contains_key(&k))
    }

    pub fn submit(&mut self, action: &Arc<Action>) {
        self.queue.push_back(action.clone());
    }

    /// Anything waiting to fire (dirty-pool contract: the unmanaged client
    /// fires on the next pump whenever its queue is non-empty).
    pub fn has_queued(&self) -> bool {
        !self.queue.is_empty()
    }

    /// Everything fires immediately — that is the baseline's defining flaw.
    pub fn drain_started(&mut self, now: SimTime) -> Vec<Started> {
        let mut out = Vec::new();
        for a in self.queue.drain(..) {
            let kind = a
                .spec
                .cost
                .iter()
                .find(|(k, d)| d.min_units() > 0 && self.endpoints.contains_key(k))
                .map(|(k, _)| k)
                .expect("API action with no endpoint dim");
            let ep = self.endpoints.get_mut(&kind).unwrap();
            let (outcome, dur) = ep.issue(now);
            // exponential client backoff on retries (1s, 2s, 4s)
            let backoff = if a.retry_count > 0 {
                SimDur::from_secs(1 << (a.retry_count - 1).min(4))
            } else {
                SimDur::ZERO
            };
            self.outcomes.insert(a.id, (kind, outcome));
            out.push(Started {
                action: a.id,
                overhead: backoff,
                exec: dur,
                units: 1,
            });
        }
        out
    }

    /// Returns the outcome of the attempt; `true` ⇒ success.
    pub fn complete(&mut self, id: ActionId) -> ApiOutcome {
        let (kind, outcome) = self
            .outcomes
            .remove(&id)
            .expect("completion for unknown API action");
        self.endpoints.get_mut(&kind).unwrap().finish(outcome);
        outcome
    }

    /// Scenario rate-limit flap: scale every endpoint's provider limits
    /// (the unmanaged client doesn't react — that's its defining flaw).
    pub fn scale_limits(&mut self, factor: f64) {
        for ep in self.endpoints.values_mut() {
            ep.scale_limits(factor);
        }
    }

    /// Counters across endpoints: (ok, rate_limited, timeout, error).
    pub fn failure_counts(&self) -> (u64, u64, u64, u64) {
        let mut t = (0, 0, 0, 0);
        for e in self.endpoints.values() {
            t.0 += e.n_ok;
            t.1 += e.n_rate_limited;
            t.2 += e.n_timeout;
            t.3 += e.n_error;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::action::{
        ActionKind, ActionSpec, CostSpec, DimCost, ElasticityModel, ResourceClass,
        ResourceRegistry, TaskId, TenantId, TrajId,
    };
    use crate::cluster::api::ApiEndpointSpec;

    fn rc(a: Action) -> Arc<Action> {
        Arc::new(a)
    }

    fn setup() -> (ResourceRegistry, UnmanagedApi, ResourceKindId) {
        let mut reg = ResourceRegistry::new();
        let k = reg.register("api:s", ResourceClass::ApiConcurrency, 4);
        let mut spec = ApiEndpointSpec::search("s");
        spec.max_concurrency = 4;
        let mut eps = HashMap::new();
        eps.insert(k, ApiEndpoint::new(spec, 3));
        (reg, UnmanagedApi::new(eps), k)
    }

    fn mk(reg: &ResourceRegistry, k: ResourceKindId, id: u64, retries: u32) -> Action {
        let mut a = Action::new(
            ActionId(id),
            ActionSpec {
                task: TaskId(0),
                tenant: TenantId(0),
                trajectory: TrajId(id),
                kind: ActionKind::ApiCall,
                cost: CostSpec::single(reg, k, DimCost::Fixed(1)),
                key_resource: None,
                elasticity: ElasticityModel::None,
                profiled_dur: None,
                service: None,
                true_dur: SimDur::from_millis(500),
            },
            SimTime::ZERO,
        );
        a.retry_count = retries;
        a
    }

    #[test]
    fn burst_triggers_rate_limits() {
        let (reg, mut api, k) = setup();
        for i in 0..20 {
            api.submit(&rc(mk(&reg, k, i, 0)));
        }
        assert!(api.has_queued());
        let started = api.drain_started(SimTime::ZERO);
        assert_eq!(started.len(), 20, "unmanaged client fires everything");
        let mut limited = 0;
        for s in &started {
            if api.complete(s.action) == ApiOutcome::RateLimited {
                limited += 1;
            }
        }
        assert!(limited >= 10, "rate-limited {limited}");
    }

    #[test]
    fn retries_carry_backoff() {
        let (reg, mut api, k) = setup();
        api.submit(&rc(mk(&reg, k, 1, 2)));
        let started = api.drain_started(SimTime::ZERO);
        assert_eq!(started[0].overhead, SimDur::from_secs(2));
        let _ = api.complete(ActionId(1));
    }
}
