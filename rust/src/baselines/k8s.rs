//! Kubernetes baseline for CPU environments (paper §6.1 Baselines).
//!
//! Trajectory-level static provisioning: each trajectory requests a pod at
//! rollout start (0.5-CPU request for limited multiplexing, 4-CPU limit),
//! holds it for its whole lifetime, and executes actions inside it with a
//! fixed core budget — no breakdown, no pooling, no elasticity. A simple
//! control-plane model reproduces the paper's congestion collapse at batch
//! 1536: pod creations drain at a bounded rate and clients time out.

use crate::action::{Action, ActionId, TrajId};
use crate::coordinator::backend::Started;
use crate::sim::{SimDur, SimTime};
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

#[derive(Debug, Clone)]
pub struct K8sCfg {
    pub nodes: u32,
    pub cores_per_node: u32,
    pub node_mem_gb: u64,
    /// CPU request per pod (guaranteed share; K8s packs by this).
    pub pod_request: f64,
    /// CPU limit per pod — max cores an action may burst to.
    pub pod_limit: u32,
    /// Control-plane pod-creation throughput (pods/s).
    pub cp_rate: f64,
    /// Client-side pod-creation timeout.
    pub cp_timeout: SimDur,
    /// Pod startup latency once scheduled (image pull, kubelet, CNI).
    pub pod_create: SimDur,
}

impl Default for K8sCfg {
    fn default() -> Self {
        K8sCfg {
            nodes: 5,
            cores_per_node: 256,
            node_mem_gb: 2400,
            pod_request: 0.5,
            pod_limit: 4,
            cp_rate: 12.0,
            cp_timeout: SimDur::from_secs(60),
            pod_create: SimDur::from_secs(3),
        }
    }
}

#[derive(Debug)]
struct Node {
    requested_cores_milli: u64, // K8s-style millicores of requests
    reserved_mem_gb: u64,
    busy_cores: u32,
}

#[derive(Debug)]
struct Pod {
    node: usize,
    mem_gb: u64,
    ready_at: SimTime,
    first_action_done: bool,
}

/// The K8s CPU baseline.
#[derive(Debug)]
pub struct K8sCpu {
    cfg: K8sCfg,
    nodes: Vec<Node>,
    pods: HashMap<TrajId, Pod>,
    /// when the control plane frees up for the next creation
    cp_next_free: SimTime,
    queue: VecDeque<Arc<Action>>,
    running: HashMap<ActionId, (TrajId, u32)>, // cores held
    pub n_cp_timeouts: u64,
}

impl K8sCpu {
    pub fn new(cfg: K8sCfg) -> Self {
        K8sCpu {
            nodes: (0..cfg.nodes)
                .map(|_| Node { requested_cores_milli: 0, reserved_mem_gb: 0, busy_cores: 0 })
                .collect(),
            cfg,
            pods: HashMap::new(),
            cp_next_free: SimTime::ZERO,
            queue: VecDeque::new(),
            running: HashMap::new(),
            n_cp_timeouts: 0,
        }
    }

    /// Pod creation at trajectory start. `Err` models a control-plane
    /// timeout (client retries later, reproducing the collapse).
    pub fn traj_start(&mut self, now: SimTime, traj: TrajId, mem_gb: u64) -> Result<(), String> {
        if self.pods.contains_key(&traj) {
            return Ok(());
        }
        // control-plane queueing: creations serialize at cp_rate
        let service = SimDur::from_secs_f64(1.0 / self.cfg.cp_rate);
        let sched_at = self.cp_next_free.max(now);
        let wait = sched_at - now;
        if wait > self.cfg.cp_timeout {
            self.n_cp_timeouts += 1;
            return Err("control-plane timeout".into());
        }
        // K8s packs by *requests*, not usage — the over-provisioning bug
        let req_milli = (self.cfg.pod_request * 1000.0) as u64;
        let node = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| {
                n.requested_cores_milli + req_milli
                    <= self.cfg.cores_per_node as u64 * 1000
                    && n.reserved_mem_gb + mem_gb <= self.cfg.node_mem_gb
            })
            .min_by_key(|(_, n)| n.requested_cores_milli)
            .map(|(i, _)| i)
            .ok_or("no node fits the pod request")?;
        self.nodes[node].requested_cores_milli += req_milli;
        self.nodes[node].reserved_mem_gb += mem_gb;
        self.cp_next_free = sched_at + service;
        self.pods.insert(
            traj,
            Pod {
                node,
                mem_gb,
                ready_at: sched_at + service + self.cfg.pod_create,
                first_action_done: false,
            },
        );
        Ok(())
    }

    pub fn traj_end(&mut self, traj: TrajId) {
        if let Some(p) = self.pods.remove(&traj) {
            let req_milli = (self.cfg.pod_request * 1000.0) as u64;
            self.nodes[p.node].requested_cores_milli -= req_milli;
            self.nodes[p.node].reserved_mem_gb -= p.mem_gb;
        }
    }

    pub fn submit(&mut self, action: &Arc<Action>) {
        self.queue.push_back(action.clone());
    }

    /// Anything waiting on a pod (dirty-pool contract: pod readiness is
    /// time-gated, so a non-empty queue must be rescanned on every pump).
    pub fn has_queued(&self) -> bool {
        !self.queue.is_empty()
    }

    pub fn complete(&mut self, id: ActionId) {
        if let Some((traj, cores)) = self.running.remove(&id) {
            if let Some(p) = self.pods.get(&traj) {
                self.nodes[p.node].busy_cores -= cores;
            }
        }
    }

    pub fn drain_started(&mut self, now: SimTime) -> Vec<Started> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.queue.len() {
            let a = &self.queue[i];
            let traj = a.spec.trajectory;
            let Some(pod) = self.pods.get(&traj) else {
                i += 1;
                continue;
            };
            if pod.ready_at > now {
                i += 1;
                continue;
            }
            // fixed burst budget: min(pod limit, action's own cap, free cores)
            let cap = a
                .spec
                .key_resource
                .map(|k| a.spec.cost.dim(k).max_units())
                .unwrap_or(1)
                .min(self.cfg.pod_limit as u64) as u32;
            let node = &mut self.nodes[pod.node];
            let free = self.cfg.cores_per_node - node.busy_cores;
            if free == 0 {
                i += 1;
                continue;
            }
            let cores = cap.min(free).max(1);
            node.busy_cores += cores;
            let a = self.queue.remove(i).expect("index in bounds");
            // first action additionally waited for pod readiness, which is
            // already modeled via ready_at gating; charge creation latency
            // as overhead on the first action for Table-1-style accounting
            let overhead = {
                let pod = self.pods.get_mut(&traj).unwrap();
                if pod.first_action_done {
                    SimDur::ZERO
                } else {
                    pod.first_action_done = true;
                    self.cfg.pod_create
                }
            };
            let exec = a.spec.exec_dur(cores as u64);
            self.running.insert(a.id, (traj, cores));
            out.push(Started { action: a.id, overhead, exec, units: cores as u64 });
        }
        out
    }

    pub fn utilization(&self) -> f64 {
        let busy: u32 = self.nodes.iter().map(|n| n.busy_cores).sum();
        busy as f64 / (self.cfg.nodes * self.cfg.cores_per_node) as f64
    }

    pub fn total_cores(&self) -> u64 {
        (self.cfg.nodes * self.cfg.cores_per_node) as u64
    }

    /// earliest pod-ready instant among queued actions (wakeup hint)
    pub fn next_wakeup(&self, now: SimTime) -> Option<SimTime> {
        self.queue
            .iter()
            .filter_map(|a| self.pods.get(&a.spec.trajectory))
            .map(|p| p.ready_at)
            .filter(|&t| t > now)
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::action::{
        ActionKind, ActionSpec, CostSpec, DimCost, ElasticityModel, ResourceClass,
        ResourceRegistry, TaskId, TenantId,
    };

    fn action(reg: &ResourceRegistry, id: u64, traj: u64, max: u64) -> Action {
        let cpu = reg.by_name("cpu").unwrap();
        Action::new(
            ActionId(id),
            ActionSpec {
                task: TaskId(0),
                tenant: TenantId(0),
                trajectory: TrajId(traj),
                kind: ActionKind::RewardCpu,
                cost: CostSpec::single(reg, cpu, DimCost::Range { min: 1, max }),
                key_resource: Some(cpu),
                elasticity: ElasticityModel::PerfectScaling,
                profiled_dur: Some(SimDur::from_secs(8)),
                service: None,
                true_dur: SimDur::from_secs(8),
            },
            SimTime::ZERO,
        )
    }

    fn reg() -> ResourceRegistry {
        let mut r = ResourceRegistry::new();
        r.register("cpu", ResourceClass::CpuCores, 16);
        r
    }

    #[test]
    fn pod_lifecycle_and_limit() {
        let r = reg();
        let mut k = K8sCpu::new(K8sCfg {
            nodes: 1,
            cores_per_node: 16,
            node_mem_gb: 64,
            ..K8sCfg::default()
        });
        k.traj_start(SimTime::ZERO, TrajId(1), 4).unwrap();
        k.submit(&Arc::new(action(&r, 1, 1, 32)));
        // pod not ready yet
        assert!(k.drain_started(SimTime::ZERO).is_empty());
        let later = SimTime::ZERO + SimDur::from_secs(10);
        let started = k.drain_started(later);
        assert_eq!(started.len(), 1);
        // K8s caps the burst at the 4-core limit even though the action
        // could scale to 32
        assert_eq!(started[0].units, 4);
        assert!(started[0].overhead >= K8sCfg::default().pod_create);
        k.complete(ActionId(1));
        k.traj_end(TrajId(1));
        assert_eq!(k.utilization(), 0.0);
    }

    #[test]
    fn control_plane_times_out_under_burst() {
        let mut k = K8sCpu::new(K8sCfg {
            cp_rate: 1.0,
            cp_timeout: SimDur::from_secs(10),
            ..K8sCfg::default()
        });
        let mut timeouts = 0;
        for i in 0..100 {
            if k.traj_start(SimTime::ZERO, TrajId(i), 1).is_err() {
                timeouts += 1;
            }
        }
        // rate 1/s with a 10s timeout admits ~11 creations at t=0
        assert!(timeouts >= 85, "timeouts {timeouts}");
        assert_eq!(k.n_cp_timeouts, timeouts);
    }

    #[test]
    fn requests_pack_but_cores_contend() {
        let r = reg();
        let mut k = K8sCpu::new(K8sCfg {
            nodes: 1,
            cores_per_node: 8,
            node_mem_gb: 1000,
            cp_rate: 1000.0,
            ..K8sCfg::default()
        });
        // 16 pods fit by request (0.5 × 16 = 8 cores)
        for i in 0..16 {
            k.traj_start(SimTime::ZERO, TrajId(i), 1).unwrap();
        }
        let t = SimTime::ZERO + SimDur::from_secs(30);
        for i in 0..16 {
            k.submit(&Arc::new(action(&r, i, i, 4)));
        }
        let started = k.drain_started(t);
        // physical cores (8) gate actual execution: 4+4 = 2 actions at limit,
        // then free cores run out (remaining actions get ≥1 until exhausted)
        let total: u64 = started.iter().map(|s| s.units).sum();
        assert!(total <= 8);
        assert!(started.len() < 16);
    }
}
