//! Paper baselines (§6.1), composed into a single [`Backend`].
//!
//! * AI Coding → Kubernetes pod-per-trajectory ([`k8s`]);
//! * MOPD / DeepSearch-reward → SGLang-style static services ([`static_gpu`]);
//! * GPU scalability comparison → ServerlessLLM-style MaaS ([`serverless`]);
//! * DeepSearch tool calls → unmanaged direct API calls ([`api`]).

pub mod api;
pub mod k8s;
pub mod serverless;
pub mod static_gpu;

pub use api::UnmanagedApi;
pub use k8s::{K8sCfg, K8sCpu};
pub use serverless::{ServerlessCfg, ServerlessGpu};
pub use static_gpu::StaticGpu;

use crate::action::{Action, TrajId};
use crate::cluster::api::{ApiEndpoint, ApiOutcome};
use crate::coordinator::backend::{Backend, StartedSink, Verdict};
use crate::rollout::workloads::Catalog;
use crate::scenario::ScenarioEvent;
use crate::sim::SimTime;
use std::collections::HashMap;
use std::sync::Arc;

/// GPU half of a baseline deployment.
pub enum GpuBaseline {
    None,
    Static(StaticGpu),
    Serverless(ServerlessGpu),
}

/// A composed baseline backend.
pub struct BaselineBackend {
    name: &'static str,
    cpu_kind: crate::action::ResourceKindId,
    gpu_kind: crate::action::ResourceKindId,
    pub k8s: Option<K8sCpu>,
    pub gpu: GpuBaseline,
    pub api: Option<UnmanagedApi>,
}

impl BaselineBackend {
    /// AI-Coding baseline: Kubernetes CPU cluster only.
    pub fn coding(cat: &Catalog, k8s_cfg: K8sCfg) -> Self {
        BaselineBackend {
            name: "k8s",
            cpu_kind: cat.cpu_cores,
            gpu_kind: cat.gpu_units,
            k8s: Some(K8sCpu::new(k8s_cfg)),
            gpu: GpuBaseline::None,
            api: None,
        }
    }

    /// MOPD baseline: nine teachers, four GPUs each (TP-4), SGLang-style.
    pub fn mopd(cat: &Catalog) -> Self {
        let plan = cat
            .teachers
            .iter()
            .map(|&ti| {
                let s = &cat.services[ti];
                (s.id, s.name.clone(), 4u8, 1u32)
            })
            .collect();
        BaselineBackend {
            name: "sglang-static",
            cpu_kind: cat.cpu_cores,
            gpu_kind: cat.gpu_units,
            k8s: None,
            gpu: GpuBaseline::Static(StaticGpu::new(plan)),
            api: None,
        }
    }

    /// DeepSearch baseline: unmanaged APIs + judge at TP-8 × 5 replicas.
    pub fn deepsearch(cat: &Catalog) -> Self {
        let judge = &cat.services[cat.judge];
        let plan = vec![(judge.id, judge.name.clone(), 8u8, 5u32)];
        let endpoints: HashMap<_, _> = cat
            .api
            .iter()
            .enumerate()
            .map(|(i, (k, spec))| (*k, ApiEndpoint::new(spec.clone(), 0xba5e + i as u64)))
            .collect();
        BaselineBackend {
            name: "unmanaged-api",
            cpu_kind: cat.cpu_cores,
            gpu_kind: cat.gpu_units,
            k8s: None,
            gpu: GpuBaseline::Static(StaticGpu::new(plan)),
            api: Some(UnmanagedApi::new(endpoints)),
        }
    }

    /// MOPD+Search baseline: ten reward services at TP-4 each (§6.1).
    pub fn mopd_search(cat: &Catalog) -> Self {
        let mut plan: Vec<(crate::action::ServiceId, String, u8, u32)> = vec![{
            let judge = &cat.services[cat.judge];
            (judge.id, judge.name.clone(), 4u8, 1u32)
        }];
        for &ti in &cat.teachers {
            let s = &cat.services[ti];
            plan.push((s.id, s.name.clone(), 4, 1));
        }
        let endpoints: HashMap<_, _> = cat
            .api
            .iter()
            .enumerate()
            .map(|(i, (k, spec))| (*k, ApiEndpoint::new(spec.clone(), 0xfee1 + i as u64)))
            .collect();
        BaselineBackend {
            name: "static-multi",
            cpu_kind: cat.cpu_cores,
            gpu_kind: cat.gpu_units,
            k8s: None,
            gpu: GpuBaseline::Static(StaticGpu::new(plan)),
            api: Some(UnmanagedApi::new(endpoints)),
        }
    }

    /// ServerlessLLM comparison (Fig. 8(b)).
    pub fn serverless(cat: &Catalog, mut cfg: ServerlessCfg) -> Self {
        for s in &cat.services {
            cfg.weights_gb.insert(s.id.0, s.weights_gb);
        }
        BaselineBackend {
            name: "serverless-llm",
            cpu_kind: cat.cpu_cores,
            gpu_kind: cat.gpu_units,
            k8s: None,
            gpu: GpuBaseline::Serverless(ServerlessGpu::new(cfg)),
            api: None,
        }
    }

    fn is_cpu(&self, a: &Action) -> bool {
        a.spec.cost.dim(self.cpu_kind).min_units() > 0
    }

    fn is_gpu(&self, a: &Action) -> bool {
        a.spec.cost.dim(self.gpu_kind).min_units() > 0
    }
}

impl Backend for BaselineBackend {
    fn name(&self) -> &'static str {
        self.name
    }

    fn traj_start(
        &mut self,
        now: SimTime,
        traj: TrajId,
        mem_gb: u64,
        first_cpu_min: Option<u32>,
    ) -> Result<(), String> {
        if first_cpu_min.is_some() {
            if let Some(k8s) = &mut self.k8s {
                return k8s.traj_start(now, traj, mem_gb);
            }
        }
        Ok(())
    }

    fn traj_end(&mut self, _now: SimTime, traj: TrajId) {
        if let Some(k8s) = &mut self.k8s {
            k8s.traj_end(traj);
        }
    }

    fn submit(&mut self, _now: SimTime, action: &Arc<Action>) {
        if self.is_cpu(action) {
            self.k8s
                .as_mut()
                .expect("CPU action without k8s baseline")
                .submit(action);
        } else if self.is_gpu(action) {
            match &mut self.gpu {
                GpuBaseline::Static(s) => s.submit(action),
                GpuBaseline::Serverless(s) => s.submit(action),
                GpuBaseline::None => panic!("GPU action without GPU baseline"),
            }
        } else {
            self.api
                .as_mut()
                .expect("API action without API baseline")
                .submit(action);
        }
    }

    fn on_complete(&mut self, now: SimTime, action: &Action) -> Verdict {
        if self.is_cpu(action) {
            self.k8s.as_mut().unwrap().complete(action.id);
            Verdict::Done
        } else if self.is_gpu(action) {
            match &mut self.gpu {
                GpuBaseline::Static(s) => {
                    s.complete(now, action.id);
                    Verdict::Done
                }
                GpuBaseline::Serverless(s) => {
                    s.complete(now, action.id);
                    if s.was_timed_out(action.id) {
                        Verdict::Failed
                    } else {
                        Verdict::Done
                    }
                }
                GpuBaseline::None => unreachable!(),
            }
        } else {
            match self.api.as_mut().unwrap().complete(action.id) {
                ApiOutcome::Ok => Verdict::Done,
                _ => Verdict::Retry,
            }
        }
    }

    fn drain_started_into(&mut self, now: SimTime, sink: &mut StartedSink) {
        // sub-backends drain in the fixed cpu → gpu → api order, the same
        // class order the sorted-pool contract gives the tangram backend
        if let Some(k8s) = &mut self.k8s {
            for s in k8s.drain_started(now) {
                sink.push(s);
            }
        }
        let gpu_started = match &mut self.gpu {
            GpuBaseline::Static(s) => s.drain_started(now),
            GpuBaseline::Serverless(s) => s.drain_started(now),
            GpuBaseline::None => Vec::new(),
        };
        for s in gpu_started {
            sink.push(s);
        }
        if let Some(api) = &mut self.api {
            for s in api.drain_started(now) {
                sink.push(s);
            }
        }
    }

    fn has_dirty(&self) -> bool {
        // The baselines' admissions are time-gated (pod readiness, queue
        // timeouts, provider load), not event-gated, so their dirty-pool
        // contract is the simplest sound one: dirty while anything waits.
        // An empty deployment has nothing to start — skipping the drain is
        // exactly the legacy no-op scan.
        self.k8s.as_ref().map_or(false, |k| k.has_queued())
            || match &self.gpu {
                GpuBaseline::Static(s) => s.has_queued(),
                GpuBaseline::Serverless(s) => s.has_queued(),
                GpuBaseline::None => false,
            }
            || self.api.as_ref().map_or(false, |a| a.has_queued())
    }

    fn next_wakeup(&self, now: SimTime) -> Option<SimTime> {
        self.k8s.as_ref().and_then(|k| k.next_wakeup(now))
    }

    fn tick(&mut self, _now: SimTime) {}

    fn utilization(&self) -> Vec<(String, f64)> {
        let mut v = Vec::new();
        if let Some(k8s) = &self.k8s {
            v.push(("cpu".into(), k8s.utilization()));
        }
        match &self.gpu {
            GpuBaseline::Static(s) => v.extend(s.utilization()),
            GpuBaseline::Serverless(s) => v.push(("gpu".into(), s.utilization())),
            GpuBaseline::None => {}
        }
        v
    }

    fn provisioned(&self) -> Vec<(String, u64)> {
        let mut v = Vec::new();
        if let Some(k8s) = &self.k8s {
            v.push(("cpu_cores".into(), k8s.total_cores()));
        }
        match &self.gpu {
            GpuBaseline::Static(s) => v.push(("gpus".into(), s.total_gpus())),
            GpuBaseline::Serverless(s) => v.push(("gpus".into(), s.total_gpus())),
            GpuBaseline::None => {}
        }
        v
    }

    // `scale_classes` / `resize` stay at the inelastic defaults on purpose:
    // pods are provisioned per trajectory, static services pin weights for
    // the whole run, and the unmanaged API client holds no quota contract
    // to renegotiate. Running the autoscaler against a baseline therefore
    // observes nothing and saves nothing — exactly the asymmetry the
    // `--against` A/B packs measure.

    fn inject(&mut self, _now: SimTime, event: &ScenarioEvent) -> bool {
        match event {
            // a provider flap hits the unmanaged client like anything else;
            // the client just keeps firing into it
            ScenarioEvent::ApiLimitScale { factor } => match &mut self.api {
                Some(api) => {
                    api.scale_limits(*factor);
                    true
                }
                None => false,
            },
            // static deployments pin weights to GPUs for the whole run and
            // never restore; serverless reloads on every dispatch anyway —
            // neither has a cache to storm
            ScenarioEvent::GpuCacheFlush => false,
            // static services pin their GPUs for the run and serverless
            // containers are provisioned per dispatch — neither deployment
            // can cordon nodes mid-run (the paper's elasticity asymmetry)
            ScenarioEvent::GpuPoolScale { .. } => false,
            // pods are provisioned per-trajectory up front; the baseline has
            // no mechanism to resize its pool mid-run (the paper's point)
            ScenarioEvent::CpuPoolScale { .. } => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::action::TaskId;
    use crate::coordinator::{run, RunCfg};
    use crate::rollout::workloads::{CatalogCfg, Workload, WorkloadKind};

    fn small_cat() -> Catalog {
        Catalog::build(&CatalogCfg {
            cpu_nodes: 2,
            cores_per_node: 16,
            gpu_nodes: 5,
            n_teachers: 4,
            ..CatalogCfg::default()
        })
    }

    #[test]
    fn k8s_baseline_runs_coding() {
        let cat = small_cat();
        let mut be = BaselineBackend::coding(
            &cat,
            K8sCfg {
                nodes: 2,
                cores_per_node: 16,
                node_mem_gb: 256,
                ..K8sCfg::default()
            },
        );
        let wl = Workload::new(TaskId(0), WorkloadKind::Coding);
        let cfg = RunCfg { batch: 8, steps: 1, seed: 5, ..RunCfg::default() };
        let m = run(&mut be, &cat, &[wl], &cfg);
        assert_eq!(m.trajectories.len(), 8);
        assert_eq!(m.failed_actions(), 0);
        // pod creation overhead must show up on first actions
        assert!(m.actions.iter().any(|a| a.overhead.0 > 0));
        // no elasticity: units never exceed the 4-core pod limit
        assert!(m.actions.iter().all(|a| a.units <= 4));
    }

    #[test]
    fn static_gpu_baseline_runs_mopd() {
        let cat = small_cat();
        let mut be = BaselineBackend::mopd(&cat);
        let wl = Workload::new(TaskId(2), WorkloadKind::Mopd);
        let cfg = RunCfg { batch: 16, steps: 1, seed: 6, ..RunCfg::default() };
        let m = run(&mut be, &cat, &[wl], &cfg);
        assert_eq!(m.trajectories.len(), 16);
        // all GPU actions pinned at TP-4
        assert!(m
            .actions
            .iter()
            .filter(|a| a.kind == crate::action::ActionKind::RewardModel)
            .all(|a| a.units == 4));
        // per-service gauges exposed for Fig. 3(b)
        assert!(m.util.iter().any(|u| u.name.starts_with("svc:")));
    }

    #[test]
    fn deepsearch_baseline_has_retries() {
        let cat = small_cat();
        let mut be = BaselineBackend::deepsearch(&cat);
        let wl = Workload::new(TaskId(1), WorkloadKind::DeepSearch);
        let cfg = RunCfg { batch: 48, steps: 1, seed: 8, ..RunCfg::default() };
        let m = run(&mut be, &cat, &[wl], &cfg);
        assert_eq!(m.trajectories.len(), 48);
        // the burst of unmanaged calls must have produced retries
        assert!(m.total_retries() > 0, "expected retry storms");
    }

    #[test]
    fn serverless_baseline_pays_reload_every_time() {
        let cat = small_cat();
        let mut be = BaselineBackend::serverless(
            &cat,
            ServerlessCfg { gpu_nodes: 5, ..ServerlessCfg::default() },
        );
        let wl = Workload::new(TaskId(2), WorkloadKind::Mopd);
        let cfg = RunCfg { batch: 8, steps: 1, seed: 10, ..RunCfg::default() };
        let m = run(&mut be, &cat, &[wl], &cfg);
        assert_eq!(m.trajectories.len(), 8);
        let gpu_actions: Vec<_> = m
            .actions
            .iter()
            .filter(|a| a.kind == crate::action::ActionKind::RewardModel && !a.failed)
            .collect();
        assert!(!gpu_actions.is_empty());
        assert!(gpu_actions.iter().all(|a| a.overhead.0 > 0), "always cold");
    }
}
