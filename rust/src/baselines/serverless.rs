//! ServerlessLLM-style Model-as-a-Service baseline (paper §6.3).
//!
//! Serves many models from a shared GPU pool like ARL-Tangram, but with the
//! two deficiencies the paper calls out: **no elastic DoP reallocation**
//! (every instance is a fixed TP-4) and **higher per-invocation system
//! overhead** (full checkpoint reload on every dispatch — no invariant
//! host-memory copy to skip write-back, plus a fixed serving-stack startup
//! cost). A client timeout makes it shed load at very high concurrency,
//! reproducing the paper's "fails to serve at batch 2048".

use crate::action::{Action, ActionId};
use crate::cluster::gpu::{GpuCluster, RestoreModel};
use crate::coordinator::backend::Started;
use crate::sim::{SimDur, SimTime};
use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::Arc;

#[derive(Debug, Clone)]
pub struct ServerlessCfg {
    pub gpu_nodes: u32,
    /// Fixed TP degree of every instance.
    pub dop: u8,
    /// Fixed serving-stack startup per dispatch.
    pub startup: SimDur,
    /// Checkpoint-reload bandwidth multiplier vs. ARL-Tangram's restore
    /// (>1 ⇒ slower; models reload without the invariant-copy optimization).
    pub reload_penalty: f64,
    /// Client gives up after waiting this long in queue.
    pub queue_timeout: SimDur,
    /// Weight footprint per service (GiB) — same catalog as the managers.
    pub weights_gb: HashMap<u32, f64>,
}

impl Default for ServerlessCfg {
    fn default() -> Self {
        ServerlessCfg {
            gpu_nodes: 5,
            dop: 4,
            startup: SimDur::from_secs(2),
            reload_penalty: 1.5,
            queue_timeout: SimDur::from_secs(600),
            weights_gb: HashMap::new(),
        }
    }
}

/// The MaaS baseline backend part.
#[derive(Debug)]
pub struct ServerlessGpu {
    cfg: ServerlessCfg,
    cluster: GpuCluster,
    restore: RestoreModel,
    queue: VecDeque<Arc<Action>>,
    running: HashMap<ActionId, crate::cluster::gpu::ChunkRef>,
    /// actions that timed out in queue → report Failed on completion
    pub timed_out: HashSet<ActionId>,
}

impl ServerlessGpu {
    pub fn new(cfg: ServerlessCfg) -> Self {
        ServerlessGpu {
            cluster: GpuCluster::new(cfg.gpu_nodes),
            restore: RestoreModel::default(),
            cfg,
            queue: VecDeque::new(),
            running: HashMap::new(),
            timed_out: HashSet::new(),
        }
    }

    pub fn submit(&mut self, action: &Arc<Action>) {
        self.queue.push_back(action.clone());
    }

    /// Anything waiting to dispatch (dirty-pool contract: the queue
    /// timeout is time-gated, so waiting work must be rescanned per pump).
    pub fn has_queued(&self) -> bool {
        !self.queue.is_empty()
    }

    pub fn complete(&mut self, now: SimTime, id: ActionId) {
        if let Some(chunk) = self.running.remove(&id) {
            // no residency tracking: the next dispatch reloads regardless
            self.cluster
                .node_mut(chunk.node)
                .release(chunk, None);
        }
        let _ = now;
    }

    pub fn was_timed_out(&mut self, id: ActionId) -> bool {
        self.timed_out.remove(&id)
    }

    pub fn drain_started(&mut self, now: SimTime) -> Vec<Started> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.queue.len() {
            let waited = now - self.queue[i].submitted_at;
            if waited > self.cfg.queue_timeout {
                // shed: complete instantly as a failure
                let a = self.queue.remove(i).expect("index in bounds");
                self.timed_out.insert(a.id);
                out.push(Started {
                    action: a.id,
                    overhead: SimDur::ZERO,
                    exec: SimDur::from_millis(1),
                    units: 0,
                });
                continue;
            }
            let svc = self.queue[i].spec.service.expect("GPU action without service");
            match self.cluster.allocate(svc, self.cfg.dop) {
                Some(alloc) => {
                    let a = self.queue.remove(i).expect("index in bounds");
                    let weights = self
                        .cfg
                        .weights_gb
                        .get(&svc.0)
                        .copied()
                        .unwrap_or(60.0);
                    // full reload every dispatch — warm or not
                    let reload = self
                        .restore
                        .restore_dur(weights, self.cfg.dop)
                        .mul_f64(self.cfg.reload_penalty);
                    let overhead = self.cfg.startup + reload;
                    let exec = a.spec.exec_dur(self.cfg.dop as u64);
                    self.running.insert(a.id, alloc.chunk);
                    out.push(Started { action: a.id, overhead, exec, units: self.cfg.dop as u64 });
                }
                None => {
                    i += 1;
                }
            }
        }
        out
    }

    pub fn utilization(&self) -> f64 {
        let total = self.cluster.total_gpus() as f64;
        (total - self.cluster.free_gpus() as f64) / total
    }

    pub fn total_gpus(&self) -> u64 {
        self.cluster.total_gpus() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::action::{
        ActionKind, ActionSpec, CostSpec, DimCost, ElasticityModel, ResourceClass,
        ResourceRegistry, ServiceId, TaskId, TenantId, TrajId,
    };

    fn mk_action(reg: &ResourceRegistry, id: u64, svc: u32, at: SimTime) -> Action {
        let gpu = reg.by_name("gpu").unwrap();
        Action::new(
            ActionId(id),
            ActionSpec {
                task: TaskId(0),
                tenant: TenantId(0),
                trajectory: TrajId(id),
                kind: ActionKind::RewardModel,
                cost: CostSpec::single(reg, gpu, DimCost::Discrete(vec![4])),
                key_resource: Some(gpu),
                elasticity: ElasticityModel::PerfectScaling,
                profiled_dur: Some(SimDur::from_secs(8)),
                service: Some(ServiceId(svc)),
                true_dur: SimDur::from_secs(8),
            },
            at,
        )
    }

    fn reg() -> ResourceRegistry {
        let mut r = ResourceRegistry::new();
        r.register("gpu", ResourceClass::GpuUnits, 8);
        r
    }

    #[test]
    fn every_dispatch_pays_reload() {
        let r = reg();
        let mut s = ServerlessGpu::new(ServerlessCfg {
            gpu_nodes: 1,
            ..ServerlessCfg::default()
        });
        s.submit(&Arc::new(mk_action(&r, 1, 0, SimTime::ZERO)));
        let st = s.drain_started(SimTime::ZERO);
        assert_eq!(st.len(), 1);
        assert!(st[0].overhead >= ServerlessCfg::default().startup);
        s.complete(SimTime::ZERO + SimDur::from_secs(5), ActionId(1));
        // same service again: still cold
        s.submit(&Arc::new(mk_action(&r, 2, 0, SimTime::ZERO)));
        let st2 = s.drain_started(SimTime::ZERO + SimDur::from_secs(5));
        assert!(st2[0].overhead >= ServerlessCfg::default().startup);
    }

    #[test]
    fn queue_timeout_sheds_load() {
        let r = reg();
        let mut s = ServerlessGpu::new(ServerlessCfg {
            gpu_nodes: 1,
            queue_timeout: SimDur::from_secs(10),
            ..ServerlessCfg::default()
        });
        // two instances fit (8 GPUs / TP4); the third waits
        for i in 0..3 {
            s.submit(&Arc::new(mk_action(&r, i, i as u32, SimTime::ZERO)));
        }
        let st = s.drain_started(SimTime::ZERO);
        assert_eq!(st.len(), 2);
        // far in the future the third times out
        let late = SimTime::ZERO + SimDur::from_secs(60);
        let st2 = s.drain_started(late);
        assert_eq!(st2.len(), 1);
        assert!(s.was_timed_out(st2[0].action));
    }
}
