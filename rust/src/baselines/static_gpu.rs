//! Static GPU-service baseline (SGLang-style, paper §6.1).
//!
//! Task-level static provisioning: every service gets dedicated replicas
//! pinned to fixed GPUs for the whole training run (e.g. nine teachers ×
//! TP-4). Requests queue per service; idle replicas of other services
//! cannot help — the §2.3 "over-provisioning within RL tasks".

use crate::action::{Action, ActionId, ServiceId};
use crate::coordinator::backend::Started;
use crate::sim::{SimDur, SimTime};
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

/// One pinned replica.
#[derive(Debug)]
struct Replica {
    busy_until: SimTime,
    busy: bool,
    /// busy-time integral for Fig. 3(b) SM-activity reporting
    busy_accum: SimDur,
    last_change: SimTime,
}

#[derive(Debug)]
struct ServiceDeployment {
    name: String,
    dop: u8,
    replicas: Vec<Replica>,
    queue: VecDeque<Arc<Action>>,
}

/// The static deployment: a fixed map service → replicas.
#[derive(Debug)]
pub struct StaticGpu {
    services: HashMap<ServiceId, ServiceDeployment>,
    running: HashMap<ActionId, (ServiceId, usize)>,
    total_gpus: u64,
}

impl StaticGpu {
    /// `plan`: (service, name, dop, n_replicas).
    pub fn new(plan: Vec<(ServiceId, String, u8, u32)>) -> Self {
        let mut services = HashMap::new();
        let mut total = 0u64;
        for (id, name, dop, n) in plan {
            total += dop as u64 * n as u64;
            services.insert(
                id,
                ServiceDeployment {
                    name,
                    dop,
                    replicas: (0..n)
                        .map(|_| Replica {
                            busy_until: SimTime::ZERO,
                            busy: false,
                            busy_accum: SimDur::ZERO,
                            last_change: SimTime::ZERO,
                        })
                        .collect(),
                    queue: VecDeque::new(),
                },
            );
        }
        StaticGpu { services, running: HashMap::new(), total_gpus: total }
    }

    pub fn submit(&mut self, action: &Arc<Action>) {
        let svc = action.spec.service.expect("GPU action without service");
        self.services
            .get_mut(&svc)
            .unwrap_or_else(|| panic!("service {svc:?} not deployed"))
            .queue
            .push_back(action.clone());
    }

    /// Anything waiting on a replica (dirty-pool contract).
    pub fn has_queued(&self) -> bool {
        self.services.values().any(|d| !d.queue.is_empty())
    }

    pub fn complete(&mut self, now: SimTime, id: ActionId) {
        if let Some((svc, ri)) = self.running.remove(&id) {
            let dep = self.services.get_mut(&svc).unwrap();
            let r = &mut dep.replicas[ri];
            r.busy_accum += now - r.last_change;
            r.busy = false;
            r.last_change = now;
        }
    }

    pub fn drain_started(&mut self, now: SimTime) -> Vec<Started> {
        let mut out = Vec::new();
        let mut started_pairs = Vec::new();
        // sorted service order: HashMap iteration varies across processes,
        // and the drain order decides same-timestamp event ordering — this
        // keeps recorded scenario traces byte-replayable
        let mut ids: Vec<ServiceId> = self.services.keys().copied().collect();
        ids.sort();
        for svc in &ids {
            let dep = self.services.get_mut(svc).expect("known service");
            while !dep.queue.is_empty() {
                let free = dep.replicas.iter().position(|r| !r.busy);
                let Some(ri) = free else { break };
                let a = dep.queue.pop_front().expect("non-empty queue has a head");
                let exec = a.spec.exec_dur(dep.dop as u64);
                let r = &mut dep.replicas[ri];
                r.busy = true;
                r.last_change = now;
                r.busy_until = now + exec;
                started_pairs.push((a.id, *svc, ri));
                out.push(Started {
                    action: a.id,
                    overhead: SimDur::ZERO, // permanently resident — no restore
                    exec,
                    units: dep.dop as u64,
                });
            }
        }
        for (id, svc, ri) in started_pairs {
            self.running.insert(id, (svc, ri));
        }
        out
    }

    /// Per-service instantaneous busy fraction (Fig. 3(b) sampling).
    pub fn utilization(&self) -> Vec<(String, f64)> {
        let mut v: Vec<(String, f64)> = self
            .services
            .values()
            .map(|d| {
                let busy = d.replicas.iter().filter(|r| r.busy).count();
                (format!("svc:{}", d.name), busy as f64 / d.replicas.len().max(1) as f64)
            })
            .collect();
        let total_busy: usize = self
            .services
            .values()
            .map(|d| d.replicas.iter().filter(|r| r.busy).count() * d.dop as usize)
            .sum();
        v.push(("gpu".into(), total_busy as f64 / self.total_gpus.max(1) as f64));
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    pub fn total_gpus(&self) -> u64 {
        self.total_gpus
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::action::{
        ActionKind, ActionSpec, CostSpec, DimCost, ElasticityModel, ResourceClass,
        ResourceRegistry, TaskId, TenantId, TrajId,
    };

    fn mk_action(reg: &ResourceRegistry, id: u64, svc: u32, secs: u64) -> Action {
        let gpu = reg.by_name("gpu").unwrap();
        Action::new(
            ActionId(id),
            ActionSpec {
                task: TaskId(0),
                tenant: TenantId(0),
                trajectory: TrajId(id),
                kind: ActionKind::RewardModel,
                cost: CostSpec::single(reg, gpu, DimCost::Discrete(vec![1, 2, 4, 8])),
                key_resource: Some(gpu),
                elasticity: ElasticityModel::PerfectScaling,
                profiled_dur: Some(SimDur::from_secs(secs)),
                service: Some(ServiceId(svc)),
                true_dur: SimDur::from_secs(secs),
            },
            SimTime::ZERO,
        )
    }

    fn reg() -> ResourceRegistry {
        let mut r = ResourceRegistry::new();
        r.register("gpu", ResourceClass::GpuUnits, 40);
        r
    }

    #[test]
    fn per_service_queues_do_not_share() {
        let r = reg();
        let mut s = StaticGpu::new(vec![
            (ServiceId(0), "a".into(), 4, 1),
            (ServiceId(1), "b".into(), 4, 1),
        ]);
        assert_eq!(s.total_gpus(), 8);
        // two requests for service 0, none for service 1
        s.submit(&Arc::new(mk_action(&r, 1, 0, 8)));
        s.submit(&Arc::new(mk_action(&r, 2, 0, 8)));
        let started = s.drain_started(SimTime::ZERO);
        // only one replica of service 0 → second request queues even though
        // service 1's replica idles (the paper's task-level waste)
        assert_eq!(started.len(), 1);
        assert_eq!(started[0].units, 4);
        s.complete(SimTime::ZERO + SimDur::from_secs(2), ActionId(1));
        let started2 = s.drain_started(SimTime::ZERO + SimDur::from_secs(2));
        assert_eq!(started2.len(), 1);
        assert_eq!(started2[0].action, ActionId(2));
    }

    #[test]
    fn utilization_reports_per_service() {
        let r = reg();
        let mut s = StaticGpu::new(vec![
            (ServiceId(0), "a".into(), 4, 2),
            (ServiceId(1), "b".into(), 2, 1),
        ]);
        s.submit(&Arc::new(mk_action(&r, 1, 0, 4)));
        let _ = s.drain_started(SimTime::ZERO);
        let u = s.utilization();
        let a = u.iter().find(|(n, _)| n == "svc:a").unwrap();
        let b = u.iter().find(|(n, _)| n == "svc:b").unwrap();
        assert_eq!(a.1, 0.5);
        assert_eq!(b.1, 0.0);
        let g = u.iter().find(|(n, _)| n == "gpu").unwrap();
        assert!((g.1 - 0.4).abs() < 1e-9); // 4 of 10 GPUs busy
    }

    #[test]
    fn exec_uses_pinned_dop() {
        let r = reg();
        let mut s = StaticGpu::new(vec![(ServiceId(0), "a".into(), 8, 1)]);
        s.submit(&Arc::new(mk_action(&r, 1, 0, 8)));
        let started = s.drain_started(SimTime::ZERO);
        // perfect scaling at dop 8 → 1s
        assert_eq!(started[0].exec, SimDur::from_secs(1));
    }
}
