//! Benchmark support: a small criterion-style timing harness (the real
//! criterion is unavailable offline) plus shared experiment presets used by
//! the `rust/benches/*` targets that regenerate the paper's tables/figures.
//!
//! Scale: by default the benches run at reduced batch sizes so `cargo bench`
//! completes in minutes; set `ARL_BENCH_FULL=1` to reproduce the paper's
//! batch sizes (1280/2048/3072).

use crate::action::TaskId;
use crate::baselines::{BaselineBackend, K8sCfg, ServerlessCfg};
use crate::coordinator::{run, Backend, RunCfg, TangramBackend, TangramCfg};
use crate::metrics::Metrics;
use crate::rollout::workloads::{Catalog, CatalogCfg, Workload, WorkloadKind};
use crate::util::stopwatch::Stopwatch;

// ---------------------------------------------------------------------------
// timing harness
// ---------------------------------------------------------------------------

/// Timing statistics over repeated runs of a closure.
#[derive(Debug, Clone)]
pub struct TimingStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl TimingStats {
    pub fn row(&self) -> String {
        fn fmt(ns: f64) -> String {
            if ns < 1e3 {
                format!("{ns:.0}ns")
            } else if ns < 1e6 {
                format!("{:.2}µs", ns / 1e3)
            } else if ns < 1e9 {
                format!("{:.2}ms", ns / 1e6)
            } else {
                format!("{:.2}s", ns / 1e9)
            }
        }
        format!(
            "{:<44} {:>10} {:>10} {:>10} {:>10}  x{}",
            self.name,
            fmt(self.mean_ns),
            fmt(self.p50_ns),
            fmt(self.p99_ns),
            fmt(self.min_ns),
            self.iters
        )
    }
}

/// Time `f` repeatedly (after warmup) and report stats.
pub fn time_it<F: FnMut()>(name: &str, iters: usize, mut f: F) -> TimingStats {
    let warmup = (iters / 10).max(1);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Stopwatch::start();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    TimingStats {
        name: name.to_string(),
        iters,
        mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
        p50_ns: crate::util::percentile(&samples, 50.0),
        p99_ns: crate::util::percentile(&samples, 99.0),
        min_ns: samples[0],
    }
}

pub fn timing_header() -> String {
    format!(
        "{:<44} {:>10} {:>10} {:>10} {:>10}",
        "benchmark", "mean", "p50", "p99", "min"
    )
}

// ---------------------------------------------------------------------------
// experiment presets
// ---------------------------------------------------------------------------

/// Whether to run at the paper's full batch sizes.
pub fn full_scale() -> bool {
    std::env::var("ARL_BENCH_FULL").map_or(false, |v| v == "1")
}

/// Scale a paper batch size down for the quick default mode.
pub fn scaled(paper_batch: usize) -> usize {
    if full_scale() {
        paper_batch
    } else {
        (paper_batch / 4).max(64)
    }
}

/// CPU-side scale: always the paper's testbed (the DES makes 1280
/// trajectories on 1280 cores sub-second, and both the contention ratio and
/// the DoP-to-node proportion matter) — (batch, cpu_nodes, cores_per_node).
pub fn cpu_scale(paper_batch: usize) -> (usize, u32, u32) {
    (paper_batch, 5, 256)
}

/// GPU-side batches always run at paper scale — the GPU DES is cheap and
/// the contention ratio against the fixed 40-GPU pool is what matters.
pub fn gpu_batch(paper_batch: usize) -> usize {
    paper_batch
}

/// The §6.1 testbed catalog (5×256-core CPU nodes for Fig. 8(a) parity,
/// 5×8-GPU nodes, 9 teachers + 1 judge, 4 API endpoints).
pub fn testbed_catalog() -> Catalog {
    Catalog::build(&CatalogCfg::default())
}

/// Catalog with a custom CPU-core provision (Fig. 8(a) right: 768–1280).
pub fn catalog_with_cores(nodes: u32, cores_per_node: u32) -> Catalog {
    Catalog::build(&CatalogCfg { cpu_nodes: nodes, cores_per_node, ..CatalogCfg::default() })
}

pub fn tangram(cat: &Catalog, cores_per_node: u32, cpu_nodes: u32, gpu_nodes: u32) -> TangramBackend {
    let _ = cat;
    TangramBackend::new(
        cat,
        TangramCfg {
            cpu_nodes,
            numa_per_node: 2,
            cores_per_numa: (cores_per_node / 2).max(1),
            gpu_nodes,
            ..TangramCfg::default()
        },
    )
}

pub fn k8s(cores_per_node: u32, cpu_nodes: u32) -> K8sCfg {
    K8sCfg { nodes: cpu_nodes, cores_per_node, ..K8sCfg::default() }
}

/// Run one experiment and return metrics + wall time.
pub fn run_experiment(
    backend: &mut dyn Backend,
    cat: &Catalog,
    wls: &[Workload],
    batch: usize,
    steps: u32,
    seed: u64,
) -> (Metrics, f64) {
    let cfg = RunCfg { batch, steps, seed, ..RunCfg::default() };
    let t = Stopwatch::start();
    let m = run(backend, cat, wls, &cfg);
    (m, t.secs())
}

pub fn coding_wl() -> Workload {
    Workload::new(TaskId(0), WorkloadKind::Coding)
}

pub fn deepsearch_wl() -> Workload {
    Workload::new(TaskId(1), WorkloadKind::DeepSearch)
}

pub fn mopd_wl() -> Workload {
    Workload::new(TaskId(2), WorkloadKind::Mopd)
}

/// Standard baselines per workload.
pub fn coding_baseline(cat: &Catalog, cores_per_node: u32, cpu_nodes: u32) -> BaselineBackend {
    BaselineBackend::coding(cat, k8s(cores_per_node, cpu_nodes))
}

pub fn mopd_baseline(cat: &Catalog) -> BaselineBackend {
    BaselineBackend::mopd(cat)
}

pub fn deepsearch_baseline(cat: &Catalog) -> BaselineBackend {
    BaselineBackend::deepsearch(cat)
}

pub fn mopd_search_baseline(cat: &Catalog) -> BaselineBackend {
    BaselineBackend::mopd_search(cat)
}

pub fn serverless_baseline(cat: &Catalog, gpu_nodes: u32) -> BaselineBackend {
    BaselineBackend::serverless(cat, ServerlessCfg { gpu_nodes, ..ServerlessCfg::default() })
}

/// Pretty-print a (label, value, unit) table row.
pub fn row(label: &str, cols: &[String]) -> String {
    let mut s = format!("{label:<28}");
    for c in cols {
        s.push_str(&format!("{c:>14}"));
    }
    s
}

// ---------------------------------------------------------------------------
// dirty-pool scheduler bench (BENCH_sched.json)
// ---------------------------------------------------------------------------

/// One scenario pack measured under dirty-pool scheduling vs the legacy
/// full sweep (same spec, same seed, tangram backend).
#[derive(Debug, Clone)]
pub struct SchedBenchRow {
    pub pack: String,
    /// Schedulable pools in the deployment (CPU nodes + GPU + endpoints).
    pub pools: usize,
    /// Elastic-scheduler invocations under dirty-pool scheduling.
    pub sched_invocations: u64,
    /// …and under the full-sweep baseline.
    pub sched_invocations_sweep: u64,
    pub drain_calls: u64,
    pub mean_sched_ns: u64,
    pub mean_drain_ns: u64,
    /// Byte-identical metrics summaries between the two modes.
    pub metrics_equal: bool,
    pub trajectories: usize,
    pub actions: usize,
}

impl SchedBenchRow {
    /// sweep / dirty invocation ratio (how much scanning the dirty set saves).
    pub fn reduction(&self) -> f64 {
        self.sched_invocations_sweep as f64 / self.sched_invocations.max(1) as f64
    }
}

/// Run every built-in scenario pack on the tangram backend twice — dirty-
/// pool and full-sweep — and report scheduler-invocation counts and mean
/// `drain_started` wall time. The acceptance bar: strictly fewer
/// invocations than the sweep at equal metrics, growing with pool count.
pub fn sched_bench_rows() -> Vec<SchedBenchRow> {
    use crate::scenario::{builtin_packs, run_scenario_tangram, summary_json};
    builtin_packs()
        .iter()
        .map(|spec| {
            let (dirty, sd) = run_scenario_tangram(spec, false).expect("dirty-pool run");
            let (sweep, ss) = run_scenario_tangram(spec, true).expect("full-sweep run");
            SchedBenchRow {
                pack: spec.name.clone(),
                pools: sd.pools,
                sched_invocations: sd.invocations,
                sched_invocations_sweep: ss.invocations,
                drain_calls: sd.drain_calls,
                mean_sched_ns: sd.mean_sched_ns,
                mean_drain_ns: sd.mean_drain_ns,
                metrics_equal: summary_json(&dirty.metrics).to_string()
                    == summary_json(&sweep.metrics).to_string(),
                trajectories: dirty.metrics.trajectories.len(),
                actions: dirty.metrics.actions.len(),
            }
        })
        .collect()
}

/// Autoscale-aware admission on/off differential on the autoscaler A/B
/// reference pack — the `admission` section of `BENCH_sched.json`, which
/// `bench-gate` ratchets alongside the dirty-vs-sweep invocation ratio.
#[derive(Debug, Clone)]
pub struct AdmissionBench {
    pub pack: String,
    /// Mean ACT with admission on (queue wait overlaps cold starts).
    pub mean_act_with: f64,
    /// …and off (resizes wait for the next evaluation tick past warm-up).
    pub mean_act_without: f64,
    /// Resource-hour savings either way — admission moves apply instants,
    /// never the bill, so these two must be equal.
    pub savings_with: f64,
    pub savings_without: f64,
}

impl AdmissionBench {
    /// with/without mean-ACT ratio: ≤ 1 means admission helped (or was
    /// neutral); the gate's hard invariant.
    pub fn act_ratio(&self) -> f64 {
        if self.mean_act_without <= 0.0 {
            return 1.0;
        }
        self.mean_act_with / self.mean_act_without
    }
}

/// Run the admission differential (coldstart-storm, autoscaled, tangram).
pub fn admission_bench() -> AdmissionBench {
    use crate::autoscale::AutoscaleCfg;
    use crate::config::BackendKind;
    use crate::scenario::{pack_by_name, run_scenario};
    let mut off_spec = pack_by_name("coldstart-storm").expect("coldstart-storm pack");
    off_spec.autoscale = Some(AutoscaleCfg::default());
    let mut on_spec = off_spec.clone();
    on_spec.autoscale.as_mut().expect("autoscale set above").admission = true;
    let off = run_scenario(&off_spec, BackendKind::Tangram).expect("admission-off run");
    let on = run_scenario(&on_spec, BackendKind::Tangram).expect("admission-on run");
    AdmissionBench {
        pack: off_spec.name,
        mean_act_with: on.metrics.mean_act(),
        mean_act_without: off.metrics.mean_act(),
        savings_with: on.metrics.savings_vs_static(),
        savings_without: off.metrics.savings_vs_static(),
    }
}

/// Actions-per-second of the million-action scale pack on the dirty-pool
/// tangram configuration — serial and with the sharded worker pool — plus
/// the process's peak RSS after the runs: the `throughput` section of
/// `BENCH_sched.json`, ratcheted by `bench-gate` (shrink-only on
/// actions/sec and on the threaded speedup, grow-capped on RSS).
#[derive(Debug, Clone)]
pub struct ThroughputBench {
    pub pack: String,
    /// Terminal actions the run completed.
    pub actions: u64,
    /// Wall-clock of the serial simulation run (seconds).
    pub wall_secs: f64,
    /// `actions / wall_secs`.
    pub actions_per_sec: f64,
    /// Worker threads used by the threaded pass (shards match the count).
    pub threads: usize,
    /// Wall-clock of the threaded pass (seconds).
    pub wall_secs_threaded: f64,
    /// `actions / wall_secs_threaded`.
    pub actions_per_sec_threaded: f64,
    /// Peak resident set of the bench process after the run (KiB; 0 where
    /// `/proc` is unavailable — the gate then skips the RSS ratchet).
    pub peak_rss_kb: u64,
}

impl ThroughputBench {
    /// threaded / serial actions-per-sec ratio (> 1 = the worker pool pays
    /// for itself on this machine).
    pub fn speedup(&self) -> f64 {
        if self.actions_per_sec <= 0.0 {
            return 1.0;
        }
        self.actions_per_sec_threaded / self.actions_per_sec
    }
}

/// Worker threads (and matching shard count) for the threaded throughput
/// pass — parallelism needs shards > 1, and four of each is the smallest
/// deployment the paper's testbed runners all have cores for.
pub const THROUGHPUT_THREADS: usize = 4;

/// Run the throughput bench: a timed serial dirty-pool tangram pass over
/// the million-action pack, then the same spec again on the
/// `--shards 4 --threads 4` worker pool. The traces are byte-identical by
/// the drain contract, so the comparison isolates pure wall-clock.
pub fn throughput_bench() -> crate::util::error::Result<ThroughputBench> {
    use crate::err;
    use crate::scenario::{million_action_pack, run_scenario_tangram, run_scenario_tangram_threaded};
    let spec = million_action_pack();
    let t = Stopwatch::start();
    let (outcome, _) = run_scenario_tangram(&spec, false)?;
    let wall_secs = t.secs();
    let actions = outcome.metrics.actions.len() as u64;
    let t = Stopwatch::start();
    let (threaded, _) =
        run_scenario_tangram_threaded(&spec, false, THROUGHPUT_THREADS, THROUGHPUT_THREADS)?;
    let wall_secs_threaded = t.secs();
    let actions_threaded = threaded.metrics.actions.len() as u64;
    if actions_threaded != actions {
        return Err(err!(
            "threaded throughput pass diverged from serial: {actions_threaded} vs {actions} actions"
        ));
    }
    Ok(ThroughputBench {
        pack: spec.name,
        actions,
        wall_secs,
        actions_per_sec: actions as f64 / wall_secs.max(1e-9),
        threads: THROUGHPUT_THREADS,
        wall_secs_threaded,
        actions_per_sec_threaded: actions as f64 / wall_secs_threaded.max(1e-9),
        peak_rss_kb: crate::metrics::peak_rss_kb(),
    })
}

/// Serialize bench rows (plus the admission differential and, when
/// measured, the throughput section) to the `BENCH_sched.json` format.
pub fn sched_bench_json(
    rows: &[SchedBenchRow],
    admission: &AdmissionBench,
    throughput: Option<&ThroughputBench>,
) -> String {
    use crate::util::json::Json;
    let mut pairs = vec![
        ("bench", Json::str("sched_dirty_pool")),
        ("backend", Json::str("tangram")),
        (
            "rows",
            Json::arr(rows.iter().map(|r| {
                Json::obj(vec![
                    ("pack", Json::str(r.pack.clone())),
                    ("pools", Json::num(r.pools as f64)),
                    ("sched_invocations", Json::num(r.sched_invocations as f64)),
                    (
                        "sched_invocations_sweep",
                        Json::num(r.sched_invocations_sweep as f64),
                    ),
                    ("reduction", Json::num(r.reduction())),
                    ("drain_calls", Json::num(r.drain_calls as f64)),
                    ("mean_sched_ns", Json::num(r.mean_sched_ns as f64)),
                    ("mean_drain_ns", Json::num(r.mean_drain_ns as f64)),
                    ("metrics_equal", Json::Bool(r.metrics_equal)),
                    ("trajectories", Json::num(r.trajectories as f64)),
                    ("actions", Json::num(r.actions as f64)),
                ])
            })),
        ),
        (
            "admission",
            Json::obj(vec![
                ("pack", Json::str(admission.pack.clone())),
                ("mean_act_with", Json::num(admission.mean_act_with)),
                ("mean_act_without", Json::num(admission.mean_act_without)),
                ("act_ratio", Json::num(admission.act_ratio())),
                ("savings_with", Json::num(admission.savings_with)),
                ("savings_without", Json::num(admission.savings_without)),
            ]),
        ),
    ];
    if let Some(t) = throughput {
        pairs.push((
            "throughput",
            Json::obj(vec![
                ("pack", Json::str(t.pack.clone())),
                ("actions", Json::num(t.actions as f64)),
                ("wall_secs", Json::num(t.wall_secs)),
                ("actions_per_sec", Json::num(t.actions_per_sec)),
                ("threads", Json::num(t.threads as f64)),
                ("wall_secs_threaded", Json::num(t.wall_secs_threaded)),
                ("actions_per_sec_threaded", Json::num(t.actions_per_sec_threaded)),
                ("speedup", Json::num(t.speedup())),
                ("peak_rss_kb", Json::num(t.peak_rss_kb as f64)),
            ]),
        ));
    }
    Json::obj(pairs).to_string()
}

// ---------------------------------------------------------------------------
// bench regression gate (BENCH_sched.json vs committed baseline)
// ---------------------------------------------------------------------------

/// One parsed row of a `BENCH_sched.json` report — the unit the CI perf
/// ratchet compares.
#[derive(Debug, Clone)]
pub struct GateRow {
    pub pack: String,
    /// sweep / dirty invocation ratio (higher = dirty-pool saves more).
    pub reduction: f64,
    pub metrics_equal: bool,
}

/// Parse the `BENCH_sched.json` format written by [`sched_bench_json`].
pub fn parse_sched_bench(text: &str) -> crate::util::error::Result<Vec<GateRow>> {
    use crate::err;
    let j = crate::util::json::Json::parse(text).map_err(|e| err!("BENCH_sched.json: {e}"))?;
    let rows = j
        .get("rows")
        .and_then(|r| r.as_arr())
        .ok_or_else(|| err!("BENCH_sched.json has no 'rows' array"))?;
    rows.iter()
        .map(|r| {
            let field = |k: &str| {
                r.get(k)
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| err!("bench row missing number '{k}'"))
            };
            Ok(GateRow {
                pack: r
                    .get("pack")
                    .and_then(|p| p.as_str())
                    .ok_or_else(|| err!("bench row missing 'pack'"))?
                    .to_string(),
                reduction: field("reduction")?,
                metrics_equal: r
                    .get("metrics_equal")
                    .and_then(|b| b.as_bool())
                    .unwrap_or(false),
            })
        })
        .collect()
}

/// Parsed `admission` section of a `BENCH_sched.json` report.
#[derive(Debug, Clone, PartialEq)]
pub struct AdmissionGate {
    pub pack: String,
    /// with/without mean-ACT ratio (≤ 1 = admission helps or is neutral).
    pub act_ratio: f64,
    pub savings_with: f64,
    pub savings_without: f64,
}

/// Parse the optional `admission` section written by [`sched_bench_json`]
/// (older baselines predate it — `Ok(None)`).
pub fn parse_admission(text: &str) -> crate::util::error::Result<Option<AdmissionGate>> {
    use crate::err;
    let j = crate::util::json::Json::parse(text).map_err(|e| err!("BENCH_sched.json: {e}"))?;
    let Some(a) = j.get("admission") else {
        return Ok(None);
    };
    let field = |k: &str| {
        a.get(k)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| err!("admission section missing number '{k}'"))
    };
    Ok(Some(AdmissionGate {
        pack: a
            .get("pack")
            .and_then(|p| p.as_str())
            .ok_or_else(|| err!("admission section missing 'pack'"))?
            .to_string(),
        act_ratio: field("act_ratio")?,
        savings_with: field("savings_with")?,
        savings_without: field("savings_without")?,
    }))
}

/// Parsed `throughput` section of a `BENCH_sched.json` report. The
/// threaded keys are `None` on baselines written before the worker pool
/// existed — the speedup ratchet then reports instead of comparing.
#[derive(Debug, Clone, PartialEq)]
pub struct ThroughputGate {
    pub pack: String,
    pub actions: f64,
    pub actions_per_sec: f64,
    pub actions_per_sec_threaded: Option<f64>,
    pub speedup: Option<f64>,
    pub peak_rss_kb: f64,
}

/// Parse the optional `throughput` section written by [`sched_bench_json`]
/// (older baselines predate it — `Ok(None)`).
pub fn parse_throughput(text: &str) -> crate::util::error::Result<Option<ThroughputGate>> {
    use crate::err;
    let j = crate::util::json::Json::parse(text).map_err(|e| err!("BENCH_sched.json: {e}"))?;
    let Some(t) = j.get("throughput") else {
        return Ok(None);
    };
    let field = |k: &str| {
        t.get(k)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| err!("throughput section missing number '{k}'"))
    };
    Ok(Some(ThroughputGate {
        pack: t
            .get("pack")
            .and_then(|p| p.as_str())
            .ok_or_else(|| err!("throughput section missing 'pack'"))?
            .to_string(),
        actions: field("actions")?,
        actions_per_sec: field("actions_per_sec")?,
        actions_per_sec_threaded: t.get("actions_per_sec_threaded").and_then(|v| v.as_f64()),
        speedup: t.get("speedup").and_then(|v| v.as_f64()),
        peak_rss_kb: field("peak_rss_kb")?,
    }))
}

/// Result of the bench regression gate.
#[derive(Debug)]
pub struct GateReport {
    /// Human-readable per-pack comparison lines.
    pub lines: Vec<String>,
    /// Hard failures (regressions, divergence, missing packs).
    pub failures: Vec<String>,
}

impl GateReport {
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }
}

/// The CI perf ratchet: compare a fresh `BENCH_sched.json` against the
/// committed baseline and fail on a >`tolerance` relative regression of
/// the dirty-vs-sweep invocation ratio, on dirty/sweep metric divergence,
/// or on a baseline pack vanishing from the fresh report. New packs in the
/// fresh report are reported but never fail (they have no baseline yet).
pub fn sched_bench_gate(
    baseline: &str,
    fresh: &str,
    tolerance: f64,
) -> crate::util::error::Result<GateReport> {
    let base_rows = parse_sched_bench(baseline)?;
    let fresh_rows = parse_sched_bench(fresh)?;
    let mut report = GateReport { lines: Vec::new(), failures: Vec::new() };
    // an empty report on either side would pass vacuously — refuse
    if base_rows.is_empty() {
        report.failures.push("baseline report has no rows (refusing a vacuous pass)".into());
    }
    if fresh_rows.is_empty() {
        report.failures.push("fresh report has no rows (bench produced nothing?)".into());
    }
    for b in &base_rows {
        let Some(f) = fresh_rows.iter().find(|f| f.pack == b.pack) else {
            report
                .failures
                .push(format!("pack '{}' present in baseline but missing from fresh run", b.pack));
            continue;
        };
        if !f.metrics_equal {
            report.failures.push(format!(
                "pack '{}': dirty-pool metrics diverged from full sweep",
                f.pack
            ));
        }
        let floor = b.reduction * (1.0 - tolerance);
        let verdict = if f.reduction < floor { "REGRESSED" } else { "ok" };
        report.lines.push(format!(
            "{:<16} reduction {:.2}x -> {:.2}x (floor {:.2}x) {}",
            b.pack, b.reduction, f.reduction, floor, verdict
        ));
        if f.reduction < floor {
            report.failures.push(format!(
                "pack '{}': dirty-vs-sweep invocation ratio regressed {:.2}x -> {:.2}x \
                 (>{:.0}% loss)",
                b.pack,
                b.reduction,
                f.reduction,
                tolerance * 100.0
            ));
        }
    }
    for f in &fresh_rows {
        if !base_rows.iter().any(|b| b.pack == f.pack) {
            // no ratio baseline yet, but dirty/sweep divergence is a hard
            // failure regardless of how new the pack is
            if !f.metrics_equal {
                report.failures.push(format!(
                    "pack '{}': dirty-pool metrics diverged from full sweep",
                    f.pack
                ));
            }
            report.lines.push(format!(
                "{:<16} new pack (reduction {:.2}x) — no baseline, commit one to ratchet it",
                f.pack, f.reduction
            ));
        }
    }
    gate_admission(&mut report, parse_admission(baseline)?, parse_admission(fresh)?, tolerance);
    gate_throughput(&mut report, parse_throughput(baseline)?, parse_throughput(fresh)?, tolerance);
    Ok(report)
}

/// Throughput ratchet: actions/sec and the threaded speedup may only
/// shrink within a widened slack (5× the invocation-ratio tolerance —
/// they are the wall-clock-derived figures in the report, so CI machine
/// noise needs the extra headroom), and peak RSS may only grow within the
/// same slack. A zero RSS on either side means `/proc` was unavailable
/// there; the RSS ratchet is skipped rather than compared against a
/// placeholder. A baseline without the threaded keys (written before the
/// worker pool existed) only reports the fresh speedup.
fn gate_throughput(
    report: &mut GateReport,
    base: Option<ThroughputGate>,
    fresh: Option<ThroughputGate>,
    tolerance: f64,
) {
    let Some(f) = fresh else {
        if base.is_some() {
            report
                .failures
                .push("throughput section present in baseline but missing from fresh run".into());
        }
        return;
    };
    if f.actions < 1.0 || f.actions_per_sec <= 0.0 {
        report.failures.push(format!(
            "throughput bench ('{}') completed no work ({:.0} actions, {:.0} actions/sec)",
            f.pack, f.actions, f.actions_per_sec
        ));
    }
    let slack = 5.0 * tolerance;
    match base {
        Some(b) => {
            let floor = b.actions_per_sec * (1.0 - slack);
            let verdict = if f.actions_per_sec < floor { "REGRESSED" } else { "ok" };
            report.lines.push(format!(
                "{:<16} throughput {:.0} -> {:.0} actions/sec (floor {:.0}) {}",
                f.pack, b.actions_per_sec, f.actions_per_sec, floor, verdict
            ));
            if f.actions_per_sec < floor {
                report.failures.push(format!(
                    "throughput ('{}'): actions/sec regressed {:.0} -> {:.0} (>{:.0}% loss)",
                    f.pack,
                    b.actions_per_sec,
                    f.actions_per_sec,
                    slack * 100.0
                ));
            }
            match (b.speedup, f.speedup) {
                (Some(bs), Some(fs)) => {
                    let floor = bs * (1.0 - slack);
                    let verdict = if fs < floor { "REGRESSED" } else { "ok" };
                    report.lines.push(format!(
                        "{:<16} threaded speedup {:.2}x -> {:.2}x (floor {:.2}x) {}",
                        f.pack, bs, fs, floor, verdict
                    ));
                    if fs < floor {
                        report.failures.push(format!(
                            "throughput ('{}'): threaded speedup regressed {:.2}x -> {:.2}x \
                             (>{:.0}% loss)",
                            f.pack,
                            bs,
                            fs,
                            slack * 100.0
                        ));
                    }
                }
                (Some(_), None) => report.failures.push(format!(
                    "throughput ('{}'): threaded speedup present in baseline but missing from \
                     fresh run",
                    f.pack
                )),
                (None, Some(fs)) => report.lines.push(format!(
                    "{:<16} threaded speedup {:.2}x — no baseline yet, commit one to ratchet it",
                    f.pack, fs
                )),
                (None, None) => {}
            }
            if b.peak_rss_kb > 0.0 && f.peak_rss_kb > 0.0 {
                let ceiling = b.peak_rss_kb * (1.0 + slack);
                let verdict = if f.peak_rss_kb > ceiling { "REGRESSED" } else { "ok" };
                report.lines.push(format!(
                    "{:<16} peak RSS {:.0} -> {:.0} KiB (ceiling {:.0}) {}",
                    f.pack, b.peak_rss_kb, f.peak_rss_kb, ceiling, verdict
                ));
                if f.peak_rss_kb > ceiling {
                    report.failures.push(format!(
                        "throughput ('{}'): peak RSS grew {:.0} -> {:.0} KiB (>{:.0}% growth)",
                        f.pack,
                        b.peak_rss_kb,
                        f.peak_rss_kb,
                        slack * 100.0
                    ));
                }
            }
        }
        None => report.lines.push(format!(
            "{:<16} throughput {:.0} actions/sec — no baseline yet, commit one to ratchet it",
            f.pack, f.actions_per_sec
        )),
    }
}

/// Admission ratchet: the fresh report must uphold the hard invariants
/// (admission never raises mean ACT, never moves the bill) and must not
/// lose more than `tolerance` of the baseline's admission benefit.
fn gate_admission(
    report: &mut GateReport,
    base: Option<AdmissionGate>,
    fresh: Option<AdmissionGate>,
    tolerance: f64,
) {
    let Some(f) = fresh else {
        if base.is_some() {
            report
                .failures
                .push("admission section present in baseline but missing from fresh run".into());
        }
        return;
    };
    if f.act_ratio > 1.0 + 1e-6 {
        report.failures.push(format!(
            "admission differential ('{}'): mean ACT with admission exceeds without \
             (ratio {:.4})",
            f.pack, f.act_ratio
        ));
    }
    // billing points never move, but earlier applies shift post-apply
    // dynamics and therefore later scale-DOWN decision timing — savings
    // must agree up to that one-evaluation drift
    if (f.savings_with - f.savings_without).abs() > 0.01 {
        report.failures.push(format!(
            "admission differential ('{}'): billing moved ({} vs {}) — admission must only \
             move apply instants",
            f.pack, f.savings_with, f.savings_without
        ));
    }
    match base {
        Some(b) => {
            // lower ratio = bigger benefit; allow `tolerance` relative slack
            let ceiling = b.act_ratio * (1.0 + tolerance);
            let verdict = if f.act_ratio > ceiling { "REGRESSED" } else { "ok" };
            report.lines.push(format!(
                "{:<16} admission ACT ratio {:.4} -> {:.4} (ceiling {:.4}) {}",
                f.pack, b.act_ratio, f.act_ratio, ceiling, verdict
            ));
            if f.act_ratio > ceiling {
                report.failures.push(format!(
                    "admission differential ('{}'): benefit regressed {:.4} -> {:.4} \
                     (>{:.0}% loss)",
                    f.pack,
                    b.act_ratio,
                    f.act_ratio,
                    tolerance * 100.0
                ));
            }
        }
        None => report.lines.push(format!(
            "{:<16} admission ACT ratio {:.4} — no baseline yet, commit one to ratchet it",
            f.pack, f.act_ratio
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_produces_sane_stats() {
        let s = time_it("noop-ish", 50, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.p50_ns <= s.p99_ns);
        assert!(s.min_ns <= s.p50_ns);
        assert!(!s.row().is_empty());
    }

    #[test]
    fn scaled_respects_env_default() {
        // default mode: quarter scale with a floor of 64
        if !full_scale() {
            assert_eq!(scaled(1280), 320);
            assert_eq!(scaled(128), 64);
        }
    }

    fn bench_json(rows: &[(&str, f64, bool)]) -> String {
        let body: Vec<String> = rows
            .iter()
            .map(|(p, r, eq)| {
                format!(r#"{{"pack":"{p}","reduction":{r},"metrics_equal":{eq}}}"#)
            })
            .collect();
        format!(r#"{{"bench":"sched_dirty_pool","rows":[{}]}}"#, body.join(","))
    }

    #[test]
    fn gate_passes_within_tolerance() {
        let base = bench_json(&[("steady-mix", 4.0, true), ("api-flap", 3.0, true)]);
        let fresh = bench_json(&[("steady-mix", 3.7, true), ("api-flap", 3.2, true)]);
        let g = sched_bench_gate(&base, &fresh, 0.10).unwrap();
        assert!(g.passed(), "{:?}", g.failures);
        assert_eq!(g.lines.len(), 2);
    }

    #[test]
    fn gate_fails_on_ratio_regression() {
        let base = bench_json(&[("steady-mix", 4.0, true)]);
        let fresh = bench_json(&[("steady-mix", 3.0, true)]); // 25% loss
        let g = sched_bench_gate(&base, &fresh, 0.10).unwrap();
        assert!(!g.passed());
        assert!(g.failures[0].contains("regressed"));
    }

    #[test]
    fn gate_fails_on_divergence_and_missing_pack() {
        let base = bench_json(&[("steady-mix", 4.0, true), ("api-flap", 3.0, true)]);
        let fresh = bench_json(&[("steady-mix", 4.0, false)]);
        let g = sched_bench_gate(&base, &fresh, 0.10).unwrap();
        assert_eq!(g.failures.len(), 2, "{:?}", g.failures);
        assert!(g.failures.iter().any(|f| f.contains("missing")));
        assert!(g.failures.iter().any(|f| f.contains("diverged")));
    }

    #[test]
    fn gate_tolerates_new_packs() {
        let base = bench_json(&[("steady-mix", 4.0, true)]);
        let fresh = bench_json(&[("steady-mix", 4.0, true), ("brand-new", 9.0, true)]);
        let g = sched_bench_gate(&base, &fresh, 0.10).unwrap();
        assert!(g.passed());
        assert!(g.lines.iter().any(|l| l.contains("new pack")));
    }

    #[test]
    fn gate_fails_on_divergent_new_pack_and_empty_reports() {
        // a brand-new pack with dirty/sweep divergence must still fail
        let base = bench_json(&[("steady-mix", 4.0, true)]);
        let fresh = bench_json(&[("steady-mix", 4.0, true), ("brand-new", 9.0, false)]);
        let g = sched_bench_gate(&base, &fresh, 0.10).unwrap();
        assert!(!g.passed());
        assert!(g.failures[0].contains("diverged"));
        // empty reports must not pass vacuously
        let empty = r#"{"rows":[]}"#;
        let g = sched_bench_gate(empty, &base, 0.10).unwrap();
        assert!(!g.passed());
        let g = sched_bench_gate(&base, empty, 0.10).unwrap();
        assert!(!g.passed());
    }

    #[test]
    fn gate_rejects_malformed_reports() {
        assert!(sched_bench_gate("not json", "{}", 0.1).is_err());
        assert!(sched_bench_gate(r#"{"rows":[]}"#, "{}", 0.1).is_err());
        assert!(parse_sched_bench(r#"{"rows":[{"pack":"x"}]}"#).is_err());
    }

    fn bench_json_with_admission(
        rows: &[(&str, f64, bool)],
        ratio: f64,
        s_with: f64,
        s_without: f64,
    ) -> String {
        let body: Vec<String> = rows
            .iter()
            .map(|(p, r, eq)| {
                format!(r#"{{"pack":"{p}","reduction":{r},"metrics_equal":{eq}}}"#)
            })
            .collect();
        format!(
            r#"{{"bench":"sched_dirty_pool","rows":[{}],"admission":{{"pack":"coldstart-storm","mean_act_with":1.0,"mean_act_without":1.0,"act_ratio":{ratio},"savings_with":{s_with},"savings_without":{s_without}}}}}"#,
            body.join(",")
        )
    }

    #[test]
    fn admission_section_parses_and_is_optional() {
        let plain = bench_json(&[("steady-mix", 4.0, true)]);
        assert_eq!(parse_admission(&plain).unwrap(), None);
        let with = bench_json_with_admission(&[("steady-mix", 4.0, true)], 0.95, 0.4, 0.4);
        let a = parse_admission(&with).unwrap().unwrap();
        assert_eq!(a.pack, "coldstart-storm");
        assert!((a.act_ratio - 0.95).abs() < 1e-12);
        assert!(parse_admission(r#"{"admission":{"pack":"x"}}"#).is_err());
    }

    #[test]
    fn gate_ratchets_the_admission_differential() {
        let rows = [("steady-mix", 4.0, true)];
        let base = bench_json_with_admission(&rows, 0.90, 0.4, 0.4);
        // within tolerance: 0.95 ≤ 0.90 × 1.10
        let ok = bench_json_with_admission(&rows, 0.95, 0.4, 0.4);
        let g = sched_bench_gate(&base, &ok, 0.10).unwrap();
        assert!(g.passed(), "{:?}", g.failures);
        assert!(g.lines.iter().any(|l| l.contains("admission ACT ratio")));
        // benefit regressed past the ceiling
        let worse = bench_json_with_admission(&rows, 0.9999, 0.4, 0.4);
        let g = sched_bench_gate(&base, &worse, 0.10).unwrap();
        assert!(!g.passed());
        assert!(g.failures.iter().any(|f| f.contains("benefit regressed")));
        // hard invariant: admission must never raise mean ACT…
        let raised = bench_json_with_admission(&rows, 1.05, 0.4, 0.4);
        let g = sched_bench_gate(&base, &raised, 0.10).unwrap();
        assert!(g.failures.iter().any(|f| f.contains("exceeds without")));
        // …or move the bill
        let moved = bench_json_with_admission(&rows, 0.9, 0.5, 0.4);
        let g = sched_bench_gate(&base, &moved, 0.10).unwrap();
        assert!(g.failures.iter().any(|f| f.contains("billing moved")));
        // a vanished section is a ratchet failure; a missing baseline is not
        let plain = bench_json(&rows);
        let g = sched_bench_gate(&base, &plain, 0.10).unwrap();
        assert!(g.failures.iter().any(|f| f.contains("missing from fresh")));
        let g = sched_bench_gate(&plain, &ok, 0.10).unwrap();
        assert!(g.passed(), "{:?}", g.failures);
        assert!(g.lines.iter().any(|l| l.contains("no baseline yet")));
    }

    fn bench_json_with_throughput(
        rows: &[(&str, f64, bool)],
        actions_per_sec: f64,
        peak_rss_kb: f64,
    ) -> String {
        let body: Vec<String> = rows
            .iter()
            .map(|(p, r, eq)| {
                format!(r#"{{"pack":"{p}","reduction":{r},"metrics_equal":{eq}}}"#)
            })
            .collect();
        format!(
            r#"{{"bench":"sched_dirty_pool","rows":[{}],"throughput":{{"pack":"million-action","actions":1000000,"wall_secs":10.0,"actions_per_sec":{actions_per_sec},"peak_rss_kb":{peak_rss_kb}}}}}"#,
            body.join(",")
        )
    }

    #[test]
    fn throughput_section_parses_and_is_optional() {
        let plain = bench_json(&[("steady-mix", 4.0, true)]);
        assert_eq!(parse_throughput(&plain).unwrap(), None);
        let with = bench_json_with_throughput(&[("steady-mix", 4.0, true)], 100000.0, 50000.0);
        let t = parse_throughput(&with).unwrap().unwrap();
        assert_eq!(t.pack, "million-action");
        assert!((t.actions_per_sec - 100000.0).abs() < 1e-9);
        assert!((t.peak_rss_kb - 50000.0).abs() < 1e-9);
        assert!(parse_throughput(r#"{"throughput":{"pack":"x"}}"#).is_err());
    }

    #[test]
    fn gate_ratchets_actions_per_sec_with_widened_slack() {
        let rows = [("steady-mix", 4.0, true)];
        let base = bench_json_with_throughput(&rows, 100000.0, 50000.0);
        // 5× the 10% tolerance → the floor is 50% of baseline
        let ok = bench_json_with_throughput(&rows, 60000.0, 50000.0);
        let g = sched_bench_gate(&base, &ok, 0.10).unwrap();
        assert!(g.passed(), "{:?}", g.failures);
        assert!(g.lines.iter().any(|l| l.contains("throughput")));
        let worse = bench_json_with_throughput(&rows, 40000.0, 50000.0);
        let g = sched_bench_gate(&base, &worse, 0.10).unwrap();
        assert!(!g.passed());
        assert!(g.failures.iter().any(|f| f.contains("actions/sec regressed")));
    }

    #[test]
    fn gate_caps_peak_rss_growth_and_skips_unmeasured_rss() {
        let rows = [("steady-mix", 4.0, true)];
        let base = bench_json_with_throughput(&rows, 100000.0, 50000.0);
        // RSS ceiling is 1.5× baseline at the widened slack
        let grown = bench_json_with_throughput(&rows, 100000.0, 80000.0);
        let g = sched_bench_gate(&base, &grown, 0.10).unwrap();
        assert!(!g.passed());
        assert!(g.failures.iter().any(|f| f.contains("peak RSS grew")));
        // an unmeasured side (0 KiB — no /proc) skips the RSS ratchet
        let unmeasured = bench_json_with_throughput(&rows, 100000.0, 0.0);
        let g = sched_bench_gate(&base, &unmeasured, 0.10).unwrap();
        assert!(g.passed(), "{:?}", g.failures);
        let g = sched_bench_gate(&unmeasured, &grown, 0.10).unwrap();
        assert!(g.passed(), "{:?}", g.failures);
    }

    #[test]
    fn gate_handles_missing_throughput_sections() {
        let rows = [("steady-mix", 4.0, true)];
        let base = bench_json_with_throughput(&rows, 100000.0, 50000.0);
        let plain = bench_json(&rows);
        // a vanished section is a ratchet failure…
        let g = sched_bench_gate(&base, &plain, 0.10).unwrap();
        assert!(g.failures.iter().any(|f| f.contains("throughput section present")));
        // …a missing baseline only reports
        let g = sched_bench_gate(&plain, &base, 0.10).unwrap();
        assert!(g.passed(), "{:?}", g.failures);
        assert!(g.lines.iter().any(|l| l.contains("no baseline yet")));
        // an empty fresh measurement is a hard failure even with no baseline
        let dead = bench_json_with_throughput(&rows, 0.0, 0.0);
        let g = sched_bench_gate(&plain, &dead, 0.10).unwrap();
        assert!(g.failures.iter().any(|f| f.contains("completed no work")));
    }

    fn bench_json_with_speedup(
        rows: &[(&str, f64, bool)],
        actions_per_sec: f64,
        speedup: f64,
    ) -> String {
        let body: Vec<String> = rows
            .iter()
            .map(|(p, r, eq)| {
                format!(r#"{{"pack":"{p}","reduction":{r},"metrics_equal":{eq}}}"#)
            })
            .collect();
        let threaded = actions_per_sec * speedup;
        format!(
            r#"{{"bench":"sched_dirty_pool","rows":[{}],"throughput":{{"pack":"million-action","actions":1000000,"wall_secs":10.0,"actions_per_sec":{actions_per_sec},"threads":4,"wall_secs_threaded":5.0,"actions_per_sec_threaded":{threaded},"speedup":{speedup},"peak_rss_kb":50000.0}}}}"#,
            body.join(",")
        )
    }

    #[test]
    fn threaded_speedup_keys_parse_as_optional() {
        // pre-worker-pool baselines have no threaded keys
        let old = bench_json_with_throughput(&[("steady-mix", 4.0, true)], 100000.0, 50000.0);
        let t = parse_throughput(&old).unwrap().unwrap();
        assert_eq!(t.speedup, None);
        assert_eq!(t.actions_per_sec_threaded, None);
        let new = bench_json_with_speedup(&[("steady-mix", 4.0, true)], 100000.0, 1.8);
        let t = parse_throughput(&new).unwrap().unwrap();
        assert!((t.speedup.unwrap() - 1.8).abs() < 1e-12);
        assert!((t.actions_per_sec_threaded.unwrap() - 180000.0).abs() < 1e-6);
    }

    #[test]
    fn gate_ratchets_the_threaded_speedup_shrink_only() {
        let rows = [("steady-mix", 4.0, true)];
        let base = bench_json_with_speedup(&rows, 100000.0, 2.0);
        // within the widened slack: 1.2 ≥ 2.0 × (1 − 0.5)
        let ok = bench_json_with_speedup(&rows, 100000.0, 1.2);
        let g = sched_bench_gate(&base, &ok, 0.10).unwrap();
        assert!(g.passed(), "{:?}", g.failures);
        assert!(g.lines.iter().any(|l| l.contains("threaded speedup")));
        // growth never fails
        let faster = bench_json_with_speedup(&rows, 100000.0, 3.0);
        let g = sched_bench_gate(&base, &faster, 0.10).unwrap();
        assert!(g.passed(), "{:?}", g.failures);
        // past the floor fails
        let worse = bench_json_with_speedup(&rows, 100000.0, 0.9);
        let g = sched_bench_gate(&base, &worse, 0.10).unwrap();
        assert!(!g.passed());
        assert!(g.failures.iter().any(|f| f.contains("threaded speedup regressed")));
        // vanished threaded keys are a ratchet failure…
        let plain = bench_json_with_throughput(&rows, 100000.0, 50000.0);
        let g = sched_bench_gate(&base, &plain, 0.10).unwrap();
        assert!(g.failures.iter().any(|f| f.contains("missing from")));
        // …an old baseline without them only reports
        let g = sched_bench_gate(&plain, &ok, 0.10).unwrap();
        assert!(g.passed(), "{:?}", g.failures);
        assert!(g.lines.iter().any(|l| l.contains("no baseline yet")));
    }

    #[test]
    fn bench_json_round_trips_the_throughput_section() {
        let t = ThroughputBench {
            pack: "million-action".into(),
            actions: 1_000_000,
            wall_secs: 8.0,
            actions_per_sec: 125_000.0,
            threads: 4,
            wall_secs_threaded: 4.0,
            actions_per_sec_threaded: 250_000.0,
            peak_rss_kb: 40_960,
        };
        assert_eq!(t.speedup().to_bits(), 2.0f64.to_bits());
        let adm = AdmissionBench {
            pack: "coldstart-storm".into(),
            mean_act_with: 1.0,
            mean_act_without: 1.0,
            savings_with: 0.4,
            savings_without: 0.4,
        };
        let text = sched_bench_json(&[], &adm, Some(&t));
        let parsed = parse_throughput(&text).unwrap().unwrap();
        assert_eq!(parsed.pack, "million-action");
        assert_eq!(parsed.actions.to_bits(), 1_000_000f64.to_bits());
        assert_eq!(parsed.actions_per_sec.to_bits(), 125_000f64.to_bits());
        assert_eq!(
            parsed.actions_per_sec_threaded.map(f64::to_bits),
            Some(250_000f64.to_bits())
        );
        assert_eq!(parsed.speedup.map(f64::to_bits), Some(2.0f64.to_bits()));
        assert_eq!(parsed.peak_rss_kb.to_bits(), 40_960f64.to_bits());
        // and without a measurement the key is absent entirely
        let text = sched_bench_json(&[], &adm, None);
        assert_eq!(parse_throughput(&text).unwrap(), None);
        assert!(!text.contains("throughput"));
    }
}
