//! Simulated external API endpoints (search, page fetch, PDF parse, …).
//!
//! The paper's DeepSearch workload hammers rate-limited third-party APIs;
//! the baseline's unmanaged calls trigger 429s/timeouts and retry storms
//! (§6.2: "frequent API failures cause trajectories to become ineffective").
//! This substrate models exactly the failure surface the Basic manager's
//! concurrency/quota enforcement removes.

use crate::sim::{SimDur, SimTime};
use crate::util::rng::Rng;

/// Outcome of issuing one request against an endpoint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ApiOutcome {
    /// Served successfully after the returned latency.
    Ok,
    /// Rejected immediately with HTTP 429 (rate limit exceeded).
    RateLimited,
    /// Accepted but exceeded the client timeout.
    Timeout,
    /// Transient server error (5xx).
    ServerError,
}

/// Static description of one endpoint.
#[derive(Debug, Clone)]
pub struct ApiEndpointSpec {
    pub name: String,
    /// Hard concurrent-request limit enforced by the provider.
    pub max_concurrency: u32,
    /// Quota: max requests per window.
    pub quota: u32,
    pub quota_window: SimDur,
    /// Log-normal latency parameters (underlying μ, σ) in seconds.
    pub lat_mu: f64,
    pub lat_sigma: f64,
    /// Client-side timeout.
    pub timeout: SimDur,
    /// Base transient-failure probability at healthy load.
    pub base_failure: f64,
}

impl ApiEndpointSpec {
    pub fn search(name: &str) -> Self {
        ApiEndpointSpec {
            name: name.into(),
            max_concurrency: 64,
            quota: 600,
            quota_window: SimDur::from_secs(60),
            lat_mu: -0.7, // median ~0.5s
            lat_sigma: 0.6,
            timeout: SimDur::from_secs(30),
            base_failure: 0.01,
        }
    }

    pub fn pdf_parse(name: &str) -> Self {
        ApiEndpointSpec {
            name: name.into(),
            max_concurrency: 24,
            quota: 240,
            quota_window: SimDur::from_secs(60),
            lat_mu: 1.0, // median ~2.7s
            lat_sigma: 0.8,
            timeout: SimDur::from_secs(120),
            base_failure: 0.03,
        }
    }
}

/// Live endpoint state. The provider enforces its limits regardless of what
/// the client does — the difference between baseline and ARL-Tangram is
/// *whether the client stays inside them*.
#[derive(Debug)]
pub struct ApiEndpoint {
    pub spec: ApiEndpointSpec,
    /// spec limits at construction (baseline for `scale_limits`)
    base_concurrency: u32,
    base_quota: u32,
    in_flight: u32,
    window_start: SimTime,
    window_used: u32,
    rng: Rng,
    // counters for reporting
    pub n_ok: u64,
    pub n_rate_limited: u64,
    pub n_timeout: u64,
    pub n_error: u64,
}

impl ApiEndpoint {
    pub fn new(spec: ApiEndpointSpec, seed: u64) -> Self {
        ApiEndpoint {
            base_concurrency: spec.max_concurrency,
            base_quota: spec.quota,
            spec,
            in_flight: 0,
            window_start: SimTime::ZERO,
            window_used: 0,
            rng: Rng::new(seed),
            n_ok: 0,
            n_rate_limited: 0,
            n_timeout: 0,
            n_error: 0,
        }
    }

    pub fn in_flight(&self) -> u32 {
        self.in_flight
    }

    /// Concurrency limit at construction — the static-provision baseline
    /// `scale_limits` factors apply to (resource-hour accounting reference).
    pub fn base_concurrency(&self) -> u32 {
        self.base_concurrency
    }

    /// Provider-side limit change (scenario rate-limit flap): scale the
    /// concurrency and window-quota limits to `factor` × their construction
    /// baseline (floor 1 so the endpoint stays reachable). Requests already
    /// in flight keep running; new admissions see the new limits.
    pub fn scale_limits(&mut self, factor: f64) {
        let f = factor.max(0.0);
        self.spec.max_concurrency =
            ((self.base_concurrency as f64 * f).round() as u32).max(1);
        self.spec.quota = ((self.base_quota as f64 * f).round() as u32).max(1);
    }

    /// Remaining quota in the current window as of `now`.
    pub fn quota_left(&self, now: SimTime) -> u32 {
        if now - self.window_start >= self.spec.quota_window {
            self.spec.quota
        } else {
            self.spec.quota.saturating_sub(self.window_used)
        }
    }

    fn roll_window(&mut self, now: SimTime) {
        if now - self.window_start >= self.spec.quota_window {
            // advance the window origin to the current aligned boundary
            let w = self.spec.quota_window.0;
            let aligned = SimTime((now.0 / w) * w);
            self.window_start = aligned;
            self.window_used = 0;
        }
    }

    /// Issue a request at `now`. Returns the outcome and the duration after
    /// which it resolves (latency for Ok/ServerError, the timeout for
    /// Timeout, ~0 for RateLimited). Caller must later call [`finish`].
    pub fn issue(&mut self, now: SimTime) -> (ApiOutcome, SimDur) {
        self.roll_window(now);
        if self.window_used >= self.spec.quota || self.in_flight >= self.spec.max_concurrency {
            self.n_rate_limited += 1;
            return (ApiOutcome::RateLimited, SimDur::from_millis(50));
        }
        self.window_used += 1;
        self.in_flight += 1;

        // load-dependent latency inflation: near the concurrency limit the
        // provider queues internally
        let load = self.in_flight as f64 / self.spec.max_concurrency as f64;
        let inflate = 1.0 + 2.0 * load * load;
        let lat = SimDur::from_secs_f64(
            self.rng.lognormal(self.spec.lat_mu, self.spec.lat_sigma) * inflate,
        );

        // failure probability grows with load
        let p_fail = (self.spec.base_failure * (1.0 + 4.0 * load)).min(0.5);
        if self.rng.chance(p_fail) {
            self.n_error += 1;
            return (ApiOutcome::ServerError, lat.mul_f64(0.3));
        }
        if lat > self.spec.timeout {
            self.n_timeout += 1;
            return (ApiOutcome::Timeout, self.spec.timeout);
        }
        self.n_ok += 1;
        (ApiOutcome::Ok, lat)
    }

    /// Mark a previously-issued request as resolved (frees a slot).
    pub fn finish(&mut self, outcome: ApiOutcome) {
        if outcome != ApiOutcome::RateLimited {
            debug_assert!(self.in_flight > 0);
            self.in_flight = self.in_flight.saturating_sub(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ep() -> ApiEndpoint {
        ApiEndpoint::new(
            ApiEndpointSpec {
                name: "t".into(),
                max_concurrency: 2,
                quota: 3,
                quota_window: SimDur::from_secs(60),
                lat_mu: -1.0,
                lat_sigma: 0.1,
                timeout: SimDur::from_secs(10),
                base_failure: 0.0,
            },
            1,
        )
    }

    #[test]
    fn concurrency_limit_enforced() {
        let mut e = ep();
        let (o1, _) = e.issue(SimTime::ZERO);
        let (o2, _) = e.issue(SimTime::ZERO);
        assert_eq!(o1, ApiOutcome::Ok);
        assert_eq!(o2, ApiOutcome::Ok);
        let (o3, _) = e.issue(SimTime::ZERO);
        assert_eq!(o3, ApiOutcome::RateLimited);
        e.finish(o1);
        assert_eq!(e.in_flight(), 1);
    }

    #[test]
    fn quota_window_rolls() {
        let mut e = ep();
        for _ in 0..2 {
            let (o, _) = e.issue(SimTime::ZERO);
            e.finish(o);
        }
        let (o, _) = e.issue(SimTime::ZERO);
        e.finish(o);
        // quota (3) exhausted
        let (o, _) = e.issue(SimTime(1));
        assert_eq!(o, ApiOutcome::RateLimited);
        assert_eq!(e.quota_left(SimTime(1)), 0);
        // next window
        let t = SimTime::ZERO + SimDur::from_secs(61);
        assert_eq!(e.quota_left(t), 3);
        let (o, _) = e.issue(t);
        assert_eq!(o, ApiOutcome::Ok);
    }

    #[test]
    fn latency_positive_and_bounded_by_timeout() {
        let mut e = ep();
        for i in 0..50 {
            let (o, d) = e.issue(SimTime(i * 1_000_000_000 * 61));
            assert!(d.0 > 0);
            if o == ApiOutcome::Ok {
                assert!(d <= e.spec.timeout);
            }
            e.finish(o);
        }
    }

    #[test]
    fn scale_limits_flaps_and_restores() {
        let mut e = ep(); // concurrency 2, quota 3
        e.scale_limits(0.5);
        assert_eq!(e.spec.max_concurrency, 1);
        assert_eq!(e.spec.quota, 2);
        let (o1, _) = e.issue(SimTime::ZERO);
        assert_eq!(o1, ApiOutcome::Ok);
        let (o2, _) = e.issue(SimTime::ZERO);
        assert_eq!(o2, ApiOutcome::RateLimited, "flapped concurrency must bite");
        // restore returns to the construction baseline, not a compounded value
        e.scale_limits(1.0);
        assert_eq!(e.spec.max_concurrency, 2);
        assert_eq!(e.spec.quota, 3);
        // floor at 1 even for extreme factors
        e.scale_limits(0.0001);
        assert_eq!(e.spec.max_concurrency, 1);
        assert_eq!(e.spec.quota, 1);
    }

    #[test]
    fn overload_raises_failures() {
        let mut spec = ApiEndpointSpec::search("s");
        spec.base_failure = 0.05;
        spec.quota = 1_000_000;
        let mut e = ApiEndpoint::new(spec, 7);
        // saturate concurrency
        let mut outs = vec![];
        for _ in 0..64 {
            outs.push(e.issue(SimTime::ZERO).0);
        }
        let fails_hot = e.n_error + e.n_timeout;
        assert!(e.in_flight() > 0);
        // at load ~1 the failure prob is ~5×base — expect some failures
        // (deterministic given the seed; sanity-check the counters add up)
        let total = e.n_ok + e.n_rate_limited + e.n_timeout + e.n_error;
        assert_eq!(total, 64);
        let _ = fails_hot;
    }
}
