//! Simulated CPU cluster substrate (paper testbed: 15 nodes × 256 AMD cores
//! × 2.4 TB). State machine mirrors what the AOE manager manipulates in
//! production: per-container cgroup core sets updated through the Docker
//! API, core exclusivity, NUMA domains, and node-level memory reservation
//! for long-lived environments.

use crate::action::TrajId;
use crate::sim::SimDur;
use std::collections::HashMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CoreId {
    pub node: NodeId,
    pub idx: u32,
}

/// Latency model of the container runtime operations AOE performs.
#[derive(Debug, Clone)]
pub struct CpuLatency {
    /// `docker update` of the cgroup (cpuset/cpulimit) before exec.
    pub cgroup_update: SimDur,
    /// `docker exec` fork under the updated cgroup.
    pub exec_fork: SimDur,
    /// Container creation (first action of a trajectory).
    pub container_create: SimDur,
}

impl Default for CpuLatency {
    fn default() -> Self {
        CpuLatency {
            cgroup_update: SimDur::from_millis(3),
            exec_fork: SimDur::from_millis(2),
            container_create: SimDur::from_millis(400),
        }
    }
}

/// A long-lived per-trajectory container. Memory stays reserved for the
/// container's lifetime (paper §5.2: "the memory allocated to each container
/// is preserved"); cores come and go per action under AOE.
#[derive(Debug, Clone)]
pub struct Container {
    pub trajectory: TrajId,
    pub mem_gb: u64,
    /// cores currently in the cgroup (empty between actions — that is the
    /// whole point of allocate-on-execution)
    pub cgroup_cores: Vec<CoreId>,
}

/// One CPU node: cores grouped into NUMA domains + a memory pool.
#[derive(Debug)]
pub struct CpuNode {
    pub id: NodeId,
    pub cores_per_numa: u32,
    pub numa_domains: u32,
    pub mem_total_gb: u64,
    pub mem_reserved_gb: u64,
    /// busy flag per core (core idx = numa * cores_per_numa + i)
    busy: Vec<bool>,
    free_count: u32,
    containers: HashMap<TrajId, Container>,
    /// cores taken offline by a scenario pool-resize (held out of the pool)
    cordoned: Vec<CoreId>,
}

impl CpuNode {
    pub fn new(id: NodeId, numa_domains: u32, cores_per_numa: u32, mem_total_gb: u64) -> Self {
        let total = (numa_domains * cores_per_numa) as usize;
        CpuNode {
            id,
            cores_per_numa,
            numa_domains,
            mem_total_gb,
            mem_reserved_gb: 0,
            busy: vec![false; total],
            free_count: total as u32,
            containers: HashMap::new(),
            cordoned: Vec::new(),
        }
    }

    pub fn total_cores(&self) -> u32 {
        self.numa_domains * self.cores_per_numa
    }

    pub fn free_cores(&self) -> u32 {
        self.free_count
    }

    pub fn free_mem_gb(&self) -> u64 {
        self.mem_total_gb - self.mem_reserved_gb
    }

    pub fn has_container(&self, t: TrajId) -> bool {
        self.containers.contains_key(&t)
    }

    pub fn container_count(&self) -> usize {
        self.containers.len()
    }

    /// Create the trajectory's container, reserving its memory for the whole
    /// trajectory lifetime. Fails if memory is insufficient.
    pub fn create_container(&mut self, t: TrajId, mem_gb: u64) -> Result<(), String> {
        if self.containers.contains_key(&t) {
            return Err(format!("container for {t:?} already exists"));
        }
        if self.free_mem_gb() < mem_gb {
            return Err(format!(
                "node {:?}: {} GiB requested, {} free",
                self.id,
                mem_gb,
                self.free_mem_gb()
            ));
        }
        self.mem_reserved_gb += mem_gb;
        self.containers
            .insert(t, Container { trajectory: t, mem_gb, cgroup_cores: vec![] });
        Ok(())
    }

    /// Tear down at trajectory end; releases memory (and any leaked cores).
    pub fn destroy_container(&mut self, t: TrajId) -> Result<(), String> {
        let c = self
            .containers
            .remove(&t)
            .ok_or_else(|| format!("no container for {t:?}"))?;
        self.mem_reserved_gb -= c.mem_gb;
        for core in c.cgroup_cores {
            self.release_core(core);
        }
        Ok(())
    }

    fn release_core(&mut self, core: CoreId) {
        debug_assert_eq!(core.node, self.id);
        let i = core.idx as usize;
        debug_assert!(self.busy[i], "double-free of core {core:?}");
        self.busy[i] = false;
        self.free_count += 1;
    }

    /// Allocate `n` cores, preferring a single NUMA domain (paper §5.2:
    /// inter-core distance hurts parallel efficiency). Returns the chosen
    /// cores or None if not enough are free anywhere.
    pub fn alloc_cores(&mut self, n: u32) -> Option<Vec<CoreId>> {
        if n == 0 {
            return Some(vec![]);
        }
        if self.free_count < n {
            return None;
        }
        // 1. a NUMA domain with ≥ n free cores (fewest-free-first to reduce
        //    fragmentation of emptier domains)
        let mut best: Option<(u32, u32)> = None; // (free_in_domain, domain)
        for d in 0..self.numa_domains {
            let free = self.domain_free(d);
            if free >= n && best.map_or(true, |(bf, _)| free < bf) {
                best = Some((free, d));
            }
        }
        let mut picked = Vec::with_capacity(n as usize);
        if let Some((_, d)) = best {
            let base = d * self.cores_per_numa;
            for i in 0..self.cores_per_numa {
                if picked.len() == n as usize {
                    break;
                }
                let idx = (base + i) as usize;
                if !self.busy[idx] {
                    picked.push(idx);
                }
            }
        } else {
            // 2. spill across domains, densest domains first
            let mut domains: Vec<u32> = (0..self.numa_domains).collect();
            domains.sort_by_key(|&d| std::cmp::Reverse(self.domain_free(d)));
            'outer: for d in domains {
                let base = d * self.cores_per_numa;
                for i in 0..self.cores_per_numa {
                    if picked.len() == n as usize {
                        break 'outer;
                    }
                    let idx = (base + i) as usize;
                    if !self.busy[idx] {
                        picked.push(idx);
                    }
                }
            }
        }
        debug_assert_eq!(picked.len(), n as usize);
        let cores: Vec<CoreId> = picked
            .into_iter()
            .map(|idx| {
                self.busy[idx] = true;
                CoreId { node: self.id, idx: idx as u32 }
            })
            .collect();
        self.free_count -= n;
        Some(cores)
    }

    /// AOE step 1: put `cores` into the container's cgroup.
    pub fn cgroup_assign(&mut self, t: TrajId, cores: Vec<CoreId>) -> Result<(), String> {
        let c = self
            .containers
            .get_mut(&t)
            .ok_or_else(|| format!("no container for {t:?}"))?;
        debug_assert!(c.cgroup_cores.is_empty(), "cgroup already populated");
        c.cgroup_cores = cores;
        Ok(())
    }

    /// AOE step 3: process exited — reclaim the cgroup's cores.
    pub fn cgroup_reclaim(&mut self, t: TrajId) -> Result<Vec<CoreId>, String> {
        let cores = {
            let c = self
                .containers
                .get_mut(&t)
                .ok_or_else(|| format!("no container for {t:?}"))?;
            std::mem::take(&mut c.cgroup_cores)
        };
        for &core in &cores {
            self.release_core(core);
        }
        Ok(cores)
    }

    /// Scenario pool-resize: grow or shrink the set of cordoned (offline)
    /// cores toward `target`. Shrinking releases cores back to the pool;
    /// growing is best-effort — only currently-free cores can be taken
    /// (busy cores are never preempted). Returns the cordon size reached.
    pub fn set_cordon(&mut self, target: u32) -> u32 {
        while self.cordoned.len() as u32 > target {
            let c = self.cordoned.pop().expect("cordon list non-empty");
            self.release_core(c);
        }
        if (self.cordoned.len() as u32) < target {
            let want = target - self.cordoned.len() as u32;
            let take = want.min(self.free_count);
            if take > 0 {
                let cores = self
                    .alloc_cores(take)
                    .expect("free_count-bounded cordon allocation");
                self.cordoned.extend(cores);
            }
        }
        self.cordoned.len() as u32
    }

    pub fn cordoned_cores(&self) -> u32 {
        self.cordoned.len() as u32
    }

    fn domain_free(&self, d: u32) -> u32 {
        let base = (d * self.cores_per_numa) as usize;
        (0..self.cores_per_numa as usize)
            .filter(|&i| !self.busy[base + i])
            .count() as u32
    }

    /// How many of the picked cores sit in one NUMA domain (test/metric aid).
    pub fn numa_spread(&self, cores: &[CoreId]) -> usize {
        let mut domains: Vec<u32> = cores
            .iter()
            .map(|c| c.idx / self.cores_per_numa)
            .collect();
        domains.sort_unstable();
        domains.dedup();
        domains.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node() -> CpuNode {
        CpuNode::new(NodeId(0), 2, 8, 64) // 16 cores, 2 NUMA, 64 GiB
    }

    #[test]
    fn container_memory_accounting() {
        let mut n = node();
        n.create_container(TrajId(1), 40).unwrap();
        assert_eq!(n.free_mem_gb(), 24);
        assert!(n.create_container(TrajId(2), 30).is_err());
        n.create_container(TrajId(2), 24).unwrap();
        assert_eq!(n.free_mem_gb(), 0);
        n.destroy_container(TrajId(1)).unwrap();
        assert_eq!(n.free_mem_gb(), 40);
        assert!(n.destroy_container(TrajId(1)).is_err());
    }

    #[test]
    fn duplicate_container_rejected() {
        let mut n = node();
        n.create_container(TrajId(1), 1).unwrap();
        assert!(n.create_container(TrajId(1), 1).is_err());
    }

    #[test]
    fn cores_prefer_single_numa() {
        let mut n = node();
        let cores = n.alloc_cores(8).unwrap();
        assert_eq!(cores.len(), 8);
        assert_eq!(n.numa_spread(&cores), 1, "should fit one domain");
        assert_eq!(n.free_cores(), 8);
    }

    #[test]
    fn cores_spill_when_fragmented() {
        let mut n = node();
        let _held = n.alloc_cores(4).unwrap(); // domain 0 now has 4 free
        let wide = n.alloc_cores(10).unwrap(); // needs both domains
        assert_eq!(wide.len(), 10);
        assert_eq!(n.numa_spread(&wide), 2);
        assert_eq!(n.free_cores(), 2);
    }

    #[test]
    fn alloc_fails_when_exhausted() {
        let mut n = node();
        assert!(n.alloc_cores(17).is_none());
        let _all = n.alloc_cores(16).unwrap();
        assert!(n.alloc_cores(1).is_none());
        assert_eq!(n.free_cores(), 0);
    }

    #[test]
    fn aoe_cycle_assign_reclaim() {
        let mut n = node();
        n.create_container(TrajId(7), 4).unwrap();
        let cores = n.alloc_cores(4).unwrap();
        n.cgroup_assign(TrajId(7), cores).unwrap();
        assert_eq!(n.free_cores(), 12);
        let reclaimed = n.cgroup_reclaim(TrajId(7)).unwrap();
        assert_eq!(reclaimed.len(), 4);
        assert_eq!(n.free_cores(), 16);
        // between actions the container holds no cores — Breakdown achieved
        assert!(n.containers[&TrajId(7)].cgroup_cores.is_empty());
    }

    #[test]
    fn destroy_reclaims_leaked_cores() {
        let mut n = node();
        n.create_container(TrajId(9), 4).unwrap();
        let cores = n.alloc_cores(6).unwrap();
        n.cgroup_assign(TrajId(9), cores).unwrap();
        n.destroy_container(TrajId(9)).unwrap();
        assert_eq!(n.free_cores(), 16);
    }

    #[test]
    fn cordon_shrinks_and_restores_the_pool() {
        let mut n = node(); // 16 cores
        assert_eq!(n.set_cordon(8), 8);
        assert_eq!(n.free_cores(), 8);
        assert_eq!(n.cordoned_cores(), 8);
        // allocations respect the shrunken pool
        assert!(n.alloc_cores(9).is_none());
        let _held = n.alloc_cores(6).unwrap();
        // best-effort growth: only 2 cores are still free
        assert_eq!(n.set_cordon(12), 10);
        assert_eq!(n.free_cores(), 0);
        // restore everything (the 6 busy cores stay allocated)
        assert_eq!(n.set_cordon(0), 0);
        assert_eq!(n.free_cores(), 10);
    }

    #[test]
    fn fewest_free_domain_chosen_first() {
        let mut n = node();
        let a = n.alloc_cores(6).unwrap(); // domain X: 2 free
        assert_eq!(n.numa_spread(&a), 1);
        // a 2-core request should pack into the 2-free domain, not break
        // open the untouched one
        let b = n.alloc_cores(2).unwrap();
        assert_eq!(
            b[0].idx / n.cores_per_numa,
            a[0].idx / n.cores_per_numa,
            "should pack into the partially-used domain"
        );
    }
}
