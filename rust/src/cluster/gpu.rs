//! Simulated GPU cluster substrate (paper testbed: 5 nodes × 8 GPUs × 3 TB
//! host memory). Implements the multi-level cell/chunk structure of §5.3:
//! buddy-style chunks of sizes {1,2,4,8}, service residency cache with
//! invariant host-memory copies, LRU eviction, and a restore-cost model.

use crate::action::ServiceId;
use crate::sim::{SimDur, SimTime};
use std::collections::HashMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GpuNodeId(pub u32);

/// A legal chunk: contiguous GPU interval `[start, start + 2^level)` with
/// `start` aligned to `2^level` (paper Eq. in §5.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ChunkRef {
    pub node: GpuNodeId,
    pub start: u8,
    pub level: u8,
}

impl ChunkRef {
    pub fn size(&self) -> u8 {
        1 << self.level
    }

    pub fn buddy(&self) -> ChunkRef {
        ChunkRef { node: self.node, start: self.start ^ self.size(), ..*self }
    }

    pub fn parent(&self) -> ChunkRef {
        ChunkRef {
            node: self.node,
            start: self.start & !(self.size() * 2 - 1),
            level: self.level + 1,
        }
    }

    pub fn is_legal(&self) -> bool {
        self.level <= 3 && self.start % self.size() == 0 && self.start + self.size() <= 8
    }
}

/// Cache tag on a free chunk: which service variant is resident in its GPUs'
/// memory, and when it was last used (for LRU).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheTag {
    pub service: ServiceId,
    pub dop: u8,
    pub last_used: SimTime,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChunkState {
    Free,
    Allocated,
    Split,
}

/// One 8-GPU node as a buddy tree over chunks. There are 15 possible chunks
/// per node (8+4+2+1), indexed by (level, start).
#[derive(Debug)]
pub struct GpuNode {
    pub id: GpuNodeId,
    state: HashMap<(u8, u8), ChunkState>, // (level, start>>level? no: start)
    cache: HashMap<(u8, u8), CacheTag>,
    /// Node taken offline by an elastic pool resize. A cordoned node takes
    /// no new allocations; busy chunks are never preempted and drain out
    /// normally. Cordoning flushes the residency cache (a deprovisioned
    /// node loses its GPU memory contents — the invariant host copies
    /// survive), so restores after an un-cordon flow through the ordinary
    /// EOE cache-miss path.
    cordoned: bool,
}

impl GpuNode {
    pub fn new(id: GpuNodeId) -> Self {
        let mut state = HashMap::new();
        // root chunk free, everything else nonexistent until split
        state.insert((3u8, 0u8), ChunkState::Free);
        GpuNode { id, state, cache: HashMap::new(), cordoned: false }
    }

    pub fn is_cordoned(&self) -> bool {
        self.cordoned
    }

    fn set_cordoned(&mut self, cordoned: bool) {
        if cordoned && !self.cordoned {
            // powering the node down drops every warm residency
            self.flush_cache();
        }
        self.cordoned = cordoned;
    }

    /// GPUs currently held by allocated chunks (every GPU sits in exactly
    /// one Free or Allocated leaf chunk, so busy = 8 − free).
    pub fn busy_gpus(&self) -> u32 {
        8 - self.free_gpus()
    }

    /// Most recent `last_used` over the node's cache tags — the coldest-
    /// first cordon ordering key ([`SimTime::ZERO`] when nothing is
    /// resident). A max over an unordered map is order-independent, so
    /// this stays deterministic.
    pub fn cache_hotness(&self) -> SimTime {
        self.cache
            .values()
            .map(|t| t.last_used)
            .max()
            .unwrap_or(SimTime::ZERO)
    }

    fn key(c: &ChunkRef) -> (u8, u8) {
        (c.level, c.start)
    }

    pub fn chunk_state(&self, c: &ChunkRef) -> Option<ChunkState> {
        self.state.get(&Self::key(c)).copied()
    }

    /// All currently-free chunks.
    pub fn free_chunks(&self) -> Vec<ChunkRef> {
        let mut v: Vec<ChunkRef> = self
            .state
            .iter()
            .filter(|(_, &s)| s == ChunkState::Free)
            .map(|(&(level, start), _)| ChunkRef { node: self.id, start, level })
            .collect();
        v.sort();
        v
    }

    pub fn cache_tag(&self, c: &ChunkRef) -> Option<CacheTag> {
        self.cache.get(&Self::key(c)).copied()
    }

    /// Drop every cached service residency (scenario restore-storm: models
    /// a node-level fault that loses GPU memory contents — the invariant
    /// host copies survive, so subsequent allocations restore cold).
    pub fn flush_cache(&mut self) {
        self.cache.clear();
    }

    pub fn free_gpus(&self) -> u32 {
        self.free_chunks().iter().map(|c| c.size() as u32).sum()
    }

    /// Split a free chunk one level down, producing two free children.
    /// Children inherit no cache (their memory layout halves differ from the
    /// parent-resident service) — the parent's cache is dropped.
    fn split(&mut self, c: ChunkRef) -> (ChunkRef, ChunkRef) {
        debug_assert_eq!(self.chunk_state(&c), Some(ChunkState::Free));
        debug_assert!(c.level > 0);
        self.state.insert(Self::key(&c), ChunkState::Split);
        self.cache.remove(&Self::key(&c));
        let l = ChunkRef { node: self.id, start: c.start, level: c.level - 1 };
        let r = ChunkRef { node: self.id, start: c.start + c.size() / 2, level: c.level - 1 };
        self.state.insert(Self::key(&l), ChunkState::Free);
        self.state.insert(Self::key(&r), ChunkState::Free);
        (l, r)
    }

    /// Merge two free buddies into their (free) parent, dropping caches.
    fn merge(&mut self, c: ChunkRef) -> ChunkRef {
        let b = c.buddy();
        debug_assert_eq!(self.chunk_state(&c), Some(ChunkState::Free));
        debug_assert_eq!(self.chunk_state(&b), Some(ChunkState::Free));
        self.state.remove(&Self::key(&c));
        self.state.remove(&Self::key(&b));
        self.cache.remove(&Self::key(&c));
        self.cache.remove(&Self::key(&b));
        let p = c.parent();
        self.state.insert(Self::key(&p), ChunkState::Free);
        p
    }

    /// Allocate a free chunk directly (must be Free).
    fn take(&mut self, c: ChunkRef) {
        debug_assert_eq!(self.chunk_state(&c), Some(ChunkState::Free));
        self.state.insert(Self::key(&c), ChunkState::Allocated);
    }

    /// Return an allocated chunk to the free pool, recording what service
    /// its GPUs now hold (stays cached until evicted — EOE). A chunk
    /// draining on a *cordoned* node records no residency — the node is
    /// being deprovisioned, so a later un-cordon must not offer stale warm
    /// hits.
    pub fn release(&mut self, c: ChunkRef, tag: Option<CacheTag>) {
        debug_assert_eq!(self.chunk_state(&c), Some(ChunkState::Allocated), "{c:?}");
        self.state.insert(Self::key(&c), ChunkState::Free);
        let tag = if self.cordoned { None } else { tag };
        match tag {
            Some(t) => {
                self.cache.insert(Self::key(&c), t);
            }
            None => {
                self.cache.remove(&Self::key(&c));
            }
        }
    }

    /// Free chunks at exactly this level.
    fn free_at(&self, level: u8) -> Vec<ChunkRef> {
        self.free_chunks().into_iter().filter(|c| c.level == level).collect()
    }

    /// Try to produce a free chunk of `level` by merging free buddies
    /// (preferring merges that destroy the least-recently-used caches).
    fn merge_up_to(&mut self, level: u8) -> bool {
        for l in 0..level {
            loop {
                let frees = self.free_at(l);
                // find a free buddy pair, preferring oldest caches
                let mut pair: Option<ChunkRef> = None;
                let mut oldest = SimTime(u64::MAX);
                for c in &frees {
                    let b = c.buddy();
                    if c.start < b.start && self.chunk_state(&b) == Some(ChunkState::Free) {
                        let age = [c, &b]
                            .iter()
                            .filter_map(|x| self.cache.get(&Self::key(x)))
                            .map(|t| t.last_used)
                            .max()
                            .unwrap_or(SimTime::ZERO);
                        if age < oldest || pair.is_none() {
                            oldest = age;
                            pair = Some(*c);
                        }
                    }
                }
                match pair {
                    Some(c) => {
                        self.merge(c);
                    }
                    None => break,
                }
                if !self.free_at(level).is_empty() {
                    return true;
                }
            }
        }
        !self.free_at(level).is_empty()
    }
}

/// Allocation outcome: the chunk plus whether the requested service variant
/// was already resident (warm ⇒ no restore overhead).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuAlloc {
    pub chunk: ChunkRef,
    pub warm: bool,
}

/// The whole GPU cluster: nodes + chunk policy (§5.3 "Pool in GPU Manager").
#[derive(Debug)]
pub struct GpuCluster {
    pub nodes: Vec<GpuNode>,
}

impl GpuCluster {
    pub fn new(n_nodes: u32) -> Self {
        GpuCluster {
            nodes: (0..n_nodes).map(|i| GpuNode::new(GpuNodeId(i))).collect(),
        }
    }

    pub fn total_gpus(&self) -> u32 {
        self.nodes.len() as u32 * 8
    }

    /// Schedulable free GPUs (cordoned nodes are offline capacity).
    pub fn free_gpus(&self) -> u32 {
        self.nodes
            .iter()
            .filter(|n| !n.cordoned)
            .map(|n| n.free_gpus())
            .sum()
    }

    /// GPUs currently provisioned (paid for): every GPU of an online node,
    /// plus the still-draining busy GPUs of cordoned nodes — busy chunks
    /// are never preempted, and capacity that is still running is still
    /// billed.
    pub fn provisioned_gpus(&self) -> u32 {
        self.nodes
            .iter()
            .map(|n| if n.cordoned { n.busy_gpus() } else { 8 })
            .sum()
    }

    /// Nodes currently cordoned by an elastic resize.
    pub fn cordoned_nodes(&self) -> u32 {
        self.nodes.iter().filter(|n| n.cordoned).count() as u32
    }

    /// Count of free chunks per level across the cluster (DP-operator seed).
    /// Cordoned nodes contribute nothing — their chunks are off-limits.
    pub fn free_chunk_counts(&self) -> [u32; 4] {
        let mut c = [0u32; 4];
        for n in self.nodes.iter().filter(|n| !n.cordoned) {
            for ch in n.free_chunks() {
                c[ch.level as usize] += 1;
            }
        }
        c
    }

    /// Elastic pool resize (`PoolClass::Gpu`): keep `available_frac` of the
    /// nodes online, cordoning whole nodes. Determinism invariant — the
    /// cordon rank is **already-cordoned nodes first** (cordons are sticky:
    /// re-applying an unchanged composed factor must not migrate the cordon
    /// onto a node that warmed up in the meantime and flush its cache),
    /// then **idle nodes before busy ones** (busy chunks are never
    /// preempted; a cordoned busy node merely drains), then **coldest EOE
    /// residency first** (a node whose free chunks carry recently-used
    /// service caches is evicted last), ties broken by higher node id (low
    /// ids stay online). At least one node stays online so minimum-DoP
    /// actions keep making progress. `1.0` restores every node — with
    /// flushed caches, so the re-warm cost of restored capacity flows
    /// through the ordinary cache-miss restore path. Returns the number of
    /// cordoned nodes reached.
    pub fn set_pool_scale(&mut self, available_frac: f64) -> u32 {
        let f = available_frac.clamp(0.0, 1.0);
        let n = self.nodes.len() as u32;
        let target_online = ((n as f64 * f).round() as u32).clamp(1, n);
        let target_cordoned = n - target_online;
        let mut order: Vec<(bool, bool, SimTime, std::cmp::Reverse<u32>, usize)> = self
            .nodes
            .iter()
            .enumerate()
            .map(|(i, nd)| {
                (
                    !nd.cordoned,
                    nd.busy_gpus() > 0,
                    nd.cache_hotness(),
                    std::cmp::Reverse(nd.id.0),
                    i,
                )
            })
            .collect();
        order.sort();
        for (rank, &(_, _, _, _, i)) in order.iter().enumerate() {
            self.nodes[i].set_cordoned((rank as u32) < target_cordoned);
        }
        target_cordoned
    }

    fn level_for(dop: u8) -> u8 {
        match dop {
            1 => 0,
            2 => 1,
            3..=4 => 2,
            _ => 3,
        }
    }

    /// Allocate a chunk for a DoP-`dop` instance of `service`.
    ///
    /// Policy (§5.3): (1) among free chunks of the exact level, prefer one
    /// already caching this (service, dop) — warm start; (2) otherwise the
    /// smallest sufficient free chunk, preferring un-cached chunks, then the
    /// LRU cache (reduces service-cache dithering); (3) split larger chunks
    /// as needed; (4) merge free buddies as a last resort.
    pub fn allocate(&mut self, service: ServiceId, dop: u8) -> Option<GpuAlloc> {
        debug_assert!((1..=8).contains(&dop));
        let level = Self::level_for(dop);

        // (1) warm chunk at the exact level (cordoned nodes are offline)
        let mut warm_hit: Option<ChunkRef> = None;
        for n in self.nodes.iter().filter(|n| !n.cordoned) {
            for c in n.free_at(level) {
                if let Some(t) = n.cache_tag(&c) {
                    if t.service == service && t.dop == dop {
                        warm_hit = Some(c);
                        break;
                    }
                }
            }
            if warm_hit.is_some() {
                break;
            }
        }
        if let Some(c) = warm_hit {
            self.node_mut(c.node).take(c);
            return Some(GpuAlloc { chunk: c, warm: true });
        }

        // (2) smallest sufficient free chunk; prefer uncached, then LRU
        let mut best: Option<(ChunkRef, u8, bool, SimTime)> = None;
        for n in self.nodes.iter().filter(|n| !n.cordoned) {
            for c in n.free_chunks() {
                if c.level < level {
                    continue;
                }
                let tag = n.cache_tag(&c);
                let cached = tag.is_some();
                let lru = tag.map(|t| t.last_used).unwrap_or(SimTime::ZERO);
                let cand = (c, c.level, cached, lru);
                best = Some(match best {
                    None => cand,
                    Some(b) => {
                        // smaller level first; then uncached before cached;
                        // then older cache first
                        let better = (cand.1, cand.2, cand.3) < (b.1, b.2, b.3);
                        if better {
                            cand
                        } else {
                            b
                        }
                    }
                });
            }
        }

        let chosen = match best {
            Some((c, ..)) => c,
            None => {
                // (4) merge free buddies somewhere to manufacture a chunk
                let nid = (0..self.nodes.len())
                    .find(|&i| !self.nodes[i].cordoned && self.nodes[i].merge_up_to(level))?;
                self.nodes[nid].free_at(level).first().copied()?
            }
        };

        // (3) split down to the exact level
        let mut c = chosen;
        {
            let node = self.node_mut(c.node);
            while c.level > level {
                let (l, _r) = node.split(c);
                c = l;
            }
            node.take(c);
        }
        Some(GpuAlloc { chunk: c, warm: false })
    }

    /// Release a chunk, caching the service that now resides on it.
    pub fn release(&mut self, chunk: ChunkRef, service: ServiceId, dop: u8, now: SimTime) {
        self.node_mut(chunk.node)
            .release(chunk, Some(CacheTag { service, dop, last_used: now }));
    }

    /// Feasibility probe for the scheduler's `accommodate`: can chunks for
    /// all these DoPs be carved out simultaneously (with splitting and
    /// merging)? Pure — operates on chunk counts, over-approximating merges
    /// per node only when buddies are actually free.
    pub fn can_accommodate(&self, dops: &[u64]) -> bool {
        // conservative simulation on cloned per-node free lists (cordoned
        // nodes offer no capacity)
        let mut per_node: Vec<Vec<u8>> = self
            .nodes
            .iter()
            .filter(|n| !n.cordoned)
            .map(|n| n.free_chunks().iter().map(|c| c.level).collect())
            .collect();
        let mut reqs: Vec<u8> = dops.iter().map(|&d| Self::level_for(d as u8)).collect();
        reqs.sort_unstable_by(|a, b| b.cmp(a)); // biggest first
        'req: for lv in reqs {
            for levels in per_node.iter_mut() {
                // exact or larger chunk available?
                if let Some(pos) = levels
                    .iter()
                    .enumerate()
                    .filter(|(_, &l)| l >= lv)
                    .min_by_key(|(_, &l)| l)
                    .map(|(i, _)| i)
                {
                    let have = levels.remove(pos);
                    // splitting leaves one free chunk at each level below
                    for l in lv..have {
                        levels.push(l);
                    }
                    continue 'req;
                }
            }
            // try merging within a node: total free GPUs in chunks < lv that
            // are mergeable is over-approximated by count-based packing; be
            // conservative and fail (real merges happen in allocate()).
            return false;
        }
        true
    }

    /// Drop all service caches cluster-wide (see [`GpuNode::flush_cache`]).
    pub fn flush_caches(&mut self) {
        for n in &mut self.nodes {
            n.flush_cache();
        }
    }

    pub fn node_mut(&mut self, id: GpuNodeId) -> &mut GpuNode {
        &mut self.nodes[id.0 as usize]
    }

    pub fn node(&self, id: GpuNodeId) -> &GpuNode {
        &self.nodes[id.0 as usize]
    }
}

/// Restore-cost model (§5.3 Breakdown): weights stream from the invariant
/// host-memory copy over PCIe; eviction is free (memory states unchanged
/// across invocations — only the GPU copy is dropped).
#[derive(Debug, Clone)]
pub struct RestoreModel {
    /// Host→device bandwidth per GPU, GiB/s (PCIe 4.0 ≈ 24).
    pub pcie_gbps: f64,
    /// Fixed per-restore overhead (cuda graphs, allocator warmup).
    pub fixed: SimDur,
}

impl Default for RestoreModel {
    fn default() -> Self {
        // Effective H2D restore bandwidth per GPU. Modern nodes overlap
        // PCIe/NVLink transfers with allocator setup; prior work the paper
        // cites (BlitzScale, Aegaeon) shows restore cost "effectively
        // reduced" — this models that optimized path.
        RestoreModel { pcie_gbps: 48.0, fixed: SimDur::from_millis(300) }
    }
}

impl RestoreModel {
    /// Restoring a `weights_gb` service sharded over `dop` GPUs moves
    /// `weights_gb / dop` per GPU in parallel.
    pub fn restore_dur(&self, weights_gb: f64, dop: u8) -> SimDur {
        let per_gpu = weights_gb / dop.max(1) as f64;
        self.fixed + SimDur::from_secs_f64(per_gpu / self.pcie_gbps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn svc(i: u32) -> ServiceId {
        ServiceId(i)
    }

    #[test]
    fn chunk_geometry() {
        let c = ChunkRef { node: GpuNodeId(0), start: 4, level: 2 };
        assert_eq!(c.size(), 4);
        assert_eq!(c.buddy().start, 0);
        assert_eq!(c.parent(), ChunkRef { node: GpuNodeId(0), start: 0, level: 3 });
        assert!(c.is_legal());
        assert!(!ChunkRef { node: GpuNodeId(0), start: 2, level: 2 }.is_legal());
        assert!(!ChunkRef { node: GpuNodeId(0), start: 6, level: 2 }.is_legal());
    }

    #[test]
    fn allocate_whole_node() {
        let mut g = GpuCluster::new(1);
        let a = g.allocate(svc(0), 8).unwrap();
        assert_eq!(a.chunk.size(), 8);
        assert!(!a.warm);
        assert_eq!(g.free_gpus(), 0);
        assert!(g.allocate(svc(1), 1).is_none());
    }

    #[test]
    fn allocate_splits_and_releases_cache() {
        let mut g = GpuCluster::new(1);
        let a = g.allocate(svc(0), 2).unwrap();
        assert_eq!(a.chunk.size(), 2);
        assert_eq!(g.free_gpus(), 6); // 2 + 4 free
        g.release(a.chunk, svc(0), 2, SimTime(100));
        assert_eq!(g.free_gpus(), 8);
        // warm re-allocation of the same variant hits the cached chunk
        let b = g.allocate(svc(0), 2).unwrap();
        assert!(b.warm);
        assert_eq!(b.chunk, a.chunk);
    }

    #[test]
    fn different_dop_is_a_cold_start() {
        // EOE treats (service, dop) as distinct variants
        let mut g = GpuCluster::new(1);
        let a = g.allocate(svc(0), 2).unwrap();
        g.release(a.chunk, svc(0), 2, SimTime(1));
        let b = g.allocate(svc(0), 4).unwrap();
        assert!(!b.warm);
    }

    #[test]
    fn prefers_uncached_chunk_over_evicting() {
        let mut g = GpuCluster::new(1);
        let a = g.allocate(svc(0), 2).unwrap(); // splits: free = [2@cached? no]
        g.release(a.chunk, svc(0), 2, SimTime(5));
        // free chunks now: 2 (cached svc0), 2 (uncached), 4 (uncached)
        let b = g.allocate(svc(1), 2).unwrap();
        assert!(!b.warm);
        assert_ne!(b.chunk, a.chunk, "should not evict svc0's cache");
        // svc0 can still warm-start
        let c = g.allocate(svc(0), 2).unwrap();
        assert!(c.warm);
    }

    #[test]
    fn lru_eviction_order() {
        let mut g = GpuCluster::new(1);
        // fill the node with 4 cached 2-chunks from different services
        let mut chunks = vec![];
        for i in 0..4 {
            chunks.push(g.allocate(svc(i), 2).unwrap().chunk);
        }
        for (i, c) in chunks.iter().enumerate() {
            g.release(*c, svc(i as u32), 2, SimTime(10 + i as u64));
        }
        // allocating for a new service must evict the oldest cache (svc0)
        let a = g.allocate(svc(9), 2).unwrap();
        assert_eq!(a.chunk, chunks[0], "LRU chunk should be chosen");
    }

    #[test]
    fn merge_manufactures_bigger_chunks() {
        let mut g = GpuCluster::new(1);
        // fragment the node into four 2-chunks, release all
        let chunks: Vec<_> = (0..4).map(|i| g.allocate(svc(i), 2).unwrap().chunk).collect();
        for (i, c) in chunks.iter().enumerate() {
            g.release(*c, svc(i as u32), 2, SimTime(i as u64));
        }
        assert_eq!(g.free_chunk_counts(), [0, 4, 0, 0]);
        // a DoP-8 request forces merges back to the root chunk
        let a = g.allocate(svc(8), 8).unwrap();
        assert_eq!(a.chunk.size(), 8);
        assert!(!a.warm);
    }

    #[test]
    fn accommodate_respects_topology() {
        let mut g = GpuCluster::new(1);
        assert!(g.can_accommodate(&[4, 2, 1, 1]));
        assert!(g.can_accommodate(&[8]));
        assert!(!g.can_accommodate(&[8, 1]));
        let _a = g.allocate(svc(0), 4).unwrap();
        assert!(g.can_accommodate(&[4]));
        assert!(g.can_accommodate(&[2, 2]));
        assert!(!g.can_accommodate(&[4, 1]));
    }

    #[test]
    fn multi_node_spreads() {
        let mut g = GpuCluster::new(2);
        let a = g.allocate(svc(0), 8).unwrap();
        let b = g.allocate(svc(1), 8).unwrap();
        assert_ne!(a.chunk.node, b.chunk.node);
        assert!(g.allocate(svc(2), 1).is_none());
        assert!(g.can_accommodate(&[]));
    }

    #[test]
    fn restore_model_scales_with_dop() {
        let m = RestoreModel { pcie_gbps: 10.0, fixed: SimDur::ZERO };
        assert_eq!(m.restore_dur(40.0, 1), SimDur::from_secs(4));
        assert_eq!(m.restore_dur(40.0, 4), SimDur::from_secs(1));
    }

    #[test]
    fn cordon_takes_coldest_node_first() {
        let mut g = GpuCluster::new(2);
        // warm node 0's cache recently; node 1 stays cold
        let a = g.allocate(svc(0), 8).unwrap();
        let hot_node = a.chunk.node;
        g.release(a.chunk, svc(0), 8, SimTime(1_000));
        let cold_node = GpuNodeId(if hot_node.0 == 0 { 1 } else { 0 });
        assert_eq!(g.set_pool_scale(0.5), 1);
        assert!(g.node(cold_node).is_cordoned(), "cold node must cordon first");
        assert!(!g.node(hot_node).is_cordoned(), "hot residency is evicted last");
        assert_eq!(g.free_gpus(), 8);
        assert_eq!(g.provisioned_gpus(), 8);
        assert_eq!(g.cordoned_nodes(), 1);
        // allocations only land on the online node
        let b = g.allocate(svc(1), 8).unwrap();
        assert_eq!(b.chunk.node, hot_node);
        assert!(g.allocate(svc(2), 1).is_none(), "cordoned capacity is offline");
        assert!(!g.can_accommodate(&[1]));
        g.release(b.chunk, svc(1), 8, SimTime(2_000));
        // restore: the cordoned node returns with a flushed cache, so the
        // re-warm cost flows through the ordinary cache-miss path
        assert_eq!(g.set_pool_scale(1.0), 0);
        assert_eq!(g.free_gpus(), 16);
        assert_eq!(g.provisioned_gpus(), 16);
        assert!(g.node(cold_node).cache_hotness() == SimTime::ZERO);
    }

    #[test]
    fn cordon_prefers_idle_nodes_and_never_preempts_busy_chunks() {
        let mut g = GpuCluster::new(2);
        let a = g.allocate(svc(0), 4).unwrap(); // one node busy
        let busy_node = a.chunk.node;
        assert_eq!(g.set_pool_scale(0.5), 1);
        assert!(
            !g.node(busy_node).is_cordoned(),
            "idle node must cordon before the busy one"
        );
        // squeeze to the floor: one node must stay online even at 0.05
        assert_eq!(g.set_pool_scale(0.05), 1);
        // the busy node's running chunk keeps draining wherever it lives
        assert_eq!(g.node(busy_node).busy_gpus(), 4);
        g.release(a.chunk, svc(0), 4, SimTime(5));
    }

    #[test]
    fn reapplied_scale_keeps_cordons_sticky() {
        let mut g = GpuCluster::new(2);
        let a = g.allocate(svc(0), 8).unwrap();
        let b = g.allocate(svc(1), 8).unwrap();
        assert_eq!(g.set_pool_scale(0.5), 1); // both busy → node 1 cordons
        assert!(g.node(GpuNodeId(1)).is_cordoned());
        // the online node drains and re-caches a hot residency (a is on 0)
        g.release(a.chunk, svc(0), 8, SimTime(1_000));
        // re-applying the same factor must NOT migrate the cordon onto the
        // now-idle hot node 0 (that would flush the hottest cache while
        // bringing the draining node back online)
        assert_eq!(g.set_pool_scale(0.5), 1);
        assert!(g.node(GpuNodeId(1)).is_cordoned(), "cordon must stay sticky");
        assert!(!g.node(GpuNodeId(0)).is_cordoned());
        let warm = g.allocate(svc(0), 8).unwrap();
        assert!(warm.warm, "hot residency must survive the re-apply");
        let _ = b;
    }

    #[test]
    fn cordoned_drain_bills_until_release_and_leaves_no_stale_cache() {
        // both nodes busy → the cordon must take a busy node (never
        // preempting it): new work is refused, the running chunk drains,
        // and its release neither re-caches nor stays on the bill
        let mut g = GpuCluster::new(2);
        let a = g.allocate(svc(0), 8).unwrap();
        let b = g.allocate(svc(1), 8).unwrap();
        assert_eq!(g.set_pool_scale(0.5), 1);
        let cordoned = if g.node(a.chunk.node).is_cordoned() { a } else { b };
        let kept = if cordoned.chunk == a.chunk { b } else { a };
        assert_eq!(g.provisioned_gpus(), 16, "draining GPUs still billed");
        let svc_id = if cordoned.chunk == a.chunk { svc(0) } else { svc(1) };
        g.release(cordoned.chunk, svc_id, 8, SimTime(99));
        assert_eq!(g.provisioned_gpus(), 8, "drained node leaves the bill");
        assert_eq!(g.free_gpus(), 0, "cordoned free capacity is offline");
        g.set_pool_scale(1.0);
        // the drained release on the cordoned node must not have cached
        let again = g.allocate(svc_id, 8).unwrap();
        assert!(!again.warm, "stale residency survived the cordon");
        let _ = kept;
    }

    #[test]
    fn free_chunk_counts_track_state() {
        let mut g = GpuCluster::new(1);
        assert_eq!(g.free_chunk_counts(), [0, 0, 0, 1]);
        let a = g.allocate(svc(0), 1).unwrap();
        assert_eq!(g.free_chunk_counts(), [1, 1, 1, 0]);
        g.release(a.chunk, svc(0), 1, SimTime(1));
        assert_eq!(g.free_chunk_counts(), [2, 1, 1, 0]);
    }
}
