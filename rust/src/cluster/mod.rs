//! Simulated external-resource substrates.
//!
//! The paper evaluates on a production testbed (15 CPU nodes, 5 GPU nodes,
//! quota-limited third-party APIs). These modules are the from-scratch
//! substitutes (DESIGN.md §2): state machines faithful to what the resource
//! managers manipulate, plus latency/failure models calibrated to the
//! paper's reported characteristics.

pub mod api;
pub mod cpu;
pub mod gpu;

pub use api::{ApiEndpoint, ApiEndpointSpec, ApiOutcome};
pub use cpu::{Container, CoreId, CpuLatency, CpuNode, NodeId};
pub use gpu::{ChunkRef, GpuAlloc, GpuCluster, GpuNode, GpuNodeId, RestoreModel};
