//! Typed experiment configuration with JSON loading.
//!
//! The launcher (`arl-tangram` binary) reads an experiment description —
//! cluster scale, workloads, batch/steps, backend — from a JSON file or CLI
//! flags, so deployments are reproducible artifacts rather than shell
//! one-liners.

use crate::baselines::K8sCfg;
use crate::coordinator::{RunCfg, TangramCfg};
use crate::rollout::workloads::CatalogCfg;
use crate::sim::SimDur;
use crate::util::error::Result;
use crate::util::json::Json;
use crate::{bail, err};

/// Which resource-management policy to deploy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Tangram,
    K8s,
    StaticGpu,
    Serverless,
    Unmanaged,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "tangram" => BackendKind::Tangram,
            "k8s" => BackendKind::K8s,
            "static" | "sglang" => BackendKind::StaticGpu,
            "serverless" => BackendKind::Serverless,
            "unmanaged" => BackendKind::Unmanaged,
            other => bail!("unknown backend {other}"),
        })
    }

    /// Canonical CLI/config name (inverse of [`BackendKind::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Tangram => "tangram",
            BackendKind::K8s => "k8s",
            BackendKind::StaticGpu => "static",
            BackendKind::Serverless => "serverless",
            BackendKind::Unmanaged => "unmanaged",
        }
    }

    /// All deployable backends, in reporting order.
    pub const ALL: [BackendKind; 5] = [
        BackendKind::Tangram,
        BackendKind::K8s,
        BackendKind::StaticGpu,
        BackendKind::Serverless,
        BackendKind::Unmanaged,
    ];
}

/// Full experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentCfg {
    pub backend: BackendKind,
    pub workloads: Vec<String>,
    pub catalog: CatalogCfg,
    pub run: RunCfg,
}

impl Default for ExperimentCfg {
    fn default() -> Self {
        ExperimentCfg {
            backend: BackendKind::Tangram,
            workloads: vec!["coding".into()],
            catalog: CatalogCfg::default(),
            run: RunCfg::default(),
        }
    }
}

impl ExperimentCfg {
    /// Load from a JSON file; unknown keys are rejected to catch typos.
    pub fn from_json(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| err!("config: {e}"))?;
        let obj = j.as_obj().ok_or_else(|| err!("config must be an object"))?;
        let mut cfg = ExperimentCfg::default();
        for (k, v) in obj {
            match k.as_str() {
                "backend" => {
                    cfg.backend = BackendKind::parse(
                        v.as_str().ok_or_else(|| err!("backend must be a string"))?,
                    )?
                }
                "workloads" => {
                    cfg.workloads = v
                        .as_arr()
                        .ok_or_else(|| err!("workloads must be an array"))?
                        .iter()
                        .map(|w| {
                            w.as_str()
                                .map(String::from)
                                .ok_or_else(|| err!("workload must be a string"))
                        })
                        .collect::<Result<_>>()?
                }
                "batch" => cfg.run.batch = need_u64(v, k)? as usize,
                "steps" => cfg.run.steps = need_u64(v, k)? as u32,
                "seed" => cfg.run.seed = need_u64(v, k)?,
                "sample_every_secs" => {
                    cfg.run.sample_every = SimDur::from_secs(need_u64(v, k)?)
                }
                "cpu_nodes" => cfg.catalog.cpu_nodes = need_u64(v, k)? as u32,
                "cores_per_node" => cfg.catalog.cores_per_node = need_u64(v, k)? as u32,
                "gpu_nodes" => cfg.catalog.gpu_nodes = need_u64(v, k)? as u32,
                "n_teachers" => cfg.catalog.n_teachers = need_u64(v, k)? as u32,
                other => bail!("unknown config key '{other}'"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.workloads.is_empty() {
            bail!("no workloads configured");
        }
        for w in &self.workloads {
            if !matches!(w.as_str(), "coding" | "deepsearch" | "mopd") {
                bail!("unknown workload '{w}'");
            }
        }
        if self.run.batch == 0 || self.run.steps == 0 {
            bail!("batch and steps must be positive");
        }
        if self.catalog.cpu_nodes == 0 || self.catalog.gpu_nodes == 0 {
            bail!("cluster must have nodes");
        }
        Ok(())
    }

    /// Tangram deployment matching the catalog scale.
    pub fn tangram_cfg(&self) -> TangramCfg {
        TangramCfg {
            cpu_nodes: self.catalog.cpu_nodes,
            numa_per_node: 2,
            cores_per_numa: (self.catalog.cores_per_node / 2).max(1),
            gpu_nodes: self.catalog.gpu_nodes,
            ..TangramCfg::default()
        }
    }

    pub fn k8s_cfg(&self) -> K8sCfg {
        K8sCfg {
            nodes: self.catalog.cpu_nodes,
            cores_per_node: self.catalog.cores_per_node,
            ..K8sCfg::default()
        }
    }
}

fn need_u64(v: &Json, key: &str) -> Result<u64> {
    v.as_u64().ok_or_else(|| err!("'{key}' must be a non-negative integer"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = ExperimentCfg::from_json(
            r#"{
                "backend": "k8s",
                "workloads": ["coding", "mopd"],
                "batch": 256,
                "steps": 3,
                "seed": 9,
                "cpu_nodes": 3,
                "cores_per_node": 128,
                "gpu_nodes": 2
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.backend, BackendKind::K8s);
        assert_eq!(cfg.workloads, vec!["coding", "mopd"]);
        assert_eq!(cfg.run.batch, 256);
        assert_eq!(cfg.catalog.cores_per_node, 128);
        assert_eq!(cfg.tangram_cfg().cores_per_numa, 64);
    }

    #[test]
    fn rejects_unknown_keys_and_values() {
        assert!(ExperimentCfg::from_json(r#"{"nope": 1}"#).is_err());
        assert!(ExperimentCfg::from_json(r#"{"backend": "magic"}"#).is_err());
        assert!(ExperimentCfg::from_json(r#"{"workloads": ["x"]}"#).is_err());
        assert!(ExperimentCfg::from_json(r#"{"batch": 0}"#).is_err());
        assert!(ExperimentCfg::from_json(r#"{"batch": -3}"#).is_err());
    }

    #[test]
    fn defaults_are_valid() {
        ExperimentCfg::default().validate().unwrap();
        assert!(BackendKind::parse("sglang").is_ok());
    }
}
