//! Dense slab of driver-owned action handles, indexed by [`ActionId`].
//!
//! The driver assigns action ids from a monotone counter, so the live id
//! set is a sliding window: a dense `VecDeque` offset by the lowest
//! still-tracked id replaces the per-action hashing (and rehash churn) of
//! the old `HashMap<ActionId, Arc<Action>>` on every submit, retry and
//! completion lookup — an O(1) offset and bounds check per access, no
//! hasher in the hot path. Memory is bounded by the in-flight window:
//! leading completed slots are reclaimed as soon as the oldest tracked
//! action is removed.

use crate::action::{Action, ActionId};
use std::collections::VecDeque;
use std::sync::Arc;

/// Offset-indexed slab of shared action handles (see the module docs).
#[derive(Debug, Default)]
pub struct ActionArena {
    /// Id of `slots[0]`; ids map to dense offsets from here.
    base: u64,
    slots: VecDeque<Option<Arc<Action>>>,
    live: usize,
}

impl ActionArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Actions currently tracked.
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    fn slot(&self, id: ActionId) -> Option<usize> {
        id.0.checked_sub(self.base).map(|o| o as usize).filter(|&o| o < self.slots.len())
    }

    /// Track `action` under `id`. The driver hands out ascending ids, so
    /// inserts only ever extend the window's trailing edge.
    pub fn insert(&mut self, id: ActionId, action: Arc<Action>) {
        if self.slots.is_empty() {
            self.base = id.0;
        }
        debug_assert!(id.0 >= self.base, "action ids must be monotone");
        let Some(offset) = id.0.checked_sub(self.base) else {
            return;
        };
        let offset = offset as usize;
        while self.slots.len() <= offset {
            self.slots.push_back(None);
        }
        debug_assert!(self.slots[offset].is_none(), "duplicate arena insert");
        if self.slots[offset].replace(action).is_none() {
            self.live += 1;
        }
    }

    pub fn get(&self, id: ActionId) -> Option<&Arc<Action>> {
        self.slot(id).and_then(|o| self.slots[o].as_ref())
    }

    pub fn get_mut(&mut self, id: ActionId) -> Option<&mut Arc<Action>> {
        let o = self.slot(id)?;
        self.slots[o].as_mut()
    }

    /// Stop tracking `id`, returning its handle and reclaiming any leading
    /// vacated slots (the sliding-window trim that bounds memory at the
    /// in-flight width instead of the all-time action count).
    pub fn remove(&mut self, id: ActionId) -> Option<Arc<Action>> {
        let o = self.slot(id)?;
        let taken = self.slots[o].take();
        if taken.is_some() {
            self.live -= 1;
            while let Some(None) = self.slots.front() {
                self.slots.pop_front();
                self.base += 1;
            }
        }
        taken
    }
}

impl std::ops::Index<ActionId> for ActionArena {
    type Output = Arc<Action>;

    fn index(&self, id: ActionId) -> &Arc<Action> {
        self.get(id).expect("action not tracked in the arena")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::action::{
        ActionKind, ActionSpec, CostSpec, DimCost, ElasticityModel, ResourceClass,
        ResourceRegistry, TaskId, TenantId, TrajId,
    };
    use crate::sim::{SimDur, SimTime};

    fn mk(id: u64) -> Arc<Action> {
        let mut reg = ResourceRegistry::new();
        let cpu = reg.register("cpu", ResourceClass::CpuCores, 8);
        Arc::new(Action::new(
            ActionId(id),
            ActionSpec {
                task: TaskId(0),
                tenant: TenantId(0),
                trajectory: TrajId(id),
                kind: ActionKind::EnvExec,
                cost: CostSpec::single(&reg, cpu, DimCost::Fixed(1)),
                key_resource: Some(cpu),
                elasticity: ElasticityModel::None,
                profiled_dur: None,
                service: None,
                true_dur: SimDur::from_secs(1),
            },
            SimTime::ZERO,
        ))
    }

    #[test]
    fn insert_get_remove_round_trip() {
        let mut arena = ActionArena::new();
        assert!(arena.is_empty());
        for id in 10..14 {
            arena.insert(ActionId(id), mk(id));
        }
        assert_eq!(arena.len(), 4);
        assert_eq!(arena.get(ActionId(12)).map(|a| a.id), Some(ActionId(12)));
        assert!(arena.get(ActionId(9)).is_none(), "below the window");
        assert!(arena.get(ActionId(14)).is_none(), "beyond the window");
        assert_eq!(arena[ActionId(11)].id, ActionId(11));
        let a = arena.remove(ActionId(12)).expect("tracked");
        assert_eq!(a.id, ActionId(12));
        assert!(arena.remove(ActionId(12)).is_none(), "second removal misses");
        assert!(arena.get(ActionId(12)).is_none());
        assert_eq!(arena.len(), 3);
    }

    #[test]
    fn window_slides_as_leading_actions_retire() {
        let mut arena = ActionArena::new();
        for id in 0..100 {
            arena.insert(ActionId(id), mk(id));
        }
        // retire in order: the slab must trim from the front and stay at
        // the in-flight width, not the all-time count
        for id in 0..90 {
            assert!(arena.remove(ActionId(id)).is_some());
        }
        assert_eq!(arena.len(), 10);
        assert!(arena.slots.len() <= 10, "leading slots must be reclaimed");
        assert_eq!(arena.base, 90);
        // the window keeps sliding across fresh inserts
        arena.insert(ActionId(100), mk(100));
        assert_eq!(arena.get(ActionId(100)).map(|a| a.id), Some(ActionId(100)));
        assert_eq!(arena.get(ActionId(95)).map(|a| a.id), Some(ActionId(95)));
    }

    #[test]
    fn out_of_order_removal_trims_lazily() {
        let mut arena = ActionArena::new();
        for id in 0..4 {
            arena.insert(ActionId(id), mk(id));
        }
        // removing a middle action leaves a hole but no trim
        assert!(arena.remove(ActionId(1)).is_some());
        assert_eq!(arena.base, 0);
        // removing the head trims through the hole in one sweep
        assert!(arena.remove(ActionId(0)).is_some());
        assert_eq!(arena.base, 2);
        assert_eq!(arena.len(), 2);
        assert_eq!(arena.get(ActionId(2)).map(|a| a.id), Some(ActionId(2)));
        // draining everything empties the slab; a later insert re-bases
        assert!(arena.remove(ActionId(2)).is_some());
        assert!(arena.remove(ActionId(3)).is_some());
        assert!(arena.is_empty());
        assert_eq!(arena.slots.len(), 0);
        arena.insert(ActionId(1000), mk(1000));
        assert_eq!(arena.base, 1000);
        assert_eq!(arena.len(), 1);
    }

    #[test]
    fn get_mut_reaches_the_tracked_handle() {
        let mut arena = ActionArena::new();
        arena.insert(ActionId(7), mk(7));
        let handle = arena.get_mut(ActionId(7)).expect("tracked");
        assert!(Arc::get_mut(handle).is_some(), "sole owner is mutable");
        let extra = arena[ActionId(7)].clone();
        let handle = arena.get_mut(ActionId(7)).expect("tracked");
        assert!(Arc::get_mut(handle).is_none(), "shared handle is not");
        drop(extra);
    }
}
