//! Execution-backend abstraction.
//!
//! The DES driver (rollout engine + RL step loop) is policy-agnostic: it
//! submits actions and reacts to completions. A [`Backend`] decides *when*
//! each action starts, with how many units, and at what overhead — this is
//! where ARL-Tangram and the paper's baselines (Kubernetes pods, static
//! SGLang services, ServerlessLLM, fixed DoP) differ.

use crate::action::{Action, ActionId, TrajId};
use crate::scenario::ScenarioEvent;
use crate::sim::{SimDur, SimTime};

/// An action the backend has decided to start now.
#[derive(Debug, Clone)]
pub struct Started {
    pub action: ActionId,
    /// Setup/restore charged before execution (Table 1 "Sys. Overhead").
    pub overhead: SimDur,
    /// Pure execution duration of this attempt.
    pub exec: SimDur,
    /// Units of the key resource granted.
    pub units: u64,
}

/// What to do when an attempt finishes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Attempt succeeded — record and advance the trajectory.
    Done,
    /// Attempt failed transiently — resubmit (driver increments retries).
    Retry,
    /// Attempt failed terminally — the trajectory is invalid.
    Failed,
}

/// Pluggable resource-management policy under the common rollout driver.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// A trajectory is starting; reserve its environment (container memory /
    /// pod). `Err` ⇒ cannot start yet (driver retries on the next
    /// completion).
    fn traj_start(
        &mut self,
        now: SimTime,
        traj: TrajId,
        mem_gb: u64,
        first_cpu_min: Option<u32>,
    ) -> Result<(), String>;

    /// Trajectory finished (or was abandoned); release its environment.
    fn traj_end(&mut self, now: SimTime, traj: TrajId);

    /// Enqueue one action (also used for retries).
    fn submit(&mut self, now: SimTime, action: &Action);

    /// An attempt finished executing; release resources and judge it.
    fn on_complete(&mut self, now: SimTime, action: &Action) -> Verdict;

    /// Collect actions that can start now (called after submits/completions
    /// and timed wakeups).
    fn drain_started(&mut self, now: SimTime) -> Vec<Started>;

    /// Earliest future instant at which the backend wants a tick (quota
    /// window rolls, retry backoffs). The driver schedules it.
    fn next_wakeup(&self, now: SimTime) -> Option<SimTime>;

    /// Timed wakeup.
    fn tick(&mut self, now: SimTime);

    /// Named utilization gauges for Fig. 3(b)-style sampling.
    fn utilization(&self) -> Vec<(String, f64)>;

    /// GPUs/CPUs provisioned (for the resource-saving reports).
    fn provisioned(&self) -> Vec<(String, u64)>;

    /// Apply a scenario fault/perturbation (rate-limit flap, cache flush,
    /// pool resize). Returns `true` when the backend's substrate honored
    /// it; the default ignores everything — static baselines are
    /// deliberately inelastic, which is exactly the asymmetry the scenario
    /// packs measure.
    fn inject(&mut self, now: SimTime, event: &ScenarioEvent) -> bool {
        let _ = (now, event);
        false
    }
}
