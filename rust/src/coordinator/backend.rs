//! Execution-backend abstraction.
//!
//! The DES driver (rollout engine + RL step loop) is policy-agnostic: it
//! submits actions and reacts to completions. A [`Backend`] decides *when*
//! each action starts, with how many units, and at what overhead — this is
//! where ARL-Tangram and the paper's baselines (Kubernetes pods, static
//! SGLang services, ServerlessLLM, fixed DoP) differ.
//!
//! # The dirty-pool contract
//!
//! The driver pumps ([`Backend::drain_started`]) after every submit,
//! completion, timed wakeup, and fault injection — under bursty queues that
//! is thousands of pumps, and re-scanning *every* resource pool on each one
//! breaks the paper's sub-ms decision budget (§4.2). Backends therefore
//! track a **dirty set** of pools and the driver honors it:
//!
//! * A pool becomes dirty when its state changes in a way that could start
//!   a queued action: an action is submitted into it, an action completes
//!   on it, a quota window rolls over ([`Backend::tick`]), a fault
//!   injection touches it ([`Backend::inject`]), or a duration observation
//!   moves the historical-average estimate of a kind the pool holds
//!   unprofiled queued actions of (the one *cross-pool* coupling — the
//!   EWMA feeds every pool's decision objective).
//! * [`Backend::drain_started_into`] schedules **only dirty pools, in
//!   sorted order** (sorted so same-timestamp `Started` ordering — and
//!   therefore recorded scenario traces — stays deterministic across
//!   processes), and clears the set. Two kinds of pool re-arm themselves:
//!   one that *started* work (its own state changed; the next pump may
//!   start more on the leftover capacity, exactly as the legacy full sweep
//!   did), and one that is *stalled* (non-empty queue, nothing running
//!   that will free capacity, nothing started) — re-arming the latter is
//!   what keeps a cordoned-then-restored CPU node live.
//! * Decisions flow into a caller-owned [`StartedSink`], so the driver
//!   reuses one buffer across every pump instead of allocating a
//!   `Vec<Started>` per drain. [`Backend::drain_started`] remains as a
//!   default allocating adapter for tests and one-shot callers.
//! * Backends that partition the drain across logical shards
//!   ([`Backend::set_shards`]) must merge per-shard decisions back in the
//!   global sorted-pool order, so the sink's contents — and therefore
//!   recorded traces — are byte-identical for any shard count.
//! * [`Backend::has_dirty`] tells the driver whether a drain could start
//!   anything at all; the driver skips `drain_started` entirely when it
//!   returns `false`. Backends whose admission is time-gated rather than
//!   event-gated (pod readiness, queue timeouts) simply report "dirty while
//!   anything is queued" — the default implementation returns `true`, which
//!   is always correct and merely forfeits the optimization.
//!
//! Actions are handed over as [`Arc<Action>`] so queue management moves
//! 8-byte handles instead of cloning full `Action`s on every submit and
//! retry. While an action is queued (state `Waiting`) the driver never
//! mutates it; backends drop their handle when they start the action, which
//! is what lets the driver reclaim exclusive ownership for bookkeeping.
//! The handles are atomically counted so a backend may *read* its queues
//! from worker threads during a drain ([`Backend::set_threads`]); all
//! mutation stays on the driver thread.

use crate::action::{Action, ActionId, TrajId};
use crate::autoscale::{LaneKey, PoolPressure};
use crate::scenario::ScenarioEvent;
use crate::sim::{SimDur, SimTime};
use std::sync::Arc;

/// An action the backend has decided to start now.
#[derive(Debug, Clone)]
pub struct Started {
    pub action: ActionId,
    /// Setup/restore charged before execution (Table 1 "Sys. Overhead").
    pub overhead: SimDur,
    /// Pure execution duration of this attempt.
    pub exec: SimDur,
    /// Units of the key resource granted.
    pub units: u64,
}

/// Reusable decision buffer for [`Backend::drain_started_into`].
///
/// The driver owns one sink for the whole run and hands it to the backend
/// on every pump; the backend pushes its decisions and the driver drains
/// them, so the steady state is alloc-free (the backing `Vec` keeps its
/// high-water capacity). Push order is the contract: decisions must arrive
/// in the global sorted-pool order regardless of how the backend
/// partitions the drain internally.
#[derive(Debug, Default)]
pub struct StartedSink {
    buf: Vec<Started>,
}

impl StartedSink {
    /// Record one start decision.
    pub fn push(&mut self, s: Started) {
        self.buf.push(s);
    }

    /// Decisions currently buffered.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Drain the buffered decisions in push order, keeping the capacity.
    pub fn drain(&mut self) -> std::vec::Drain<'_, Started> {
        self.buf.drain(..)
    }

    /// Consume the sink into its backing `Vec` (the legacy return shape).
    pub fn into_vec(self) -> Vec<Started> {
        self.buf
    }
}

/// What to do when an attempt finishes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Attempt succeeded — record and advance the trajectory.
    Done,
    /// Attempt failed transiently — resubmit (driver increments retries).
    Retry,
    /// Attempt failed terminally — the trajectory is invalid.
    Failed,
}

/// Pluggable resource-management policy under the common rollout driver.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// A trajectory is starting; reserve its environment (container memory /
    /// pod). `Err` ⇒ cannot start yet (driver retries on the next
    /// completion).
    fn traj_start(
        &mut self,
        now: SimTime,
        traj: TrajId,
        mem_gb: u64,
        first_cpu_min: Option<u32>,
    ) -> Result<(), String>;

    /// Trajectory finished (or was abandoned); release its environment.
    fn traj_end(&mut self, now: SimTime, traj: TrajId);

    /// Enqueue one action (also used for retries). The backend keeps a
    /// clone of the `Arc` handle while the action waits and drops it when
    /// the action starts (see the dirty-pool contract above).
    fn submit(&mut self, now: SimTime, action: &Arc<Action>);

    /// An attempt finished executing; release resources and judge it.
    fn on_complete(&mut self, now: SimTime, action: &Action) -> Verdict;

    /// Collect actions that can start now (called after submits/completions
    /// and timed wakeups), pushing decisions into the caller's sink in the
    /// global sorted-pool order. Under the dirty-pool contract this
    /// schedules only pools whose state changed since the previous drain.
    /// The driver reuses one sink across pumps, so implementations must not
    /// assume it starts with spare capacity — only that it starts empty.
    fn drain_started_into(&mut self, now: SimTime, sink: &mut StartedSink);

    /// Allocating adapter over [`Backend::drain_started_into`] for tests
    /// and one-shot callers; the driver's hot path never uses it.
    fn drain_started(&mut self, now: SimTime) -> Vec<Started> {
        let mut sink = StartedSink::default();
        self.drain_started_into(now, &mut sink);
        sink.into_vec()
    }

    /// Dirty-pool contract: `true` when at least one pool's state changed
    /// since the last [`Backend::drain_started`], so draining could start
    /// something. The driver skips `drain_started` when this is `false`.
    /// The default (always `true`) is correct for any backend and simply
    /// keeps the legacy every-pump scan.
    fn has_dirty(&self) -> bool {
        true
    }

    /// Earliest future instant at which the backend wants a tick (quota
    /// window rolls, retry backoffs). The driver schedules it.
    fn next_wakeup(&self, now: SimTime) -> Option<SimTime>;

    /// Timed wakeup.
    fn tick(&mut self, now: SimTime);

    /// Named utilization gauges for Fig. 3(b)-style sampling.
    fn utilization(&self) -> Vec<(String, f64)>;

    /// GPUs/CPUs provisioned (for the resource-saving reports).
    fn provisioned(&self) -> Vec<(String, u64)>;

    /// Apply a scenario fault/perturbation (rate-limit flap, cache flush,
    /// pool resize). Returns `true` when the backend's substrate honored
    /// it; the default ignores everything — static baselines are
    /// deliberately inelastic, which is exactly the asymmetry the scenario
    /// packs measure.
    fn inject(&mut self, now: SimTime, event: &ScenarioEvent) -> bool {
        let _ = (now, event);
        false
    }

    /// Live demand observations for every scale target this backend can
    /// elastically resize, sorted by [`LaneKey`] (the autoscaler's
    /// deterministic evaluation order). The CPU and GPU pools
    /// are single-target classes (`endpoint == None`); the API class
    /// reports one row **per provider endpoint** (sorted by endpoint kind
    /// id) so quota lanes resize per provider. The default — no resizable
    /// targets — is the statically-provisioned deployment the paper
    /// baselines model.
    fn scale_classes(&self) -> Vec<PoolPressure> {
        Vec::new()
    }

    /// Elastically resize one scale target to `factor` × its full static
    /// provision, returning the provisioned unit count the **whole class**
    /// actually reached (resizes are best-effort: busy capacity is never
    /// preempted). `key.endpoint` narrows an API-class resize to one
    /// provider (`None` on single-target classes, or to sweep every
    /// endpoint). Implementations reuse the same substrate machinery as the
    /// `cpu_pool_scale` / `gpu_pool_scale` / `api_limit_scale` fault
    /// injections — including dirtying the affected pools, so the pump
    /// that follows reschedules them. `None` means the substrate cannot
    /// resize this class (the deliberately-inelastic default).
    fn resize(&mut self, now: SimTime, key: LaneKey, factor: f64) -> Option<u64> {
        let _ = (now, key, factor);
        None
    }

    /// Install per-tenant weighted-fair-queueing weights on every lane
    /// queue. The default ignores them — inelastic baselines keep plain
    /// FCFS, which single-tenant workloads cannot distinguish from WFQ
    /// anyway (see `coordinator::queue`).
    fn set_tenant_weights(&mut self, weights: &[(u32, u32)]) {
        let _ = weights;
    }

    /// Partition the drain across `n` logical shards (contiguous slices of
    /// the sorted pool list, processed in ascending shard order and merged
    /// back in that order — which *is* the global sorted-pool order, so the
    /// decision stream is byte-identical for any `n`). `n = 1` must be
    /// bitwise the unsharded path. The default ignores the knob — backends
    /// without sub-pool parallelism have nothing to partition.
    fn set_shards(&mut self, n: usize) {
        let _ = n;
    }

    /// Execute the shard slices of [`Backend::set_shards`] on up to `n`
    /// worker threads. Workers run only the *read-only* decision half of a
    /// drain; decisions are applied serially in ascending shard order, so
    /// the sink's contents — and therefore recorded traces — stay
    /// byte-identical for any `(shards, threads)` combination, and `n = 1`
    /// is bitwise the serial path. Effective parallelism is capped by the
    /// shard count: `--shards 1` leaves a single worker regardless of `n`.
    /// The default ignores the knob — backends without a sharded drain have
    /// nothing to parallelize.
    fn set_threads(&mut self, n: usize) {
        let _ = n;
    }
}
