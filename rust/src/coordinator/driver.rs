//! Discrete-event experiment driver.
//!
//! Runs one or more RL tasks (workloads) through a pluggable [`Backend`]
//! under the virtual clock, reproducing the paper's training loop: each
//! step, a batch of trajectories rolls out (LLM generation interleaved with
//! external actions on the backend), then the training phase runs on the
//! internal GPU cluster, then the next step begins. Collects [`Metrics`].
//!
//! [`run_session`] additionally wires in the scenario subsystem through a
//! [`Session`]: timed [`ScenarioEvent`] fault injections delivered through
//! [`Backend::inject`], an optional [`TraceRecorder`] that captures every
//! scheduling decision for differential replay, an optional [`Autoscaler`],
//! and per-tenant WFQ weights installed into the backend's lane queues.

use super::arena::ActionArena;
use super::backend::{Backend, StartedSink, Verdict};
use crate::action::{Action, ActionId, ActionKind, ActionSpec, ActionState, TenantId, TrajId};
use crate::autoscale::{Autoscaler, LaneKey, ScaleCmd};
use crate::metrics::{ActionRecord, Metrics, ProvisionRecord, StepRecord, TrajRecord, UtilSample};
use crate::rollout::workloads::Catalog;
use crate::rollout::{Phase, Workload};
use crate::scenario::trace::{TraceKind, TraceRecorder};
use crate::scenario::{ScenarioEvent, TimedEvent};
use crate::sim::{Engine, SimDur, SimTime};
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::sync::Arc;

/// Experiment-run parameters.
#[derive(Debug, Clone)]
pub struct RunCfg {
    /// Trajectories per step (the paper's "RL batch size" under GRPO).
    pub batch: usize,
    pub steps: u32,
    pub seed: u64,
    /// Utilization sampling period.
    pub sample_every: SimDur,
    /// Max transparent retries per action before it fails terminally.
    pub max_api_retries: u32,
    /// Max restarts of a trajectory that had a terminally-failed action.
    pub max_traj_restarts: u32,
    /// Spread each step's trajectory arrivals evenly over this window
    /// (ZERO = the thundering-herd batch arrival the paper measures;
    /// scenario packs use it to model staggered dataset loading).
    pub arrival_spread: SimDur,
}

impl Default for RunCfg {
    fn default() -> Self {
        RunCfg {
            batch: 128,
            steps: 2,
            seed: 42,
            sample_every: SimDur::from_secs(5),
            max_api_retries: 3,
            max_traj_restarts: 2,
            arrival_spread: SimDur::ZERO,
        }
    }
}

#[derive(Debug)]
enum Ev {
    StepStart(usize),
    TrajStart(TrajId),
    GenDone(TrajId),
    ActionDone(ActionId),
    Wakeup,
    Sample,
    /// Deliver scenario injection `i` to the backend.
    Inject(usize),
    /// Periodic autoscaler evaluation (only scheduled when one is wired).
    Autoscale,
    /// Autoscale-aware admission wakeup at a warming requisition's
    /// maturity instant: apply the matured resize there (and pump), so
    /// queued work overlaps the cold start instead of waiting for the next
    /// `Autoscale` tick past it. Only scheduled when
    /// `AutoscaleCfg::admission` is set.
    Admit,
}

struct TrajRt {
    plan: crate::rollout::TrajectoryPlan,
    wl: usize,
    /// Copied from the workload at spawn so action construction needs no
    /// second borrow into `wls`.
    tenant: TenantId,
    phase: usize,
    started: SimTime,
    gen: SimDur,
    tool: SimDur,
    reward: SimDur,
    restarts: u32,
    failed: bool,
    env_bound: bool,
}

struct WlState {
    workload: Workload,
    step: u32,
    remaining: usize,
    step_started: SimTime,
    done: bool,
}

struct Driver<'a> {
    backend: &'a mut dyn Backend,
    cat: &'a Catalog,
    cfg: &'a RunCfg,
    eng: Engine<Ev>,
    metrics: Metrics,
    rng: Rng,
    /// Single owner of every live action. Backends hold `Arc` handles only
    /// while an action waits in a queue and drop them on start, so the
    /// driver can reclaim exclusive access (`Arc::get_mut`) for the mutable
    /// bookkeeping — no full-`Action` clones on submit or retry. Ids are
    /// handed out monotonically, so a sliding-window slab beats a hash map
    /// on every hot-path lookup (see [`ActionArena`]).
    actions: ActionArena,
    /// (overhead, exec) of the in-flight attempt
    attempt: HashMap<ActionId, (SimDur, SimDur)>,
    trajs: HashMap<TrajId, TrajRt>,
    wls: Vec<WlState>,
    next_action: u64,
    next_traj: u64,
    /// earliest already-scheduled wakeup (dedup — without this, every pump
    /// under a waiting backend would enqueue another Wakeup event and the
    /// event count explodes quadratically)
    wakeup_at: Option<SimTime>,
    /// earliest already-scheduled admission wakeup (same dedup)
    admit_at: Option<SimTime>,
    /// scenario fault timeline (delivered via `Ev::Inject`)
    injections: &'a [TimedEvent],
    /// decision-trace sink (scenario record/replay)
    rec: Option<&'a mut TraceRecorder>,
    /// elastic pool autoscaler (None = static provisioning)
    asc: Option<&'a mut Autoscaler>,
    /// actions submitted but not yet started (trace queue-depth gauge)
    waiting: u64,
    /// reusable drain buffer: one sink for the whole run, so the steady
    /// state of the pump hot path allocates nothing per drain
    sink: StartedSink,
}

/// Everything a run carries besides the backend/workload essentials: the
/// scenario fault timeline, the decision-trace recorder, the elastic
/// autoscaler, and per-tenant WFQ weights. Built builder-style so call
/// sites name exactly the hooks they use and [`run_session`] keeps a fixed
/// five-argument shape no matter how many hooks are added later.
///
/// The session *owns* its hooks; after the run, reclaim the recorder or
/// autoscaler with [`Session::take_recorder`] / [`Session::take_autoscaler`].
#[derive(Default)]
pub struct Session {
    injections: Vec<TimedEvent>,
    recorder: Option<TraceRecorder>,
    autoscaler: Option<Autoscaler>,
    tenant_weights: Vec<(u32, u32)>,
    /// Drain shards requested via [`Session::with_shards`] (0 = leave the
    /// backend's default — unset is distinct from asking for 1 shard so
    /// replay can honor whatever the backend was constructed with).
    shards: usize,
    /// Worker threads for the decide half of the drain, requested via
    /// [`Session::with_threads`] (0 = leave the backend's default, the
    /// same unset-vs-explicit distinction as `shards`).
    threads: usize,
}

impl Session {
    pub fn new() -> Self {
        Self::default()
    }

    /// Timed scenario fault injections, delivered via [`Backend::inject`].
    pub fn with_injections(mut self, injections: Vec<TimedEvent>) -> Self {
        self.injections = injections;
        self
    }

    /// Record every scheduling decision for differential replay.
    pub fn with_recorder(mut self, recorder: TraceRecorder) -> Self {
        self.recorder = Some(recorder);
        self
    }

    /// Evaluate an elastic autoscaler on its virtual-time cadence, resizing
    /// pools through [`Backend::resize`] and billing capacity into the
    /// provision records.
    pub fn with_autoscaler(mut self, autoscaler: Autoscaler) -> Self {
        self.autoscaler = Some(autoscaler);
        self
    }

    /// Per-tenant WFQ weights installed into the backend's lane queues
    /// before the run (empty ⇒ every tenant at weight 1).
    pub fn with_tenant_weights(mut self, weights: Vec<(u32, u32)>) -> Self {
        self.tenant_weights = weights;
        self
    }

    /// Partition the backend's drain across `n` logical shards
    /// ([`Backend::set_shards`]). Decisions merge in the global sorted-pool
    /// order, so any `n` produces byte-identical traces; `n = 1` is
    /// bitwise the unsharded path. `0` leaves the backend's default.
    pub fn with_shards(mut self, n: usize) -> Self {
        self.shards = n;
        self
    }

    /// Run the decide half of each drain on up to `n` worker threads
    /// ([`Backend::set_threads`]). Plans apply serially in ascending shard
    /// order, so any `n` produces byte-identical traces; `n = 1` is
    /// bitwise the serial path. `0` leaves the backend's default.
    /// Parallelism is capped by the shard count — pair with
    /// [`Session::with_shards`].
    pub fn with_threads(mut self, n: usize) -> Self {
        self.threads = n;
        self
    }

    /// Reclaim the recorder after a run (e.g. to write the trace file).
    pub fn take_recorder(&mut self) -> Option<TraceRecorder> {
        self.recorder.take()
    }

    /// Reclaim the autoscaler after a run (e.g. to read `applied`).
    pub fn take_autoscaler(&mut self) -> Option<Autoscaler> {
        self.autoscaler.take()
    }
}

/// Run the experiment with default hooks; returns collected metrics.
pub fn run(
    backend: &mut dyn Backend,
    cat: &Catalog,
    workloads: &[Workload],
    cfg: &RunCfg,
) -> Metrics {
    run_session(backend, cat, workloads, cfg, &mut Session::new())
}

/// [`run`] with the scenario hooks carried by a [`Session`] (fault
/// injections, trace recorder, autoscaler, tenant weights).
pub fn run_session(
    backend: &mut dyn Backend,
    cat: &Catalog,
    workloads: &[Workload],
    cfg: &RunCfg,
    session: &mut Session,
) -> Metrics {
    let Session { injections, recorder, autoscaler, tenant_weights, shards, threads } = session;
    let injections: &[TimedEvent] = injections;
    if !tenant_weights.is_empty() {
        backend.set_tenant_weights(tenant_weights);
    }
    if *shards > 0 {
        backend.set_shards(*shards);
    }
    if *threads > 0 {
        backend.set_threads(*threads);
    }
    let mut d = Driver {
        backend,
        cat,
        cfg,
        eng: Engine::new(),
        metrics: Metrics::new(),
        rng: Rng::new(cfg.seed),
        actions: ActionArena::new(),
        attempt: HashMap::new(),
        trajs: HashMap::new(),
        wls: workloads
            .iter()
            .map(|w| WlState {
                workload: w.clone(),
                step: 0,
                remaining: 0,
                step_started: SimTime::ZERO,
                done: false,
            })
            .collect(),
        next_action: 0,
        next_traj: 0,
        wakeup_at: None,
        admit_at: None,
        injections,
        rec: recorder,
        asc: autoscaler,
        waiting: 0,
        sink: StartedSink::default(),
    };
    // pin the initial provision of every pool (the resource-hour series
    // baseline; without resizes this is the whole static bill)
    for (pool, units) in d.backend.provisioned() {
        d.metrics.provision.push(ProvisionRecord {
            at: SimTime::ZERO,
            pool: pool.clone(),
            units,
        });
        d.trace(SimTime::ZERO, TraceKind::Provision { pool, units });
    }
    for wl in 0..d.wls.len() {
        // a tenant's arrival phase shifts only its first step; later steps
        // chain off rollout + train completion as usual
        let at = SimTime::ZERO + d.wls[wl].workload.phase;
        d.eng.schedule_at(at, Ev::StepStart(wl));
    }
    for (i, te) in injections.iter().enumerate() {
        d.eng.schedule_at(te.at, Ev::Inject(i));
    }
    d.eng.schedule_in(cfg.sample_every, Ev::Sample);
    if let Some(interval) = d.asc.as_ref().map(|a| a.interval()) {
        d.eng.schedule_in(interval, Ev::Autoscale);
    }
    while let Some((now, ev)) = d.eng.next() {
        d.handle(now, ev);
    }
    d.metrics
}

/// Scale-trace label: it carries the endpoint so per-provider decisions
/// stay auditable, while provision records keep the plain pool name — one
/// billing series per pool.
fn scale_label(key: LaneKey) -> String {
    match key.endpoint {
        Some(e) => format!("{}@{e}", key.class.name()),
        None => key.class.name().to_string(),
    }
}

impl Driver<'_> {
    /// Record a trace event (no-op without a recorder).
    fn trace(&mut self, at: SimTime, kind: TraceKind) {
        if let Some(r) = self.rec.as_deref_mut() {
            r.push(at, kind);
        }
    }

    fn handle(&mut self, now: SimTime, ev: Ev) {
        match ev {
            Ev::StepStart(wl) => self.step_start(now, wl),
            Ev::TrajStart(t) => self.traj_start(now, t),
            Ev::GenDone(t) => {
                if self.trajs.contains_key(&t) {
                    self.advance(now, t);
                }
            }
            Ev::ActionDone(id) => self.action_done(now, id),
            Ev::Wakeup => {
                if self.wakeup_at == Some(now) {
                    self.wakeup_at = None;
                }
                self.backend.tick(now);
                self.pump(now);
            }
            Ev::Sample => {
                for (name, value) in self.backend.utilization() {
                    self.metrics.util.push(UtilSample { at: now, name, value });
                }
                if !self.wls.iter().all(|w| w.done) {
                    self.eng.schedule_in(self.cfg.sample_every, Ev::Sample);
                }
            }
            Ev::Inject(i) => self.inject(now, i),
            Ev::Autoscale => self.autoscale(now),
            Ev::Admit => self.admit(now),
        }
    }

    /// One autoscaler evaluation: observe per-target pool pressure, let the
    /// policy decide, bill scale-up capacity from the decision instant, and
    /// apply matured resizes through [`Backend::resize`] (which dirties the
    /// affected pools exactly like the fault-injection path, so the pump
    /// that follows reschedules them at the resize instant). Billing is
    /// per **pool** even though scaling is per target: a `Decide` records
    /// the autoscaler's folded pool total (per-endpoint requisitions
    /// included), an `Apply` records the substrate units the class actually
    /// reached.
    fn autoscale(&mut self, now: SimTime) {
        let obs = self.backend.scale_classes();
        let (cmds, interval) = match self.asc.as_deref_mut() {
            Some(a) => (a.eval(now, &obs), a.interval()),
            None => return,
        };
        let mut applied = false;
        for cmd in cmds {
            match cmd {
                ScaleCmd::Decide { key, factor, pool_units } => {
                    // requisitioned: billed now, schedulable after warm-up
                    let pool = key.class.name().to_string();
                    self.metrics.provision.push(ProvisionRecord {
                        at: now,
                        pool: pool.clone(),
                        units: pool_units,
                    });
                    self.trace(
                        now,
                        TraceKind::Scale {
                            pool: scale_label(key),
                            phase: "decide".into(),
                            factor,
                        },
                    );
                    self.trace(now, TraceKind::Provision { pool, units: pool_units });
                }
                ScaleCmd::Apply { key, factor } => {
                    if self.apply_scale(now, key, factor) {
                        applied = true;
                    }
                }
            }
        }
        if applied {
            // capacity moved — re-run admission at the resize instant, the
            // same re-arm the fault-injection path performs
            self.backend.tick(now);
            self.pump(now);
        }
        if !self.wls.iter().all(|w| w.done) {
            self.eng.schedule_in(interval, Ev::Autoscale);
        }
        self.schedule_admit(now);
    }

    /// Apply one resize in the substrate and record its billing point.
    /// Returns whether the backend honored it. Shared by the evaluation
    /// tick ([`Self::autoscale`]) and the admission path ([`Self::admit`]).
    fn apply_scale(&mut self, now: SimTime, key: LaneKey, factor: f64) -> bool {
        let Some(reached) = self.backend.resize(now, key, factor) else {
            return false;
        };
        // substrate truth, floored by the autoscaler's billed pool total:
        // without the floor, an Apply on one endpoint would re-record the
        // class series at substrate level and silently un-bill another
        // endpoint's still-warming requisition (billed from its decision
        // instant). Over-billing under an active provider fault is the
        // conservative side for the savings claim.
        let billed = self.asc.as_deref().map_or(0, |a| a.billed_units(key.class));
        let units = reached.max(billed);
        let pool = key.class.name().to_string();
        self.metrics.provision.push(ProvisionRecord { at: now, pool: pool.clone(), units });
        self.trace(
            now,
            TraceKind::Scale { pool: scale_label(key), phase: "apply".into(), factor },
        );
        self.trace(now, TraceKind::Provision { pool, units });
        true
    }

    /// Admission wakeup: mature every requisition whose cold start elapsed
    /// and resize the substrate NOW — between evaluation ticks — so queued
    /// work starts the moment billed capacity turns schedulable. Decision
    /// and billing state are untouched (see `Autoscaler::mature`): billing
    /// points never move, only apply instants do.
    fn admit(&mut self, now: SimTime) {
        if self.admit_at == Some(now) {
            self.admit_at = None;
        }
        if self.wls.iter().all(|w| w.done) {
            // run over — a trailing maturation would only stretch the
            // provision series past the admission-off run's end
            return;
        }
        let cmds = match self.asc.as_deref_mut() {
            Some(a) => a.mature(now),
            None => return,
        };
        let mut applied = false;
        for cmd in cmds {
            if let ScaleCmd::Apply { key, factor } = cmd {
                if self.apply_scale(now, key, factor) {
                    applied = true;
                }
            }
        }
        if applied {
            self.backend.tick(now);
            self.pump(now);
        }
        self.schedule_admit(now);
    }

    /// Schedule the next admission wakeup at the earliest still-warming
    /// requisition's maturity instant (deduped like [`Self::pump`]'s
    /// backend wakeups). No-op unless `AutoscaleCfg::admission` is set.
    fn schedule_admit(&mut self, now: SimTime) {
        let Some(asc) = self.asc.as_deref() else { return };
        if !asc.admission() {
            return;
        }
        let Some(at) = asc.next_pending_ready() else { return };
        if at > now && self.admit_at.map_or(true, |w| at < w || w <= now) {
            self.eng.schedule_at(at, Ev::Admit);
            self.admit_at = Some(at);
        }
    }

    fn inject(&mut self, now: SimTime, i: usize) {
        let event: ScenarioEvent = self.injections[i].event.clone();
        let applied = self.backend.inject(now, &event);
        self.trace(
            now,
            TraceKind::Inject { index: i as u64, desc: event.describe(), applied },
        );
        // capacity may have appeared (restored pool) or vanished; either way
        // re-run admission so the backend's queues react at the fault instant
        self.backend.tick(now);
        self.pump(now);
    }

    fn step_start(&mut self, now: SimTime, wl: usize) {
        let state = &mut self.wls[wl];
        let step = state.step;
        state.step_started = now;
        state.remaining = self.cfg.batch;
        let task = self.wls[wl].workload.task;
        self.trace(now, TraceKind::StepStart { task: task.0, step });
        for i in 0..self.cfg.batch {
            let t = TrajId(self.next_traj);
            self.next_traj += 1;
            let plan = self.wls[wl].workload.gen_trajectory(self.cat, &mut self.rng);
            // staggered arrivals: trajectory i of the batch enters at an
            // even offset inside the spread window (ZERO ⇒ thundering herd)
            let offset = if self.cfg.arrival_spread.0 == 0 {
                SimDur::ZERO
            } else {
                SimDur(self.cfg.arrival_spread.0 * i as u64 / self.cfg.batch as u64)
            };
            self.trajs.insert(
                t,
                TrajRt {
                    plan,
                    wl,
                    tenant: self.wls[wl].workload.tenant,
                    phase: 0,
                    started: now + offset,
                    gen: SimDur::ZERO,
                    tool: SimDur::ZERO,
                    reward: SimDur::ZERO,
                    restarts: 0,
                    failed: false,
                    env_bound: false,
                },
            );
            self.trace(now, TraceKind::TrajSpawn { traj: t.0, task: task.0 });
            self.eng.schedule_at(now + offset, Ev::TrajStart(t));
        }
    }

    fn traj_start(&mut self, now: SimTime, t: TrajId) {
        let rt = self.trajs.get_mut(&t).unwrap();
        if !rt.env_bound {
            let first_cpu = rt.plan.first_cpu_min(self.cat.cpu_cores);
            let needs_env = first_cpu.is_some();
            if needs_env {
                match self.backend.traj_start(now, t, rt.plan.mem_gb, first_cpu) {
                    Ok(()) => rt.env_bound = true,
                    Err(_) => {
                        // environment cluster full — retry shortly
                        self.eng.schedule_in(SimDur::from_secs(5), Ev::TrajStart(t));
                        return;
                    }
                }
            } else {
                let _ = self.backend.traj_start(now, t, rt.plan.mem_gb, None);
                rt.env_bound = true;
            }
        }
        self.advance(now, t);
    }

    /// Move a trajectory forward from its current phase.
    fn advance(&mut self, now: SimTime, t: TrajId) {
        let rt = self.trajs.get_mut(&t).unwrap();
        if rt.phase >= rt.plan.phases.len() {
            self.finish_traj(now, t);
            return;
        }
        match &rt.plan.phases[rt.phase] {
            Phase::Gen(d) => {
                let d = *d;
                rt.gen += d;
                rt.phase += 1;
                self.eng.schedule_in(d, Ev::GenDone(t));
            }
            Phase::Act(tpl) => {
                let id = ActionId(self.next_action);
                self.next_action += 1;
                let spec = ActionSpec {
                    task: rt.plan.task,
                    tenant: rt.tenant,
                    trajectory: t,
                    kind: tpl.kind,
                    cost: tpl.cost.clone(),
                    key_resource: tpl.key_resource,
                    elasticity: tpl.elasticity.clone(),
                    profiled_dur: tpl.profiled_dur,
                    service: tpl.service,
                    true_dur: tpl.true_dur,
                };
                rt.phase += 1;
                let kind = spec.kind;
                let tenant = spec.tenant;
                let a = Arc::new(Action::new(id, spec, now));
                self.backend.submit(now, &a);
                self.actions.insert(id, a);
                self.waiting += 1;
                self.metrics.ledger.submitted += 1;
                self.trace(
                    now,
                    TraceKind::Submit {
                        action: id.0,
                        traj: t.0,
                        kind: kind.name().to_string(),
                        tenant: tenant.0,
                        queue_depth: self.waiting,
                    },
                );
                self.pump(now);
            }
        }
    }

    fn finish_traj(&mut self, now: SimTime, t: TrajId) {
        let rt = self.trajs.remove(&t).unwrap();
        self.backend.traj_end(now, t);
        self.trace(
            now,
            TraceKind::TrajEnd { traj: t.0, failed: rt.failed, restarts: rt.restarts },
        );
        self.metrics.trajectories.push(TrajRecord {
            id: t,
            task: rt.plan.task,
            started: rt.started,
            finished: now,
            gen_dur: rt.gen,
            tool_dur: rt.tool,
            reward_dur: rt.reward,
            failed: rt.failed,
            restarts: rt.restarts,
        });
        let wl = &mut self.wls[rt.wl];
        wl.remaining -= 1;
        if wl.remaining == 0 {
            let task = wl.workload.task.0;
            let step = wl.step;
            let rollout = now - wl.step_started;
            self.metrics.steps.push(StepRecord {
                index: wl.step,
                rollout_dur: rollout,
                train_dur: wl.workload.train_dur,
            });
            wl.step += 1;
            if wl.step < self.cfg.steps {
                let at = now + wl.workload.train_dur;
                let wli = rt.wl;
                self.eng.schedule_at(at, Ev::StepStart(wli));
            } else {
                wl.done = true;
            }
            self.trace(now, TraceKind::StepEnd { task, step, rollout_ns: rollout.0 });
        }
        // resources freed (container teardown) — others may start now
        self.pump(now);
    }

    /// Collect backend start decisions and schedule their completions.
    /// Honors the dirty-pool contract: when the backend reports no dirty
    /// pool, the drain is skipped entirely (nothing could start).
    fn pump(&mut self, now: SimTime) {
        if self.backend.has_dirty() {
            // the sink is moved out for the drain (an empty `StartedSink`
            // is allocation-free) and put back below, keeping its
            // high-water capacity across pumps — the steady-state hot path
            // allocates nothing per drain
            let mut sink = std::mem::take(&mut self.sink);
            self.backend.drain_started_into(now, &mut sink);
            for s in sink.drain() {
                let rc = self.actions.get_mut(s.action).expect("unknown started action");
                let a = Arc::get_mut(rc)
                    .expect("started action still referenced by a backend queue");
                debug_assert_eq!(a.state, ActionState::Waiting);
                a.state = ActionState::Running;
                if a.started_at.is_none() {
                    a.started_at = Some(now);
                }
                a.allocated_units = s.units;
                a.overhead += s.overhead;
                self.attempt.insert(s.action, (s.overhead, s.exec));
                self.waiting = self.waiting.saturating_sub(1);
                self.metrics.ledger.started += 1;
                self.trace(
                    now,
                    TraceKind::Start {
                        action: s.action.0,
                        units: s.units,
                        overhead_ns: s.overhead.0,
                        exec_ns: s.exec.0,
                        queue_depth: self.waiting,
                    },
                );
                self.eng.schedule_in(s.overhead + s.exec, Ev::ActionDone(s.action));
            }
            self.sink = sink;
        }
        if let Some(at) = self.backend.next_wakeup(now) {
            if at > now && self.wakeup_at.map_or(true, |w| at < w || w <= now) {
                self.eng.schedule_at(at, Ev::Wakeup);
                self.wakeup_at = Some(at);
            }
        }
    }

    fn action_done(&mut self, now: SimTime, id: ActionId) {
        let verdict = self.backend.on_complete(now, &self.actions[id]);
        let retries = self.actions[id].retry_count;
        let effective = match verdict {
            Verdict::Retry if retries >= self.cfg.max_api_retries => Verdict::Failed,
            v => v,
        };
        match effective {
            Verdict::Retry => {
                let retries = {
                    let rc = self.actions.get_mut(id).unwrap();
                    let a = Arc::get_mut(rc)
                        .expect("retried action still referenced by a backend queue");
                    a.retry_count += 1;
                    a.state = ActionState::Waiting;
                    a.retry_count
                };
                let handle = self.actions[id].clone();
                self.backend.submit(now, &handle);
                self.waiting += 1;
                self.metrics.ledger.retried += 1;
                self.trace(
                    now,
                    TraceKind::Complete { action: id.0, outcome: "retry".to_string(), retries },
                );
            }
            Verdict::Done | Verdict::Failed => {
                let failed = effective == Verdict::Failed;
                if failed {
                    self.metrics.ledger.failed += 1;
                } else {
                    self.metrics.ledger.done += 1;
                }
                let a = self.actions.remove(id).unwrap();
                let (overhead, _exec) = self.attempt.remove(&id).unwrap_or_default();
                self.trace(
                    now,
                    TraceKind::Complete {
                        action: id.0,
                        outcome: if failed { "failed" } else { "done" }.to_string(),
                        retries: a.retry_count,
                    },
                );
                self.metrics.actions.push(ActionRecord {
                    id,
                    task: a.spec.task,
                    tenant: a.spec.tenant,
                    trajectory: a.spec.trajectory,
                    kind: a.spec.kind,
                    submitted: a.submitted_at,
                    started: a.started_at.unwrap_or(now),
                    finished: now,
                    overhead,
                    units: a.allocated_units,
                    retries: a.retry_count,
                    failed,
                });
                if let Some(rt) = self.trajs.get_mut(&a.spec.trajectory) {
                    let act_dur = now - a.submitted_at;
                    match a.spec.kind {
                        ActionKind::RewardCpu | ActionKind::RewardModel => rt.reward += act_dur,
                        _ => rt.tool += act_dur,
                    }
                    if failed {
                        if rt.restarts < self.cfg.max_traj_restarts {
                            // ineffective trajectory — roll it out again
                            // (paper §6.2: failures reduce the pass rate and
                            // slow the step)
                            rt.restarts += 1;
                            rt.phase = 0;
                            self.eng.schedule_at(now, Ev::TrajStart(a.spec.trajectory));
                        } else {
                            rt.failed = true;
                            rt.phase = rt.plan.phases.len();
                            self.advance(now, a.spec.trajectory);
                        }
                    } else {
                        self.advance(now, a.spec.trajectory);
                    }
                }
            }
        }
        self.pump(now);
    }
}
