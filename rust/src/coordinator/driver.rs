//! Discrete-event experiment driver.
//!
//! Runs one or more RL tasks (workloads) through a pluggable [`Backend`]
//! under the virtual clock, reproducing the paper's training loop: each
//! step, a batch of trajectories rolls out (LLM generation interleaved with
//! external actions on the backend), then the training phase runs on the
//! internal GPU cluster, then the next step begins. Collects [`Metrics`].

use super::backend::{Backend, Verdict};
use crate::action::{Action, ActionId, ActionKind, ActionSpec, ActionState, TrajId};
use crate::metrics::{ActionRecord, Metrics, StepRecord, TrajRecord, UtilSample};
use crate::rollout::workloads::Catalog;
use crate::rollout::{Phase, Workload};
use crate::sim::{Engine, SimDur, SimTime};
use crate::util::rng::Rng;
use std::collections::HashMap;

/// Experiment-run parameters.
#[derive(Debug, Clone)]
pub struct RunCfg {
    /// Trajectories per step (the paper's "RL batch size" under GRPO).
    pub batch: usize,
    pub steps: u32,
    pub seed: u64,
    /// Utilization sampling period.
    pub sample_every: SimDur,
    /// Max transparent retries per action before it fails terminally.
    pub max_api_retries: u32,
    /// Max restarts of a trajectory that had a terminally-failed action.
    pub max_traj_restarts: u32,
}

impl Default for RunCfg {
    fn default() -> Self {
        RunCfg {
            batch: 128,
            steps: 2,
            seed: 42,
            sample_every: SimDur::from_secs(5),
            max_api_retries: 3,
            max_traj_restarts: 2,
        }
    }
}

#[derive(Debug)]
enum Ev {
    StepStart(usize),
    TrajStart(TrajId),
    GenDone(TrajId),
    ActionDone(ActionId),
    Wakeup,
    Sample,
}

struct TrajRt {
    plan: crate::rollout::TrajectoryPlan,
    wl: usize,
    phase: usize,
    started: SimTime,
    gen: SimDur,
    tool: SimDur,
    reward: SimDur,
    restarts: u32,
    failed: bool,
    env_bound: bool,
}

struct WlState {
    workload: Workload,
    step: u32,
    remaining: usize,
    step_started: SimTime,
    done: bool,
}

struct Driver<'a> {
    backend: &'a mut dyn Backend,
    cat: &'a Catalog,
    cfg: &'a RunCfg,
    eng: Engine<Ev>,
    metrics: Metrics,
    rng: Rng,
    actions: HashMap<ActionId, Action>,
    /// (overhead, exec) of the in-flight attempt
    attempt: HashMap<ActionId, (SimDur, SimDur)>,
    trajs: HashMap<TrajId, TrajRt>,
    wls: Vec<WlState>,
    next_action: u64,
    next_traj: u64,
    /// earliest already-scheduled wakeup (dedup — without this, every pump
    /// under a waiting backend would enqueue another Wakeup event and the
    /// event count explodes quadratically)
    wakeup_at: Option<SimTime>,
}

/// Run the experiment; returns collected metrics.
pub fn run(
    backend: &mut dyn Backend,
    cat: &Catalog,
    workloads: &[Workload],
    cfg: &RunCfg,
) -> Metrics {
    let mut d = Driver {
        backend,
        cat,
        cfg,
        eng: Engine::new(),
        metrics: Metrics::new(),
        rng: Rng::new(cfg.seed),
        actions: HashMap::new(),
        attempt: HashMap::new(),
        trajs: HashMap::new(),
        wls: workloads
            .iter()
            .map(|w| WlState {
                workload: w.clone(),
                step: 0,
                remaining: 0,
                step_started: SimTime::ZERO,
                done: false,
            })
            .collect(),
        next_action: 0,
        next_traj: 0,
        wakeup_at: None,
    };
    for wl in 0..d.wls.len() {
        d.eng.schedule_at(SimTime::ZERO, Ev::StepStart(wl));
    }
    d.eng.schedule_in(cfg.sample_every, Ev::Sample);
    while let Some((now, ev)) = d.eng.next() {
        d.handle(now, ev);
    }
    d.metrics
}

impl Driver<'_> {
    fn handle(&mut self, now: SimTime, ev: Ev) {
        match ev {
            Ev::StepStart(wl) => self.step_start(now, wl),
            Ev::TrajStart(t) => self.traj_start(now, t),
            Ev::GenDone(t) => {
                if self.trajs.contains_key(&t) {
                    self.advance(now, t);
                }
            }
            Ev::ActionDone(id) => self.action_done(now, id),
            Ev::Wakeup => {
                if self.wakeup_at == Some(now) {
                    self.wakeup_at = None;
                }
                self.backend.tick(now);
                self.pump(now);
            }
            Ev::Sample => {
                for (name, value) in self.backend.utilization() {
                    self.metrics.util.push(UtilSample { at: now, name, value });
                }
                if !self.wls.iter().all(|w| w.done) {
                    self.eng.schedule_in(self.cfg.sample_every, Ev::Sample);
                }
            }
        }
    }

    fn step_start(&mut self, now: SimTime, wl: usize) {
        let state = &mut self.wls[wl];
        state.step_started = now;
        state.remaining = self.cfg.batch;
        for _ in 0..self.cfg.batch {
            let t = TrajId(self.next_traj);
            self.next_traj += 1;
            let plan = self.wls[wl].workload.gen_trajectory(self.cat, &mut self.rng);
            self.trajs.insert(
                t,
                TrajRt {
                    plan,
                    wl,
                    phase: 0,
                    started: now,
                    gen: SimDur::ZERO,
                    tool: SimDur::ZERO,
                    reward: SimDur::ZERO,
                    restarts: 0,
                    failed: false,
                    env_bound: false,
                },
            );
            self.eng.schedule_at(now, Ev::TrajStart(t));
        }
    }

    fn traj_start(&mut self, now: SimTime, t: TrajId) {
        let rt = self.trajs.get_mut(&t).unwrap();
        if !rt.env_bound {
            let first_cpu = rt.plan.first_cpu_min(self.cat.cpu_cores);
            let needs_env = first_cpu.is_some();
            if needs_env {
                match self.backend.traj_start(now, t, rt.plan.mem_gb, first_cpu) {
                    Ok(()) => rt.env_bound = true,
                    Err(_) => {
                        // environment cluster full — retry shortly
                        self.eng.schedule_in(SimDur::from_secs(5), Ev::TrajStart(t));
                        return;
                    }
                }
            } else {
                let _ = self.backend.traj_start(now, t, rt.plan.mem_gb, None);
                rt.env_bound = true;
            }
        }
        self.advance(now, t);
    }

    /// Move a trajectory forward from its current phase.
    fn advance(&mut self, now: SimTime, t: TrajId) {
        let rt = self.trajs.get_mut(&t).unwrap();
        if rt.phase >= rt.plan.phases.len() {
            self.finish_traj(now, t);
            return;
        }
        match &rt.plan.phases[rt.phase] {
            Phase::Gen(d) => {
                let d = *d;
                rt.gen += d;
                rt.phase += 1;
                self.eng.schedule_in(d, Ev::GenDone(t));
            }
            Phase::Act(tpl) => {
                let id = ActionId(self.next_action);
                self.next_action += 1;
                let spec = ActionSpec {
                    task: rt.plan.task,
                    trajectory: t,
                    kind: tpl.kind,
                    cost: tpl.cost.clone(),
                    key_resource: tpl.key_resource,
                    elasticity: tpl.elasticity.clone(),
                    profiled_dur: tpl.profiled_dur,
                    service: tpl.service,
                    true_dur: tpl.true_dur,
                };
                rt.phase += 1;
                let a = Action::new(id, spec, now);
                self.backend.submit(now, &a);
                self.actions.insert(id, a);
                self.pump(now);
            }
        }
    }

    fn finish_traj(&mut self, now: SimTime, t: TrajId) {
        let rt = self.trajs.remove(&t).unwrap();
        self.backend.traj_end(now, t);
        self.metrics.trajectories.push(TrajRecord {
            id: t,
            task: rt.plan.task,
            started: rt.started,
            finished: now,
            gen_dur: rt.gen,
            tool_dur: rt.tool,
            reward_dur: rt.reward,
            failed: rt.failed,
            restarts: rt.restarts,
        });
        let wl = &mut self.wls[rt.wl];
        wl.remaining -= 1;
        if wl.remaining == 0 {
            self.metrics.steps.push(StepRecord {
                index: wl.step,
                rollout_dur: now - wl.step_started,
                train_dur: wl.workload.train_dur,
            });
            wl.step += 1;
            if wl.step < self.cfg.steps {
                let at = now + wl.workload.train_dur;
                let wli = rt.wl;
                self.eng.schedule_at(at, Ev::StepStart(wli));
            } else {
                wl.done = true;
            }
        }
        // resources freed (container teardown) — others may start now
        self.pump(now);
    }

    /// Collect backend start decisions and schedule their completions.
    fn pump(&mut self, now: SimTime) {
        let started = self.backend.drain_started(now);
        for s in started {
            let a = self.actions.get_mut(&s.action).expect("unknown started action");
            debug_assert_eq!(a.state, ActionState::Waiting);
            a.state = ActionState::Running;
            if a.started_at.is_none() {
                a.started_at = Some(now);
            }
            a.allocated_units = s.units;
            a.overhead += s.overhead;
            self.attempt.insert(s.action, (s.overhead, s.exec));
            self.eng.schedule_in(s.overhead + s.exec, Ev::ActionDone(s.action));
        }
        if let Some(at) = self.backend.next_wakeup(now) {
            if at > now && self.wakeup_at.map_or(true, |w| at < w || w <= now) {
                self.eng.schedule_at(at, Ev::Wakeup);
                self.wakeup_at = Some(at);
            }
        }
    }

    fn action_done(&mut self, now: SimTime, id: ActionId) {
        let verdict = self.backend.on_complete(now, &self.actions[&id]);
        let retries = self.actions[&id].retry_count;
        let effective = match verdict {
            Verdict::Retry if retries >= self.cfg.max_api_retries => Verdict::Failed,
            v => v,
        };
        match effective {
            Verdict::Retry => {
                let a = self.actions.get_mut(&id).unwrap();
                a.retry_count += 1;
                a.state = ActionState::Waiting;
                let snapshot = a.clone();
                self.backend.submit(now, &snapshot);
            }
            Verdict::Done | Verdict::Failed => {
                let failed = effective == Verdict::Failed;
                let a = self.actions.remove(&id).unwrap();
                let (overhead, _exec) = self.attempt.remove(&id).unwrap_or_default();
                self.metrics.actions.push(ActionRecord {
                    id,
                    task: a.spec.task,
                    trajectory: a.spec.trajectory,
                    kind: a.spec.kind,
                    submitted: a.submitted_at,
                    started: a.started_at.unwrap_or(now),
                    finished: now,
                    overhead,
                    units: a.allocated_units,
                    retries: a.retry_count,
                    failed,
                });
                if let Some(rt) = self.trajs.get_mut(&a.spec.trajectory) {
                    let act_dur = now - a.submitted_at;
                    match a.spec.kind {
                        ActionKind::RewardCpu | ActionKind::RewardModel => rt.reward += act_dur,
                        _ => rt.tool += act_dur,
                    }
                    if failed {
                        if rt.restarts < self.cfg.max_traj_restarts {
                            // ineffective trajectory — roll it out again
                            // (paper §6.2: failures reduce the pass rate and
                            // slow the step)
                            rt.restarts += 1;
                            rt.phase = 0;
                            self.eng.schedule_at(now, Ev::TrajStart(a.spec.trajectory));
                        } else {
                            rt.failed = true;
                            rt.phase = rt.plan.phases.len();
                            self.advance(now, a.spec.trajectory);
                        }
                    } else {
                        self.advance(now, a.spec.trajectory);
                    }
                }
            }
        }
        self.pump(now);
    }
}
