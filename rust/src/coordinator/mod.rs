//! The coordinator layer: backend abstraction, the ARL-Tangram coordinator,
//! and the discrete-event experiment driver.

pub mod arena;
pub mod backend;
pub mod driver;
mod parallel;
pub mod queue;
pub mod tangram;

pub use arena::ActionArena;
pub use backend::{Backend, Started, StartedSink, Verdict};
pub use driver::{run, run_session, RunCfg, Session};
pub use queue::ActionQueue;
pub use tangram::{TangramBackend, TangramCfg};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::action::TaskId;
    use crate::rollout::workloads::{Catalog, CatalogCfg, Workload, WorkloadKind};
    use crate::sim::SimDur;

    fn small_cat() -> Catalog {
        Catalog::build(&CatalogCfg {
            cpu_nodes: 2,
            cores_per_node: 32,
            gpu_nodes: 2,
            n_teachers: 4,
            ..CatalogCfg::default()
        })
    }

    fn tangram_for(cat: &Catalog) -> TangramBackend {
        TangramBackend::new(
            cat,
            TangramCfg {
                cpu_nodes: 2,
                numa_per_node: 2,
                cores_per_numa: 8, // 16 cores/node
                node_mem_gb: 256,
                gpu_nodes: 2,
                ..TangramCfg::default()
            },
        )
    }

    #[test]
    fn coding_end_to_end_completes() {
        let cat = small_cat();
        let mut be = tangram_for(&cat);
        let wl = Workload::new(TaskId(0), WorkloadKind::Coding);
        let cfg = RunCfg { batch: 16, steps: 2, seed: 7, ..RunCfg::default() };
        let m = run(&mut be, &cat, &[wl], &cfg);
        assert_eq!(m.trajectories.len(), 32);
        assert_eq!(m.steps.len(), 2);
        assert!(m.actions.len() >= 32 * 5, "n_actions {}", m.actions.len());
        assert_eq!(m.failed_actions(), 0);
        assert!(m.mean_act() > 0.0);
        // every action record is self-consistent
        for a in &m.actions {
            assert!(a.finished >= a.started);
            assert!(a.started >= a.submitted);
        }
        // cluster drained completely
        assert_eq!(be.cpu.free_cores(), 32);
        assert_eq!(be.gpu.free_gpus(), 16);
    }

    #[test]
    fn deepsearch_end_to_end_uses_apis_and_gpu() {
        let cat = small_cat();
        let mut be = tangram_for(&cat);
        let wl = Workload::new(TaskId(1), WorkloadKind::DeepSearch);
        let cfg = RunCfg { batch: 12, steps: 1, seed: 9, ..RunCfg::default() };
        let m = run(&mut be, &cat, &[wl], &cfg);
        assert_eq!(m.trajectories.len(), 12);
        let api = m
            .actions
            .iter()
            .filter(|a| a.kind == crate::action::ActionKind::ApiCall)
            .count();
        let rm = m
            .actions
            .iter()
            .filter(|a| a.kind == crate::action::ActionKind::RewardModel)
            .count();
        assert!(api >= 12 * 4, "api {api}");
        assert!(rm >= 12, "rm {rm}");
    }

    #[test]
    fn mopd_multiplexes_teachers() {
        let cat = small_cat();
        let mut be = tangram_for(&cat);
        let wl = Workload::new(TaskId(2), WorkloadKind::Mopd);
        let cfg = RunCfg { batch: 24, steps: 1, seed: 11, ..RunCfg::default() };
        let m = run(&mut be, &cat, &[wl], &cfg);
        assert_eq!(m.trajectories.len(), 24);
        assert!(be.gpu.n_cold + be.gpu.n_warm > 0);
        // multiplexing must produce some warm hits
        assert!(be.gpu.warm_ratio() > 0.05, "warm {}", be.gpu.warm_ratio());
    }

    #[test]
    fn two_tasks_share_the_gpu_pool() {
        let cat = small_cat();
        let mut be = tangram_for(&cat);
        let wls = [
            Workload::new(TaskId(1), WorkloadKind::DeepSearch),
            Workload::new(TaskId(2), WorkloadKind::Mopd),
        ];
        let cfg = RunCfg { batch: 8, steps: 1, seed: 13, ..RunCfg::default() };
        let m = run(&mut be, &cat, &wls, &cfg);
        assert_eq!(m.trajectories.len(), 16);
        assert_eq!(m.steps.len(), 2); // one per workload
        let t1 = m.actions.iter().filter(|a| a.task == TaskId(1)).count();
        let t2 = m.actions.iter().filter(|a| a.task == TaskId(2)).count();
        assert!(t1 > 0 && t2 > 0);
    }

    #[test]
    fn determinism_same_seed_same_metrics() {
        let cat = small_cat();
        let wl = Workload::new(TaskId(0), WorkloadKind::Coding);
        let cfg = RunCfg { batch: 8, steps: 1, seed: 21, ..RunCfg::default() };
        let m1 = run(&mut tangram_for(&cat), &cat, &[wl.clone()], &cfg);
        let m2 = run(&mut tangram_for(&cat), &cat, &[wl], &cfg);
        assert_eq!(m1.actions.len(), m2.actions.len());
        assert!((m1.mean_act() - m2.mean_act()).abs() < 1e-12);
        assert!((m1.mean_step_dur() - m2.mean_step_dur()).abs() < 1e-12);
    }

    #[test]
    fn completions_feed_the_duration_estimator() {
        // Satellite bugfix regression: EnvExec actions are unprofiled, so
        // the scheduler's only handle on their duration is the historical
        // EWMA — which used to be dead code (observe() never called). After
        // a run the estimator must hold observed history, not the fallback.
        let cat = small_cat();
        let mut be = tangram_for(&cat);
        let wl = Workload::new(TaskId(0), WorkloadKind::Coding);
        let cfg = RunCfg { batch: 8, steps: 1, seed: 23, ..RunCfg::default() };
        let m = run(&mut be, &cat, &[wl], &cfg);
        assert!(!m.actions.is_empty());
        let sentinel = SimDur::from_secs(123_456);
        let est = be
            .sched
            .stats
            .estimate(crate::action::ActionKind::EnvExec, sentinel);
        assert_ne!(est, sentinel, "estimator never observed a completion");
        // coding env execs are clamped to (1ms, 60s) — the EWMA of observed
        // exec durations must land inside that range
        assert!(est.secs_f64() > 0.0 && est.secs_f64() <= 60.0, "{est:?}");
    }

    #[test]
    fn autoscaler_resize_composes_with_injected_faults() {
        // Fault injections and autoscaler resizes own separate factors and
        // the substrate sees their product — a scale-up must never cancel a
        // provider fault, and a fault restore must never undo a scale-down.
        use crate::autoscale::{LaneKey, PoolClass};
        use crate::scenario::ScenarioEvent;
        use crate::sim::SimTime;
        let cat = small_cat();
        let mut be = tangram_for(&cat); // 2 nodes × 16 = 32 cores
        let t = SimTime::ZERO;
        assert!(be.inject(t, &ScenarioEvent::CpuPoolScale { factor: 0.5 }));
        // autoscaler squeezes the faulted pool further: 0.5 × 0.5 = 0.25
        assert_eq!(be.resize(t, LaneKey::class_wide(PoolClass::Cpu), 0.5), Some(8));
        // fault restores, autoscaler factor survives: capacity = 0.5 × 32
        assert!(be.inject(t, &ScenarioEvent::CpuPoolScale { factor: 1.0 }));
        assert_eq!(be.cpu.total_cores() - be.cpu.cordoned_cores() as u64, 16);
        // autoscaler restores under no fault → the full pool returns
        assert_eq!(be.resize(t, LaneKey::class_wide(PoolClass::Cpu), 1.0), Some(32));
        // API side: a provider flap survives an autoscaler scale-up
        let lanes0 = be.provisioned_lanes();
        assert!(be.inject(t, &ScenarioEvent::ApiLimitScale { factor: 0.5 }));
        let flapped = be.provisioned_lanes();
        assert!(flapped < lanes0);
        let after = be.resize(t, LaneKey::class_wide(PoolClass::Api), 1.0).unwrap();
        assert_eq!(after, flapped, "scale-up must not cancel the provider fault");
    }

    #[test]
    fn gpu_resize_composes_with_flushes_and_fault_restores() {
        // The PoolClass::Gpu mirror of the CPU/API composition regression:
        // a gpu_cache_flush injected mid-scale-down must not cancel the
        // autoscale factor, a gpu_pool_scale fault composes (product), and
        // a fault restore must not undo the autoscaler's scale-down.
        use crate::autoscale::{LaneKey, PoolClass};
        use crate::scenario::ScenarioEvent;
        use crate::sim::SimTime;
        let cat = small_cat();
        let mut be = TangramBackend::new(
            &cat,
            TangramCfg {
                cpu_nodes: 2,
                numa_per_node: 2,
                cores_per_numa: 8,
                node_mem_gb: 256,
                gpu_nodes: 4, // 32 GPUs
                ..TangramCfg::default()
            },
        );
        let t = SimTime::ZERO;
        assert_eq!(be.gpu.provisioned_gpus(), 32);
        // autoscaler cordons half the nodes
        assert_eq!(be.resize(t, LaneKey::class_wide(PoolClass::Gpu), 0.5), Some(16));
        assert_eq!(be.gpu.cordoned_nodes(), 2);
        // a cache flush mid-scale-down drops residencies but NOT cordons
        assert!(be.inject(t, &ScenarioEvent::GpuCacheFlush));
        assert_eq!(be.gpu.cordoned_nodes(), 2, "flush must not cancel the scale-down");
        assert_eq!(be.gpu.provisioned_gpus(), 16);
        // a provider-side squeeze composes: 0.5 × 0.5 = 0.25 → 1 node
        assert!(be.inject(t, &ScenarioEvent::GpuPoolScale { factor: 0.5 }));
        assert_eq!(be.gpu.provisioned_gpus(), 8);
        // fault restores, the autoscaler's scale-down survives: 0.5 × 32
        assert!(be.inject(t, &ScenarioEvent::GpuPoolScale { factor: 1.0 }));
        assert_eq!(be.gpu.provisioned_gpus(), 16, "fault restore must not undo it");
        // autoscaler restores under no fault → the full pool returns
        assert_eq!(be.resize(t, LaneKey::class_wide(PoolClass::Gpu), 1.0), Some(32));
        assert_eq!(be.gpu.cordoned_nodes(), 0);
    }

    #[test]
    fn api_endpoints_resize_independently() {
        use crate::autoscale::{LaneKey, PoolClass, PoolPressure};
        use crate::sim::SimTime;
        let cat = small_cat();
        let mut be = tangram_for(&cat);
        let t = SimTime::ZERO;
        let rows: Vec<PoolPressure> = be.scale_classes();
        // one row per class target: cpu, gpu, then one per endpoint sorted
        // by endpoint kind id
        assert_eq!(rows[0].key.class, PoolClass::Cpu);
        assert_eq!(rows[1].key.class, PoolClass::Gpu);
        let eps: Vec<u32> = rows[2..].iter().map(|r| r.key.endpoint.unwrap()).collect();
        assert_eq!(rows[2..].len(), cat.api.len());
        let mut sorted = eps.clone();
        sorted.sort_unstable();
        assert_eq!(eps, sorted, "endpoint rows must be sorted by kind id");
        // squeeze only the first endpoint: its lanes shrink, the rest stay
        let lanes0 = be.provisioned_lanes();
        let first = eps[0];
        let after = be.resize(t, LaneKey::endpoint(PoolClass::Api, first), 0.25).unwrap();
        assert!(after < lanes0);
        let rows2 = be.scale_classes();
        let row_first = rows2.iter().find(|r| r.key.endpoint == Some(first)).unwrap();
        assert!(row_first.provisioned_units < row_first.baseline_units);
        for r in rows2.iter().filter(|r| r.key.class == PoolClass::Api) {
            if r.key.endpoint != Some(first) {
                assert_eq!(
                    r.provisioned_units, r.baseline_units,
                    "untouched endpoints must keep their static provision"
                );
            }
        }
        // restoring the endpoint returns the full lane count
        assert_eq!(be.resize(t, LaneKey::endpoint(PoolClass::Api, first), 1.0), Some(lanes0));
    }

    #[test]
    fn small_window_still_makes_progress() {
        // queue far larger than the candidate window
        let cat = small_cat();
        let mut be = tangram_for(&cat);
        let wl = Workload::new(TaskId(2), WorkloadKind::Mopd);
        let cfg = RunCfg {
            batch: 64,
            steps: 1,
            seed: 17,
            ..RunCfg::default()
        };
        let m = run(&mut be, &cat, &[wl], &cfg);
        assert_eq!(m.trajectories.len(), 64);
        assert_eq!(m.failed_actions(), 0);
    }

    #[test]
    fn full_sweep_index_survives_a_scheduling_panic() {
        // Regression for the full-sweep drain's cached pool index: the old
        // take/put-back idiom (`mem::take(&mut self.all_pools)` … restore)
        // lost the index on any unwind out of `schedule_pool`, after which
        // every full-sweep drain silently scheduled zero pools. The drain
        // now walks the cache in place, so an unwind leaves it intact.
        use crate::action::{
            Action, ActionId, ActionKind, ActionSpec, CostSpec, DimCost, ElasticityModel,
            TenantId, TrajId,
        };
        use crate::sim::SimTime;
        use std::panic::{catch_unwind, AssertUnwindSafe};
        use std::sync::Arc;
        let cat = small_cat();
        let mut be = TangramBackend::new(
            &cat,
            TangramCfg {
                cpu_nodes: 2,
                numa_per_node: 2,
                cores_per_numa: 8,
                node_mem_gb: 256,
                gpu_nodes: 2,
                full_sweep: true,
                ..TangramCfg::default()
            },
        );
        let pools_before = be.pool_count();
        assert!(pools_before > 0);
        // a GPU-cost action with no service id: the GPU arm of
        // `schedule_pool` panics on it ("GPU action without service")
        let poisoned = Arc::new(Action::new(
            ActionId(1),
            ActionSpec {
                task: TaskId(0),
                tenant: TenantId(0),
                trajectory: TrajId(1),
                kind: ActionKind::RewardModel,
                cost: CostSpec::single(&cat.registry, cat.gpu_units, DimCost::Fixed(1)),
                key_resource: Some(cat.gpu_units),
                elasticity: ElasticityModel::None,
                profiled_dur: Some(SimDur::from_secs(1)),
                service: None,
                true_dur: SimDur::from_secs(1),
            },
            SimTime::ZERO,
        ));
        be.gpu.queue.push_back(poisoned);
        let unwound = catch_unwind(AssertUnwindSafe(|| be.drain_started(SimTime::ZERO)));
        assert!(unwound.is_err(), "the poisoned action must panic the sweep");
        assert_eq!(be.pool_count(), pools_before, "pool index lost on unwind");
        // with the poison removed, the backend keeps working
        let _ = be.gpu.queue.pop_front();
        let started = be.drain_started(SimTime::ZERO);
        assert!(started.is_empty(), "recovered drain runs clean on empty queues");
    }

    #[test]
    fn sharded_drain_matches_serial_metrics() {
        // Worker-count independence at the metrics level: contiguous shard
        // chunks processed in ascending order visit pools exactly like the
        // serial drain, so every decision — and thus every derived metric —
        // is identical for any shard count, including counts far above the
        // pool count. (Byte-level trace parity lives in scenario::replay.)
        let cat = small_cat();
        let wls = [
            Workload::new(TaskId(1), WorkloadKind::DeepSearch),
            Workload::new(TaskId(2), WorkloadKind::Mopd),
        ];
        let cfg = RunCfg { batch: 12, steps: 1, seed: 31, ..RunCfg::default() };
        let serial = run(&mut tangram_for(&cat), &cat, &wls, &cfg);
        for shards in [2usize, 3, 8, 64] {
            let mut be = tangram_for(&cat);
            be.set_shards(shards);
            let m = run(&mut be, &cat, &wls, &cfg);
            assert_eq!(m.actions.len(), serial.actions.len(), "shards={shards}");
            assert_eq!(
                m.mean_act().to_bits(),
                serial.mean_act().to_bits(),
                "shards={shards}"
            );
            assert_eq!(
                m.mean_step_dur().to_bits(),
                serial.mean_step_dur().to_bits(),
                "shards={shards}"
            );
        }
    }

    #[test]
    fn threaded_drain_matches_serial_metrics() {
        // Worker-thread independence: the pool only runs the read-only
        // decide half of a drain and plans apply in ascending shard order,
        // so every decision — and thus every derived metric — is identical
        // for any thread count, including counts above the shard count.
        let cat = small_cat();
        let wls = [
            Workload::new(TaskId(1), WorkloadKind::DeepSearch),
            Workload::new(TaskId(2), WorkloadKind::Mopd),
        ];
        let cfg = RunCfg { batch: 12, steps: 1, seed: 31, ..RunCfg::default() };
        let serial = run(&mut tangram_for(&cat), &cat, &wls, &cfg);
        for threads in [2usize, 4, 16] {
            let mut be = tangram_for(&cat);
            be.set_shards(4);
            be.set_threads(threads);
            let m = run(&mut be, &cat, &wls, &cfg);
            assert_eq!(m.actions.len(), serial.actions.len(), "threads={threads}");
            assert_eq!(
                m.mean_act().to_bits(),
                serial.mean_act().to_bits(),
                "threads={threads}"
            );
            assert_eq!(
                m.mean_step_dur().to_bits(),
                serial.mean_step_dur().to_bits(),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn utilization_sampled() {
        let cat = small_cat();
        let mut be = tangram_for(&cat);
        let wl = Workload::new(TaskId(0), WorkloadKind::Coding);
        let cfg = RunCfg {
            batch: 8,
            steps: 1,
            seed: 3,
            sample_every: SimDur::from_secs(2),
            ..RunCfg::default()
        };
        let m = run(&mut be, &cat, &[wl], &cfg);
        assert!(m.util.iter().any(|u| u.name == "cpu"));
        assert!(m.mean_util("cpu") > 0.0);
    }
}
