//! Scoped worker pool for the threaded sharded drain.
//!
//! The only place in the tree allowed to spawn threads (the determinism
//! lint's `ambient-threads` rule allowlists exactly this file): ambient
//! parallelism anywhere else could reorder observable decisions, while
//! this pool runs only the *read-only* decide half of a drain
//! ([`TangramBackend::decide_pool`]) and hands every plan back to the
//! driver thread, which applies them in ascending shard order —
//! byte-identical to the serial drain for any worker count.
//!
//! Workers are scoped (`std::thread::scope`), spawned per drain, and share
//! `&TangramBackend` immutably; each worker owns a contiguous range of
//! shards (cut with the same balanced formula as the shard slices
//! themselves), so segment `s` of the returned vector always holds shard
//! `s`'s plans regardless of which worker produced it.

use super::tangram::{shard_slice, PoolPlan, TangramBackend};
use crate::lanes::PoolId;
use crate::sim::SimTime;

/// Decide every shard slice of `pools` on up to `workers` scoped threads.
///
/// Returns one segment per shard, in ascending shard order: the
/// `(pool, plan)` pairs of that shard's contiguous pool slice, in slice
/// order. Concatenating the segments therefore reproduces the serial
/// sorted-pool visit order exactly. A panicking worker is resumed on the
/// caller's thread with its original payload.
pub(crate) fn decide_shards(
    be: &TangramBackend,
    now: SimTime,
    pools: &[PoolId],
    shards: usize,
    workers: usize,
) -> Vec<Vec<(PoolId, PoolPlan)>> {
    let mut segments: Vec<Vec<(PoolId, PoolPlan)>> = Vec::new();
    segments.resize_with(shards, Vec::new);
    let workers = workers.min(shards).max(1);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        let mut rest = segments.as_mut_slice();
        let mut lo = 0usize;
        for w in 0..workers {
            // Contiguous worker ranges over the shard list; slices tile the
            // list in order, so the previous range's `hi` is this one's
            // `lo` and `rest` can be split off front-to-back.
            let (_, hi) = shard_slice(shards, w, workers);
            let (mine, tail) = rest.split_at_mut(hi - lo);
            rest = tail;
            let base = lo;
            lo = hi;
            handles.push(scope.spawn(move || {
                for (offset, segment) in mine.iter_mut().enumerate() {
                    let shard = base + offset;
                    let (plo, phi) = shard_slice(pools.len(), shard, shards);
                    segment.reserve(phi - plo);
                    for &pool in &pools[plo..phi] {
                        segment.push((pool, be.decide_pool(now, pool)));
                    }
                }
            }));
        }
        for handle in handles {
            if let Err(payload) = handle.join() {
                // surface worker panics on the driver thread with the
                // original payload instead of a bare join-failure message
                std::panic::resume_unwind(payload);
            }
        }
    });
    segments
}
