//! Deterministic weighted-fair waiting queue over shared action handles.
//!
//! The coordinator's hot path used to keep `Vec<Action>` queues: `remove(0)`
//! shifted the whole tail on every admission, positional removal re-shifted
//! it on every scheduler decision, and every submit/retry cloned a full
//! `Action` (spec, cost vectors, elasticity model). [`ActionQueue`] replaces
//! that with a `VecDeque<Arc<Action>>` — pops are O(1), queue entries are
//! 8-byte handles — plus an id index so decisions for actions that already
//! left the queue (topology raced) are rejected in O(1).
//!
//! # Weighted fair queueing (multi-tenant)
//!
//! With several RL jobs sharing one lane, plain FCFS lets a bursty tenant
//! park a wall of actions in front of everyone else's. The queue therefore
//! orders entries by a per-tenant **virtual finish time**: each push charges
//! the tenant `WFQ_SCALE / weight` virtual units past the later of the
//! queue's virtual clock and the tenant's previous finish, and entries sort
//! by `(finish, tenant, action id)` — a fully deterministic order (ties
//! broken by tenant id, then action id; no wall clock, no hashing).
//!
//! **Single-tenant degeneracy (the golden-trace invariant):** with one
//! tenant every push lands strictly after the tenant's previous finish, so
//! the sort order is exactly arrival order — byte-for-byte FCFS. All
//! pre-tenancy scenarios therefore replay unchanged. `set_fcfs(true)`
//! forces plain arrival order even with many tenants (the differential
//! baseline the fairness tests compare against).

use crate::action::{Action, ActionId, ActionKind};
use std::collections::{BTreeMap, HashSet, VecDeque};
use std::sync::Arc;

/// Virtual-time units one weight-1 push costs. Large enough that integer
/// division by any sane weight keeps distinct per-tenant finish spacing.
const WFQ_SCALE: u64 = 1 << 20;

/// Index of an [`ActionKind`] into the per-kind unprofiled counters.
fn kind_index(k: ActionKind) -> usize {
    match k {
        ActionKind::EnvExec => 0,
        ActionKind::RewardCpu => 1,
        ActionKind::RewardModel => 2,
        ActionKind::ApiCall => 3,
    }
}

/// Weighted-fair queue of waiting actions, indexed by [`ActionId`].
#[derive(Debug, Default)]
pub struct ActionQueue {
    items: VecDeque<Arc<Action>>,
    /// `(virtual finish, tenant, action id)` per entry, aligned with
    /// `items` — the deterministic service order.
    keys: VecDeque<(u64, u32, u64)>,
    ids: HashSet<ActionId>,
    /// The queue's virtual clock: advances to the finish tag of every
    /// serviced entry, so an idle tenant re-enters at the present instead
    /// of back-filling virtual history.
    vtime: u64,
    /// Last assigned virtual finish per tenant.
    last_finish: BTreeMap<u32, u64>,
    /// WFQ weight per tenant (absent ⇒ 1).
    weights: BTreeMap<u32, u64>,
    /// Plain arrival order, ignoring tenants (differential baseline).
    fcfs: bool,
    /// Arrival sequence for `fcfs` keys.
    seq: u64,
    /// Queued actions per kind with no profiled duration. The scheduler
    /// estimates these from the historical-average EWMA, so a pool holding
    /// any must be re-dirtied when that kind's EWMA moves (the dirty-pool
    /// contract's only cross-pool coupling).
    unprofiled: [usize; 4],
}

impl ActionQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Install per-tenant WFQ weights (weights below 1 are clamped to 1;
    /// tenants not listed default to weight 1). Installing on a non-empty
    /// queue is unsupported — weights are a session-construction knob.
    pub fn set_weights(&mut self, weights: &[(u32, u32)]) {
        debug_assert!(self.items.is_empty(), "weights installed mid-flight");
        self.weights = weights.iter().map(|&(t, w)| (t, (w as u64).max(1))).collect();
    }

    /// Force plain arrival order (ignoring tenants). The fairness tests'
    /// differential baseline; never used by production backends unless the
    /// scenario explicitly opts out of WFQ.
    pub fn set_fcfs(&mut self, fcfs: bool) {
        debug_assert!(self.items.is_empty(), "ordering mode flipped mid-flight");
        self.fcfs = fcfs;
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn contains(&self, id: ActionId) -> bool {
        self.ids.contains(&id)
    }

    /// Queued actions of `kind` whose duration the scheduler can only
    /// estimate from the historical-average EWMA.
    pub fn has_unprofiled(&self, kind: ActionKind) -> bool {
        self.unprofiled[kind_index(kind)] > 0
    }

    fn track(&mut self, action: &Action, delta: isize) {
        if action.spec.profiled_dur.is_none() {
            let slot = &mut self.unprofiled[kind_index(action.spec.kind)];
            *slot = slot.checked_add_signed(delta).expect("unprofiled count underflow");
        }
    }

    /// Enqueue in service order: WFQ virtual-finish position (single-tenant
    /// degenerates to the tail, i.e. FCFS), or the plain tail under
    /// `set_fcfs(true)`. The name predates tenancy — callers still say
    /// "push_back" for "submit".
    pub fn push_back(&mut self, action: Arc<Action>) {
        debug_assert!(!self.ids.contains(&action.id), "duplicate queue entry");
        self.ids.insert(action.id);
        self.track(&action, 1);
        if self.fcfs {
            self.seq += 1;
            self.keys.push_back((self.seq, action.spec.tenant.0, action.id.0));
            self.items.push_back(action);
            return;
        }
        let tenant = action.spec.tenant.0;
        let weight = self.weights.get(&tenant).copied().unwrap_or(1);
        let prev = self.last_finish.get(&tenant).copied().unwrap_or(0);
        let start = self.vtime.max(prev);
        let finish = start + WFQ_SCALE / weight;
        self.last_finish.insert(tenant, finish);
        let key = (finish, tenant, action.id.0);
        let idx = self.keys.partition_point(|k| k < &key);
        self.keys.insert(idx, key);
        self.items.insert(idx, action);
    }

    /// The service-order head, if any.
    pub fn front(&self) -> Option<&Action> {
        self.items.front().map(|a| a.as_ref())
    }

    /// Dequeue the service-order head.
    pub fn pop_front(&mut self) -> Option<Arc<Action>> {
        let a = self.items.pop_front()?;
        if let Some(k) = self.keys.pop_front() {
            self.vtime = self.vtime.max(k.0);
        }
        self.ids.remove(&a.id);
        self.track(&a, -1);
        Some(a)
    }

    /// Shared handle for a queued action (`None` if it already left the
    /// queue — the id index makes the miss O(1)).
    pub fn get(&self, id: ActionId) -> Option<&Arc<Action>> {
        if !self.ids.contains(&id) {
            return None;
        }
        self.items.iter().find(|a| a.id == id)
    }

    /// Remove a queued action by id (scheduler decisions apply out of
    /// service order within one drain). Servicing mid-queue advances the
    /// virtual clock exactly like a head pop — the entry was served.
    pub fn remove(&mut self, id: ActionId) -> Option<Arc<Action>> {
        if !self.ids.remove(&id) {
            return None;
        }
        let idx = self
            .items
            .iter()
            .position(|a| a.id == id)
            .expect("queue id index out of sync");
        let a = self.items.remove(idx)?;
        if let Some(k) = self.keys.remove(idx) {
            self.vtime = self.vtime.max(k.0);
        }
        self.track(&a, -1);
        Some(a)
    }

    /// Borrowed service-order view for the scheduler (`&[&Action]`).
    pub fn refs(&self) -> Vec<&Action> {
        self.items.iter().map(|a| a.as_ref()).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Arc<Action>> {
        self.items.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::action::{
        ActionKind, ActionSpec, CostSpec, DimCost, ElasticityModel, ResourceClass,
        ResourceRegistry, TaskId, TenantId, TrajId,
    };
    use crate::sim::{SimDur, SimTime};

    fn mk(id: u64) -> Arc<Action> {
        mk_tenant(id, 0)
    }

    fn mk_tenant(id: u64, tenant: u32) -> Arc<Action> {
        let mut reg = ResourceRegistry::new();
        let cpu = reg.register("cpu", ResourceClass::CpuCores, 8);
        Arc::new(Action::new(
            ActionId(id),
            ActionSpec {
                task: TaskId(0),
                tenant: TenantId(tenant),
                trajectory: TrajId(id),
                kind: ActionKind::EnvExec,
                cost: CostSpec::single(&reg, cpu, DimCost::Fixed(1)),
                key_resource: Some(cpu),
                elasticity: ElasticityModel::None,
                profiled_dur: None,
                service: None,
                true_dur: SimDur::from_secs(1),
            },
            SimTime::ZERO,
        ))
    }

    #[test]
    fn fifo_order_and_id_index() {
        let mut q = ActionQueue::new();
        for i in 0..4 {
            q.push_back(mk(i));
        }
        assert_eq!(q.len(), 4);
        assert!(q.contains(ActionId(2)));
        assert_eq!(q.front().unwrap().id, ActionId(0));
        let refs = q.refs();
        assert_eq!(refs.iter().map(|a| a.id.0).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(q.pop_front().unwrap().id, ActionId(0));
        assert!(!q.contains(ActionId(0)));
    }

    #[test]
    fn remove_by_id_keeps_relative_order() {
        let mut q = ActionQueue::new();
        for i in 0..5 {
            q.push_back(mk(i));
        }
        assert_eq!(q.remove(ActionId(2)).unwrap().id, ActionId(2));
        assert!(q.remove(ActionId(2)).is_none(), "second removal is a miss");
        assert!(q.get(ActionId(2)).is_none());
        let order: Vec<u64> = q.iter().map(|a| a.id.0).collect();
        assert_eq!(order, vec![0, 1, 3, 4]);
        assert_eq!(q.get(ActionId(3)).unwrap().id, ActionId(3));
    }

    #[test]
    fn unprofiled_counts_track_membership() {
        // mk() builds unprofiled EnvExec actions — the counter must follow
        // every push/pop/remove so the EWMA re-dirty coupling stays exact.
        let mut q = ActionQueue::new();
        assert!(!q.has_unprofiled(ActionKind::EnvExec));
        for i in 0..3 {
            q.push_back(mk(i));
        }
        assert!(q.has_unprofiled(ActionKind::EnvExec));
        assert!(!q.has_unprofiled(ActionKind::ApiCall), "kind-precise tracking");
        let _ = q.pop_front();
        let _ = q.remove(ActionId(1));
        assert!(q.has_unprofiled(ActionKind::EnvExec));
        let _ = q.remove(ActionId(2));
        assert!(!q.has_unprofiled(ActionKind::EnvExec), "drained queue has none");
    }

    #[test]
    fn queue_holds_handles_not_clones() {
        let mut q = ActionQueue::new();
        let a = mk(7);
        q.push_back(a.clone());
        assert_eq!(Arc::strong_count(&a), 2);
        let back = q.pop_front().unwrap();
        assert!(Arc::ptr_eq(&a, &back), "queue must hand back the same allocation");
    }

    fn drain_order(q: &mut ActionQueue) -> Vec<u64> {
        let mut out = Vec::new();
        while let Some(a) = q.pop_front() {
            out.push(a.id.0);
        }
        out
    }

    #[test]
    fn single_tenant_wfq_is_exactly_fcfs() {
        // the golden-trace invariant: with one tenant (any weight, any
        // interleaving of pops and pushes) WFQ order IS arrival order
        let mut wfq = ActionQueue::new();
        wfq.set_weights(&[(0, 3)]);
        let mut fcfs = ActionQueue::new();
        fcfs.set_fcfs(true);
        for i in 0..3 {
            wfq.push_back(mk(i));
            fcfs.push_back(mk(i));
        }
        assert_eq!(wfq.pop_front().unwrap().id.0, fcfs.pop_front().unwrap().id.0);
        for i in 3..6 {
            wfq.push_back(mk(i));
            fcfs.push_back(mk(i));
        }
        assert_eq!(drain_order(&mut wfq), drain_order(&mut fcfs));
    }

    #[test]
    fn wfq_interleaves_tenants_by_weight() {
        // tenant 0 parks a burst of 6 first; tenant 1 then submits 3. Under
        // FCFS tenant 1 waits out the whole burst; under 1:1 WFQ its first
        // action is serviced after exactly one more tenant-0 action.
        let mut q = ActionQueue::new();
        for i in 0..6 {
            q.push_back(mk_tenant(i, 0));
        }
        // pop one so vtime advances to tenant 0's first finish
        assert_eq!(q.pop_front().unwrap().id.0, 0);
        for i in 10..13 {
            q.push_back(mk_tenant(i, 1));
        }
        let order = drain_order(&mut q);
        let pos_first_t1 = order.iter().position(|&id| id == 10).unwrap();
        assert!(
            pos_first_t1 <= 1,
            "late tenant must not wait out the parked burst, order {order:?}"
        );
        // both tenants drain alternately from the interleave point on
        assert_eq!(order, vec![1, 10, 2, 11, 3, 12, 4, 5]);
    }

    #[test]
    fn wfq_weights_bias_the_interleave() {
        // weight 2 vs 1: tenant 0 gets two slots per tenant-1 slot
        let mut q = ActionQueue::new();
        q.set_weights(&[(0, 2), (1, 1)]);
        for i in 0..4 {
            q.push_back(mk_tenant(i, 0));
        }
        for i in 10..12 {
            q.push_back(mk_tenant(i, 1));
        }
        let order = drain_order(&mut q);
        assert_eq!(order, vec![0, 1, 10, 2, 3, 11]);
    }

    #[test]
    fn wfq_ties_break_by_tenant_then_id() {
        // equal weights, simultaneous first pushes: finishes tie, the lower
        // tenant id wins, then action id within a tenant
        let mut q = ActionQueue::new();
        q.push_back(mk_tenant(5, 1));
        q.push_back(mk_tenant(4, 0));
        let order = drain_order(&mut q);
        assert_eq!(order, vec![4, 5]);
    }

    #[test]
    fn fcfs_mode_ignores_tenants() {
        let mut q = ActionQueue::new();
        q.set_fcfs(true);
        q.set_weights(&[(0, 8), (1, 1)]);
        for i in 0..3 {
            q.push_back(mk_tenant(i, 1));
        }
        q.push_back(mk_tenant(3, 0));
        assert_eq!(drain_order(&mut q), vec![0, 1, 2, 3]);
    }
}
