//! FCFS waiting queue over shared action handles.
//!
//! The coordinator's hot path used to keep `Vec<Action>` queues: `remove(0)`
//! shifted the whole tail on every admission, positional removal re-shifted
//! it on every scheduler decision, and every submit/retry cloned a full
//! `Action` (spec, cost vectors, elasticity model). [`ActionQueue`] replaces
//! that with a `VecDeque<Rc<Action>>` — pops are O(1), queue entries are
//! 8-byte handles — plus an id index so decisions for actions that already
//! left the queue (topology raced) are rejected in O(1).

use crate::action::{Action, ActionId, ActionKind};
use std::collections::{HashSet, VecDeque};
use std::rc::Rc;

/// Index of an [`ActionKind`] into the per-kind unprofiled counters.
fn kind_index(k: ActionKind) -> usize {
    match k {
        ActionKind::EnvExec => 0,
        ActionKind::RewardCpu => 1,
        ActionKind::RewardModel => 2,
        ActionKind::ApiCall => 3,
    }
}

/// FCFS queue of waiting actions, indexed by [`ActionId`].
#[derive(Debug, Default)]
pub struct ActionQueue {
    items: VecDeque<Rc<Action>>,
    ids: HashSet<ActionId>,
    /// Queued actions per kind with no profiled duration. The scheduler
    /// estimates these from the historical-average EWMA, so a pool holding
    /// any must be re-dirtied when that kind's EWMA moves (the dirty-pool
    /// contract's only cross-pool coupling).
    unprofiled: [usize; 4],
}

impl ActionQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn contains(&self, id: ActionId) -> bool {
        self.ids.contains(&id)
    }

    /// Queued actions of `kind` whose duration the scheduler can only
    /// estimate from the historical-average EWMA.
    pub fn has_unprofiled(&self, kind: ActionKind) -> bool {
        self.unprofiled[kind_index(kind)] > 0
    }

    fn track(&mut self, action: &Action, delta: isize) {
        if action.spec.profiled_dur.is_none() {
            let slot = &mut self.unprofiled[kind_index(action.spec.kind)];
            *slot = slot.checked_add_signed(delta).expect("unprofiled count underflow");
        }
    }

    /// Enqueue at the tail (FCFS order = submit order).
    pub fn push_back(&mut self, action: Rc<Action>) {
        debug_assert!(!self.ids.contains(&action.id), "duplicate queue entry");
        self.ids.insert(action.id);
        self.track(&action, 1);
        self.items.push_back(action);
    }

    /// The FCFS head, if any.
    pub fn front(&self) -> Option<&Action> {
        self.items.front().map(|a| a.as_ref())
    }

    /// Dequeue the FCFS head.
    pub fn pop_front(&mut self) -> Option<Rc<Action>> {
        let a = self.items.pop_front()?;
        self.ids.remove(&a.id);
        self.track(&a, -1);
        Some(a)
    }

    /// Shared handle for a queued action (`None` if it already left the
    /// queue — the id index makes the miss O(1)).
    pub fn get(&self, id: ActionId) -> Option<&Rc<Action>> {
        if !self.ids.contains(&id) {
            return None;
        }
        self.items.iter().find(|a| a.id == id)
    }

    /// Remove a queued action by id (scheduler decisions apply out of FCFS
    /// order within one drain).
    pub fn remove(&mut self, id: ActionId) -> Option<Rc<Action>> {
        if !self.ids.remove(&id) {
            return None;
        }
        let idx = self
            .items
            .iter()
            .position(|a| a.id == id)
            .expect("queue id index out of sync");
        let a = self.items.remove(idx)?;
        self.track(&a, -1);
        Some(a)
    }

    /// Borrowed FCFS view for the scheduler (`&[&Action]`).
    pub fn refs(&self) -> Vec<&Action> {
        self.items.iter().map(|a| a.as_ref()).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Rc<Action>> {
        self.items.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::action::{
        ActionKind, ActionSpec, CostSpec, DimCost, ElasticityModel, ResourceClass,
        ResourceRegistry, TaskId, TrajId,
    };
    use crate::sim::{SimDur, SimTime};

    fn mk(id: u64) -> Rc<Action> {
        let mut reg = ResourceRegistry::new();
        let cpu = reg.register("cpu", ResourceClass::CpuCores, 8);
        Rc::new(Action::new(
            ActionId(id),
            ActionSpec {
                task: TaskId(0),
                trajectory: TrajId(id),
                kind: ActionKind::EnvExec,
                cost: CostSpec::single(&reg, cpu, DimCost::Fixed(1)),
                key_resource: Some(cpu),
                elasticity: ElasticityModel::None,
                profiled_dur: None,
                service: None,
                true_dur: SimDur::from_secs(1),
            },
            SimTime::ZERO,
        ))
    }

    #[test]
    fn fifo_order_and_id_index() {
        let mut q = ActionQueue::new();
        for i in 0..4 {
            q.push_back(mk(i));
        }
        assert_eq!(q.len(), 4);
        assert!(q.contains(ActionId(2)));
        assert_eq!(q.front().unwrap().id, ActionId(0));
        let refs = q.refs();
        assert_eq!(refs.iter().map(|a| a.id.0).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(q.pop_front().unwrap().id, ActionId(0));
        assert!(!q.contains(ActionId(0)));
    }

    #[test]
    fn remove_by_id_keeps_relative_order() {
        let mut q = ActionQueue::new();
        for i in 0..5 {
            q.push_back(mk(i));
        }
        assert_eq!(q.remove(ActionId(2)).unwrap().id, ActionId(2));
        assert!(q.remove(ActionId(2)).is_none(), "second removal is a miss");
        assert!(q.get(ActionId(2)).is_none());
        let order: Vec<u64> = q.iter().map(|a| a.id.0).collect();
        assert_eq!(order, vec![0, 1, 3, 4]);
        assert_eq!(q.get(ActionId(3)).unwrap().id, ActionId(3));
    }

    #[test]
    fn unprofiled_counts_track_membership() {
        // mk() builds unprofiled EnvExec actions — the counter must follow
        // every push/pop/remove so the EWMA re-dirty coupling stays exact.
        let mut q = ActionQueue::new();
        assert!(!q.has_unprofiled(ActionKind::EnvExec));
        for i in 0..3 {
            q.push_back(mk(i));
        }
        assert!(q.has_unprofiled(ActionKind::EnvExec));
        assert!(!q.has_unprofiled(ActionKind::ApiCall), "kind-precise tracking");
        let _ = q.pop_front();
        let _ = q.remove(ActionId(1));
        assert!(q.has_unprofiled(ActionKind::EnvExec));
        let _ = q.remove(ActionId(2));
        assert!(!q.has_unprofiled(ActionKind::EnvExec), "drained queue has none");
    }

    #[test]
    fn queue_holds_handles_not_clones() {
        let mut q = ActionQueue::new();
        let a = mk(7);
        q.push_back(a.clone());
        assert_eq!(Rc::strong_count(&a), 2);
        let back = q.pop_front().unwrap();
        assert!(Rc::ptr_eq(&a, &back), "queue must hand back the same allocation");
    }
}
