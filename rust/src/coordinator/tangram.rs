//! The ARL-Tangram coordinator backend: unified action queue + elastic
//! scheduler + heterogeneous resource managers (paper Fig. 4).
//!
//! Routing: CPU actions go to the per-node queue of their trajectory's
//! bound node (per-node scheduling, §5.2); GPU service actions go to the
//! cluster-wide GPU queue; API actions go to per-endpoint queues under
//! Basic-manager admission. Every queue is FCFS and scheduled with the same
//! elastic algorithm (§4.2).
//!
//! Scheduling is **dirty-pool incremental** (see the contract on
//! [`Backend`]): each pump re-runs the elastic scheduler only over pools
//! whose state changed — a completion on one CPU node no longer rescans
//! every node, the GPU cluster, and every API endpoint. Pools are drained
//! in sorted [`PoolId`] order so same-timestamp decisions (and therefore
//! recorded scenario traces) stay byte-deterministic across processes.
//! `TangramCfg::full_sweep` restores the legacy scan-everything behaviour
//! for differential testing and the scheduler-invocation benchmarks.

use super::backend::{Backend, Started, Verdict};
use super::queue::ActionQueue;
use crate::action::{Action, ActionId, ResourceKindId, TrajId};
use crate::autoscale::{PoolClass, PoolPressure};
use crate::cluster::api::{ApiEndpoint, ApiOutcome};
use crate::cluster::cpu::{CpuLatency, NodeId};
use crate::cluster::gpu::RestoreModel;
use crate::managers::{BasicManager, CpuManager, GpuManager, ServiceSpec};
use crate::rollout::workloads::Catalog;
use crate::scenario::ScenarioEvent;
use crate::scheduler::{ElasticScheduler, ResourceState, SchedulerConfig};
use crate::sim::{SimDur, SimTime};
use std::collections::{BTreeSet, HashMap, HashSet};
use std::rc::Rc;

/// Cluster-scale knobs for the Tangram deployment.
#[derive(Debug, Clone)]
pub struct TangramCfg {
    pub cpu_nodes: u32,
    pub numa_per_node: u32,
    pub cores_per_numa: u32,
    pub node_mem_gb: u64,
    pub gpu_nodes: u32,
    pub sched: SchedulerConfig,
    pub cpu_latency: CpuLatency,
    pub restore: RestoreModel,
    pub max_api_retries: u32,
    /// Debug/bench escape hatch: schedule every pool on every pump (the
    /// pre-dirty-pool behaviour) instead of only dirty pools.
    pub full_sweep: bool,
}

impl Default for TangramCfg {
    fn default() -> Self {
        TangramCfg {
            cpu_nodes: 5,
            numa_per_node: 2,
            cores_per_numa: 128,
            node_mem_gb: 2400,
            gpu_nodes: 5,
            sched: SchedulerConfig::default(),
            cpu_latency: CpuLatency::default(),
            restore: RestoreModel::default(),
            max_api_retries: 3,
            full_sweep: false,
        }
    }
}

/// One schedulable resource pool. The derived ordering (CPU nodes by id,
/// then the GPU cluster, then API endpoints by kind) is the deterministic
/// drain order — `BTreeSet<PoolId>` iteration visits dirty pools exactly
/// the way the legacy full sweep visited all pools.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum PoolId {
    CpuNode(NodeId),
    Gpu,
    Api(ResourceKindId),
}

pub struct TangramBackend {
    cfg: TangramCfg,
    cpu_kind: ResourceKindId,
    gpu_kind: ResourceKindId,
    pub cpu: CpuManager,
    pub gpu: GpuManager,
    api_mgrs: HashMap<ResourceKindId, BasicManager>,
    endpoints: HashMap<ResourceKindId, ApiEndpoint>,
    pub sched: ElasticScheduler,
    cpu_queues: HashMap<NodeId, ActionQueue>,
    gpu_queue: ActionQueue,
    api_queues: HashMap<ResourceKindId, ActionQueue>,
    /// pools whose state changed since the last drain (sorted iteration)
    dirty: BTreeSet<PoolId>,
    /// trajectories that have already run their first CPU action (container
    /// creation charged once)
    containers_created: HashSet<TrajId>,
    /// outcome of the in-flight attempt per API action
    api_outcomes: HashMap<ActionId, ApiOutcome>,
    /// exec duration of the in-flight attempt (feeds the §4.2 historical-
    /// average estimator on successful completion)
    inflight_exec: HashMap<ActionId, SimDur>,
    /// scheduling-decision count + cumulative wall time (hot-path metric)
    pub sched_invocations: u64,
    pub sched_wall: std::time::Duration,
    /// drain_started call count + cumulative wall time
    pub drain_calls: u64,
    pub drain_wall: std::time::Duration,
    /// Scenario-fault scale factors (injections) and autoscaler scale
    /// factors are tracked separately and COMPOSED (product) into the
    /// substrate, so a scale-up never cancels an injected provider flap
    /// and an injected restore never silently undoes an autoscaler
    /// scale-down (the two layers own different knobs in production too).
    /// The API autoscale factor is **per endpoint** (quota lanes resize
    /// per provider); a `gpu_cache_flush` is orthogonal to both GPU
    /// factors — it drops residencies, never cordons.
    fault_cpu_scale: f64,
    auto_cpu_scale: f64,
    fault_gpu_scale: f64,
    auto_gpu_scale: f64,
    fault_api_scale: f64,
    auto_api_scale: HashMap<ResourceKindId, f64>,
}

impl TangramBackend {
    pub fn new(cat: &Catalog, cfg: TangramCfg) -> Self {
        let cpu = CpuManager::new(
            cfg.cpu_nodes,
            cfg.numa_per_node,
            cfg.cores_per_numa,
            cfg.node_mem_gb,
            cfg.cpu_latency.clone(),
        );
        let services: Vec<ServiceSpec> = cat.services.clone();
        let mut gpu = GpuManager::new(cfg.gpu_nodes, cfg.restore.clone(), services);
        gpu.prewarm(SimTime::ZERO);
        let mut api_mgrs = HashMap::new();
        let mut endpoints = HashMap::new();
        let mut api_queues = HashMap::new();
        for (i, (kind, spec)) in cat.api.iter().enumerate() {
            // admit to ~90% of the provider's hard limit: the margin absorbs
            // in-flight accounting races and keeps the provider out of its
            // load-shedding regime (where latency inflates and errors grow)
            let limit = ((spec.max_concurrency as f64 * 0.9) as u64).max(1);
            api_mgrs.insert(*kind, BasicManager::concurrency(&spec.name, limit));
            endpoints.insert(*kind, ApiEndpoint::new(spec.clone(), 0x5eed + i as u64));
            api_queues.insert(*kind, ActionQueue::new());
        }
        let cpu_queues = cpu
            .node_ids()
            .into_iter()
            .map(|n| (n, ActionQueue::new()))
            .collect();
        TangramBackend {
            sched: ElasticScheduler::new(cfg.sched.clone()),
            cfg,
            cpu_kind: cat.cpu_cores,
            gpu_kind: cat.gpu_units,
            cpu,
            gpu,
            api_mgrs,
            endpoints,
            cpu_queues,
            gpu_queue: ActionQueue::new(),
            api_queues,
            dirty: BTreeSet::new(),
            containers_created: HashSet::new(),
            api_outcomes: HashMap::new(),
            inflight_exec: HashMap::new(),
            sched_invocations: 0,
            sched_wall: std::time::Duration::ZERO,
            drain_calls: 0,
            drain_wall: std::time::Duration::ZERO,
            fault_cpu_scale: 1.0,
            auto_cpu_scale: 1.0,
            fault_gpu_scale: 1.0,
            auto_gpu_scale: 1.0,
            fault_api_scale: 1.0,
            auto_api_scale: HashMap::new(),
        }
    }

    /// Push the composed (fault × autoscale) CPU scale into the cordon
    /// machinery and re-dirty every node — capacity moved either way, and a
    /// restore must immediately revive stalled queues (queue-stall bugfix).
    fn apply_cpu_scale(&mut self) {
        let f = (self.fault_cpu_scale * self.auto_cpu_scale).clamp(0.0, 1.0);
        self.cpu.set_pool_scale(f);
        let nodes: Vec<NodeId> = self.cpu_queues.keys().copied().collect();
        for n in nodes {
            self.dirty.insert(PoolId::CpuNode(n));
        }
    }

    /// Push the composed (fault × autoscale) GPU scale into the whole-node
    /// cordon machinery and re-dirty the GPU pool — capacity moved either
    /// way, and a restore must immediately revive a stalled queue.
    fn apply_gpu_scale(&mut self) {
        let f = (self.fault_gpu_scale * self.auto_gpu_scale).clamp(0.0, 1.0);
        let _ = self.gpu.set_pool_scale(f);
        self.dirty.insert(PoolId::Gpu);
    }

    /// Push the composed (fault × per-endpoint autoscale) API scale into
    /// one provider's limits, re-derive its 90%-of-limit admission margin,
    /// and re-dirty the endpoint pool.
    fn apply_api_scale_one(&mut self, kind: ResourceKindId) {
        let auto = self.auto_api_scale.get(&kind).copied().unwrap_or(1.0);
        let f = (self.fault_api_scale * auto).max(0.0);
        if let Some(ep) = self.endpoints.get_mut(&kind) {
            ep.scale_limits(f);
            if let Some(mgr) = self.api_mgrs.get_mut(&kind) {
                mgr.limit = ((ep.spec.max_concurrency as f64 * 0.9) as u64).max(1);
            }
            self.dirty.insert(PoolId::Api(kind));
        }
    }

    /// [`Self::apply_api_scale_one`] over every endpoint (fault flaps hit
    /// all providers at once; autoscaler resizes come in per-endpoint).
    fn apply_api_scale(&mut self) {
        let mut kinds: Vec<ResourceKindId> = self.endpoints.keys().copied().collect();
        kinds.sort();
        for kind in kinds {
            self.apply_api_scale_one(kind);
        }
    }

    fn classify(&self, a: &Action) -> PoolId {
        if a.spec.cost.dim(self.cpu_kind).min_units() > 0 {
            let node = self
                .cpu
                .binding(a.spec.trajectory)
                .expect("CPU action for unbound trajectory");
            PoolId::CpuNode(node)
        } else if a.spec.cost.dim(self.gpu_kind).min_units() > 0 {
            PoolId::Gpu
        } else {
            let kind = a
                .spec
                .cost
                .iter()
                .find(|(_, d)| d.min_units() > 0)
                .map(|(k, _)| k)
                .expect("action with empty cost");
            PoolId::Api(kind)
        }
    }

    /// Run the elastic scheduler over one queue and apply its decisions.
    fn schedule_pool(&mut self, now: SimTime, pool: PoolId, out: &mut Vec<Started>) {
        match pool {
            PoolId::CpuNode(node) => {
                if self.cpu_queues[&node].is_empty() {
                    return;
                }
                let mut decisions = {
                    let state = self.cpu.node_state(node);
                    let mut map: HashMap<ResourceKindId, &dyn ResourceState> = HashMap::new();
                    map.insert(self.cpu_kind, &state);
                    let refs = self.cpu_queues[&node].refs();
                    let t0 = std::time::Instant::now();
                    let d = self.sched.schedule(now, &refs, &map);
                    self.sched_wall += t0.elapsed();
                    self.sched_invocations += 1;
                    d
                };
                // Liveness guard: "wait for more capacity" is only sound
                // when something is running that will free capacity. With an
                // idle node, force the queue head at its minimum.
                if decisions.is_empty()
                    && self.cpu.node_state(node).running_completions().is_empty()
                {
                    if let Some(head) = self.cpu_queues[&node].front() {
                        let units = head.spec.cost.dim(self.cpu_kind).min_units();
                        let mut alloc = head.spec.cost.min_vector();
                        alloc.set(self.cpu_kind, units);
                        decisions.push(crate::scheduler::Decision {
                            action: head.id,
                            units,
                            alloc,
                        });
                    }
                }
                for dec in decisions {
                    let a = match self.cpu_queues[&node].get(dec.action) {
                        Some(rc) => rc.clone(),
                        None => continue,
                    };
                    let first = self.containers_created.insert(a.spec.trajectory);
                    let exec = a.spec.exec_dur(dec.units);
                    // overhead known only after allocate; estimate for the
                    // expected-done bookkeeping, then patch below
                    let est_done = now + exec;
                    match self.cpu.allocate(
                        a.id,
                        a.spec.trajectory,
                        dec.units as u32,
                        first,
                        est_done,
                    ) {
                        Ok(lease) => {
                            let _ = self.cpu_queues.get_mut(&node).unwrap().remove(a.id);
                            self.inflight_exec.insert(a.id, exec);
                            out.push(Started {
                                action: a.id,
                                overhead: lease.overhead,
                                exec,
                                units: dec.units,
                            });
                        }
                        Err(_) => {
                            // topology raced (or the pool was cordoned under
                            // us); the action stays queued — the stall
                            // re-arm in drain_started and the cordon-restore
                            // injection keep the pool scheduled. Undo the
                            // first-action marker.
                            if first {
                                self.containers_created.remove(&a.spec.trajectory);
                            }
                        }
                    }
                }
            }
            PoolId::Gpu => {
                if self.gpu_queue.is_empty() {
                    return;
                }
                let mut decisions = {
                    let mut map: HashMap<ResourceKindId, &dyn ResourceState> = HashMap::new();
                    map.insert(self.gpu_kind, &self.gpu);
                    let refs = self.gpu_queue.refs();
                    let t0 = std::time::Instant::now();
                    let d = self.sched.schedule(now, &refs, &map);
                    self.sched_wall += t0.elapsed();
                    self.sched_invocations += 1;
                    d
                };
                // Liveness guard (see CPU pool): an idle cluster must not
                // "wait" — force the head at its minimum legal DoP.
                if decisions.is_empty() && self.gpu.running_completions().is_empty() {
                    if let Some(head) = self.gpu_queue.front() {
                        let units = head.spec.cost.dim(self.gpu_kind).min_units();
                        let mut alloc = head.spec.cost.min_vector();
                        alloc.set(self.gpu_kind, units);
                        decisions.push(crate::scheduler::Decision {
                            action: head.id,
                            units,
                            alloc,
                        });
                    }
                }
                for dec in decisions {
                    let a = match self.gpu_queue.get(dec.action) {
                        Some(rc) => rc.clone(),
                        None => continue,
                    };
                    let service = a.spec.service.expect("GPU action without service");
                    let exec = a.spec.exec_dur(dec.units);
                    match self.gpu.allocate(a.id, service, dec.units as u8, now + exec) {
                        Ok(lease) => {
                            let _ = self.gpu_queue.remove(a.id);
                            self.inflight_exec.insert(a.id, exec);
                            out.push(Started {
                                action: a.id,
                                overhead: lease.overhead,
                                exec,
                                units: dec.units,
                            });
                        }
                        Err(_) => {}
                    }
                }
            }
            PoolId::Api(kind) => {
                loop {
                    let mgr = self.api_mgrs.get_mut(&kind).unwrap();
                    mgr.tick(now);
                    let ep = self.endpoints.get_mut(&kind).unwrap();
                    let q = self.api_queues.get_mut(&kind).unwrap();
                    if q.is_empty() {
                        break;
                    }
                    // admission: provider concurrency via the Basic manager
                    // plus the provider's remaining window quota
                    if mgr.available_units() == 0 || ep.quota_left(now) == 0 {
                        break;
                    }
                    let a = q.pop_front().expect("non-empty queue has a head");
                    let (outcome, dur) = ep.issue(now);
                    debug_assert_ne!(
                        outcome,
                        ApiOutcome::RateLimited,
                        "admission control must prevent provider 429s"
                    );
                    mgr.allocate(a.id, 1, now + dur).expect("admission raced");
                    self.api_outcomes.insert(a.id, outcome);
                    self.inflight_exec.insert(a.id, dur);
                    out.push(Started { action: a.id, overhead: SimDur::ZERO, exec: dur, units: 1 });
                }
            }
        }
    }

    /// Every pool in *sorted* order (the legacy full sweep; see [`PoolId`]).
    fn all_pools(&self) -> Vec<PoolId> {
        let mut nodes: Vec<NodeId> = self.cpu_queues.keys().copied().collect();
        nodes.sort();
        let mut pools: Vec<PoolId> = nodes.into_iter().map(PoolId::CpuNode).collect();
        pools.push(PoolId::Gpu);
        let mut kinds: Vec<ResourceKindId> = self.api_queues.keys().copied().collect();
        kinds.sort();
        pools.extend(kinds.into_iter().map(PoolId::Api));
        pools
    }

    /// Schedulable pools in this deployment (CPU nodes + GPU + endpoints).
    pub fn pool_count(&self) -> usize {
        self.cpu_queues.len() + 1 + self.api_queues.len()
    }

    /// Currently-provisioned API quota lanes (sum of provider concurrency
    /// limits after any flaps/resizes).
    pub fn provisioned_lanes(&self) -> u64 {
        self.endpoints.values().map(|e| e.spec.max_concurrency as u64).sum()
    }

    /// Mean scheduler decision latency (wall-clock, for §Perf).
    pub fn mean_sched_latency(&self) -> std::time::Duration {
        if self.sched_invocations == 0 {
            return std::time::Duration::ZERO;
        }
        self.sched_wall / self.sched_invocations as u32
    }

    /// Mean `drain_started` wall time (the whole pump hot path).
    pub fn mean_drain_latency(&self) -> std::time::Duration {
        if self.drain_calls == 0 {
            return std::time::Duration::ZERO;
        }
        self.drain_wall / self.drain_calls as u32
    }
}

impl Backend for TangramBackend {
    fn name(&self) -> &'static str {
        "arl-tangram"
    }

    fn traj_start(
        &mut self,
        _now: SimTime,
        traj: TrajId,
        mem_gb: u64,
        first_cpu_min: Option<u32>,
    ) -> Result<(), String> {
        if let Some(min_cores) = first_cpu_min {
            self.cpu.bind_trajectory(traj, min_cores, mem_gb)?;
        }
        Ok(())
    }

    fn traj_end(&mut self, _now: SimTime, traj: TrajId) {
        if let Some(node) = self.cpu.binding(traj) {
            let _ = self.cpu.release_trajectory(traj);
            self.containers_created.remove(&traj);
            // container teardown returns memory and any still-assigned
            // cgroup cores to the node — capacity moved, so the pool must
            // be rescheduled on the pump that follows
            self.dirty.insert(PoolId::CpuNode(node));
        }
    }

    fn submit(&mut self, _now: SimTime, action: &Rc<Action>) {
        let pool = self.classify(action);
        match pool {
            PoolId::CpuNode(n) => self.cpu_queues.get_mut(&n).unwrap().push_back(action.clone()),
            PoolId::Gpu => self.gpu_queue.push_back(action.clone()),
            PoolId::Api(k) => self.api_queues.get_mut(&k).unwrap().push_back(action.clone()),
        }
        self.dirty.insert(pool);
    }

    fn on_complete(&mut self, now: SimTime, action: &Action) -> Verdict {
        let pool = self.classify(action);
        let exec = self.inflight_exec.remove(&action.id);
        let verdict = match pool {
            PoolId::CpuNode(_) => {
                self.cpu.complete(action.id).expect("cpu complete");
                Verdict::Done
            }
            PoolId::Gpu => {
                self.gpu.complete(action.id, now).expect("gpu complete");
                Verdict::Done
            }
            PoolId::Api(k) => {
                let outcome = self
                    .api_outcomes
                    .remove(&action.id)
                    .unwrap_or(ApiOutcome::Ok);
                let mgr = self.api_mgrs.get_mut(&k).unwrap();
                mgr.complete(action.id, 1);
                self.endpoints.get_mut(&k).unwrap().finish(outcome);
                match outcome {
                    ApiOutcome::Ok => Verdict::Done,
                    _ => {
                        // transient failure — retry under admission control
                        // (driver enforces the retry budget)
                        Verdict::Retry
                    }
                }
            }
        };
        // §4.2 historical-average estimator: successful attempts feed the
        // per-kind EWMA the scheduler uses for unprofiled actions. The
        // observation moves the estimate for every queued unprofiled action
        // of this kind — the one cross-pool coupling in the dirty contract —
        // so any pool holding one must be re-evaluated, exactly as the
        // legacy full sweep would have.
        if verdict == Verdict::Done {
            if let Some(exec) = exec {
                let kind = action.spec.kind;
                self.sched.stats.observe(kind, exec);
                for (&node, q) in self.cpu_queues.iter() {
                    if q.has_unprofiled(kind) {
                        self.dirty.insert(PoolId::CpuNode(node));
                    }
                }
                if self.gpu_queue.has_unprofiled(kind) {
                    self.dirty.insert(PoolId::Gpu);
                }
            }
        }
        // capacity freed (or the retry will resubmit) — the pool must be
        // rescheduled on this pump
        self.dirty.insert(pool);
        verdict
    }

    fn drain_started(&mut self, now: SimTime) -> Vec<Started> {
        let t0 = std::time::Instant::now();
        let mut out = Vec::new();
        let pools: Vec<PoolId> = if self.cfg.full_sweep {
            self.all_pools()
        } else {
            // BTreeSet iteration = sorted PoolId order (determinism)
            std::mem::take(&mut self.dirty).into_iter().collect()
        };
        for pool in pools {
            let before = out.len();
            self.schedule_pool(now, pool, &mut out);
            if self.cfg.full_sweep {
                continue;
            }
            if out.len() > before {
                // Started something — the pool's own state changed, so it
                // is dirty again by definition. Re-arming keeps parity with
                // the legacy sweep: the eviction estimate may have planned
                // an immediate follow-on start on the leftover budget, which
                // the sweep realized at the driver's next same-instant pump.
                self.dirty.insert(pool);
                continue;
            }
            // Stall re-arm: a pool with waiting work, nothing running that
            // will free capacity, and nothing started (e.g. the liveness
            // guard's forced head lost its cores to a cordon) has no future
            // event of its own to dirty it — keep it dirty so every pump
            // retries until capacity returns (cordon restore, traj teardown).
            let stalled = match pool {
                PoolId::CpuNode(n) => {
                    !self.cpu_queues[&n].is_empty()
                        && self.cpu.node_state(n).running_completions().is_empty()
                }
                PoolId::Gpu => {
                    !self.gpu_queue.is_empty() && self.gpu.running_completions().is_empty()
                }
                // API admission is covered by completions and the quota-
                // window wakeup contract — never stalled silently
                PoolId::Api(_) => false,
            };
            if stalled {
                self.dirty.insert(pool);
            }
        }
        self.drain_calls += 1;
        self.drain_wall += t0.elapsed();
        out
    }

    fn has_dirty(&self) -> bool {
        if self.cfg.full_sweep {
            return true;
        }
        !self.dirty.is_empty()
    }

    fn next_wakeup(&self, now: SimTime) -> Option<SimTime> {
        // quota-gated API queues wake at the next window boundary
        let mut earliest: Option<SimTime> = None;
        for (kind, q) in &self.api_queues {
            if q.is_empty() {
                continue;
            }
            let ep = &self.endpoints[kind];
            if ep.quota_left(now) == 0 {
                let w = ep.spec.quota_window.0;
                let next = SimTime((now.0 / w + 1) * w);
                earliest = Some(earliest.map_or(next, |e: SimTime| e.min(next)));
            }
        }
        earliest
    }

    fn tick(&mut self, now: SimTime) {
        for mgr in self.api_mgrs.values_mut() {
            mgr.tick(now);
        }
        // a tick can roll quota windows open — any endpoint with waiting
        // work must be rescheduled on the pump that follows
        for (kind, q) in &self.api_queues {
            if !q.is_empty() {
                self.dirty.insert(PoolId::Api(*kind));
            }
        }
    }

    fn utilization(&self) -> Vec<(String, f64)> {
        vec![
            ("cpu".into(), self.cpu.utilization()),
            ("gpu".into(), self.gpu.utilization()),
        ]
    }

    fn provisioned(&self) -> Vec<(String, u64)> {
        vec![
            ("cpu_cores".into(), self.cpu.total_cores() - self.cpu.cordoned_cores() as u64),
            ("gpus".into(), self.gpu.provisioned_gpus() as u64),
            ("api_lanes".into(), self.provisioned_lanes()),
        ]
    }

    fn scale_classes(&self) -> Vec<PoolPressure> {
        // sorted by (class, endpoint): Cpu < Gpu < Api, endpoints by kind
        // id — the autoscaler's deterministic eval order
        let total = self.cpu.total_cores();
        let cordoned = self.cpu.cordoned_cores() as u64;
        let free = self.cpu.free_cores();
        let cpu = PoolPressure {
            class: PoolClass::Cpu,
            endpoint: None,
            queued: self.cpu_queues.values().map(|q| q.len() as u64).sum(),
            // minimum core demand of the waiting work (unit-denominated,
            // so policies never mix action counts into core sums)
            queued_units: self
                .cpu_queues
                .values()
                .flat_map(|q| q.iter())
                .map(|a| a.spec.cost.dim(self.cpu_kind).min_units())
                .sum(),
            // cordoned cores read as busy in free_cores; subtract them so
            // in-use reflects real allocations only
            in_use_units: total.saturating_sub(free).saturating_sub(cordoned),
            provisioned_units: total - cordoned,
            baseline_units: total,
        };
        let gpu = PoolPressure {
            class: PoolClass::Gpu,
            endpoint: None,
            queued: self.gpu_queue.len() as u64,
            queued_units: self
                .gpu_queue
                .iter()
                .map(|a| a.spec.cost.dim(self.gpu_kind).min_units())
                .sum(),
            in_use_units: self.gpu.in_use_gpus(),
            provisioned_units: self.gpu.provisioned_gpus() as u64,
            baseline_units: self.gpu.total_gpus() as u64,
        };
        let mut rows = vec![cpu, gpu];
        // per-endpoint API pressure: each provider's quota lanes scale
        // independently (a flapping search provider must not drag the
        // PDF-parse lanes down with it)
        let mut kinds: Vec<ResourceKindId> = self.endpoints.keys().copied().collect();
        kinds.sort();
        for kind in kinds {
            let ep = &self.endpoints[&kind];
            let queued = self.api_queues[&kind].len() as u64;
            rows.push(PoolPressure {
                class: PoolClass::Api,
                endpoint: Some(kind.0),
                queued,
                // every API call occupies exactly one provider lane
                queued_units: queued,
                in_use_units: ep.in_flight() as u64,
                provisioned_units: ep.spec.max_concurrency as u64,
                baseline_units: ep.base_concurrency() as u64,
            });
        }
        rows
    }

    fn resize(
        &mut self,
        _now: SimTime,
        class: PoolClass,
        endpoint: Option<u32>,
        factor: f64,
    ) -> Option<u64> {
        // the autoscaler owns its own factor; the substrate sees the
        // composition with any injected fault, through the same cordon /
        // provider-limit machinery (incl. pool dirtying) as `inject`
        match class {
            PoolClass::Cpu => {
                self.auto_cpu_scale = factor.clamp(0.0, 1.0);
                self.apply_cpu_scale();
                Some(self.cpu.total_cores() - self.cpu.cordoned_cores() as u64)
            }
            PoolClass::Gpu => {
                self.auto_gpu_scale = factor.clamp(0.0, 1.0);
                self.apply_gpu_scale();
                Some(self.gpu.provisioned_gpus() as u64)
            }
            PoolClass::Api => {
                let f = factor.max(0.0);
                match endpoint {
                    Some(e) => {
                        self.auto_api_scale.insert(ResourceKindId(e), f);
                        self.apply_api_scale_one(ResourceKindId(e));
                    }
                    None => {
                        // blanket resize (tests / class-wide policies)
                        let kinds: Vec<ResourceKindId> =
                            self.endpoints.keys().copied().collect();
                        for k in kinds {
                            self.auto_api_scale.insert(k, f);
                        }
                        self.apply_api_scale();
                    }
                }
                Some(self.provisioned_lanes())
            }
        }
    }

    fn inject(&mut self, _now: SimTime, event: &ScenarioEvent) -> bool {
        match event {
            ScenarioEvent::ApiLimitScale { factor } => {
                // track the provider: the fault factor composes with any
                // autoscaler factor (re-deriving the 90%-of-limit admission
                // margins from the flapped specs)
                self.fault_api_scale = *factor;
                self.apply_api_scale();
                !self.endpoints.is_empty()
            }
            ScenarioEvent::GpuCacheFlush => {
                // orthogonal to the GPU scale factors: residencies drop,
                // cordons are untouched — a flush mid-scale-down must not
                // cancel the autoscale factor
                self.gpu.flush_caches();
                self.dirty.insert(PoolId::Gpu);
                true
            }
            ScenarioEvent::GpuPoolScale { factor } => {
                self.fault_gpu_scale = *factor;
                self.apply_gpu_scale();
                true
            }
            ScenarioEvent::CpuPoolScale { factor } => {
                self.fault_cpu_scale = *factor;
                self.apply_cpu_scale();
                true
            }
        }
    }
}
