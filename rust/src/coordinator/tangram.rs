//! The ARL-Tangram coordinator backend: unified action queue + elastic
//! scheduler + heterogeneous resource managers (paper Fig. 4).
//!
//! Routing: CPU actions go to the per-node queue of their trajectory's
//! bound node (per-node scheduling, §5.2); GPU service actions go to the
//! cluster-wide GPU queue; API actions go to per-endpoint queues under
//! Basic-manager admission. Every queue is FCFS and scheduled with the same
//! elastic algorithm (§4.2).

use super::backend::{Backend, Started, Verdict};
use crate::action::{Action, ActionId, ResourceKindId, TrajId};
use crate::cluster::api::{ApiEndpoint, ApiOutcome};
use crate::cluster::cpu::{CpuLatency, NodeId};
use crate::cluster::gpu::RestoreModel;
use crate::managers::{BasicManager, CpuManager, GpuManager, ServiceSpec};
use crate::rollout::workloads::Catalog;
use crate::scenario::ScenarioEvent;
use crate::scheduler::{ElasticScheduler, ResourceState, SchedulerConfig};
use crate::sim::{SimDur, SimTime};
use std::collections::{HashMap, HashSet};

/// Cluster-scale knobs for the Tangram deployment.
#[derive(Debug, Clone)]
pub struct TangramCfg {
    pub cpu_nodes: u32,
    pub numa_per_node: u32,
    pub cores_per_numa: u32,
    pub node_mem_gb: u64,
    pub gpu_nodes: u32,
    pub sched: SchedulerConfig,
    pub cpu_latency: CpuLatency,
    pub restore: RestoreModel,
    pub max_api_retries: u32,
}

impl Default for TangramCfg {
    fn default() -> Self {
        TangramCfg {
            cpu_nodes: 5,
            numa_per_node: 2,
            cores_per_numa: 128,
            node_mem_gb: 2400,
            gpu_nodes: 5,
            sched: SchedulerConfig::default(),
            cpu_latency: CpuLatency::default(),
            restore: RestoreModel::default(),
            max_api_retries: 3,
        }
    }
}

enum Pool {
    CpuNode(NodeId),
    Gpu,
    Api(ResourceKindId),
}

pub struct TangramBackend {
    #[allow(dead_code)]
    cfg: TangramCfg,
    cpu_kind: ResourceKindId,
    gpu_kind: ResourceKindId,
    pub cpu: CpuManager,
    pub gpu: GpuManager,
    api_mgrs: HashMap<ResourceKindId, BasicManager>,
    endpoints: HashMap<ResourceKindId, ApiEndpoint>,
    sched: ElasticScheduler,
    cpu_queues: HashMap<NodeId, Vec<Action>>,
    gpu_queue: Vec<Action>,
    api_queues: HashMap<ResourceKindId, Vec<Action>>,
    /// trajectories that have already run their first CPU action (container
    /// creation charged once)
    containers_created: HashSet<TrajId>,
    /// outcome of the in-flight attempt per API action
    api_outcomes: HashMap<ActionId, ApiOutcome>,
    /// scheduling-decision count + cumulative wall time (hot-path metric)
    pub sched_invocations: u64,
    pub sched_wall: std::time::Duration,
}

impl TangramBackend {
    pub fn new(cat: &Catalog, cfg: TangramCfg) -> Self {
        let cpu = CpuManager::new(
            cfg.cpu_nodes,
            cfg.numa_per_node,
            cfg.cores_per_numa,
            cfg.node_mem_gb,
            cfg.cpu_latency.clone(),
        );
        let services: Vec<ServiceSpec> = cat.services.clone();
        let mut gpu = GpuManager::new(cfg.gpu_nodes, cfg.restore.clone(), services);
        gpu.prewarm(SimTime::ZERO);
        let mut api_mgrs = HashMap::new();
        let mut endpoints = HashMap::new();
        let mut api_queues = HashMap::new();
        for (i, (kind, spec)) in cat.api.iter().enumerate() {
            // admit to ~90% of the provider's hard limit: the margin absorbs
            // in-flight accounting races and keeps the provider out of its
            // load-shedding regime (where latency inflates and errors grow)
            let limit = ((spec.max_concurrency as f64 * 0.9) as u64).max(1);
            api_mgrs.insert(*kind, BasicManager::concurrency(&spec.name, limit));
            endpoints.insert(*kind, ApiEndpoint::new(spec.clone(), 0x5eed + i as u64));
            api_queues.insert(*kind, Vec::new());
        }
        let cpu_queues = cpu.node_ids().into_iter().map(|n| (n, Vec::new())).collect();
        TangramBackend {
            sched: ElasticScheduler::new(cfg.sched.clone()),
            cfg,
            cpu_kind: cat.cpu_cores,
            gpu_kind: cat.gpu_units,
            cpu,
            gpu,
            api_mgrs,
            endpoints,
            cpu_queues,
            gpu_queue: Vec::new(),
            api_queues,
            containers_created: HashSet::new(),
            api_outcomes: HashMap::new(),
            sched_invocations: 0,
            sched_wall: std::time::Duration::ZERO,
        }
    }

    fn classify(&self, a: &Action) -> Pool {
        if a.spec.cost.dim(self.cpu_kind).min_units() > 0 {
            let node = self
                .cpu
                .binding(a.spec.trajectory)
                .expect("CPU action for unbound trajectory");
            Pool::CpuNode(node)
        } else if a.spec.cost.dim(self.gpu_kind).min_units() > 0 {
            Pool::Gpu
        } else {
            let kind = a
                .spec
                .cost
                .iter()
                .find(|(_, d)| d.min_units() > 0)
                .map(|(k, _)| k)
                .expect("action with empty cost");
            Pool::Api(kind)
        }
    }

    /// Run the elastic scheduler over one queue and apply its decisions.
    fn schedule_pool(&mut self, now: SimTime, pool: &Pool, out: &mut Vec<Started>) {
        match pool {
            Pool::CpuNode(node) => {
                let node = *node;
                let queue = &self.cpu_queues[&node];
                if queue.is_empty() {
                    return;
                }
                let mut decisions = {
                    let state = self.cpu.node_state(node);
                    let mut map: HashMap<ResourceKindId, &dyn ResourceState> = HashMap::new();
                    map.insert(self.cpu_kind, &state);
                    let refs: Vec<&Action> = queue.iter().collect();
                    let t0 = std::time::Instant::now();
                    let d = self.sched.schedule(now, &refs, &map);
                    self.sched_wall += t0.elapsed();
                    self.sched_invocations += 1;
                    d
                };
                // Liveness guard: "wait for more capacity" is only sound
                // when something is running that will free capacity. With an
                // idle node, force the queue head at its minimum.
                if decisions.is_empty()
                    && self.cpu.node_state(node).running_completions().is_empty()
                {
                    if let Some(head) = self.cpu_queues[&node].first() {
                        let units = head.spec.cost.dim(self.cpu_kind).min_units();
                        let mut alloc = head.spec.cost.min_vector();
                        alloc.set(self.cpu_kind, units);
                        decisions.push(crate::scheduler::Decision {
                            action: head.id,
                            units,
                            alloc,
                        });
                    }
                }
                for dec in decisions {
                    let q = self.cpu_queues.get_mut(&node).unwrap();
                    let idx = match q.iter().position(|a| a.id == dec.action) {
                        Some(i) => i,
                        None => continue,
                    };
                    let a = q[idx].clone();
                    let first = self.containers_created.insert(a.spec.trajectory);
                    let exec = a.spec.exec_dur(dec.units);
                    // overhead known only after allocate; estimate for the
                    // expected-done bookkeeping, then patch below
                    let est_done = now + exec;
                    match self.cpu.allocate(
                        a.id,
                        a.spec.trajectory,
                        dec.units as u32,
                        first,
                        est_done,
                    ) {
                        Ok(lease) => {
                            self.cpu_queues.get_mut(&node).unwrap().remove(idx);
                            out.push(Started {
                                action: a.id,
                                overhead: lease.overhead,
                                exec,
                                units: dec.units,
                            });
                        }
                        Err(_) => {
                            // topology raced; undo the first-action marker
                            if first {
                                self.containers_created.remove(&a.spec.trajectory);
                            }
                        }
                    }
                }
            }
            Pool::Gpu => {
                if self.gpu_queue.is_empty() {
                    return;
                }
                let mut decisions = {
                    let mut map: HashMap<ResourceKindId, &dyn ResourceState> = HashMap::new();
                    map.insert(self.gpu_kind, &self.gpu);
                    let refs: Vec<&Action> = self.gpu_queue.iter().collect();
                    let t0 = std::time::Instant::now();
                    let d = self.sched.schedule(now, &refs, &map);
                    self.sched_wall += t0.elapsed();
                    self.sched_invocations += 1;
                    d
                };
                // Liveness guard (see CPU pool): an idle cluster must not
                // "wait" — force the head at its minimum legal DoP.
                if decisions.is_empty() && self.gpu.running_completions().is_empty() {
                    if let Some(head) = self.gpu_queue.first() {
                        let units = head.spec.cost.dim(self.gpu_kind).min_units();
                        let mut alloc = head.spec.cost.min_vector();
                        alloc.set(self.gpu_kind, units);
                        decisions.push(crate::scheduler::Decision {
                            action: head.id,
                            units,
                            alloc,
                        });
                    }
                }
                for dec in decisions {
                    let idx = match self.gpu_queue.iter().position(|a| a.id == dec.action) {
                        Some(i) => i,
                        None => continue,
                    };
                    let a = self.gpu_queue[idx].clone();
                    let service = a.spec.service.expect("GPU action without service");
                    let exec = a.spec.exec_dur(dec.units);
                    match self.gpu.allocate(a.id, service, dec.units as u8, now + exec) {
                        Ok(lease) => {
                            self.gpu_queue.remove(idx);
                            out.push(Started {
                                action: a.id,
                                overhead: lease.overhead,
                                exec,
                                units: dec.units,
                            });
                        }
                        Err(_) => {}
                    }
                }
            }
            Pool::Api(kind) => {
                let kind = *kind;
                loop {
                    let mgr = self.api_mgrs.get_mut(&kind).unwrap();
                    mgr.tick(now);
                    let ep = self.endpoints.get_mut(&kind).unwrap();
                    let q = self.api_queues.get_mut(&kind).unwrap();
                    if q.is_empty() {
                        break;
                    }
                    // admission: provider concurrency via the Basic manager
                    // plus the provider's remaining window quota
                    if mgr.available_units() == 0 || ep.quota_left(now) == 0 {
                        break;
                    }
                    let a = q.remove(0);
                    let (outcome, dur) = ep.issue(now);
                    debug_assert_ne!(
                        outcome,
                        ApiOutcome::RateLimited,
                        "admission control must prevent provider 429s"
                    );
                    mgr.allocate(a.id, 1, now + dur).expect("admission raced");
                    self.api_outcomes.insert(a.id, outcome);
                    out.push(Started { action: a.id, overhead: SimDur::ZERO, exec: dur, units: 1 });
                }
            }
        }
    }

    /// Every pool in *sorted* order. HashMap iteration order varies across
    /// processes (RandomState), and the pool order decides the ordering of
    /// same-timestamp `Started` events — sorting is what makes recorded
    /// traces replay byte-identically in a fresh process.
    fn all_pools(&self) -> Vec<Pool> {
        let mut nodes: Vec<NodeId> = self.cpu_queues.keys().copied().collect();
        nodes.sort();
        let mut pools: Vec<Pool> = nodes.into_iter().map(Pool::CpuNode).collect();
        pools.push(Pool::Gpu);
        let mut kinds: Vec<ResourceKindId> = self.api_queues.keys().copied().collect();
        kinds.sort();
        pools.extend(kinds.into_iter().map(Pool::Api));
        pools
    }

    /// Mean scheduler decision latency (wall-clock, for §Perf).
    pub fn mean_sched_latency(&self) -> std::time::Duration {
        if self.sched_invocations == 0 {
            return std::time::Duration::ZERO;
        }
        self.sched_wall / self.sched_invocations as u32
    }
}

impl Backend for TangramBackend {
    fn name(&self) -> &'static str {
        "arl-tangram"
    }

    fn traj_start(
        &mut self,
        _now: SimTime,
        traj: TrajId,
        mem_gb: u64,
        first_cpu_min: Option<u32>,
    ) -> Result<(), String> {
        if let Some(min_cores) = first_cpu_min {
            self.cpu.bind_trajectory(traj, min_cores, mem_gb)?;
        }
        Ok(())
    }

    fn traj_end(&mut self, _now: SimTime, traj: TrajId) {
        if self.cpu.binding(traj).is_some() {
            let _ = self.cpu.release_trajectory(traj);
            self.containers_created.remove(&traj);
        }
    }

    fn submit(&mut self, _now: SimTime, action: &Action) {
        match self.classify(action) {
            Pool::CpuNode(n) => self.cpu_queues.get_mut(&n).unwrap().push(action.clone()),
            Pool::Gpu => self.gpu_queue.push(action.clone()),
            Pool::Api(k) => self.api_queues.get_mut(&k).unwrap().push(action.clone()),
        }
    }

    fn on_complete(&mut self, now: SimTime, action: &Action) -> Verdict {
        match self.classify(action) {
            Pool::CpuNode(_) => {
                self.cpu.complete(action.id).expect("cpu complete");
                Verdict::Done
            }
            Pool::Gpu => {
                self.gpu.complete(action.id, now).expect("gpu complete");
                Verdict::Done
            }
            Pool::Api(k) => {
                let outcome = self
                    .api_outcomes
                    .remove(&action.id)
                    .unwrap_or(ApiOutcome::Ok);
                let mgr = self.api_mgrs.get_mut(&k).unwrap();
                mgr.complete(action.id, 1);
                self.endpoints.get_mut(&k).unwrap().finish(outcome);
                match outcome {
                    ApiOutcome::Ok => Verdict::Done,
                    _ if action.spec.true_dur == SimDur::ZERO => Verdict::Failed, // unused guard
                    _ => {
                        // transient failure — retry under admission control
                        // (driver enforces the retry budget)
                        Verdict::Retry
                    }
                }
            }
        }
    }

    fn drain_started(&mut self, now: SimTime) -> Vec<Started> {
        let mut out = Vec::new();
        for pool in self.all_pools() {
            self.schedule_pool(now, &pool, &mut out);
        }
        out
    }

    fn next_wakeup(&self, now: SimTime) -> Option<SimTime> {
        // quota-gated API queues wake at the next window boundary
        let mut earliest: Option<SimTime> = None;
        for (kind, q) in &self.api_queues {
            if q.is_empty() {
                continue;
            }
            let ep = &self.endpoints[kind];
            if ep.quota_left(now) == 0 {
                let w = ep.spec.quota_window.0;
                let next = SimTime((now.0 / w + 1) * w);
                earliest = Some(earliest.map_or(next, |e: SimTime| e.min(next)));
            }
        }
        earliest
    }

    fn tick(&mut self, now: SimTime) {
        for mgr in self.api_mgrs.values_mut() {
            mgr.tick(now);
        }
    }

    fn utilization(&self) -> Vec<(String, f64)> {
        vec![
            ("cpu".into(), self.cpu.utilization()),
            ("gpu".into(), self.gpu.utilization()),
        ]
    }

    fn provisioned(&self) -> Vec<(String, u64)> {
        vec![
            ("cpu_cores".into(), self.cpu.total_cores()),
            ("gpus".into(), self.gpu.total_gpus() as u64),
        ]
    }

    fn inject(&mut self, _now: SimTime, event: &ScenarioEvent) -> bool {
        match event {
            ScenarioEvent::ApiLimitScale { factor } => {
                for (kind, ep) in self.endpoints.iter_mut() {
                    ep.scale_limits(*factor);
                    if let Some(mgr) = self.api_mgrs.get_mut(kind) {
                        // track the provider: re-derive the 90%-of-limit
                        // admission margin from the flapped spec
                        mgr.limit =
                            ((ep.spec.max_concurrency as f64 * 0.9) as u64).max(1);
                    }
                }
                !self.endpoints.is_empty()
            }
            ScenarioEvent::GpuCacheFlush => {
                self.gpu.flush_caches();
                true
            }
            ScenarioEvent::CpuPoolScale { factor } => {
                self.cpu.set_pool_scale(*factor);
                true
            }
        }
    }
}
