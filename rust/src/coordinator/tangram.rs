//! The ARL-Tangram coordinator backend: unified action queue + elastic
//! scheduler + heterogeneous resource managers (paper Fig. 4).
//!
//! Routing: CPU actions go to the per-node queue of their trajectory's
//! bound node (per-node scheduling, §5.2); GPU service actions go to the
//! cluster-wide GPU queue; API actions go to per-endpoint queues under
//! Basic-manager admission. Every queue is a deterministic per-tenant
//! weighted-fair queue (exactly FCFS on single-tenant runs — see
//! `coordinator::queue`) scheduled with the same elastic algorithm (§4.2).
//!
//! Scheduling is **dirty-pool incremental** (see the contract on
//! [`Backend`]): each pump re-runs the elastic scheduler only over pools
//! whose state changed — a completion on one CPU node no longer rescans
//! every node, the GPU cluster, and every API endpoint. Pools are drained
//! in sorted [`PoolId`] order so same-timestamp decisions (and therefore
//! recorded scenario traces) stay byte-deterministic across processes.
//! `TangramCfg::full_sweep` restores the legacy scan-everything behaviour
//! for differential testing and the scheduler-invocation benchmarks.
//!
//! The drain optionally partitions its pool work-list across **logical
//! shards** ([`Backend::set_shards`]): contiguous slices of the sorted
//! list, processed in ascending shard order and merged back in that order
//! — which *is* the global sorted-pool order, so the decision stream (and
//! every recorded trace) is byte-identical for any shard count and
//! `--shards 1` is bitwise the unsharded path. Contiguous-in-order
//! chunking (not round-robin) also keeps the one cross-pool coupling in a
//! drain — the container-creation first-marker — ordered exactly as the
//! serial loop ordered it.
//!
//! The shard slices optionally execute on a **worker pool**
//! ([`Backend::set_threads`], [`crate::coordinator::parallel`]): each pool
//! visit splits into a read-only *decide* half ([`PoolPlan`] — the elastic
//! scheduler invocation plus the liveness guard, taking `&self`) and a
//! mutating *apply* half (queue removal, manager allocation, sink pushes,
//! the serial API admission loop). Workers run only decides, one worker
//! per shard up to the thread budget; the driver thread then applies every
//! plan in ascending shard order. Pools are disjoint, and nothing an apply
//! mutates (manager leases, `containers_created`, in-flight tables, the
//! EWMA — which only moves in `on_complete`) feeds another pool's decide
//! within the same drain, so batching all decides before the first apply
//! produces byte-identical plans to the serial interleaving — the
//! threads-parity invariant the fuzzer re-checks on every seed. With one
//! thread (or one shard) the drain runs the exact serial
//! decide-then-apply-per-pool loop unchanged.
//!
//! Every *scaling* concern — classification, pressure reporting,
//! fault × autoscale factor composition, substrate application, provision
//! accounting — lives behind the [`ElasticLane`] abstraction
//! ([`crate::lanes`]): the backend holds one [`CpuLane`], one [`GpuLane`],
//! and one [`ApiLane`] and routes `scale_classes` / `resize` / the pool
//! fault injections generically over the lane array — no per-class
//! `match` remains on those paths.

use super::backend::{Backend, Started, StartedSink, Verdict};
use crate::action::{Action, ActionId, ResourceKindId, TrajId};
use crate::autoscale::{LaneKey, PoolPressure};
use crate::cluster::api::ApiOutcome;
use crate::cluster::cpu::CpuLatency;
use crate::cluster::gpu::RestoreModel;
use crate::lanes::{ApiLane, CpuLane, ElasticLane, GpuLane, PoolId};
use crate::managers::{CpuManager, GpuManager, ServiceSpec};
use crate::rollout::workloads::Catalog;
use crate::scenario::ScenarioEvent;
use crate::scheduler::{Decision, ElasticScheduler, ResourceMap, SchedulerConfig};
use crate::sim::{SimDur, SimTime};
use crate::util::stopwatch::Stopwatch;
use std::collections::{BTreeSet, HashMap, HashSet};
use std::sync::Arc;

/// Cluster-scale knobs for the Tangram deployment.
#[derive(Debug, Clone)]
pub struct TangramCfg {
    pub cpu_nodes: u32,
    pub numa_per_node: u32,
    pub cores_per_numa: u32,
    pub node_mem_gb: u64,
    pub gpu_nodes: u32,
    pub sched: SchedulerConfig,
    pub cpu_latency: CpuLatency,
    pub restore: RestoreModel,
    pub max_api_retries: u32,
    /// Debug/bench escape hatch: schedule every pool on every pump (the
    /// pre-dirty-pool behaviour) instead of only dirty pools.
    pub full_sweep: bool,
    /// Differential escape hatch: plain arrival-order queues instead of
    /// per-tenant weighted-fair queues. Indistinguishable on single-tenant
    /// runs (WFQ degenerates to FCFS there); the fairness tests compare
    /// multi-tenant runs against this baseline.
    pub fcfs_queues: bool,
}

impl Default for TangramCfg {
    fn default() -> Self {
        TangramCfg {
            cpu_nodes: 5,
            numa_per_node: 2,
            cores_per_numa: 128,
            node_mem_gb: 2400,
            gpu_nodes: 5,
            sched: SchedulerConfig::default(),
            cpu_latency: CpuLatency::default(),
            restore: RestoreModel::default(),
            max_api_retries: 3,
            full_sweep: false,
            fcfs_queues: false,
        }
    }
}

pub struct TangramBackend {
    cfg: TangramCfg,
    cpu_kind: ResourceKindId,
    gpu_kind: ResourceKindId,
    /// The elastic lanes, one per pool class. Each lane owns its
    /// substrate manager(s) AND the FCFS queues feeding it; the scheduling
    /// hot path reads the managers through the lanes' `Deref`.
    pub cpu: CpuLane,
    pub gpu: GpuLane,
    pub api: ApiLane,
    pub sched: ElasticScheduler,
    /// pools whose state changed since the last drain (sorted iteration)
    dirty: BTreeSet<PoolId>,
    /// Cached sorted full-sweep pool index (every lane's sub-pools in lane
    /// order). Built once at construction; any lane topology change (none
    /// exists today — nodes and endpoints are fixed at deploy) must call
    /// [`Self::rebuild_pool_index`] to invalidate it. Replaces the fresh
    /// sorted `Vec<PoolId>` the drain path used to allocate per call.
    all_pools: Vec<PoolId>,
    /// Logical drain shards (see the module docs): contiguous slices of
    /// the sorted pool work-list, processed in ascending order. `1` is the
    /// unsharded path; any value yields byte-identical decisions.
    shards: usize,
    /// Worker-thread budget for the decide half of a drain (see the module
    /// docs). Effective parallelism is `threads.min(shard_count)`; `1` is
    /// the serial path, and any value yields byte-identical decisions.
    threads: usize,
    /// trajectories that have already run their first CPU action (container
    /// creation charged once)
    containers_created: HashSet<TrajId>,
    /// outcome of the in-flight attempt per API action
    api_outcomes: HashMap<ActionId, ApiOutcome>,
    /// exec duration of the in-flight attempt (feeds the §4.2 historical-
    /// average estimator on successful completion)
    inflight_exec: HashMap<ActionId, SimDur>,
    /// scheduling-decision count + cumulative wall time (hot-path metric)
    pub sched_invocations: u64,
    pub sched_wall: std::time::Duration,
    /// drain_started call count + cumulative wall time
    pub drain_calls: u64,
    pub drain_wall: std::time::Duration,
}

/// Deferred outcome of the read-only decision half of one pool visit.
///
/// Produced by [`TangramBackend::decide_pool`] (shared `&self`, safe on
/// worker threads) and consumed by `apply_plan` on the driver thread in
/// ascending shard order — the deterministic-merge contract.
pub(crate) enum PoolPlan {
    /// Nothing to decide (empty CPU/GPU queue).
    Empty,
    /// CPU or GPU pool: elastic-scheduler decisions with the liveness
    /// guard already folded in, plus the scheduler wall time they cost
    /// (the invocation-count delta is always exactly one).
    Decisions { decisions: Vec<Decision>, wall: std::time::Duration },
    /// API pool: admission is inherently serial — every admitted call
    /// advances the endpoint's PRNG and quota window — so the whole arm
    /// runs in the apply half. The marker still flows through the plan
    /// pipeline so threaded and serial drains share one code path.
    Api,
}

/// Contiguous balanced chunk `[lo, hi)` of a `len`-pool work-list for
/// `shard` of `shards` shards. Chunks tile the list in ascending order, so
/// processing shards `0..shards` in order visits pools in exactly the
/// serial (sorted) order — the deterministic-merge invariant the
/// shard-parity tests pin. Shared with the worker pool in
/// [`crate::coordinator::parallel`] so both sides cut identical slices.
pub(crate) fn shard_slice(len: usize, shard: usize, shards: usize) -> (usize, usize) {
    (shard * len / shards, (shard + 1) * len / shards)
}

impl TangramBackend {
    pub fn new(cat: &Catalog, cfg: TangramCfg) -> Self {
        let cpu_mgr = CpuManager::new(
            cfg.cpu_nodes,
            cfg.numa_per_node,
            cfg.cores_per_numa,
            cfg.node_mem_gb,
            cfg.cpu_latency.clone(),
        );
        let services: Vec<ServiceSpec> = cat.services.clone();
        let mut gpu_mgr = GpuManager::new(cfg.gpu_nodes, cfg.restore.clone(), services);
        gpu_mgr.prewarm(SimTime::ZERO);
        let mut be = TangramBackend {
            sched: ElasticScheduler::new(cfg.sched.clone()),
            cfg,
            cpu_kind: cat.cpu_cores,
            gpu_kind: cat.gpu_units,
            cpu: CpuLane::new(cpu_mgr, cat.cpu_cores),
            gpu: GpuLane::new(gpu_mgr, cat.gpu_units),
            api: ApiLane::new(&cat.api),
            dirty: BTreeSet::new(),
            all_pools: Vec::new(),
            shards: 1,
            threads: 1,
            containers_created: HashSet::new(),
            api_outcomes: HashMap::new(),
            inflight_exec: HashMap::new(),
            sched_invocations: 0,
            sched_wall: std::time::Duration::ZERO,
            drain_calls: 0,
            drain_wall: std::time::Duration::ZERO,
        };
        be.rebuild_pool_index();
        if be.cfg.fcfs_queues {
            be.for_each_queue(|q| q.set_fcfs(true));
        }
        be
    }

    /// Visit every lane queue (construction-time configuration only: WFQ
    /// weights, the FCFS differential knob). Queues are all empty here, and
    /// the applied setting is per-queue — visit order cannot matter.
    fn for_each_queue(&mut self, mut f: impl FnMut(&mut crate::coordinator::queue::ActionQueue)) {
        // arl-lint: allow(nondet-iteration): per-queue configuration — each
        // queue gets the same setting, order-insensitive
        for q in self.cpu.queues.values_mut() {
            f(q);
        }
        f(&mut self.gpu.queue);
        // arl-lint: allow(nondet-iteration): per-queue configuration — each
        // queue gets the same setting, order-insensitive
        for q in self.api.queues.values_mut() {
            f(q);
        }
    }

    /// Every lane in [`PoolClass`] order — the deterministic classification
    /// probe order, pressure-row order, and (concatenated over
    /// [`ElasticLane::pool_ids`]) the sorted full-sweep drain order.
    fn lanes(&self) -> [&dyn ElasticLane; 3] {
        [&self.cpu, &self.gpu, &self.api]
    }

    fn lanes_mut(&mut self) -> [&mut dyn ElasticLane; 3] {
        [&mut self.cpu, &mut self.gpu, &mut self.api]
    }

    /// Rebuild the cached sorted full-sweep pool index. Must be called
    /// after any lane add/remove (today: construction only).
    fn rebuild_pool_index(&mut self) {
        let pools: Vec<PoolId> = self.lanes().iter().flat_map(|l| l.pool_ids()).collect();
        debug_assert!(
            pools.windows(2).all(|w| w[0] < w[1]),
            "lane pool ids must concatenate into sorted PoolId order"
        );
        self.all_pools = pools;
    }

    fn classify(&self, a: &Action) -> PoolId {
        self.lanes().iter().find_map(|l| l.classify(a)).expect("action with empty cost")
    }

    /// Read-only decision half of one pool visit (see [`PoolPlan`]).
    /// Borrows `self` shared so shard workers can decide concurrently;
    /// everything it reads — queues, manager availability, the duration
    /// EWMA — is mutated only by [`Self::apply_plan`] for *other* pools or
    /// outside the drain entirely, which is what makes batched decides
    /// byte-equal to the serial decide/apply interleaving.
    pub(crate) fn decide_pool(&self, now: SimTime, pool: PoolId) -> PoolPlan {
        match pool {
            PoolId::CpuNode(node) => {
                if self.cpu.queues[&node].is_empty() {
                    return PoolPlan::Empty;
                }
                let (mut decisions, wall) = {
                    let state = self.cpu.mgr.node_state(node);
                    let mut map = ResourceMap::new();
                    map.insert(self.cpu_kind, &state);
                    let refs = self.cpu.queues[&node].refs();
                    let t0 = Stopwatch::start();
                    let d = self.sched.schedule(now, &refs, &map);
                    (d, t0.elapsed())
                };
                // Liveness guard: "wait for more capacity" is only sound
                // when something is running that will free capacity. With an
                // idle node, force the queue head at its minimum.
                if decisions.is_empty()
                    && self.cpu.mgr.node_state(node).running_completions().is_empty()
                {
                    if let Some(head) = self.cpu.queues[&node].front() {
                        let units = head.spec.cost.dim(self.cpu_kind).min_units();
                        let mut alloc = head.spec.cost.min_vector();
                        alloc.set(self.cpu_kind, units);
                        decisions.push(Decision { action: head.id, units, alloc });
                    }
                }
                PoolPlan::Decisions { decisions, wall }
            }
            PoolId::Gpu => {
                if self.gpu.queue.is_empty() {
                    return PoolPlan::Empty;
                }
                let (mut decisions, wall) = {
                    let mut map = ResourceMap::new();
                    map.insert(self.gpu_kind, &self.gpu.mgr);
                    let refs = self.gpu.queue.refs();
                    let t0 = Stopwatch::start();
                    let d = self.sched.schedule(now, &refs, &map);
                    (d, t0.elapsed())
                };
                // Liveness guard (see CPU pool): an idle cluster must not
                // "wait" — force the head at its minimum legal DoP.
                if decisions.is_empty() && self.gpu.mgr.running_completions().is_empty() {
                    if let Some(head) = self.gpu.queue.front() {
                        let units = head.spec.cost.dim(self.gpu_kind).min_units();
                        let mut alloc = head.spec.cost.min_vector();
                        alloc.set(self.gpu_kind, units);
                        decisions.push(Decision { action: head.id, units, alloc });
                    }
                }
                PoolPlan::Decisions { decisions, wall }
            }
            // API admission mutates on every step (endpoint PRNG, quota
            // bookkeeping, even the idle-loop `mgr.tick`) — decide is a
            // marker and the entire arm runs serially in the apply half.
            PoolId::Api(_) => PoolPlan::Api,
        }
    }

    /// Mutating apply half of one pool visit: queue removal, manager
    /// allocation, first-container bookkeeping, sink pushes — and the whole
    /// serial API admission loop. Always runs on the driver thread, pools
    /// in ascending (shard, pool) order, which is exactly the serial visit
    /// order — the byte-identity invariant.
    fn apply_plan(&mut self, now: SimTime, pool: PoolId, plan: PoolPlan, out: &mut StartedSink) {
        let decisions = match plan {
            PoolPlan::Empty => return,
            PoolPlan::Api => {
                let PoolId::Api(kind) = pool else {
                    debug_assert!(false, "API plan for a non-API pool");
                    return;
                };
                self.apply_api(now, kind, out);
                return;
            }
            PoolPlan::Decisions { decisions, wall } => {
                self.sched_wall += wall;
                self.sched_invocations += 1;
                decisions
            }
        };
        match pool {
            PoolId::CpuNode(node) => {
                for dec in decisions {
                    let a = match self.cpu.queues[&node].get(dec.action) {
                        Some(rc) => rc.clone(),
                        None => continue,
                    };
                    let first = self.containers_created.insert(a.spec.trajectory);
                    let exec = a.spec.exec_dur(dec.units);
                    // overhead known only after allocate; estimate for the
                    // expected-done bookkeeping, then patch below
                    let est_done = now + exec;
                    match self.cpu.mgr.allocate(
                        a.id,
                        a.spec.trajectory,
                        dec.units as u32,
                        first,
                        est_done,
                    ) {
                        Ok(lease) => {
                            let _ = self.cpu.queues.get_mut(&node).unwrap().remove(a.id);
                            self.inflight_exec.insert(a.id, exec);
                            out.push(Started {
                                action: a.id,
                                overhead: lease.overhead,
                                exec,
                                units: dec.units,
                            });
                        }
                        Err(_) => {
                            // topology raced (or the pool was cordoned under
                            // us); the action stays queued — the stall
                            // re-arm in drain_started and the cordon-restore
                            // injection keep the pool scheduled. Undo the
                            // first-action marker.
                            if first {
                                self.containers_created.remove(&a.spec.trajectory);
                            }
                        }
                    }
                }
            }
            PoolId::Gpu => {
                for dec in decisions {
                    let a = match self.gpu.queue.get(dec.action) {
                        Some(rc) => rc.clone(),
                        None => continue,
                    };
                    let service = a.spec.service.expect("GPU action without service");
                    let exec = a.spec.exec_dur(dec.units);
                    match self.gpu.mgr.allocate(a.id, service, dec.units as u8, now + exec) {
                        Ok(lease) => {
                            let _ = self.gpu.queue.remove(a.id);
                            self.inflight_exec.insert(a.id, exec);
                            out.push(Started {
                                action: a.id,
                                overhead: lease.overhead,
                                exec,
                                units: dec.units,
                            });
                        }
                        Err(_) => {}
                    }
                }
            }
            PoolId::Api(_) => debug_assert!(false, "decision plan for an API pool"),
        }
    }

    /// The serial API admission loop (see [`PoolPlan::Api`]): provider
    /// concurrency via the Basic manager plus the provider's remaining
    /// window quota, admitted strictly in queue order.
    fn apply_api(&mut self, now: SimTime, kind: ResourceKindId, out: &mut StartedSink) {
        loop {
            let mgr = self.api.mgrs.get_mut(&kind).unwrap();
            mgr.tick(now);
            let ep = self.api.endpoints.get_mut(&kind).unwrap();
            let q = self.api.queues.get_mut(&kind).unwrap();
            if q.is_empty() {
                break;
            }
            // admission: provider concurrency via the Basic manager
            // plus the provider's remaining window quota
            if mgr.available_units() == 0 || ep.quota_left(now) == 0 {
                break;
            }
            let a = q.pop_front().expect("non-empty queue has a head");
            let (outcome, dur) = ep.issue(now);
            debug_assert_ne!(
                outcome,
                ApiOutcome::RateLimited,
                "admission control must prevent provider 429s"
            );
            mgr.allocate(a.id, 1, now + dur).expect("admission raced");
            self.api_outcomes.insert(a.id, outcome);
            self.inflight_exec.insert(a.id, dur);
            out.push(Started { action: a.id, overhead: SimDur::ZERO, exec: dur, units: 1 });
        }
    }

    /// Run the elastic scheduler over one queue and apply its decisions —
    /// the fused serial path (each pool's decide immediately applied),
    /// bitwise the pre-threading code path and the `threads == 1`
    /// behaviour.
    fn schedule_pool(&mut self, now: SimTime, pool: PoolId, out: &mut StartedSink) {
        let plan = self.decide_pool(now, pool);
        self.apply_plan(now, pool, plan, out);
    }

    /// Every pool in *sorted* order — the cached full-sweep index, built
    /// at construction and rebuilt only on lane add/remove.
    pub fn all_pools(&self) -> &[PoolId] {
        &self.all_pools
    }

    /// Schedulable pools in this deployment (CPU nodes + GPU + endpoints).
    pub fn pool_count(&self) -> usize {
        self.all_pools.len()
    }

    /// Currently-provisioned API quota lanes (sum of provider concurrency
    /// limits after any flaps/resizes).
    pub fn provisioned_lanes(&self) -> u64 {
        self.api.provisioned_lanes()
    }

    /// Shards actually used for a work-list of `len` pools: never more
    /// shards than pools, never fewer than one.
    fn shard_count(&self, len: usize) -> usize {
        self.shards.min(len).max(1)
    }

    /// Contiguous balanced chunk `[lo, hi)` of a `len`-pool work-list for
    /// `shard` of [`Self::shard_count`] shards. Chunks tile the list in
    /// ascending order, so processing shards 0..n in order visits pools in
    /// exactly the serial (sorted) order — the deterministic-merge
    /// invariant the shard-parity tests pin.
    fn shard_bounds(&self, len: usize, shard: usize) -> (usize, usize) {
        shard_slice(len, shard, self.shard_count(len))
    }

    /// Worker threads a drain over `len` pools actually uses: one per
    /// shard up to the configured budget, never fewer than one. With
    /// `--shards 1` the drain stays serial regardless of the budget —
    /// parallelism comes from shards, threads only execute them.
    fn worker_count(&self, len: usize) -> usize {
        self.threads.min(self.shard_count(len)).max(1)
    }

    /// Mean wall-clock per invocation of one counted hot-path stat.
    fn mean_latency(total: std::time::Duration, count: u64) -> std::time::Duration {
        if count == 0 {
            return std::time::Duration::ZERO;
        }
        total / count as u32
    }

    /// Mean scheduler decision latency (wall-clock, for §Perf).
    pub fn mean_sched_latency(&self) -> std::time::Duration {
        Self::mean_latency(self.sched_wall, self.sched_invocations)
    }

    /// Mean `drain_started` wall time (the whole pump hot path).
    pub fn mean_drain_latency(&self) -> std::time::Duration {
        Self::mean_latency(self.drain_wall, self.drain_calls)
    }
}

impl Backend for TangramBackend {
    fn name(&self) -> &'static str {
        "arl-tangram"
    }

    fn traj_start(
        &mut self,
        _now: SimTime,
        traj: TrajId,
        mem_gb: u64,
        first_cpu_min: Option<u32>,
    ) -> Result<(), String> {
        if let Some(min_cores) = first_cpu_min {
            self.cpu.mgr.bind_trajectory(traj, min_cores, mem_gb)?;
        }
        Ok(())
    }

    fn traj_end(&mut self, _now: SimTime, traj: TrajId) {
        if let Some(node) = self.cpu.mgr.binding(traj) {
            let _ = self.cpu.mgr.release_trajectory(traj);
            self.containers_created.remove(&traj);
            // container teardown returns memory and any still-assigned
            // cgroup cores to the node — capacity moved, so the pool must
            // be rescheduled on the pump that follows
            self.dirty.insert(PoolId::CpuNode(node));
        }
    }

    fn submit(&mut self, _now: SimTime, action: &Arc<Action>) {
        let pool = self.classify(action);
        match pool {
            PoolId::CpuNode(n) => self.cpu.queues.get_mut(&n).unwrap().push_back(action.clone()),
            PoolId::Gpu => self.gpu.queue.push_back(action.clone()),
            PoolId::Api(k) => self.api.queues.get_mut(&k).unwrap().push_back(action.clone()),
        }
        self.dirty.insert(pool);
    }

    fn on_complete(&mut self, now: SimTime, action: &Action) -> Verdict {
        let pool = self.classify(action);
        let exec = self.inflight_exec.remove(&action.id);
        let verdict = match pool {
            PoolId::CpuNode(_) => {
                self.cpu.mgr.complete(action.id).expect("cpu complete");
                Verdict::Done
            }
            PoolId::Gpu => {
                self.gpu.mgr.complete(action.id, now).expect("gpu complete");
                Verdict::Done
            }
            PoolId::Api(k) => {
                let outcome = self
                    .api_outcomes
                    .remove(&action.id)
                    .unwrap_or(ApiOutcome::Ok);
                let mgr = self.api.mgrs.get_mut(&k).unwrap();
                mgr.complete(action.id, 1);
                self.api.endpoints.get_mut(&k).unwrap().finish(outcome);
                match outcome {
                    ApiOutcome::Ok => Verdict::Done,
                    _ => {
                        // transient failure — retry under admission control
                        // (driver enforces the retry budget)
                        Verdict::Retry
                    }
                }
            }
        };
        // §4.2 historical-average estimator: successful attempts feed the
        // per-kind EWMA the scheduler uses for unprofiled actions. The
        // observation moves the estimate for every queued unprofiled action
        // of this kind — the one cross-pool coupling in the dirty contract —
        // so any pool holding one must be re-evaluated, exactly as the
        // legacy full sweep would have.
        if verdict == Verdict::Done {
            if let Some(exec) = exec {
                let kind = action.spec.kind;
                self.sched.stats.observe(kind, exec);
                // arl-lint: allow(nondet-iteration): only inserts into the
                // dirty BTreeSet — membership is order-insensitive
                for (&node, q) in self.cpu.queues.iter() {
                    if q.has_unprofiled(kind) {
                        self.dirty.insert(PoolId::CpuNode(node));
                    }
                }
                if self.gpu.queue.has_unprofiled(kind) {
                    self.dirty.insert(PoolId::Gpu);
                }
            }
        }
        // capacity freed (or the retry will resubmit) — the pool must be
        // rescheduled on this pump
        self.dirty.insert(pool);
        verdict
    }

    fn drain_started_into(&mut self, now: SimTime, sink: &mut StartedSink) {
        let t0 = Stopwatch::start();
        if self.cfg.full_sweep {
            if self.worker_count(self.all_pools.len()) > 1 {
                // Threaded sweep: batch-decide every shard slice on the
                // worker pool, then apply in ascending shard order (the
                // serial visit order — see the module docs).
                let pools = self.all_pools.clone();
                let shards = self.shard_count(pools.len());
                let workers = self.worker_count(pools.len());
                let plans = super::parallel::decide_shards(self, now, &pools, shards, workers);
                for segment in plans {
                    for (pool, plan) in segment {
                        self.apply_plan(now, pool, plan, sink);
                    }
                }
            } else {
                // Cached sorted index, walked by index so a panic inside
                // schedule_pool (however unlikely) can never leave the cache
                // empty — the old take/put-back idiom lost `all_pools` on any
                // unwind between the take and the restore. The index loop is a
                // `while` because holding a borrow of `self.all_pools` across
                // the `&mut self` call is not possible.
                for shard in 0..self.shard_count(self.all_pools.len()) {
                    let (mut i, hi) = self.shard_bounds(self.all_pools.len(), shard);
                    while i < hi {
                        let pool = self.all_pools[i];
                        self.schedule_pool(now, pool, sink);
                        i += 1;
                    }
                }
            }
        } else {
            // BTreeSet iteration = sorted PoolId order (determinism); the
            // shard partition is contiguous over that order, so ascending
            // shards concatenate back into exactly the serial visit order.
            let pools: Vec<PoolId> = std::mem::take(&mut self.dirty).into_iter().collect();
            if self.worker_count(pools.len()) > 1 {
                let shards = self.shard_count(pools.len());
                let workers = self.worker_count(pools.len());
                let plans = super::parallel::decide_shards(self, now, &pools, shards, workers);
                for segment in plans {
                    for (pool, plan) in segment {
                        let before = sink.len();
                        self.apply_plan(now, pool, plan, sink);
                        // re-arm rules identical to the serial loop below
                        if sink.len() > before {
                            self.dirty.insert(pool);
                            continue;
                        }
                        if self.lanes().iter().any(|l| l.has_stalled_waiters(pool)) {
                            self.dirty.insert(pool);
                        }
                    }
                }
            } else {
                for shard in 0..self.shard_count(pools.len()) {
                    let (lo, hi) = self.shard_bounds(pools.len(), shard);
                    for &pool in &pools[lo..hi] {
                        let before = sink.len();
                        self.schedule_pool(now, pool, sink);
                        if sink.len() > before {
                            // Started something — the pool's own state changed,
                            // so it is dirty again by definition. Re-arming
                            // keeps parity with the legacy sweep: the eviction
                            // estimate may have planned an immediate follow-on
                            // start on the leftover budget, which the sweep
                            // realized at the driver's next same-instant pump.
                            self.dirty.insert(pool);
                            continue;
                        }
                        // Stall re-arm: a pool with waiting work, nothing
                        // running that will free capacity, and nothing started
                        // (e.g. the liveness guard's forced head lost its cores
                        // to a cordon) has no future event of its own to dirty
                        // it — keep it dirty so every pump retries until
                        // capacity returns (cordon restore, traj teardown).
                        // Each lane owns its class's stall predicate.
                        if self.lanes().iter().any(|l| l.has_stalled_waiters(pool)) {
                            self.dirty.insert(pool);
                        }
                    }
                }
            }
        }
        self.drain_calls += 1;
        self.drain_wall += t0.elapsed();
    }

    fn has_dirty(&self) -> bool {
        if self.cfg.full_sweep {
            return true;
        }
        !self.dirty.is_empty()
    }

    fn next_wakeup(&self, now: SimTime) -> Option<SimTime> {
        // quota-gated API queues wake at the next window boundary
        let mut earliest: Option<SimTime> = None;
        // arl-lint: allow(nondet-iteration): min-reduction over all
        // endpoints — the result is independent of visit order
        for (kind, q) in &self.api.queues {
            if q.is_empty() {
                continue;
            }
            let ep = &self.api.endpoints[kind];
            if ep.quota_left(now) == 0 {
                let w = ep.spec.quota_window.0;
                let next = SimTime((now.0 / w + 1) * w);
                earliest = Some(earliest.map_or(next, |e: SimTime| e.min(next)));
            }
        }
        earliest
    }

    fn tick(&mut self, now: SimTime) {
        // arl-lint: allow(nondet-iteration): each manager ticks its own
        // isolated state — no cross-manager coupling
        for mgr in self.api.mgrs.values_mut() {
            mgr.tick(now);
        }
        // a tick can roll quota windows open — any endpoint with waiting
        // work must be rescheduled on the pump that follows
        // arl-lint: allow(nondet-iteration): only inserts into the dirty
        // BTreeSet — membership is order-insensitive
        for (kind, q) in &self.api.queues {
            if !q.is_empty() {
                self.dirty.insert(PoolId::Api(*kind));
            }
        }
    }

    fn utilization(&self) -> Vec<(String, f64)> {
        vec![
            ("cpu".into(), self.cpu.mgr.utilization()),
            ("gpu".into(), self.gpu.mgr.utilization()),
        ]
    }

    fn provisioned(&self) -> Vec<(String, u64)> {
        // one billing gauge per lane, named by class, in lane order
        self.lanes()
            .iter()
            .map(|l| (l.class().name().to_string(), l.provisioned_units()))
            .collect()
    }

    fn scale_classes(&self) -> Vec<PoolPressure> {
        // lanes in class order, rows endpoint-sorted within each lane —
        // the autoscaler's deterministic (class, endpoint) eval order
        self.lanes().iter().flat_map(|l| l.pressures()).collect()
    }

    fn resize(&mut self, _now: SimTime, key: LaneKey, factor: f64) -> Option<u64> {
        // the autoscaler owns its own factor; the lane composes it with any
        // injected fault and pushes the product through the same cordon /
        // provider-limit machinery as `inject` — including the dirty list,
        // so the pump that follows reschedules the affected pools
        let resized = {
            let mut lanes = self.lanes_mut();
            let lane = lanes.iter_mut().find(|l| l.class() == key.class)?;
            lane.set_auto(key.endpoint, factor)
        };
        for pool in resized.dirty {
            self.dirty.insert(pool);
        }
        Some(resized.reached)
    }

    fn set_tenant_weights(&mut self, weights: &[(u32, u32)]) {
        self.for_each_queue(|q| q.set_weights(weights));
    }

    fn set_shards(&mut self, n: usize) {
        self.shards = n.max(1);
    }

    fn set_threads(&mut self, n: usize) {
        self.threads = n.max(1);
    }

    fn inject(&mut self, _now: SimTime, event: &ScenarioEvent) -> bool {
        if let ScenarioEvent::GpuCacheFlush = event {
            // orthogonal to the GPU scale factors: residencies drop,
            // cordons are untouched — a flush mid-scale-down must not
            // cancel the autoscale factor
            self.gpu.mgr.flush_caches();
            self.dirty.insert(PoolId::Gpu);
            return true;
        }
        // every other event is a class-wide pool fault: route it through
        // the lane, which composes it with any autoscaler factor
        let Some((class, factor)) = event.pool_fault() else {
            return false;
        };
        let resized = {
            let mut lanes = self.lanes_mut();
            match lanes.iter_mut().find(|l| l.class() == class) {
                Some(lane) => lane.set_fault(factor),
                None => return false,
            }
        };
        for pool in resized.dirty {
            self.dirty.insert(pool);
        }
        resized.applied
    }
}
