//! The API quota-lane lane: per-endpoint provider state, Basic-manager
//! admission, and FCFS queues behind the [`ElasticLane`] contract. One
//! scale target **per provider endpoint** (sorted by kind id) — a flapping
//! search provider must not drag the PDF-parse lanes down with it — while
//! the class-wide fault factor models provider-side flaps hitting every
//! endpoint at once.

use super::{ElasticLane, PoolId, Resized};
use crate::action::{Action, ResourceKindId};
use crate::autoscale::{LaneKey, PoolClass, PoolPressure};
use crate::cluster::api::{ApiEndpoint, ApiEndpointSpec};
use crate::coordinator::queue::ActionQueue;
use crate::managers::BasicManager;
use std::collections::HashMap;

/// API lane: one target per endpoint, all billing into one `api_lanes`
/// provision series.
pub struct ApiLane {
    /// Admission managers (90%-of-limit margin) per endpoint.
    pub mgrs: HashMap<ResourceKindId, BasicManager>,
    /// Provider-side endpoint state per kind.
    pub endpoints: HashMap<ResourceKindId, ApiEndpoint>,
    /// Per-endpoint FCFS waiting queues.
    pub queues: HashMap<ResourceKindId, ActionQueue>,
    fault: f64,
    auto: HashMap<ResourceKindId, f64>,
}

impl ApiLane {
    pub fn new(api: &[(ResourceKindId, ApiEndpointSpec)]) -> Self {
        let mut mgrs = HashMap::new();
        let mut endpoints = HashMap::new();
        let mut queues = HashMap::new();
        for (i, (kind, spec)) in api.iter().enumerate() {
            // admit to ~90% of the provider's hard limit: the margin absorbs
            // in-flight accounting races and keeps the provider out of its
            // load-shedding regime (where latency inflates and errors grow)
            mgrs.insert(
                *kind,
                BasicManager::concurrency(&spec.name, Self::admission_limit(spec.max_concurrency)),
            );
            endpoints.insert(*kind, ApiEndpoint::new(spec.clone(), 0x5eed + i as u64));
            queues.insert(*kind, ActionQueue::new());
        }
        ApiLane { mgrs, endpoints, queues, fault: 1.0, auto: HashMap::new() }
    }

    /// The 90%-of-provider-limit admission margin (floor 1).
    fn admission_limit(max_concurrency: u32) -> u64 {
        ((max_concurrency as f64 * 0.9) as u64).max(1)
    }

    /// Endpoint kinds in sorted order (the deterministic target order).
    pub fn kinds(&self) -> Vec<ResourceKindId> {
        // arl-lint: allow(nondet-iteration): collected then sorted — the
        // returned order is deterministic
        let mut kinds: Vec<ResourceKindId> = self.endpoints.keys().copied().collect();
        kinds.sort();
        kinds
    }

    /// Currently-provisioned quota lanes (sum of provider concurrency
    /// limits after any flaps/resizes).
    pub fn provisioned_lanes(&self) -> u64 {
        // arl-lint: allow(nondet-iteration): commutative sum — order cannot
        // change the result
        self.endpoints.values().map(|e| e.spec.max_concurrency as u64).sum()
    }

    /// Push the composed (fault × per-endpoint autoscale) factor into one
    /// provider's limits, re-derive its admission margin, and report the
    /// endpoint pool dirty.
    fn apply_one(&mut self, kind: ResourceKindId, dirty: &mut Vec<PoolId>) {
        let auto = self.auto.get(&kind).copied().unwrap_or(1.0);
        let f = (self.fault * auto).max(0.0);
        if let Some(ep) = self.endpoints.get_mut(&kind) {
            ep.scale_limits(f);
            if let Some(mgr) = self.mgrs.get_mut(&kind) {
                mgr.limit = Self::admission_limit(ep.spec.max_concurrency);
            }
            dirty.push(PoolId::Api(kind));
        }
    }
}

impl ElasticLane for ApiLane {
    fn class(&self) -> PoolClass {
        PoolClass::Api
    }

    fn classify(&self, action: &Action) -> Option<PoolId> {
        // lanes are probed in class order, so any remaining non-zero cost
        // dimension belongs to an API endpoint kind
        action
            .spec
            .cost
            .iter()
            .find(|(_, d)| d.min_units() > 0)
            .map(|(k, _)| PoolId::Api(k))
    }

    fn pool_ids(&self) -> Vec<PoolId> {
        self.kinds().into_iter().map(PoolId::Api).collect()
    }

    fn pressures(&self) -> Vec<PoolPressure> {
        // one row per provider endpoint, sorted by kind id: each provider's
        // quota lanes scale independently
        self.kinds()
            .into_iter()
            .map(|kind| {
                let ep = &self.endpoints[&kind];
                let queued = self.queues[&kind].len() as u64;
                PoolPressure {
                    key: LaneKey::endpoint(PoolClass::Api, kind.0),
                    queued,
                    // every API call occupies exactly one provider lane
                    queued_units: queued,
                    in_use_units: ep.in_flight() as u64,
                    provisioned_units: ep.spec.max_concurrency as u64,
                    baseline_units: ep.base_concurrency() as u64,
                }
            })
            .collect()
    }

    fn provisioned_units(&self) -> u64 {
        self.provisioned_lanes()
    }

    fn set_fault(&mut self, factor: f64) -> Resized {
        // fault flaps hit all providers at once; each endpoint composes the
        // flap with its own autoscale factor
        self.fault = factor;
        let mut dirty = Vec::new();
        for kind in self.kinds() {
            self.apply_one(kind, &mut dirty);
        }
        Resized {
            reached: self.provisioned_lanes(),
            applied: !self.endpoints.is_empty(),
            dirty,
        }
    }

    fn set_auto(&mut self, endpoint: Option<u32>, factor: f64) -> Resized {
        let f = factor.max(0.0);
        let mut dirty = Vec::new();
        match endpoint {
            Some(e) => {
                self.auto.insert(ResourceKindId(e), f);
                self.apply_one(ResourceKindId(e), &mut dirty);
            }
            None => {
                // blanket resize (tests / class-wide policies); apply_one
                // reads only this kind's factor, so one sorted pass does it
                for kind in self.kinds() {
                    self.auto.insert(kind, f);
                    self.apply_one(kind, &mut dirty);
                }
            }
        }
        Resized {
            reached: self.provisioned_lanes(),
            applied: !self.endpoints.is_empty(),
            dirty,
        }
    }

    fn has_stalled_waiters(&self, _pool: PoolId) -> bool {
        // API admission is never silently stalled: a queued call either
        // rides an in-flight completion or the quota-window wakeup
        // (`next_wakeup`), so there is always a future event of its own
        false
    }
}
