//! $/unit-hour cost model over the elastic lanes.
//!
//! Resource-hour accounting (`Metrics::pool_unit_hours`) treats every unit
//! alike, but a GPU-hour does not cost what a core-hour costs. A
//! [`CostModel`] attaches a **rate card** — $ per unit-hour, keyed by
//! provision-pool name (`cpu_cores`, `gpus`, `api_lanes`) with optional
//! per-endpoint overrides (`api_lanes@3`) — so `savings_vs_static` gains a
//! dollar-weighted sibling (`Metrics::savings_vs_static_cost`) and the
//! offline `--replay a --against b` comparison gains a cost-delta column.
//!
//! The model is **embedded in the `ScenarioSpec`** (and therefore in
//! recorded trace files), so replays reproduce cost figures byte-for-byte.
//! It is pure reporting: rates never influence a scheduling or scaling
//! decision, which is what keeps the pure-refactor golden-trace invariant
//! intact for static runs.
//!
//! Because billing stays one provision series per pool (per-endpoint API
//! requisitions fold into `api_lanes` — see `Autoscaler::billed_units`),
//! per-endpoint rate overrides resolve to a **baseline-weighted mean** over
//! the class's endpoints ([`CostModel::resolve`]); the resolution is
//! deterministic (sorted pressure rows) and reproducible offline from the
//! embedded catalog.

use crate::autoscale::{PoolClass, PoolPressure};
use crate::util::error::Result;
use crate::util::json::Json;
use crate::{bail, err};
use std::collections::BTreeMap;

/// A $/unit-hour rate card keyed by provision-pool name, with optional
/// per-endpoint overrides (`api_lanes@<endpoint kind id>`). The JSON form
/// is flat: every key is a pool name except the reserved `default` key.
#[derive(Debug, Clone, PartialEq)]
pub struct CostModel {
    /// Explicit rates; keys are pool names or `pool@endpoint` overrides.
    pub rates: BTreeMap<String, f64>,
    /// Rate for pools with no explicit entry.
    pub default_rate: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        // a deliberately simple on-demand-flavored rate card; every value
        // survives the shortest-round-trip f64 JSON path exactly
        let mut rates = BTreeMap::new();
        rates.insert("cpu_cores".to_string(), 0.05);
        rates.insert("gpus".to_string(), 2.5);
        rates.insert("api_lanes".to_string(), 0.25);
        CostModel { rates, default_rate: 0.05 }
    }
}

impl CostModel {
    /// Rate for one target: the `pool@endpoint` override when present,
    /// else the pool rate, else the default.
    pub fn rate_for(&self, pool: &str, endpoint: Option<u32>) -> f64 {
        if let Some(e) = endpoint {
            if let Some(r) = self.rates.get(&format!("{pool}@{e}")) {
                return *r;
            }
        }
        self.rates.get(pool).copied().unwrap_or(self.default_rate)
    }

    /// Resolve the effective per-pool rates against a deployment: pools
    /// whose class reports per-endpoint scale targets get the
    /// baseline-weighted mean of their endpoint rates (billing is a single
    /// provision series per pool), every other provisioned pool gets its
    /// plain rate. Deterministic in the (sorted) inputs.
    pub fn resolve(
        &self,
        pressures: &[PoolPressure],
        provisioned: &[(String, u64)],
    ) -> BTreeMap<String, f64> {
        let mut out = BTreeMap::new();
        for (pool, _) in provisioned {
            out.insert(pool.clone(), self.rate_for(pool, None));
        }
        for class in PoolClass::ALL {
            let rows: Vec<&PoolPressure> = pressures
                .iter()
                .filter(|p| p.key.class == class && p.key.endpoint.is_some())
                .collect();
            if rows.is_empty() {
                continue;
            }
            let total: u64 = rows.iter().map(|p| p.baseline_units).sum();
            if total == 0 {
                continue;
            }
            let weighted: f64 = rows
                .iter()
                .map(|p| self.rate_for(class.name(), p.key.endpoint) * p.baseline_units as f64)
                .sum();
            out.insert(class.name().to_string(), weighted / total as f64);
        }
        out
    }

    pub fn validate(&self) -> Result<()> {
        if !self.default_rate.is_finite() || self.default_rate < 0.0 {
            bail!("cost default rate {} must be a non-negative finite number", self.default_rate);
        }
        for (k, v) in &self.rates {
            if k.is_empty() {
                bail!("cost rate with an empty pool name");
            }
            if k == "default" {
                // reserved by the JSON form — a rates entry under this name
                // would serialize as a duplicate key and vanish on re-parse
                bail!("'default' is the fallback-rate key, not a pool name");
            }
            if !v.is_finite() || *v < 0.0 {
                bail!("cost rate '{k}' = {v} must be a non-negative finite number");
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> =
            self.rates.iter().map(|(k, v)| (k.as_str(), Json::num(*v))).collect();
        pairs.push(("default", Json::num(self.default_rate)));
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let obj = j.as_obj().ok_or_else(|| err!("'cost' must be an object"))?;
        let mut model = CostModel { rates: BTreeMap::new(), default_rate: 0.05 };
        for (k, v) in obj {
            let rate = v.as_f64().ok_or_else(|| err!("cost rate '{k}' must be a number"))?;
            if k == "default" {
                model.default_rate = rate;
            } else {
                model.rates.insert(k.clone(), rate);
            }
        }
        model.validate()?;
        Ok(model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autoscale::LaneKey;

    fn row(class: PoolClass, endpoint: Option<u32>, baseline: u64) -> PoolPressure {
        PoolPressure {
            key: LaneKey { class, endpoint },
            queued: 0,
            queued_units: 0,
            in_use_units: 0,
            provisioned_units: baseline,
            baseline_units: baseline,
        }
    }

    #[test]
    fn default_card_round_trips_through_json() {
        let m = CostModel::default();
        let j = m.to_json();
        let back = CostModel::from_json(&j).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.to_json().to_string(), j.to_string());
    }

    #[test]
    fn endpoint_override_beats_pool_rate_beats_default() {
        let mut m = CostModel::default();
        m.rates.insert("api_lanes@3".into(), 1.5);
        assert_eq!(m.rate_for("api_lanes", Some(3)), 1.5);
        assert_eq!(m.rate_for("api_lanes", Some(4)), 0.25);
        assert_eq!(m.rate_for("api_lanes", None), 0.25);
        assert_eq!(m.rate_for("pods", None), m.default_rate);
    }

    #[test]
    fn resolve_weights_endpoint_overrides_by_baseline_share() {
        let mut m = CostModel::default();
        m.rates.insert("api_lanes@0".into(), 1.0);
        m.rates.insert("api_lanes@1".into(), 3.0);
        let pressures = vec![
            row(PoolClass::Cpu, None, 128),
            row(PoolClass::Api, Some(0), 30),
            row(PoolClass::Api, Some(1), 10),
        ];
        let provisioned = vec![
            ("cpu_cores".to_string(), 128u64),
            ("api_lanes".to_string(), 40u64),
        ];
        let rates = m.resolve(&pressures, &provisioned);
        assert_eq!(rates["cpu_cores"], 0.05);
        // (1.0×30 + 3.0×10) / 40 = 1.5
        assert!((rates["api_lanes"] - 1.5).abs() < 1e-12);
    }

    #[test]
    fn resolve_covers_every_provisioned_pool() {
        let m = CostModel::default();
        let provisioned = vec![("pods".to_string(), 8u64), ("gpus".to_string(), 16u64)];
        let rates = m.resolve(&[], &provisioned);
        assert_eq!(rates["pods"], m.default_rate);
        assert_eq!(rates["gpus"], 2.5);
    }

    #[test]
    fn reserved_default_key_is_not_a_pool() {
        let mut m = CostModel::default();
        m.rates.insert("default".into(), 1.5);
        assert!(m.validate().is_err(), "a 'default' pool rate would shadow the fallback");
        // the JSON path routes the key to the fallback rate instead
        let parsed = CostModel::from_json(&Json::parse(r#"{"default":1.5}"#).unwrap()).unwrap();
        assert_eq!(parsed.default_rate, 1.5);
        assert!(parsed.rates.is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(CostModel::from_json(&Json::parse(r#"{"gpus":"lots"}"#).unwrap()).is_err());
        assert!(CostModel::from_json(&Json::parse(r#"{"gpus":-1}"#).unwrap()).is_err());
        assert!(CostModel::from_json(&Json::parse(r#"{"default":-0.5}"#).unwrap()).is_err());
        assert!(CostModel::from_json(&Json::parse("[]").unwrap()).is_err());
    }
}
