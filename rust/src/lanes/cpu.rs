//! The CPU environment lane: AOE manager + per-node FCFS queues behind the
//! [`ElasticLane`] contract. Resizes cordon cores on every node through
//! `CpuManager::set_pool_scale` (best-effort; busy cores are never
//! preempted, one core per node stays online).

use super::{ElasticLane, PoolId, Resized};
use crate::action::{Action, ResourceKindId};
use crate::autoscale::{LaneKey, PoolClass, PoolPressure};
use crate::cluster::cpu::NodeId;
use crate::coordinator::queue::ActionQueue;
use crate::managers::CpuManager;
use std::collections::HashMap;

/// CPU lane: one scale target (`endpoint == None`), one sub-pool per node.
///
/// `Deref`s to the wrapped [`CpuManager`] so the scheduling hot path (and
/// tests) keep reading allocation state through the lane.
pub struct CpuLane {
    /// The AOE manager (the `Deref` target).
    pub mgr: CpuManager,
    /// Per-node FCFS waiting queues (per-node scheduling, paper §5.2).
    pub queues: HashMap<NodeId, ActionQueue>,
    kind: ResourceKindId,
    fault: f64,
    auto: f64,
}

impl CpuLane {
    pub fn new(mgr: CpuManager, kind: ResourceKindId) -> Self {
        let queues = mgr.node_ids().into_iter().map(|n| (n, ActionQueue::new())).collect();
        CpuLane { mgr, queues, kind, fault: 1.0, auto: 1.0 }
    }

    /// The resource kind this lane's cost dimension is keyed by.
    pub fn kind(&self) -> ResourceKindId {
        self.kind
    }

    /// Push the composed (fault × autoscale) factor into the cordon
    /// machinery; every node must be re-dirtied — capacity moved either
    /// way, and a restore must immediately revive stalled queues (the
    /// queue-stall bugfix).
    fn apply(&mut self) -> Vec<PoolId> {
        let f = (self.fault * self.auto).clamp(0.0, 1.0);
        self.mgr.set_pool_scale(f);
        self.pool_ids()
    }
}

impl std::ops::Deref for CpuLane {
    type Target = CpuManager;
    fn deref(&self) -> &CpuManager {
        &self.mgr
    }
}

impl std::ops::DerefMut for CpuLane {
    fn deref_mut(&mut self) -> &mut CpuManager {
        &mut self.mgr
    }
}

impl ElasticLane for CpuLane {
    fn class(&self) -> PoolClass {
        PoolClass::Cpu
    }

    fn classify(&self, action: &Action) -> Option<PoolId> {
        if action.spec.cost.dim(self.kind).min_units() == 0 {
            return None;
        }
        let node = self
            .mgr
            .binding(action.spec.trajectory)
            .expect("CPU action for unbound trajectory");
        Some(PoolId::CpuNode(node))
    }

    fn pool_ids(&self) -> Vec<PoolId> {
        // arl-lint: allow(nondet-iteration): collected then sorted — the
        // returned order is deterministic
        let mut nodes: Vec<NodeId> = self.queues.keys().copied().collect();
        nodes.sort();
        nodes.into_iter().map(PoolId::CpuNode).collect()
    }

    fn pressures(&self) -> Vec<PoolPressure> {
        let total = self.mgr.total_cores();
        let cordoned = self.mgr.cordoned_cores() as u64;
        let free = self.mgr.free_cores();
        vec![PoolPressure {
            key: LaneKey::class_wide(PoolClass::Cpu),
            // arl-lint: allow(nondet-iteration): commutative sum — order
            // cannot change the result
            queued: self.queues.values().map(|q| q.len() as u64).sum(),
            // minimum core demand of the waiting work (unit-denominated,
            // so policies never mix action counts into core sums)
            queued_units: self
                .queues
                .values() // arl-lint: allow(nondet-iteration): commutative sum
                .flat_map(|q| q.iter())
                .map(|a| a.spec.cost.dim(self.kind).min_units())
                .sum(),
            // cordoned cores read as busy in free_cores; subtract them so
            // in-use reflects real allocations only
            in_use_units: total.saturating_sub(free).saturating_sub(cordoned),
            provisioned_units: total - cordoned,
            baseline_units: total,
        }]
    }

    fn provisioned_units(&self) -> u64 {
        self.mgr.total_cores() - self.mgr.cordoned_cores() as u64
    }

    fn set_fault(&mut self, factor: f64) -> Resized {
        self.fault = factor;
        let dirty = self.apply();
        Resized { reached: self.provisioned_units(), applied: true, dirty }
    }

    fn set_auto(&mut self, _endpoint: Option<u32>, factor: f64) -> Resized {
        self.auto = factor.clamp(0.0, 1.0);
        let dirty = self.apply();
        Resized { reached: self.provisioned_units(), applied: true, dirty }
    }

    fn has_stalled_waiters(&self, pool: PoolId) -> bool {
        // a cordoned node with queued work and nothing running has no
        // completion coming to revive it — only a resize/restore will
        let PoolId::CpuNode(node) = pool else {
            return false;
        };
        self.queues.get(&node).is_some_and(|q| !q.is_empty())
            && self.mgr.node_state(node).running_completions().is_empty()
    }
}
