//! The GPU service lane: EOE manager + the cluster-wide FCFS queue behind
//! the [`ElasticLane`] contract. Resizes cordon **whole nodes** through
//! `GpuCluster::set_pool_scale` with the sticky coldest-first order (see
//! the cluster docs for the determinism invariant); a cache flush is
//! orthogonal to both factors — it drops residencies, never cordons.

use super::{ElasticLane, PoolId, Resized};
use crate::action::{Action, ResourceKindId};
use crate::autoscale::{LaneKey, PoolClass, PoolPressure};
use crate::coordinator::queue::ActionQueue;
use crate::managers::GpuManager;

/// GPU lane: one scale target (`endpoint == None`), one cluster-wide pool.
///
/// `Deref`s to the wrapped [`GpuManager`] so the scheduling hot path (and
/// tests) keep reading allocation/cache state through the lane.
pub struct GpuLane {
    /// The EOE manager (the `Deref` target).
    pub mgr: GpuManager,
    /// Cluster-wide FCFS waiting queue for GPU service actions.
    pub queue: ActionQueue,
    kind: ResourceKindId,
    fault: f64,
    auto: f64,
}

impl GpuLane {
    pub fn new(mgr: GpuManager, kind: ResourceKindId) -> Self {
        GpuLane { mgr, queue: ActionQueue::new(), kind, fault: 1.0, auto: 1.0 }
    }

    /// The resource kind this lane's cost dimension is keyed by.
    pub fn kind(&self) -> ResourceKindId {
        self.kind
    }

    /// Push the composed (fault × autoscale) factor into the whole-node
    /// cordon machinery and report the pool dirty — capacity moved either
    /// way, and a restore must immediately revive a stalled queue.
    fn apply(&mut self) -> Vec<PoolId> {
        let f = (self.fault * self.auto).clamp(0.0, 1.0);
        let _ = self.mgr.set_pool_scale(f);
        vec![PoolId::Gpu]
    }
}

impl std::ops::Deref for GpuLane {
    type Target = GpuManager;
    fn deref(&self) -> &GpuManager {
        &self.mgr
    }
}

impl std::ops::DerefMut for GpuLane {
    fn deref_mut(&mut self) -> &mut GpuManager {
        &mut self.mgr
    }
}

impl ElasticLane for GpuLane {
    fn class(&self) -> PoolClass {
        PoolClass::Gpu
    }

    fn classify(&self, action: &Action) -> Option<PoolId> {
        if action.spec.cost.dim(self.kind).min_units() == 0 {
            return None;
        }
        Some(PoolId::Gpu)
    }

    fn pool_ids(&self) -> Vec<PoolId> {
        vec![PoolId::Gpu]
    }

    fn pressures(&self) -> Vec<PoolPressure> {
        vec![PoolPressure {
            key: LaneKey::class_wide(PoolClass::Gpu),
            queued: self.queue.len() as u64,
            queued_units: self
                .queue
                .iter()
                .map(|a| a.spec.cost.dim(self.kind).min_units())
                .sum(),
            in_use_units: self.mgr.in_use_gpus(),
            provisioned_units: self.mgr.provisioned_gpus() as u64,
            baseline_units: self.mgr.total_gpus() as u64,
        }]
    }

    fn provisioned_units(&self) -> u64 {
        self.mgr.provisioned_gpus() as u64
    }

    fn set_fault(&mut self, factor: f64) -> Resized {
        self.fault = factor;
        let dirty = self.apply();
        Resized { reached: self.provisioned_units(), applied: true, dirty }
    }

    fn set_auto(&mut self, _endpoint: Option<u32>, factor: f64) -> Resized {
        self.auto = factor.clamp(0.0, 1.0);
        let dirty = self.apply();
        Resized { reached: self.provisioned_units(), applied: true, dirty }
    }

    fn has_stalled_waiters(&self, pool: PoolId) -> bool {
        // a cordoned-down cluster with queued service work and nothing
        // running sees no completion — only a resize/restore revives it
        pool == PoolId::Gpu
            && !self.queue.is_empty()
            && self.mgr.running_completions().is_empty()
    }
}
