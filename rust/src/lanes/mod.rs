//! Elastic resource lanes: the unified substrate-side abstraction behind
//! the paper's *unified action-level formulation* over heterogeneous
//! external resources.
//!
//! Before this subsystem existed, the tangram backend special-cased every
//! resource class: three copies of the compose-and-push scaling logic
//! (`apply_cpu_scale` / `apply_gpu_scale` / `apply_api_scale_one`) and a
//! per-class `match` in every scaling path (`scale_classes`, `resize`, the
//! fault injections). An [`ElasticLane`] collapses that duplication: one
//! trait, keyed by `LaneKey` (class + endpoint) targets, that owns
//!
//! * **classification** — routing an [`Action`] to the lane's sub-pool
//!   ([`ElasticLane::classify`] → [`PoolId`]);
//! * **pressure reporting** — the [`PoolPressure`] observation rows the
//!   autoscaler consumes, one per scale target, endpoint-sorted;
//! * **fault × auto factor composition** — scenario fault factors and
//!   autoscaler factors are tracked separately and COMPOSED (product) into
//!   the substrate, so a scale-up never cancels an injected provider flap
//!   and an injected restore never silently undoes an autoscaler
//!   scale-down (the two layers own different knobs in production too);
//! * **substrate application** — core cordons ([`CpuLane`]), whole-node
//!   GPU cordons ([`GpuLane`]), provider limits + admission margins
//!   ([`ApiLane`]);
//! * **provision accounting** — the `Backend::provisioned` billing gauge
//!   ([`ElasticLane::provisioned_units`]).
//!
//! # Lane contract (determinism rules)
//!
//! * Lanes enumerate in `PoolClass` order (Cpu < Gpu < Api) and each lane
//!   returns its sub-pools and pressure rows **sorted** (nodes by id,
//!   endpoints by kind id), so the concatenation over lanes is the sorted
//!   global [`PoolId`] order — the deterministic drain/eval order recorded
//!   scenario traces replay byte-for-byte.
//! * [`ElasticLane::set_fault`] / [`ElasticLane::set_auto`] return the
//!   sub-pools whose capacity moved ([`Resized::dirty`]); the backend must
//!   re-dirty exactly those so a restore immediately revives stalled
//!   queues (the cordon queue-stall contract).
//! * Resizes are best-effort: busy capacity is never preempted, and every
//!   lane keeps a floor online (one core per CPU node, one GPU node, one
//!   API lane) so minimum-width actions keep making progress.

pub mod api;
pub mod cost;
pub mod cpu;
pub mod gpu;

pub use api::ApiLane;
pub use cost::CostModel;
pub use cpu::CpuLane;
pub use gpu::GpuLane;

use crate::action::{Action, ResourceKindId};
use crate::autoscale::{PoolClass, PoolPressure};
use crate::cluster::cpu::NodeId;

/// One schedulable resource pool. The derived ordering (CPU nodes by id,
/// then the GPU cluster, then API endpoints by kind) is the deterministic
/// drain order — `BTreeSet<PoolId>` iteration visits dirty pools exactly
/// the way the legacy full sweep visited all pools.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum PoolId {
    CpuNode(NodeId),
    Gpu,
    Api(ResourceKindId),
}

/// Result of pushing a composed scale factor into a lane's substrate.
#[derive(Debug, Clone)]
pub struct Resized {
    /// Units the whole class actually reached (best-effort — busy capacity
    /// is never preempted).
    pub reached: u64,
    /// Whether the lane has a substrate that honored the factor at all
    /// (an API lane with zero endpoints reports `false`).
    pub applied: bool,
    /// Sub-pools whose capacity moved; the backend must re-dirty them so
    /// the pump that follows reschedules their queues at the resize
    /// instant.
    pub dirty: Vec<PoolId>,
}

/// A class of elastically-resizable external resource, wrapping the
/// substrate machinery (cluster managers, provider limits) plus the FCFS
/// queues that feed it. See the module docs for the lane contract.
pub trait ElasticLane {
    /// The pool class this lane scales (one lane per class).
    fn class(&self) -> PoolClass;

    /// Route an action to this lane's sub-pool; `None` when the action's
    /// cost vector does not touch this lane. Lanes are probed in class
    /// order, so the API lane may claim any remaining non-zero dimension.
    fn classify(&self, action: &Action) -> Option<PoolId>;

    /// Sub-pools of this lane in sorted order (the cached full-sweep index
    /// concatenates these across lanes).
    fn pool_ids(&self) -> Vec<PoolId>;

    /// Live demand observations, one row per scale target, sorted by
    /// endpoint — the autoscaler's deterministic evaluation order.
    fn pressures(&self) -> Vec<PoolPressure>;

    /// Currently-provisioned units of the whole class (the
    /// `Backend::provisioned` billing gauge, named [`PoolClass::name`]).
    fn provisioned_units(&self) -> u64;

    /// Set the class-wide scenario-fault factor and push the composed
    /// (fault × auto) product into the substrate.
    fn set_fault(&mut self, factor: f64) -> Resized;

    /// Set the autoscaler factor for one target (`None` sweeps every
    /// target of the lane) and push the composed product into the
    /// substrate.
    fn set_auto(&mut self, endpoint: Option<u32>, factor: f64) -> Resized;

    /// Whether `pool` (a sub-pool of this lane; `false` for any other
    /// lane's pool) is **stalled**: it has waiting work but nothing running
    /// that will free capacity, and no future event of its own will arrive
    /// to revive it. The backend keeps stalled pools dirty across drains so
    /// a later resize/restore can start their queues — this is the
    /// cordon queue-stall contract, owned by the lane so the backend's
    /// drain hot path needs no per-class `match`.
    fn has_stalled_waiters(&self, pool: PoolId) -> bool;
}
