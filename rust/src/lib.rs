//! ARL-Tangram: action-level external-resource orchestration for agentic RL.
//!
//! Reproduction of "ARL-Tangram: Unleash the Resource Efficiency in Agentic
//! Reinforcement Learning" (CS.DC 2026). The crate implements the paper's
//! three-layer architecture:
//!
//! * **Layer 3 (this crate)** — the coordinator: unified action formulation,
//!   the elastic action-level scheduler (Algorithms 1–4 of the paper), and
//!   heterogeneous resource managers (Basic / CPU-AOE / GPU-EOE).
//! * **Layer 2 (python/compile)** — JAX reward-/policy-model compute graphs,
//!   AOT-lowered to HLO text artifacts.
//! * **Layer 1 (python/compile/kernels)** — Pallas kernels called by Layer 2.
//!
//! The `runtime` module loads the AOT artifacts via PJRT and executes them
//! from the Rust hot path, so "GPU reward services" in the simulation run
//! real model compute.

pub mod action;
pub mod analysis;
pub mod autoscale;
pub mod baselines;
pub mod bench;
pub mod cluster;
pub mod coordinator;
pub mod lanes;
pub mod managers;
pub mod metrics;
pub mod config;
pub mod rollout;
pub mod runtime;
pub mod scenario;
pub mod scheduler;
pub mod sim;
pub mod testkit;
pub mod util;

pub fn crate_version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
