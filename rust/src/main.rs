//! `arl-tangram` — the launcher binary.
//!
//! Subcommands:
//!   run        run an experiment (workloads × backend) in the DES and print
//!              the metric report; `--config file.json` or flags
//!   serve      load the AOT artifacts and run a reward-scoring smoke loop
//!              through the coordinator (PJRT on the hot path)
//!   version    print build info
//!
//! Examples:
//!   arl-tangram run --workloads coding --backend tangram --batch 256
//!   arl-tangram run --config experiments/coding.json
//!   arl-tangram serve --artifacts artifacts

use arl_tangram::action::TaskId;
use arl_tangram::baselines::{BaselineBackend, ServerlessCfg};
use arl_tangram::config::{BackendKind, ExperimentCfg};
use arl_tangram::coordinator::{run, Backend, TangramBackend};
use arl_tangram::rollout::workloads::{Catalog, Workload, WorkloadKind};
use arl_tangram::runtime::{PjrtEngine, RewardModel};
use arl_tangram::util::cli::Args;
use arl_tangram::util::logging;

fn main() {
    logging::init_from_env();
    let mut argv: Vec<String> = std::env::args().collect();
    let sub = if argv.len() > 1 && !argv[1].starts_with('-') {
        argv.remove(1)
    } else {
        "run".to_string()
    };
    let code = match sub.as_str() {
        "run" => cmd_run(argv),
        "serve" => cmd_serve(argv),
        "version" => {
            println!("arl-tangram {}", arl_tangram::crate_version());
            0
        }
        other => {
            eprintln!("unknown subcommand '{other}' (expected: run | serve | version)");
            2
        }
    };
    std::process::exit(code);
}

fn cmd_run(argv: Vec<String>) -> i32 {
    let args = match Args::new("run an agentic-RL resource-management experiment")
        .opt("config", "", "JSON experiment config (overrides other flags)")
        .opt("workloads", "coding", "comma list: coding,deepsearch,mopd")
        .opt("backend", "tangram", "tangram | k8s | static | serverless | unmanaged")
        .opt("batch", "128", "trajectories per RL step")
        .opt("steps", "2", "RL steps")
        .opt("seed", "42", "rng seed")
        .parse_from(argv)
    {
        Ok(a) => a,
        Err(usage) => {
            eprintln!("{usage}");
            return 2;
        }
    };

    let cfg = if !args.str("config").is_empty() {
        match std::fs::read_to_string(args.str("config"))
            .map_err(anyhow::Error::from)
            .and_then(|t| ExperimentCfg::from_json(&t))
        {
            Ok(c) => c,
            Err(e) => {
                eprintln!("config error: {e}");
                return 2;
            }
        }
    } else {
        let mut c = ExperimentCfg::default();
        c.workloads = args
            .str("workloads")
            .split(',')
            .map(str::trim)
            .map(String::from)
            .collect();
        c.backend = match BackendKind::parse(&args.str("backend")) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        c.run.batch = args.u64("batch") as usize;
        c.run.steps = args.u64("steps") as u32;
        c.run.seed = args.u64("seed");
        if let Err(e) = c.validate() {
            eprintln!("config error: {e}");
            return 2;
        }
        c
    };

    let cat = Catalog::build(&cfg.catalog);
    let wls: Vec<Workload> = cfg
        .workloads
        .iter()
        .enumerate()
        .map(|(i, w)| {
            let kind = match w.as_str() {
                "coding" => WorkloadKind::Coding,
                "deepsearch" => WorkloadKind::DeepSearch,
                _ => WorkloadKind::Mopd,
            };
            Workload::new(TaskId(i as u32), kind)
        })
        .collect();

    let mut tangram;
    let mut baseline;
    let backend: &mut dyn Backend = match cfg.backend {
        BackendKind::Tangram => {
            tangram = TangramBackend::new(&cat, cfg.tangram_cfg());
            &mut tangram
        }
        BackendKind::K8s => {
            baseline = BaselineBackend::coding(&cat, cfg.k8s_cfg());
            &mut baseline
        }
        BackendKind::StaticGpu => {
            baseline = BaselineBackend::mopd_search(&cat);
            &mut baseline
        }
        BackendKind::Serverless => {
            baseline = BaselineBackend::serverless(
                &cat,
                ServerlessCfg { gpu_nodes: cfg.catalog.gpu_nodes, ..ServerlessCfg::default() },
            );
            &mut baseline
        }
        BackendKind::Unmanaged => {
            baseline = BaselineBackend::deepsearch(&cat);
            &mut baseline
        }
    };

    let name = backend.name();
    println!(
        "running {:?} on {name}: batch={} steps={} seed={}",
        cfg.workloads, cfg.run.batch, cfg.run.steps, cfg.run.seed
    );
    let t = std::time::Instant::now();
    let m = run(backend, &cat, &wls, &cfg.run);
    println!("simulated in {:.1}s wall\n", t.elapsed().as_secs_f64());
    println!("trajectories        : {}", m.trajectories.len());
    println!("actions             : {} ({} failed, {} retries)", m.actions.len(), m.failed_actions(), m.total_retries());
    println!("mean ACT            : {:9.2}s (p99 {:.2}s)", m.mean_act(), m.p99_act());
    let (exec, queue, ovh) = m.act_breakdown();
    println!("ACT breakdown       : exec {exec:.2}s | queue {queue:.2}s | overhead {ovh:.3}s");
    println!("mean step duration  : {:9.2}s", m.mean_step_dur());
    println!("env-active ratio    : {:9.2}", m.mean_active_ratio());
    for (pool, prov) in backend.provisioned() {
        println!("provisioned {pool:<8}: {prov:9}");
    }
    0
}

fn cmd_serve(argv: Vec<String>) -> i32 {
    let args = match Args::new("load artifacts and smoke the PJRT hot path")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("requests", "16", "scoring requests to serve")
        .parse_from(argv)
    {
        Ok(a) => a,
        Err(usage) => {
            eprintln!("{usage}");
            return 2;
        }
    };
    let eng = match PjrtEngine::load(args.str("artifacts")) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("failed to load artifacts: {e}");
            return 1;
        }
    };
    println!("platform {} | {} artifacts", eng.platform(), eng.meta.artifacts.len());
    let rm = match RewardModel::init(&eng, 1) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("reward init: {e}");
            return 1;
        }
    };
    let n = args.u64("requests");
    let t = std::time::Instant::now();
    for i in 0..n {
        let tokens: Vec<i32> = (0..rm.batch * rm.seq).map(|j| ((j as u64 + i) % 64) as i32).collect();
        let mask = vec![1f32; rm.batch * rm.seq];
        match rm.score(&tokens, &mask) {
            Ok(s) => {
                if i == 0 {
                    println!("first scores: {s:?}");
                }
            }
            Err(e) => {
                eprintln!("score failed: {e}");
                return 1;
            }
        }
    }
    let dt = t.elapsed().as_secs_f64();
    println!(
        "served {n} scoring batches in {dt:.2}s ({:.1} req/s, {:.1}ms median-ish)",
        n as f64 / dt,
        dt / n as f64 * 1e3
    );
    0
}
