//! `arl-tangram` — the launcher binary.
//!
//! Subcommands:
//!   run        run an experiment (workloads × backend) in the DES and print
//!              the metric report; `--config file.json` or flags
//!   scenario   record/replay deterministic scenario traces: run a named
//!              pack (or a spec file), capture every scheduling decision as
//!              JSONL, and byte-diff a later replay against it; `--against`
//!              A/B-diffs two recordings (per-pool ACT/resource-hour table);
//!              `--autoscale` sizes pools to demand and reports the
//!              resource-hour savings vs static provisioning; `--fuzz`
//!              sweeps seeded random specs through the invariant oracle
//!   bench-gate compare a fresh BENCH_sched.json against the committed
//!              baseline (CI perf ratchet; exit 1 on >tolerance regression)
//!   lint       determinism lint: static source-level checks of the replay
//!              contracts (sorted iteration, quantized factors, no wall
//!              clock / ambient rng in decision paths) ratcheted against
//!              the committed lint_baseline.json; exit 1 on any finding
//!              the baseline does not accept
//!   serve      load the AOT artifacts and run a reward-scoring smoke loop
//!              through the coordinator (PJRT on the hot path)
//!   version    print build info
//!
//! Examples:
//!   arl-tangram run --workloads coding --backend tangram --batch 256
//!   arl-tangram run --config experiments/coding.json
//!   arl-tangram scenario --list
//!   arl-tangram scenario --pack api-flap --backend tangram --record t.jsonl
//!   arl-tangram scenario --replay t.jsonl
//!   arl-tangram scenario --pack coldstart-storm --autoscale --record auto.jsonl
//!   arl-tangram scenario --pack coldstart-storm --autoscale --admission   # overlap queue wait with cold starts
//!   arl-tangram scenario --pack gpu-thrash --autoscale   # GPU-elastic A/B reference
//!   arl-tangram scenario --replay static.jsonl --against auto.jsonl
//!   arl-tangram scenario --fuzz 0 --cases 50   # seeded fuzz + invariant oracle sweep
//!   arl-tangram scenario --pack steady-mix --shards 4    # sharded drain, byte-identical trace
//!   arl-tangram scenario --pack steady-mix --shards 4 --threads 4  # worker threads, same bytes
//!   arl-tangram scenario --pack million-action --scale 2 # multiply catalog×batch before running
//!   arl-tangram bench-gate --baseline testdata/BENCH_sched.baseline.json
//!   arl-tangram lint --json
//!   arl-tangram serve --artifacts artifacts

use arl_tangram::action::TaskId;
use arl_tangram::analysis::{self, Baseline, LintConfig};
use arl_tangram::autoscale::{AutoscaleCfg, PolicyKind};
use arl_tangram::config::{BackendKind, ExperimentCfg};
use arl_tangram::coordinator::{run, Backend};
use arl_tangram::lanes::CostModel;
use arl_tangram::metrics::Metrics;
use arl_tangram::rollout::workloads::{Catalog, Workload, WorkloadKind};
use arl_tangram::runtime::{PjrtEngine, RewardModel};
use arl_tangram::scenario::{
    ab_compare, build_backend, builtin_packs, fuzz_spec, pack_by_name, pack_description,
    read_trace_file, replay_trace_threaded, run_scenario_tangram, run_scenario_tangram_threaded,
    run_scenario_threaded, summary_json, write_trace_file, ScenarioSpec,
};
use arl_tangram::testkit::oracle;
use arl_tangram::util::cli::Args;
use arl_tangram::util::json::Json;
use arl_tangram::util::logging;
use arl_tangram::util::stopwatch::Stopwatch;

fn main() {
    logging::init_from_env();
    let mut argv: Vec<String> = std::env::args().collect();
    let sub = if argv.len() > 1 && !argv[1].starts_with('-') {
        argv.remove(1)
    } else {
        "run".to_string()
    };
    let code = match sub.as_str() {
        "run" => cmd_run(argv),
        "scenario" => cmd_scenario(argv),
        "bench-gate" => cmd_bench_gate(argv),
        "lint" => cmd_lint(argv),
        "serve" => cmd_serve(argv),
        "version" => {
            println!("arl-tangram {}", arl_tangram::crate_version());
            0
        }
        other => {
            eprintln!(
                "unknown subcommand '{other}' (expected: run | scenario | bench-gate | lint | serve | version)"
            );
            2
        }
    };
    std::process::exit(code);
}

fn cmd_run(argv: Vec<String>) -> i32 {
    let args = match Args::new("run an agentic-RL resource-management experiment")
        .opt("config", "", "JSON experiment config (overrides other flags)")
        .opt("workloads", "coding", "comma list: coding,deepsearch,mopd")
        .opt("backend", "tangram", "tangram | k8s | static | serverless | unmanaged")
        .opt("batch", "128", "trajectories per RL step")
        .opt("steps", "2", "RL steps")
        .opt("seed", "42", "rng seed")
        .parse_from(argv)
    {
        Ok(a) => a,
        Err(usage) => {
            eprintln!("{usage}");
            return 2;
        }
    };

    let cfg = if !args.str("config").is_empty() {
        match std::fs::read_to_string(args.str("config"))
            .map_err(arl_tangram::util::error::Error::from)
            .and_then(|t| ExperimentCfg::from_json(&t))
        {
            Ok(c) => c,
            Err(e) => {
                eprintln!("config error: {e}");
                return 2;
            }
        }
    } else {
        let mut c = ExperimentCfg::default();
        c.workloads = args
            .str("workloads")
            .split(',')
            .map(str::trim)
            .map(String::from)
            .collect();
        c.backend = match BackendKind::parse(&args.str("backend")) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        c.run.batch = args.u64("batch") as usize;
        c.run.steps = args.u64("steps") as u32;
        c.run.seed = args.u64("seed");
        if let Err(e) = c.validate() {
            eprintln!("config error: {e}");
            return 2;
        }
        c
    };

    let cat = Catalog::build(&cfg.catalog);
    let wls: Vec<Workload> = cfg
        .workloads
        .iter()
        .enumerate()
        .map(|(i, w)| {
            // cfg.validate() already rejected unknown names
            let kind = WorkloadKind::parse(w).unwrap_or(WorkloadKind::Mopd);
            Workload::new(TaskId(i as u32), kind)
        })
        .collect();

    // same BackendKind→deployment matrix as `arl-tangram scenario`
    let mut backend = build_backend(&cfg.catalog, &cat, cfg.backend);

    let name = backend.name();
    println!(
        "running {:?} on {name}: batch={} steps={} seed={}",
        cfg.workloads, cfg.run.batch, cfg.run.steps, cfg.run.seed
    );
    let t = Stopwatch::start();
    let m = run(backend.as_mut(), &cat, &wls, &cfg.run);
    println!("simulated in {:.1}s wall\n", t.secs());
    println!("trajectories        : {}", m.trajectories.len());
    println!("actions             : {} ({} failed, {} retries)", m.actions.len(), m.failed_actions(), m.total_retries());
    println!("mean ACT            : {:9.2}s (p99 {:.2}s)", m.mean_act(), m.p99_act());
    let (exec, queue, ovh) = m.act_breakdown();
    println!("ACT breakdown       : exec {exec:.2}s | queue {queue:.2}s | overhead {ovh:.3}s");
    println!("mean step duration  : {:9.2}s", m.mean_step_dur());
    println!("env-active ratio    : {:9.2}", m.mean_active_ratio());
    for (pool, prov) in backend.provisioned() {
        println!("provisioned {pool:<8}: {prov:9}");
    }
    print_resource_report(&m, false);
    0
}

/// A `scenario` usage error: the message for stderr; the caller exits 2.
#[derive(Debug, PartialEq)]
struct UsageError(String);

impl std::fmt::Display for UsageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.0.fmt(f)
    }
}

/// Where the `scenario` run path gets its spec from.
#[derive(Debug, PartialEq)]
enum SpecSource {
    File(String),
    Pack(String),
}

/// What a validated `scenario` flag set asks for.
#[derive(Debug, PartialEq)]
enum ScenarioMode {
    List,
    Fuzz,
    Against { replay: String, against: String },
    Replay { path: String, shards: usize, threads: usize },
    Run {
        source: SpecSource,
        backend: BackendKind,
        full_sweep: bool,
        shards: usize,
        threads: usize,
        scale: u32,
    },
}

/// The `scenario` subcommand's flag set, lifted out of [`Args`] so every
/// usage rule lives in one unit-testable decision function instead of
/// scattered eprintln-and-exit checks.
#[derive(Debug, Default, Clone)]
struct ScenarioArgs {
    list: bool,
    pack: String,
    spec: String,
    backend: String,
    record: String,
    replay: String,
    against: String,
    fuzz: String,
    cases: u64,
    full_sweep: bool,
    autoscale: bool,
    autoscale_policy: String,
    admission: bool,
    shards: u64,
    threads: u64,
    scale: u64,
}

impl ScenarioArgs {
    fn from_cli(args: &Args) -> ScenarioArgs {
        ScenarioArgs {
            list: args.bool("list"),
            pack: args.str("pack"),
            spec: args.str("spec"),
            backend: args.str("backend"),
            record: args.str("record"),
            replay: args.str("replay"),
            against: args.str("against"),
            fuzz: args.str("fuzz"),
            cases: args.u64("cases"),
            full_sweep: args.bool("full-sweep"),
            autoscale: args.bool("autoscale"),
            autoscale_policy: args.str("autoscale-policy"),
            admission: args.bool("admission"),
            shards: args.u64("shards"),
            threads: args.u64("threads"),
            scale: args.u64("scale"),
        }
    }

    /// Resolve the flag set to a [`ScenarioMode`], or the exact usage
    /// complaint. Mode precedence mirrors the CLI contract: `--list`, then
    /// `--fuzz`, then `--against`, then `--replay`, then the run path.
    fn validate(&self) -> Result<ScenarioMode, UsageError> {
        let usage = |m: &str| Err(UsageError(m.to_string()));
        if self.list {
            return Ok(ScenarioMode::List);
        }
        if self.shards == 0 {
            return usage("--shards must be at least 1");
        }
        if self.threads == 0 {
            return usage("--threads must be at least 1");
        }
        if self.scale == 0 {
            return usage("--scale must be at least 1 (it multiplies the spec; 1 = unscaled)");
        }
        if !self.fuzz.is_empty() {
            if !self.record.is_empty() && self.cases.max(1) != 1 {
                return usage("--record with --fuzz needs --cases 1");
            }
            if self.shards > 1 || self.threads > 1 || self.scale > 1 {
                return usage(
                    "--fuzz generates its own specs; --shards/--threads/--scale do not apply",
                );
            }
            return Ok(ScenarioMode::Fuzz);
        }
        if !self.against.is_empty() {
            if self.replay.is_empty() {
                return usage("--against needs --replay (the A side of the comparison)");
            }
            if self.shards > 1 || self.threads > 1 || self.scale > 1 {
                return usage(
                    "--against diffs recorded traces offline; --shards/--threads/--scale do not apply",
                );
            }
            return Ok(ScenarioMode::Against {
                replay: self.replay.clone(),
                against: self.against.clone(),
            });
        }
        if !self.replay.is_empty() {
            if self.scale > 1 {
                // a recording pins its spec; scaling the re-run would
                // guarantee a divergence, not test anything
                return usage("--scale multiplies a spec before it runs and cannot be combined with --replay");
            }
            return Ok(ScenarioMode::Replay {
                path: self.replay.clone(),
                shards: self.shards as usize,
                threads: self.threads as usize,
            });
        }
        let backend = BackendKind::parse(&self.backend).map_err(|e| UsageError(e.to_string()))?;
        if self.shards > 1 && backend != BackendKind::Tangram {
            return usage("--shards only applies to the tangram backend");
        }
        if self.threads > 1 && backend != BackendKind::Tangram {
            return usage("--threads only applies to the tangram backend");
        }
        if self.full_sweep && backend != BackendKind::Tangram {
            return usage("--full-sweep only applies to the tangram backend");
        }
        if self.full_sweep && !self.record.is_empty() {
            // a recorded trace replays through the default (dirty-pool)
            // scheduler; pinning a sweep-mode recording would report
            // spurious divergences
            return usage("--full-sweep is an A/B debug mode and cannot be combined with --record");
        }
        if self.autoscale {
            PolicyKind::parse(&self.autoscale_policy).map_err(|e| UsageError(e.to_string()))?;
        }
        if self.admission && !self.autoscale && self.spec.is_empty() {
            return usage(
                "--admission needs --autoscale (or a spec with an embedded autoscale config)",
            );
        }
        let source = if !self.spec.is_empty() {
            SpecSource::File(self.spec.clone())
        } else if !self.pack.is_empty() {
            SpecSource::Pack(self.pack.clone())
        } else {
            return usage("need --pack, --spec, --replay, or --list");
        };
        Ok(ScenarioMode::Run {
            source,
            backend,
            full_sweep: self.full_sweep,
            shards: self.shards as usize,
            threads: self.threads as usize,
            scale: self.scale.min(u32::MAX as u64) as u32,
        })
    }
}

fn cmd_scenario(argv: Vec<String>) -> i32 {
    let args = match Args::new("record/replay deterministic scenario traces")
        .opt("pack", "", "built-in scenario pack (see --list)")
        .opt("spec", "", "scenario spec JSON file (overrides --pack)")
        .opt("backend", "tangram", "tangram | k8s | static | serverless | unmanaged")
        .opt("seed", "", "override the spec's seed")
        .opt("record", "", "write the decision trace + summary to this JSONL file")
        .opt("replay", "", "re-run a recorded trace file and diff (exit 1 on divergence)")
        .opt("against", "", "with --replay: A/B-diff the two trace files offline instead")
        .opt("fuzz", "", "fuzz mode: oracle-check generated specs from this base seed")
        .opt("shards", "1", "tangram drain shards (traces are byte-identical for any value)")
        .opt("threads", "1", "tangram decide-half worker threads (byte-identical for any value)")
        .opt("scale", "1", "multiply the spec's catalog and batch by N before running")
        .opt("cases", "1", "with --fuzz: number of consecutive seeds to check")
        .opt("fail-out", "", "with --fuzz: write the minimized failing spec JSON here")
        .flag("list", "list built-in scenario packs")
        .flag("full-sweep", "tangram only: schedule every pool on every pump (legacy A/B baseline)")
        .flag("autoscale", "size pools to demand with the elastic autoscaler (embedded in the trace)")
        .opt("autoscale-policy", "queue", "autoscaler policy: queue | ewma")
        .flag("admission", "with --autoscale: pre-admit queued work against billed-but-warming capacity")
        .parse_from(argv)
    {
        Ok(a) => a,
        Err(usage) => {
            eprintln!("{usage}");
            return 2;
        }
    };

    let mode = match ScenarioArgs::from_cli(&args).validate() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };

    if matches!(mode, ScenarioMode::List) {
        for p in builtin_packs() {
            // multi-tenant packs carry their workloads inside the tenant
            // mixes; render those as tenant(weight):mix entries instead
            let wls: Vec<String> = if p.tenants.is_empty() {
                p.workloads.iter().map(|w| w.name().to_string()).collect()
            } else {
                p.tenants
                    .iter()
                    .map(|t| {
                        let mix: Vec<&str> = t.workloads.iter().map(|w| w.name()).collect();
                        format!("t{}(w{}):{}", t.id, t.weight, mix.join("+"))
                    })
                    .collect()
            };
            println!(
                "{:<16} workloads=[{}] batch={} steps={} seed={} events={}",
                p.name,
                wls.join(","),
                p.batch,
                p.steps,
                p.seed,
                p.events.len()
            );
            println!("{:<16}   {}", "", pack_description(&p.name));
        }
        return 0;
    }

    // ---- fuzz path (--fuzz <seed> [--cases N]) --------------------------
    if matches!(mode, ScenarioMode::Fuzz) {
        return cmd_scenario_fuzz(&args);
    }

    // ---- A/B path (--replay a.jsonl --against b.jsonl) ------------------
    if let ScenarioMode::Against { replay, against } = &mode {
        return cmd_scenario_against(replay, against);
    }

    // ---- replay path ----------------------------------------------------
    if let ScenarioMode::Replay { path, shards, threads } = &mode {
        let recorded = match read_trace_file(path) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("replay error: {e}");
                return 2;
            }
        };
        let mut knobs = String::new();
        if *shards > 1 {
            knobs.push_str(&format!(", {shards} shards"));
        }
        if *threads > 1 {
            knobs.push_str(&format!(", {threads} threads"));
        }
        println!(
            "replaying '{}' on {} ({} recorded events{})",
            recorded.spec.name,
            recorded.backend.name(),
            recorded.events.len(),
            knobs
        );
        let report = match replay_trace_threaded(&recorded, *shards, *threads) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("replay error: {e}");
                return 2;
            }
        };
        if report.identical {
            println!(
                "replay OK: {} events and metrics summary byte-identical",
                report.replayed_events
            );
            return 0;
        }
        eprintln!("REPLAY DIVERGED");
        if let Some(d) = &report.summary_diff {
            eprintln!("  summary: {d}");
        }
        for d in &report.trace_divergences {
            eprintln!("  {d}");
        }
        1
    } else {
        // ---- record/run path --------------------------------------------
        let (source, backend, full_sweep, shards, threads, scale) = match mode {
            ScenarioMode::Run { source, backend, full_sweep, shards, threads, scale } => {
                (source, backend, full_sweep, shards, threads, scale)
            }
            // list / fuzz / against / replay all returned above
            _ => return 2,
        };
        let mut spec = match source {
            SpecSource::File(path) => match std::fs::read_to_string(&path)
                .map_err(arl_tangram::util::error::Error::from)
                .and_then(|t| ScenarioSpec::from_json(&t))
            {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("spec error: {e}");
                    return 2;
                }
            },
            SpecSource::Pack(name) => match pack_by_name(&name) {
                Some(s) => s,
                None => {
                    eprintln!("unknown pack '{name}' — try `arl-tangram scenario --list`");
                    return 2;
                }
            },
        };
        if !args.str("seed").is_empty() {
            spec.seed = args.u64("seed");
        }
        if scale > 1 {
            spec.scale(scale);
        }
        if args.bool("autoscale") {
            let policy = match PolicyKind::parse(&args.str("autoscale-policy")) {
                Ok(p) => p,
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            };
            spec.autoscale = Some(AutoscaleCfg { policy, ..AutoscaleCfg::default() });
            // autoscaled CLI runs always price their unit-hours; a spec
            // file's own rate card wins over the default
            if spec.cost.is_none() {
                spec.cost = Some(CostModel::default());
            }
        }
        if args.bool("admission") {
            match spec.autoscale.as_mut() {
                Some(asc) => asc.admission = true,
                None => {
                    eprintln!(
                        "--admission needs --autoscale (or a spec with an embedded autoscale config)"
                    );
                    return 2;
                }
            }
        }
        let t = Stopwatch::start();
        // the tangram path also surfaces the scheduler hot-path counters
        let (outcome, sched) = if backend == BackendKind::Tangram {
            match run_scenario_tangram_threaded(&spec, full_sweep, shards, threads) {
                Ok((o, s)) => (o, Some(s)),
                Err(e) => {
                    eprintln!("scenario error: {e}");
                    return 2;
                }
            }
        } else {
            match run_scenario_threaded(&spec, backend, shards, threads) {
                Ok(o) => (o, None),
                Err(e) => {
                    eprintln!("scenario error: {e}");
                    return 2;
                }
            }
        };
        println!(
            "scenario '{}' on {}: {} trace events in {:.1}s wall",
            spec.name,
            backend.name(),
            outcome.events.len(),
            t.secs()
        );
        println!("summary: {}", summary_json(&outcome.metrics));
        print_resource_report(&outcome.metrics, spec.autoscale.is_some());
        if let Some(s) = sched {
            println!(
                "scheduler: {} invocations over {} drains across {} pools ({}ns mean decision, {}ns mean drain{})",
                s.invocations,
                s.drain_calls,
                s.pools,
                s.mean_sched_ns,
                s.mean_drain_ns,
                if full_sweep { ", full sweep" } else { "" }
            );
        }
        if !args.str("record").is_empty() {
            let path = args.str("record");
            if let Err(e) = write_trace_file(&path, &spec, backend, &outcome) {
                eprintln!("{e}");
                return 1;
            }
            println!("trace written to {path} (verify with: arl-tangram scenario --replay {path})");
        }
        0
    }
}

/// Per-pool resource-hour (and, with a cost model, dollar) report — the
/// paper's §6 savings surface plus its $-weighted sibling.
fn print_resource_report(m: &Metrics, autoscaled: bool) {
    for (pool, used, stat) in m.resource_rows() {
        println!("resource-hours {pool:<10}: {used:10.2} unit-h (static {stat:10.2} unit-h)");
    }
    let savings = m.savings_vs_static();
    println!(
        "savings_vs_static   : {:9.1}%{}",
        savings * 100.0,
        if autoscaled { "" } else { " (static provisioning)" }
    );
    let cost_rows = m.cost_rows();
    if !cost_rows.is_empty() {
        for (pool, rate, used, stat) in &cost_rows {
            println!(
                "cost {pool:<20}: {used:10.2} $ (static {stat:10.2} $ @ {rate} $/unit-h)"
            );
        }
        println!(
            "savings_vs_static_cost: {:7.1}%",
            Metrics::cost_savings_of(&cost_rows) * 100.0
        );
    }
    if m.multi_tenant() {
        let mut costs: std::collections::BTreeMap<u32, f64> = std::collections::BTreeMap::new();
        for (tenant, _pool, dollars) in m.tenant_cost_rows() {
            *costs.entry(tenant).or_insert(0.0) += dollars;
        }
        for (tenant, r) in m.tenant_rollups() {
            println!(
                "tenant {tenant:<6}: {:5} actions ({} failed, {} retries) \
                 | mean ACT {:8.2}s | mean queue {:8.2}s | attributed {:8.2} $",
                r.actions,
                r.failed,
                r.retries,
                r.mean_act_secs(),
                r.mean_queue_secs(),
                costs.get(&tenant).copied().unwrap_or(0.0)
            );
        }
    }
}

/// `scenario --fuzz <seed> [--cases N]`: run the `testkit::oracle` invariant
/// battery over consecutive fuzzed seeds; on a violation, shrink the spec
/// simplest-first, print (and optionally write) the minimized reproduction,
/// and exit 1 so CI promotes the seed to the regression corpus.
fn cmd_scenario_fuzz(args: &Args) -> i32 {
    let base = args.u64("fuzz");
    let cases = args.u64("cases").max(1);
    // ScenarioArgs::validate already rejected --record with --cases != 1
    let record = args.str("record");
    for i in 0..cases {
        let seed = base.wrapping_add(i);
        let spec = fuzz_spec(seed);
        let report = match oracle::check_spec(&spec) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("fuzz seed {seed}: engine error: {e}");
                return 2;
            }
        };
        if !report.is_clean() {
            eprintln!("fuzz seed {seed} VIOLATED:\n{}", report.describe());
            let (min_spec, min_msg) = oracle::minimize_failure(spec, report.describe());
            eprintln!("minimized spec:\n{}", min_spec.to_json());
            eprintln!("minimized violations:\n{min_msg}");
            let out_path = args.str("fail-out");
            if !out_path.is_empty() {
                let body = Json::obj(vec![
                    ("seed", Json::num(seed as f64)),
                    ("spec", min_spec.to_json()),
                    ("violations", Json::str(min_msg)),
                ]);
                if let Err(e) = std::fs::write(&out_path, format!("{body}\n")) {
                    eprintln!("writing {out_path}: {e}");
                }
            }
            return 1;
        }
        println!(
            "fuzz seed {seed} OK: {} actions, {} trace events",
            report.actions, report.trace_events
        );
    }
    if !record.is_empty() {
        let spec = fuzz_spec(base);
        match run_scenario_tangram(&spec, false) {
            Ok((outcome, _)) => {
                if let Err(e) = write_trace_file(&record, &spec, BackendKind::Tangram, &outcome) {
                    eprintln!("record error: {e}");
                    return 2;
                }
                println!("recorded fuzz seed {base} to {record}");
            }
            Err(e) => {
                eprintln!("record error: {e}");
                return 2;
            }
        }
    }
    0
}

/// Offline A/B diff of two recorded traces: event-stream divergence check
/// plus the per-pool ACT/resource-hour delta table. Exit 0 only when the
/// traces are byte-identical — a non-zero exit is the "these schedulers
/// behave differently" signal for scripts and CI.
fn cmd_scenario_against(path_a: &str, path_b: &str) -> i32 {
    let (a, b) = match (read_trace_file(path_a), read_trace_file(path_b)) {
        (Ok(a), Ok(b)) => (a, b),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("A/B error: {e}");
            return 2;
        }
    };
    println!(
        "A: '{}' on {} ({} events) | B: '{}' on {} ({} events)",
        a.spec.name,
        a.backend.name(),
        a.events.len(),
        b.spec.name,
        b.backend.name(),
        b.events.len()
    );
    let report = ab_compare(&a, &b);
    let fmt_delta = |d: Option<f64>| match d {
        Some(d) => format!("{:+7.1}%", d * 100.0),
        None => "      -".to_string(),
    };
    println!(
        "{:<10} {:>8} {:>8} {:>11} {:>11} {:>8} {:>11} {:>11} {:>8} {:>10} {:>10} {:>8}",
        "pool", "acts A", "acts B", "ACT A (s)", "ACT B (s)", "dACT", "unit-h A", "unit-h B",
        "dRES", "cost A ($)", "cost B ($)", "dCOST"
    );
    for r in &report.rows {
        println!(
            "{:<10} {:>8} {:>8} {:>11.2} {:>11.2} {:>8} {:>11.2} {:>11.2} {:>8} {:>10.2} {:>10.2} {:>8}",
            r.pool,
            r.a.actions,
            r.b.actions,
            r.a.mean_act_secs,
            r.b.mean_act_secs,
            fmt_delta(r.act_delta()),
            r.a.unit_hours,
            r.b.unit_hours,
            fmt_delta(r.hours_delta()),
            r.cost_a,
            r.cost_b,
            fmt_delta(r.cost_delta()),
        );
    }
    if !report.tenant_rows.is_empty() {
        println!(
            "{:<10} {:>8} {:>8} {:>11} {:>11} {:>8} {:>9} {:>9}",
            "tenant", "acts A", "acts B", "ACT A (s)", "ACT B (s)", "dACT", "retries A",
            "retries B"
        );
        for r in &report.tenant_rows {
            println!(
                "{:<10} {:>8} {:>8} {:>11.2} {:>11.2} {:>8} {:>9} {:>9}",
                r.tenant,
                r.a.actions,
                r.b.actions,
                r.a.mean_act_secs,
                r.b.mean_act_secs,
                fmt_delta(r.act_delta()),
                r.a.retries,
                r.b.retries,
            );
        }
    }
    if report.identical {
        println!("traces are byte-identical");
        return 0;
    }
    if let Some(d) = &report.summary_diff {
        eprintln!("summary diverges: {d}");
    }
    for d in &report.divergences {
        eprintln!("  {d}");
    }
    eprintln!("TRACES DIVERGE (expected for an A/B of different schedulers)");
    1
}

/// CI perf ratchet: compare a fresh BENCH_sched.json against the committed
/// baseline; exit 1 on regression, 2 on unreadable/malformed input.
fn cmd_bench_gate(argv: Vec<String>) -> i32 {
    let args = match Args::new("gate BENCH_sched.json against a committed baseline")
        .opt("baseline", "testdata/BENCH_sched.baseline.json", "committed baseline report")
        .opt("fresh", "BENCH_sched.json", "freshly generated report")
        .opt("tolerance", "0.10", "allowed relative loss of the dirty-vs-sweep ratio")
        .parse_from(argv)
    {
        Ok(a) => a,
        Err(usage) => {
            eprintln!("{usage}");
            return 2;
        }
    };
    let tolerance = match args.str("tolerance").parse::<f64>() {
        Ok(t) if (0.0..1.0).contains(&t) => t,
        _ => {
            eprintln!("--tolerance must be a number in [0, 1)");
            return 2;
        }
    };
    let read = |path: &str| {
        std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))
    };
    let (base, fresh) = match (read(&args.str("baseline")), read(&args.str("fresh"))) {
        (Ok(b), Ok(f)) => (b, f),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("bench-gate: {e}");
            return 2;
        }
    };
    match arl_tangram::bench::sched_bench_gate(&base, &fresh, tolerance) {
        Ok(report) => {
            for line in &report.lines {
                println!("{line}");
            }
            if report.passed() {
                println!("bench gate OK ({:.0}% tolerance)", tolerance * 100.0);
                0
            } else {
                for f in &report.failures {
                    eprintln!("BENCH REGRESSION: {f}");
                }
                1
            }
        }
        Err(e) => {
            eprintln!("bench-gate: {e}");
            2
        }
    }
}

fn cmd_serve(argv: Vec<String>) -> i32 {
    let args = match Args::new("load artifacts and smoke the PJRT hot path")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("requests", "16", "scoring requests to serve")
        .parse_from(argv)
    {
        Ok(a) => a,
        Err(usage) => {
            eprintln!("{usage}");
            return 2;
        }
    };
    let eng = match PjrtEngine::load(args.str("artifacts")) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("failed to load artifacts: {e}");
            return 1;
        }
    };
    println!("platform {} | {} artifacts", eng.platform(), eng.meta.artifacts.len());
    let rm = match RewardModel::init(&eng, 1) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("reward init: {e}");
            return 1;
        }
    };
    let n = args.u64("requests");
    let t = Stopwatch::start();
    for i in 0..n {
        let tokens: Vec<i32> = (0..rm.batch * rm.seq).map(|j| ((j as u64 + i) % 64) as i32).collect();
        let mask = vec![1f32; rm.batch * rm.seq];
        match rm.score(&tokens, &mask) {
            Ok(s) => {
                if i == 0 {
                    println!("first scores: {s:?}");
                }
            }
            Err(e) => {
                eprintln!("score failed: {e}");
                return 1;
            }
        }
    }
    let dt = t.secs();
    println!(
        "served {n} scoring batches in {dt:.2}s ({:.1} req/s, {:.1}ms median-ish)",
        n as f64 / dt,
        dt / n as f64 * 1e3
    );
    0
}

/// `arl-tangram lint` — the determinism lint over `rust/src`.
///
/// Exit codes: 0 = clean against the baseline, 1 = new findings or a stale
/// baseline, 2 = usage/setup error (mirrors `bench-gate`).
fn cmd_lint(argv: Vec<String>) -> i32 {
    let args = match Args::new("static determinism lint over the source tree")
        .opt("root", "src", "source root to scan")
        .opt("baseline", "lint_baseline.json", "accepted-findings baseline (shrink-only ratchet)")
        .flag("json", "emit a machine-readable report to stdout")
        .flag("write-baseline", "rewrite the baseline from current findings and exit")
        .parse_from(argv)
    {
        Ok(a) => a,
        Err(usage) => {
            eprintln!("{usage}");
            return 2;
        }
    };
    let cfg = LintConfig::default();
    let root = args.str("root");
    let findings = match analysis::lint_tree(std::path::Path::new(&root), &cfg) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("lint: {e}");
            return 2;
        }
    };
    let bpath = args.str("baseline");
    if args.bool("write-baseline") {
        let baseline = Baseline::from_findings(&findings);
        if let Err(e) = baseline.save(std::path::Path::new(&bpath)) {
            eprintln!("lint: {e}");
            return 2;
        }
        let files: usize = baseline.counts.values().map(|f| f.len()).sum();
        println!("wrote {bpath}: {} findings across {files} (rule, file) buckets", findings.len());
        return 0;
    }
    let baseline = match Baseline::load(std::path::Path::new(&bpath)) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("lint: {e}");
            return 2;
        }
    };
    let cmp = baseline.compare(&findings);
    if args.bool("json") {
        println!("{}", analysis::report_json(&findings, &cmp));
    } else {
        for v in &cmp.violations {
            eprintln!("lint: {v}");
        }
        for s in &cmp.stale {
            eprintln!("lint: {s}");
        }
        // print the individual findings for every offending bucket so the
        // fix is a line number away, not a diff of counts
        if !cmp.violations.is_empty() {
            for f in &findings {
                eprintln!("  {f}");
            }
        }
        println!(
            "lint: {} findings, {} accepted by baseline — {}",
            findings.len(),
            baseline.counts.values().map(|f| f.values().sum::<u64>()).sum::<u64>(),
            if cmp.ok() { "OK" } else { "FAIL" }
        );
    }
    if cmp.ok() {
        0
    } else {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> ScenarioArgs {
        ScenarioArgs {
            backend: "tangram".into(),
            cases: 1,
            shards: 1,
            threads: 1,
            scale: 1,
            ..ScenarioArgs::default()
        }
    }

    #[test]
    fn list_wins_over_everything() {
        let mut a = base();
        a.list = true;
        a.fuzz = "7".into();
        a.replay = "x.jsonl".into();
        assert_eq!(a.validate(), Ok(ScenarioMode::List));
    }

    #[test]
    fn fuzz_record_needs_single_case() {
        let mut a = base();
        a.fuzz = "7".into();
        a.record = "t.jsonl".into();
        a.cases = 3;
        assert!(a.validate().unwrap_err().0.contains("--cases 1"));
        a.cases = 1;
        assert_eq!(a.validate(), Ok(ScenarioMode::Fuzz));
        // the CLI clamps --cases to at least 1, so 0 means "one case"
        a.cases = 0;
        assert_eq!(a.validate(), Ok(ScenarioMode::Fuzz));
    }

    #[test]
    fn against_requires_replay() {
        let mut a = base();
        a.against = "b.jsonl".into();
        assert!(a.validate().unwrap_err().0.contains("--replay"));
        a.replay = "a.jsonl".into();
        assert_eq!(
            a.validate(),
            Ok(ScenarioMode::Against { replay: "a.jsonl".into(), against: "b.jsonl".into() })
        );
    }

    #[test]
    fn replay_mode_and_spec_precedence() {
        let mut a = base();
        a.replay = "a.jsonl".into();
        assert_eq!(
            a.validate(),
            Ok(ScenarioMode::Replay { path: "a.jsonl".into(), shards: 1, threads: 1 })
        );

        let mut a = base();
        a.pack = "steady-mix".into();
        a.spec = "custom.json".into(); // --spec overrides --pack
        assert_eq!(
            a.validate(),
            Ok(ScenarioMode::Run {
                source: SpecSource::File("custom.json".into()),
                backend: BackendKind::Tangram,
                full_sweep: false,
                shards: 1,
                threads: 1,
                scale: 1,
            })
        );
    }

    #[test]
    fn run_needs_a_source_and_a_known_backend() {
        let a = base();
        assert!(a.validate().unwrap_err().0.contains("--pack"));
        let mut a = base();
        a.pack = "steady-mix".into();
        a.backend = "quantum".into();
        assert!(a.validate().is_err());
    }

    #[test]
    fn full_sweep_rules() {
        let mut a = base();
        a.pack = "steady-mix".into();
        a.full_sweep = true;
        assert!(matches!(a.validate(), Ok(ScenarioMode::Run { full_sweep: true, .. })));
        a.backend = "k8s".into();
        assert!(a.validate().unwrap_err().0.contains("tangram"));
        a.backend = "tangram".into();
        a.record = "t.jsonl".into();
        assert!(a.validate().unwrap_err().0.contains("--record"));
    }

    #[test]
    fn admission_needs_autoscale_or_spec() {
        let mut a = base();
        a.pack = "steady-mix".into();
        a.admission = true;
        assert!(a.validate().unwrap_err().0.contains("--autoscale"));
        a.autoscale = true;
        a.autoscale_policy = "queue".into();
        assert!(matches!(a.validate(), Ok(ScenarioMode::Run { .. })));
        // a spec file may embed its own autoscale config; that case is
        // checked after the spec is loaded, not at the flag level
        let mut a = base();
        a.spec = "s.json".into();
        a.admission = true;
        assert!(matches!(a.validate(), Ok(ScenarioMode::Run { .. })));
    }

    #[test]
    fn shards_rules() {
        // zero is a usage error in any mode
        let mut a = base();
        a.pack = "steady-mix".into();
        a.shards = 0;
        assert!(a.validate().unwrap_err().0.contains("--shards"));
        // sharded tangram run and sharded replay both validate, carrying N
        a.shards = 4;
        assert!(matches!(a.validate(), Ok(ScenarioMode::Run { shards: 4, .. })));
        let mut a = base();
        a.replay = "t.jsonl".into();
        a.shards = 8;
        assert_eq!(
            a.validate(),
            Ok(ScenarioMode::Replay { path: "t.jsonl".into(), shards: 8, threads: 1 })
        );
        // non-tangram backends have no sharded drain
        let mut a = base();
        a.pack = "steady-mix".into();
        a.backend = "k8s".into();
        a.shards = 2;
        assert!(a.validate().unwrap_err().0.contains("tangram"));
        // fuzz and offline A/B reject the flag
        let mut a = base();
        a.fuzz = "7".into();
        a.shards = 2;
        assert!(a.validate().unwrap_err().0.contains("--fuzz"));
        let mut a = base();
        a.replay = "a.jsonl".into();
        a.against = "b.jsonl".into();
        a.shards = 2;
        assert!(a.validate().unwrap_err().0.contains("offline"));
    }

    #[test]
    fn threads_rules() {
        // zero is a usage error in any mode
        let mut a = base();
        a.pack = "steady-mix".into();
        a.threads = 0;
        assert!(a.validate().unwrap_err().0.contains("--threads"));
        // threaded tangram run and threaded replay both validate, carrying N
        a.threads = 4;
        assert!(matches!(a.validate(), Ok(ScenarioMode::Run { threads: 4, .. })));
        let mut a = base();
        a.replay = "t.jsonl".into();
        a.shards = 4;
        a.threads = 4;
        assert_eq!(
            a.validate(),
            Ok(ScenarioMode::Replay { path: "t.jsonl".into(), shards: 4, threads: 4 })
        );
        // non-tangram backends have no worker pool
        let mut a = base();
        a.pack = "steady-mix".into();
        a.backend = "k8s".into();
        a.threads = 2;
        assert!(a.validate().unwrap_err().0.contains("tangram"));
        // fuzz and offline A/B reject the flag
        let mut a = base();
        a.fuzz = "7".into();
        a.threads = 2;
        assert!(a.validate().unwrap_err().0.contains("--fuzz"));
        let mut a = base();
        a.replay = "a.jsonl".into();
        a.against = "b.jsonl".into();
        a.threads = 2;
        assert!(a.validate().unwrap_err().0.contains("offline"));
    }

    #[test]
    fn scale_rules() {
        let mut a = base();
        a.pack = "steady-mix".into();
        a.scale = 0;
        assert!(a.validate().unwrap_err().0.contains("--scale"));
        a.scale = 10;
        assert!(matches!(a.validate(), Ok(ScenarioMode::Run { scale: 10, .. })));
        // a recording pins its spec — scaling the re-run is a usage error
        let mut a = base();
        a.replay = "t.jsonl".into();
        a.scale = 2;
        assert!(a.validate().unwrap_err().0.contains("--replay"));
        let mut a = base();
        a.fuzz = "7".into();
        a.scale = 2;
        assert!(a.validate().unwrap_err().0.contains("--fuzz"));
    }

    #[test]
    fn autoscale_policy_is_parse_checked() {
        let mut a = base();
        a.pack = "steady-mix".into();
        a.autoscale = true;
        a.autoscale_policy = "psychic".into();
        assert!(a.validate().is_err());
        a.autoscale_policy = "ewma".into();
        assert!(a.validate().is_ok());
    }
}
