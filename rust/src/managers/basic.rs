//! Basic Resource Manager (paper §5.1).
//!
//! For external resources that cannot be scaled up — API concurrency and
//! request quotas — this manager only *admits* actions so the provider's
//! limits are never violated (preventing the 429/timeout/retry storms the
//! unmanaged baseline suffers). Two consumption patterns:
//!
//! * **concurrency-based**: at most `limit` actions in flight;
//! * **quota-based**: at most `limit` admissions per rolling window.

use crate::action::ActionId;
use crate::scheduler::{BasicOperator, DpOperator, ResourceState};
use crate::sim::{SimDur, SimTime};
use std::collections::HashMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BasicPattern {
    Concurrency,
    Quota { window: SimDur },
}

/// Admission-control manager for one non-scalable resource kind.
#[derive(Debug)]
pub struct BasicManager {
    pub name: String,
    pub pattern: BasicPattern,
    pub limit: u64,
    in_flight: u64,
    window_start: SimTime,
    window_used: u64,
    /// expected completions + held units of admitted actions (Alg 2 seed)
    active: HashMap<ActionId, (SimTime, u64)>,
    now: SimTime,
}

impl BasicManager {
    pub fn concurrency(name: &str, limit: u64) -> Self {
        BasicManager {
            name: name.into(),
            pattern: BasicPattern::Concurrency,
            limit,
            in_flight: 0,
            window_start: SimTime::ZERO,
            window_used: 0,
            active: HashMap::new(),
            now: SimTime::ZERO,
        }
    }

    pub fn quota(name: &str, limit: u64, window: SimDur) -> Self {
        BasicManager {
            pattern: BasicPattern::Quota { window },
            ..Self::concurrency(name, limit)
        }
    }

    /// Advance the manager's clock (rolls quota windows).
    pub fn tick(&mut self, now: SimTime) {
        self.now = now;
        if let BasicPattern::Quota { window } = self.pattern {
            if now - self.window_start >= window {
                let w = window.0;
                self.window_start = SimTime((now.0 / w) * w);
                self.window_used = 0;
            }
        }
    }

    fn slots_free(&self) -> u64 {
        match self.pattern {
            BasicPattern::Concurrency => self.limit.saturating_sub(self.in_flight),
            BasicPattern::Quota { .. } => self
                .limit
                .saturating_sub(self.window_used)
                // quota admissions also hold an in-flight slot until done
                .min(self.limit.saturating_sub(self.in_flight).max(0)),
        }
    }

    /// Admit `action` for `units` slots (almost always 1). Fails when the
    /// provider limit would be violated — the action must stay queued.
    pub fn allocate(
        &mut self,
        action: ActionId,
        units: u64,
        expected_done: SimTime,
    ) -> Result<(), String> {
        if units > self.slots_free() {
            return Err(format!(
                "{}: {} units requested, {} free",
                self.name,
                units,
                self.slots_free()
            ));
        }
        self.in_flight += units;
        if matches!(self.pattern, BasicPattern::Quota { .. }) {
            self.window_used += units;
        }
        self.active.insert(action, (expected_done, units));
        Ok(())
    }

    pub fn complete(&mut self, action: ActionId, units: u64) {
        debug_assert!(self.in_flight >= units);
        self.in_flight -= units;
        self.active.remove(&action);
    }

    pub fn in_flight(&self) -> u64 {
        self.in_flight
    }
}

impl ResourceState for BasicManager {
    fn available_units(&self) -> u64 {
        self.slots_free()
    }

    fn accommodate(&self, min_units: &[u64]) -> bool {
        min_units.iter().sum::<u64>() <= self.slots_free()
    }

    fn dp_operator(&self, reserved: &[u64]) -> Box<dyn DpOperator> {
        let used: u64 = reserved.iter().sum();
        Box::new(BasicOperator::new(self.slots_free().saturating_sub(used)))
    }

    fn running_completions(&self) -> Vec<(SimTime, u64)> {
        // arl-lint: allow(nondet-iteration): the scheduler heapifies these
        // by the full (time, units) pair — return order is immaterial
        self.active.values().copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concurrency_admits_up_to_limit() {
        let mut m = BasicManager::concurrency("search", 2);
        m.allocate(ActionId(1), 1, SimTime(10)).unwrap();
        m.allocate(ActionId(2), 1, SimTime(20)).unwrap();
        assert!(m.allocate(ActionId(3), 1, SimTime(30)).is_err());
        m.complete(ActionId(1), 1);
        m.allocate(ActionId(3), 1, SimTime(30)).unwrap();
        assert_eq!(m.in_flight(), 2);
    }

    #[test]
    fn quota_refills_per_window() {
        let w = SimDur::from_secs(60);
        let mut m = BasicManager::quota("q", 2, w);
        m.allocate(ActionId(1), 1, SimTime(1)).unwrap();
        m.complete(ActionId(1), 1);
        m.allocate(ActionId(2), 1, SimTime(2)).unwrap();
        m.complete(ActionId(2), 1);
        // window quota spent even though nothing is in flight
        assert_eq!(m.available_units(), 0);
        assert!(m.allocate(ActionId(3), 1, SimTime(3)).is_err());
        m.tick(SimTime::ZERO + w);
        assert_eq!(m.available_units(), 2);
        m.allocate(ActionId(3), 1, SimTime(3)).unwrap();
    }

    #[test]
    fn resource_state_views() {
        let mut m = BasicManager::concurrency("s", 4);
        m.allocate(ActionId(1), 1, SimTime(99)).unwrap();
        assert_eq!(m.available_units(), 3);
        assert!(m.accommodate(&[1, 1, 1]));
        assert!(!m.accommodate(&[2, 2]));
        let op = m.dp_operator(&[1]);
        assert_eq!(op.max_alloc(), 2);
        assert_eq!(m.running_completions(), vec![(SimTime(99), 1)]);
    }

    #[test]
    fn multi_unit_admission() {
        let mut m = BasicManager::concurrency("s", 4);
        m.allocate(ActionId(1), 3, SimTime(5)).unwrap();
        assert!(m.allocate(ActionId(2), 2, SimTime(5)).is_err());
        m.complete(ActionId(1), 3);
        assert_eq!(m.in_flight(), 0);
    }
}
