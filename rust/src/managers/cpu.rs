//! CPU Manager via allocate-on-execution (paper §5.2).
//!
//! **Breakdown**: before each `docker exec`, the container's cgroup is
//! updated to the scheduler-assigned core set; after the process exits the
//! cores are reclaimed. Memory stays reserved for the trajectory's lifetime
//! (cheap in memory-rich nodes, and it preserves environment state).
//!
//! **Pool**: cores and memory are co-managed. The first action of a
//! trajectory picks a node — filtered by "enough cores for the action and
//! enough memory for the whole trajectory", then memory-load-balanced — and
//! all later actions of that trajectory stay on it. Core selection prefers
//! a single NUMA domain. Each node runs the elastic scheduling algorithm
//! independently (128+-core nodes keep fragmentation mild).

use crate::action::{ActionId, TrajId};
use crate::cluster::cpu::{CoreId, CpuLatency, CpuNode, NodeId};
use crate::scheduler::{BasicOperator, DpOperator, ResourceState};
use crate::sim::{SimDur, SimTime};
use std::collections::HashMap;

/// A granted CPU allocation for one action.
#[derive(Debug, Clone)]
pub struct CpuLease {
    pub action: ActionId,
    pub trajectory: TrajId,
    pub node: NodeId,
    pub cores: Vec<CoreId>,
    /// AOE overhead charged before execution (cgroup update + fork, plus
    /// container creation on the trajectory's first action).
    pub overhead: SimDur,
}

#[derive(Debug)]
struct Active {
    trajectory: TrajId,
    node: NodeId,
    expected_done: SimTime,
    units: u64,
}

/// The AOE CPU manager.
#[derive(Debug)]
pub struct CpuManager {
    nodes: Vec<CpuNode>,
    pub latency: CpuLatency,
    bindings: HashMap<TrajId, NodeId>,
    active: HashMap<ActionId, Active>,
}

impl CpuManager {
    pub fn new(
        n_nodes: u32,
        numa_domains: u32,
        cores_per_numa: u32,
        mem_gb: u64,
        latency: CpuLatency,
    ) -> Self {
        CpuManager {
            nodes: (0..n_nodes)
                .map(|i| CpuNode::new(NodeId(i), numa_domains, cores_per_numa, mem_gb))
                .collect(),
            latency,
            bindings: HashMap::new(),
            active: HashMap::new(),
        }
    }

    pub fn node_ids(&self) -> Vec<NodeId> {
        self.nodes.iter().map(|n| n.id).collect()
    }

    pub fn total_cores(&self) -> u64 {
        self.nodes.iter().map(|n| n.total_cores() as u64).sum()
    }

    pub fn free_cores(&self) -> u64 {
        self.nodes.iter().map(|n| n.free_cores() as u64).sum()
    }

    pub fn binding(&self, t: TrajId) -> Option<NodeId> {
        self.bindings.get(&t).copied()
    }

    /// Bind a new trajectory to a node (§5.2 "Pool"): filter by action cores
    /// + trajectory memory, then pick the node with the most free memory
    /// (CPU-memory load balancing). Creates the container.
    pub fn bind_trajectory(
        &mut self,
        t: TrajId,
        min_cores: u32,
        traj_mem_gb: u64,
    ) -> Result<NodeId, String> {
        if let Some(n) = self.bindings.get(&t) {
            return Ok(*n);
        }
        let best = self
            .nodes
            .iter()
            .filter(|n| n.free_cores() >= min_cores && n.free_mem_gb() >= traj_mem_gb)
            .max_by_key(|n| n.free_mem_gb())
            .map(|n| n.id)
            .ok_or_else(|| {
                format!("no node with {min_cores} cores and {traj_mem_gb} GiB free")
            })?;
        self.node_mut(best).create_container(t, traj_mem_gb)?;
        self.bindings.insert(t, best);
        Ok(best)
    }

    /// Tear down a finished trajectory's container and binding.
    pub fn release_trajectory(&mut self, t: TrajId) -> Result<(), String> {
        let node = self
            .bindings
            .remove(&t)
            .ok_or_else(|| format!("{t:?} not bound"))?;
        self.node_mut(node).destroy_container(t)
    }

    /// AOE allocate: put `cores_n` cores into the trajectory's cgroup.
    /// `first_action` charges container creation. Fails (action stays
    /// queued) if the node cannot supply the cores right now.
    pub fn allocate(
        &mut self,
        action: ActionId,
        t: TrajId,
        cores_n: u32,
        first_action: bool,
        expected_done: SimTime,
    ) -> Result<CpuLease, String> {
        let node_id = *self
            .bindings
            .get(&t)
            .ok_or_else(|| format!("{t:?} not bound to a node"))?;
        let lat = self.latency.clone();
        let node = self.node_mut(node_id);
        let cores = node
            .alloc_cores(cores_n)
            .ok_or_else(|| format!("node {node_id:?} lacks {cores_n} cores"))?;
        node.cgroup_assign(t, cores.clone())?;
        let mut overhead = lat.cgroup_update + lat.exec_fork;
        if first_action {
            overhead += lat.container_create;
        }
        self.active.insert(
            action,
            Active { trajectory: t, node: node_id, expected_done, units: cores_n as u64 },
        );
        Ok(CpuLease { action, trajectory: t, node: node_id, cores, overhead })
    }

    /// AOE reclaim: process exited; cores leave the cgroup and free up.
    pub fn complete(&mut self, action: ActionId) -> Result<(), String> {
        let a = self
            .active
            .remove(&action)
            .ok_or_else(|| format!("{action:?} not active"))?;
        self.node_mut(a.node).cgroup_reclaim(a.trajectory)?;
        Ok(())
    }

    /// Scheduler view over one node (per-node scheduling, §5.2).
    pub fn node_state(&self, node: NodeId) -> CpuNodeState<'_> {
        CpuNodeState { mgr: self, node }
    }

    pub fn node(&self, id: NodeId) -> &CpuNode {
        &self.nodes[id.0 as usize]
    }

    fn node_mut(&mut self, id: NodeId) -> &mut CpuNode {
        &mut self.nodes[id.0 as usize]
    }

    /// Fraction of all cores currently allocated (utilization sample).
    /// Cordoned cores count as busy — an offline core is not idle capacity.
    pub fn utilization(&self) -> f64 {
        let total = self.total_cores() as f64;
        (total - self.free_cores() as f64) / total
    }

    /// Scenario pool-resize: keep only `available_frac` of every node's
    /// cores schedulable (best-effort — busy cores are never preempted; at
    /// least one core per node stays online so minimum-width actions keep
    /// making progress). `1.0` restores the full pool. Returns the total
    /// cordoned core count reached.
    pub fn set_pool_scale(&mut self, available_frac: f64) -> u32 {
        let f = available_frac.clamp(0.0, 1.0);
        let mut cordoned = 0;
        for n in &mut self.nodes {
            let total = n.total_cores();
            let avail_target = ((total as f64 * f).round() as u32).clamp(1, total);
            cordoned += n.set_cordon(total - avail_target);
        }
        cordoned
    }

    /// Cores currently cordoned (offline) across the cluster.
    pub fn cordoned_cores(&self) -> u32 {
        self.nodes.iter().map(|n| n.cordoned_cores()).sum()
    }
}

/// Per-node [`ResourceState`]: cores within a node are a flat pool (NUMA
/// preference is a soft placement policy inside `alloc_cores`, not a
/// feasibility constraint).
pub struct CpuNodeState<'a> {
    mgr: &'a CpuManager,
    node: NodeId,
}

impl ResourceState for CpuNodeState<'_> {
    fn available_units(&self) -> u64 {
        self.mgr.node(self.node).free_cores() as u64
    }

    fn accommodate(&self, min_units: &[u64]) -> bool {
        min_units.iter().sum::<u64>() <= self.available_units()
    }

    fn dp_operator(&self, reserved: &[u64]) -> Box<dyn DpOperator> {
        let used: u64 = reserved.iter().sum();
        Box::new(BasicOperator::new(self.available_units().saturating_sub(used)))
    }

    fn running_completions(&self) -> Vec<(SimTime, u64)> {
        self.mgr
            .active
            .values() // arl-lint: allow(nondet-iteration): consumer heapifies
            .filter(|a| a.node == self.node)
            .map(|a| (a.expected_done, a.units))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr() -> CpuManager {
        // 2 nodes × (2 NUMA × 4 cores) × 32 GiB
        CpuManager::new(2, 2, 4, 32, CpuLatency::default())
    }

    #[test]
    fn binding_prefers_most_free_memory() {
        let mut m = mgr();
        let n1 = m.bind_trajectory(TrajId(1), 1, 20).unwrap();
        // node n1 now has 12 GiB free; the other has 32 → next binding goes there
        let n2 = m.bind_trajectory(TrajId(2), 1, 20).unwrap();
        assert_ne!(n1, n2);
        // rebinding the same trajectory is a no-op returning the same node
        assert_eq!(m.bind_trajectory(TrajId(1), 1, 999).unwrap(), n1);
    }

    #[test]
    fn binding_fails_when_nothing_fits() {
        let mut m = mgr();
        assert!(m.bind_trajectory(TrajId(1), 9, 1).is_err()); // > 8 cores
        assert!(m.bind_trajectory(TrajId(1), 1, 33).is_err()); // > 32 GiB
    }

    #[test]
    fn aoe_allocate_complete_cycle() {
        let mut m = mgr();
        let node = m.bind_trajectory(TrajId(1), 1, 4).unwrap();
        let lease = m
            .allocate(ActionId(1), TrajId(1), 4, true, SimTime(100))
            .unwrap();
        assert_eq!(lease.cores.len(), 4);
        assert_eq!(lease.node, node);
        // first action pays container creation
        assert!(lease.overhead >= CpuLatency::default().container_create);
        assert_eq!(m.node(node).free_cores(), 4);
        m.complete(ActionId(1)).unwrap();
        assert_eq!(m.node(node).free_cores(), 8);
        // subsequent actions pay only cgroup + fork
        let lease2 = m
            .allocate(ActionId(2), TrajId(1), 2, false, SimTime(200))
            .unwrap();
        assert!(lease2.overhead < CpuLatency::default().container_create);
        m.complete(ActionId(2)).unwrap();
    }

    #[test]
    fn allocate_fails_without_binding_or_cores() {
        let mut m = mgr();
        assert!(m
            .allocate(ActionId(1), TrajId(1), 1, true, SimTime(1))
            .is_err());
        m.bind_trajectory(TrajId(1), 1, 1).unwrap();
        assert!(m
            .allocate(ActionId(1), TrajId(1), 9, true, SimTime(1))
            .is_err());
    }

    #[test]
    fn release_trajectory_frees_memory() {
        let mut m = mgr();
        let node = m.bind_trajectory(TrajId(1), 1, 30).unwrap();
        assert_eq!(m.node(node).free_mem_gb(), 2);
        m.release_trajectory(TrajId(1)).unwrap();
        assert_eq!(m.node(node).free_mem_gb(), 32);
        assert!(m.release_trajectory(TrajId(1)).is_err());
    }

    #[test]
    fn node_state_tracks_running() {
        let mut m = mgr();
        let node = m.bind_trajectory(TrajId(1), 1, 4).unwrap();
        let _ = m
            .allocate(ActionId(1), TrajId(1), 3, true, SimTime(777))
            .unwrap();
        let st = m.node_state(node);
        assert_eq!(st.available_units(), 5);
        assert!(st.accommodate(&[2, 3]));
        assert!(!st.accommodate(&[3, 3]));
        assert_eq!(st.running_completions(), vec![(SimTime(777), 3)]);
        let other = m
            .node_ids()
            .into_iter()
            .find(|&n| n != node)
            .unwrap();
        assert!(m.node_state(other).running_completions().is_empty());
    }

    #[test]
    fn pool_scale_cordons_and_restores() {
        let mut m = mgr(); // 2 nodes × 8 cores
        assert_eq!(m.set_pool_scale(0.5), 8);
        assert_eq!(m.free_cores(), 8);
        assert_eq!(m.cordoned_cores(), 8);
        // at least one core per node always stays online
        assert_eq!(m.set_pool_scale(0.05), 14);
        assert_eq!(m.free_cores(), 2);
        assert_eq!(m.set_pool_scale(1.0), 0);
        assert_eq!(m.free_cores(), 16);
    }

    #[test]
    fn utilization_tracks_allocations() {
        let mut m = mgr();
        assert_eq!(m.utilization(), 0.0);
        m.bind_trajectory(TrajId(1), 1, 1).unwrap();
        let _ = m.allocate(ActionId(1), TrajId(1), 8, true, SimTime(1)).unwrap();
        assert_eq!(m.utilization(), 0.5);
    }
}
