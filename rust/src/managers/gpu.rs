//! GPU Manager via evict-on-execution (paper §5.3).
//!
//! **Breakdown**: every service keeps an invariant copy of its state in host
//! memory (prepared at initialization). When an action requests a service,
//! the manager allocates a chunk; if the (service, DoP) variant is already
//! resident on that chunk's GPUs the action runs immediately (warm),
//! otherwise the service is restored from host memory — evicting whatever
//! was cached on those GPUs, which is free because the GPU copy is
//! invariant. After completion the chunk returns to the pool with the
//! service still cached.
//!
//! **Pool**: multi-level chunk structure with LRU + prefer-warm selection
//! (implemented in [`crate::cluster::gpu::GpuCluster`]); elastic DoP falls
//! out of treating every DoP configuration as a distinct service variant.

use crate::action::{ActionId, ServiceId};
use crate::cluster::gpu::{ChunkRef, GpuCluster, RestoreModel};
use crate::scheduler::{ChunkOperator, DpOperator, ResourceState};
use crate::sim::{SimDur, SimTime};
use std::collections::HashMap;

/// Static description of a deployable model service (reward model, teacher
/// model, LLM judge).
#[derive(Debug, Clone)]
pub struct ServiceSpec {
    pub id: ServiceId,
    pub name: String,
    /// Total parameter footprint in GiB (restore traffic source).
    pub weights_gb: f64,
    /// Legal tensor-parallel degrees, ascending (e.g. `[1,2,4,8]`).
    pub dop_choices: Vec<u8>,
    /// Measured parallel efficiency per DoP index (E(m) table for the
    /// action formulation; length ≥ `dop_choices.len()` not required —
    /// clamps).
    pub efficiency: Vec<f64>,
}

impl ServiceSpec {
    /// A DoP is legal if listed.
    pub fn allows_dop(&self, dop: u8) -> bool {
        self.dop_choices.contains(&dop)
    }
}

/// A granted GPU allocation for one action.
#[derive(Debug, Clone)]
pub struct GpuLease {
    pub action: ActionId,
    pub service: ServiceId,
    pub dop: u8,
    pub chunk: ChunkRef,
    /// true ⇒ no restore needed (service variant already resident).
    pub warm: bool,
    /// Restore overhead charged before execution (zero when warm).
    pub overhead: SimDur,
}

#[derive(Debug)]
struct Active {
    lease: GpuLease,
    expected_done: SimTime,
}

/// The EOE GPU manager.
#[derive(Debug)]
pub struct GpuManager {
    cluster: GpuCluster,
    pub restore: RestoreModel,
    services: HashMap<ServiceId, ServiceSpec>,
    active: HashMap<ActionId, Active>,
    // counters for Table-1-style overhead accounting
    pub n_warm: u64,
    pub n_cold: u64,
    pub restore_time_total: SimDur,
}

impl GpuManager {
    pub fn new(n_nodes: u32, restore: RestoreModel, specs: Vec<ServiceSpec>) -> Self {
        GpuManager {
            cluster: GpuCluster::new(n_nodes),
            restore,
            services: specs.into_iter().map(|s| (s.id, s)).collect(),
            active: HashMap::new(),
            n_warm: 0,
            n_cold: 0,
            restore_time_total: SimDur::ZERO,
        }
    }

    pub fn service(&self, id: ServiceId) -> &ServiceSpec {
        &self.services[&id]
    }

    pub fn services(&self) -> impl Iterator<Item = &ServiceSpec> {
        // arl-lint: allow(nondet-iteration): order-agnostic accessor; no
        // decision-path consumer iterates it
        self.services.values()
    }

    pub fn total_gpus(&self) -> u32 {
        self.cluster.total_gpus()
    }

    pub fn free_gpus(&self) -> u32 {
        self.cluster.free_gpus()
    }

    /// GPUs currently provisioned (online nodes + still-draining busy GPUs
    /// of cordoned nodes — the `PoolClass::Gpu` billing gauge).
    pub fn provisioned_gpus(&self) -> u32 {
        self.cluster.provisioned_gpus()
    }

    /// Nodes cordoned by the elastic `PoolClass::Gpu` lane.
    pub fn cordoned_nodes(&self) -> u32 {
        self.cluster.cordoned_nodes()
    }

    /// GPUs held by running allocations (autoscaler in-use gauge; counts
    /// actual chunk sizes, not requested DoPs — a DoP-3 action holds 4).
    pub fn in_use_gpus(&self) -> u64 {
        self.active
            .values() // arl-lint: allow(nondet-iteration): commutative sum
            .map(|a| a.lease.chunk.size() as u64)
            .sum()
    }

    /// Elastic `PoolClass::Gpu` resize: cordon/restore whole nodes
    /// coldest-first (see `GpuCluster::set_pool_scale` for the determinism
    /// invariant). Returns the provisioned GPU count reached.
    pub fn set_pool_scale(&mut self, available_frac: f64) -> u64 {
        let _ = self.cluster.set_pool_scale(available_frac);
        self.provisioned_gpus() as u64
    }

    /// Utilization counts cordoned capacity as busy — an offline GPU is
    /// not idle capacity (same convention as the CPU cordon).
    pub fn utilization(&self) -> f64 {
        let total = self.total_gpus() as f64;
        (total - self.free_gpus() as f64) / total
    }

    /// Pre-warm caches at initialization (§5.3: "iteratively prepares all
    /// required services by deploying them on each feasible group of GPUs
    /// and backing up their states in CPU memory"). Deploy each service once
    /// at its *largest* DoP round-robin until the cluster is covered.
    pub fn prewarm(&mut self, now: SimTime) {
        // arl-lint: allow(nondet-iteration): collected then sorted by id on
        // the next line — deploy order is deterministic
        let mut specs: Vec<ServiceSpec> = self.services.values().cloned().collect();
        specs.sort_by_key(|s| s.id);
        'outer: loop {
            for s in &specs {
                let dop = s.dop_choices.last().copied().unwrap_or(1);
                match self.cluster.allocate(s.id, dop) {
                    Some(a) => self.cluster.release(a.chunk, s.id, dop, now),
                    None => break 'outer,
                }
            }
            // every service seeded once per sweep; one sweep is enough
            break;
        }
    }

    /// Allocate a chunk for `action` requesting `service` at `dop`.
    pub fn allocate(
        &mut self,
        action: ActionId,
        service: ServiceId,
        dop: u8,
        expected_done: SimTime,
    ) -> Result<GpuLease, String> {
        let spec = self
            .services
            .get(&service)
            .ok_or_else(|| format!("unknown service {service:?}"))?;
        if !spec.allows_dop(dop) {
            return Err(format!("{}: illegal DoP {dop}", spec.name));
        }
        let weights = spec.weights_gb;
        let alloc = self
            .cluster
            .allocate(service, dop)
            .ok_or_else(|| format!("no chunk for DoP {dop}"))?;
        let overhead = if alloc.warm {
            self.n_warm += 1;
            SimDur::ZERO
        } else {
            self.n_cold += 1;
            let d = self.restore.restore_dur(weights, dop);
            self.restore_time_total += d;
            d
        };
        let lease = GpuLease {
            action,
            service,
            dop,
            chunk: alloc.chunk,
            warm: alloc.warm,
            overhead,
        };
        self.active
            .insert(action, Active { lease: lease.clone(), expected_done });
        Ok(lease)
    }

    /// Action finished: the chunk returns to the pool, service still cached.
    pub fn complete(&mut self, action: ActionId, now: SimTime) -> Result<(), String> {
        let a = self
            .active
            .remove(&action)
            .ok_or_else(|| format!("{action:?} not active"))?;
        self.cluster
            .release(a.lease.chunk, a.lease.service, a.lease.dop, now);
        Ok(())
    }

    /// Scenario restore-storm: drop every warm (service, DoP) residency so
    /// the next allocation of each variant pays a cold restore. Running
    /// actions are unaffected (their chunks re-cache on release).
    pub fn flush_caches(&mut self) {
        self.cluster.flush_caches();
    }

    /// Warm-hit ratio over all allocations so far.
    pub fn warm_ratio(&self) -> f64 {
        let total = self.n_warm + self.n_cold;
        if total == 0 {
            return 0.0;
        }
        self.n_warm as f64 / total as f64
    }
}

impl ResourceState for GpuManager {
    fn available_units(&self) -> u64 {
        self.free_gpus() as u64
    }

    fn accommodate(&self, min_units: &[u64]) -> bool {
        self.cluster.can_accommodate(min_units)
    }

    fn dp_operator(&self, reserved: &[u64]) -> Box<dyn DpOperator> {
        let counts = self.cluster.free_chunk_counts();
        let bounds = ChunkOperator::cluster_bounds(self.total_gpus());
        let op = ChunkOperator::new(counts, bounds);
        // pre-consume reservations from co-scheduled non-key actions
        let mut state = op.full_state();
        for &r in reserved {
            if let Some(s2) = op.consume(state, r) {
                state = s2;
            }
        }
        let avail = op.decode(state);
        Box::new(ChunkOperator::new(avail, bounds))
    }

    fn running_completions(&self) -> Vec<(SimTime, u64)> {
        self.active
            .values() // arl-lint: allow(nondet-iteration): consumer heapifies
            .map(|a| (a.expected_done, a.lease.dop as u64))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs(n: u32) -> Vec<ServiceSpec> {
        (0..n)
            .map(|i| ServiceSpec {
                id: ServiceId(i),
                name: format!("teacher-{i}"),
                weights_gb: 60.0,
                dop_choices: vec![1, 2, 4, 8],
                efficiency: vec![1.0, 0.95, 0.85, 0.8, 0.7, 0.7, 0.7, 0.65],
            })
            .collect()
    }

    fn mgr(nodes: u32, services: u32) -> GpuManager {
        GpuManager::new(nodes, RestoreModel::default(), specs(services))
    }

    #[test]
    fn cold_then_warm_allocation() {
        let mut m = mgr(1, 2);
        let l1 = m
            .allocate(ActionId(1), ServiceId(0), 4, SimTime(10))
            .unwrap();
        assert!(!l1.warm);
        assert!(l1.overhead > SimDur::ZERO);
        m.complete(ActionId(1), SimTime(10)).unwrap();
        let l2 = m
            .allocate(ActionId(2), ServiceId(0), 4, SimTime(20))
            .unwrap();
        assert!(l2.warm);
        assert_eq!(l2.overhead, SimDur::ZERO);
        assert_eq!(l2.chunk, l1.chunk);
        assert_eq!(m.n_warm, 1);
        assert_eq!(m.n_cold, 1);
        assert!((m.warm_ratio() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn illegal_dop_rejected() {
        let mut m = GpuManager::new(
            1,
            RestoreModel::default(),
            vec![ServiceSpec {
                id: ServiceId(0),
                name: "rm".into(),
                weights_gb: 10.0,
                dop_choices: vec![4, 8],
                efficiency: vec![1.0; 8],
            }],
        );
        assert!(m.allocate(ActionId(1), ServiceId(0), 2, SimTime(1)).is_err());
        assert!(m.allocate(ActionId(1), ServiceId(9), 4, SimTime(1)).is_err());
    }

    #[test]
    fn exhaustion_fails_cleanly() {
        let mut m = mgr(1, 1);
        let _l = m.allocate(ActionId(1), ServiceId(0), 8, SimTime(1)).unwrap();
        assert!(m.allocate(ActionId(2), ServiceId(0), 1, SimTime(1)).is_err());
        assert_eq!(m.free_gpus(), 0);
        assert_eq!(m.utilization(), 1.0);
    }

    #[test]
    fn prewarm_seeds_caches() {
        let mut m = mgr(2, 2);
        m.prewarm(SimTime::ZERO);
        assert_eq!(m.free_gpus(), 16); // everything released again
        // both services should now warm-start at DoP 8
        let l = m.allocate(ActionId(1), ServiceId(0), 8, SimTime(1)).unwrap();
        assert!(l.warm);
        let l2 = m.allocate(ActionId(2), ServiceId(1), 8, SimTime(1)).unwrap();
        assert!(l2.warm);
    }

    #[test]
    fn resource_state_for_scheduler() {
        let mut m = mgr(1, 1);
        assert_eq!(m.available_units(), 8);
        assert!(m.accommodate(&[4, 2, 1, 1]));
        assert!(!m.accommodate(&[8, 1]));
        let _l = m.allocate(ActionId(1), ServiceId(0), 4, SimTime(42)).unwrap();
        assert_eq!(m.available_units(), 4);
        assert_eq!(m.running_completions(), vec![(SimTime(42), 4)]);
        // dp operator reflects the free 4-chunk
        let op = m.dp_operator(&[]);
        assert_eq!(op.max_alloc(), 4);
        // reserving those 4 leaves nothing
        let op2 = m.dp_operator(&[4]);
        assert_eq!(op2.max_alloc(), 0);
    }

    #[test]
    fn flush_forces_cold_restart() {
        let mut m = mgr(1, 1);
        let l1 = m.allocate(ActionId(1), ServiceId(0), 4, SimTime(1)).unwrap();
        assert!(!l1.warm);
        m.complete(ActionId(1), SimTime(1)).unwrap();
        m.flush_caches();
        let l2 = m.allocate(ActionId(2), ServiceId(0), 4, SimTime(2)).unwrap();
        assert!(!l2.warm, "flushed cache must force a cold restore");
        assert_eq!(m.n_cold, 2);
    }

    #[test]
    fn pool_scale_cordons_and_restores_nodes() {
        let mut m = mgr(4, 2); // 32 GPUs
        assert_eq!(m.set_pool_scale(0.5), 16);
        assert_eq!(m.cordoned_nodes(), 2);
        assert_eq!(m.free_gpus(), 16);
        // scheduler view shrinks with the cordon
        assert_eq!(m.available_units(), 16);
        assert!(m.accommodate(&[8, 8]));
        assert!(!m.accommodate(&[8, 8, 1]));
        // at least one node always stays online
        assert_eq!(m.set_pool_scale(0.05), 8);
        assert_eq!(m.cordoned_nodes(), 3);
        assert_eq!(m.set_pool_scale(1.0), 32);
        assert_eq!(m.cordoned_nodes(), 0);
        assert_eq!(m.free_gpus(), 32);
    }

    #[test]
    fn scale_down_forces_cold_rewarm_on_restore() {
        // a (service, dop) warm on a node that gets cordoned must pay the
        // ordinary cache-miss restore once the node returns
        let mut m = mgr(2, 1);
        let l = m.allocate(ActionId(1), ServiceId(0), 8, SimTime(1)).unwrap();
        let node = l.chunk.node;
        m.complete(ActionId(1), SimTime(10)).unwrap();
        // the warm node is hottest → the *other* node cordons; cordon down
        // to one node and verify the warm hit survives on the online node
        assert_eq!(m.set_pool_scale(0.5), 8);
        let l2 = m.allocate(ActionId(2), ServiceId(0), 8, SimTime(20)).unwrap();
        assert!(l2.warm, "hot node must be kept online");
        assert_eq!(l2.chunk.node, node);
        m.complete(ActionId(2), SimTime(30)).unwrap();
        m.set_pool_scale(1.0);
        // the restored node lost its (flushed) cache: new work there is cold
        let l3 = m.allocate(ActionId(3), ServiceId(0), 8, SimTime(40)).unwrap();
        let l4 = m.allocate(ActionId(4), ServiceId(0), 8, SimTime(40)).unwrap();
        assert!(l3.warm ^ l4.warm, "exactly one of the two nodes is still warm");
    }

    #[test]
    fn in_use_gpus_counts_chunk_sizes() {
        let mut m = mgr(1, 1);
        assert_eq!(m.in_use_gpus(), 0);
        let _l = m.allocate(ActionId(1), ServiceId(0), 4, SimTime(1)).unwrap();
        assert_eq!(m.in_use_gpus(), 4);
        m.complete(ActionId(1), SimTime(2)).unwrap();
        assert_eq!(m.in_use_gpus(), 0);
    }

    #[test]
    fn restore_totals_accumulate() {
        let mut m = mgr(1, 2);
        let _a = m.allocate(ActionId(1), ServiceId(0), 4, SimTime(1)).unwrap();
        let _b = m.allocate(ActionId(2), ServiceId(1), 4, SimTime(1)).unwrap();
        assert_eq!(m.n_cold, 2);
        assert!(m.restore_time_total > SimDur::ZERO);
    }
}
