//! Heterogeneous resource managers (paper §5).
//!
//! Each manager owns one class of external resource and implements the two
//! halves of action-level management:
//!
//! * **Breakdown** — release resources after every action while preserving
//!   environment/service state (AOE cgroup cycling, EOE service caching);
//! * **Pool** — allocate from a shared pool with fragmentation- and
//!   parallel-efficiency-aware policies (NUMA affinity, chunk structure).
//!
//! All managers expose the scheduler's [`ResourceState`] so the elastic
//! algorithm stays topology-agnostic (§5: "a standardized interface …
//! maintaining transparency of heterogeneous resources").

pub mod basic;
pub mod cpu;
pub mod gpu;

pub use basic::BasicManager;
pub use cpu::{CpuLease, CpuManager};
pub use gpu::{GpuLease, GpuManager, ServiceSpec};
