//! Experiment metrics: ACTs, stage breakdowns, utilization timelines.
//!
//! Every figure/table in the paper's evaluation reduces to aggregations
//! over these records: Fig. 6 = windowed mean ACT; Fig. 7 = per-stage
//! normalized durations; Fig. 8 = mean ACT vs batch/capacity; Table 1 =
//! exec/queue/overhead decomposition.

use crate::action::{ActionId, ActionKind, TaskId, TenantId, TrajId};
use crate::sim::{SimDur, SimTime};
use crate::util::json::Json;
use crate::util::{mean, percentile};
use std::collections::{BTreeMap, HashMap};

/// Final record of one action.
#[derive(Debug, Clone)]
pub struct ActionRecord {
    pub id: ActionId,
    pub task: TaskId,
    /// Tenant (training job) the action belongs to; `TenantId(0)` in
    /// single-tenant runs.
    pub tenant: TenantId,
    pub trajectory: TrajId,
    pub kind: ActionKind,
    pub submitted: SimTime,
    pub started: SimTime,
    pub finished: SimTime,
    /// setup/restore portion of the busy time (Table 1 "Sys. Overhead")
    pub overhead: SimDur,
    pub units: u64,
    pub retries: u32,
    pub failed: bool,
}

impl ActionRecord {
    pub fn act(&self) -> SimDur {
        self.finished - self.submitted
    }

    pub fn queue_dur(&self) -> SimDur {
        self.started - self.submitted
    }

    /// Pure execution (busy minus overhead).
    pub fn exec_dur(&self) -> SimDur {
        (self.finished - self.started) - self.overhead
    }
}

/// Final record of one trajectory.
#[derive(Debug, Clone)]
pub struct TrajRecord {
    pub id: TrajId,
    pub task: TaskId,
    pub started: SimTime,
    pub finished: SimTime,
    /// total LLM-generation time
    pub gen_dur: SimDur,
    /// summed ACT of tool/environment actions
    pub tool_dur: SimDur,
    /// summed ACT of reward actions
    pub reward_dur: SimDur,
    pub failed: bool,
    pub restarts: u32,
}

impl TrajRecord {
    pub fn lifetime(&self) -> SimDur {
        self.finished - self.started
    }

    /// Fig. 3(c): fraction of the lifetime spent in external actions.
    pub fn active_ratio(&self) -> f64 {
        let l = self.lifetime().secs_f64();
        if l <= 0.0 {
            return 0.0;
        }
        ((self.tool_dur + self.reward_dur).secs_f64() / l).min(1.0)
    }
}

/// Record of one RL training step.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub index: u32,
    pub rollout_dur: SimDur,
    pub train_dur: SimDur,
}

impl StepRecord {
    pub fn total(&self) -> SimDur {
        self.rollout_dur + self.train_dur
    }
}

/// A named utilization timeline sample.
#[derive(Debug, Clone)]
pub struct UtilSample {
    pub at: SimTime,
    pub name: String,
    pub value: f64,
}

/// A provisioned-capacity change point: pool `pool` holds `units` from `at`
/// until its next record. The driver emits one per pool at run start and one
/// per autoscaler billing point (scale-up decisions bill from the decision
/// instant — capacity costs money while it warms — and every applied resize
/// records the units actually reached).
#[derive(Debug, Clone)]
pub struct ProvisionRecord {
    pub at: SimTime,
    pub pool: String,
    pub units: u64,
}

/// Step-integrate a provision point series to `end`: each point's units
/// hold until the next point (or `end`). Unit-seconds. The single billing
/// convention shared by the in-run accounting and the offline `--against`
/// trace comparison.
pub fn integrate_unit_secs(points: &[(SimTime, u64)], end: SimTime) -> f64 {
    let mut secs = 0.0;
    for (i, &(t0, units)) in points.iter().enumerate() {
        let until = points.get(i + 1).map_or(end, |&(t1, _)| t1);
        secs += units as f64 * until.saturating_sub(t0).secs_f64();
    }
    secs
}

/// Peak resident-set size of this process in KiB (`VmHWM` from
/// `/proc/self/status`), or 0 where the proc interface is unavailable.
/// Host-side reporting for the throughput bench — never feeds a simulated
/// decision, so the platform dependence cannot touch determinism.
#[cfg(target_os = "linux")]
pub fn peak_rss_kb() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let digits: String = rest.chars().filter(|c| c.is_ascii_digit()).collect();
            return digits.parse().unwrap_or(0);
        }
    }
    0
}

/// Peak resident-set size in KiB — 0 on platforms without `/proc`.
#[cfg(not(target_os = "linux"))]
pub fn peak_rss_kb() -> u64 {
    0
}

/// Collector for one experiment run.
#[derive(Debug, Default)]
pub struct Metrics {
    pub actions: Vec<ActionRecord>,
    pub trajectories: Vec<TrajRecord>,
    pub steps: Vec<StepRecord>,
    pub util: Vec<UtilSample>,
    pub provision: Vec<ProvisionRecord>,
    /// Resolved $/unit-hour per provision pool (`lanes::CostModel::resolve`
    /// against the deployment; set by the scenario engine when the spec
    /// embeds a cost model). `None` = unit-hour accounting only — the
    /// serialized form is unchanged, which is what keeps static golden
    /// traces byte-identical.
    pub cost_rates: Option<BTreeMap<String, f64>>,
    /// Submit/start/complete conservation counters maintained by the DES
    /// driver (the `testkit::oracle` ledger invariant reads these against
    /// the recorded trace). Deliberately NOT serialized by the JSON
    /// summary: golden traces and summary digests stay byte-identical.
    pub ledger: ActionLedger,
}

/// Conservation counters over the action lifecycle: every submitted action
/// is started at least once, retried zero or more times, and completed
/// exactly once (done or failed). Violations mean the scheduler lost,
/// duplicated, or double-completed work.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ActionLedger {
    /// Actions handed to the backend (first submission only, not retries).
    pub submitted: u64,
    /// Backend launches, including retry re-launches.
    pub started: u64,
    /// Retry re-submissions after a `Verdict::Retry`.
    pub retried: u64,
    /// Terminal successful completions.
    pub done: u64,
    /// Terminal failures (retry budget exhausted).
    pub failed: u64,
}

/// Per-tenant aggregate over the action records (multi-tenant reporting):
/// counts plus summed ACT / queue-wait nanoseconds. Summing every tenant's
/// rollup field-by-field reproduces the global rollup **bitwise** — the
/// integer sums carry no rounding, which is what the tenancy conservation
/// tests assert.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TenantRollup {
    /// All completed actions of the tenant (failed included).
    pub actions: u64,
    /// Terminally-failed actions.
    pub failed: u64,
    /// Transparent retries summed over all actions.
    pub retries: u64,
    /// Summed ACT (submit→finish) of successful actions, virtual ns.
    pub act_ns: u64,
    /// Summed queue wait (submit→start) of successful actions, virtual ns.
    pub queue_ns: u64,
}

impl TenantRollup {
    fn absorb(&mut self, a: &ActionRecord) {
        self.actions += 1;
        self.retries += a.retries as u64;
        if a.failed {
            self.failed += 1;
        } else {
            self.act_ns += a.act().0;
            self.queue_ns += a.queue_dur().0;
        }
    }

    /// Mean ACT in seconds over the tenant's successful actions.
    pub fn mean_act_secs(&self) -> f64 {
        let ok = self.actions - self.failed;
        if ok == 0 {
            return 0.0;
        }
        self.act_ns as f64 / 1e9 / ok as f64
    }

    /// Mean queue wait in seconds over the tenant's successful actions.
    pub fn mean_queue_secs(&self) -> f64 {
        let ok = self.actions - self.failed;
        if ok == 0 {
            return 0.0;
        }
        self.queue_ns as f64 / 1e9 / ok as f64
    }
}

/// Provision pool an action kind's resource consumption bills against
/// (matches the [`crate::coordinator::Backend::provisioned`] gauge names).
pub fn pool_of_kind(kind: ActionKind) -> &'static str {
    match kind {
        ActionKind::EnvExec | ActionKind::RewardCpu => "cpu_cores",
        ActionKind::RewardModel => "gpus",
        ActionKind::ApiCall => "api_lanes",
    }
}

impl ActionLedger {
    /// Terminal completions of either outcome.
    pub fn completed(&self) -> u64 {
        self.done + self.failed
    }

    /// The conservation law itself: one terminal completion per submission,
    /// and one launch per submission plus one per retry.
    pub fn balanced(&self) -> bool {
        self.submitted == self.completed() && self.started == self.submitted + self.retried
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    // ---- aggregations -----------------------------------------------------

    /// Mean ACT in seconds over all (successful) actions.
    pub fn mean_act(&self) -> f64 {
        mean(&self
            .actions
            .iter()
            .filter(|a| !a.failed)
            .map(|a| a.act().secs_f64())
            .collect::<Vec<_>>())
    }

    pub fn mean_act_of(&self, kind: ActionKind) -> f64 {
        mean(&self
            .actions
            .iter()
            .filter(|a| !a.failed && a.kind == kind)
            .map(|a| a.act().secs_f64())
            .collect::<Vec<_>>())
    }

    pub fn p99_act(&self) -> f64 {
        let mut v: Vec<f64> = self
            .actions
            .iter()
            .filter(|a| !a.failed)
            .map(|a| a.act().secs_f64())
            .collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile(&v, 99.0)
    }

    /// Windowed mean ACT (Fig. 6): buckets of `window` over the run.
    pub fn act_timeline(&self, window: SimDur) -> Vec<(f64, f64)> {
        let mut buckets: HashMap<u64, Vec<f64>> = HashMap::new();
        for a in self.actions.iter().filter(|a| !a.failed) {
            let b = a.submitted.0 / window.0.max(1);
            buckets.entry(b).or_default().push(a.act().secs_f64());
        }
        let mut out: Vec<(f64, f64)> = buckets
            .into_iter()
            .map(|(b, v)| ((b * window.0) as f64 / 1e9, mean(&v)))
            .collect();
        out.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        out
    }

    /// Invocation counts per window (Fig. 3(d)).
    pub fn invocation_timeline(&self, window: SimDur, task: Option<TaskId>) -> Vec<(f64, u64)> {
        let mut buckets: HashMap<u64, u64> = HashMap::new();
        for a in &self.actions {
            if task.map_or(false, |t| a.task != t) {
                continue;
            }
            *buckets.entry(a.submitted.0 / window.0.max(1)).or_default() += 1;
        }
        let mut out: Vec<(f64, u64)> = buckets
            .into_iter()
            .map(|(b, v)| ((b * window.0) as f64 / 1e9, v))
            .collect();
        out.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        out
    }

    /// Table 1 rows: (mean exec, mean queue, mean overhead) seconds.
    pub fn act_breakdown(&self) -> (f64, f64, f64) {
        let ok: Vec<&ActionRecord> = self.actions.iter().filter(|a| !a.failed).collect();
        let exec = mean(&ok.iter().map(|a| a.exec_dur().secs_f64()).collect::<Vec<_>>());
        let queue = mean(&ok.iter().map(|a| a.queue_dur().secs_f64()).collect::<Vec<_>>());
        let ovh = mean(&ok.iter().map(|a| a.overhead.secs_f64()).collect::<Vec<_>>());
        (exec, queue, ovh)
    }

    /// Fig. 7 stage sums over trajectories: (gen, tool, reward) seconds.
    pub fn stage_totals(&self) -> (f64, f64, f64) {
        let g = mean(&self.trajectories.iter().map(|t| t.gen_dur.secs_f64()).collect::<Vec<_>>());
        let t = mean(&self.trajectories.iter().map(|t| t.tool_dur.secs_f64()).collect::<Vec<_>>());
        let r = mean(&self
            .trajectories
            .iter()
            .map(|t| t.reward_dur.secs_f64())
            .collect::<Vec<_>>());
        (g, t, r)
    }

    /// Mean step duration in seconds (paper's "step duration").
    pub fn mean_step_dur(&self) -> f64 {
        mean(&self.steps.iter().map(|s| s.total().secs_f64()).collect::<Vec<_>>())
    }

    /// Mean active ratio across trajectories (Fig. 3(c)).
    pub fn mean_active_ratio(&self) -> f64 {
        mean(&self.trajectories.iter().map(|t| t.active_ratio()).collect::<Vec<_>>())
    }

    /// Mean utilization of a named pool over its samples (Fig. 3(b)).
    pub fn mean_util(&self, name: &str) -> f64 {
        mean(&self
            .util
            .iter()
            .filter(|u| u.name == name)
            .map(|u| u.value)
            .collect::<Vec<_>>())
    }

    /// Last instant anything happened (the resource-hour integration bound).
    pub fn run_end(&self) -> SimTime {
        let mut end = SimTime::ZERO;
        for a in &self.actions {
            end = end.max(a.finished);
        }
        for t in &self.trajectories {
            end = end.max(t.finished);
        }
        for u in &self.util {
            end = end.max(u.at);
        }
        for p in &self.provision {
            end = end.max(p.at);
        }
        end
    }

    /// Resource-hour accounting for one pool: integrate the provision step
    /// function over the run. Returns `(used, static)` unit-hours, where
    /// *static* is what a peak-provisioned deployment would have paid over
    /// the same span — the paper's savings denominator.
    pub fn pool_unit_hours(&self, pool: &str) -> (f64, f64) {
        self.pool_unit_hours_to(pool, self.run_end())
    }

    fn pool_unit_hours_to(&self, pool: &str, end: SimTime) -> (f64, f64) {
        let points: Vec<(SimTime, u64)> = self
            .provision
            .iter()
            .filter(|r| r.pool == pool)
            .map(|r| (r.at, r.units))
            .collect();
        let Some(&(first, _)) = points.first() else {
            return (0.0, 0.0);
        };
        let peak = points.iter().map(|&(_, u)| u).max().unwrap_or(0);
        let used_secs = integrate_unit_secs(&points, end);
        let static_secs = peak as f64 * end.saturating_sub(first).secs_f64();
        (used_secs / 3600.0, static_secs / 3600.0)
    }

    /// Per-pool resource-hour rows, sorted by pool name:
    /// `(pool, used unit-hours, static unit-hours)`. The run-end scan
    /// happens once, not per pool.
    pub fn resource_rows(&self) -> Vec<(String, f64, f64)> {
        let end = self.run_end();
        let mut pools: Vec<String> = self.provision.iter().map(|r| r.pool.clone()).collect();
        pools.sort();
        pools.dedup();
        pools
            .into_iter()
            .map(|p| {
                let (used, stat) = self.pool_unit_hours_to(&p, end);
                (p, used, stat)
            })
            .collect()
    }

    /// Aggregate external-resource savings vs a static peak-provisioned
    /// deployment (the paper's headline §6 metric; 0.712 ⇒ 71.2%). Pools
    /// are weighted by their static unit-hour share. 0 when nothing was
    /// ever resized — a static run pays the static bill by definition.
    pub fn savings_vs_static(&self) -> f64 {
        let (mut used, mut stat) = (0.0, 0.0);
        for (_, u, s) in self.resource_rows() {
            used += u;
            stat += s;
        }
        if stat <= 0.0 {
            return 0.0;
        }
        1.0 - used / stat
    }

    /// Dollar accounting for one pool under the resolved rate card:
    /// `(used $, static $)` — rate × the [`Self::pool_unit_hours`] pair.
    /// Pools without a resolved rate (or with no cost model at all) fall
    /// back to rate 1.0, i.e. plain unit-hours.
    pub fn pool_cost(&self, pool: &str) -> (f64, f64) {
        let (used, stat) = self.pool_unit_hours(pool);
        let rate = self.rate_of(pool);
        (rate * used, rate * stat)
    }

    fn rate_of(&self, pool: &str) -> f64 {
        self.cost_rates
            .as_ref()
            .and_then(|r| r.get(pool).copied())
            .unwrap_or(1.0)
    }

    /// Per-pool dollar rows, sorted by pool name:
    /// `(pool, rate, used $, static $)`. Empty without a cost model.
    pub fn cost_rows(&self) -> Vec<(String, f64, f64, f64)> {
        if self.cost_rates.is_none() {
            return Vec::new();
        }
        self.resource_rows()
            .into_iter()
            .map(|(pool, used, stat)| {
                let rate = self.rate_of(&pool);
                (pool, rate, rate * used, rate * stat)
            })
            .collect()
    }

    /// Dollar-weighted savings over precomputed [`Self::cost_rows`] — the
    /// reporting paths integrate the provision series once and derive the
    /// headline figure from the same rows they print.
    pub fn cost_savings_of(rows: &[(String, f64, f64, f64)]) -> f64 {
        let (mut used, mut stat) = (0.0, 0.0);
        for (_, _, u, s) in rows {
            used += *u;
            stat += *s;
        }
        if stat <= 0.0 {
            return 0.0;
        }
        1.0 - used / stat
    }

    /// Dollar-weighted sibling of [`Self::savings_vs_static`]: pools are
    /// weighted by $/unit-hour instead of unit-hours, so saving a GPU-hour
    /// counts what it actually costs. Falls back to the unweighted figure
    /// without a cost model; always finite (0 when nothing was billed).
    pub fn savings_vs_static_cost(&self) -> f64 {
        if self.cost_rates.is_none() {
            return self.savings_vs_static();
        }
        Self::cost_savings_of(&self.cost_rows())
    }

    pub fn failed_actions(&self) -> usize {
        self.actions.iter().filter(|a| a.failed).count()
    }

    // ---- multi-tenant rollups --------------------------------------------

    /// Whether any action belongs to a tenant other than 0. Gates every
    /// tenant-specific serialization so single-tenant runs keep their exact
    /// bytes.
    pub fn multi_tenant(&self) -> bool {
        self.actions.iter().any(|a| a.tenant.0 != 0)
    }

    /// Per-tenant aggregates, sorted by tenant id. Computed on demand — the
    /// collector itself stays a flat record sink.
    pub fn tenant_rollups(&self) -> BTreeMap<u32, TenantRollup> {
        let mut out: BTreeMap<u32, TenantRollup> = BTreeMap::new();
        for a in &self.actions {
            out.entry(a.tenant.0).or_default().absorb(a);
        }
        out
    }

    /// Mean ACT in seconds over one tenant's successful actions.
    pub fn mean_act_of_tenant(&self, tenant: u32) -> f64 {
        mean(&self
            .actions
            .iter()
            .filter(|a| !a.failed && a.tenant.0 == tenant)
            .map(|a| a.act().secs_f64())
            .collect::<Vec<_>>())
    }

    /// A tenant's share of each provision pool's busy unit-time:
    /// `(pool, share in [0,1])`, sorted by pool, pools the tenant never
    /// touched omitted. Shares are `units × busy-time` ratios, so across
    /// tenants they sum to 1 per pool with any usage at all.
    pub fn tenant_pool_shares(&self) -> BTreeMap<u32, BTreeMap<&'static str, f64>> {
        // u128 unit-time sums: 64-bit ns × 64-bit units cannot overflow
        let mut per: BTreeMap<u32, BTreeMap<&'static str, u128>> = BTreeMap::new();
        let mut totals: BTreeMap<&'static str, u128> = BTreeMap::new();
        for a in &self.actions {
            let w = a.units as u128 * (a.finished - a.started).0 as u128;
            if w == 0 {
                continue;
            }
            let pool = pool_of_kind(a.kind);
            *per.entry(a.tenant.0).or_default().entry(pool).or_default() += w;
            *totals.entry(pool).or_default() += w;
        }
        per.into_iter()
            .map(|(t, pools)| {
                let shares = pools
                    .into_iter()
                    .map(|(pool, w)| (pool, w as f64 / totals[pool] as f64))
                    .collect();
                (t, shares)
            })
            .collect()
    }

    /// Per-tenant dollar attribution: each pool's **used** cost (rate ×
    /// integrated unit-hours) prorated by the tenant's busy unit-time share
    /// of that pool. Rows `(tenant, pool, dollars)` sorted by (tenant,
    /// pool); without a cost model the rates fall back to 1.0 (plain
    /// unit-hours), same as [`Self::pool_cost`].
    pub fn tenant_cost_rows(&self) -> Vec<(u32, String, f64)> {
        let mut out = Vec::new();
        let mut used_cache: BTreeMap<&'static str, f64> = BTreeMap::new();
        for (tenant, shares) in self.tenant_pool_shares() {
            for (pool, share) in shares {
                let used = *used_cache
                    .entry(pool)
                    .or_insert_with(|| self.pool_cost(pool).0);
                out.push((tenant, pool.to_string(), used * share));
            }
        }
        out
    }

    pub fn total_retries(&self) -> u64 {
        self.actions.iter().map(|a| a.retries as u64).sum()
    }

    /// Full-fidelity deterministic JSON serialization: every record, all
    /// times as integer virtual nanoseconds, object keys sorted. Two
    /// same-seed runs must serialize **byte-identically** — this is the
    /// diff target of the scenario replay engine (`scenario::replay`) and
    /// the system-level determinism tests.
    pub fn to_json(&self) -> Json {
        fn ns(n: u64) -> Json {
            Json::Num(n as f64)
        }
        let actions = Json::arr(self.actions.iter().map(|a| {
            let mut pairs = vec![
                ("id", ns(a.id.0)),
                ("task", ns(a.task.0 as u64)),
                ("traj", ns(a.trajectory.0)),
                ("kind", Json::str(a.kind.name())),
                ("submitted", ns(a.submitted.0)),
                ("started", ns(a.started.0)),
                ("finished", ns(a.finished.0)),
                ("overhead", ns(a.overhead.0)),
                ("units", ns(a.units)),
                ("retries", ns(a.retries as u64)),
                ("failed", Json::Bool(a.failed)),
            ];
            // tenant 0 is implicit so single-tenant summaries keep their
            // exact historical bytes
            if a.tenant.0 != 0 {
                pairs.push(("tenant", ns(a.tenant.0 as u64)));
            }
            Json::obj(pairs)
        }));
        let trajectories = Json::arr(self.trajectories.iter().map(|t| {
            Json::obj(vec![
                ("id", ns(t.id.0)),
                ("task", ns(t.task.0 as u64)),
                ("started", ns(t.started.0)),
                ("finished", ns(t.finished.0)),
                ("gen_dur", ns(t.gen_dur.0)),
                ("tool_dur", ns(t.tool_dur.0)),
                ("reward_dur", ns(t.reward_dur.0)),
                ("failed", Json::Bool(t.failed)),
                ("restarts", ns(t.restarts as u64)),
            ])
        }));
        let steps = Json::arr(self.steps.iter().map(|s| {
            Json::obj(vec![
                ("index", ns(s.index as u64)),
                ("rollout_dur", ns(s.rollout_dur.0)),
                ("train_dur", ns(s.train_dur.0)),
            ])
        }));
        let util = Json::arr(self.util.iter().map(|u| {
            Json::obj(vec![
                ("at", ns(u.at.0)),
                ("name", Json::str(u.name.clone())),
                ("value", Json::num(u.value)),
            ])
        }));
        let provision = Json::arr(self.provision.iter().map(|p| {
            Json::obj(vec![
                ("at", ns(p.at.0)),
                ("pool", Json::str(p.pool.clone())),
                ("units", ns(p.units)),
            ])
        }));
        let mut pairs = vec![
            ("actions", actions),
            ("provision", provision),
            ("savings_vs_static", Json::num(self.savings_vs_static())),
            ("steps", steps),
            ("trajectories", trajectories),
            ("util", util),
        ];
        // cost keys appear ONLY when a cost model is wired, so cost-free
        // runs (every static golden trace) keep their exact bytes
        if let Some(rates) = &self.cost_rates {
            let rates_json =
                Json::obj(rates.iter().map(|(k, v)| (k.as_str(), Json::num(*v))).collect());
            pairs.push(("cost_rates", rates_json));
            pairs.push(("savings_vs_static_cost", Json::num(self.savings_vs_static_cost())));
        }
        // tenant rollups appear ONLY in multi-tenant runs — same gate as
        // the per-action tenant key
        let tenant_keys: Vec<String>;
        if self.multi_tenant() {
            let mut costs: BTreeMap<u32, Vec<(String, f64)>> = BTreeMap::new();
            for (t, pool, dollars) in self.tenant_cost_rows() {
                costs.entry(t).or_default().push((pool, dollars));
            }
            let rollups = self.tenant_rollups();
            tenant_keys = rollups.keys().map(|t| t.to_string()).collect();
            let objs: Vec<(&str, Json)> = rollups
                .iter()
                .zip(tenant_keys.iter())
                .map(|((t, r), key)| {
                    let mut p = vec![
                        ("act_ns", ns(r.act_ns)),
                        ("actions", ns(r.actions)),
                        ("failed", ns(r.failed)),
                        ("queue_ns", ns(r.queue_ns)),
                        ("retries", ns(r.retries)),
                    ];
                    if let Some(c) = costs.get(t) {
                        p.push((
                            "cost",
                            Json::obj(
                                c.iter().map(|(pool, d)| (pool.as_str(), Json::num(*d))).collect(),
                            ),
                        ));
                    }
                    (key.as_str(), Json::obj(p))
                })
                .collect();
            pairs.push(("tenant_rollups", Json::obj(objs)));
        }
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, sub: u64, start: u64, fin: u64, kind: ActionKind) -> ActionRecord {
        ActionRecord {
            id: ActionId(id),
            task: TaskId(0),
            tenant: TenantId(0),
            trajectory: TrajId(id),
            kind,
            submitted: SimTime(sub * 1_000_000_000),
            started: SimTime(start * 1_000_000_000),
            finished: SimTime(fin * 1_000_000_000),
            overhead: SimDur::from_secs(1),
            units: 1,
            retries: 0,
            failed: false,
        }
    }

    #[test]
    fn peak_rss_reports_where_proc_exists() {
        #[cfg(target_os = "linux")]
        assert!(peak_rss_kb() > 0, "a running test process has a high-water RSS");
        #[cfg(not(target_os = "linux"))]
        assert_eq!(peak_rss_kb(), 0);
    }

    #[test]
    fn act_and_breakdown() {
        let mut m = Metrics::new();
        m.actions.push(rec(1, 0, 2, 10, ActionKind::EnvExec));
        m.actions.push(rec(2, 0, 0, 4, ActionKind::RewardCpu));
        assert!((m.mean_act() - 7.0).abs() < 1e-9); // (10 + 4)/2
        let (exec, queue, ovh) = m.act_breakdown();
        assert!((queue - 1.0).abs() < 1e-9); // (2 + 0)/2
        assert!((ovh - 1.0).abs() < 1e-9);
        assert!((exec - ((8.0 - 1.0) + (4.0 - 1.0)) / 2.0).abs() < 1e-9);
        assert!((m.mean_act_of(ActionKind::EnvExec) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn failed_actions_excluded_from_act() {
        let mut m = Metrics::new();
        m.actions.push(rec(1, 0, 2, 10, ActionKind::ApiCall));
        let mut f = rec(2, 0, 0, 600, ActionKind::ApiCall);
        f.failed = true;
        f.retries = 3;
        m.actions.push(f);
        assert!((m.mean_act() - 10.0).abs() < 1e-9);
        assert_eq!(m.failed_actions(), 1);
        assert_eq!(m.total_retries(), 3);
    }

    #[test]
    fn timelines_bucket_correctly() {
        let mut m = Metrics::new();
        m.actions.push(rec(1, 5, 6, 7, ActionKind::ApiCall));
        m.actions.push(rec(2, 8, 9, 10, ActionKind::ApiCall));
        m.actions.push(rec(3, 15, 16, 17, ActionKind::ApiCall));
        let tl = m.act_timeline(SimDur::from_secs(10));
        assert_eq!(tl.len(), 2);
        assert_eq!(tl[0].0, 0.0);
        assert_eq!(tl[1].0, 10.0);
        let inv = m.invocation_timeline(SimDur::from_secs(10), Some(TaskId(0)));
        assert_eq!(inv[0].1, 2);
        assert_eq!(inv[1].1, 1);
    }

    #[test]
    fn trajectory_ratios() {
        let t = TrajRecord {
            id: TrajId(1),
            task: TaskId(0),
            started: SimTime::ZERO,
            finished: SimTime::ZERO + SimDur::from_secs(100),
            gen_dur: SimDur::from_secs(50),
            tool_dur: SimDur::from_secs(20),
            reward_dur: SimDur::from_secs(27),
            failed: false,
            restarts: 0,
        };
        assert!((t.active_ratio() - 0.47).abs() < 1e-9);
        assert_eq!(t.lifetime(), SimDur::from_secs(100));
    }

    #[test]
    fn to_json_is_deterministic_and_complete() {
        let mut m = Metrics::new();
        m.actions.push(rec(1, 0, 2, 10, ActionKind::EnvExec));
        m.steps.push(StepRecord {
            index: 0,
            rollout_dur: SimDur::from_secs(10),
            train_dur: SimDur::from_secs(5),
        });
        m.util.push(UtilSample { at: SimTime(3), name: "cpu".into(), value: 0.5 });
        let a = m.to_json().to_string();
        let b = m.to_json().to_string();
        assert_eq!(a, b);
        let j = Json::parse(&a).unwrap();
        assert_eq!(j.get("actions").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(
            j.path(&["actions"]).unwrap().as_arr().unwrap()[0]
                .get("kind")
                .unwrap()
                .as_str(),
            Some("env_exec")
        );
        assert_eq!(j.get("steps").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(j.get("util").unwrap().as_arr().unwrap().len(), 1);
    }

    fn prov(at_secs: u64, pool: &str, units: u64) -> ProvisionRecord {
        ProvisionRecord {
            at: SimTime(at_secs * 1_000_000_000),
            pool: pool.into(),
            units,
        }
    }

    #[test]
    fn resource_hours_integrate_the_step_function() {
        let mut m = Metrics::new();
        // run spans 0..3600s (one action pins the end of the run)
        m.actions.push(rec(1, 0, 1, 3600, ActionKind::EnvExec));
        // 100 units for 1800s, then 25 units for the remaining 1800s
        m.provision.push(prov(0, "cpu_cores", 100));
        m.provision.push(prov(1800, "cpu_cores", 25));
        let (used, stat) = m.pool_unit_hours("cpu_cores");
        assert!((used - (100.0 * 0.5 + 25.0 * 0.5)).abs() < 1e-9, "used {used}");
        assert!((stat - 100.0).abs() < 1e-9, "static {stat}");
        assert!((m.savings_vs_static() - 0.375).abs() < 1e-9);
    }

    #[test]
    fn static_provision_reports_zero_savings() {
        let mut m = Metrics::new();
        m.actions.push(rec(1, 0, 1, 100, ActionKind::EnvExec));
        m.provision.push(prov(0, "cpu_cores", 64));
        m.provision.push(prov(0, "gpus", 16));
        assert!(m.savings_vs_static().abs() < 1e-12);
        let rows = m.resource_rows();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].0, "cpu_cores"); // sorted
        assert_eq!(rows[1].0, "gpus");
        // no provision records at all → defined zero, not NaN
        assert_eq!(Metrics::new().savings_vs_static(), 0.0);
    }

    #[test]
    fn gpu_pool_series_contributes_to_savings() {
        // a gpus scale-down mid-run must shrink the gpus unit-hours and
        // surface in the aggregate savings alongside the other pools
        let mut m = Metrics::new();
        m.actions.push(rec(1, 0, 1, 100, ActionKind::RewardModel));
        m.provision.push(prov(0, "cpu_cores", 128));
        m.provision.push(prov(0, "gpus", 24));
        m.provision.push(prov(50, "gpus", 8)); // cordoned to one node
        let (used, stat) = m.pool_unit_hours("gpus");
        assert!(used < stat, "gpus used {used} !< static {stat}");
        assert!((used - (24.0 * 50.0 + 8.0 * 50.0) / 3600.0).abs() < 1e-9);
        // aggregate: cpu 128×100 + gpus (24×50 + 8×50) of 12800+2400 static
        let expected = 1.0 - (12800.0 + 1600.0) / (12800.0 + 2400.0);
        assert!((m.savings_vs_static() - expected).abs() < 1e-9);
    }

    #[test]
    fn savings_weight_pools_by_static_share() {
        let mut m = Metrics::new();
        m.actions.push(rec(1, 0, 1, 100, ActionKind::EnvExec));
        m.provision.push(prov(0, "cpu_cores", 90));
        m.provision.push(prov(0, "api_lanes", 10));
        // halve the big pool halfway through
        m.provision.push(prov(50, "cpu_cores", 45));
        // aggregate: used = 90*.5 + 45*.5 + 10 = 77.5 of 100 static
        assert!((m.savings_vs_static() - 0.225).abs() < 1e-9);
    }

    #[test]
    fn cost_weighting_reprices_the_savings() {
        // 128 cores halved mid-run + 16 GPUs static: unit-hours say the
        // cpu shrink dominates, dollars say the (expensive) static GPUs do
        let mut m = Metrics::new();
        m.actions.push(rec(1, 0, 1, 100, ActionKind::EnvExec));
        m.provision.push(prov(0, "cpu_cores", 128));
        m.provision.push(prov(50, "cpu_cores", 64));
        m.provision.push(prov(0, "gpus", 16));
        let unweighted = m.savings_vs_static();
        assert!(unweighted > 0.0);
        // without a cost model the dollar figure IS the unweighted figure
        assert_eq!(m.savings_vs_static_cost(), unweighted);
        assert!(m.cost_rows().is_empty());
        let mut rates = BTreeMap::new();
        rates.insert("cpu_cores".to_string(), 0.1);
        rates.insert("gpus".to_string(), 10.0);
        m.cost_rates = Some(rates);
        // used$ = 0.1×(128×50 + 64×50)/3600 + 10×16×100/3600
        // stat$ = 0.1×128×100/3600 + 10×16×100/3600
        let used = (0.1 * (128.0 * 50.0 + 64.0 * 50.0) + 10.0 * 1600.0) / 3600.0;
        let stat = (0.1 * 12800.0 + 10.0 * 1600.0) / 3600.0;
        let weighted = m.savings_vs_static_cost();
        assert!((weighted - (1.0 - used / stat)).abs() < 1e-9, "got {weighted}");
        assert!(weighted < unweighted, "cheap-cpu savings must deflate in dollars");
        assert!(weighted.is_finite());
        let rows = m.cost_rows();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].0, "cpu_cores");
        assert!((rows[0].1 - 0.1).abs() < 1e-12);
        let (gpu_used, gpu_stat) = m.pool_cost("gpus");
        assert!((gpu_used - gpu_stat).abs() < 1e-9, "static pool: used$ == static$");
        // cost keys only serialize when the model is wired
        let j = m.to_json().to_string();
        assert!(j.contains("savings_vs_static_cost"));
        m.cost_rates = None;
        assert!(!m.to_json().to_string().contains("savings_vs_static_cost"));
    }

    #[test]
    fn tenant_rollups_sum_bitwise_to_global() {
        let mut m = Metrics::new();
        m.actions.push(rec(1, 0, 2, 10, ActionKind::EnvExec));
        let mut b = rec(2, 1, 3, 9, ActionKind::ApiCall);
        b.tenant = TenantId(1);
        b.retries = 2;
        m.actions.push(b);
        let mut c = rec(3, 5, 6, 7, ActionKind::RewardModel);
        c.tenant = TenantId(1);
        c.failed = true;
        m.actions.push(c);
        assert!(m.multi_tenant());
        let rolls = m.tenant_rollups();
        assert_eq!(rolls.len(), 2);
        let mut total = TenantRollup::default();
        for r in rolls.values() {
            total.actions += r.actions;
            total.failed += r.failed;
            total.retries += r.retries;
            total.act_ns += r.act_ns;
            total.queue_ns += r.queue_ns;
        }
        // bitwise: the u64 sums over tenants equal the global sums
        let mut global = TenantRollup::default();
        for a in &m.actions {
            global.absorb(a);
        }
        assert_eq!(total, global);
        assert_eq!(global.actions, 3);
        assert_eq!(global.failed, 1);
        assert_eq!(global.retries, 2);
        assert!((rolls[&1].mean_act_secs() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn tenant_pool_shares_sum_to_one_per_pool() {
        let mut m = Metrics::new();
        // tenant 0: 10 unit-secs of cpu; tenant 1: 30 unit-secs of cpu
        let a = rec(1, 0, 0, 10, ActionKind::EnvExec); // units 1, busy 10s
        m.actions.push(a);
        let mut b = rec(2, 0, 0, 30, ActionKind::RewardCpu);
        b.tenant = TenantId(1);
        m.actions.push(b);
        let shares = m.tenant_pool_shares();
        assert!((shares[&0]["cpu_cores"] - 0.25).abs() < 1e-12);
        assert!((shares[&1]["cpu_cores"] - 0.75).abs() < 1e-12);
        // cost rows prorate the used pool bill by exactly those shares
        m.provision.push(prov(0, "cpu_cores", 4));
        let rows = m.tenant_cost_rows();
        assert_eq!(rows.len(), 2);
        let total: f64 = rows.iter().map(|(_, _, d)| d).sum();
        let (used, _) = m.pool_cost("cpu_cores");
        assert!((total - used).abs() < 1e-9);
    }

    #[test]
    fn tenant_keys_only_serialize_multi_tenant() {
        let mut m = Metrics::new();
        m.actions.push(rec(1, 0, 2, 10, ActionKind::EnvExec));
        let j = m.to_json().to_string();
        assert!(!j.contains("tenant"), "single-tenant bytes must be unchanged");
        let mut b = rec(2, 0, 1, 5, ActionKind::ApiCall);
        b.tenant = TenantId(1);
        m.actions.push(b);
        let j = m.to_json().to_string();
        assert!(j.contains("\"tenant\":1"));
        assert!(j.contains("tenant_rollups"));
        let parsed = Json::parse(&j).unwrap();
        let rolls = parsed.get("tenant_rollups").unwrap();
        assert!(rolls.get("0").is_some());
        assert!(rolls.get("1").is_some());
    }

    #[test]
    fn step_and_util_aggregates() {
        let mut m = Metrics::new();
        m.steps.push(StepRecord {
            index: 0,
            rollout_dur: SimDur::from_secs(100),
            train_dur: SimDur::from_secs(60),
        });
        m.steps.push(StepRecord {
            index: 1,
            rollout_dur: SimDur::from_secs(80),
            train_dur: SimDur::from_secs(60),
        });
        assert!((m.mean_step_dur() - 150.0).abs() < 1e-9);
        m.util.push(UtilSample { at: SimTime(0), name: "gpu".into(), value: 0.2 });
        m.util.push(UtilSample { at: SimTime(1), name: "gpu".into(), value: 0.4 });
        m.util.push(UtilSample { at: SimTime(1), name: "cpu".into(), value: 0.9 });
        assert!((m.mean_util("gpu") - 0.3).abs() < 1e-9);
    }
}
