//! Agentic-RL rollout engine: trace-driven ReAct trajectory generation.
//!
//! The paper's rollouts come from real LLMs (Qwen3-32B / MiMo-V2) acting on
//! in-house datasets; the scheduler only ever sees the resulting *arrival
//! process* — interleaved LLM-generation gaps and external actions with
//! their cost/elasticity mix. [`workloads`] reproduces that process with
//! distributions calibrated to the paper's Fig. 3 characteristics (≈47%
//! env-active ratio for coding, 3-orders-of-magnitude invocation
//! burstiness, long-tailed reward computation).
//!
//! Plans are materialized up front (durations pre-sampled), which doubles
//! as the trace record/replay mechanism used by the Fig. 9 ablation.

pub mod workloads;

pub use workloads::{Workload, WorkloadKind};

use crate::action::{ActionKind, CostSpec, ElasticityModel, ResourceKindId, ServiceId, TaskId};
use crate::sim::SimDur;

/// Template for one action inside a plan (becomes an [`crate::action::ActionSpec`]
/// when submitted).
#[derive(Debug, Clone)]
pub struct ActionTemplate {
    pub kind: ActionKind,
    pub cost: CostSpec,
    pub key_resource: Option<ResourceKindId>,
    pub elasticity: ElasticityModel,
    pub profiled_dur: Option<SimDur>,
    pub service: Option<ServiceId>,
    pub true_dur: SimDur,
    /// Stage attribution for Fig. 7: true ⇒ reward, false ⇒ tool/env.
    pub is_reward: bool,
}

/// One phase of a trajectory.
#[derive(Debug, Clone)]
pub enum Phase {
    /// LLM generation on the training cluster (no external resources).
    Gen(SimDur),
    /// External invocation.
    Act(ActionTemplate),
}

/// A fully-materialized trajectory plan.
#[derive(Debug, Clone)]
pub struct TrajectoryPlan {
    pub task: TaskId,
    /// Environment memory reserved for the trajectory's lifetime (GiB);
    /// zero for workloads without CPU environments.
    pub mem_gb: u64,
    pub phases: Vec<Phase>,
}

impl TrajectoryPlan {
    pub fn n_actions(&self) -> usize {
        self.phases.iter().filter(|p| matches!(p, Phase::Act(_))).count()
    }

    pub fn total_gen(&self) -> SimDur {
        self.phases
            .iter()
            .filter_map(|p| match p {
                Phase::Gen(d) => Some(*d),
                _ => None,
            })
            .sum()
    }

    pub fn total_act_true(&self) -> SimDur {
        self.phases
            .iter()
            .filter_map(|p| match p {
                Phase::Act(a) => Some(a.true_dur),
                _ => None,
            })
            .sum()
    }

    /// First CPU-cores requirement (node-binding input), if any.
    pub fn first_cpu_min(&self, cpu_kind: ResourceKindId) -> Option<u32> {
        self.phases.iter().find_map(|p| match p {
            Phase::Act(a) => {
                let m = a.cost.dim(cpu_kind).min_units();
                (m > 0).then_some(m as u32)
            }
            _ => None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::action::{DimCost, ResourceClass, ResourceRegistry};

    #[test]
    fn plan_accessors() {
        let mut reg = ResourceRegistry::new();
        let cpu = reg.register("cpu", ResourceClass::CpuCores, 64);
        let t = ActionTemplate {
            kind: ActionKind::EnvExec,
            cost: CostSpec::single(&reg, cpu, DimCost::Fixed(2)),
            key_resource: Some(cpu),
            elasticity: ElasticityModel::None,
            profiled_dur: None,
            service: None,
            true_dur: SimDur::from_secs(3),
            is_reward: false,
        };
        let plan = TrajectoryPlan {
            task: TaskId(0),
            mem_gb: 4,
            phases: vec![
                Phase::Gen(SimDur::from_secs(10)),
                Phase::Act(t.clone()),
                Phase::Gen(SimDur::from_secs(5)),
                Phase::Act(t),
            ],
        };
        assert_eq!(plan.n_actions(), 2);
        assert_eq!(plan.total_gen(), SimDur::from_secs(15));
        assert_eq!(plan.total_act_true(), SimDur::from_secs(6));
        assert_eq!(plan.first_cpu_min(cpu), Some(2));
    }
}
