//! Workload catalogs for the paper's three agentic RL tasks (§6.1).
//!
//! * **AI Coding** — SWEBench-style: multi-turn shell/file actions in a
//!   per-trajectory CPU environment; reward = running the test suite
//!   (long-tailed, CPU-scalable — the only CPU-scalable action kind, as in
//!   the paper's ablation).
//! * **DeepSearch** — BrowseComp-style: bursts of rate-limited API calls,
//!   reward via an LLM-judge GPU service.
//! * **MOPD** — multi-teacher on-policy distillation: trajectory log-probs
//!   against 9–12 teacher-model GPU services, highly bursty at batch
//!   boundaries.
//!
//! Distribution parameters are calibrated so the *baseline* run reproduces
//! the paper's Fig. 3 motivation numbers (≈47% coding env-active ratio,
//! invocation counts swinging ~3 orders of magnitude, <3% mean teacher-GPU
//! activity under static deployment).

use super::{ActionTemplate, Phase, TrajectoryPlan};
use crate::action::{
    ActionKind, CostSpec, DimCost, ElasticityModel, ResourceClass,
    ResourceKindId, ResourceRegistry, ServiceId, TaskId, TenantId,
};
use crate::cluster::api::ApiEndpointSpec;
use crate::managers::ServiceSpec;
use crate::sim::SimDur;
use crate::util::rng::Rng;

/// Everything the experiments need to know about the external world:
/// resource kinds, API endpoints, GPU services.
#[derive(Debug)]
pub struct Catalog {
    pub registry: ResourceRegistry,
    pub cpu_cores: ResourceKindId,
    pub gpu_units: ResourceKindId,
    /// (kind, endpoint spec) per managed API endpoint.
    pub api: Vec<(ResourceKindId, ApiEndpointSpec)>,
    pub services: Vec<ServiceSpec>,
    /// index into `services` of the DeepSearch judge.
    pub judge: usize,
    /// indices into `services` of the MOPD teachers.
    pub teachers: Vec<usize>,
}

/// Catalog scale knobs (testbed §6.1 by default).
#[derive(Debug, Clone)]
pub struct CatalogCfg {
    pub cpu_nodes: u32,
    pub cores_per_node: u32,
    pub gpu_nodes: u32,
    pub n_teachers: u32,
    pub teacher_gb: f64,
    pub judge_gb: f64,
    pub n_search_endpoints: u32,
}

impl Default for CatalogCfg {
    fn default() -> Self {
        CatalogCfg {
            cpu_nodes: 5,
            cores_per_node: 256,
            gpu_nodes: 5,
            n_teachers: 9,
            teacher_gb: 60.0,
            judge_gb: 40.0,
            n_search_endpoints: 3,
        }
    }
}

impl Catalog {
    pub fn build(cfg: &CatalogCfg) -> Self {
        let mut registry = ResourceRegistry::new();
        let cpu_cores = registry.register(
            "cpu_cores",
            ResourceClass::CpuCores,
            (cfg.cpu_nodes * cfg.cores_per_node) as u64,
        );
        let gpu_units =
            registry.register("gpu_units", ResourceClass::GpuUnits, (cfg.gpu_nodes * 8) as u64);

        let mut api = Vec::new();
        for i in 0..cfg.n_search_endpoints {
            let spec = ApiEndpointSpec::search(&format!("search-{i}"));
            let kind = registry.register(
                &format!("api:search-{i}"),
                ResourceClass::ApiConcurrency,
                spec.max_concurrency as u64,
            );
            api.push((kind, spec));
        }
        let pdf = ApiEndpointSpec::pdf_parse("pdf-parse");
        let pdf_kind = registry.register(
            "api:pdf-parse",
            ResourceClass::ApiConcurrency,
            pdf.max_concurrency as u64,
        );
        api.push((pdf_kind, pdf));

        // GPU efficiency per DoP 1..8 (TP efficiency measured offline)
        let eff = vec![1.0, 0.92, 0.85, 0.82, 0.72, 0.68, 0.65, 0.62];
        let mut services = Vec::new();
        let judge = 0usize;
        services.push(ServiceSpec {
            id: ServiceId(0),
            name: "judge".into(),
            weights_gb: cfg.judge_gb,
            dop_choices: vec![1, 2, 4, 8],
            efficiency: eff.clone(),
        });
        let mut teachers = Vec::new();
        for i in 0..cfg.n_teachers {
            teachers.push(services.len());
            services.push(ServiceSpec {
                id: ServiceId(1 + i),
                name: format!("teacher-{i}"),
                weights_gb: cfg.teacher_gb,
                dop_choices: vec![1, 2, 4, 8],
                efficiency: eff.clone(),
            });
        }

        Catalog { registry, cpu_cores, gpu_units, api, services, judge, teachers }
    }

    pub fn service_elasticity(&self, idx: usize) -> ElasticityModel {
        ElasticityModel::Table(self.services[idx].efficiency.clone())
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadKind {
    Coding,
    DeepSearch,
    Mopd,
}

impl WorkloadKind {
    pub fn name(self) -> &'static str {
        match self {
            WorkloadKind::Coding => "coding",
            WorkloadKind::DeepSearch => "deepsearch",
            WorkloadKind::Mopd => "mopd",
        }
    }

    /// Inverse of [`WorkloadKind::name`] (config/spec parsing).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "coding" => Some(WorkloadKind::Coding),
            "deepsearch" => Some(WorkloadKind::DeepSearch),
            "mopd" => Some(WorkloadKind::Mopd),
            _ => None,
        }
    }
}

/// One RL task generating trajectories of a given kind.
#[derive(Debug, Clone)]
pub struct Workload {
    pub task: TaskId,
    /// Tenant (training job) this task belongs to in multi-tenant runs;
    /// `TenantId(0)` for the classic single-tenant experiments.
    pub tenant: TenantId,
    pub kind: WorkloadKind,
    /// Arrival phase: the tenant's first step starts this far into the run
    /// (ZERO = all tenants arrive together).
    pub phase: SimDur,
    /// Duration of the (GPU-training-cluster) train phase per step.
    pub train_dur: SimDur,
    /// Max CPU DoP for scalable reward actions (paper ablation: 32).
    pub max_reward_dop: u64,
    /// Fig. 9 ablation: pin scalable reward actions at this DoP instead of
    /// letting the scheduler choose (None = elastic).
    pub fixed_dop: Option<u64>,
}

impl Workload {
    pub fn new(task: TaskId, kind: WorkloadKind) -> Self {
        let train_dur = match kind {
            WorkloadKind::Coding => SimDur::from_secs(90),
            WorkloadKind::DeepSearch => SimDur::from_secs(60),
            WorkloadKind::Mopd => SimDur::from_secs(120),
        };
        Workload {
            task,
            tenant: TenantId(0),
            kind,
            phase: SimDur::ZERO,
            train_dur,
            max_reward_dop: 32,
            fixed_dop: None,
        }
    }

    /// Materialize one trajectory plan.
    pub fn gen_trajectory(&self, cat: &Catalog, rng: &mut Rng) -> TrajectoryPlan {
        match self.kind {
            WorkloadKind::Coding => self.gen_coding(cat, rng),
            WorkloadKind::DeepSearch => self.gen_deepsearch(cat, rng),
            WorkloadKind::Mopd => self.gen_mopd(cat, rng),
        }
    }

    fn gen_coding(&self, cat: &Catalog, rng: &mut Rng) -> TrajectoryPlan {
        let turns = rng.range(4, 9);
        let mut phases = Vec::new();
        for _ in 0..turns {
            // LLM thinks…
            phases.push(Phase::Gen(SimDur::from_secs_f64(
                rng.lognormal(12.0f64.ln(), 0.45).clamp(2.0, 120.0),
            )));
            // …then edits files / runs shell commands (1–2 per turn)
            for _ in 0..rng.range(1, 2) {
                let dur = rng.lognormal(0.4f64.ln(), 1.6).clamp(0.001, 60.0);
                phases.push(Phase::Act(ActionTemplate {
                    kind: ActionKind::EnvExec,
                    cost: CostSpec::single(&cat.registry, cat.cpu_cores, DimCost::Fixed(1)),
                    key_resource: Some(cat.cpu_cores),
                    elasticity: ElasticityModel::None,
                    profiled_dur: None, // env execs are LLM-dependent, unprofiled
                    service: None,
                    true_dur: SimDur::from_secs_f64(dur),
                    is_reward: false,
                }));
            }
        }
        // reward: run the test suite — long-tailed and CPU-scalable
        phases.push(Phase::Gen(SimDur::from_secs_f64(
            rng.lognormal(8.0f64.ln(), 0.4).clamp(1.0, 60.0),
        )));
        let t_ori = rng.pareto(60.0, 1.6).clamp(15.0, 600.0);
        let reward_cost = match self.fixed_dop {
            Some(d) => DimCost::Fixed(d),
            None => DimCost::Range { min: 1, max: self.max_reward_dop },
        };
        phases.push(Phase::Act(ActionTemplate {
            kind: ActionKind::RewardCpu,
            cost: CostSpec::single(&cat.registry, cat.cpu_cores, reward_cost),
            key_resource: Some(cat.cpu_cores),
            elasticity: ElasticityModel::Amdahl { serial_frac: 0.04 },
            // profiled in advance (§6.1: "scalability and execution durations
            // profiled … only for reward calculation on CPUs and reward model
            // inference on GPUs") — with profiling noise
            profiled_dur: Some(SimDur::from_secs_f64(
                t_ori * rng.normal(1.0, 0.1).clamp(0.7, 1.3),
            )),
            service: None,
            true_dur: SimDur::from_secs_f64(t_ori),
            is_reward: true,
        }));
        TrajectoryPlan { task: self.task, mem_gb: rng.range(2, 8), phases }
    }

    fn gen_deepsearch(&self, cat: &Catalog, rng: &mut Rng) -> TrajectoryPlan {
        let turns = rng.range(5, 12);
        let mut phases = Vec::new();
        for _ in 0..turns {
            phases.push(Phase::Gen(SimDur::from_secs_f64(
                rng.lognormal(12.0f64.ln(), 0.5).clamp(1.0, 120.0),
            )));
            let calls = if rng.chance(0.8) { 1 } else { 2 };
            for _ in 0..calls {
                // skewed endpoint choice: search dominates, pdf occasional
                let idx = if rng.chance(0.9) {
                    rng.zipf(cat.api.len() - 1, 0.9)
                } else {
                    cat.api.len() - 1 // pdf
                };
                let (kind_id, _) = cat.api[idx];
                phases.push(Phase::Act(ActionTemplate {
                    kind: ActionKind::ApiCall,
                    cost: CostSpec::single(&cat.registry, kind_id, DimCost::Fixed(1)),
                    key_resource: None, // APIs are inherently non-scalable
                    elasticity: ElasticityModel::None,
                    profiled_dur: None,
                    service: None,
                    // placeholder — real latency comes from the endpoint sim
                    true_dur: SimDur::from_millis(500),
                    is_reward: false,
                }));
            }
        }
        // reward: LLM-judge scores the trajectory on the GPU service
        let judge = cat.judge;
        let t_ori = rng.lognormal(6.0f64.ln(), 0.5).clamp(2.0, 30.0);
        phases.push(Phase::Act(ActionTemplate {
            kind: ActionKind::RewardModel,
            cost: CostSpec::single(
                &cat.registry,
                cat.gpu_units,
                DimCost::Discrete(cat.services[judge].dop_choices.iter().map(|&d| d as u64).collect()),
            ),
            key_resource: Some(cat.gpu_units),
            elasticity: cat.service_elasticity(judge),
            profiled_dur: Some(SimDur::from_secs_f64(
                t_ori * rng.normal(1.0, 0.08).clamp(0.8, 1.2),
            )),
            service: Some(cat.services[judge].id),
            true_dur: SimDur::from_secs_f64(t_ori),
            is_reward: true,
        }));
        TrajectoryPlan { task: self.task, mem_gb: 0, phases }
    }

    fn gen_mopd(&self, cat: &Catalog, rng: &mut Rng) -> TrajectoryPlan {
        let mut phases = Vec::new();
        // long single/dual-turn rollout; external resources untouched.
        // The heavy tail dominates the step (paper §6.2: MOPD's rollout is
        // "dominated by the long-tail trajectory").
        for _ in 0..rng.range(1, 2) {
            phases.push(Phase::Gen(SimDur::from_secs_f64(
                rng.lognormal(60.0f64.ln(), 0.8).clamp(10.0, 900.0),
            )));
        }
        // reward: log-probs against a skewed subset of teacher services —
        // all fired at trajectory end (the paper's bursty pattern)
        let k = rng.range(2, cat.teachers.len().min(5) as u64) as usize;
        let mut picked = std::collections::BTreeSet::new();
        while picked.len() < k {
            picked.insert(rng.zipf(cat.teachers.len(), 0.8));
        }
        for t in picked {
            let idx = cat.teachers[t];
            // a log-prob pass over one trajectory: seconds at DoP 1 (short
            // enough that teacher GPUs idle most of the time — Fig. 3(b) —
            // yet long enough that EOE restore stays ~25% of exec, Table 1)
            let t_ori = rng.lognormal(6.0f64.ln(), 0.5).clamp(1.5, 30.0);
            phases.push(Phase::Act(ActionTemplate {
                kind: ActionKind::RewardModel,
                cost: CostSpec::single(
                    &cat.registry,
                    cat.gpu_units,
                    DimCost::Discrete(
                        cat.services[idx].dop_choices.iter().map(|&d| d as u64).collect(),
                    ),
                ),
                key_resource: Some(cat.gpu_units),
                elasticity: cat.service_elasticity(idx),
                profiled_dur: Some(SimDur::from_secs_f64(
                    t_ori * rng.normal(1.0, 0.08).clamp(0.8, 1.2),
                )),
                service: Some(cat.services[idx].id),
                true_dur: SimDur::from_secs_f64(t_ori),
                is_reward: true,
            }));
        }
        TrajectoryPlan { task: self.task, mem_gb: 0, phases }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cat() -> Catalog {
        Catalog::build(&CatalogCfg::default())
    }

    #[test]
    fn catalog_registers_everything() {
        let c = cat();
        assert_eq!(c.registry.info(c.cpu_cores).capacity, 5 * 256);
        assert_eq!(c.registry.info(c.gpu_units).capacity, 40);
        assert_eq!(c.api.len(), 4); // 3 search + 1 pdf
        assert_eq!(c.services.len(), 10); // judge + 9 teachers
        assert_eq!(c.teachers.len(), 9);
    }

    #[test]
    fn coding_plans_are_well_formed() {
        let c = cat();
        let w = Workload::new(TaskId(0), WorkloadKind::Coding);
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let p = w.gen_trajectory(&c, &mut rng);
            assert!(p.n_actions() >= 5);
            assert!(p.mem_gb >= 2 && p.mem_gb <= 8);
            // last action is the scalable reward
            let last = p
                .phases
                .iter()
                .rev()
                .find_map(|ph| match ph {
                    Phase::Act(a) => Some(a),
                    _ => None,
                })
                .unwrap();
            assert!(last.is_reward);
            assert_eq!(last.kind, ActionKind::RewardCpu);
            assert!(matches!(last.elasticity, ElasticityModel::Amdahl { .. }));
            assert!(last.profiled_dur.is_some());
            for ph in &p.phases {
                if let Phase::Act(a) = ph {
                    a.cost.validate().unwrap();
                }
            }
        }
    }

    #[test]
    fn coding_env_active_ratio_near_paper() {
        // sanity: the *inherent* active ratio (no queuing) should be in the
        // ballpark of the paper's 47% so the baseline lands near Fig. 3(c).
        let c = cat();
        let w = Workload::new(TaskId(0), WorkloadKind::Coding);
        let mut rng = Rng::new(7);
        let mut act = 0.0;
        let mut total = 0.0;
        for _ in 0..300 {
            let p = w.gen_trajectory(&c, &mut rng);
            act += p.total_act_true().secs_f64();
            total += (p.total_gen() + p.total_act_true()).secs_f64();
        }
        let ratio = act / total;
        assert!((0.30..0.65).contains(&ratio), "active ratio {ratio}");
    }

    #[test]
    fn deepsearch_uses_apis_and_judge() {
        let c = cat();
        let w = Workload::new(TaskId(1), WorkloadKind::DeepSearch);
        let mut rng = Rng::new(2);
        let p = w.gen_trajectory(&c, &mut rng);
        let acts: Vec<&ActionTemplate> = p
            .phases
            .iter()
            .filter_map(|ph| match ph {
                Phase::Act(a) => Some(a),
                _ => None,
            })
            .collect();
        assert!(acts.iter().filter(|a| a.kind == ActionKind::ApiCall).count() >= 4);
        let reward = acts.last().unwrap();
        assert_eq!(reward.kind, ActionKind::RewardModel);
        assert_eq!(reward.service, Some(ServiceId(0)));
        assert_eq!(p.mem_gb, 0);
    }

    #[test]
    fn mopd_hits_multiple_teachers() {
        let c = cat();
        let w = Workload::new(TaskId(2), WorkloadKind::Mopd);
        let mut rng = Rng::new(3);
        let mut distinct = std::collections::BTreeSet::new();
        for _ in 0..100 {
            let p = w.gen_trajectory(&c, &mut rng);
            let rewards: Vec<ServiceId> = p
                .phases
                .iter()
                .filter_map(|ph| match ph {
                    Phase::Act(a) if a.kind == ActionKind::RewardModel => a.service,
                    _ => None,
                })
                .collect();
            assert!(rewards.len() >= 2);
            // no duplicate teacher per trajectory
            let set: std::collections::BTreeSet<_> = rewards.iter().collect();
            assert_eq!(set.len(), rewards.len());
            distinct.extend(rewards);
        }
        assert!(distinct.len() >= 6, "zipf should still touch most teachers");
    }

    #[test]
    fn plans_are_deterministic_per_seed() {
        let c = cat();
        let w = Workload::new(TaskId(0), WorkloadKind::Coding);
        let p1 = w.gen_trajectory(&c, &mut Rng::new(42));
        let p2 = w.gen_trajectory(&c, &mut Rng::new(42));
        assert_eq!(p1.phases.len(), p2.phases.len());
        assert_eq!(p1.total_gen(), p2.total_gen());
        assert_eq!(p1.mem_gb, p2.mem_gb);
    }
}
