//! `artifacts/meta.json` — the calling-convention contract with aot.py.

use crate::err;
use crate::util::error::Result;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// One parameter-pytree leaf (flattening order = artifact argument order).
#[derive(Debug, Clone)]
pub struct LeafSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl LeafSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Model-side config mirrored from `python/compile/model.py`.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub param_count: u64,
    pub params: Vec<LeafSpec>,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
}

/// Parsed meta.json.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub policy: ModelMeta,
    pub reward: ModelMeta,
    pub n_param_arrays: usize,
    pub artifacts: BTreeMap<String, String>,
}

fn leafs(j: &Json) -> Result<Vec<LeafSpec>> {
    j.as_arr()
        .ok_or_else(|| err!("params not an array"))?
        .iter()
        .map(|l| {
            Ok(LeafSpec {
                name: l
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| err!("leaf missing name"))?
                    .to_string(),
                shape: l
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| err!("leaf missing shape"))?
                    .iter()
                    .map(|d| d.as_u64().map(|v| v as usize))
                    .collect::<Option<Vec<_>>>()
                    .ok_or_else(|| err!("bad shape"))?,
                dtype: l
                    .get("dtype")
                    .and_then(Json::as_str)
                    .unwrap_or("float32")
                    .to_string(),
            })
        })
        .collect()
}

fn model(j: &Json) -> Result<ModelMeta> {
    Ok(ModelMeta {
        param_count: j
            .get("param_count")
            .and_then(Json::as_u64)
            .ok_or_else(|| err!("missing param_count"))?,
        params: leafs(j.get("params").ok_or_else(|| err!("missing params"))?)?,
        batch: j.get("batch").and_then(Json::as_u64).unwrap_or(1) as usize,
        seq: j.get("seq").and_then(Json::as_u64).unwrap_or(1) as usize,
        vocab: j
            .path(&["config", "vocab"])
            .and_then(Json::as_u64)
            .unwrap_or(0) as usize,
    })
}

impl ArtifactMeta {
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| err!("meta.json: {e}"))?;
        let artifacts = j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| err!("missing artifacts"))?
            .iter()
            .map(|(k, v)| {
                Ok((
                    k.clone(),
                    v.as_str().ok_or_else(|| err!("bad artifact path"))?.to_string(),
                ))
            })
            .collect::<Result<BTreeMap<_, _>>>()?;
        Ok(ArtifactMeta {
            policy: model(j.get("policy").ok_or_else(|| err!("missing policy"))?)?,
            reward: model(j.get("reward").ok_or_else(|| err!("missing reward"))?)?,
            n_param_arrays: j
                .path(&["train", "n_param_arrays"])
                .and_then(Json::as_u64)
                .ok_or_else(|| err!("missing n_param_arrays"))? as usize,
            artifacts,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "policy": {
        "config": {"vocab": 512, "d_model": 128},
        "param_count": 541696,
        "params": [
          {"name": "['embed']", "shape": [512, 128], "dtype": "float32"},
          {"name": "['ln_f']", "shape": [128], "dtype": "float32"}
        ],
        "batch": 4, "seq": 64
      },
      "reward": {
        "config": {"vocab": 512},
        "param_count": 541824,
        "params": [{"name": "['embed']", "shape": [512, 128], "dtype": "float32"}],
        "batch": 2, "seq": 64
      },
      "train": {"n_param_arrays": 2},
      "artifacts": {"policy_init": "policy_init.hlo.txt"}
    }"#;

    #[test]
    fn parses_sample() {
        let m = ArtifactMeta::parse(SAMPLE).unwrap();
        assert_eq!(m.policy.params.len(), 2);
        assert_eq!(m.policy.params[0].elems(), 512 * 128);
        assert_eq!(m.policy.batch, 4);
        assert_eq!(m.policy.vocab, 512);
        assert_eq!(m.n_param_arrays, 2);
        assert_eq!(m.artifacts["policy_init"], "policy_init.hlo.txt");
        assert_eq!(m.reward.batch, 2);
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(ArtifactMeta::parse("{}").is_err());
        assert!(ArtifactMeta::parse("not json").is_err());
    }
}
