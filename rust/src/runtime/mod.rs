//! PJRT runtime: load the AOT-lowered HLO-text artifacts and execute them
//! from the Rust hot path (Python never runs at serving/training time).
//!
//! The heavy half binds to vendored `xla` PJRT bindings and is gated behind
//! `--cfg arl_pjrt`; the default (offline, zero-dependency) build swaps in
//! [`stub`], which exposes the same types with constructors that fail with
//! an actionable message. [`meta`] — the calling-convention contract with
//! `python/compile/aot.py` — is pure JSON and always available.

pub mod meta;

#[cfg(arl_pjrt)]
mod pjrt;
#[cfg(arl_pjrt)]
pub mod trainer;

#[cfg(not(arl_pjrt))]
mod stub;

pub use meta::{ArtifactMeta, LeafSpec};

#[cfg(arl_pjrt)]
pub use pjrt::{f32_matrix, f32_vector, tokens_literal, PjrtEngine};
#[cfg(arl_pjrt)]
pub use trainer::{RewardModel, Trainer};

#[cfg(not(arl_pjrt))]
pub use stub::{PjrtEngine, RewardModel, Trainer};
