//! The real PJRT engine (compiled only with `--cfg arl_pjrt`): load the
//! AOT-lowered HLO-text artifacts and execute them from the Rust hot path.
//!
//! The interchange format is HLO *text* — see `python/compile/aot.py` and
//! /opt/xla-example/README.md for why serialized protos don't round-trip
//! through xla_extension 0.5.1.

use super::meta::ArtifactMeta;
use crate::util::error::{Error, Result};
use crate::{ensure, err};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A loaded PJRT engine: CPU client + compiled executables per artifact.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    pub meta: ArtifactMeta,
    dir: PathBuf,
}

impl PjrtEngine {
    /// Load `meta.json` and compile every artifact it lists.
    pub fn load(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let meta_path = dir.join("meta.json");
        let meta_text = std::fs::read_to_string(&meta_path).map_err(|e| {
            Error::from(e).context(format!("reading {meta_path:?} — run `make artifacts` first"))
        })?;
        let meta = ArtifactMeta::parse(&meta_text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| err!("PJRT client: {e}"))?;
        let mut exes = HashMap::new();
        for (name, file) in &meta.artifacts {
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| err!("non-utf8 path"))?,
            )
            .map_err(|e| err!("parsing {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| err!("compiling {name}: {e}"))?;
            exes.insert(name.clone(), exe);
        }
        Ok(PjrtEngine { client, exes, meta, dir })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn has(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    /// Execute an artifact: flat literal inputs → flat literal outputs
    /// (artifacts are lowered with `return_tuple=True`; this un-tuples).
    pub fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| err!("unknown artifact {name}"))?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| err!("executing {name}: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| err!("fetching result of {name}: {e}"))?;
        lit.to_tuple().map_err(|e| err!("untupling {name}: {e}"))
    }
}

/// Build an `i32[batch, seq]` literal from row-major data.
pub fn tokens_literal(data: &[i32], batch: usize, seq: usize) -> Result<xla::Literal> {
    ensure!(data.len() == batch * seq, "shape mismatch");
    xla::Literal::vec1(data)
        .reshape(&[batch as i64, seq as i64])
        .map_err(|e| err!("reshape: {e}"))
}

/// Build an `f32[batch, n]` literal.
pub fn f32_matrix(data: &[f32], batch: usize, n: usize) -> Result<xla::Literal> {
    ensure!(data.len() == batch * n, "shape mismatch");
    xla::Literal::vec1(data)
        .reshape(&[batch as i64, n as i64])
        .map_err(|e| err!("reshape: {e}"))
}

/// Build an `f32[n]` vector literal.
pub fn f32_vector(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}
