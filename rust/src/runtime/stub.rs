//! API-compatible stand-in for the PJRT runtime when the crate is built
//! without the vendored `xla` bindings (the default — the offline build has
//! no cargo registry). Every constructor fails with a clear message, so the
//! launcher's `serve` subcommand and the examples degrade gracefully instead
//! of failing to link.
//!
//! Build with `RUSTFLAGS="--cfg arl_pjrt"` (and the `xla` crate vendored)
//! to swap in the real engine from [`super::pjrt`].

use super::meta::ArtifactMeta;
use crate::util::error::Result;
use crate::{bail, err};
use std::marker::PhantomData;
use std::path::{Path, PathBuf};

const UNAVAILABLE: &str =
    "PJRT runtime not compiled in — vendor the xla bindings and rebuild with RUSTFLAGS=\"--cfg arl_pjrt\"";

/// Stub engine: loading always fails (no PJRT client is linked).
pub struct PjrtEngine {
    pub meta: ArtifactMeta,
    dir: PathBuf,
}

impl PjrtEngine {
    pub fn load(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let _ = artifact_dir.as_ref();
        bail!("{UNAVAILABLE}");
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn has(&self, _name: &str) -> bool {
        false
    }
}

/// Stub trainer, mirroring `runtime::trainer::Trainer`'s public surface.
pub struct Trainer<'e> {
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    _eng: PhantomData<&'e PjrtEngine>,
}

impl<'e> Trainer<'e> {
    pub fn init(_eng: &'e PjrtEngine, _seed: u32) -> Result<Self> {
        Err(err!("{UNAVAILABLE}"))
    }

    pub fn logits(&self, _tokens: &[i32]) -> Result<Vec<f32>> {
        Err(err!("{UNAVAILABLE}"))
    }

    pub fn logprobs(&self, _tokens: &[i32]) -> Result<Vec<f32>> {
        Err(err!("{UNAVAILABLE}"))
    }

    pub fn train_step(
        &mut self,
        _tokens: &[i32],
        _mask: &[f32],
        _advantages: &[f32],
        _old_logp: &[f32],
        _lr: f32,
    ) -> Result<f32> {
        Err(err!("{UNAVAILABLE}"))
    }

    pub fn step_count(&self) -> Result<i32> {
        Err(err!("{UNAVAILABLE}"))
    }
}

/// Stub reward model, mirroring `runtime::trainer::RewardModel`.
pub struct RewardModel<'e> {
    pub batch: usize,
    pub seq: usize,
    _eng: PhantomData<&'e PjrtEngine>,
}

impl<'e> RewardModel<'e> {
    pub fn init(_eng: &'e PjrtEngine, _seed: u32) -> Result<Self> {
        Err(err!("{UNAVAILABLE}"))
    }

    pub fn score(&self, _tokens: &[i32], _mask: &[f32]) -> Result<Vec<f32>> {
        Err(err!("{UNAVAILABLE}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_but_cleanly() {
        let e = PjrtEngine::load("artifacts").unwrap_err();
        assert!(e.to_string().contains("arl_pjrt"), "{e}");
    }
}
