//! GRPO trainer + reward-model service over the PJRT engine.
//!
//! State (policy params, Adam moments, step counter) lives as XLA literals
//! owned by the trainer and threaded through the `train_step` artifact —
//! the whole update is one compiled module, so Rust never touches math.

use super::{f32_matrix, tokens_literal, PjrtEngine};
use crate::util::error::Result;
use crate::{ensure, err};
use xla::Literal;

/// The xla crate's `Literal` is not `Clone` and `execute` consumes inputs;
/// round-trip through host data to duplicate. (The §Perf pass replaces the
/// per-step param copies with device-resident buffers if this shows up.)
fn clone_lit(l: &Literal) -> Result<Literal> {
    let shape = l.array_shape().map_err(|e| err!("{e}"))?;
    let dims: Vec<i64> = shape.dims().to_vec();
    let v = l.to_vec::<f32>().map_err(|e| err!("{e}"))?;
    Literal::vec1(&v).reshape(&dims).map_err(|e| err!("{e}"))
}

/// The RL policy under training.
pub struct Trainer<'e> {
    eng: &'e PjrtEngine,
    params: Vec<Literal>,
    m: Vec<Literal>,
    v: Vec<Literal>,
    step: Literal,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
}

impl<'e> Trainer<'e> {
    /// Initialize policy parameters on-device via the `policy_init` artifact.
    pub fn init(eng: &'e PjrtEngine, seed: u32) -> Result<Self> {
        let p = eng.run("policy_init", &[Literal::scalar(seed)])?;
        let n = eng.meta.n_param_arrays;
        ensure!(p.len() == n, "policy_init returned {} arrays, want {n}", p.len());
        let zeros = || -> Result<Vec<Literal>> {
            eng.meta
                .policy
                .params
                .iter()
                .map(|spec| {
                    let z = vec![0f32; spec.elems()];
                    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                    Literal::vec1(&z)
                        .reshape(&dims)
                        .map_err(|e| err!("zeros: {e}"))
                })
                .collect()
        };
        Ok(Trainer {
            eng,
            params: p,
            m: zeros()?,
            v: zeros()?,
            step: Literal::scalar(0i32),
            batch: eng.meta.policy.batch,
            seq: eng.meta.policy.seq,
            vocab: eng.meta.policy.vocab,
        })
    }

    /// Forward logits for sampling: `tokens` i32[batch,seq] →
    /// f32[batch, seq, vocab] flattened row-major.
    pub fn logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let t = tokens_literal(tokens, self.batch, self.seq)?;
        let mut inputs: Vec<Literal> = self
            .params
            .iter()
            .map(clone_lit)
            .collect::<Result<_>>()?;
        inputs.push(t);
        let out = self.eng.run("policy_fwd", &inputs)?;
        out[0].to_vec::<f32>().map_err(|e| err!("{e}"))
    }

    /// Per-token behaviour log-probs: f32[batch, seq-1] flattened.
    pub fn logprobs(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let t = tokens_literal(tokens, self.batch, self.seq)?;
        let mut inputs: Vec<Literal> = self
            .params
            .iter()
            .map(clone_lit)
            .collect::<Result<_>>()?;
        inputs.push(t);
        let out = self.eng.run("policy_logprobs", &inputs)?;
        out[0].to_vec::<f32>().map_err(|e| err!("{e}"))
    }

    /// One GRPO Adam step; returns the loss. `mask`/`old_logp` are
    /// `[batch, seq-1]`, `advantages` is `[batch]`.
    pub fn train_step(
        &mut self,
        tokens: &[i32],
        mask: &[f32],
        advantages: &[f32],
        old_logp: &[f32],
        lr: f32,
    ) -> Result<f32> {
        let n = self.params.len();
        let mut inputs: Vec<Literal> = Vec::with_capacity(3 * n + 6);
        for l in self.params.iter().chain(&self.m).chain(&self.v) {
            inputs.push(clone_lit(l)?);
        }
        inputs.push(Self::clone_i32(&self.step)?);
        inputs.push(tokens_literal(tokens, self.batch, self.seq)?);
        inputs.push(f32_matrix(mask, self.batch, self.seq - 1)?);
        inputs.push(Literal::vec1(advantages));
        inputs.push(f32_matrix(old_logp, self.batch, self.seq - 1)?);
        inputs.push(Literal::scalar(lr));
        let mut out = self.eng.run("train_step", &inputs)?;
        ensure!(out.len() == 3 * n + 2, "train_step returned {}", out.len());
        let loss = out
            .pop()
            .unwrap()
            .get_first_element::<f32>()
            .map_err(|e| err!("{e}"))?;
        self.step = out.pop().unwrap();
        self.v = out.split_off(2 * n);
        self.m = out.split_off(n);
        self.params = out;
        Ok(loss)
    }

    fn clone_i32(l: &Literal) -> Result<Literal> {
        let v = l.get_first_element::<i32>().map_err(|e| err!("{e}"))?;
        Ok(Literal::scalar(v))
    }

    pub fn step_count(&self) -> Result<i32> {
        self.step.get_first_element::<i32>().map_err(|e| err!("{e}"))
    }
}

/// The reward-model service (what the GPU manager's EOE multiplexes).
pub struct RewardModel<'e> {
    eng: &'e PjrtEngine,
    params: Vec<Literal>,
    pub batch: usize,
    pub seq: usize,
}

impl<'e> RewardModel<'e> {
    pub fn init(eng: &'e PjrtEngine, seed: u32) -> Result<Self> {
        let p = eng.run("reward_init", &[Literal::scalar(seed)])?;
        Ok(RewardModel {
            eng,
            params: p,
            batch: eng.meta.reward.batch,
            seq: eng.meta.reward.seq,
        })
    }

    /// Score a batch: tokens i32[batch,seq], mask f32[batch,seq] → f32[batch].
    pub fn score(&self, tokens: &[i32], mask: &[f32]) -> Result<Vec<f32>> {
        let mut inputs: Vec<Literal> = self
            .params
            .iter()
            .map(clone_lit)
            .collect::<Result<_>>()?;
        inputs.push(tokens_literal(tokens, self.batch, self.seq)?);
        inputs.push(f32_matrix(mask, self.batch, self.seq)?);
        let out = self.eng.run("reward_fwd", &inputs)?;
        out[0].to_vec::<f32>().map_err(|e| err!("{e}"))
    }
}
