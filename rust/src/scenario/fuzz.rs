//! Seeded scenario fuzzer: derives random-but-deterministic [`ScenarioSpec`]s
//! from a bare `u64` seed.
//!
//! Hand-authored packs stop covering the scheduler's state space once faults,
//! autoscale decisions, and admission maturation interleave freely; the fuzzer
//! samples that space mechanically and the `testkit::oracle` invariant battery
//! checks every sampled execution. Determinism contract: same seed ⇒
//! byte-identical spec JSON (and therefore, via the record→replay ratchet,
//! byte-identical trace). The generator draws exclusively from
//! [`SplitMix64`] — no global state, no time, no environment.
//!
//! Every drawn value is chosen to survive the JSON text round-trip exactly
//! (integers, and f64s that are small dyadic rationals), and every spec
//! passes [`ScenarioSpec::validate`] by construction: factor menus sit inside
//! the validated ranges, catalogs keep at least one node per pool, and the
//! run seed stays below the 2^53 JSON-exactness bound.

use crate::autoscale::{AutoscaleCfg, PolicyKind};
use crate::lanes::CostModel;
use crate::rollout::workloads::{CatalogCfg, WorkloadKind};
use crate::scenario::{ScenarioEvent, ScenarioSpec, TenantMix, TimedEvent};
use crate::sim::{SimDur, SimTime};
use crate::util::rng::SplitMix64;
use std::collections::BTreeMap;

/// Pool-fault factors (cpu/gpu): must lie in the validated [0.05, 1] band.
const POOL_FACTORS: [f64; 6] = [0.125, 0.25, 0.375, 0.5, 0.75, 1.0];
/// API limit factors: validated band is [0.01, 10]; we stay ≤ 1 so the
/// oracle's provision-cap invariant (`units ≤ baseline`) holds unweakened.
const API_FACTORS: [f64; 4] = [0.125, 0.25, 0.5, 1.0];
/// Autoscale floors: validated band is [0.05, 1].
const MIN_FACTORS: [f64; 4] = [0.125, 0.25, 0.375, 0.5];
/// $/unit-hour menu: eighths, exact in f64 and in JSON text.
const RATE_MENU: [f64; 6] = [0.125, 0.25, 0.5, 1.0, 2.5, 4.0];

/// Generate the deterministic fuzz spec for `seed`.
pub fn fuzz_spec(seed: u64) -> ScenarioSpec {
    // Salt so fuzz case N doesn't share a stream prefix with run seed N.
    let mut r = SplitMix64::new(seed ^ 0x5EED_F022_D1CE_0001);

    let kinds = [WorkloadKind::Coding, WorkloadKind::DeepSearch, WorkloadKind::Mopd];
    let n_workloads = r.range(1, 3) as usize;
    let workloads: Vec<WorkloadKind> = (0..n_workloads).map(|_| *r.pick(&kinds)).collect();

    let catalog = CatalogCfg {
        cpu_nodes: r.range(1, 3) as u32,
        cores_per_node: *r.pick(&[16u32, 32, 64]),
        gpu_nodes: r.range(1, 3) as u32,
        n_teachers: r.range(2, 4) as u32,
        n_search_endpoints: r.range(1, 3) as u32,
        ..CatalogCfg::default()
    };

    let n_events = r.range(0, 4);
    let events: Vec<TimedEvent> = (0..n_events)
        .map(|_| {
            let at = SimTime(SimDur::from_secs(r.range(1, 25)).0);
            let event = match r.range(0, 3) {
                0 => ScenarioEvent::ApiLimitScale { factor: *r.pick(&API_FACTORS) },
                1 => ScenarioEvent::GpuCacheFlush,
                2 => ScenarioEvent::GpuPoolScale { factor: *r.pick(&POOL_FACTORS) },
                _ => ScenarioEvent::CpuPoolScale { factor: *r.pick(&POOL_FACTORS) },
            };
            TimedEvent { at, event }
        })
        .collect();

    let autoscale = if r.chance(1, 2) {
        Some(AutoscaleCfg {
            policy: if r.chance(1, 2) { PolicyKind::Queue } else { PolicyKind::Ewma },
            interval: SimDur::from_secs(r.range(1, 3)),
            min_factor: *r.pick(&MIN_FACTORS),
            down_hold: SimDur::from_secs(r.range(4, 10)),
            cpu_warmup: SimDur::from_secs(r.range(0, 5)),
            gpu_warmup: SimDur::from_secs(r.range(0, 5)),
            api_warmup: SimDur::from_secs(r.range(0, 3)),
            admission: r.chance(1, 2),
            ..AutoscaleCfg::default()
        })
    } else {
        None
    };

    let cost = if r.chance(1, 2) {
        let mut rates = BTreeMap::new();
        for pool in ["cpu_cores", "gpus", "api_lanes"] {
            if r.chance(2, 3) {
                rates.insert(pool.to_string(), *r.pick(&RATE_MENU));
            }
        }
        if r.chance(1, 3) {
            // per-endpoint override on a real search-endpoint kind id: the
            // registry assigns cpu_cores=0, gpu_units=1, then search-N from 2
            let e = 2 + r.range(0, catalog.n_search_endpoints.saturating_sub(1) as u64);
            rates.insert(format!("api_lanes@{e}"), *r.pick(&RATE_MENU));
        }
        Some(CostModel { rates, default_rate: *r.pick(&RATE_MENU) })
    } else {
        None
    };

    // Multi-tenant fork: drawn from a separately-salted stream so the base
    // spec for a given seed keeps its exact bytes — a multi-tenant fuzz case
    // is its single-tenant twin with the same workloads re-homed to tenant 0
    // plus 1–2 extra tenants under random WFQ weights and arrival phases.
    let mut tr = SplitMix64::new(seed ^ 0x5EED_F022_D1CE_0002);
    let (workloads, tenants) = if tr.chance(1, 2) {
        let mut tenants = vec![TenantMix {
            id: 0,
            weight: tr.range(1, 4) as u32,
            workloads,
            phase: SimDur::ZERO,
        }];
        for id in 1..=tr.range(1, 2) as u32 {
            tenants.push(TenantMix {
                id,
                weight: tr.range(1, 4) as u32,
                workloads: (0..tr.range(1, 2)).map(|_| *tr.pick(&kinds)).collect(),
                phase: SimDur::from_secs(tr.range(0, 10)),
            });
        }
        (vec![], tenants)
    } else {
        (workloads, vec![])
    };

    let mut spec = ScenarioSpec {
        name: format!("fuzz-{seed}"),
        workloads,
        batch: r.range(4, 12) as usize,
        steps: r.range(1, 2) as u32,
        seed: r.range(0, u32::MAX as u64),
        arrival_spread: SimDur::from_secs(r.range(0, 8)),
        catalog,
        events,
        autoscale,
        cost,
        tenants,
    };

    // Scale fork: a separately-salted stream (the base spec for a seed keeps
    // its exact bytes) occasionally doubles the spec through the same
    // `ScenarioSpec::scale` path the CLI's `--scale` uses, so the oracle
    // battery exercises scale-multiplied catalogs and batches too.
    let mut sr = SplitMix64::new(seed ^ 0x5EED_F022_D1CE_0003);
    if sr.chance(1, 8) {
        spec.scale(2);
    }
    spec
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_spec() {
        for seed in 0..64 {
            let a = fuzz_spec(seed);
            let b = fuzz_spec(seed);
            assert_eq!(a.to_json().to_string(), b.to_json().to_string(), "seed {seed}");
        }
    }

    #[test]
    fn every_fuzz_spec_validates_and_round_trips() {
        for seed in 0..256 {
            let spec = fuzz_spec(seed);
            spec.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            let text = spec.to_json().to_string();
            let back = ScenarioSpec::from_json(&text).unwrap();
            assert_eq!(back.to_json().to_string(), text, "seed {seed} round-trip drifted");
        }
    }

    #[test]
    fn seeds_explore_the_space() {
        // coarse coverage: across a small window the fuzzer must produce
        // specs with and without events / autoscale / cost
        let specs: Vec<ScenarioSpec> = (0..64).map(fuzz_spec).collect();
        assert!(specs.iter().any(|s| !s.events.is_empty()));
        assert!(specs.iter().any(|s| s.events.is_empty()));
        assert!(specs.iter().any(|s| s.autoscale.is_some()));
        assert!(specs.iter().any(|s| s.autoscale.is_none()));
        assert!(specs.iter().any(|s| s.cost.is_some()));
        assert!(specs.iter().any(|s| s.cost.is_none()));
        assert!(specs.iter().any(|s| s.autoscale.as_ref().is_some_and(|a| a.admission)));
        // tenancy: both single- and multi-tenant shapes appear, and every
        // multi-tenant spec yields non-trivial weights somewhere in the window
        assert!(specs.iter().any(|s| s.tenants.is_empty()));
        assert!(specs.iter().any(|s| s.tenants.len() >= 2));
        assert!(specs
            .iter()
            .any(|s| s.tenants.iter().any(|t| t.weight > 1)));
        assert!(specs
            .iter()
            .any(|s| s.tenants.iter().any(|t| t.phase > SimDur::ZERO)));
    }

    #[test]
    fn scale_fork_is_salted_and_applies() {
        // replays the fork's own stream: which seeds in the window scaled
        let scaled: Vec<u64> = (0..64)
            .filter(|&s| SplitMix64::new(s ^ 0x5EED_F022_D1CE_0003).chance(1, 8))
            .collect();
        assert!(!scaled.is_empty(), "no scale-multiplied specs in the window");
        assert!(scaled.len() < 32, "the scale fork must stay the rare case");
        for &s in &scaled {
            let spec = fuzz_spec(s);
            spec.validate().unwrap_or_else(|e| panic!("scaled seed {s}: {e}"));
            // base batch is 4..=12; the ×2 scale leaves an even batch ≥ 8
            assert!(
                spec.batch >= 8 && spec.batch % 2 == 0,
                "seed {s}: scale fork did not fire (batch {})",
                spec.batch
            );
        }
    }
}
