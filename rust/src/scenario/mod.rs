//! Scenario packs + deterministic trace-record/replay (the repo's quality
//! ratchet for scheduler changes).
//!
//! The paper's evaluation covers three calibrated tasks; production-grade
//! confidence needs *many* workload shapes. This subsystem makes workload
//! composition declarative and every run auditable:
//!
//! * [`ScenarioSpec`] — a JSON-loadable description of an experiment
//!   scenario: workload mix, batch/steps/seed, arrival spread (thundering
//!   herd vs staggered), cluster catalog scale, and a timeline of
//!   [`ScenarioEvent`] fault injections (API rate-limit flaps, GPU
//!   restore-storms via cache flush, CPU pool squeezes).
//! * [`trace`] — a [`TraceRecorder`] hooked into the DES driver captures
//!   every scheduling decision as a compact JSONL stream.
//! * [`replay`] — re-runs a recorded scenario and **byte-diffs** the
//!   serialized metrics and the decision trace, failing loudly on any
//!   divergence; `arl-tangram scenario --record/--replay` exposes this on
//!   the CLI.
//! * [`packs`] — named built-in scenarios exercised by the conformance
//!   suite across every backend.
//!
//! Determinism contract: same spec + same seed ⇒ byte-identical metrics
//! JSON and trace, *across processes*. Everything on the decision path
//! iterates in sorted order (see `TangramBackend::all_pools`,
//! `StaticGpu::drain_started`, and the sparse-DP frontier ordering in
//! `scheduler::dp`).

pub mod fuzz;
pub mod packs;
pub mod replay;
pub mod trace;

pub use fuzz::fuzz_spec;
pub use packs::{builtin_packs, million_action_pack, pack_by_name, pack_description};
pub use replay::{
    ab_compare, build_backend, diff_summaries, diff_traces, parse_trace_file, read_trace_file,
    replay_trace, replay_trace_sharded, replay_trace_threaded, resolved_cost_rates, run_scenario,
    run_scenario_sharded, run_scenario_tangram, run_scenario_tangram_sharded,
    run_scenario_tangram_threaded, run_scenario_threaded, summary_json, trace_file_contents,
    trace_pool_stats, trace_tenant_stats, write_trace_file, AbReport, AbRow, AbTenantRow,
    RecordedTrace, ReplayReport, ScenarioOutcome, SchedStats, TracePoolStats, TraceTenantStats,
};
pub use trace::{TraceEvent, TraceKind, TraceRecorder};

use crate::action::{TaskId, TenantId};
use crate::autoscale::{AutoscaleCfg, PoolClass};
use crate::config::BackendKind;
use crate::lanes::CostModel;
use crate::coordinator::RunCfg;
use crate::rollout::workloads::{CatalogCfg, Workload, WorkloadKind};
use crate::sim::{SimDur, SimTime};
use crate::util::error::Result;
use crate::util::json::Json;
use crate::{bail, err};

/// A mid-run perturbation delivered to the backend at a scheduled instant.
///
/// Backends apply what their substrate supports and ignore the rest (the
/// static baselines are *deliberately* inelastic — that asymmetry is the
/// paper's point); the trace records whether each injection was applied.
#[derive(Debug, Clone, PartialEq)]
pub enum ScenarioEvent {
    /// Scale every API endpoint's provider limits (concurrency + window
    /// quota) to `factor` × their spec baseline. `factor < 1` models a
    /// rate-limit flap; `1.0` restores the original limits.
    ApiLimitScale { factor: f64 },
    /// Drop all warm GPU service caches: the next allocation of every
    /// (service, DoP) variant pays a cold restore (a restore-storm follows
    /// under MOPD-style bursts).
    GpuCacheFlush,
    /// Resize the GPU pool mid-run: cordon whole GPU nodes coldest-first
    /// (EOE-residency-aware; busy chunks are never preempted, at least one
    /// node stays online) so only ~`factor` of the nodes keep taking work.
    /// `1.0` restores cordoned nodes — with flushed caches, so restored
    /// capacity re-warms through the ordinary cache-miss path. Composes
    /// (product) with any autoscaler `PoolClass::Gpu` factor.
    GpuPoolScale { factor: f64 },
    /// Resize the CPU pool mid-run: cordon cores on every node so only
    /// `factor` of each node's cores stay schedulable (best-effort — busy
    /// cores are not preempted; at least one core per node stays online).
    /// `1.0` returns cordoned cores to the pool.
    CpuPoolScale { factor: f64 },
}

impl ScenarioEvent {
    /// The class-wide fault factor this event pushes into an elastic lane
    /// (`lanes::ElasticLane::set_fault`), or `None` for events that are
    /// not pool-scale faults (a cache flush drops residencies, never
    /// capacity). Backends route these generically instead of matching per
    /// class.
    pub fn pool_fault(&self) -> Option<(PoolClass, f64)> {
        match self {
            ScenarioEvent::ApiLimitScale { factor } => Some((PoolClass::Api, *factor)),
            ScenarioEvent::GpuPoolScale { factor } => Some((PoolClass::Gpu, *factor)),
            ScenarioEvent::CpuPoolScale { factor } => Some((PoolClass::Cpu, *factor)),
            ScenarioEvent::GpuCacheFlush => None,
        }
    }

    /// Human-readable one-liner (trace + CLI reporting).
    pub fn describe(&self) -> String {
        match self {
            ScenarioEvent::ApiLimitScale { factor } => format!("api_limit_scale {factor}"),
            ScenarioEvent::GpuCacheFlush => "gpu_cache_flush".to_string(),
            ScenarioEvent::GpuPoolScale { factor } => format!("gpu_pool_scale {factor}"),
            ScenarioEvent::CpuPoolScale { factor } => format!("cpu_pool_scale {factor}"),
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            ScenarioEvent::ApiLimitScale { factor } => Json::obj(vec![
                ("kind", Json::str("api_limit_scale")),
                ("factor", Json::num(*factor)),
            ]),
            ScenarioEvent::GpuCacheFlush => {
                Json::obj(vec![("kind", Json::str("gpu_cache_flush"))])
            }
            ScenarioEvent::GpuPoolScale { factor } => Json::obj(vec![
                ("kind", Json::str("gpu_pool_scale")),
                ("factor", Json::num(*factor)),
            ]),
            ScenarioEvent::CpuPoolScale { factor } => Json::obj(vec![
                ("kind", Json::str("cpu_pool_scale")),
                ("factor", Json::num(*factor)),
            ]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| err!("scenario event missing 'kind'"))?;
        let factor = || {
            j.get("factor")
                .and_then(Json::as_f64)
                .ok_or_else(|| err!("scenario event '{kind}' missing 'factor'"))
        };
        Ok(match kind {
            "api_limit_scale" => ScenarioEvent::ApiLimitScale { factor: factor()? },
            "gpu_cache_flush" => ScenarioEvent::GpuCacheFlush,
            "gpu_pool_scale" => ScenarioEvent::GpuPoolScale { factor: factor()? },
            "cpu_pool_scale" => ScenarioEvent::CpuPoolScale { factor: factor()? },
            other => bail!("unknown scenario event kind '{other}'"),
        })
    }
}

/// A [`ScenarioEvent`] pinned to a virtual-time instant.
#[derive(Debug, Clone, PartialEq)]
pub struct TimedEvent {
    pub at: SimTime,
    pub event: ScenarioEvent,
}

/// One tenant (training job) in a multi-tenant scenario: its action-level
/// WFQ weight at the lane queues, its workload mix, and its arrival phase.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantMix {
    /// Tenant id carried on every action (ids strictly increasing across
    /// the `tenants` array; 0 is the implicit tenant of single-tenant
    /// specs).
    pub id: u32,
    /// Weighted-fair-queueing weight (≥ 1). All-equal weights make WFQ
    /// order indistinguishable from FCFS on a per-tenant basis.
    pub weight: u32,
    /// The tenant's workload mix; task ids are assigned by global position
    /// across the concatenated tenant mixes.
    pub workloads: Vec<WorkloadKind>,
    /// Arrival phase: the tenant's first step starts this far into the run
    /// (models a job joining a busy shared deployment).
    pub phase: SimDur,
}

/// Declarative scenario description (JSON-loadable via `util::json`).
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub name: String,
    /// Workload mix; task ids are assigned by position. Mutually exclusive
    /// with `tenants` (single-tenant shorthand — every workload belongs to
    /// the implicit tenant 0).
    pub workloads: Vec<WorkloadKind>,
    /// Multi-tenant workload mixes (empty = single-tenant; the key is then
    /// omitted from the serialized spec, keeping legacy bytes identical).
    pub tenants: Vec<TenantMix>,
    pub batch: usize,
    pub steps: u32,
    pub seed: u64,
    /// Spread each step's trajectory arrivals uniformly over this window
    /// (ZERO = the thundering-herd batch arrival the paper measures).
    pub arrival_spread: SimDur,
    /// External-world scale (cluster nodes, teachers, endpoints).
    pub catalog: CatalogCfg,
    /// Fault-injection timeline.
    pub events: Vec<TimedEvent>,
    /// Elastic pool autoscaler (None = static provisioning). Embedded in
    /// the spec so recorded traces replay with the same scaling decisions.
    pub autoscale: Option<AutoscaleCfg>,
    /// $/unit-hour rate card (None = unit-hours only). Embedded in the
    /// spec — and therefore in recorded traces — so replays reproduce the
    /// cost figures byte-for-byte. Pure reporting: never influences a
    /// scheduling or scaling decision.
    pub cost: Option<CostModel>,
}

fn workload_kind_parse(s: &str) -> Result<WorkloadKind> {
    WorkloadKind::parse(s).ok_or_else(|| err!("unknown workload '{s}'"))
}

fn tenant_mix_from_json(j: &Json) -> Result<TenantMix> {
    let obj = j.as_obj().ok_or_else(|| err!("tenant mix must be an object"))?;
    let mut t = TenantMix { id: 0, weight: 1, workloads: vec![], phase: SimDur::ZERO };
    for (k, v) in obj {
        match k.as_str() {
            "id" => {
                t.id = v.as_u64().ok_or_else(|| err!("tenant 'id' must be an integer"))? as u32
            }
            "weight" => {
                t.weight =
                    v.as_u64().ok_or_else(|| err!("tenant 'weight' must be an integer"))? as u32
            }
            "workloads" => {
                t.workloads = v
                    .as_arr()
                    .ok_or_else(|| err!("tenant 'workloads' must be an array"))?
                    .iter()
                    .map(|w| {
                        workload_kind_parse(
                            w.as_str().ok_or_else(|| err!("workload must be a string"))?,
                        )
                    })
                    .collect::<Result<_>>()?
            }
            "phase_secs" => {
                let s =
                    v.as_f64().ok_or_else(|| err!("tenant 'phase_secs' must be a number"))?;
                if s < 0.0 {
                    bail!("tenant 'phase_secs' must be non-negative");
                }
                t.phase = SimDur::from_secs_f64(s);
            }
            other => bail!("unknown tenant key '{other}'"),
        }
    }
    Ok(t)
}

fn catalog_to_json(c: &CatalogCfg) -> Json {
    Json::obj(vec![
        ("cpu_nodes", Json::num(c.cpu_nodes as f64)),
        ("cores_per_node", Json::num(c.cores_per_node as f64)),
        ("gpu_nodes", Json::num(c.gpu_nodes as f64)),
        ("n_teachers", Json::num(c.n_teachers as f64)),
        ("teacher_gb", Json::num(c.teacher_gb)),
        ("judge_gb", Json::num(c.judge_gb)),
        ("n_search_endpoints", Json::num(c.n_search_endpoints as f64)),
    ])
}

fn catalog_from_json(j: &Json) -> Result<CatalogCfg> {
    let mut c = CatalogCfg::default();
    let obj = j.as_obj().ok_or_else(|| err!("'catalog' must be an object"))?;
    for (k, v) in obj {
        let u = || v.as_u64().ok_or_else(|| err!("catalog key '{k}' must be an integer"));
        let f = || v.as_f64().ok_or_else(|| err!("catalog key '{k}' must be a number"));
        match k.as_str() {
            "cpu_nodes" => c.cpu_nodes = u()? as u32,
            "cores_per_node" => c.cores_per_node = u()? as u32,
            "gpu_nodes" => c.gpu_nodes = u()? as u32,
            "n_teachers" => c.n_teachers = u()? as u32,
            "teacher_gb" => c.teacher_gb = f()?,
            "judge_gb" => c.judge_gb = f()?,
            "n_search_endpoints" => c.n_search_endpoints = u()? as u32,
            other => bail!("unknown catalog key '{other}'"),
        }
    }
    Ok(c)
}

impl ScenarioSpec {
    /// Which workload kinds a backend composition can execute at all (the
    /// baselines are single-purpose deployments, §6.1).
    pub fn backend_supports(backend: BackendKind, kind: WorkloadKind) -> bool {
        match backend {
            BackendKind::Tangram => true,
            BackendKind::K8s => kind == WorkloadKind::Coding,
            // static multi-service deployment: judge + teachers + APIs
            BackendKind::StaticGpu => {
                matches!(kind, WorkloadKind::DeepSearch | WorkloadKind::Mopd)
            }
            // GPU pool only — no CPU environments, no API client
            BackendKind::Serverless => kind == WorkloadKind::Mopd,
            // unmanaged APIs + judge service
            BackendKind::Unmanaged => kind == WorkloadKind::DeepSearch,
        }
    }

    /// The scenario's effective workload mix with owning tenant and arrival
    /// phase per entry: the top-level `workloads` under the implicit tenant
    /// 0, or the concatenation of the per-tenant mixes. Task ids are
    /// assigned by position in this flattened order.
    fn flat_workloads(&self) -> Vec<(WorkloadKind, u32, SimDur)> {
        if self.tenants.is_empty() {
            self.workloads.iter().map(|&k| (k, 0, SimDur::ZERO)).collect()
        } else {
            self.tenants
                .iter()
                .flat_map(|t| t.workloads.iter().map(|&k| (k, t.id, t.phase)))
                .collect()
        }
    }

    /// The subset of this scenario's workload mix the backend supports,
    /// with task ids stable across backends (assigned by flattened mix
    /// position) and tenant/phase carried onto each workload.
    pub fn workloads_for(&self, backend: BackendKind) -> Vec<Workload> {
        self.flat_workloads()
            .into_iter()
            .enumerate()
            .filter(|&(_, (k, _, _))| Self::backend_supports(backend, k))
            .map(|(i, (k, tenant, phase))| {
                let mut w = Workload::new(TaskId(i as u32), k);
                w.tenant = TenantId(tenant);
                w.phase = phase;
                w
            })
            .collect()
    }

    /// Per-tenant WFQ weights for [`crate::coordinator::Session`]
    /// (empty on single-tenant specs — every queue then stays at the
    /// FCFS-equivalent default weight).
    pub fn tenant_weights(&self) -> Vec<(u32, u32)> {
        self.tenants.iter().map(|t| (t.id, t.weight)).collect()
    }

    /// Driver configuration for this scenario.
    pub fn run_cfg(&self) -> RunCfg {
        RunCfg {
            batch: self.batch,
            steps: self.steps,
            seed: self.seed,
            arrival_spread: self.arrival_spread,
            ..RunCfg::default()
        }
    }

    /// Multiply the scenario's size by `factor`: cluster nodes, GPU
    /// services, API endpoints, and the per-step trajectory batch all
    /// scale together, so the workload grows with the deployment instead
    /// of drowning a fixed one. `--scale N` on the CLI and the fuzzer's
    /// scaled specs go through here. Only existing numeric fields change —
    /// a scaled spec serializes with the same JSON shape, so recorded
    /// traces replay exactly (the factor itself is never serialized).
    pub fn scale(&mut self, factor: u32) {
        let f = factor.max(1);
        self.catalog.cpu_nodes = self.catalog.cpu_nodes.saturating_mul(f);
        self.catalog.gpu_nodes = self.catalog.gpu_nodes.saturating_mul(f);
        self.catalog.n_teachers = self.catalog.n_teachers.saturating_mul(f);
        self.catalog.n_search_endpoints = self.catalog.n_search_endpoints.saturating_mul(f);
        self.batch = self.batch.saturating_mul(f as usize);
    }

    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty() {
            bail!("scenario needs a name");
        }
        if self.workloads.is_empty() && self.tenants.is_empty() {
            bail!("scenario '{}' has no workloads", self.name);
        }
        if !self.tenants.is_empty() {
            if !self.workloads.is_empty() {
                bail!(
                    "scenario '{}': declare workloads under 'tenants' or at top level, not both",
                    self.name
                );
            }
            let mut prev: Option<u32> = None;
            for t in &self.tenants {
                if prev.is_some_and(|p| t.id <= p) {
                    bail!("scenario '{}': tenant ids must be strictly increasing", self.name);
                }
                prev = Some(t.id);
                if t.weight == 0 {
                    bail!("scenario '{}': tenant {} weight must be ≥ 1", self.name, t.id);
                }
                if t.workloads.is_empty() {
                    bail!("scenario '{}': tenant {} has no workloads", self.name, t.id);
                }
            }
        }
        if self.batch == 0 || self.steps == 0 {
            bail!("scenario '{}': batch and steps must be positive", self.name);
        }
        // the spec round-trips through JSON numbers (f64): seeds above 2^53
        // would record rounded and replay a different RNG stream
        if self.seed > (1u64 << 53) {
            bail!("scenario '{}': seed must be ≤ 2^53 (JSON round-trip)", self.name);
        }
        if self.catalog.cpu_nodes == 0 || self.catalog.gpu_nodes == 0 {
            bail!("scenario '{}': cluster must have nodes", self.name);
        }
        if let Some(asc) = &self.autoscale {
            asc.validate()?;
        }
        if let Some(cost) = &self.cost {
            cost.validate()?;
        }
        for te in &self.events {
            match te.event {
                ScenarioEvent::ApiLimitScale { factor } => {
                    if !(0.01..=10.0).contains(&factor) {
                        bail!("api_limit_scale factor {factor} out of [0.01, 10]");
                    }
                }
                ScenarioEvent::CpuPoolScale { factor } => {
                    if !(0.05..=1.0).contains(&factor) {
                        bail!("cpu_pool_scale factor {factor} out of [0.05, 1]");
                    }
                }
                ScenarioEvent::GpuPoolScale { factor } => {
                    if !(0.05..=1.0).contains(&factor) {
                        bail!("gpu_pool_scale factor {factor} out of [0.05, 1]");
                    }
                }
                ScenarioEvent::GpuCacheFlush => {}
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::str(self.name.clone())),
            (
                "workloads",
                Json::arr(self.workloads.iter().map(|w| Json::str(w.name()))),
            ),
            ("batch", Json::num(self.batch as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("arrival_spread_secs", Json::num(self.arrival_spread.secs_f64())),
            ("catalog", catalog_to_json(&self.catalog)),
            (
                "events",
                Json::arr(self.events.iter().map(|te| {
                    let mut o = match te.event.to_json() {
                        Json::Obj(m) => m,
                        _ => unreachable!("event json is an object"),
                    };
                    o.insert("at_secs".into(), Json::num(te.at.secs_f64()));
                    Json::Obj(o)
                })),
            ),
        ];
        if let Some(asc) = &self.autoscale {
            pairs.push(("autoscale", asc.to_json()));
        }
        if let Some(cost) = &self.cost {
            pairs.push(("cost", cost.to_json()));
        }
        // the tenants key appears ONLY on multi-tenant specs, so every
        // legacy single-tenant spec keeps its exact bytes
        if !self.tenants.is_empty() {
            pairs.push((
                "tenants",
                Json::arr(self.tenants.iter().map(|t| {
                    Json::obj(vec![
                        ("id", Json::num(t.id as f64)),
                        ("weight", Json::num(t.weight as f64)),
                        (
                            "workloads",
                            Json::arr(t.workloads.iter().map(|w| Json::str(w.name()))),
                        ),
                        ("phase_secs", Json::num(t.phase.secs_f64())),
                    ])
                })),
            ));
        }
        Json::obj(pairs)
    }

    pub fn from_json_value(j: &Json) -> Result<Self> {
        let obj = j.as_obj().ok_or_else(|| err!("scenario spec must be an object"))?;
        let mut spec = ScenarioSpec {
            name: String::new(),
            workloads: vec![],
            batch: 16,
            steps: 1,
            seed: 42,
            arrival_spread: SimDur::ZERO,
            catalog: CatalogCfg::default(),
            events: vec![],
            autoscale: None,
            cost: None,
            tenants: vec![],
        };
        for (k, v) in obj {
            match k.as_str() {
                "name" => {
                    spec.name = v
                        .as_str()
                        .ok_or_else(|| err!("'name' must be a string"))?
                        .to_string()
                }
                "workloads" => {
                    spec.workloads = v
                        .as_arr()
                        .ok_or_else(|| err!("'workloads' must be an array"))?
                        .iter()
                        .map(|w| {
                            workload_kind_parse(
                                w.as_str().ok_or_else(|| err!("workload must be a string"))?,
                            )
                        })
                        .collect::<Result<_>>()?
                }
                "batch" => {
                    spec.batch =
                        v.as_u64().ok_or_else(|| err!("'batch' must be an integer"))? as usize
                }
                "steps" => {
                    spec.steps =
                        v.as_u64().ok_or_else(|| err!("'steps' must be an integer"))? as u32
                }
                "seed" => {
                    spec.seed = v.as_u64().ok_or_else(|| err!("'seed' must be an integer"))?
                }
                "arrival_spread_secs" => {
                    let s = v.as_f64().ok_or_else(|| err!("'arrival_spread_secs' must be a number"))?;
                    if s < 0.0 {
                        bail!("'arrival_spread_secs' must be non-negative");
                    }
                    spec.arrival_spread = SimDur::from_secs_f64(s);
                }
                "catalog" => spec.catalog = catalog_from_json(v)?,
                "autoscale" => spec.autoscale = Some(AutoscaleCfg::from_json(v)?),
                "cost" => spec.cost = Some(CostModel::from_json(v)?),
                "tenants" => {
                    spec.tenants = v
                        .as_arr()
                        .ok_or_else(|| err!("'tenants' must be an array"))?
                        .iter()
                        .map(tenant_mix_from_json)
                        .collect::<Result<_>>()?
                }
                "events" => {
                    spec.events = v
                        .as_arr()
                        .ok_or_else(|| err!("'events' must be an array"))?
                        .iter()
                        .map(|e| {
                            let at = e
                                .get("at_secs")
                                .and_then(Json::as_f64)
                                .ok_or_else(|| err!("event missing 'at_secs'"))?;
                            if at < 0.0 {
                                bail!("event 'at_secs' must be non-negative");
                            }
                            Ok(TimedEvent {
                                at: SimTime(SimDur::from_secs_f64(at).0),
                                event: ScenarioEvent::from_json(e)?,
                            })
                        })
                        .collect::<Result<_>>()?
                }
                other => bail!("unknown scenario key '{other}'"),
            }
        }
        spec.validate()?;
        Ok(spec)
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| err!("scenario spec: {e}"))?;
        Self::from_json_value(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_packs_validate_and_round_trip() {
        for spec in builtin_packs() {
            spec.validate().unwrap();
            let j = spec.to_json().to_string();
            let back = ScenarioSpec::from_json(&j).unwrap();
            assert_eq!(back.to_json().to_string(), j, "round trip for '{}'", spec.name);
        }
    }

    #[test]
    fn spec_json_rejects_garbage() {
        assert!(ScenarioSpec::from_json("{}").is_err()); // no name/workloads
        assert!(ScenarioSpec::from_json(r#"{"name":"x","workloads":["nope"]}"#).is_err());
        assert!(ScenarioSpec::from_json(
            r#"{"name":"x","workloads":["coding"],"events":[{"kind":"warp_drive","at_secs":1}]}"#
        )
        .is_err());
        assert!(ScenarioSpec::from_json(
            r#"{"name":"x","workloads":["coding"],"events":[{"kind":"cpu_pool_scale","factor":0.0,"at_secs":1}]}"#
        )
        .is_err());
    }

    #[test]
    fn capability_matrix() {
        use BackendKind::*;
        assert!(ScenarioSpec::backend_supports(Tangram, WorkloadKind::Coding));
        assert!(ScenarioSpec::backend_supports(K8s, WorkloadKind::Coding));
        assert!(!ScenarioSpec::backend_supports(K8s, WorkloadKind::Mopd));
        assert!(ScenarioSpec::backend_supports(StaticGpu, WorkloadKind::DeepSearch));
        assert!(!ScenarioSpec::backend_supports(Serverless, WorkloadKind::DeepSearch));
        assert!(ScenarioSpec::backend_supports(Unmanaged, WorkloadKind::DeepSearch));
    }

    #[test]
    fn workloads_for_keeps_task_ids_stable() {
        let spec = pack_by_name("steady-mix").unwrap();
        let all = spec.workloads_for(BackendKind::Tangram);
        let k8s = spec.workloads_for(BackendKind::K8s);
        assert_eq!(all.len(), spec.workloads.len());
        for w in &k8s {
            let same = all.iter().find(|a| a.task == w.task).unwrap();
            assert_eq!(same.kind, w.kind, "task ids must identify the same workload");
        }
    }

    #[test]
    fn autoscale_spec_round_trips() {
        let mut spec = pack_by_name("steady-mix").unwrap();
        spec.autoscale = Some(crate::autoscale::AutoscaleCfg {
            min_factor: 0.25,
            ..crate::autoscale::AutoscaleCfg::default()
        });
        let j = spec.to_json().to_string();
        let back = ScenarioSpec::from_json(&j).unwrap();
        assert_eq!(back.to_json().to_string(), j);
        assert_eq!(back.autoscale, spec.autoscale);
        // invalid autoscaler configs are rejected at spec load
        assert!(ScenarioSpec::from_json(
            r#"{"name":"x","workloads":["coding"],"autoscale":{"min_factor":0.001}}"#
        )
        .is_err());
    }

    #[test]
    fn cost_model_round_trips_through_the_spec() {
        let mut spec = pack_by_name("coldstart-storm").unwrap();
        spec.cost = Some(CostModel::default());
        let j = spec.to_json().to_string();
        assert!(j.contains("\"cost\""));
        let back = ScenarioSpec::from_json(&j).unwrap();
        assert_eq!(back.cost, spec.cost);
        assert_eq!(back.to_json().to_string(), j);
        // a spec without a cost model keeps its pre-cost bytes (the static
        // golden-trace compatibility invariant)
        let plain = pack_by_name("coldstart-storm").unwrap();
        assert!(!plain.to_json().to_string().contains("\"cost\""));
        // invalid rate cards are rejected at spec load
        assert!(ScenarioSpec::from_json(
            r#"{"name":"x","workloads":["coding"],"cost":{"gpus":-2}}"#
        )
        .is_err());
    }

    #[test]
    fn tenant_specs_round_trip_and_validate() {
        let spec = ScenarioSpec::from_json(
            r#"{"name":"t","tenants":[
                {"id":0,"weight":4,"workloads":["coding"],"phase_secs":0},
                {"id":1,"weight":1,"workloads":["mopd","deepsearch"],"phase_secs":20}
            ]}"#,
        )
        .unwrap();
        assert_eq!(spec.tenants.len(), 2);
        assert_eq!(spec.tenant_weights(), vec![(0, 4), (1, 1)]);
        let j = spec.to_json().to_string();
        assert!(j.contains("\"tenants\""));
        let back = ScenarioSpec::from_json(&j).unwrap();
        assert_eq!(back.tenants, spec.tenants);
        assert_eq!(back.to_json().to_string(), j);
        // single-tenant specs keep their legacy bytes — no tenants key
        let plain = pack_by_name("steady-mix").unwrap();
        assert!(!plain.to_json().to_string().contains("\"tenants\""));
        assert!(plain.tenant_weights().is_empty());
    }

    #[test]
    fn tenant_validation_rejects_bad_mixes() {
        // both top-level workloads and tenants
        assert!(ScenarioSpec::from_json(
            r#"{"name":"t","workloads":["coding"],"tenants":[{"id":0,"weight":1,"workloads":["coding"]}]}"#
        )
        .is_err());
        // non-increasing ids
        assert!(ScenarioSpec::from_json(
            r#"{"name":"t","tenants":[{"id":1,"weight":1,"workloads":["coding"]},{"id":1,"weight":1,"workloads":["mopd"]}]}"#
        )
        .is_err());
        // zero weight
        assert!(ScenarioSpec::from_json(
            r#"{"name":"t","tenants":[{"id":0,"weight":0,"workloads":["coding"]}]}"#
        )
        .is_err());
        // empty tenant mix
        assert!(ScenarioSpec::from_json(
            r#"{"name":"t","tenants":[{"id":0,"weight":1,"workloads":[]}]}"#
        )
        .is_err());
    }

    #[test]
    fn tenant_workloads_flatten_with_stable_task_ids() {
        let spec = ScenarioSpec::from_json(
            r#"{"name":"t","tenants":[
                {"id":0,"weight":2,"workloads":["coding","mopd"]},
                {"id":3,"weight":1,"workloads":["deepsearch"],"phase_secs":5}
            ]}"#,
        )
        .unwrap();
        let all = spec.workloads_for(BackendKind::Tangram);
        assert_eq!(all.len(), 3);
        assert_eq!(all[0].task, TaskId(0));
        assert_eq!(all[0].tenant, TenantId(0));
        assert_eq!(all[1].kind, WorkloadKind::Mopd);
        assert_eq!(all[2].task, TaskId(2));
        assert_eq!(all[2].tenant, TenantId(3));
        assert_eq!(all[2].phase, SimDur::from_secs(5));
        // capability filtering keeps flattened task ids stable
        let un = spec.workloads_for(BackendKind::Unmanaged);
        assert_eq!(un.len(), 1);
        assert_eq!(un[0].task, TaskId(2));
        assert_eq!(un[0].tenant, TenantId(3));
    }

    #[test]
    fn scale_multiplies_catalog_and_batch_but_keeps_the_shape() {
        let mut spec = pack_by_name("steady-mix").unwrap();
        let base = spec.clone();
        spec.scale(4);
        assert_eq!(spec.catalog.cpu_nodes, base.catalog.cpu_nodes * 4);
        assert_eq!(spec.catalog.gpu_nodes, base.catalog.gpu_nodes * 4);
        assert_eq!(spec.catalog.n_teachers, base.catalog.n_teachers * 4);
        assert_eq!(
            spec.catalog.n_search_endpoints,
            base.catalog.n_search_endpoints * 4
        );
        assert_eq!(spec.batch, base.batch * 4);
        // untouched knobs stay put: scaling grows the world, not the clock
        assert_eq!(spec.steps, base.steps);
        assert_eq!(spec.seed, base.seed);
        assert_eq!(spec.name, base.name);
        spec.validate().unwrap();
        // a scaled spec round-trips through JSON with the same key set —
        // the factor is a runtime knob, never a serialized field
        let j = spec.to_json().to_string();
        let back = ScenarioSpec::from_json(&j).unwrap();
        assert_eq!(back.to_json().to_string(), j);
        // factor 0/1 are identity
        let mut one = base.clone();
        one.scale(0);
        assert_eq!(one.batch, base.batch);
        assert_eq!(one.catalog.cpu_nodes, base.catalog.cpu_nodes);
    }

    #[test]
    fn pool_faults_map_events_to_lane_classes() {
        assert_eq!(
            ScenarioEvent::ApiLimitScale { factor: 0.5 }.pool_fault(),
            Some((PoolClass::Api, 0.5))
        );
        assert_eq!(
            ScenarioEvent::CpuPoolScale { factor: 0.25 }.pool_fault(),
            Some((PoolClass::Cpu, 0.25))
        );
        assert_eq!(
            ScenarioEvent::GpuPoolScale { factor: 0.5 }.pool_fault(),
            Some((PoolClass::Gpu, 0.5))
        );
        assert_eq!(ScenarioEvent::GpuCacheFlush.pool_fault(), None);
    }

    #[test]
    fn every_pack_has_a_catalog_description() {
        for p in builtin_packs() {
            assert!(
                !pack_description(&p.name).is_empty(),
                "pack '{}' has no --list description",
                p.name
            );
        }
    }

    #[test]
    fn event_descriptions_are_stable() {
        assert_eq!(
            ScenarioEvent::ApiLimitScale { factor: 0.25 }.describe(),
            "api_limit_scale 0.25"
        );
        assert_eq!(ScenarioEvent::GpuCacheFlush.describe(), "gpu_cache_flush");
        assert_eq!(
            ScenarioEvent::GpuPoolScale { factor: 0.5 }.describe(),
            "gpu_pool_scale 0.5"
        );
    }

    #[test]
    fn gpu_pool_scale_round_trips_and_validates() {
        let spec = ScenarioSpec::from_json(
            r#"{"name":"x","workloads":["mopd"],"events":[{"kind":"gpu_pool_scale","factor":0.5,"at_secs":3}]}"#,
        )
        .unwrap();
        assert_eq!(
            spec.events[0].event,
            ScenarioEvent::GpuPoolScale { factor: 0.5 }
        );
        let j = spec.to_json().to_string();
        assert_eq!(ScenarioSpec::from_json(&j).unwrap().to_json().to_string(), j);
        assert!(ScenarioSpec::from_json(
            r#"{"name":"x","workloads":["mopd"],"events":[{"kind":"gpu_pool_scale","factor":0.0,"at_secs":3}]}"#
        )
        .is_err());
    }
}
