//! Built-in scenario packs.
//!
//! Each pack is a [`ScenarioSpec`] the conformance suite runs across every
//! backend (each backend executes the subset of the mix it supports). Packs
//! are deliberately small — the DES makes them seconds-fast — while still
//! hitting the stress axes the paper motivates: workload mixing, arrival
//! bursts, API rate-limit flaps, GPU restore-storms, and mid-run CPU and
//! GPU pool squeezes. `arl-tangram scenario --list` prints this catalog.

use super::{ScenarioEvent, ScenarioSpec, TenantMix, TimedEvent};
use crate::rollout::workloads::{CatalogCfg, WorkloadKind};
use crate::sim::{SimDur, SimTime};

fn small_catalog() -> CatalogCfg {
    CatalogCfg {
        cpu_nodes: 2,
        cores_per_node: 64,
        gpu_nodes: 2,
        n_teachers: 4,
        ..CatalogCfg::default()
    }
}

fn at(secs: u64, event: ScenarioEvent) -> TimedEvent {
    TimedEvent { at: SimTime(SimDur::from_secs(secs).0), event }
}

/// All built-in packs, in catalog order.
pub fn builtin_packs() -> Vec<ScenarioSpec> {
    vec![
        // Fault-free tri-workload mix: the conformance baseline every
        // backend must reproduce bit-for-bit.
        ScenarioSpec {
            name: "steady-mix".into(),
            workloads: vec![WorkloadKind::Coding, WorkloadKind::DeepSearch, WorkloadKind::Mopd],
            batch: 10,
            steps: 1,
            seed: 101,
            arrival_spread: SimDur::ZERO,
            catalog: small_catalog(),
            events: vec![],
            autoscale: None,
            cost: None,
            tenants: vec![],
        },
        // Thundering-herd arrivals plus a mid-burst provider flap: the
        // §2.3 burstiness story with the provider fighting back.
        ScenarioSpec {
            name: "burst-arrivals".into(),
            workloads: vec![WorkloadKind::Coding, WorkloadKind::DeepSearch],
            batch: 24,
            steps: 1,
            seed: 202,
            arrival_spread: SimDur::ZERO,
            catalog: small_catalog(),
            events: vec![
                at(20, ScenarioEvent::ApiLimitScale { factor: 0.5 }),
                at(120, ScenarioEvent::ApiLimitScale { factor: 1.0 }),
            ],
            autoscale: None,
            cost: None,
            tenants: vec![],
        },
        // Repeated deep rate-limit flaps on the DeepSearch path: quota and
        // concurrency collapse to 5% of baseline, twice, so the admission
        // layer must queue and ride the quota-window wakeups.
        ScenarioSpec {
            name: "api-flap".into(),
            workloads: vec![WorkloadKind::DeepSearch],
            batch: 16,
            steps: 1,
            seed: 303,
            arrival_spread: SimDur::from_secs(5),
            catalog: small_catalog(),
            events: vec![
                at(15, ScenarioEvent::ApiLimitScale { factor: 0.05 }),
                at(60, ScenarioEvent::ApiLimitScale { factor: 1.0 }),
                at(90, ScenarioEvent::ApiLimitScale { factor: 0.05 }),
                at(150, ScenarioEvent::ApiLimitScale { factor: 1.0 }),
            ],
            autoscale: None,
            cost: None,
            tenants: vec![],
        },
        // Restore storms: warm (service, DoP) caches are dropped every few
        // tens of seconds across the reward-burst window, so teacher and
        // judge invocations keep paying cold restores.
        ScenarioSpec {
            name: "restore-storm".into(),
            workloads: vec![WorkloadKind::Mopd, WorkloadKind::DeepSearch],
            batch: 12,
            steps: 1,
            seed: 404,
            arrival_spread: SimDur::ZERO,
            catalog: small_catalog(),
            events: vec![
                at(10, ScenarioEvent::GpuCacheFlush),
                at(30, ScenarioEvent::GpuCacheFlush),
                at(50, ScenarioEvent::GpuCacheFlush),
                at(70, ScenarioEvent::GpuCacheFlush),
                at(90, ScenarioEvent::GpuCacheFlush),
                at(120, ScenarioEvent::GpuCacheFlush),
                at(150, ScenarioEvent::GpuCacheFlush),
                at(180, ScenarioEvent::GpuCacheFlush),
                at(240, ScenarioEvent::GpuCacheFlush),
                at(300, ScenarioEvent::GpuCacheFlush),
            ],
            autoscale: None,
            cost: None,
            tenants: vec![],
        },
        // Mid-run CPU pool squeeze: half of every node's cores cordon off
        // at t=20s and return at t=100s (elastic-pool resizing; Mopd rides
        // along so the GPU-only serverless baseline is exercised too).
        ScenarioSpec {
            name: "pool-squeeze".into(),
            workloads: vec![WorkloadKind::Coding, WorkloadKind::Mopd],
            batch: 16,
            steps: 1,
            seed: 505,
            arrival_spread: SimDur::from_secs(10),
            catalog: small_catalog(),
            events: vec![
                at(20, ScenarioEvent::CpuPoolScale { factor: 0.5 }),
                at(100, ScenarioEvent::CpuPoolScale { factor: 1.0 }),
            ],
            autoscale: None,
            cost: None,
            tenants: vec![],
        },
        // Serverless cold-start storm: two RL steps of coding + MOPD with
        // repeated warm-cache drops, so GPU restores keep going cold while
        // the CPU side cycles between rollout bursts and idle training
        // gaps. This is the autoscaler's A/B reference pack: run it with
        // `--autoscale` and the inter-step gaps plus the idle API lanes are
        // where the resource-hour savings live, while the storm exercises
        // scale-up latency against cold capacity.
        ScenarioSpec {
            name: "coldstart-storm".into(),
            workloads: vec![WorkloadKind::Coding, WorkloadKind::Mopd],
            batch: 16,
            steps: 2,
            seed: 606,
            arrival_spread: SimDur::from_secs(10),
            catalog: small_catalog(),
            events: vec![
                at(15, ScenarioEvent::GpuCacheFlush),
                at(45, ScenarioEvent::GpuCacheFlush),
                at(75, ScenarioEvent::GpuCacheFlush),
                at(150, ScenarioEvent::GpuCacheFlush),
                at(300, ScenarioEvent::GpuCacheFlush),
            ],
            autoscale: None,
            cost: None,
            tenants: vec![],
        },
        // Teacher-count sweep: MOPD against twice the teacher fleet on a
        // pool that cannot pin them all resident — multiplexing pressure,
        // restore churn, and scale-down safety on the long reward tail.
        ScenarioSpec {
            name: "teacher-sweep".into(),
            workloads: vec![WorkloadKind::Mopd],
            batch: 20,
            steps: 1,
            seed: 707,
            arrival_spread: SimDur::from_secs(5),
            catalog: CatalogCfg {
                cpu_nodes: 2,
                cores_per_node: 64,
                gpu_nodes: 3,
                n_teachers: 8,
                ..CatalogCfg::default()
            },
            events: vec![at(30, ScenarioEvent::GpuCacheFlush)],
            autoscale: None,
            cost: None,
            tenants: vec![],
        },
        // GPU-thrash: teacher-sweep-style arrivals under cache-flush storms
        // plus a mid-run provider-side GPU squeeze — the GPU-elasticity A/B
        // reference pack. Two RL steps with a 120s training gap and long
        // MOPD generation tails leave the teacher pool idle for most of the
        // run (Fig. 3(b): <3% static teacher-GPU activity), which is where
        // the `PoolClass::Gpu` lane's savings live; the flush storm and the
        // gpu_pool_scale flap exercise fault × resize composition (a flush
        // mid-scale-down must not cancel the autoscale factor, the fault
        // restore must not undo it) and scale-up against cold caches.
        ScenarioSpec {
            name: "gpu-thrash".into(),
            workloads: vec![WorkloadKind::Mopd],
            batch: 16,
            steps: 2,
            seed: 909,
            arrival_spread: SimDur::from_secs(8),
            catalog: CatalogCfg {
                cpu_nodes: 2,
                cores_per_node: 64,
                gpu_nodes: 3,
                n_teachers: 8,
                ..CatalogCfg::default()
            },
            events: vec![
                at(20, ScenarioEvent::GpuCacheFlush),
                at(50, ScenarioEvent::GpuCacheFlush),
                at(80, ScenarioEvent::GpuPoolScale { factor: 0.5 }),
                at(110, ScenarioEvent::GpuCacheFlush),
                at(140, ScenarioEvent::GpuPoolScale { factor: 1.0 }),
                at(200, ScenarioEvent::GpuCacheFlush),
                at(300, ScenarioEvent::GpuCacheFlush),
            ],
            autoscale: None,
            cost: None,
            tenants: vec![],
        },
        // Multi-step flap+squeeze composition: API rate-limit flaps and CPU
        // pool squeezes interleave across two RL steps, so admission rides
        // quota windows while the cordon machinery shrinks and restores the
        // environment pool mid-rollout.
        ScenarioSpec {
            name: "flap-squeeze".into(),
            workloads: vec![WorkloadKind::Coding, WorkloadKind::DeepSearch],
            batch: 12,
            steps: 2,
            seed: 808,
            arrival_spread: SimDur::from_secs(5),
            catalog: small_catalog(),
            events: vec![
                at(15, ScenarioEvent::ApiLimitScale { factor: 0.3 }),
                at(40, ScenarioEvent::CpuPoolScale { factor: 0.5 }),
                at(70, ScenarioEvent::ApiLimitScale { factor: 1.0 }),
                at(110, ScenarioEvent::CpuPoolScale { factor: 1.0 }),
                at(180, ScenarioEvent::ApiLimitScale { factor: 0.2 }),
                at(260, ScenarioEvent::ApiLimitScale { factor: 1.0 }),
            ],
            autoscale: None,
            cost: None,
            tenants: vec![],
        },
        // Two coding tenants on a deliberately small shared CPU pool: a
        // steady high-weight job (one task) vs a bursty low-weight sweep
        // (four tasks arriving 20s late). Under plain FCFS the burst buries
        // the steady tenant's queue waits; the lane WFQ keeps the steady
        // tenant's ACT near its isolated-run value — the fairness
        // differential the tenancy tests measure.
        ScenarioSpec {
            name: "tenant-fairshare".into(),
            workloads: vec![],
            batch: 10,
            steps: 1,
            seed: 1010,
            arrival_spread: SimDur::ZERO,
            catalog: CatalogCfg {
                cpu_nodes: 2,
                cores_per_node: 32,
                gpu_nodes: 1,
                n_teachers: 2,
                ..CatalogCfg::default()
            },
            events: vec![],
            autoscale: None,
            cost: None,
            tenants: vec![
                TenantMix {
                    id: 0,
                    weight: 8,
                    workloads: vec![WorkloadKind::Coding],
                    phase: SimDur::ZERO,
                },
                TenantMix {
                    id: 1,
                    weight: 1,
                    workloads: vec![
                        WorkloadKind::Coding,
                        WorkloadKind::Coding,
                        WorkloadKind::Coding,
                        WorkloadKind::Coding,
                    ],
                    phase: SimDur::from_secs(20),
                },
            ],
        },
        // A batch MOPD sweep sharing GPUs and API lanes with an interactive
        // DeepSearch job that joins 5s in at 4× weight: the cross-class
        // multi-tenant mix (teacher GPU bursts vs rate-limited API calls +
        // judge rewards) with per-tenant cost attribution across all three
        // pools.
        ScenarioSpec {
            name: "tenant-batch-interactive".into(),
            workloads: vec![],
            batch: 8,
            steps: 1,
            seed: 1111,
            arrival_spread: SimDur::ZERO,
            catalog: small_catalog(),
            events: vec![],
            autoscale: None,
            cost: None,
            tenants: vec![
                TenantMix {
                    id: 0,
                    weight: 1,
                    workloads: vec![WorkloadKind::Mopd],
                    phase: SimDur::ZERO,
                },
                TenantMix {
                    id: 1,
                    weight: 4,
                    workloads: vec![WorkloadKind::DeepSearch],
                    phase: SimDur::from_secs(5),
                },
            ],
        },
    ]
}

/// The million-action scale pack (`--pack million-action`): the throughput
/// ratchet's workload. Deliberately NOT in [`builtin_packs`] — the
/// conformance matrix, fuzz corpus, and golden set stay seconds-fast and
/// their floors unchanged — but fully addressable by name, so the CLI and
/// the bench harness run it like any other pack. Three workload classes ×
/// batch 1024 × 48 steps ≈ 150k trajectories ≈ a million-order submitted
/// action stream, on a catalog sized so queues drain instead of piling up.
pub fn million_action_pack() -> ScenarioSpec {
    ScenarioSpec {
        name: "million-action".into(),
        workloads: vec![WorkloadKind::Coding, WorkloadKind::DeepSearch, WorkloadKind::Mopd],
        batch: 1024,
        steps: 48,
        seed: 1_000_000,
        arrival_spread: SimDur::from_secs(10),
        catalog: CatalogCfg {
            cpu_nodes: 8,
            cores_per_node: 64,
            gpu_nodes: 4,
            n_teachers: 8,
            ..CatalogCfg::default()
        },
        events: vec![],
        autoscale: None,
        cost: None,
        tenants: vec![],
    }
}

/// Look up a pack by name: the built-in catalog, plus the by-name-only
/// scale packs ([`million_action_pack`]).
pub fn pack_by_name(name: &str) -> Option<ScenarioSpec> {
    builtin_packs()
        .into_iter()
        .find(|p| p.name == name)
        .or_else(|| (name == "million-action").then(million_action_pack))
}

/// One-line description per built-in pack (`scenario --list` catalog).
/// Kept OUT of [`ScenarioSpec`] on purpose: spec JSON is embedded in
/// recorded trace headers, and adding a field there would re-bless every
/// static golden trace for a cosmetic string.
pub fn pack_description(name: &str) -> &'static str {
    match name {
        "steady-mix" => "fault-free tri-workload mix — the conformance baseline",
        "burst-arrivals" => "thundering-herd arrivals with a mid-burst provider flap",
        "api-flap" => "repeated deep API rate-limit flaps on the DeepSearch path",
        "restore-storm" => "GPU cache-flush storm — every reward pays cold restores",
        "pool-squeeze" => "mid-run CPU cordon squeeze and restore",
        "coldstart-storm" => "2-step coding+MOPD under flush storms — autoscaler A/B reference",
        "teacher-sweep" => "8 teachers on a pool that cannot pin them all resident",
        "gpu-thrash" => "flush storms + GPU pool squeeze — GPU-elasticity A/B reference",
        "flap-squeeze" => "API flaps and CPU squeezes composed across two RL steps",
        "tenant-fairshare" => "steady vs bursty coding tenants on one WFQ CPU pool (8:1)",
        "tenant-batch-interactive" => "batch MOPD vs interactive DeepSearch tenants (1:4)",
        "million-action" => "million-action scale pack — the throughput ratchet's workload",
        _ => "",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BackendKind;

    #[test]
    fn lookup_works() {
        assert!(pack_by_name("api-flap").is_some());
        assert!(pack_by_name("coldstart-storm").is_some());
        assert!(pack_by_name("teacher-sweep").is_some());
        assert!(pack_by_name("flap-squeeze").is_some());
        assert!(pack_by_name("gpu-thrash").is_some());
        assert!(pack_by_name("tenant-fairshare").is_some());
        assert!(pack_by_name("tenant-batch-interactive").is_some());
        assert!(pack_by_name("nope").is_none());
        assert!(builtin_packs().len() >= 11);
    }

    #[test]
    fn million_action_pack_is_by_name_only_and_million_scale() {
        let p = pack_by_name("million-action").unwrap();
        p.validate().unwrap();
        assert!(!pack_description("million-action").is_empty());
        // the conformance matrix, fuzz corpus, and golden floors must not
        // absorb a multi-second scale pack
        assert!(
            builtin_packs().iter().all(|b| b.name != "million-action"),
            "scale packs stay out of the built-in catalog"
        );
        // million-order action stream: every trajectory submits several
        // actions, so the trajectory count alone must clear ~10^5
        let trajectories = p.workloads.len() * p.batch * p.steps as usize;
        assert!(trajectories >= 100_000, "trajectories {trajectories}");
    }

    #[test]
    fn every_backend_is_exercised_by_at_least_three_packs() {
        for backend in BackendKind::ALL {
            let n = builtin_packs()
                .iter()
                .filter(|p| !p.workloads_for(backend).is_empty())
                .count();
            assert!(n >= 3, "{backend:?} only covered by {n} packs");
        }
    }

    #[test]
    fn tenant_packs_are_multi_tenant_and_validate() {
        for name in ["tenant-fairshare", "tenant-batch-interactive"] {
            let p = pack_by_name(name).unwrap();
            p.validate().unwrap();
            assert!(p.workloads.is_empty(), "{name}: tenant packs use the tenants mix");
            assert!(p.tenants.len() >= 2, "{name}");
            assert!(
                p.tenants.iter().any(|t| t.id != 0),
                "{name}: must exercise a non-zero tenant id"
            );
            let weights = p.tenant_weights();
            assert!(
                weights.iter().any(|&(_, w)| w != weights[0].1),
                "{name}: weights must actually differ for the WFQ to matter"
            );
        }
    }
}
