//! Replay/diff engine: re-run a recorded scenario and byte-diff the result.
//!
//! A recorded trace file is self-contained JSONL:
//!
//! ```text
//! {"arl_tangram_trace":1,"backend":"tangram","spec":{…}}   ← header
//! {"at":0,"ev":"step_start",…}                             ← events …
//! {"summary":{…}}                                          ← footer
//! ```
//!
//! [`replay_trace`] rebuilds the catalog/backend from the embedded spec,
//! re-runs it under the same seed, and compares both the serialized metrics
//! summary (byte equality, including an FNV-1a digest over the *full*
//! [`Metrics::to_json`] record stream) and the decision trace event-by-
//! event. Any divergence means the scheduler is nondeterministic or its
//! behaviour drifted — both are release blockers for scale/perf PRs.

use super::trace::{TraceEvent, TraceKind, TraceRecorder};
use super::ScenarioSpec;
use crate::autoscale::Autoscaler;
use crate::baselines::{BaselineBackend, ServerlessCfg};
use crate::config::{BackendKind, ExperimentCfg};
use crate::coordinator::{run_session, Backend, Session, TangramBackend};
use crate::metrics::Metrics;
use crate::rollout::workloads::{Catalog, CatalogCfg};
use crate::sim::SimTime;
use crate::util::error::Result;
use crate::util::json::Json;
use crate::{bail, err};
use std::collections::BTreeMap;
use std::collections::HashMap;

/// Metrics + decision trace of one scenario run.
pub struct ScenarioOutcome {
    pub metrics: Metrics,
    pub events: Vec<TraceEvent>,
}

/// FNV-1a 64-bit digest (stable, dependency-free content fingerprint).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The Tangram deployment for a catalog scale — shared by [`build_backend`]
/// and [`run_scenario_tangram`] so record/replay and the differential test
/// paths always deploy identically.
fn tangram_cfg_for(catalog: &CatalogCfg) -> crate::coordinator::TangramCfg {
    ExperimentCfg { catalog: catalog.clone(), ..ExperimentCfg::default() }.tangram_cfg()
}

/// Deploy the backend composition for a catalog scale — the single
/// BackendKind→deployment matrix shared by `arl-tangram run` and the
/// scenario engine (so both commands always deploy identically).
pub fn build_backend(
    catalog: &CatalogCfg,
    cat: &Catalog,
    backend: BackendKind,
) -> Box<dyn Backend> {
    // reuse the launcher's catalog→deployment scaling rules
    let exp = ExperimentCfg { catalog: catalog.clone(), ..ExperimentCfg::default() };
    match backend {
        BackendKind::Tangram => Box::new(TangramBackend::new(cat, tangram_cfg_for(catalog))),
        BackendKind::K8s => Box::new(BaselineBackend::coding(cat, exp.k8s_cfg())),
        BackendKind::StaticGpu => Box::new(BaselineBackend::mopd_search(cat)),
        BackendKind::Serverless => Box::new(BaselineBackend::serverless(
            cat,
            ServerlessCfg { gpu_nodes: catalog.gpu_nodes, ..ServerlessCfg::default() },
        )),
        BackendKind::Unmanaged => Box::new(BaselineBackend::deepsearch(cat)),
    }
}

/// Run one scenario on one backend, recording the decision trace. When the
/// spec embeds an autoscale config, the elastic pool autoscaler runs too
/// (on inelastic baselines it observes nothing and never resizes — that
/// asymmetry is the paper's point).
pub fn run_scenario(spec: &ScenarioSpec, backend: BackendKind) -> Result<ScenarioOutcome> {
    run_scenario_sharded(spec, backend, 1)
}

/// [`run_scenario`] with an explicit drain shard count (the sharded-drain
/// contract: any count replays byte-identically; backends without a sharded
/// drain ignore it). `--shards N` on the CLI lands here.
pub fn run_scenario_sharded(
    spec: &ScenarioSpec,
    backend: BackendKind,
    shards: usize,
) -> Result<ScenarioOutcome> {
    run_scenario_threaded(spec, backend, shards, 0)
}

/// [`run_scenario_sharded`] with an explicit worker-thread count for the
/// decide half of each drain (the threaded-drain contract: plans apply in
/// ascending shard order, so any `(shards, threads)` pair replays
/// byte-identically; `0` leaves the backend's default, mirroring the shard
/// knob). `--threads N` on the CLI lands here.
pub fn run_scenario_threaded(
    spec: &ScenarioSpec,
    backend: BackendKind,
    shards: usize,
    threads: usize,
) -> Result<ScenarioOutcome> {
    spec.validate()?;
    let wls = spec.workloads_for(backend);
    if wls.is_empty() {
        bail!(
            "backend '{}' supports none of the workloads in scenario '{}'",
            backend.name(),
            spec.name
        );
    }
    let cat = Catalog::build(&spec.catalog);
    let mut be = build_backend(&spec.catalog, &cat, backend);
    let mut session = session_for(spec).with_shards(shards).with_threads(threads);
    let cfg = spec.run_cfg();
    let mut metrics = run_session(be.as_mut(), &cat, &wls, &cfg, &mut session);
    attach_cost(&mut metrics, spec, be.as_ref());
    let rec = session.take_recorder().unwrap_or_default();
    Ok(ScenarioOutcome { metrics, events: rec.events })
}

/// Build the run [`Session`] a spec describes: its fault timeline, a fresh
/// trace recorder, its embedded autoscaler (when any), and its per-tenant
/// WFQ weights (empty on single-tenant specs). The one spec→session mapping
/// shared by every scenario entry point, so record, replay, and the
/// differential tests always run under identical hooks.
fn session_for(spec: &ScenarioSpec) -> Session {
    let mut session = Session::new()
        .with_injections(spec.events.clone())
        .with_recorder(TraceRecorder::new())
        .with_tenant_weights(spec.tenant_weights());
    if let Some(asc) = spec.autoscale.clone() {
        session = session.with_autoscaler(Autoscaler::new(asc));
    }
    session
}

/// Wire the spec's embedded rate card into the metrics (post-run: cost is
/// pure reporting and must never influence a scheduling decision). The
/// resolution only reads deploy-time invariants (baselines, pool names),
/// so it matches [`resolved_cost_rates`]'s offline reconstruction exactly.
fn attach_cost(metrics: &mut Metrics, spec: &ScenarioSpec, be: &dyn Backend) {
    if let Some(cost) = &spec.cost {
        metrics.cost_rates = Some(cost.resolve(&be.scale_classes(), &be.provisioned()));
    }
}

/// Effective $/unit-hour per pool for a recorded trace: the embedded
/// spec's cost model — or the default rate card when the spec has none —
/// resolved against a fresh deployment of the embedded catalog. Purely
/// offline; deterministic.
pub fn resolved_cost_rates(
    spec: &ScenarioSpec,
    backend: BackendKind,
) -> BTreeMap<String, f64> {
    let cost = spec.cost.clone().unwrap_or_default();
    let cat = Catalog::build(&spec.catalog);
    let be = build_backend(&spec.catalog, &cat, backend);
    cost.resolve(&be.scale_classes(), &be.provisioned())
}

/// Scheduler hot-path counters of one Tangram scenario run (the dirty-pool
/// benchmark surface; see `BENCH_sched.json`).
#[derive(Debug, Clone)]
pub struct SchedStats {
    /// Elastic-scheduler invocations (Algorithm 1 runs over one pool).
    pub invocations: u64,
    /// `drain_started` calls the driver issued.
    pub drain_calls: u64,
    /// Mean wall-clock per scheduler invocation (ns).
    pub mean_sched_ns: u64,
    /// Mean wall-clock per `drain_started` (ns).
    pub mean_drain_ns: u64,
    /// Schedulable pools in the deployment (CPU nodes + GPU + endpoints).
    pub pools: usize,
}

/// [`run_scenario`] specialized to the Tangram backend, returning the
/// scheduler hot-path counters alongside the outcome. `full_sweep` restores
/// the legacy schedule-every-pool-per-pump behaviour — the differential
/// baseline for the dirty-pool refactor.
pub fn run_scenario_tangram(
    spec: &ScenarioSpec,
    full_sweep: bool,
) -> Result<(ScenarioOutcome, SchedStats)> {
    run_scenario_tangram_sharded(spec, full_sweep, 1)
}

/// [`run_scenario_tangram`] with an explicit drain shard count. The shard
/// partition is contiguous over the sorted pool order, so any count yields
/// the serial decision stream byte-for-byte — the parity tests and the
/// fuzz oracle's shards invariant run through here.
pub fn run_scenario_tangram_sharded(
    spec: &ScenarioSpec,
    full_sweep: bool,
    shards: usize,
) -> Result<(ScenarioOutcome, SchedStats)> {
    run_scenario_tangram_threaded(spec, full_sweep, shards, 0)
}

/// [`run_scenario_tangram_sharded`] with an explicit worker-thread count.
/// Workers run only the read-only decide half of each drain and plans apply
/// in ascending shard order, so any `(shards, threads)` pair yields the
/// serial decision stream byte-for-byte — the threads-parity tests, the
/// fuzz oracle's threads invariant, and the throughput bench run through
/// here. `0` leaves the backend's default.
pub fn run_scenario_tangram_threaded(
    spec: &ScenarioSpec,
    full_sweep: bool,
    shards: usize,
    threads: usize,
) -> Result<(ScenarioOutcome, SchedStats)> {
    spec.validate()?;
    let wls = spec.workloads_for(BackendKind::Tangram);
    if wls.is_empty() {
        bail!("scenario '{}' has no workloads the tangram backend supports", spec.name);
    }
    let cat = Catalog::build(&spec.catalog);
    // same catalog→deployment scaling as build_backend, plus the sweep knob
    let mut tcfg = tangram_cfg_for(&spec.catalog);
    tcfg.full_sweep = full_sweep;
    let mut be = TangramBackend::new(&cat, tcfg);
    let mut session = session_for(spec).with_shards(shards).with_threads(threads);
    let cfg = spec.run_cfg();
    let mut metrics = run_session(&mut be, &cat, &wls, &cfg, &mut session);
    attach_cost(&mut metrics, spec, &be);
    let rec = session.take_recorder().unwrap_or_default();
    let stats = SchedStats {
        invocations: be.sched_invocations,
        drain_calls: be.drain_calls,
        mean_sched_ns: be.mean_sched_latency().as_nanos() as u64,
        mean_drain_ns: be.mean_drain_latency().as_nanos() as u64,
        pools: be.pool_count(),
    };
    Ok((ScenarioOutcome { metrics, events: rec.events }, stats))
}

/// Deterministic metrics summary: headline aggregates plus an FNV digest
/// over the full serialized record stream. Byte-compare two of these to
/// byte-compare entire runs.
pub fn summary_json(m: &Metrics) -> Json {
    let full = m.to_json().to_string();
    let (exec, queue, ovh) = m.act_breakdown();
    let hours = Json::obj(
        m.resource_rows()
            .iter()
            .map(|(pool, used, _)| (pool.as_str(), Json::num(*used)))
            .collect(),
    );
    let mut pairs = vec![
        ("actions", Json::num(m.actions.len() as f64)),
        ("failed_actions", Json::num(m.failed_actions() as f64)),
        ("retries", Json::num(m.total_retries() as f64)),
        ("trajectories", Json::num(m.trajectories.len() as f64)),
        ("steps", Json::num(m.steps.len() as f64)),
        ("mean_act_secs", Json::num(m.mean_act())),
        ("p99_act_secs", Json::num(m.p99_act())),
        ("exec_secs", Json::num(exec)),
        ("queue_secs", Json::num(queue)),
        ("overhead_secs", Json::num(ovh)),
        ("mean_step_secs", Json::num(m.mean_step_dur())),
        ("resource_unit_hours", hours),
        ("savings_vs_static", Json::num(m.savings_vs_static())),
        ("metrics_fnv64", Json::str(format!("{:016x}", fnv1a64(full.as_bytes())))),
    ];
    // dollar figures ride along ONLY for cost-model runs — cost-free trace
    // summaries (every static golden) keep their exact bytes
    let cost_rows = m.cost_rows();
    if !cost_rows.is_empty() {
        let pool_cost = Json::obj(
            cost_rows
                .iter()
                .map(|(pool, _, used, _)| (pool.as_str(), Json::num(*used)))
                .collect(),
        );
        pairs.push(("pool_cost", pool_cost));
        // derived from the rows computed above — same accumulation order
        // as Metrics::savings_vs_static_cost, so the figures agree bitwise
        pairs.push(("savings_vs_static_cost", Json::num(Metrics::cost_savings_of(&cost_rows))));
    }
    // per-tenant headline rows ride along ONLY for multi-tenant runs — every
    // single-tenant golden summary keeps its exact bytes
    let tenant_keys: Vec<String>;
    if m.multi_tenant() {
        let rollups = m.tenant_rollups();
        tenant_keys = rollups.keys().map(|t| t.to_string()).collect();
        let mut costs: BTreeMap<u32, f64> = BTreeMap::new();
        for (t, _, dollars) in m.tenant_cost_rows() {
            *costs.entry(t).or_default() += dollars;
        }
        let tenants = Json::obj(
            rollups
                .iter()
                .zip(tenant_keys.iter())
                .map(|((t, r), key)| {
                    let row = Json::obj(vec![
                        ("actions", Json::num(r.actions as f64)),
                        ("cost", Json::num(costs.get(t).copied().unwrap_or(0.0))),
                        ("failed", Json::num(r.failed as f64)),
                        ("mean_act_secs", Json::num(r.mean_act_secs())),
                        ("mean_queue_secs", Json::num(r.mean_queue_secs())),
                        ("retries", Json::num(r.retries as f64)),
                    ]);
                    (key.as_str(), row)
                })
                .collect(),
        );
        pairs.push(("tenants", tenants));
    }
    Json::obj(pairs)
}

/// `None` when the serialized summaries are byte-identical; otherwise the
/// first differing key (or a length note).
pub fn diff_summaries(a: &Json, b: &Json) -> Option<String> {
    if a.to_string() == b.to_string() {
        return None;
    }
    if let (Some(ma), Some(mb)) = (a.as_obj(), b.as_obj()) {
        for (k, va) in ma {
            match mb.get(k) {
                Some(vb) if va == vb => {}
                Some(vb) => return Some(format!("'{k}': {va} != {vb}")),
                None => return Some(format!("'{k}' missing from replay")),
            }
        }
        for k in mb.keys() {
            if !ma.contains_key(k) {
                return Some(format!("'{k}' only in replay"));
            }
        }
    }
    Some("summaries differ".to_string())
}

/// First `max` divergences between two decision traces.
pub fn diff_traces(a: &[TraceEvent], b: &[TraceEvent], max: usize) -> Vec<String> {
    let mut out = Vec::new();
    let n = a.len().min(b.len());
    for i in 0..n {
        if out.len() >= max {
            return out;
        }
        if a[i] != b[i] {
            out.push(format!(
                "event {i}: recorded {:?} vs replayed {:?}",
                a[i], b[i]
            ));
        }
    }
    if a.len() != b.len() && out.len() < max {
        out.push(format!(
            "trace length: recorded {} vs replayed {} events",
            a.len(),
            b.len()
        ));
    }
    out
}

/// A parsed trace file (header spec + events + recorded summary).
pub struct RecordedTrace {
    pub spec: ScenarioSpec,
    pub backend: BackendKind,
    pub events: Vec<TraceEvent>,
    pub summary: Json,
}

/// Serialize a run to the self-contained trace-file format.
pub fn trace_file_contents(
    spec: &ScenarioSpec,
    backend: BackendKind,
    outcome: &ScenarioOutcome,
) -> String {
    let header = Json::obj(vec![
        ("arl_tangram_trace", Json::num(1.0)),
        ("backend", Json::str(backend.name())),
        ("spec", spec.to_json()),
    ]);
    let mut s = String::new();
    s.push_str(&header.to_string());
    s.push('\n');
    for e in &outcome.events {
        s.push_str(&e.to_json().to_string());
        s.push('\n');
    }
    let footer = Json::obj(vec![("summary", summary_json(&outcome.metrics))]);
    s.push_str(&footer.to_string());
    s.push('\n');
    s
}

pub fn write_trace_file(
    path: &str,
    spec: &ScenarioSpec,
    backend: BackendKind,
    outcome: &ScenarioOutcome,
) -> Result<()> {
    std::fs::write(path, trace_file_contents(spec, backend, outcome))
        .map_err(|e| err!("writing trace {path}: {e}"))
}

/// Parse the trace-file format produced by [`trace_file_contents`].
pub fn parse_trace_file(text: &str) -> Result<RecordedTrace> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header_line = lines.next().ok_or_else(|| err!("empty trace file"))?;
    let header = Json::parse(header_line).map_err(|e| err!("trace header: {e}"))?;
    if header.get("arl_tangram_trace").and_then(Json::as_u64) != Some(1) {
        bail!("not an arl-tangram trace file (missing/unknown version marker)");
    }
    let backend = BackendKind::parse(
        header
            .get("backend")
            .and_then(Json::as_str)
            .ok_or_else(|| err!("trace header missing 'backend'"))?,
    )?;
    let spec = ScenarioSpec::from_json_value(
        header.get("spec").ok_or_else(|| err!("trace header missing 'spec'"))?,
    )?;
    let mut events = Vec::new();
    let mut summary = None;
    for line in lines {
        let j = Json::parse(line).map_err(|e| err!("trace line: {e}"))?;
        if let Some(s) = j.get("summary") {
            summary = Some(s.clone());
        } else {
            events.push(TraceEvent::from_json(&j)?);
        }
    }
    let summary = summary.ok_or_else(|| err!("trace file missing summary footer"))?;
    Ok(RecordedTrace { spec, backend, events, summary })
}

pub fn read_trace_file(path: &str) -> Result<RecordedTrace> {
    let text = std::fs::read_to_string(path).map_err(|e| err!("reading trace {path}: {e}"))?;
    parse_trace_file(&text)
}

/// Result of replaying a recorded trace.
pub struct ReplayReport {
    /// Byte-identical summary AND identical event stream.
    pub identical: bool,
    pub summary_diff: Option<String>,
    pub trace_divergences: Vec<String>,
    pub fresh_summary: Json,
    pub replayed_events: usize,
}

/// Re-run the recorded scenario and diff against the recording.
pub fn replay_trace(recorded: &RecordedTrace) -> Result<ReplayReport> {
    replay_trace_sharded(recorded, 1)
}

/// [`replay_trace`] with an explicit drain shard count: the CI parity smoke
/// replays a golden at `--shards 4` and must still match it byte-for-byte.
pub fn replay_trace_sharded(recorded: &RecordedTrace, shards: usize) -> Result<ReplayReport> {
    replay_trace_threaded(recorded, shards, 0)
}

/// [`replay_trace_sharded`] with an explicit worker-thread count: the CI
/// parity smoke replays a golden at `--shards 4 --threads 4` and must still
/// match it byte-for-byte. `0` leaves the backend's default.
pub fn replay_trace_threaded(
    recorded: &RecordedTrace,
    shards: usize,
    threads: usize,
) -> Result<ReplayReport> {
    let outcome = run_scenario_threaded(&recorded.spec, recorded.backend, shards, threads)?;
    let fresh_summary = summary_json(&outcome.metrics);
    let summary_diff = diff_summaries(&recorded.summary, &fresh_summary);
    let trace_divergences = diff_traces(&recorded.events, &outcome.events, 10);
    Ok(ReplayReport {
        identical: summary_diff.is_none() && trace_divergences.is_empty(),
        summary_diff,
        trace_divergences,
        fresh_summary,
        replayed_events: outcome.events.len(),
    })
}

// ---------------------------------------------------------------------------
// A/B trace comparison (`--replay a.jsonl --against b.jsonl`)
// ---------------------------------------------------------------------------

/// Which provision pool an action kind draws from (the A/B table rows).
fn pool_of_kind(kind: &str) -> &'static str {
    match kind {
        "env_exec" | "reward_cpu" => "cpu_cores",
        "reward_model" => "gpus",
        "api_call" => "api_lanes",
        _ => "other",
    }
}

/// Per-pool ACT and resource-hour aggregates of one recorded trace.
#[derive(Debug, Default, Clone)]
pub struct TracePoolStats {
    pub actions: usize,
    pub mean_act_secs: f64,
    pub unit_hours: f64,
}

/// One row of the `--against` comparison table.
#[derive(Debug, Clone)]
pub struct AbRow {
    pub pool: String,
    pub a: TracePoolStats,
    pub b: TracePoolStats,
    /// $ = resolved rate × unit-hours, under each trace's own embedded
    /// rate card (the default card when a spec carries no cost model).
    pub cost_a: f64,
    pub cost_b: f64,
}

/// Relative delta of B vs A, `None` when A has no signal.
fn rel_delta(a: f64, b: f64) -> Option<f64> {
    if a.abs() < 1e-12 {
        return None;
    }
    Some((b - a) / a)
}

impl AbRow {
    pub fn act_delta(&self) -> Option<f64> {
        rel_delta(self.a.mean_act_secs, self.b.mean_act_secs)
    }

    pub fn hours_delta(&self) -> Option<f64> {
        rel_delta(self.a.unit_hours, self.b.unit_hours)
    }

    pub fn cost_delta(&self) -> Option<f64> {
        rel_delta(self.cost_a, self.cost_b)
    }
}

/// Per-tenant ACT/retry aggregates of one recorded trace. No unit-hours:
/// `provision` billing points are pool-level, not tenant-attributed, so a
/// trace alone cannot split capacity dollars by tenant (the in-run metrics
/// do that via busy-time shares).
#[derive(Debug, Default, Clone)]
pub struct TraceTenantStats {
    pub actions: usize,
    pub mean_act_secs: f64,
    pub retries: u64,
}

/// One per-tenant row of the `--against` comparison table (present only
/// when either trace carries multi-tenant submits).
#[derive(Debug, Clone)]
pub struct AbTenantRow {
    pub tenant: u32,
    pub a: TraceTenantStats,
    pub b: TraceTenantStats,
}

impl AbTenantRow {
    pub fn act_delta(&self) -> Option<f64> {
        rel_delta(self.a.mean_act_secs, self.b.mean_act_secs)
    }
}

/// A/B comparison of two recorded traces.
pub struct AbReport {
    /// Byte-identical event streams and summaries (A/B of a no-op change).
    pub identical: bool,
    /// First event-stream divergences (capped), for the exit-code path.
    pub divergences: Vec<String>,
    pub summary_diff: Option<String>,
    /// Per-pool ACT / resource-hour table, sorted by pool name.
    pub rows: Vec<AbRow>,
    /// Per-tenant ACT table, sorted by tenant id; empty unless at least one
    /// side recorded a multi-tenant run.
    pub tenant_rows: Vec<AbTenantRow>,
}

/// Reduce one trace's event stream to per-pool ACT and resource-hour stats.
/// ACT is final-completion minus first-submit per action (retries fold into
/// their action); resource-hours integrate the `provision` billing events
/// to the last event timestamp.
pub fn trace_pool_stats(events: &[TraceEvent]) -> BTreeMap<String, TracePoolStats> {
    let end = events.last().map_or(SimTime::ZERO, |e| e.at);
    let mut submits: HashMap<u64, (SimTime, &'static str)> = HashMap::new();
    let mut acts: BTreeMap<&'static str, Vec<f64>> = BTreeMap::new();
    let mut series: BTreeMap<String, Vec<(SimTime, u64)>> = BTreeMap::new();
    for e in events {
        match &e.kind {
            TraceKind::Submit { action, kind, .. } => {
                submits.entry(*action).or_insert((e.at, pool_of_kind(kind)));
            }
            TraceKind::Complete { action, outcome, .. } if outcome != "retry" => {
                if let Some((t0, pool)) = submits.remove(action) {
                    acts.entry(pool).or_default().push(e.at.saturating_sub(t0).secs_f64());
                }
            }
            TraceKind::Provision { pool, units } => {
                series.entry(pool.clone()).or_default().push((e.at, *units));
            }
            _ => {}
        }
    }
    let mut out: BTreeMap<String, TracePoolStats> = BTreeMap::new();
    for (pool, v) in acts {
        let st = out.entry(pool.to_string()).or_default();
        st.actions = v.len();
        st.mean_act_secs = crate::util::mean(&v);
    }
    for (pool, points) in series {
        // same billing convention as the in-run accounting
        let unit_secs = crate::metrics::integrate_unit_secs(&points, end);
        out.entry(pool).or_default().unit_hours = unit_secs / 3600.0;
    }
    out
}

/// Reduce one trace's event stream to per-tenant ACT/retry stats. Same ACT
/// convention as [`trace_pool_stats`] (final completion minus first submit;
/// retries fold into their action); `retry` completions count against the
/// submitting tenant.
pub fn trace_tenant_stats(events: &[TraceEvent]) -> BTreeMap<u32, TraceTenantStats> {
    let mut submits: HashMap<u64, (SimTime, u32)> = HashMap::new();
    let mut acts: BTreeMap<u32, Vec<f64>> = BTreeMap::new();
    let mut retries: BTreeMap<u32, u64> = BTreeMap::new();
    for e in events {
        match &e.kind {
            TraceKind::Submit { action, tenant, .. } => {
                submits.entry(*action).or_insert((e.at, *tenant));
            }
            TraceKind::Complete { action, outcome, .. } => {
                if outcome == "retry" {
                    if let Some(&(_, t)) = submits.get(action) {
                        *retries.entry(t).or_default() += 1;
                    }
                } else if let Some((t0, t)) = submits.remove(action) {
                    acts.entry(t).or_default().push(e.at.saturating_sub(t0).secs_f64());
                }
            }
            _ => {}
        }
    }
    let mut out: BTreeMap<u32, TraceTenantStats> = BTreeMap::new();
    for (t, v) in acts {
        let st = out.entry(t).or_default();
        st.actions = v.len();
        st.mean_act_secs = crate::util::mean(&v);
    }
    for (t, n) in retries {
        out.entry(t).or_default().retries = n;
    }
    out
}

/// Compare two recorded traces event-by-event and build the per-pool
/// ACT/resource-hour delta table — the A/B harness for autoscaler-on vs
/// static (or any two scheduler variants). Purely offline: nothing re-runs.
pub fn ab_compare(a: &RecordedTrace, b: &RecordedTrace) -> AbReport {
    let divergences = diff_traces(&a.events, &b.events, 5);
    let summary_diff = diff_summaries(&a.summary, &b.summary);
    let sa = trace_pool_stats(&a.events);
    let sb = trace_pool_stats(&b.events);
    // each side prices its unit-hours under its own embedded rate card
    let ra = resolved_cost_rates(&a.spec, a.backend);
    let rb = resolved_cost_rates(&b.spec, b.backend);
    let mut pools: Vec<String> = sa.keys().chain(sb.keys()).cloned().collect();
    pools.sort();
    pools.dedup();
    let rows = pools
        .into_iter()
        .map(|pool| {
            let sta = sa.get(&pool).cloned().unwrap_or_default();
            let stb = sb.get(&pool).cloned().unwrap_or_default();
            AbRow {
                cost_a: ra.get(&pool).copied().unwrap_or(1.0) * sta.unit_hours,
                cost_b: rb.get(&pool).copied().unwrap_or(1.0) * stb.unit_hours,
                a: sta,
                b: stb,
                pool,
            }
        })
        .collect();
    // the tenant table appears only when a side actually ran multi-tenant —
    // single-tenant A/B output is unchanged
    let ta = trace_tenant_stats(&a.events);
    let tb = trace_tenant_stats(&b.events);
    let tenant_rows = if ta.keys().chain(tb.keys()).any(|t| *t != 0) {
        let mut ids: Vec<u32> = ta.keys().chain(tb.keys()).copied().collect();
        ids.sort_unstable();
        ids.dedup();
        ids.into_iter()
            .map(|tenant| AbTenantRow {
                a: ta.get(&tenant).cloned().unwrap_or_default(),
                b: tb.get(&tenant).cloned().unwrap_or_default(),
                tenant,
            })
            .collect()
    } else {
        Vec::new()
    };
    AbReport {
        identical: divergences.is_empty() && summary_diff.is_none(),
        divergences,
        summary_diff,
        rows,
        tenant_rows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_is_stable() {
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), fnv1a64(b"a"));
        assert_ne!(fnv1a64(b"a"), fnv1a64(b"b"));
    }

    #[test]
    fn trace_file_round_trips() {
        let spec = crate::scenario::pack_by_name("steady-mix").unwrap();
        let outcome = run_scenario(&spec, BackendKind::Serverless).unwrap();
        let text = trace_file_contents(&spec, BackendKind::Serverless, &outcome);
        let rt = parse_trace_file(&text).unwrap();
        assert_eq!(rt.backend, BackendKind::Serverless);
        assert_eq!(rt.spec.to_json().to_string(), spec.to_json().to_string());
        assert_eq!(rt.events, outcome.events);
        assert_eq!(
            rt.summary.to_string(),
            summary_json(&outcome.metrics).to_string()
        );
    }

    #[test]
    fn shard_counts_record_byte_identical_traces() {
        // The sharded-drain contract: the FULL serialized trace file —
        // header, every decision event, summary (with its FNV digest over
        // the complete metrics record stream) — is byte-identical for any
        // worker count, including counts above the pool count. No
        // re-blessing, ever.
        let spec = crate::scenario::pack_by_name("steady-mix").unwrap();
        let (base, _) = run_scenario_tangram_sharded(&spec, false, 1).unwrap();
        let base_text = trace_file_contents(&spec, BackendKind::Tangram, &base);
        for shards in [2usize, 8, 64] {
            let (o, _) = run_scenario_tangram_sharded(&spec, false, shards).unwrap();
            let text = trace_file_contents(&spec, BackendKind::Tangram, &o);
            assert_eq!(text, base_text, "trace bytes diverged at shards={shards}");
        }
        // the full-sweep differential path shards over the cached index —
        // same contract there
        let (sweep1, _) = run_scenario_tangram_sharded(&spec, true, 1).unwrap();
        let (sweep3, _) = run_scenario_tangram_sharded(&spec, true, 3).unwrap();
        assert_eq!(
            trace_file_contents(&spec, BackendKind::Tangram, &sweep1),
            trace_file_contents(&spec, BackendKind::Tangram, &sweep3),
            "full-sweep trace bytes diverged under sharding"
        );
    }

    #[test]
    fn shard_and_thread_grid_records_byte_identical_traces() {
        // The threaded-drain contract over the full (shards, threads) grid:
        // workers run only the read-only decide half and plans apply in
        // ascending shard order, so the FULL serialized trace file is
        // byte-identical to the serial run for every combination — thread
        // counts above the shard count included. No re-blessing, ever.
        let spec = crate::scenario::pack_by_name("steady-mix").unwrap();
        let (base, _) = run_scenario_tangram_threaded(&spec, false, 1, 1).unwrap();
        let base_text = trace_file_contents(&spec, BackendKind::Tangram, &base);
        for shards in [1usize, 2, 8] {
            for threads in [1usize, 2, 4] {
                let (o, _) =
                    run_scenario_tangram_threaded(&spec, false, shards, threads).unwrap();
                let text = trace_file_contents(&spec, BackendKind::Tangram, &o);
                assert_eq!(
                    text, base_text,
                    "trace bytes diverged at shards={shards} threads={threads}"
                );
            }
        }
        // the full-sweep differential path drains through the same worker
        // pool — same contract there
        let (sweep1, _) = run_scenario_tangram_threaded(&spec, true, 1, 1).unwrap();
        let (sweep43, _) = run_scenario_tangram_threaded(&spec, true, 4, 3).unwrap();
        assert_eq!(
            trace_file_contents(&spec, BackendKind::Tangram, &sweep1),
            trace_file_contents(&spec, BackendKind::Tangram, &sweep43),
            "full-sweep trace bytes diverged under threading"
        );
    }

    #[test]
    fn threaded_replay_matches_a_serial_recording() {
        // the CI parity smoke in library form: record serial, replay at
        // --shards 4 --threads 4, byte-identical summary and event stream
        let spec = crate::scenario::pack_by_name("steady-mix").unwrap();
        let outcome = run_scenario(&spec, BackendKind::Tangram).unwrap();
        let recorded = RecordedTrace {
            spec: spec.clone(),
            backend: BackendKind::Tangram,
            events: outcome.events.clone(),
            summary: summary_json(&outcome.metrics),
        };
        let report = replay_trace_threaded(&recorded, 4, 4).unwrap();
        assert!(report.identical, "diff: {:?}", report.summary_diff);
        assert_eq!(report.replayed_events, outcome.events.len());
    }

    #[test]
    fn sharded_replay_matches_a_serial_recording() {
        // the CI parity smoke in library form: record serial, replay at
        // --shards 4, byte-identical summary and event stream
        let spec = crate::scenario::pack_by_name("steady-mix").unwrap();
        let outcome = run_scenario(&spec, BackendKind::Tangram).unwrap();
        let recorded = RecordedTrace {
            spec: spec.clone(),
            backend: BackendKind::Tangram,
            events: outcome.events.clone(),
            summary: summary_json(&outcome.metrics),
        };
        let report = replay_trace_sharded(&recorded, 4).unwrap();
        assert!(report.identical, "diff: {:?}", report.summary_diff);
        assert_eq!(report.replayed_events, outcome.events.len());
    }

    #[test]
    fn diff_reports_divergence() {
        let a = Json::obj(vec![("x", Json::num(1.0))]);
        let b = Json::obj(vec![("x", Json::num(2.0))]);
        assert!(diff_summaries(&a, &a).is_none());
        assert!(diff_summaries(&a, &b).unwrap().contains("'x'"));
    }

    #[test]
    fn unsupported_backend_is_an_error() {
        let spec = crate::scenario::pack_by_name("api-flap").unwrap(); // deepsearch only
        assert!(run_scenario(&spec, BackendKind::K8s).is_err());
    }

    #[test]
    fn tenant_summary_and_trace_stats() {
        let spec = crate::scenario::pack_by_name("tenant-fairshare").unwrap();
        let outcome = run_scenario(&spec, BackendKind::Tangram).unwrap();
        let summary = summary_json(&outcome.metrics);
        assert!(summary.get("tenants").is_some());
        let ts = trace_tenant_stats(&outcome.events);
        assert_eq!(ts.keys().copied().collect::<Vec<_>>(), vec![0, 1]);
        assert!(ts.values().all(|s| s.actions > 0));
        // single-tenant runs keep their summary bytes and an all-zero ledger
        let single = crate::scenario::pack_by_name("steady-mix").unwrap();
        let so = run_scenario(&single, BackendKind::Tangram).unwrap();
        assert!(summary_json(&so.metrics).get("tenants").is_none());
        assert!(trace_tenant_stats(&so.events).keys().all(|t| *t == 0));
        // and a single-tenant A/B comparison carries no tenant table
        let rt = |spec: &ScenarioSpec, outcome: &ScenarioOutcome| RecordedTrace {
            spec: spec.clone(),
            backend: BackendKind::Tangram,
            events: outcome.events.clone(),
            summary: summary_json(&outcome.metrics),
        };
        assert!(ab_compare(&rt(&single, &so), &rt(&single, &so)).tenant_rows.is_empty());
        let ab = ab_compare(&rt(&spec, &outcome), &rt(&spec, &outcome));
        assert_eq!(ab.tenant_rows.len(), 2);
        assert!(ab.identical);
    }
}
