//! Deterministic decision-trace recording (the scenario subsystem's flight
//! recorder).
//!
//! The [`TraceRecorder`] hooks into the DES driver
//! ([`crate::coordinator::driver::run_session`]) and captures every
//! scheduling-relevant transition — action submit/start/complete, trajectory
//! and step boundaries, fault injections — as a compact JSONL event stream.
//! Two same-seed runs of the same [`crate::scenario::ScenarioSpec`] must
//! produce *byte-identical* streams; the replay engine
//! ([`crate::scenario::replay`]) diffs them to catch any nondeterminism or
//! behavioural drift introduced by a scheduler change.
//!
//! Event timestamps are virtual nanoseconds (exact integers — every value a
//! run can produce is far below 2^53, so the JSON number round-trip is
//! lossless).

use crate::sim::SimTime;
use crate::util::error::Result;
use crate::util::json::Json;
use crate::{bail, err};

/// One recorded driver transition.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceKind {
    /// An RL step began for a task.
    StepStart { task: u32, step: u32 },
    /// All trajectories of the step finished rolling out.
    StepEnd { task: u32, step: u32, rollout_ns: u64 },
    /// A trajectory was spawned (plan materialized).
    TrajSpawn { traj: u64, task: u32 },
    /// A trajectory finished (all phases done or terminally failed).
    TrajEnd { traj: u64, failed: bool, restarts: u32 },
    /// An action entered the backend's waiting queue. `tenant` is 0 in
    /// single-tenant runs and is then omitted from the serialized form
    /// (legacy traces stay byte-identical and parse back with tenant 0).
    Submit { action: u64, traj: u64, kind: String, tenant: u32, queue_depth: u64 },
    /// The backend started an attempt: granted units, charged overhead.
    Start { action: u64, units: u64, overhead_ns: u64, exec_ns: u64, queue_depth: u64 },
    /// An attempt finished with the driver's effective verdict
    /// (`done` | `retry` | `failed`); `retry` means the action was evicted
    /// from its slot and re-queued.
    Complete { action: u64, outcome: String, retries: u32 },
    /// A scenario fault was injected; `applied` is false when the backend
    /// has no substrate for it (e.g. a CPU cordon on a GPU-only baseline).
    Inject { index: u64, desc: String, applied: bool },
    /// A provisioned-capacity billing point: pool `pool` holds (and is paid
    /// for at) `units` from here until its next `provision` event. Emitted
    /// per pool at run start and at every autoscaler billing point — the
    /// `--against` A/B comparison integrates these into resource-hours.
    Provision { pool: String, units: u64 },
    /// An autoscaler transition: `phase` is `"decide"` (scale-up chosen,
    /// capacity billed, cold start begins) or `"apply"` (substrate resized).
    /// Factors are quantized so the f64 survives the JSON round-trip.
    Scale { pool: String, phase: String, factor: f64 },
}

impl TraceKind {
    /// Short tag used as the `ev` field in JSONL.
    pub fn tag(&self) -> &'static str {
        match self {
            TraceKind::StepStart { .. } => "step_start",
            TraceKind::StepEnd { .. } => "step_end",
            TraceKind::TrajSpawn { .. } => "traj_spawn",
            TraceKind::TrajEnd { .. } => "traj_end",
            TraceKind::Submit { .. } => "submit",
            TraceKind::Start { .. } => "start",
            TraceKind::Complete { .. } => "complete",
            TraceKind::Inject { .. } => "inject",
            TraceKind::Provision { .. } => "provision",
            TraceKind::Scale { .. } => "scale",
        }
    }
}

/// A trace event: virtual timestamp + transition.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub at: SimTime,
    pub kind: TraceKind,
}

fn num(n: u64) -> Json {
    Json::Num(n as f64)
}

fn get_u64(j: &Json, key: &str) -> Result<u64> {
    j.get(key)
        .and_then(Json::as_u64)
        .ok_or_else(|| err!("trace event missing integer field '{key}'"))
}

fn get_str(j: &Json, key: &str) -> Result<String> {
    Ok(j.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| err!("trace event missing string field '{key}'"))?
        .to_string())
}

fn get_bool(j: &Json, key: &str) -> Result<bool> {
    j.get(key)
        .and_then(Json::as_bool)
        .ok_or_else(|| err!("trace event missing boolean field '{key}'"))
}

fn get_f64(j: &Json, key: &str) -> Result<f64> {
    j.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| err!("trace event missing number field '{key}'"))
}

impl TraceEvent {
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> =
            vec![("at", num(self.at.0)), ("ev", Json::str(self.kind.tag()))];
        match &self.kind {
            TraceKind::StepStart { task, step } => {
                pairs.push(("task", num(*task as u64)));
                pairs.push(("step", num(*step as u64)));
            }
            TraceKind::StepEnd { task, step, rollout_ns } => {
                pairs.push(("task", num(*task as u64)));
                pairs.push(("step", num(*step as u64)));
                pairs.push(("rollout_ns", num(*rollout_ns)));
            }
            TraceKind::TrajSpawn { traj, task } => {
                pairs.push(("traj", num(*traj)));
                pairs.push(("task", num(*task as u64)));
            }
            TraceKind::TrajEnd { traj, failed, restarts } => {
                pairs.push(("traj", num(*traj)));
                pairs.push(("failed", Json::Bool(*failed)));
                pairs.push(("restarts", num(*restarts as u64)));
            }
            TraceKind::Submit { action, traj, kind, tenant, queue_depth } => {
                pairs.push(("action", num(*action)));
                pairs.push(("traj", num(*traj)));
                pairs.push(("kind", Json::str(kind.clone())));
                if *tenant != 0 {
                    pairs.push(("tenant", num(*tenant as u64)));
                }
                pairs.push(("queue_depth", num(*queue_depth)));
            }
            TraceKind::Start { action, units, overhead_ns, exec_ns, queue_depth } => {
                pairs.push(("action", num(*action)));
                pairs.push(("units", num(*units)));
                pairs.push(("overhead_ns", num(*overhead_ns)));
                pairs.push(("exec_ns", num(*exec_ns)));
                pairs.push(("queue_depth", num(*queue_depth)));
            }
            TraceKind::Complete { action, outcome, retries } => {
                pairs.push(("action", num(*action)));
                pairs.push(("outcome", Json::str(outcome.clone())));
                pairs.push(("retries", num(*retries as u64)));
            }
            TraceKind::Inject { index, desc, applied } => {
                pairs.push(("index", num(*index)));
                pairs.push(("desc", Json::str(desc.clone())));
                pairs.push(("applied", Json::Bool(*applied)));
            }
            TraceKind::Provision { pool, units } => {
                pairs.push(("pool", Json::str(pool.clone())));
                pairs.push(("units", num(*units)));
            }
            TraceKind::Scale { pool, phase, factor } => {
                pairs.push(("pool", Json::str(pool.clone())));
                pairs.push(("phase", Json::str(phase.clone())));
                pairs.push(("factor", Json::num(*factor)));
            }
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<TraceEvent> {
        let at = SimTime(get_u64(j, "at")?);
        let tag = get_str(j, "ev")?;
        let kind = match tag.as_str() {
            "step_start" => TraceKind::StepStart {
                task: get_u64(j, "task")? as u32,
                step: get_u64(j, "step")? as u32,
            },
            "step_end" => TraceKind::StepEnd {
                task: get_u64(j, "task")? as u32,
                step: get_u64(j, "step")? as u32,
                rollout_ns: get_u64(j, "rollout_ns")?,
            },
            "traj_spawn" => TraceKind::TrajSpawn {
                traj: get_u64(j, "traj")?,
                task: get_u64(j, "task")? as u32,
            },
            "traj_end" => TraceKind::TrajEnd {
                traj: get_u64(j, "traj")?,
                failed: get_bool(j, "failed")?,
                restarts: get_u64(j, "restarts")? as u32,
            },
            "submit" => TraceKind::Submit {
                action: get_u64(j, "action")?,
                traj: get_u64(j, "traj")?,
                kind: get_str(j, "kind")?,
                tenant: j.get("tenant").and_then(Json::as_u64).unwrap_or(0) as u32,
                queue_depth: get_u64(j, "queue_depth")?,
            },
            "start" => TraceKind::Start {
                action: get_u64(j, "action")?,
                units: get_u64(j, "units")?,
                overhead_ns: get_u64(j, "overhead_ns")?,
                exec_ns: get_u64(j, "exec_ns")?,
                queue_depth: get_u64(j, "queue_depth")?,
            },
            "complete" => TraceKind::Complete {
                action: get_u64(j, "action")?,
                outcome: get_str(j, "outcome")?,
                retries: get_u64(j, "retries")? as u32,
            },
            "inject" => TraceKind::Inject {
                index: get_u64(j, "index")?,
                desc: get_str(j, "desc")?,
                applied: get_bool(j, "applied")?,
            },
            "provision" => TraceKind::Provision {
                pool: get_str(j, "pool")?,
                units: get_u64(j, "units")?,
            },
            "scale" => TraceKind::Scale {
                pool: get_str(j, "pool")?,
                phase: get_str(j, "phase")?,
                factor: get_f64(j, "factor")?,
            },
            other => bail!("unknown trace event tag '{other}'"),
        };
        Ok(TraceEvent { at, kind })
    }
}

/// Collects [`TraceEvent`]s during a driver run.
#[derive(Debug, Default)]
pub struct TraceRecorder {
    pub events: Vec<TraceEvent>,
}

impl TraceRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, at: SimTime, kind: TraceKind) {
        self.events.push(TraceEvent { at, kind });
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// One JSON object per line; keys sorted (BTreeMap) ⇒ byte-deterministic.
    pub fn to_jsonl(&self) -> String {
        let mut s = String::new();
        for e in &self.events {
            s.push_str(&e.to_json().to_string());
            s.push('\n');
        }
        s
    }

    /// Parse an event-only JSONL stream (no header/summary lines).
    pub fn parse_jsonl(text: &str) -> Result<Vec<TraceEvent>> {
        text.lines()
            .filter(|l| !l.trim().is_empty())
            .map(|l| {
                let j = Json::parse(l).map_err(|e| err!("trace line: {e}"))?;
                TraceEvent::from_json(&j)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<TraceEvent> {
        vec![
            TraceEvent {
                at: SimTime(0),
                kind: TraceKind::StepStart { task: 0, step: 0 },
            },
            TraceEvent {
                at: SimTime(5),
                kind: TraceKind::Submit {
                    action: 1,
                    traj: 2,
                    kind: "env_exec".into(),
                    tenant: 0,
                    queue_depth: 1,
                },
            },
            TraceEvent {
                at: SimTime(6),
                kind: TraceKind::Submit {
                    action: 2,
                    traj: 3,
                    kind: "api_call".into(),
                    tenant: 2,
                    queue_depth: 2,
                },
            },
            TraceEvent {
                at: SimTime(9),
                kind: TraceKind::Start {
                    action: 1,
                    units: 4,
                    overhead_ns: 3,
                    exec_ns: 100,
                    queue_depth: 0,
                },
            },
            TraceEvent {
                at: SimTime(112),
                kind: TraceKind::Complete { action: 1, outcome: "done".into(), retries: 0 },
            },
            TraceEvent {
                at: SimTime(200),
                kind: TraceKind::Inject { index: 0, desc: "api_limit_scale 0.25".into(), applied: true },
            },
            TraceEvent {
                at: SimTime(250),
                kind: TraceKind::Provision { pool: "cpu_cores".into(), units: 640 },
            },
            TraceEvent {
                at: SimTime(260),
                kind: TraceKind::Scale {
                    pool: "cpu_cores".into(),
                    phase: "decide".into(),
                    factor: 0.375,
                },
            },
            TraceEvent {
                at: SimTime(300),
                kind: TraceKind::TrajEnd { traj: 2, failed: false, restarts: 1 },
            },
        ]
    }

    #[test]
    fn jsonl_round_trips() {
        let mut rec = TraceRecorder::new();
        for e in sample() {
            rec.push(e.at, e.kind);
        }
        let text = rec.to_jsonl();
        let back = TraceRecorder::parse_jsonl(&text).unwrap();
        assert_eq!(back, rec.events);
    }

    #[test]
    fn serialization_is_byte_deterministic() {
        let mut a = TraceRecorder::new();
        let mut b = TraceRecorder::new();
        for e in sample() {
            a.push(e.at, e.kind.clone());
            b.push(e.at, e.kind);
        }
        assert_eq!(a.to_jsonl(), b.to_jsonl());
    }

    #[test]
    fn submit_tenant_gating() {
        // tenant 0 serializes without the key (legacy byte-compatibility);
        // a legacy line without the key parses back as tenant 0
        let mut rec = TraceRecorder::new();
        for e in sample() {
            rec.push(e.at, e.kind);
        }
        let text = rec.to_jsonl();
        let lines: Vec<&str> = text.lines().collect();
        assert!(!lines[1].contains("tenant"), "{}", lines[1]);
        assert!(lines[2].contains("\"tenant\":2"), "{}", lines[2]);
        let legacy = "{\"action\":7,\"at\":5,\"ev\":\"submit\",\"kind\":\"env_exec\",\"queue_depth\":1,\"traj\":2}";
        let back = TraceRecorder::parse_jsonl(legacy).unwrap();
        match &back[0].kind {
            TraceKind::Submit { tenant, .. } => assert_eq!(*tenant, 0),
            other => panic!("wrong kind {other:?}"),
        }
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(TraceRecorder::parse_jsonl("{\"ev\":\"start\"}").is_err());
        assert!(TraceRecorder::parse_jsonl("{\"at\":1,\"ev\":\"nope\"}").is_err());
        assert!(TraceRecorder::parse_jsonl("not json").is_err());
    }
}
