//! Topology-agnostic `DPArrange` (paper Algorithm 3) and its DP operators
//! (Basic + GPU-chunk, Algorithm 4).
//!
//! `DPArrange` solves: given scalable tasks `c_1..c_m` with per-task
//! feasible unit sets `S_i` and duration functions `T_i(k)`, and a resource
//! whose *topology* is abstracted behind a [`DpOperator`], find the discrete
//! allocation minimizing `Σ T_i(k_i)` subject to topological feasibility.
//!
//! The operator abstracts the resource as a finite state space: a state is
//! "what remains available"; consuming `k` units maps one state to another
//! (or is infeasible). The Basic operator's state is simply the remaining
//! unit count; the GPU operator's state is the multiset of free chunks,
//! mixed-radix-encoded exactly as in Algorithm 4.
//!
//! Deviation from the paper's pseudocode, documented: Algorithm 3's
//! `IsValid(j', S_{1:i-1})` recursive feasibility probe is redundant under
//! forward DP — a state is reachable for tasks `1..i-1` iff
//! `dp[i-1][state] < ∞` — so we iterate reachable states directly. Same
//! semantics, strictly less work (their stated complexity bound
//! `O(k·n²·m²)` is preserved).

use crate::sim::SimDur;
use std::collections::HashMap;

/// Topology abstraction for one resource kind (paper Appendix B).
pub trait DpOperator {
    /// Size of the state space. States are `0..num_states()`.
    fn num_states(&self) -> usize;

    /// The state representing the currently-available capacity.
    fn full_state(&self) -> usize;

    /// Consume `k` units from state `j`; `None` if topologically infeasible.
    fn consume(&self, j: usize, k: u64) -> Option<usize>;

    /// Largest single-task allocation this operator can ever satisfy
    /// (used to prune per-task unit sets before the DP).
    fn max_alloc(&self) -> u64;
}

/// Basic DP operator: a flat pool of `units` interchangeable units
/// (CPU cores within one NUMA-checked node, API slots). State = remaining
/// units; `consume` is plain subtraction (paper Alg. 3 "Basic DP Operator").
#[derive(Debug, Clone)]
pub struct BasicOperator {
    units: u64,
}

impl BasicOperator {
    pub fn new(units: u64) -> Self {
        BasicOperator { units }
    }
}

impl DpOperator for BasicOperator {
    fn num_states(&self) -> usize {
        self.units as usize + 1
    }

    fn full_state(&self) -> usize {
        self.units as usize
    }

    fn consume(&self, j: usize, k: u64) -> Option<usize> {
        (j as u64 >= k).then(|| j - k as usize)
    }

    fn max_alloc(&self) -> u64 {
        self.units
    }
}

/// GPU-topology-aware DP operator (paper Algorithm 4).
///
/// A state is `(a, b, c, d)` — the number of free chunks of sizes 1, 2, 4, 8
/// — linearized by mixed-radix encoding with bounds `(n1, n2, n4, n8)`.
/// Consuming `k ∈ {1,2,4,8}` GPUs takes the smallest free chunk of level
/// ≥ log2(k) and buddy-splits it (§5.3: "GPU Manager splits the chunk into
/// several legal chunks"); non-power-of-two `k` rounds up to the next legal
/// DoP, matching the manager's allocation rule.
#[derive(Debug, Clone)]
pub struct ChunkOperator {
    max: [u32; 4], // n1, n2, n4, n8 bounds
    avail: [u32; 4],
}

impl ChunkOperator {
    /// `avail[i]` = currently free chunks of size `2^i`; `max[i]` = bound on
    /// how many such chunks can ever exist (for the radix encoding). The
    /// natural bound for a cluster of `g` GPUs is `g / 2^i`.
    pub fn new(avail: [u32; 4], max: [u32; 4]) -> Self {
        for i in 0..4 {
            assert!(avail[i] <= max[i], "avail {avail:?} exceeds max {max:?}");
        }
        ChunkOperator { max, avail }
    }

    /// Convenience: bounds for a cluster of `total_gpus`.
    pub fn cluster_bounds(total_gpus: u32) -> [u32; 4] {
        [total_gpus, total_gpus / 2, total_gpus / 4, total_gpus / 8]
    }

    pub fn encode(&self, s: [u32; 4]) -> usize {
        let r1 = (self.max[0] + 1) as usize;
        let r2 = (self.max[1] + 1) as usize;
        let r4 = (self.max[2] + 1) as usize;
        s[0] as usize
            + r1 * (s[1] as usize + r2 * (s[2] as usize + r4 * s[3] as usize))
    }

    pub fn decode(&self, mut j: usize) -> [u32; 4] {
        let r1 = (self.max[0] + 1) as usize;
        let r2 = (self.max[1] + 1) as usize;
        let r4 = (self.max[2] + 1) as usize;
        let a = (j % r1) as u32;
        j /= r1;
        let b = (j % r2) as u32;
        j /= r2;
        let c = (j % r4) as u32;
        j /= r4;
        [a, b, c, j as u32]
    }

    /// Round `k` up to the next legal chunk level; `None` if k > 8.
    fn level_for(k: u64) -> Option<usize> {
        match k {
            1 => Some(0),
            2 => Some(1),
            3..=4 => Some(2),
            5..=8 => Some(3),
            _ => None,
        }
    }
}

impl DpOperator for ChunkOperator {
    fn num_states(&self) -> usize {
        (self.max[0] as usize + 1)
            * (self.max[1] as usize + 1)
            * (self.max[2] as usize + 1)
            * (self.max[3] as usize + 1)
    }

    fn full_state(&self) -> usize {
        self.encode(self.avail)
    }

    fn consume(&self, j: usize, k: u64) -> Option<usize> {
        if k == 0 {
            return Some(j);
        }
        let lvl = Self::level_for(k)?;
        let mut s = self.decode(j);
        // smallest free chunk at level ≥ lvl
        let src = (lvl..4).find(|&l| s[l] > 0)?;
        s[src] -= 1;
        // buddy-split down to the target level, leaving one free chunk at
        // each intermediate level
        for l in lvl..src {
            if s[l] >= self.max[l] {
                return None; // cannot represent (bound too tight) — reject
            }
            s[l] += 1;
        }
        Some(self.encode(s))
    }

    fn max_alloc(&self) -> u64 {
        (0..4).rev().find(|&l| self.avail[l] > 0).map_or(0, |l| 1 << l)
    }
}

/// Result of `dp_arrange`.
#[derive(Debug, Clone, PartialEq)]
pub struct Arrangement {
    /// Allocated units per task (same order as input).
    pub units: Vec<u64>,
    /// `Σ T_i(k_i)` under the optimal allocation, seconds.
    pub total_dur_secs: f64,
}

/// Topology-agnostic DPArrange (paper Algorithm 3), sparse formulation.
///
/// `unit_sets[i]` — feasible unit counts for task `i` (ascending);
/// `dur(i, k)` — execution duration of task `i` with `k` units.
/// Returns `None` when no feasible joint allocation exists.
///
/// §Perf note: the paper's pseudocode iterates the full state space
/// (`O(k·n²·m²)`); for the GPU chunk topology that is ~57k states per node
/// group and showed up as 40–200 ms per decision in `sched_hotpath`. The
/// set of states actually *reachable* by consume-chains from the start
/// state is tiny (bounded by `∏|S_i|`), so we propagate a sparse frontier
/// instead — identical results, ~100× faster (see EXPERIMENTS.md §Perf).
pub fn dp_arrange(
    op: &dyn DpOperator,
    unit_sets: &[Vec<u64>],
    dur: impl Fn(usize, u64) -> SimDur,
) -> Option<Arrangement> {
    let m = unit_sets.len();
    if m == 0 {
        return Some(Arrangement { units: vec![], total_dur_secs: 0.0 });
    }
    // Hybrid: small state spaces (flat pools — BasicOperator) are faster
    // with a dense table (no hashing); big ones (chunk topologies) need the
    // sparse frontier. Crossover measured in sched_hotpath.
    if op.num_states() <= 4096 {
        return dp_arrange_dense(op, unit_sets, dur);
    }
    let max_alloc = op.max_alloc();

    // frontier: reachable state -> best cost
    let mut dp: HashMap<usize, f64> = HashMap::with_capacity(64);
    dp.insert(op.full_state(), 0.0);
    // choice[i][state] = (units, prev_state) for backtracking
    let mut choice: Vec<HashMap<usize, (u64, usize)>> = Vec::with_capacity(m);

    for (i, set) in unit_sets.iter().enumerate() {
        // memoize durations per distinct k for this task
        let mut cur: HashMap<usize, f64> = HashMap::with_capacity(dp.len() * 2);
        let mut ch: HashMap<usize, (u64, usize)> = HashMap::with_capacity(dp.len() * 2);
        // Sorted frontier iteration: cost ties between predecessor states
        // must resolve identically in every process (HashMap order is
        // per-process random), or recorded scenario traces would not
        // replay byte-identically. Sorting fixes the tie-winner.
        // arl-lint: allow(nondet-iteration): collected then sorted on the
        // next line — iteration order is deterministic
        let mut frontier: Vec<(usize, f64)> = dp.iter().map(|(&j, &c)| (j, c)).collect();
        frontier.sort_unstable_by_key(|&(j, _)| j);
        for (j, base) in frontier {
            for &k in set {
                if k > max_alloc {
                    break; // sets ascend; nothing larger fits either
                }
                if let Some(j2) = op.consume(j, k) {
                    let cost = base + dur(i, k).secs_f64();
                    let slot = cur.entry(j2).or_insert(f64::INFINITY);
                    if cost < *slot {
                        *slot = cost;
                        ch.insert(j2, (k, j));
                    }
                }
            }
        }
        if cur.is_empty() {
            return None; // task i cannot be placed under any reachable state
        }
        dp = cur;
        choice.push(ch);
    }

    // best terminal state (ties broken by state id — see frontier note)
    let (mut state, total) = dp
        .iter() // arl-lint: allow(nondet-iteration): min_by fully tie-broken
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(a.0.cmp(b.0)))
        .map(|(&s, &c)| (s, c))?;

    // backtrack
    let mut units = vec![0u64; m];
    for i in (0..m).rev() {
        let (k, prev) = choice[i][&state];
        units[i] = k;
        state = prev;
    }
    Some(Arrangement { units, total_dur_secs: total })
}

/// Dense-table variant for small state spaces (the paper's literal Alg. 3
/// shape, minus the redundant `IsValid` — see module docs).
fn dp_arrange_dense(
    op: &dyn DpOperator,
    unit_sets: &[Vec<u64>],
    dur: impl Fn(usize, u64) -> SimDur,
) -> Option<Arrangement> {
    let m = unit_sets.len();
    let n = op.num_states();
    let max_alloc = op.max_alloc();
    const INF: f64 = f64::INFINITY;

    let mut dp = vec![INF; n];
    let mut cur = vec![INF; n];
    dp[op.full_state()] = 0.0;
    let mut choice: Vec<Vec<(u64, u32)>> = Vec::with_capacity(m);

    for (i, set) in unit_sets.iter().enumerate() {
        cur.iter_mut().for_each(|x| *x = INF);
        let mut ch = vec![(0u64, u32::MAX); n];
        let mut any = false;
        for (j, &base) in dp.iter().enumerate() {
            if base.is_infinite() {
                continue;
            }
            for &k in set {
                if k > max_alloc {
                    break;
                }
                if let Some(j2) = op.consume(j, k) {
                    let cost = base + dur(i, k).secs_f64();
                    if cost < cur[j2] {
                        cur[j2] = cost;
                        ch[j2] = (k, j as u32);
                        any = true;
                    }
                }
            }
        }
        if !any {
            return None;
        }
        std::mem::swap(&mut dp, &mut cur);
        choice.push(ch);
    }

    let (mut state, best) = dp
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())?;
    if best.is_infinite() {
        return None;
    }
    let total = *best;
    let mut units = vec![0u64; m];
    for i in (0..m).rev() {
        let (k, prev) = choice[i][state];
        debug_assert_ne!(prev, u32::MAX, "broken backtrack at task {i}");
        units[i] = k;
        state = prev as usize;
    }
    Some(Arrangement { units, total_dur_secs: total })
}

/// Brute-force reference for testing: enumerate the cartesian product.
#[cfg(test)]
pub fn brute_force(
    op: &dyn DpOperator,
    unit_sets: &[Vec<u64>],
    dur: impl Fn(usize, u64) -> SimDur + Copy,
) -> Option<Arrangement> {
    fn rec(
        op: &dyn DpOperator,
        sets: &[Vec<u64>],
        dur: impl Fn(usize, u64) -> SimDur + Copy,
        i: usize,
        state: usize,
        acc: f64,
        picks: &mut Vec<u64>,
        best: &mut Option<Arrangement>,
    ) {
        if i == sets.len() {
            if best.as_ref().map_or(true, |b| acc < b.total_dur_secs) {
                *best = Some(Arrangement { units: picks.clone(), total_dur_secs: acc });
            }
            return;
        }
        for &k in &sets[i] {
            if let Some(s2) = op.consume(state, k) {
                picks.push(k);
                rec(op, sets, dur, i + 1, s2, acc + dur(i, k).secs_f64(), picks, best);
                picks.pop();
            }
        }
    }
    let mut best = None;
    let mut picks = Vec::new();
    rec(op, unit_sets, dur, 0, op.full_state(), 0.0, &mut picks, &mut best);
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::action::ElasticityModel;

    fn perfect_dur(t_secs: u64) -> impl Fn(usize, u64) -> SimDur + Copy {
        move |_, k| {
            ElasticityModel::PerfectScaling.scaled_dur(SimDur::from_secs(t_secs), k)
        }
    }

    #[test]
    fn basic_single_task_takes_everything() {
        let op = BasicOperator::new(8);
        let sets = vec![(1..=8).collect::<Vec<u64>>()];
        let arr = dp_arrange(&op, &sets, perfect_dur(8)).unwrap();
        assert_eq!(arr.units, vec![8]);
        assert!((arr.total_dur_secs - 1.0).abs() < 1e-9);
    }

    #[test]
    fn basic_two_tasks_split_evenly_when_identical() {
        let op = BasicOperator::new(8);
        let sets = vec![(1..=8).collect::<Vec<u64>>(), (1..=8).collect::<Vec<u64>>()];
        let arr = dp_arrange(&op, &sets, perfect_dur(8)).unwrap();
        assert_eq!(arr.units.iter().sum::<u64>(), 8);
        // 8/m4 + 8/4 = 4 is optimal (any split summing 8 with equal perfect
        // scaling gives ≥ 4; 4+4 achieves 4).
        assert!((arr.total_dur_secs - 4.0).abs() < 1e-9);
        assert_eq!(arr.units, vec![4, 4]);
    }

    #[test]
    fn favors_the_long_task() {
        // task0: 16s perfect-scaling, task1: 2s fixed 1 unit
        let op = BasicOperator::new(4);
        let sets = vec![vec![1, 2, 3], vec![1]];
        let arr = dp_arrange(&op, &sets, |i, k| {
            if i == 0 {
                ElasticityModel::PerfectScaling.scaled_dur(SimDur::from_secs(16), k)
            } else {
                SimDur::from_secs(2)
            }
        })
        .unwrap();
        assert_eq!(arr.units, vec![3, 1]);
    }

    #[test]
    fn infeasible_when_min_exceeds_capacity() {
        let op = BasicOperator::new(3);
        let sets = vec![vec![2], vec![2]];
        assert!(dp_arrange(&op, &sets, perfect_dur(1)).is_none());
    }

    #[test]
    fn empty_task_list_is_trivially_feasible() {
        let op = BasicOperator::new(3);
        let arr = dp_arrange(&op, &[], perfect_dur(1)).unwrap();
        assert!(arr.units.is_empty());
        assert_eq!(arr.total_dur_secs, 0.0);
    }

    #[test]
    fn matches_brute_force_basic() {
        // randomized-ish small instances, deterministic seeds
        let cases: Vec<(u64, Vec<Vec<u64>>, Vec<u64>)> = vec![
            (6, vec![vec![1, 2, 4], vec![1, 3], vec![1]], vec![10, 6, 3]),
            (5, vec![vec![1, 2], vec![1, 2], vec![1, 2]], vec![4, 9, 2]),
            (10, vec![vec![2, 4, 8], vec![1, 5]], vec![12, 7]),
        ];
        for (units, sets, durs) in cases {
            let op = BasicOperator::new(units);
            let dur = |i: usize, k: u64| {
                ElasticityModel::Amdahl { serial_frac: 0.1 }
                    .scaled_dur(SimDur::from_secs(durs[i]), k)
            };
            let a = dp_arrange(&op, &sets, dur);
            let b = brute_force(&op, &sets, dur);
            match (a, b) {
                (Some(a), Some(b)) => {
                    assert!((a.total_dur_secs - b.total_dur_secs).abs() < 1e-9)
                }
                (None, None) => {}
                (a, b) => panic!("mismatch {a:?} vs {b:?}"),
            }
        }
    }

    // -- chunk operator -------------------------------------------------------

    #[test]
    fn chunk_encode_decode_roundtrip() {
        let op = ChunkOperator::new([3, 2, 1, 2], [8, 4, 2, 2]);
        for a in 0..=8u32 {
            for b in 0..=4 {
                for c in 0..=2 {
                    for d in 0..=2 {
                        let s = [a, b, c, d];
                        assert_eq!(op.decode(op.encode(s)), s);
                    }
                }
            }
        }
    }

    #[test]
    fn chunk_consume_exact_size() {
        // one free 8-chunk
        let op = ChunkOperator::new([0, 0, 0, 1], [8, 4, 2, 1]);
        let j = op.full_state();
        let j2 = op.consume(j, 8).unwrap();
        assert_eq!(op.decode(j2), [0, 0, 0, 0]);
    }

    #[test]
    fn chunk_consume_splits_buddies() {
        // allocating 1 GPU from a free 8-chunk leaves 1+2+4 free
        let op = ChunkOperator::new([0, 0, 0, 1], [8, 4, 2, 1]);
        let j2 = op.consume(op.full_state(), 1).unwrap();
        assert_eq!(op.decode(j2), [1, 1, 1, 0]);
    }

    #[test]
    fn chunk_rounds_up_odd_requests() {
        // k=3 consumes a 4-chunk
        let op = ChunkOperator::new([0, 0, 2, 0], [8, 4, 2, 1]);
        let j2 = op.consume(op.full_state(), 3).unwrap();
        assert_eq!(op.decode(j2), [0, 0, 1, 0]);
    }

    #[test]
    fn chunk_infeasible_when_fragmented() {
        // 8 GPUs free but as 8 singles: a DoP-8 service cannot be placed
        let op = ChunkOperator::new([8, 0, 0, 0], [8, 4, 2, 1]);
        assert_eq!(op.consume(op.full_state(), 8), None);
        assert_eq!(op.consume(op.full_state(), 2), None);
        assert!(op.consume(op.full_state(), 1).is_some());
        assert_eq!(op.max_alloc(), 1);
    }

    #[test]
    fn chunk_rejects_oversize() {
        let op = ChunkOperator::new([0, 0, 0, 1], [8, 4, 2, 1]);
        assert_eq!(op.consume(op.full_state(), 9), None);
    }

    #[test]
    fn dp_arrange_over_chunks() {
        // Cluster: two free 8-chunks. Tasks: one elastic service (DoP 1/2/4/8)
        // with an 8s profile, one fixed DoP-4, one fixed DoP-1.
        let bounds = ChunkOperator::cluster_bounds(16);
        let op = ChunkOperator::new([0, 0, 0, 2], bounds);
        let sets = vec![vec![1, 2, 4, 8], vec![4], vec![1]];
        let arr = dp_arrange(&op, &sets, |i, k| match i {
            0 => ElasticityModel::Table(vec![1.0, 0.95, 0.85, 0.85, 0.7, 0.7, 0.7, 0.7])
                .scaled_dur(SimDur::from_secs(8), k),
            1 => SimDur::from_secs(3),
            _ => SimDur::from_secs(1),
        })
        .unwrap();
        // elastic service should take the whole second 8-chunk
        assert_eq!(arr.units[0], 8);
        assert_eq!(arr.units[1], 4);
        assert_eq!(arr.units[2], 1);
        // cross-check vs brute force
        let bf = brute_force(&op, &sets, |i, k| match i {
            0 => ElasticityModel::Table(vec![1.0, 0.95, 0.85, 0.85, 0.7, 0.7, 0.7, 0.7])
                .scaled_dur(SimDur::from_secs(8), k),
            1 => SimDur::from_secs(3),
            _ => SimDur::from_secs(1),
        })
        .unwrap();
        assert!((arr.total_dur_secs - bf.total_dur_secs).abs() < 1e-9);
    }
}
