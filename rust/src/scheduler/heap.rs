//! Completion heap used by the ACT-approximation (Algorithm 2).
//!
//! A min-heap of `(completion time, units freed)` for scheduled/executing
//! actions, plus free capacity available immediately. `estimate` simulates
//! draining the remaining waiting queue onto freed *units* to approximate
//! the ACTs of actions behind the current candidates (paper §4.2).
//!
//! Deviation from the paper's pseudocode, documented: Algorithm 2's heap
//! holds bare timestamps and a pop stands for "some resources freed". That
//! slot model under-counts the cost of wide allocations (a 32-core action
//! frees one *slot* but 32 cores), which made the greedy eviction blind to
//! saturation. We track freed units explicitly — same algorithm, honest
//! capacity accounting.

use crate::sim::{SimDur, SimTime};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Min-heap of (completion time, units).
#[derive(Debug, Clone, Default)]
pub struct CompletionHeap {
    heap: BinaryHeap<(Reverse<SimTime>, u64)>,
    total_units: u64,
}

impl CompletionHeap {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_entries(entries: impl IntoIterator<Item = (SimTime, u64)>) -> Self {
        let mut h = Self::new();
        for (t, u) in entries {
            h.push(t, u);
        }
        h
    }

    pub fn push(&mut self, t: SimTime, units: u64) {
        if units == 0 {
            return;
        }
        self.total_units += units;
        self.heap.push((Reverse(t), units));
    }

    /// Earliest (time, units) entry.
    pub fn pop(&mut self) -> Option<(SimTime, u64)> {
        let (Reverse(t), u) = self.heap.pop()?;
        self.total_units -= u;
        Some((t, u))
    }

    pub fn peek(&self) -> Option<SimTime> {
        self.heap.peek().map(|&(Reverse(t), _)| t)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn total_units(&self) -> u64 {
        self.total_units
    }

    /// When do `need` units accumulate, starting from the earliest entries?
    /// Consumes those entries; re-pushes any surplus at the ready time.
    /// Returns `None` if the heap can never supply `need` units.
    fn acquire(&mut self, need: u64) -> Option<SimTime> {
        if need == 0 {
            return self.peek();
        }
        if self.total_units < need {
            return None;
        }
        let mut acc = 0u64;
        let mut ready = SimTime::ZERO;
        while acc < need {
            let (t, u) = self.pop()?;
            acc += u;
            ready = ready.max(t);
        }
        if acc > need {
            self.push(ready, acc - need);
        }
        Some(ready)
    }

    /// Estimate the summed remaining ACTs of the waiting tail (Algorithm 2,
    /// `ESTIMATE`): action `i` needs `units(i)` units for `dur(i, units)`;
    /// the first action explores each allocation in `explore` ("the first
    /// remaining action … explores multiple allocation choices", §4.2) and
    /// the best lookahead wins. `now` anchors remaining-ACT accounting.
    pub fn estimate<U, F>(&self, now: SimTime, rest: usize, explore: &[u64], units: U, dur: F) -> f64
    where
        U: Fn(usize) -> u64,
        F: Fn(usize, u64) -> SimDur,
    {
        if rest == 0 {
            return 0.0;
        }
        let cap = self.total_units.max(1);
        let mut best = f64::INFINITY;
        let one = [1u64];
        let explore = if explore.is_empty() { &one[..] } else { explore };
        for &d in explore {
            let mut heap = self.clone();
            let mut obj = 0.0;
            for i in 0..rest {
                let want = if i == 0 { d } else { units(i) };
                let want = want.clamp(1, cap);
                let ready = match heap.acquire(want) {
                    Some(t) => t.max(now),
                    None => {
                        obj = f64::INFINITY;
                        break;
                    }
                };
                let done = ready + dur(i, want);
                obj += (done - now).secs_f64();
                heap.push(done, want);
            }
            if obj < best {
                best = obj;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_order_and_tracks_units() {
        let mut h = CompletionHeap::from_entries([
            (SimTime(30), 2),
            (SimTime(10), 4),
            (SimTime(20), 1),
        ]);
        assert_eq!(h.total_units(), 7);
        assert_eq!(h.pop(), Some((SimTime(10), 4)));
        assert_eq!(h.peek(), Some(SimTime(20)));
        assert_eq!(h.total_units(), 3);
    }

    #[test]
    fn acquire_accumulates_units() {
        let mut h = CompletionHeap::from_entries([
            (SimTime(10), 2),
            (SimTime(20), 2),
            (SimTime(30), 4),
        ]);
        // 3 units need the first two entries → ready at t=20, 1 surplus
        assert_eq!(h.acquire(3), Some(SimTime(20)));
        assert_eq!(h.total_units(), 5); // 1 surplus + 4
        assert_eq!(h.acquire(100), None);
    }

    #[test]
    fn estimate_empty_rest_is_zero() {
        let h = CompletionHeap::from_entries([(SimTime(5), 1)]);
        assert_eq!(h.estimate(SimTime(0), 0, &[1, 2, 3], |_| 1, |_, _| SimDur(1)), 0.0);
    }

    #[test]
    fn estimate_sequential_fill() {
        // one 1-unit slot frees at t=10; two 1-unit 5s actions run
        // back-to-back: remaining ACTs 15 and 20 → 35.
        let h = CompletionHeap::from_entries([(SimTime(10_000_000_000), 1)]);
        let e = h.estimate(SimTime(0), 2, &[1], |_| 1, |_, _| SimDur::from_secs(5));
        assert!((e - 35.0).abs() < 1e-9, "{e}");
    }

    #[test]
    fn estimate_depth_picks_best_first_allocation() {
        // 8 units free now; first action scales perfectly (8s at 1 unit)
        let h = CompletionHeap::from_entries([(SimTime::ZERO, 8)]);
        let shallow = h.estimate(SimTime::ZERO, 1, &[1], |_| 1, |_, d| SimDur::from_secs(8 / d));
        let deep = h.estimate(SimTime::ZERO, 1, &[1, 8], |_| 1, |_, d| SimDur::from_secs(8 / d));
        assert!(deep < shallow);
        assert!((deep - 1.0).abs() < 1e-9);
    }

    #[test]
    fn estimate_respects_unit_capacity() {
        // two 4-unit slots free now; two actions needing 4 units for 5s run
        // in parallel (5+5), but two 8-unit actions must serialize.
        let h = CompletionHeap::from_entries([(SimTime::ZERO, 4), (SimTime::ZERO, 4)]);
        let par = h.estimate(SimTime::ZERO, 2, &[4], |_| 4, |_, _| SimDur::from_secs(5));
        assert!((par - 10.0).abs() < 1e-9, "{par}");
        let ser = h.estimate(SimTime::ZERO, 2, &[8], |_| 8, |_, _| SimDur::from_secs(5));
        // first takes all 8 (d=8 explored) → 5s; second waits → 10s; total 15
        assert!((ser - 15.0).abs() < 1e-9, "{ser}");
    }

    #[test]
    fn estimate_infeasible_needs_are_clamped() {
        let h = CompletionHeap::from_entries([(SimTime::ZERO, 2)]);
        // wants 10 units but pool is 2 → clamped to 2, still finite
        let e = h.estimate(SimTime::ZERO, 1, &[10], |_| 10, |_, _| SimDur::from_secs(1));
        assert!(e.is_finite());
    }
}
