//! Elastic action-level scheduler (paper §4.2, Algorithms 1–2).
//!
//! Invoked by the coordinator whenever resources free up or actions arrive.
//! FCFS determines ordering (starvation would invalidate whole
//! trajectories); the algorithm decides *how many units* each candidate
//! gets, via greedy eviction over an approximated ACT objective, with
//! `DPArrange` (Algorithm 3) resolving optimal discrete allocations on the
//! resource topology.

pub mod dp;
pub mod heap;

pub use dp::{dp_arrange, Arrangement, BasicOperator, ChunkOperator, DpOperator};
pub use heap::CompletionHeap;

use crate::action::{
    Action, ActionId, ActionKind, ResourceKindId, ResourceVector,
};
use crate::sim::{SimDur, SimTime};
use std::collections::HashMap;

/// Scheduler tunables.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Lookahead depth of the objective approximation (paper: 2–3 suffices).
    pub depth: u64,
    /// Upper bound on the candidate window (keeps the decision latency
    /// within the sub-ms budget under bursty queues).
    pub max_candidates: usize,
    /// Fallback duration estimate when nothing is profiled or observed yet.
    pub default_dur: SimDur,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            depth: 2,
            max_candidates: 32,
            default_dur: SimDur::from_millis(500),
        }
    }
}

/// Sorted-vec index map from resource kind to pool view — the scheduler's
/// per-decision replacement for `BTreeMap<ResourceKindId, &dyn ResourceState>`.
/// A pool exposes a handful of kinds (typically one), so a binary-searched
/// `Vec` beats tree nodes on both build and iteration cost in the per-drain
/// hot path while keeping the property the determinism lint's ordering
/// contract requires: iteration is sorted by kind, never hash order.
#[derive(Default)]
pub struct ResourceMap<'a> {
    entries: Vec<(ResourceKindId, &'a dyn ResourceState)>,
}

impl<'a> ResourceMap<'a> {
    pub fn new() -> Self {
        ResourceMap { entries: Vec::new() }
    }

    /// Insert (or replace) the view for `kind`, keeping entries sorted.
    pub fn insert(&mut self, kind: ResourceKindId, res: &'a dyn ResourceState) {
        match self.entries.binary_search_by_key(&kind, |e| e.0) {
            Ok(i) => self.entries[i].1 = res,
            Err(i) => self.entries.insert(i, (kind, res)),
        }
    }

    pub fn get(&self, kind: ResourceKindId) -> Option<&'a dyn ResourceState> {
        self.entries.binary_search_by_key(&kind, |e| e.0).ok().map(|i| self.entries[i].1)
    }

    pub fn contains_key(&self, kind: ResourceKindId) -> bool {
        self.entries.binary_search_by_key(&kind, |e| e.0).is_ok()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Entries in ascending kind order (the deterministic iteration order
    /// every scheduling decision depends on).
    pub fn iter(&self) -> impl Iterator<Item = (ResourceKindId, &'a dyn ResourceState)> + '_ {
        self.entries.iter().map(|&(k, r)| (k, r))
    }
}

/// View of one resource pool that the scheduler needs: quantities, topology
/// feasibility, and a DP operator. Implemented by the resource managers
/// (§5's "standardized interface").
pub trait ResourceState {
    /// Remaining units of this kind.
    fn available_units(&self) -> u64;

    /// Topology check: can actions with these per-action unit minimums all
    /// be placed simultaneously right now?
    fn accommodate(&self, min_units: &[u64]) -> bool;

    /// DP operator over the current availability with `reserved` allocations
    /// pre-consumed (unit amounts belonging to co-scheduled actions whose
    /// key elasticity resource is a *different* kind).
    fn dp_operator(&self, reserved: &[u64]) -> Box<dyn DpOperator>;

    /// Expected completion times and held units of actions currently
    /// executing on this kind (seeds the completion heap of Algorithm 2).
    fn running_completions(&self) -> Vec<(SimTime, u64)>;
}

/// Historical execution-duration averages per action kind (EWMA). Used for
/// unprofiled actions in heap estimates — the paper accepts historical
/// averages because "scalable actions typically last much longer … and
/// dominate the evolution of the completion heap".
#[derive(Debug, Clone, Default)]
pub struct DurationStats {
    ewma: HashMap<ActionKind, f64>,
}

impl DurationStats {
    const ALPHA: f64 = 0.1;

    pub fn observe(&mut self, kind: ActionKind, dur: SimDur) {
        let x = dur.secs_f64();
        self.ewma
            .entry(kind)
            .and_modify(|m| *m += Self::ALPHA * (x - *m))
            .or_insert(x);
    }

    pub fn estimate(&self, kind: ActionKind, default: SimDur) -> SimDur {
        self.ewma
            .get(&kind)
            .map(|m| SimDur::from_secs_f64(*m))
            .unwrap_or(default)
    }
}

/// One scheduling decision: run `action` now with `units` of its key
/// resource (and its minimums on every other dimension).
#[derive(Debug, Clone, PartialEq)]
pub struct Decision {
    pub action: ActionId,
    /// Units of the key elasticity resource (== the key-dim minimum for
    /// non-scalable actions).
    pub units: u64,
    /// Full allocation vector across all kinds.
    pub alloc: ResourceVector,
}

/// The elastic scheduler. Stateless apart from duration statistics; the
/// coordinator owns queues and resource managers.
#[derive(Debug, Default)]
pub struct ElasticScheduler {
    pub cfg: SchedulerConfig,
    pub stats: DurationStats,
}

impl ElasticScheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        ElasticScheduler { cfg, stats: DurationStats::default() }
    }

    /// Best-known execution-duration estimate for `a` at `m` units.
    fn est(&self, a: &Action, m: u64) -> SimDur {
        a.spec
            .est_dur(m)
            .unwrap_or_else(|| self.stats.estimate(a.spec.kind, self.cfg.default_dur))
    }

    /// Algorithm 1. `queue` is the FCFS waiting queue; `resources[kind]`
    /// exposes each pool. Returns decisions for the selected actions
    /// (everything else stays queued). The resource map is a sorted-vec
    /// [`ResourceMap`] so every iteration over it is sorted by kind —
    /// scheduling decisions must replay byte-identically and hash order is
    /// per-process random.
    pub fn schedule(
        &self,
        now: SimTime,
        queue: &[&Action],
        resources: &ResourceMap<'_>,
    ) -> Vec<Decision> {
        if queue.is_empty() {
            return vec![];
        }
        // ---- candidate selection (Alg 1 line 2) --------------------------
        // Largest FCFS prefix whose summed minimum requirements fit every
        // pool by quantity, and whose per-action minimums the topologies can
        // accommodate.
        let mut cand: Vec<&Action> = Vec::new();
        // Per-decision budget index: sorted kind → remaining units. Mirrors
        // the ResourceMap's order; binary-searched instead of tree-walked so
        // the hot path allocates one flat Vec, not a node per kind.
        let mut budget: Vec<(ResourceKindId, u64)> =
            resources.iter().map(|(k, r)| (k, r.available_units())).collect();
        'outer: for &a in queue.iter().take(self.cfg.max_candidates) {
            // quantity check
            for (kind, dim) in a.spec.cost.iter() {
                let need = dim.min_units();
                if need == 0 {
                    continue;
                }
                match budget.binary_search_by_key(&kind, |e| e.0) {
                    Ok(i) if budget[i].1 >= need => {}
                    _ => break 'outer,
                }
            }
            // topology check on the grown prefix, per kind
            let mut ok = true;
            for (kind, res) in resources.iter() {
                let mins: Vec<u64> = cand
                    .iter()
                    .chain(std::iter::once(&a))
                    .map(|c| c.spec.cost.dim(kind).min_units())
                    .filter(|&m| m > 0)
                    .collect();
                if !mins.is_empty() && !res.accommodate(&mins) {
                    ok = false;
                    break;
                }
            }
            if !ok {
                break;
            }
            for (kind, dim) in a.spec.cost.iter() {
                if dim.min_units() > 0 {
                    let i = budget
                        .binary_search_by_key(&kind, |e| e.0)
                        .expect("budget kind vanished between checks");
                    budget[i].1 -= dim.min_units();
                }
            }
            cand.push(a);
        }
        if cand.is_empty() {
            return vec![];
        }

        // ---- group by key elasticity resource (Alg 1 lines 3-4) ----------
        // Actions whose key resource is a given kind form that kind's group;
        // their minimums on *other* kinds stay fixed (the single-key-resource
        // assumption of §4.1 decouples the groups). Sorted-vec insert keeps
        // the deterministic ascending-kind group order the BTreeMap used to
        // provide.
        let mut selected: Vec<Decision> = Vec::new();
        let mut grouped: Vec<(ResourceKindId, &dyn ResourceState, Vec<&Action>)> = Vec::new();
        for &a in &cand {
            match a.spec.key_resource.and_then(|k| resources.get(k).map(|r| (k, r))) {
                Some((k, res)) => match grouped.binary_search_by_key(&k, |e| e.0) {
                    Ok(i) => grouped[i].2.push(a),
                    Err(i) => grouped.insert(i, (k, res, vec![a])),
                },
                None => selected.push(min_decision(a)),
            }
        }

        // grouped entries are already in ascending kind order
        for (kind, res, group) in &grouped {
            let kind = *kind;
            let res = *res;

            // Alg 1 lines 5-6: if elasticity is unknown (or zero) for every
            // member, select all at minimum units.
            if group.iter().all(|a| !a.spec.is_scalable()) {
                selected.extend(group.iter().map(|a| min_decision(a)));
                continue;
            }

            // units already pinned on this kind by candidates keyed elsewhere
            let reserved: Vec<u64> = cand
                .iter()
                .filter(|a| a.spec.key_resource != Some(kind))
                .map(|a| a.spec.cost.dim(kind).min_units())
                .filter(|&m| m > 0)
                .collect();
            let reserved_sum: u64 = reserved.iter().sum();
            let budget = res.available_units().saturating_sub(reserved_sum);

            // waiting-queue tail on this kind (actions behind the candidate
            // window) — the `AC_j` of Algorithm 2.
            let tail: Vec<&Action> = queue
                .iter()
                .skip(cand.len())
                .filter(|a| a.spec.key_resource == Some(kind))
                .copied()
                .collect();

            // Reserve minimum units for the visible waiting tail so the DP
            // does not hand the entire pool to the current candidates and
            // starve imminent arrivals (honest-capacity variant of Alg. 1;
            // falls back to the unreserved pool when minimums don't fit).
            let tail_reserve: u64 = tail
                .iter()
                .take(self.cfg.max_candidates)
                .map(|a| a.spec.cost.dim(kind).min_units())
                .sum();
            let min_needed: u64 = group
                .iter()
                .map(|a| a.spec.cost.dim(kind).min_units())
                .sum();
            let mut with_tail = reserved.clone();
            let spare = budget.saturating_sub(min_needed);
            if tail_reserve > 0 && tail_reserve <= spare {
                with_tail.push(tail_reserve.min(spare));
            }
            let op = res.dp_operator(&with_tail);
            let heap = CompletionHeap::from_entries(res.running_completions());

            // ---- greedy eviction (Alg 1 lines 7-11) -----------------------
            let mut evict = 0usize;
            let mut best_obj = f64::INFINITY;
            let mut best_arr: Option<Arrangement> = None;
            // t runs to |C_j| inclusive (paper Alg. 1 line 8): evicting the
            // whole group means "wait for more capacity instead of starting
            // now" — crucial when one freed core would otherwise trap a
            // long scalable action at DoP 1.
            //
            // Deviation from the paper's early break (`newObj >= obj`):
            // evicting a cheap action (a 1-core env exec) is often obj-
            // neutral, and breaking there hides the strictly better deeper
            // levels (e.g. full eviction). We scan all |C_j|+1 levels and
            // take the argmin — same asymptotics (window-bounded), strictly
            // better decisions.
            for t in 0..=group.len() {
                let keep = &group[..group.len() - t];
                let evicted = &group[group.len() - t..];
                let (obj, arr) = self.approx_objective(
                    now, kind, budget, keep, evicted, &tail, op.as_ref(), &heap,
                );
                if obj < best_obj {
                    best_obj = obj;
                    best_arr = arr;
                    evict = t;
                }
            }

            let keep = &group[..group.len() - evict];
            match best_arr {
                Some(arr) => {
                    for (a, &units) in keep.iter().zip(&arr.units) {
                        let mut alloc = a.spec.cost.min_vector();
                        alloc.set(kind, units);
                        selected.push(Decision { action: a.id, units, alloc });
                    }
                }
                // No feasible arrangement even at minimums (topology moved
                // under us) — fall back to minimum decisions; the managers'
                // allocate() will reject what truly cannot be placed.
                None => selected.extend(keep.iter().map(|a| min_decision(a))),
            }
        }
        selected
    }

    /// Algorithm 2: approximated total-ACT objective of scheduling `keep`
    /// now (exact part via DPArrange) plus the estimated ACTs of
    /// `evicted ++ tail` drained through the unit-aware completion heap.
    #[allow(clippy::too_many_arguments)]
    fn approx_objective(
        &self,
        now: SimTime,
        kind: ResourceKindId,
        budget: u64,
        keep: &[&Action],
        evicted: &[&Action],
        tail: &[&Action],
        op: &dyn DpOperator,
        heap: &CompletionHeap,
    ) -> (f64, Option<Arrangement>) {
        // Exact part: optimal allocation among kept candidates.
        let sets: Vec<Vec<u64>> = keep
            .iter()
            .map(|a| {
                if a.spec.is_scalable() {
                    a.spec.cost.dim(a.spec.key_resource.unwrap()).choices()
                } else {
                    vec![a.spec.cost.dim(a.spec.key_resource.unwrap()).min_units()]
                }
            })
            .collect();
        let arr = match dp_arrange(op, &sets, |i, k| self.est(keep[i], k)) {
            Some(a) => a,
            None => return (f64::INFINITY, None),
        };

        // Updated heap: kept candidates complete at now + dur, freeing their
        // units; capacity not taken by them is free immediately.
        let mut h = heap.clone();
        let mut taken = 0u64;
        for (a, &units) in keep.iter().zip(&arr.units) {
            h.push(now + self.est(a, units), units.max(1));
            taken += units;
        }
        h.push(now, budget.saturating_sub(taken));

        // Estimated part: evicted candidates first (they re-queue at the
        // front), then the waiting tail. The first remaining action explores
        // `depth` allocation choices spread across its feasible unit set
        // (min … max), so "wait for a wide allocation" is a visible option.
        let rest: Vec<&Action> = evicted.iter().chain(tail.iter()).copied().collect();
        let explore: Vec<u64> = rest
            .first()
            .map(|a| {
                let choices = a.spec.cost.dim(kind).choices();
                spread(&choices, self.cfg.depth as usize)
            })
            .unwrap_or_default();
        let est = h.estimate(
            now,
            rest.len(),
            &explore,
            |i| rest[i].spec.cost.dim(kind).min_units().max(1),
            |i, u| self.est(rest[i], u),
        );
        (arr.total_dur_secs + est, Some(arr))
    }
}

/// Pick roughly `n` values spread across a sorted choice set, always
/// including both extremes (the depth-bounded exploration of Algorithm 2).
/// The extremes are non-negotiable — "wait for the wide allocation" must
/// stay a visible option — so a budget of `n == 1` still yields both ends
/// (the old code returned only `choices[0]`, blinding depth-1 configs to
/// wide allocations).
fn spread(choices: &[u64], n: usize) -> Vec<u64> {
    if choices.is_empty() || n == 0 {
        return vec![1];
    }
    let n = n.max(2);
    if choices.len() <= n {
        return choices.to_vec();
    }
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let idx = i * (choices.len() - 1) / (n - 1);
        out.push(choices[idx]);
    }
    out.dedup();
    out
}

/// Minimum-allocation decision for non-scalable / key-less actions.
fn min_decision(a: &Action) -> Decision {
    let alloc = a.spec.cost.min_vector();
    let units = a
        .spec
        .key_resource
        .map(|k| alloc.get(k))
        .unwrap_or(0);
    Decision { action: a.id, units, alloc }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::action::{
        ActionSpec, CostSpec, DimCost, ElasticityModel, ResourceClass,
        ResourceRegistry, TaskId, TenantId, TrajId,
    };

    /// Flat-pool resource for tests.
    struct Pool {
        units: u64,
        running: Vec<(SimTime, u64)>,
    }

    impl ResourceState for Pool {
        fn available_units(&self) -> u64 {
            self.units
        }
        fn accommodate(&self, mins: &[u64]) -> bool {
            mins.iter().sum::<u64>() <= self.units
        }
        fn dp_operator(&self, reserved: &[u64]) -> Box<dyn DpOperator> {
            let used: u64 = reserved.iter().sum();
            Box::new(BasicOperator::new(self.units.saturating_sub(used)))
        }
        fn running_completions(&self) -> Vec<(SimTime, u64)> {
            self.running.clone()
        }
    }

    fn reg() -> (ResourceRegistry, ResourceKindId) {
        let mut r = ResourceRegistry::new();
        let cpu = r.register("cpu", ResourceClass::CpuCores, 16);
        (r, cpu)
    }

    fn scalable(reg: &ResourceRegistry, kind: ResourceKindId, id: u64, secs: u64, max: u64) -> Action {
        let spec = ActionSpec {
            task: TaskId(0),
            tenant: TenantId(0),
            trajectory: TrajId(id),
            kind: ActionKind::RewardCpu,
            cost: CostSpec::single(reg, kind, DimCost::Range { min: 1, max }),
            key_resource: Some(kind),
            elasticity: ElasticityModel::PerfectScaling,
            profiled_dur: Some(SimDur::from_secs(secs)),
            service: None,
            true_dur: SimDur::from_secs(secs),
        };
        Action::new(ActionId(id), spec, SimTime::ZERO)
    }

    fn rigid(reg: &ResourceRegistry, kind: ResourceKindId, id: u64, units: u64) -> Action {
        let spec = ActionSpec {
            task: TaskId(0),
            tenant: TenantId(0),
            trajectory: TrajId(id),
            kind: ActionKind::EnvExec,
            cost: CostSpec::single(reg, kind, DimCost::Fixed(units)),
            key_resource: Some(kind),
            elasticity: ElasticityModel::None,
            profiled_dur: Some(SimDur::from_secs(1)),
            service: None,
            true_dur: SimDur::from_secs(1),
        };
        Action::new(ActionId(id), spec, SimTime::ZERO)
    }

    fn run(
        sched: &ElasticScheduler,
        queue: &[&Action],
        pool: &Pool,
        kind: ResourceKindId,
    ) -> Vec<Decision> {
        let mut map = ResourceMap::new();
        map.insert(kind, pool);
        sched.schedule(SimTime::ZERO, queue, &map)
    }

    #[test]
    fn resource_map_is_sorted_and_replaces_on_duplicate_insert() {
        let a = Pool { units: 3, running: vec![] };
        let b = Pool { units: 7, running: vec![] };
        let mut map = ResourceMap::new();
        assert!(map.is_empty());
        map.insert(ResourceKindId(9), &a);
        map.insert(ResourceKindId(2), &b);
        map.insert(ResourceKindId(5), &a);
        assert_eq!(map.len(), 3);
        let kinds: Vec<u32> = map.iter().map(|(k, _)| k.0).collect();
        assert_eq!(kinds, vec![2, 5, 9], "iteration must be ascending by kind");
        assert!(map.contains_key(ResourceKindId(5)));
        assert!(!map.contains_key(ResourceKindId(4)));
        assert_eq!(map.get(ResourceKindId(2)).map(|r| r.available_units()), Some(7));
        // duplicate insert replaces the view, not the ordering
        map.insert(ResourceKindId(2), &a);
        assert_eq!(map.len(), 3);
        assert_eq!(map.get(ResourceKindId(2)).map(|r| r.available_units()), Some(3));
    }

    #[test]
    fn empty_queue_no_decisions() {
        let (r, cpu) = reg();
        let _ = r;
        let sched = ElasticScheduler::default();
        let pool = Pool { units: 16, running: vec![] };
        assert!(run(&sched, &[], &pool, cpu).is_empty());
    }

    #[test]
    fn single_scalable_action_gets_all_units() {
        let (r, cpu) = reg();
        let sched = ElasticScheduler::default();
        let a = scalable(&r, cpu, 1, 16, 16);
        let pool = Pool { units: 16, running: vec![] };
        let d = run(&sched, &[&a], &pool, cpu);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].units, 16);
    }

    #[test]
    fn rigid_actions_get_min_units() {
        let (r, cpu) = reg();
        let sched = ElasticScheduler::default();
        let a = rigid(&r, cpu, 1, 2);
        let b = rigid(&r, cpu, 2, 3);
        let pool = Pool { units: 16, running: vec![] };
        let d = run(&sched, &[&a, &b], &pool, cpu);
        assert_eq!(d.len(), 2);
        assert_eq!(d[0].units, 2);
        assert_eq!(d[1].units, 3);
    }

    #[test]
    fn candidate_window_respects_capacity() {
        let (r, cpu) = reg();
        let sched = ElasticScheduler::default();
        let actions: Vec<Action> = (0..10).map(|i| rigid(&r, cpu, i, 3)).collect();
        let refs: Vec<&Action> = actions.iter().collect();
        let pool = Pool { units: 10, running: vec![] };
        let d = run(&sched, &refs, &pool, cpu);
        // only ⌊10/3⌋ = 3 fit
        assert_eq!(d.len(), 3);
        assert_eq!(d[0].action, ActionId(0));
        assert_eq!(d[2].action, ActionId(2));
    }

    #[test]
    fn eviction_fires_when_wide_rigid_action_starves_scalable() {
        // A: 16s perfectly-scalable (range 1..16). B: rigid, needs 15 units,
        // runs 0.1s. Keeping both pins A at 1 unit → obj ≈ 16.1s. Evicting B
        // lets A take all 16 units (1s); B slots in right after (est ≈ 1.1s)
        // → obj ≈ 2.1s. Greedy eviction must pick the latter.
        let (r, cpu) = reg();
        let sched = ElasticScheduler::default();
        let a = scalable(&r, cpu, 1, 16, 16);
        let mut b = rigid(&r, cpu, 2, 15);
        b.spec.profiled_dur = Some(SimDur::from_millis(100));
        b.spec.true_dur = SimDur::from_millis(100);
        let pool = Pool { units: 16, running: vec![] };
        let d = run(&sched, &[&a, &b], &pool, cpu);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].action, ActionId(1));
        assert_eq!(d[0].units, 16);
    }

    #[test]
    fn identical_scalable_actions_serialize_for_lower_total_act() {
        // Two identical 16s perfectly-scalable actions on 16 units: sharing
        // 8/8 gives ACTs 2+2=4; serializing at 16 units gives 1+2=3. With
        // the unit-aware completion heap (and the min..max exploration of
        // Alg. 2), greedy eviction finds the serialization.
        let (r, cpu) = reg();
        let sched = ElasticScheduler::default();
        let a = scalable(&r, cpu, 1, 16, 16);
        let b = scalable(&r, cpu, 2, 16, 16);
        let pool = Pool { units: 16, running: vec![] };
        let d = run(&sched, &[&a, &b], &pool, cpu);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].action, ActionId(1), "FCFS head runs first");
        assert_eq!(d[0].units, 16);
    }

    #[test]
    fn no_eviction_when_parallel_is_better() {
        // Short actions with capped scalability: running both in parallel at
        // max (8 units each) beats serializing them.
        let (r, cpu) = reg();
        let sched = ElasticScheduler::default();
        let a = scalable(&r, cpu, 1, 8, 8);
        let b = scalable(&r, cpu, 2, 8, 8);
        let pool = Pool { units: 16, running: vec![] };
        let d = run(&sched, &[&a, &b], &pool, cpu);
        assert_eq!(d.len(), 2, "{d:?}");
        assert_eq!(d[0].units, 8);
        assert_eq!(d[1].units, 8);
    }

    #[test]
    fn mixed_scalable_and_rigid_share_the_pool() {
        let (r, cpu) = reg();
        let sched = ElasticScheduler::default();
        let a = rigid(&r, cpu, 1, 4);
        let b = scalable(&r, cpu, 2, 12, 16);
        let pool = Pool { units: 16, running: vec![] };
        let d = run(&sched, &[&a, &b], &pool, cpu);
        assert_eq!(d.len(), 2);
        let da = d.iter().find(|x| x.action == ActionId(1)).unwrap();
        let db = d.iter().find(|x| x.action == ActionId(2)).unwrap();
        assert_eq!(da.units, 4);
        assert_eq!(db.units, 12); // everything that's left
    }

    #[test]
    fn unknown_elasticity_group_selected_at_min() {
        let (r, cpu) = reg();
        let sched = ElasticScheduler::default();
        // Range cost but elasticity None → not scalable → min units.
        let mut a = scalable(&r, cpu, 1, 8, 8);
        a.spec.elasticity = ElasticityModel::None;
        let pool = Pool { units: 16, running: vec![] };
        let d = run(&sched, &[&a], &pool, cpu);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].units, 1);
    }

    #[test]
    fn fcfs_order_is_preserved_for_selection() {
        let (r, cpu) = reg();
        let sched = ElasticScheduler::default();
        let actions: Vec<Action> = (0..5).map(|i| rigid(&r, cpu, i, 4)).collect();
        let refs: Vec<&Action> = actions.iter().collect();
        let pool = Pool { units: 8, running: vec![] };
        let d = run(&sched, &refs, &pool, cpu);
        // first two fit; 3rd does not (12 > 8)
        let ids: Vec<u64> = d.iter().map(|x| x.action.0).collect();
        assert_eq!(ids, vec![0, 1]);
    }

    #[test]
    fn spread_always_includes_the_extremes() {
        let choices: Vec<u64> = vec![1, 2, 4, 8, 16];
        // n == 1 (depth-1 config): both extremes must survive — the wide-
        // allocation option is the whole point of the exploration
        assert_eq!(spread(&choices, 1), vec![1, 16]);
        assert_eq!(spread(&choices, 2), vec![1, 16]);
        // interior budgets keep the extremes and spread the middle
        let s = spread(&choices, 3);
        assert_eq!(s.first(), Some(&1));
        assert_eq!(s.last(), Some(&16));
        assert!(s.len() <= 3);
        // n ≥ len: the whole choice set verbatim
        assert_eq!(spread(&choices, 5), choices);
        assert_eq!(spread(&choices, 50), choices);
        // degenerate inputs
        assert_eq!(spread(&[], 3), vec![1]);
        assert_eq!(spread(&choices, 0), vec![1]);
        assert_eq!(spread(&[4], 1), vec![4]);
        assert_eq!(spread(&[2, 9], 1), vec![2, 9]);
    }

    #[test]
    fn unprofiled_estimate_converges_to_observed_history() {
        // Satellite bugfix: the historical-average estimator must converge
        // to what `observe` feeds it, so unprofiled actions stop falling
        // back to `default_dur` once completions flow in.
        let mut s = DurationStats::default();
        let fallback = SimDur::from_millis(500);
        assert_eq!(s.estimate(ActionKind::EnvExec, fallback), fallback);
        for _ in 0..50 {
            s.observe(ActionKind::EnvExec, SimDur::from_secs(4));
        }
        let est = s.estimate(ActionKind::EnvExec, fallback).secs_f64();
        assert!((est - 4.0).abs() < 1e-9, "{est}");
        // EWMA tracks drifting history toward the new regime
        for _ in 0..200 {
            s.observe(ActionKind::EnvExec, SimDur::from_secs(1));
        }
        let est = s.estimate(ActionKind::EnvExec, fallback).secs_f64();
        assert!((est - 1.0).abs() < 0.05, "{est}");
    }

    #[test]
    fn duration_stats_ewma() {
        let mut s = DurationStats::default();
        let d = SimDur::from_secs(10);
        assert_eq!(s.estimate(ActionKind::ApiCall, d), d); // default
        s.observe(ActionKind::ApiCall, SimDur::from_secs(2));
        assert_eq!(s.estimate(ActionKind::ApiCall, d), SimDur::from_secs(2));
        s.observe(ActionKind::ApiCall, SimDur::from_secs(4));
        let e = s.estimate(ActionKind::ApiCall, d).secs_f64();
        assert!(e > 2.0 && e < 4.0);
    }
}
