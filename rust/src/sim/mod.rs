//! Discrete-event simulation engine.
//!
//! The paper's evaluation runs on a 48-node production cluster; our
//! substitute executes the *same coordinator code* against simulated
//! external resources under a virtual clock, which makes cluster-scale
//! sweeps (batch 128→3072, Fig. 8) deterministic and laptop-fast.
//!
//! The engine is a classic event-heap DES: events carry an opaque payload
//! `E`; ties break by insertion sequence so runs are reproducible.

pub mod time;

pub use time::{SimDur, SimTime};

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Internal heap entry. Min-heap by (time, seq).
struct Entry<E> {
    at: SimTime,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, o: &Self) -> bool {
        self.at == o.at && self.seq == o.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, o: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest first
        o.at.cmp(&self.at).then(o.seq.cmp(&self.seq))
    }
}

/// Event-driven virtual-time executor.
pub struct Engine<E> {
    heap: BinaryHeap<Entry<E>>,
    now: SimTime,
    seq: u64,
    processed: u64,
}

impl<E> Default for Engine<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Engine<E> {
    pub fn new() -> Self {
        Engine { heap: BinaryHeap::new(), now: SimTime::ZERO, seq: 0, processed: 0 }
    }

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events processed so far (DES throughput metric).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `ev` at absolute time `at`. Scheduling in the past is a bug.
    pub fn schedule_at(&mut self, at: SimTime, ev: E) {
        debug_assert!(at >= self.now, "scheduling into the past: {at:?} < {:?}", self.now);
        let at = at.max(self.now);
        self.heap.push(Entry { at, seq: self.seq, ev });
        self.seq += 1;
    }

    /// Schedule `ev` after delay `d`.
    pub fn schedule_in(&mut self, d: SimDur, ev: E) {
        self.schedule_at(self.now + d, ev);
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn next(&mut self) -> Option<(SimTime, E)> {
        let e = self.heap.pop()?;
        debug_assert!(e.at >= self.now);
        self.now = e.at;
        self.processed += 1;
        Some((e.at, e.ev))
    }

    /// Run until the heap drains or `f` returns false (stop condition).
    pub fn run_while<F: FnMut(&mut Self, SimTime, E) -> bool>(&mut self, mut f: F) {
        while let Some(e) = self.heap.pop() {
            self.now = e.at;
            self.processed += 1;
            if !f(self, e.at, e.ev) {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_fire_in_time_order() {
        let mut eng: Engine<u32> = Engine::new();
        eng.schedule_at(SimTime(30), 3);
        eng.schedule_at(SimTime(10), 1);
        eng.schedule_at(SimTime(20), 2);
        let mut got = vec![];
        while let Some((t, e)) = eng.next() {
            got.push((t.0, e));
        }
        assert_eq!(got, vec![(10, 1), (20, 2), (30, 3)]);
        assert_eq!(eng.processed(), 3);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut eng: Engine<u32> = Engine::new();
        for i in 0..10 {
            eng.schedule_at(SimTime(5), i);
        }
        let got: Vec<u32> = std::iter::from_fn(|| eng.next().map(|(_, e)| e)).collect();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances_and_relative_scheduling_works() {
        let mut eng: Engine<&'static str> = Engine::new();
        eng.schedule_in(SimDur(100), "a");
        let (t, _) = eng.next().unwrap();
        assert_eq!(t, SimTime(100));
        eng.schedule_in(SimDur(50), "b");
        let (t, _) = eng.next().unwrap();
        assert_eq!(t, SimTime(150));
        assert_eq!(eng.now(), SimTime(150));
    }

    #[test]
    fn run_while_can_stop_early_and_cascade() {
        let mut eng: Engine<u32> = Engine::new();
        eng.schedule_at(SimTime(1), 0);
        let mut count = 0;
        eng.run_while(|eng, _, ev| {
            count += 1;
            if ev < 100 {
                eng.schedule_in(SimDur(1), ev + 1); // cascade
            }
            ev < 49 // stop after event 49
        });
        assert_eq!(count, 50);
        assert!(eng.pending() > 0);
    }
}
