//! Virtual time for the discrete-event simulator.
//!
//! `SimTime`/`SimDur` are nanosecond-resolution fixed-point values. All paper
//! metrics (ACT, step duration, utilization) are integrals over this clock;
//! nanosecond ticks keep sub-millisecond actions (paper §2.4: "down to 1ms
//! in AI coding", scheduling windows shorter still) exactly representable.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// Absolute virtual time (ns since simulation start).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

/// A duration in virtual time (ns).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimDur(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }

    pub fn saturating_sub(self, other: SimTime) -> SimDur {
        SimDur(self.0.saturating_sub(other.0))
    }
}

impl SimDur {
    pub const ZERO: SimDur = SimDur(0);

    pub fn from_secs_f64(s: f64) -> SimDur {
        debug_assert!(s >= 0.0, "negative duration {s}");
        SimDur((s.max(0.0) * 1e9).round() as u64)
    }

    pub fn from_millis(ms: u64) -> SimDur {
        SimDur(ms * 1_000_000)
    }

    pub fn from_micros(us: u64) -> SimDur {
        SimDur(us * 1_000)
    }

    pub fn from_secs(s: u64) -> SimDur {
        SimDur(s * 1_000_000_000)
    }

    pub fn secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }

    pub fn millis_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }

    pub fn mul_f64(self, f: f64) -> SimDur {
        debug_assert!(f >= 0.0);
        SimDur((self.0 as f64 * f).round() as u64)
    }

    pub fn div_u64(self, d: u64) -> SimDur {
        SimDur(self.0 / d.max(1))
    }
}

impl Add<SimDur> for SimTime {
    type Output = SimTime;
    fn add(self, d: SimDur) -> SimTime {
        SimTime(self.0 + d.0)
    }
}

impl AddAssign<SimDur> for SimTime {
    fn add_assign(&mut self, d: SimDur) {
        self.0 += d.0;
    }
}

impl Sub<SimTime> for SimTime {
    type Output = SimDur;
    fn sub(self, other: SimTime) -> SimDur {
        debug_assert!(self >= other, "time went backwards: {self:?} - {other:?}");
        SimDur(self.0 - other.0)
    }
}

impl Add for SimDur {
    type Output = SimDur;
    fn add(self, o: SimDur) -> SimDur {
        SimDur(self.0 + o.0)
    }
}

impl AddAssign for SimDur {
    fn add_assign(&mut self, o: SimDur) {
        self.0 += o.0;
    }
}

impl Sub for SimDur {
    type Output = SimDur;
    fn sub(self, o: SimDur) -> SimDur {
        SimDur(self.0.saturating_sub(o.0))
    }
}

impl std::iter::Sum for SimDur {
    fn sum<I: Iterator<Item = SimDur>>(iter: I) -> SimDur {
        iter.fold(SimDur::ZERO, |a, b| a + b)
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.3}s", self.secs_f64())
    }
}

impl fmt::Display for SimDur {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 < 1_000 {
            write!(f, "{}ns", self.0)
        } else if self.0 < 1_000_000 {
            write!(f, "{:.1}µs", self.0 as f64 / 1e3)
        } else if self.0 < 1_000_000_000 {
            write!(f, "{:.1}ms", self.0 as f64 / 1e6)
        } else {
            write!(f, "{:.2}s", self.secs_f64())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let t = SimTime::ZERO + SimDur::from_millis(5);
        assert_eq!(t.0, 5_000_000);
        assert_eq!((t + SimDur::from_micros(1)) - t, SimDur::from_micros(1));
        assert_eq!(SimDur::from_secs_f64(1.5).0, 1_500_000_000);
    }

    #[test]
    fn conversions_round_trip() {
        let d = SimDur::from_secs_f64(0.123456789);
        assert!((d.secs_f64() - 0.123456789).abs() < 1e-9);
        assert_eq!(SimDur::from_secs(2).millis_f64(), 2000.0);
    }

    #[test]
    fn scaling() {
        assert_eq!(SimDur::from_millis(10).mul_f64(0.5), SimDur::from_millis(5));
        assert_eq!(SimDur::from_millis(10).div_u64(4), SimDur::from_micros(2500));
        assert_eq!(SimDur::from_millis(1).div_u64(0), SimDur::from_millis(1));
    }

    #[test]
    fn display_units() {
        assert_eq!(format!("{}", SimDur(500)), "500ns");
        assert_eq!(format!("{}", SimDur::from_micros(1500)), "1.5ms");
        assert_eq!(format!("{}", SimDur::from_secs(3)), "3.00s");
    }

    #[test]
    fn saturating_sub() {
        let a = SimTime(5);
        let b = SimTime(9);
        assert_eq!(a.saturating_sub(b), SimDur::ZERO);
        assert_eq!(b.saturating_sub(a), SimDur(4));
    }
}
