//! Property-testing kit (offline substitute for `proptest`).
//!
//! A seeded generator framework with greedy input shrinking: when a property
//! fails, the runner re-tries progressively simpler inputs derived from the
//! failing case and reports the smallest reproduction found, plus the seed
//! for exact replay.

pub mod oracle;

use crate::util::rng::Rng;

/// Number of cases per property (override with `ARL_PROPTEST_CASES`).
pub fn default_cases() -> u32 {
    std::env::var("ARL_PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256)
}

/// A generator of values + their shrink candidates.
pub trait Gen {
    type Value: std::fmt::Debug + Clone;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Simpler variants of `v` to try when it fails (ordered simplest-first).
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let _ = v;
        vec![]
    }
}

/// Run `prop` over `cases` random inputs; panics with the smallest failing
/// input and its seed.
pub fn check<G: Gen, F: Fn(&G::Value) -> Result<(), String>>(
    name: &str,
    gen: &G,
    cases: u32,
    prop: F,
) {
    let base_seed = 0xa11_5eed;
    for case in 0..cases {
        let mut rng = Rng::new(base_seed + case as u64);
        let v = gen.generate(&mut rng);
        if let Err(msg) = prop(&v) {
            let best = shrink_failure(gen, v, msg, &prop, 500);
            panic!(
                "property '{name}' failed (case {case}, seed {}):\n  input: {:?}\n  error: {}",
                base_seed + case as u64,
                best.0,
                best.1
            );
        }
    }
}

/// Greedy shrink of a failing input: [`Gen::shrink`] candidates are ordered
/// simplest-first, so we try them **front-to-back** and restart the frontier
/// from the first candidate that still fails. (The runner used to `pop()`
/// from the back, which tried the *least*-simplified candidate first and
/// burned the whole budget on near-original inputs.) Returns the simplest
/// failing input found and its error.
pub fn shrink_failure<G: Gen, F: Fn(&G::Value) -> Result<(), String>>(
    gen: &G,
    v: G::Value,
    msg: String,
    prop: &F,
    mut budget: u32,
) -> (G::Value, String) {
    let mut best = (v, msg);
    let mut frontier = std::collections::VecDeque::from(gen.shrink(&best.0));
    while let Some(cand) = frontier.pop_front() {
        if budget == 0 {
            break;
        }
        budget -= 1;
        if let Err(m) = prop(&cand) {
            frontier = std::collections::VecDeque::from(gen.shrink(&cand));
            best = (cand, m);
        }
    }
    best
}

/// Uniform integer in [lo, hi].
pub struct IntRange(pub u64, pub u64);

impl Gen for IntRange {
    type Value = u64;
    fn generate(&self, rng: &mut Rng) -> u64 {
        rng.range(self.0, self.1)
    }
    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = vec![];
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (v - self.0) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// Vector of values from an element generator, length in [min_len, max_len].
pub struct VecOf<G> {
    pub elem: G,
    pub min_len: usize,
    pub max_len: usize,
}

impl<G: Gen> Gen for VecOf<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let n = rng.range(self.min_len as u64, self.max_len as u64) as usize;
        (0..n).map(|_| self.elem.generate(rng)).collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = vec![];
        if v.len() > self.min_len {
            out.push(v[..self.min_len].to_vec()); // shortest prefix
            out.push(v[..v.len() / 2].to_vec()); // half
            let mut minus_one = v.clone();
            minus_one.pop();
            out.push(minus_one);
        }
        // element-wise shrink of the first element
        if let Some(first) = v.first() {
            for s in self.elem.shrink(first) {
                let mut w = v.clone();
                w[0] = s;
                out.push(w);
            }
        }
        out.retain(|w| w.len() >= self.min_len);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum fits", &VecOf { elem: IntRange(0, 9), min_len: 0, max_len: 10 }, 64, |v| {
            if v.iter().sum::<u64>() <= 90 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let caught = std::panic::catch_unwind(|| {
            check("len<3", &VecOf { elem: IntRange(0, 9), min_len: 0, max_len: 16 }, 64, |v| {
                if v.len() < 3 {
                    Ok(())
                } else {
                    Err(format!("len {}", v.len()))
                }
            });
        });
        let msg = format!("{:?}", caught.unwrap_err().downcast_ref::<String>());
        // shrinker should find a minimal-ish failing case (len 3-ish, not 16)
        assert!(msg.contains("failed"), "{msg}");
    }

    #[test]
    fn shrink_tries_simplest_candidates_first() {
        // Regression: a len-16 failing vector must shrink to the minimal
        // failing length (3). With the old back-first `pop()`, the runner
        // kept re-trying element-wise shrinks of the full-length vector and
        // reported a len-16 input.
        let gen = VecOf { elem: IntRange(0, 9), min_len: 0, max_len: 16 };
        let prop = |v: &Vec<u64>| {
            if v.len() < 3 {
                Ok(())
            } else {
                Err(format!("len {}", v.len()))
            }
        };
        let failing = vec![9u64; 16];
        let (best, msg) = shrink_failure(&gen, failing, "len 16".into(), &prop, 500);
        assert_eq!(best.len(), 3, "expected minimal failing length, got {best:?} ({msg})");
    }

    #[test]
    fn int_range_respects_bounds() {
        let g = IntRange(3, 7);
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let v = g.generate(&mut rng);
            assert!((3..=7).contains(&v));
        }
        assert!(g.shrink(&3).is_empty());
        assert!(g.shrink(&7).contains(&3));
    }
}
