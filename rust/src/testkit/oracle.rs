//! Invariant oracle over fuzzed scenario executions.
//!
//! Every contract PRs 1–5 accumulated — record→replay byte-identity,
//! submit/complete conservation, provision floors and warming monotonicity,
//! fault × autoscale product composition, `PoolClass`-ordered lane
//! enumeration, dirty-pool ≡ full-sweep — is checked here mechanically over
//! any [`ScenarioSpec`], so the seeded fuzzer (`scenario --fuzz`) can hunt
//! scheduler bugs instead of waiting for a hand-authored pack to trip one.
//!
//! The battery is deliberately conservative: each invariant is stated in a
//! form that is *provable* from the scheduler's contracts, so a reported
//! [`Violation`] is a real bug (or a broken contract), never fuzz noise.
//! A failing spec is shrunk simplest-first by [`minimize_failure`], reusing
//! the property-test shrink machinery, and the offending seed is promoted
//! to `rust/testdata/fuzz_seeds.txt` as a permanent regression.

use crate::autoscale::{Autoscaler, PoolClass};
use crate::config::BackendKind;
use crate::coordinator::{run_session, Backend, Session};
use crate::rollout::workloads::Catalog;
use crate::scenario::{
    build_backend, fuzz_spec, parse_trace_file, replay_trace, run_scenario_tangram,
    run_scenario_tangram_sharded, run_scenario_tangram_threaded, trace_file_contents,
    trace_tenant_stats, ScenarioEvent, ScenarioOutcome, ScenarioSpec, TraceKind, TraceRecorder,
};
use crate::sim::SimTime;
use crate::testkit::{shrink_failure, Gen};
use crate::util::error::Result;
use crate::util::rng::{Rng, SplitMix64};
use std::collections::{BTreeMap, BTreeSet};

/// One invariant breach: which law broke, and the concrete evidence.
#[derive(Debug, Clone)]
pub struct Violation {
    pub invariant: &'static str,
    pub detail: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] {}", self.invariant, self.detail)
    }
}

/// Outcome of running the full battery over one spec.
#[derive(Debug)]
pub struct OracleReport {
    /// Terminal actions completed by the primary (dirty-pool) run.
    pub actions: usize,
    /// Trace events recorded by the primary run.
    pub trace_events: usize,
    pub violations: Vec<Violation>,
}

impl OracleReport {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// All violations, one per line (empty string when clean).
    pub fn describe(&self) -> String {
        self.violations.iter().map(|v| format!("{v}\n")).collect()
    }
}

/// Run every invariant over `spec`. `Err` means the engine itself could not
/// execute the spec (invalid spec, unsupported backend) — distinct from a
/// clean run that *violated* an invariant, which lands in the report.
pub fn check_spec(spec: &ScenarioSpec) -> Result<OracleReport> {
    let (dirty, _) = run_scenario_tangram(spec, false)?;
    let (sweep, _) = run_scenario_tangram(spec, true)?;
    let mut violations = Vec::new();
    check_replay(spec, &dirty, &mut violations)?;
    check_ledger(&dirty, &mut violations);
    check_provision(spec, &dirty, &mut violations);
    check_lane_order(spec, &mut violations);
    check_composition(spec, &mut violations);
    check_dirty_sweep(spec, &dirty, &sweep, &mut violations);
    check_tenants(spec, &dirty, &mut violations);
    check_wfq_neutrality(spec, &mut violations)?;
    check_shards_parity(spec, &dirty, &mut violations)?;
    Ok(OracleReport {
        actions: dirty.metrics.actions.len(),
        trace_events: dirty.events.len(),
        violations,
    })
}

/// Generate the fuzz spec for `seed` and run the battery over it.
pub fn check_seed(seed: u64) -> Result<OracleReport> {
    check_spec(&fuzz_spec(seed))
}

// ---- invariants -----------------------------------------------------------

/// Record→replay byte-identity: serializing the run to the trace-file
/// format, parsing it back, and re-executing must reproduce the identical
/// summary and event stream.
fn check_replay(spec: &ScenarioSpec, out: &ScenarioOutcome, v: &mut Vec<Violation>) -> Result<()> {
    let text = trace_file_contents(spec, BackendKind::Tangram, out);
    let recorded = parse_trace_file(&text)?;
    let report = replay_trace(&recorded)?;
    if !report.identical {
        let mut detail = String::new();
        if let Some(d) = &report.summary_diff {
            detail.push_str(d);
        }
        for d in report.trace_divergences.iter().take(3) {
            detail.push_str("; ");
            detail.push_str(d);
        }
        v.push(Violation { invariant: "record-replay-identity", detail });
    }
    Ok(())
}

/// No lost / duplicated / double-completed actions. Cross-checks the
/// driver's [`crate::metrics::ActionLedger`] against a scan of the recorded
/// trace: one `Submit` per action, one terminal `Complete`, and one `Start`
/// per submission plus one per retry.
fn check_ledger(out: &ScenarioOutcome, v: &mut Vec<Violation>) {
    let led = out.metrics.ledger;
    if !led.balanced() {
        v.push(Violation {
            invariant: "action-ledger",
            detail: format!("driver ledger unbalanced: {led:?}"),
        });
    }
    if led.submitted != out.metrics.actions.len() as u64
        || led.failed != out.metrics.failed_actions() as u64
        || led.retried != out.metrics.total_retries()
    {
        v.push(Violation {
            invariant: "action-ledger",
            detail: format!(
                "ledger {led:?} disagrees with records: {} actions, {} failed, {} retries",
                out.metrics.actions.len(),
                out.metrics.failed_actions(),
                out.metrics.total_retries()
            ),
        });
    }

    #[derive(Default)]
    struct Scan {
        submits: u32,
        starts: u32,
        retry_completes: u32,
        terminal: u32,
    }
    let mut scan: BTreeMap<u64, Scan> = BTreeMap::new();
    for ev in &out.events {
        match &ev.kind {
            TraceKind::Submit { action, .. } => scan.entry(*action).or_default().submits += 1,
            TraceKind::Start { action, .. } => {
                let e = scan.entry(*action).or_default();
                if e.submits == 0 {
                    v.push(Violation {
                        invariant: "action-ledger",
                        detail: format!("action {action} started before any submit"),
                    });
                }
                e.starts += 1;
            }
            TraceKind::Complete { action, outcome, .. } => {
                let e = scan.entry(*action).or_default();
                if outcome == "retry" {
                    e.retry_completes += 1;
                } else {
                    e.terminal += 1;
                }
            }
            _ => {}
        }
    }
    for (id, s) in &scan {
        if s.submits != 1 || s.terminal != 1 || s.starts != s.retry_completes + 1 {
            v.push(Violation {
                invariant: "action-ledger",
                detail: format!(
                    "action {id}: {} submits, {} starts, {} retries, {} terminal completes",
                    s.submits, s.starts, s.retry_completes, s.terminal
                ),
            });
        }
    }
    if scan.len() != out.metrics.actions.len() {
        v.push(Violation {
            invariant: "action-ledger",
            detail: format!(
                "trace saw {} distinct actions, metrics recorded {}",
                scan.len(),
                out.metrics.actions.len()
            ),
        });
    }
}

/// Provision conservation: billed units stay positive, never exceed the
/// static baseline (fault factors ≤ 1), respect the autoscale floor
/// `max(1, Σ round(baselineᵢ · min_factor))`, and never dip below a billed
/// scale-up level while that capacity is still warming.
fn check_provision(spec: &ScenarioSpec, out: &ScenarioOutcome, v: &mut Vec<Violation>) {
    // per-pool baseline = the initial provision gauge at t=0
    let mut baseline: BTreeMap<&str, u64> = BTreeMap::new();
    for rec in &out.metrics.provision {
        baseline.entry(rec.pool.as_str()).or_insert(rec.units);
    }
    // the baseline cap only holds when no API fault scales limits UP
    let mut api_cap_holds = true;
    for te in &spec.events {
        if let ScenarioEvent::ApiLimitScale { factor } = &te.event {
            if *factor > 1.0 {
                api_cap_holds = false;
            }
        }
    }
    let floors = autoscale_floors(spec);
    for rec in &out.metrics.provision {
        if rec.units == 0 {
            v.push(Violation {
                invariant: "provision-conservation",
                detail: format!("pool '{}' billed zero units at {:?}", rec.pool, rec.at),
            });
        }
        let cap = baseline[rec.pool.as_str()];
        if rec.units > cap && (rec.pool != "api_lanes" || api_cap_holds) {
            v.push(Violation {
                invariant: "provision-conservation",
                detail: format!(
                    "pool '{}' billed {} units over its baseline {}",
                    rec.pool, rec.units, cap
                ),
            });
        }
        if let Some(floor) = floors.get(rec.pool.as_str()) {
            if rec.units < *floor {
                v.push(Violation {
                    invariant: "provision-conservation",
                    detail: format!(
                        "pool '{}' billed {} units below the autoscale floor {}",
                        rec.pool, rec.units, floor
                    ),
                });
            }
        }
    }
    check_warming_monotone(out, v);
}

/// Per-class floor implied by `min_factor`, computed from a fresh
/// deployment's scale targets (quantized factors never go below the floor,
/// and per-target rounding is monotone in the factor).
fn autoscale_floors(spec: &ScenarioSpec) -> BTreeMap<&'static str, u64> {
    let mut floors = BTreeMap::new();
    let Some(asc) = &spec.autoscale else {
        return floors;
    };
    let cat = Catalog::build(&spec.catalog);
    let backend = build_backend(&spec.catalog, &cat, BackendKind::Tangram);
    let targets = backend.scale_classes();
    for class in PoolClass::ALL {
        let mut sum = 0u64;
        for p in targets.iter().filter(|p| p.key.class == class) {
            sum += (p.baseline_units as f64 * asc.min_factor).round() as u64;
        }
        floors.insert(class.name(), sum.max(1));
    }
    floors
}

/// While a billed scale-up is warming (between its `Scale{decide}` and the
/// matching `Scale{apply}`), the pool's provision gauge must not fall below
/// the level billed at the decision — unless an intervening scale-*down*
/// decision for the class lowers it, which clears the requirement.
fn check_warming_monotone(out: &ScenarioOutcome, v: &mut Vec<Violation>) {
    let class_of = |label: &str| label.split('@').next().unwrap_or(label).to_string();
    // last decided/applied factor per exact scale label ("gpus", "api_lanes@2")
    let mut last_factor: BTreeMap<String, f64> = BTreeMap::new();
    // per class: floor billed by a pending up-scale, awaiting its apply
    let mut warming_floor: BTreeMap<String, u64> = BTreeMap::new();
    // class whose next Provision event sets (rather than checks) the floor
    let mut expect_floor: Option<String> = None;
    for ev in &out.events {
        match &ev.kind {
            TraceKind::Scale { pool, phase, factor } => {
                let class = class_of(pool);
                let prev = *last_factor.get(pool).unwrap_or(&1.0);
                if phase == "decide" {
                    if *factor > prev {
                        expect_floor = Some(class);
                    } else {
                        // a scale-down decision legitimately lowers billing
                        warming_floor.remove(&class);
                        expect_floor = None;
                    }
                } else {
                    // capacity became schedulable; warming constraint ends
                    warming_floor.remove(&class);
                }
                last_factor.insert(pool.clone(), *factor);
            }
            TraceKind::Provision { pool, units } => {
                if expect_floor.as_deref() == Some(pool.as_str()) {
                    warming_floor.insert(pool.clone(), *units);
                    expect_floor = None;
                } else if let Some(floor) = warming_floor.get(pool) {
                    if units < floor {
                        v.push(Violation {
                            invariant: "warming-monotone",
                            detail: format!(
                                "pool '{pool}' billed {units} below its warming level {floor}"
                            ),
                        });
                    }
                }
            }
            _ => {}
        }
    }
}

/// Lanes enumerate in `PoolClass` order: scale targets sorted by
/// `(class, endpoint)` with no duplicate key, and the provision gauges
/// named in non-descending class order.
fn check_lane_order(spec: &ScenarioSpec, v: &mut Vec<Violation>) {
    let cat = Catalog::build(&spec.catalog);
    let backend = build_backend(&spec.catalog, &cat, BackendKind::Tangram);
    let rows = backend.scale_classes();
    for w in rows.windows(2) {
        if w[0].key() >= w[1].key() {
            v.push(Violation {
                invariant: "lane-order",
                detail: format!("scale targets out of order: {:?} !< {:?}", w[0].key(), w[1].key()),
            });
        }
    }
    let class_rank = |name: &str| PoolClass::ALL.iter().position(|c| c.name() == name);
    let mut ranks = Vec::new();
    for (name, _) in backend.provisioned() {
        if let Some(rank) = class_rank(&name) {
            ranks.push(rank);
        }
    }
    if ranks.windows(2).any(|w| w[0] > w[1]) {
        v.push(Violation {
            invariant: "lane-order",
            detail: format!("provision gauges out of class order: {:?}", backend.provisioned()),
        });
    }
}

/// Fault × autoscale composition stays a product: injecting fault `f` and
/// resizing to `a` — in either order — must provision exactly what a single
/// factor `f·a` provisions, and re-applying the same factor is idempotent.
fn check_composition(spec: &ScenarioSpec, v: &mut Vec<Violation>) {
    let cat = Catalog::build(&spec.catalog);
    let mut r = SplitMix64::new(spec.seed ^ 0xFAC7_0125);
    let menu = [0.125f64, 0.25, 0.375, 0.5, 0.75, 1.0];
    for class in PoolClass::ALL {
        for _ in 0..3 {
            let f = *r.pick(&menu);
            let a = *r.pick(&menu);
            let mut fault_first = build_backend(&spec.catalog, &cat, BackendKind::Tangram);
            fault_first.inject(SimTime::ZERO, &fault_event(class, f));
            resize_class(fault_first.as_mut(), class, a);
            let mut auto_first = build_backend(&spec.catalog, &cat, BackendKind::Tangram);
            resize_class(auto_first.as_mut(), class, a);
            auto_first.inject(SimTime::ZERO, &fault_event(class, f));
            let mut product = build_backend(&spec.catalog, &cat, BackendKind::Tangram);
            product.inject(SimTime::ZERO, &fault_event(class, f * a));
            if fault_first.provisioned() != auto_first.provisioned() {
                v.push(Violation {
                    invariant: "fault-auto-product",
                    detail: format!(
                        "{}: fault {f} x auto {a} is order-dependent: {:?} vs {:?}",
                        class.name(),
                        fault_first.provisioned(),
                        auto_first.provisioned()
                    ),
                });
            }
            if fault_first.provisioned() != product.provisioned() {
                v.push(Violation {
                    invariant: "fault-auto-product",
                    detail: format!(
                        "{}: fault {f} then auto {a} != single factor: {:?} vs {:?}",
                        class.name(),
                        fault_first.provisioned(),
                        product.provisioned()
                    ),
                });
            }
            let before = fault_first.provisioned();
            resize_class(fault_first.as_mut(), class, a);
            if fault_first.provisioned() != before {
                v.push(Violation {
                    invariant: "fault-auto-product",
                    detail: format!(
                        "{}: re-applying auto {a} was not idempotent: {:?} vs {:?}",
                        class.name(),
                        before,
                        fault_first.provisioned()
                    ),
                });
            }
        }
    }
}

/// The class-wide fault injection for `class` at `factor`.
fn fault_event(class: PoolClass, factor: f64) -> ScenarioEvent {
    match class {
        PoolClass::Cpu => ScenarioEvent::CpuPoolScale { factor },
        PoolClass::Gpu => ScenarioEvent::GpuPoolScale { factor },
        PoolClass::Api => ScenarioEvent::ApiLimitScale { factor },
    }
}

/// Resize every scale target of `class` to the same autoscale factor.
fn resize_class(backend: &mut dyn Backend, class: PoolClass, factor: f64) {
    let mut keys = Vec::new();
    for p in backend.scale_classes() {
        if p.key.class == class {
            keys.push(p.key);
        }
    }
    for key in keys {
        backend.resize(SimTime::ZERO, key, factor);
    }
}

/// Dirty-pool incremental scheduling completes identical work to a full
/// sweep; on fault-free, autoscale-free specs the agreement is
/// decision-for-decision (same per-action allocation and timing).
fn check_dirty_sweep(
    spec: &ScenarioSpec,
    dirty: &ScenarioOutcome,
    sweep: &ScenarioOutcome,
    v: &mut Vec<Violation>,
) {
    let d = &dirty.metrics;
    let s = &sweep.metrics;
    if d.trajectories.len() != s.trajectories.len()
        || d.actions.len() != s.actions.len()
        || d.failed_actions() != s.failed_actions()
        || d.total_retries() != s.total_retries()
    {
        v.push(Violation {
            invariant: "dirty-vs-sweep",
            detail: format!(
                "traj/act/failed/retry counts: dirty {}/{}/{}/{} vs sweep {}/{}/{}/{}",
                d.trajectories.len(),
                d.actions.len(),
                d.failed_actions(),
                d.total_retries(),
                s.trajectories.len(),
                s.actions.len(),
                s.failed_actions(),
                s.total_retries()
            ),
        });
        return;
    }
    if !spec.events.is_empty() || spec.autoscale.is_some() {
        return;
    }
    for (da, sa) in d.actions.iter().zip(s.actions.iter()) {
        if da.id != sa.id
            || da.units != sa.units
            || da.started != sa.started
            || da.finished != sa.finished
            || da.retries != sa.retries
        {
            v.push(Violation {
                invariant: "dirty-vs-sweep",
                detail: format!(
                    "per-action divergence at {:?}: dirty {:?}@{:?}..{:?} vs sweep {:?}@{:?}..{:?}",
                    da.id, da.units, da.started, da.finished, sa.units, sa.started, sa.finished
                ),
            });
            return;
        }
    }
}

/// Tenant conservation: every tenant id observed in the records or the
/// trace is declared by the spec (0 for single-tenant specs), the
/// per-tenant rollups sum **bitwise** to the global tallies, and the
/// trace's per-tenant terminal completions agree with the records.
fn check_tenants(spec: &ScenarioSpec, out: &ScenarioOutcome, v: &mut Vec<Violation>) {
    let declared: BTreeSet<u32> = if spec.tenants.is_empty() {
        std::iter::once(0).collect()
    } else {
        spec.tenants.iter().map(|t| t.id).collect()
    };
    let m = &out.metrics;
    let rollups = m.tenant_rollups();
    for t in rollups.keys() {
        if !declared.contains(t) {
            v.push(Violation {
                invariant: "tenant-conservation",
                detail: format!("undeclared tenant {t} in the action records"),
            });
        }
    }
    let mut sum = crate::metrics::TenantRollup::default();
    for r in rollups.values() {
        sum.actions += r.actions;
        sum.failed += r.failed;
        sum.retries += r.retries;
        sum.act_ns += r.act_ns;
        sum.queue_ns += r.queue_ns;
    }
    let ok = |a: &&crate::metrics::ActionRecord| !a.failed;
    let global_act: u64 = m.actions.iter().filter(ok).map(|a| a.act().0).sum();
    let global_queue: u64 = m.actions.iter().filter(ok).map(|a| a.queue_dur().0).sum();
    if sum.actions != m.actions.len() as u64
        || sum.failed != m.failed_actions() as u64
        || sum.retries != m.total_retries()
        || sum.act_ns != global_act
        || sum.queue_ns != global_queue
    {
        v.push(Violation {
            invariant: "tenant-conservation",
            detail: format!(
                "rollup sum {sum:?} != global ({} actions, {} failed, {} retries, \
                 {global_act} act_ns, {global_queue} queue_ns)",
                m.actions.len(),
                m.failed_actions(),
                m.total_retries()
            ),
        });
    }
    // the recorded trace agrees tenant-by-tenant with the records
    let ts = trace_tenant_stats(&out.events);
    for t in ts.keys() {
        if !declared.contains(t) {
            v.push(Violation {
                invariant: "tenant-conservation",
                detail: format!("undeclared tenant {t} in the recorded trace"),
            });
        }
    }
    for (t, r) in &rollups {
        let seen = ts.get(t).map_or(0, |s| s.actions as u64);
        if seen != r.actions {
            v.push(Violation {
                invariant: "tenant-conservation",
                detail: format!(
                    "tenant {t}: trace completed {seen} actions, records hold {}",
                    r.actions
                ),
            });
        }
    }
}

/// WFQ neutrality: installing an all-equal weight table must be a no-op.
/// A multi-tenant run with every weight forced to 1 must produce a trace
/// and metrics stream byte-identical to the same run with no weight table
/// installed at all — per-tenant WFQ at uniform weight IS arrival order.
fn check_wfq_neutrality(spec: &ScenarioSpec, v: &mut Vec<Violation>) -> Result<()> {
    if spec.tenants.is_empty() {
        return Ok(());
    }
    let mut eq = spec.clone();
    eq.cost = None; // cost attribution is post-run reporting; keep arms equal
    for t in &mut eq.tenants {
        t.weight = 1;
    }
    // normal path: the Session installs the all-ones weight table
    let weighted = crate::scenario::run_scenario(&eq, BackendKind::Tangram)?;
    // manual session: identical hooks, but no weight table installed
    let cat = Catalog::build(&eq.catalog);
    let wls = eq.workloads_for(BackendKind::Tangram);
    let mut be = build_backend(&eq.catalog, &cat, BackendKind::Tangram);
    let mut session = Session::new()
        .with_injections(eq.events.clone())
        .with_recorder(TraceRecorder::new());
    if let Some(a) = eq.autoscale.clone() {
        session = session.with_autoscaler(Autoscaler::new(a));
    }
    let cfg = eq.run_cfg();
    let metrics = run_session(be.as_mut(), &cat, &wls, &cfg, &mut session);
    let events = session.take_recorder().map(|r| r.events).unwrap_or_default();
    if events != weighted.events {
        let divs = crate::scenario::diff_traces(&weighted.events, &events, 3);
        v.push(Violation {
            invariant: "wfq-neutrality",
            detail: format!("equal weights != unweighted: {}", divs.join("; ")),
        });
    }
    if metrics.to_json().to_string() != weighted.metrics.to_json().to_string() {
        v.push(Violation {
            invariant: "wfq-neutrality",
            detail: "equal-weights metrics diverged from the unweighted run".to_string(),
        });
    }
    Ok(())
}

/// Sharded- and threaded-drain parity, composed so one fuzz seed covers
/// both knobs: re-running the dirty-pool configuration with the drain
/// partitioned across 3 logical shards *and* decided on 2 worker threads
/// must serialize to the exact trace-file bytes of the serial run — the
/// worker-count-independence contract behind `--shards N --threads N`
/// (contiguous chunks of the sorted pool order, decided in parallel,
/// applied in ascending shard order). On a mismatch, a third run at the
/// same shard count but one thread attributes the divergence to the shard
/// partition or to the worker pool.
fn check_shards_parity(
    spec: &ScenarioSpec,
    dirty: &ScenarioOutcome,
    v: &mut Vec<Violation>,
) -> Result<()> {
    let (threaded, _) = run_scenario_tangram_threaded(spec, false, 3, 2)?;
    let serial_text = trace_file_contents(spec, BackendKind::Tangram, dirty);
    let threaded_text = trace_file_contents(spec, BackendKind::Tangram, &threaded);
    if serial_text != threaded_text {
        let divs = crate::scenario::diff_traces(&dirty.events, &threaded.events, 3);
        // attribute: does the same shard count diverge without the pool?
        let (sharded, _) = run_scenario_tangram_sharded(spec, false, 3)?;
        let sharded_text = trace_file_contents(spec, BackendKind::Tangram, &sharded);
        if sharded_text != serial_text {
            v.push(Violation {
                invariant: "shards-parity",
                detail: format!(
                    "shards=3 trace bytes diverged from the serial drain: {}",
                    divs.join("; ")
                ),
            });
        } else {
            v.push(Violation {
                invariant: "threads-parity",
                detail: format!(
                    "shards=3 threads=2 trace bytes diverged from the serial drain \
                     (shards=3 alone matches): {}",
                    divs.join("; ")
                ),
            });
        }
    }
    Ok(())
}

// ---- failure minimization -------------------------------------------------

/// [`Gen`] over fuzzed specs whose `shrink` simplifies a failing spec's
/// timeline simplest-first: drop the fault timeline, then the autoscaler,
/// then the cost card, then halve the run and the catalog.
pub struct FuzzSpecGen;

impl Gen for FuzzSpecGen {
    type Value = ScenarioSpec;

    fn generate(&self, rng: &mut Rng) -> ScenarioSpec {
        // keep the derived fuzz seed inside the spec-validated 2^53 bound
        fuzz_spec(rng.next_u64() >> 11)
    }

    fn shrink(&self, spec: &ScenarioSpec) -> Vec<ScenarioSpec> {
        let mut out = Vec::new();
        let mut push = |s: ScenarioSpec| {
            if s.validate().is_ok() {
                out.push(s);
            }
        };
        if !spec.tenants.is_empty() {
            // single-tenant twin: same work, no tenancy dimension
            let mut s = spec.clone();
            s.workloads = s.tenants.iter().flat_map(|t| t.workloads.iter().copied()).collect();
            s.tenants.clear();
            push(s);
            if spec.tenants.len() > 1 {
                // dropping the last mix keeps ids strictly increasing
                let mut s = spec.clone();
                s.tenants.truncate(spec.tenants.len() - 1);
                push(s);
            }
        }
        if !spec.events.is_empty() {
            push(ScenarioSpec { events: vec![], ..spec.clone() });
        }
        if spec.autoscale.is_some() {
            push(ScenarioSpec { autoscale: None, ..spec.clone() });
        }
        if spec.cost.is_some() {
            push(ScenarioSpec { cost: None, ..spec.clone() });
        }
        if spec.events.len() > 1 {
            push(ScenarioSpec {
                events: spec.events[..spec.events.len() / 2].to_vec(),
                ..spec.clone()
            });
            push(ScenarioSpec {
                events: spec.events[..spec.events.len() - 1].to_vec(),
                ..spec.clone()
            });
        }
        if spec.workloads.len() > 1 {
            push(ScenarioSpec { workloads: spec.workloads[..1].to_vec(), ..spec.clone() });
        }
        if spec.batch > 1 {
            push(ScenarioSpec { batch: spec.batch / 2, ..spec.clone() });
        }
        if spec.steps > 1 {
            push(ScenarioSpec { steps: 1, ..spec.clone() });
        }
        if spec.arrival_spread.0 > 0 {
            push(ScenarioSpec { arrival_spread: crate::sim::SimDur(0), ..spec.clone() });
        }
        if spec.catalog.cpu_nodes > 1 || spec.catalog.gpu_nodes > 1 {
            let mut cat = spec.catalog.clone();
            cat.cpu_nodes = 1;
            cat.gpu_nodes = 1;
            push(ScenarioSpec { catalog: cat, ..spec.clone() });
        }
        out
    }
}

/// Shrink a violating spec to the simplest spec that still violates *some*
/// invariant, re-running the full battery on every candidate. Returns the
/// minimized spec and its violation summary.
pub fn minimize_failure(spec: ScenarioSpec, msg: String) -> (ScenarioSpec, String) {
    let prop = |s: &ScenarioSpec| match check_spec(s) {
        Ok(r) if r.is_clean() => Ok(()),
        Ok(r) => Err(r.describe()),
        Err(e) => Err(format!("engine error: {e}")),
    };
    // each probe is three full simulations; keep the budget modest
    shrink_failure(&FuzzSpecGen, spec, msg, &prop, 60)
}
