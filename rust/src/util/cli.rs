//! Tiny CLI argument parser (offline substitute for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! generates usage text. Enough for the launcher binary and examples.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
}

/// Declarative arg set + parsed values.
#[derive(Debug, Default)]
pub struct Args {
    specs: Vec<ArgSpec>,
    values: BTreeMap<String, String>,
    positional: Vec<String>,
    program: String,
    about: &'static str,
}

impl Args {
    pub fn new(about: &'static str) -> Self {
        Args { about, ..Default::default() }
    }

    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: None, is_flag: true });
        self
    }

    /// Parse from an iterator (first item = program name). Returns usage text
    /// as Err on `--help` or malformed input.
    pub fn parse_from<I: IntoIterator<Item = String>>(mut self, args: I) -> Result<Self, String> {
        let mut it = args.into_iter();
        self.program = it.next().unwrap_or_else(|| "prog".into());
        for s in &self.specs {
            if let Some(d) = &s.default {
                self.values.insert(s.name.to_string(), d.clone());
            }
        }
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n{}", self.usage()))?;
                let val = if spec.is_flag {
                    inline_val.unwrap_or_else(|| "true".into())
                } else {
                    match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{key} needs a value"))?,
                    }
                };
                self.values.insert(key, val);
            } else {
                self.positional.push(a);
            }
        }
        Ok(self)
    }

    pub fn parse(self) -> Result<Self, String> {
        self.parse_from(std::env::args())
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{}\n\nUSAGE: {} [OPTIONS]\n\nOPTIONS:\n", self.about, self.program);
        for spec in &self.specs {
            let d = spec
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{:<22} {}{}\n", spec.name, spec.help, d));
        }
        s
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> String {
        self.values.get(name).cloned().unwrap_or_default()
    }

    pub fn u64(&self, name: &str) -> u64 {
        self.values
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("--{name}: expected integer"))
    }

    pub fn f64(&self, name: &str) -> f64 {
        self.values
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("--{name}: expected number"))
    }

    pub fn bool(&self, name: &str) -> bool {
        matches!(self.values.get(name).map(|s| s.as_str()), Some("true" | "1" | "yes"))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> Args {
        Args::new("test")
            .opt("batch", "128", "batch size")
            .opt("mode", "sim", "mode")
            .flag("verbose", "chatty")
    }

    fn parse(args: &[&str]) -> Result<Args, String> {
        mk().parse_from(
            std::iter::once("prog".to_string()).chain(args.iter().map(|s| s.to_string())),
        )
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]).unwrap();
        assert_eq!(a.u64("batch"), 128);
        assert_eq!(a.str("mode"), "sim");
        assert!(!a.bool("verbose"));
    }

    #[test]
    fn overrides_and_flags() {
        let a = parse(&["--batch", "64", "--verbose", "--mode=real", "pos1"]).unwrap();
        assert_eq!(a.u64("batch"), 64);
        assert!(a.bool("verbose"));
        assert_eq!(a.str("mode"), "real");
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(parse(&["--nope"]).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let e = parse(&["--help"]).unwrap_err();
        assert!(e.contains("--batch"));
        assert!(e.contains("batch size"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse(&["--batch"]).is_err());
    }
}
