//! Minimal error type (offline substitute for `anyhow`/`thiserror`).
//!
//! The crate builds with zero external dependencies (see the note in
//! Cargo.toml); fallible paths that previously leaned on `anyhow` use this
//! string-backed error plus the [`err!`]/[`bail!`]/[`ensure!`] macros, which
//! mirror the `anyhow!` idiom closely enough that call sites read the same.

use std::fmt;

/// A string-backed error with `anyhow::Error`-like ergonomics.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Error(m.into())
    }

    /// Prefix the message with context (the `anyhow::Context` pattern).
    pub fn context(self, ctx: impl fmt::Display) -> Self {
        Error(format!("{ctx}: {}", self.0))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error(s.to_string())
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error(e.to_string())
    }
}

impl From<super::json::JsonError> for Error {
    fn from(e: super::json::JsonError) -> Self {
        Error(e.to_string())
    }
}

/// `Result` defaulted to [`Error`] (the `anyhow::Result` shape).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Build an [`Error`] from a format string (substitute for `anyhow!`).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] (substitute for `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*).into())
    };
}

/// Return early with an error unless `cond` holds (substitute for
/// `anyhow::ensure!`).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(!flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn construction_and_display() {
        let e = err!("bad value {}", 3);
        assert_eq!(e.to_string(), "bad value 3");
        assert_eq!(e.context("loading config").to_string(), "loading config: bad value 3");
    }

    #[test]
    fn conversions() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "missing");
        assert!(Error::from(io).to_string().contains("missing"));
        let e: Error = "plain".into();
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn bail_and_ensure() {
        fn f() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(f().unwrap_err().to_string(), "nope 1");
        assert_eq!(fails(false).unwrap(), 7);
        assert!(fails(true).is_err());
    }
}
